"""repro: Continuous Matrix Approximation on Distributed Data, at pod scale.

Core entry points:
    repro.core        — the paper's protocols (FD, HH, distributed tracking)
    repro.query       — coordinator query serving (store -> engine -> service)
    repro.models      — 10-arch decoder zoo (``--arch``)
    repro.launch      — mesh / dryrun / train / serve drivers
    repro.kernels     — Pallas TPU kernels + oracles
"""
__version__ = "1.0.0"
