"""Pallas TPU kernel: causal flash attention with GQA + sliding window.

TPU-native decomposition of the assigned-arch hot-spot (32k-token prefill):

    grid = (batch * q_heads, n_q_blocks, n_kv_blocks)   — kv innermost
    scratch (VMEM): acc (BQ, DH) f32, m/l (BQ, 128) f32 (lane-replicated)

Per (q-block, kv-block) step the kernel performs the online-softmax update
entirely in VMEM; KV blocks stream from HBM.  Causality and sliding windows
are enforced two ways: whole out-of-range KV blocks are *skipped* (pl.when
guard — on TPU the MXU work is predicated away, which is where the real
sub-quadratic win for SWA archs comes from), and partially-masked diagonal /
window-boundary blocks apply an in-VMEM mask.

GQA is free: grid dim 0 enumerates q heads; the kv BlockSpec index_map folds
the q head onto its kv head (h // group), so no repeat/copy of KV ever
materialises.

Restrictions (by design, this is the self-attention path): sq == skv,
sq % block_q == 0, skv % block_kv == 0, dh % 128 == 0.  Decode (sq=1) uses
the XLA path in models/attention.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 256
DEFAULT_BLOCK_KV = 512
NEG_INF = -1e30


def _flash_kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    acc_ref,
    m_ref,
    l_ref,
    *,
    scale: float,
    causal: bool,
    window: int,
    logit_softcap: float,
    block_q: int,
    block_kv: int,
    n_kv: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    q_start = qi * block_q
    q_last = q_start + block_q - 1
    k_start = ki * block_kv
    k_last = k_start + block_kv - 1

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # Whole-block validity (static per grid point except via program_id).
    valid = jnp.bool_(True)
    if causal:
        valid &= k_start <= q_last
    if window > 0:
        # Needed iff some q row in this block can still see the kv block:
        # the earliest visible kpos for the block is q_start - window + 1.
        valid &= k_last > q_start - window

    @pl.when(valid)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale  # (BQ, DH)
        k = k_ref[0, 0].astype(jnp.float32)  # (BKV, DH)
        v = v_ref[0, 0].astype(jnp.float32)  # (BKV, DH)
        s = jax.lax.dot_general(
            q, k, dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # (BQ, BKV)
        if logit_softcap > 0.0:
            s = logit_softcap * jnp.tanh(s / logit_softcap)

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 1)
        mask = jnp.ones((block_q, block_kv), jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window > 0:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, :1]  # (BQ, 1), lane-replicated storage
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        l_new = alpha * l_ref[:, :1] + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    # Finalise once the last *valid* kv block for this q block is done.
    if causal:
        ki_last = jnp.minimum(q_last // block_kv, n_kv - 1)
    else:
        ki_last = n_kv - 1

    @pl.when(ki == ki_last)
    def _final():
        l = l_ref[:, :1]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    scale: float | None = None,
    logit_softcap: float = 0.0,
    block_q: int = DEFAULT_BLOCK_Q,
    block_kv: int = DEFAULT_BLOCK_KV,
    interpret: bool = False,
) -> jax.Array:
    """q: (b, hq, s, dh); k, v: (b, hkv, s, dh); returns (b, hq, s, dh)."""
    b, hq, sq, dh = q.shape
    _, hkv, skv, _ = k.shape
    if sq != skv:
        raise ValueError("flash kernel is the self-attention path (sq == skv)")
    block_q = min(block_q, sq)
    block_kv = min(block_kv, skv)
    if sq % block_q or skv % block_kv:
        raise ValueError(f"seq {sq} not divisible by blocks ({block_q}, {block_kv})")
    if hq % hkv:
        raise ValueError(f"GQA needs hq % hkv == 0, got {hq}, {hkv}")
    group = hq // hkv
    if scale is None:
        scale = dh**-0.5
    n_q = sq // block_q
    n_kv = skv // block_kv

    kernel = functools.partial(
        _flash_kernel,
        scale=scale,
        causal=causal,
        window=window,
        logit_softcap=logit_softcap,
        block_q=block_q,
        block_kv=block_kv,
        n_kv=n_kv,
    )
    grid = (b * hq, n_q, n_kv)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, dh), lambda bh, qi, ki: (bh // hq, bh % hq, qi, 0)),
            pl.BlockSpec(
                (1, 1, block_kv, dh),
                lambda bh, qi, ki: (bh // hq, (bh % hq) // group, ki, 0),
            ),
            pl.BlockSpec(
                (1, 1, block_kv, dh),
                lambda bh, qi, ki: (bh // hq, (bh % hq) // group, ki, 0),
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, block_q, dh), lambda bh, qi, ki: (bh // hq, bh % hq, qi, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, dh), jnp.float32),
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, 128), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
