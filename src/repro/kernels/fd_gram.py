"""Pallas TPU kernel: FD Gram product ``G = B @ B.T``.

The Frequent Directions shrink computes the Gram matrix of the (2l, d) row
buffer.  ``2l`` is small (128–512) while ``d`` is the model/feature dimension
(up to 4096+), so the natural TPU decomposition streams d-blocks from HBM
through VMEM and accumulates the (2l, 2l) result in VMEM:

    grid = (d / BLOCK_D,)
    step i:  G += B[:, i*BD:(i+1)*BD] @ B[:, i*BD:(i+1)*BD].T     (MXU)

VMEM working set per step: L*BD (input block, bf16/f32) + L*L (accumulator,
f32).  With L=512, BD=512, f32: 1 MiB + 1 MiB — comfortably inside the
~16 MiB v5e VMEM, and the MXU sees (512, 512) x (512, 512) tiles, fully
aligned to the 128-lane requirement.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_D = 512


def _gram_kernel(b_ref, o_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    blk = b_ref[...].astype(jnp.float32)
    o_ref[...] += jax.lax.dot_general(
        blk,
        blk,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def fd_gram_pallas(
    b: jax.Array,
    *,
    block_d: int = DEFAULT_BLOCK_D,
    interpret: bool = False,
) -> jax.Array:
    """``B @ B.T`` with d-streaming accumulation.  B: (L, d), L % 8 == 0,
    d % block_d == 0 (pad upstream — ``fd_ops.fd_gram`` does)."""
    l, d = b.shape
    if d % block_d != 0:
        raise ValueError(f"d={d} must be a multiple of block_d={block_d}")
    grid = (d // block_d,)
    return pl.pallas_call(
        _gram_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((l, block_d), lambda i: (0, i))],
        out_specs=pl.BlockSpec((l, l), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((l, l), jnp.float32),
        interpret=interpret,
    )(b)
