"""Pallas TPU kernel: fused batched quadratic form ``q_j = ||B x_j||^2``.

The coordinator answers the paper's query — ``||A x||^2`` estimated by
``||B x||^2`` — for *batches* of directions at serving time
(``repro.query.engine``).  Unfused, that is a (L, d) x (d, N) matmul whose
(L, N) product round-trips HBM before the square-and-reduce pass.  The kernel
keeps the product tile VMEM-resident and folds the reduction into the final
d-step, so the (L, N) intermediate never touches HBM:

    grid = (N / BLOCK_N, d / BLOCK_D)          # d innermost
    step (j, i):  acc += B[:, blk_i] @ X[blk_j, blk_i].T          (MXU)
    step (j, nd-1):  out[blk_j] = sum_L acc * acc                 (VPU)

VMEM working set: L*BLOCK_D + BLOCK_N*BLOCK_D inputs plus the (L, BLOCK_N)
f32 accumulator — with L=128, BLOCK_N=256, BLOCK_D=512 under 1 MiB, far
inside v5e VMEM, and every matmul tile is 128-lane aligned.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_N = 256
DEFAULT_BLOCK_D = 512


def _quadform_kernel(b_ref, x_ref, o_ref, acc_ref):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        b_ref[...].astype(jnp.float32),
        x_ref[...].astype(jnp.float32),
        dimension_numbers=(((1,), (1,)), ((), ())),  # B_blk @ X_blk.T
        preferred_element_type=jnp.float32,
    )

    @pl.when(pl.program_id(1) == pl.num_programs(1) - 1)
    def _reduce():
        acc = acc_ref[...]
        o_ref[...] = jnp.sum(acc * acc, axis=0, keepdims=True)


def _quadform_packed_kernel(b_ref, x_ref, o_ref, acc_ref):
    # Same contraction as _quadform_kernel with a leading tenant grid axis:
    # each (tenant, query-block) owns its own accumulator lifetime because
    # the d axis stays innermost.
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        b_ref[0].astype(jnp.float32),
        x_ref[0].astype(jnp.float32),
        dimension_numbers=(((1,), (1,)), ((), ())),  # B_t_blk @ X_t_blk.T
        preferred_element_type=jnp.float32,
    )

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _reduce():
        acc = acc_ref[...]
        o_ref[...] = jnp.sum(acc * acc, axis=0)[None, None, :]


def quadform_packed_pallas(
    b: jax.Array,
    x: jax.Array,
    *,
    block_n: int = DEFAULT_BLOCK_N,
    block_d: int = DEFAULT_BLOCK_D,
    interpret: bool = False,
) -> jax.Array:
    """Cross-tenant packed quadratic forms: one launch for T sketches.

    b: (T, L, d) stacked sketches, x: (T, N, d) per-tenant direction blocks
    -> (T, 1, N) f32 with out[t, 0, j] = ||B_t x_tj||^2.  Shape rules match
    ``quadform_pallas`` per tenant (pad upstream; zero rows are exact
    no-ops).  This is the serving layer's batch-packing primitive: queued
    queries for different tenants whose sketches share (L, d) ride a single
    kernel launch instead of T dispatches.
    """
    t, l, d = b.shape
    tx, n, dx = x.shape
    if (tx, dx) != (t, d):
        raise ValueError(f"packed directions {x.shape} incompatible with sketches {b.shape}")
    if n % block_n != 0 or d % block_d != 0:
        raise ValueError(f"(N={n}, d={d}) must tile into ({block_n}, {block_d}) blocks")
    grid = (t, n // block_n, d // block_d)  # d innermost, tenant outermost
    return pl.pallas_call(
        _quadform_packed_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, l, block_d), lambda t, j, i: (t, 0, i)),  # B_t
            pl.BlockSpec((1, block_n, block_d), lambda t, j, i: (t, j, i)),  # X_t
        ],
        out_specs=pl.BlockSpec((1, 1, block_n), lambda t, j, i: (t, 0, j)),
        out_shape=jax.ShapeDtypeStruct((t, 1, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((l, block_n), jnp.float32)],
        interpret=interpret,
    )(b, x)


def quadform_pallas(
    b: jax.Array,
    x: jax.Array,
    *,
    block_n: int = DEFAULT_BLOCK_N,
    block_d: int = DEFAULT_BLOCK_D,
    interpret: bool = False,
) -> jax.Array:
    """``sum_i (B @ x_j)_i^2`` for every row x_j of X.

    b: (L, d) sketch, x: (N, d) directions -> (1, N) f32.
    L % 8 == 0, N % block_n == 0, d % block_d == 0 (pad upstream —
    ``repro.kernels.ops.quadform`` does; zero pad rows/cols are exact no-ops).
    """
    l, d = b.shape
    n, dx = x.shape
    if dx != d:
        raise ValueError(f"direction dim {dx} != sketch dim {d}")
    if n % block_n != 0 or d % block_d != 0:
        raise ValueError(f"(N={n}, d={d}) must tile into ({block_n}, {block_d}) blocks")
    grid = (n // block_n, d // block_d)
    return pl.pallas_call(
        _quadform_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((l, block_d), lambda j, i: (0, i)),  # B, streams d
            pl.BlockSpec((block_n, block_d), lambda j, i: (j, i)),  # X
        ],
        out_specs=pl.BlockSpec((1, block_n), lambda j, i: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((l, block_n), jnp.float32)],
        interpret=interpret,
    )(b, x)
