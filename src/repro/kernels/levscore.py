"""Pallas TPU kernel: fused batched ridge-leverage scoring ``tau_j = x_j^T M x_j``.

Leverage protocols score candidate rows against a precomputed factor
``M = (B^T B + lambda I)^+`` (see ``core/leverage.py``).  Scoring S rows
one at a time is S matvec pairs (``M @ x`` then ``x . (M x)``) with S
dispatches; unfused batch scoring materializes the (S, d) product
``X @ M`` in HBM before the multiply-and-reduce pass.  The kernel reuses
the ``quadform`` tiling discipline — d innermost, the intermediate kept
VMEM-resident — so scoring S rows costs one fused sweep over M:

    grid = (N / BLOCK_N, d / BLOCK_D)          # d innermost
    step (j, i):  y = X[blk_j, :] @ M[:, blk_i]              (MXU)
                  o[blk_j] += sum_d y * X[blk_j, blk_i]      (VPU)

The (S, d) intermediate ``X @ M`` never touches HBM: each (BLOCK_N,
BLOCK_D) column slab of it lives only as ``y``.  VMEM working set:
BLOCK_N*d (full query rows) + d*BLOCK_D (the M slab) + BLOCK_N*BLOCK_D
f32 — with BLOCK_N=256, BLOCK_D=512, d<=2048 about 3 MiB, inside v5e
VMEM, and every matmul tile is 128-lane aligned.

``X`` is passed twice under two BlockSpecs (full rows for the contraction,
the (j, i) slab for the reduce) — two views of one HBM buffer, not a copy.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.quadform import DEFAULT_BLOCK_D, DEFAULT_BLOCK_N


def _levscore_kernel(xf_ref, m_ref, xs_ref, o_ref):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    y = jax.lax.dot_general(
        xf_ref[...].astype(jnp.float32),
        m_ref[...].astype(jnp.float32),
        dimension_numbers=(((1,), (0,)), ((), ())),  # X_blk @ M[:, blk_i]
        preferred_element_type=jnp.float32,
    )
    o_ref[...] += jnp.sum(y * xs_ref[...].astype(jnp.float32), axis=1)[None, :]


def levscore_pallas(
    m: jax.Array,
    x: jax.Array,
    *,
    block_n: int = DEFAULT_BLOCK_N,
    block_d: int = DEFAULT_BLOCK_D,
    interpret: bool = False,
) -> jax.Array:
    """``tau_j = x_j^T M x_j`` for every row x_j of X.

    m: (d, d) scoring factor, x: (N, d) rows -> (1, N) f32.
    N % block_n == 0, d % block_d == 0 (pad upstream —
    ``repro.kernels.ops.levscore`` does; zero pad rows/cols are exact
    no-ops).  M need not be symmetric; only ``x^T M x`` is computed.
    """
    d, d2 = m.shape
    n, dx = x.shape
    if d != d2:
        raise ValueError(f"scoring factor must be square, got {m.shape}")
    if dx != d:
        raise ValueError(f"row dim {dx} != factor dim {d}")
    if n % block_n != 0 or d % block_d != 0:
        raise ValueError(f"(N={n}, d={d}) must tile into ({block_n}, {block_d}) blocks")
    grid = (n // block_n, d // block_d)
    return pl.pallas_call(
        _levscore_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda j, i: (j, 0)),  # X, full rows
            pl.BlockSpec((d, block_d), lambda j, i: (0, i)),  # M, streams d
            pl.BlockSpec((block_n, block_d), lambda j, i: (j, i)),  # X slab
        ],
        out_specs=pl.BlockSpec((1, block_n), lambda j, i: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, n), jnp.float32),
        interpret=interpret,
    )(x, m, x)
