"""Jit'd wrappers for the FD Pallas kernels (padding + backend dispatch).

``interpret`` defaults to True off-TPU so the same call sites work in this
CPU container and on real hardware.  Padding: L to a multiple of 8 (f32
sublane), d to a multiple of the d-block.  Zero rows/cols are exact no-ops
for both kernels.

``path`` follows the ``ops.levscore`` convention: ``auto`` routes to the
Pallas kernel on a real accelerator and to the jit'd XLA reference wherever
the kernel would run in interpret mode (interpreted Pallas loses to XLA on
CPU); ``"pallas"`` / ``"xla"`` force one implementation.  Both paths agree
to 1e-5 (regression-tested).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.fd_gram import DEFAULT_BLOCK_D, fd_gram_pallas
from repro.kernels.fd_project import fd_project_pallas

__all__ = ["FD_PATHS", "fd_gram", "fd_project"]

FD_PATHS = ("auto", "pallas", "xla")


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def _use_xla(path: str, interpret: bool | None, which: str) -> tuple[bool, bool]:
    """Resolve (use_xla, interpret) for one call under the shared convention."""
    if path not in FD_PATHS:
        raise ValueError(f"unknown {which} path {path!r}; choose from {FD_PATHS}")
    if interpret is None:
        interpret = not _on_tpu()
    return path == "xla" or (path == "auto" and interpret), interpret


@jax.jit
def _gram_xla(b):
    from repro.kernels.ref import ref_fd_gram

    return ref_fd_gram(b)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def _gram_padded(b, *, block_d, interpret):
    return fd_gram_pallas(b, block_d=block_d, interpret=interpret)


def fd_gram(
    b: jax.Array,
    *,
    block_d: int = 0,
    interpret: bool | None = None,
    path: str = "auto",
) -> jax.Array:
    """``B @ B.T`` (f32), backend-dispatched, any (L, d)."""
    use_xla, interpret = _use_xla(path, interpret, "fd_gram")
    if use_xla:
        return _gram_xla(b)
    l, d = b.shape
    if block_d <= 0:
        block_d = min(DEFAULT_BLOCK_D, _pad_to(d, 128))
    lp = _pad_to(max(l, 8), 8)
    dp = _pad_to(d, block_d)
    bp = jnp.pad(b, ((0, lp - l), (0, dp - d)))
    g = _gram_padded(bp, block_d=block_d, interpret=interpret)
    return g[:l, :l]


@jax.jit
def _project_xla(w, u, b):
    from repro.kernels.ref import ref_fd_project

    return ref_fd_project(w, u, b)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def _project_padded(w, u, b, *, block_d, interpret):
    return fd_project_pallas(w, u, b, block_d=block_d, interpret=interpret)


def fd_project(
    w: jax.Array,
    u: jax.Array,
    b: jax.Array,
    *,
    block_d: int = 0,
    interpret: bool | None = None,
    path: str = "auto",
) -> jax.Array:
    """``diag(w) @ (U.T @ B)``, backend-dispatched, any (L,), (L,L), (L,d)."""
    use_xla, interpret = _use_xla(path, interpret, "fd_project")
    if use_xla:
        return _project_xla(w, u, b)
    l, d = b.shape
    if block_d <= 0:
        block_d = min(DEFAULT_BLOCK_D, _pad_to(d, 128))
    lp = _pad_to(max(l, 8), 8)
    dp = _pad_to(d, block_d)
    wp = jnp.pad(w, (0, lp - l))
    up = jnp.pad(u, ((0, lp - l), (0, lp - l)))
    bp = jnp.pad(b, ((0, lp - l), (0, dp - d)))
    out = _project_padded(wp, up, bp, block_d=block_d, interpret=interpret)
    return out[:l, :d]
