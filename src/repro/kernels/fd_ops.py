"""Jit'd wrappers for the FD Pallas kernels (padding + interpret dispatch).

``interpret`` defaults to True off-TPU so the same call sites work in this
CPU container and on real hardware.  Padding: L to a multiple of 8 (f32
sublane), d to a multiple of the d-block.  Zero rows/cols are exact no-ops
for both kernels.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.fd_gram import DEFAULT_BLOCK_D, fd_gram_pallas
from repro.kernels.fd_project import fd_project_pallas

__all__ = ["fd_gram", "fd_project"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: int, mult: int) -> int:
    return -(-x // mult) * mult


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def _gram_padded(b, *, block_d, interpret):
    return fd_gram_pallas(b, block_d=block_d, interpret=interpret)


def fd_gram(b: jax.Array, *, block_d: int = 0, interpret: bool | None = None) -> jax.Array:
    """``B @ B.T`` (f32) via the Pallas kernel, any (L, d)."""
    if interpret is None:
        interpret = not _on_tpu()
    l, d = b.shape
    if block_d <= 0:
        block_d = min(DEFAULT_BLOCK_D, _pad_to(d, 128))
    lp = _pad_to(max(l, 8), 8)
    dp = _pad_to(d, block_d)
    bp = jnp.pad(b, ((0, lp - l), (0, dp - d)))
    g = _gram_padded(bp, block_d=block_d, interpret=interpret)
    return g[:l, :l]


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def _project_padded(w, u, b, *, block_d, interpret):
    return fd_project_pallas(w, u, b, block_d=block_d, interpret=interpret)


def fd_project(
    w: jax.Array, u: jax.Array, b: jax.Array, *, block_d: int = 0, interpret: bool | None = None
) -> jax.Array:
    """``diag(w) @ (U.T @ B)`` via the Pallas kernel, any (L,), (L,L), (L,d)."""
    if interpret is None:
        interpret = not _on_tpu()
    l, d = b.shape
    if block_d <= 0:
        block_d = min(DEFAULT_BLOCK_D, _pad_to(d, 128))
    lp = _pad_to(max(l, 8), 8)
    dp = _pad_to(d, block_d)
    wp = jnp.pad(w, (0, lp - l))
    up = jnp.pad(u, ((0, lp - l), (0, lp - l)))
    bp = jnp.pad(b, ((0, lp - l), (0, dp - d)))
    out = _project_padded(wp, up, bp, block_d=block_d, interpret=interpret)
    return out[:l, :d]
