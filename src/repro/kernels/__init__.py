"""Pallas TPU kernels for the paper's compute hot-spots.

fd_gram / fd_project — the two matmul-shaped stages of the Frequent
Directions shrink; quadform — the batched ``||B x||^2`` serving hot-spot
(repro.query); flash_attention — the assigned-arch prefill hot-spot.
Each kernel has a pure-jnp oracle in ref.py and a jit'd wrapper in ops.py /
fd_ops.py.  On CPU the wrappers dispatch with interpret=True.
"""
