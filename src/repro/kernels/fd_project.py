"""Pallas TPU kernel: fused FD shrink projection ``B' = diag(w) @ (U.T @ B)``.

After the eigendecomposition ``B B^T = U diag(lam) U^T``, Frequent Directions
rebuilds the shrunk sketch as ``diag(w) U^T B`` with
``w = sqrt(max(lam - delta, 0) / lam)``.  Unfused, this is a (L,L)x(L,d)
matmul plus a full (L,d) rescale pass over HBM; fusing the rescale into the
matmul epilogue saves one complete read+write of the (L,d) product.

    grid = (d / BLOCK_D,)
    step i:  out[:, blk_i] = w[:, None] * (U.T @ B[:, blk_i])      (MXU + VPU)

U (L,L) and w (L,1) stay VMEM-resident across all grid steps (their
index_map is constant), B streams through.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_D = 512


def _project_kernel(w_ref, u_ref, b_ref, o_ref):
    ut_b = jax.lax.dot_general(
        u_ref[...].astype(jnp.float32),
        b_ref[...].astype(jnp.float32),
        dimension_numbers=(((0,), (0,)), ((), ())),  # U.T @ B_blk
        preferred_element_type=jnp.float32,
    )
    o_ref[...] = (w_ref[...] * ut_b).astype(o_ref.dtype)


def fd_project_pallas(
    w: jax.Array,
    u: jax.Array,
    b: jax.Array,
    *,
    block_d: int = DEFAULT_BLOCK_D,
    interpret: bool = False,
) -> jax.Array:
    """diag(w) @ (U.T @ B).  w: (L,), u: (L, L), b: (L, d)."""
    l, d = b.shape
    if u.shape != (l, l) or w.shape != (l,):
        raise ValueError(f"shape mismatch: w{w.shape} u{u.shape} b{b.shape}")
    if d % block_d != 0:
        raise ValueError(f"d={d} must be a multiple of block_d={block_d}")
    grid = (d // block_d,)
    return pl.pallas_call(
        _project_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((l, 1), lambda i: (0, 0)),  # w, resident
            pl.BlockSpec((l, l), lambda i: (0, 0)),  # U, resident
            pl.BlockSpec((l, block_d), lambda i: (0, i)),  # B, streamed
        ],
        out_specs=pl.BlockSpec((l, block_d), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((l, d), b.dtype),
        interpret=interpret,
    )(w[:, None], u, b)
