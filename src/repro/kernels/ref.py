"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contracts).

Tests sweep shapes/dtypes and assert the kernels (interpret=True on CPU)
match these to tight tolerances.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ref_fd_gram(b: jax.Array) -> jax.Array:
    """FD Gram product ``G = B @ B.T`` in f32.  b: (L, d) -> (L, L)."""
    b32 = b.astype(jnp.float32)
    return jnp.matmul(b32, b32.T, preferred_element_type=jnp.float32)


def ref_fd_project(w: jax.Array, u: jax.Array, b: jax.Array) -> jax.Array:
    """FD shrink projection ``diag(w) @ (U.T @ B)``.

    w: (L,), u: (L, L), b: (L, d) -> (L, d) in b's dtype.
    """
    out = w[:, None].astype(jnp.float32) * jnp.matmul(
        u.astype(jnp.float32).T, b.astype(jnp.float32), preferred_element_type=jnp.float32
    )
    return out.astype(b.dtype)


def ref_fd_gram_batched(b: jax.Array) -> jax.Array:
    """Stacked FD Grams ``G_t = B_t @ B_t.T``.  b: (T, L, d) -> (T, L, L)."""
    return jax.vmap(ref_fd_gram)(b)


def ref_fd_project_batched(w: jax.Array, u: jax.Array, b: jax.Array) -> jax.Array:
    """Stacked shrink projections ``diag(w_t) @ (U_t.T @ B_t)``.

    w: (T, L), u: (T, L, L), b: (T, L, d) -> (T, L, d) in b's dtype.
    """
    return jax.vmap(ref_fd_project)(w, u, b)


def ref_fd_shrink(b: jax.Array) -> tuple[jax.Array, jax.Array]:
    """One full FD shrink of a stacked buffer: (T, 2l, d) -> (B', delta).

    The oracle for ``ops.fd_shrink``: Gram -> eigh (descending) -> clamp ->
    ``delta_t = lam_t[l]`` -> guarded ``w`` -> projection, all batched over
    the leading tenant axis.  Returns ``(B', delta)`` with B' (T, 2l, d)
    and delta (T,) f32.  Also accepts unstacked (2l, d) -> ((2l, d), ()).
    """
    squeeze = b.ndim == 2
    bs = b[None] if squeeze else b
    g = ref_fd_gram_batched(bs)
    lam, u = jnp.linalg.eigh(g)  # ascending
    lam = jnp.flip(lam, axis=-1)
    u = jnp.flip(u, axis=-1)
    lam = jnp.maximum(lam, 0.0)
    half = bs.shape[1] // 2
    delta = lam[:, half]
    shifted = jnp.maximum(lam - delta[:, None], 0.0)
    w = jnp.sqrt(shifted / jnp.maximum(lam, 1e-30))
    w = jnp.where(lam <= 1e-30, 0.0, w)
    out = ref_fd_project_batched(w, u, bs)
    if squeeze:
        return out[0], delta[0]
    return out, delta


def ref_fd_spectra(b: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Stacked sketch spectra via the Gram trick: (T, l, d) -> (s, vt).

    The oracle for ``ops.fd_spectra``: ``s`` (T, l) descending singular
    values, ``vt`` (T, l, d) right singular directions (rows below
    ``1e-7 * s_max`` zeroed).  Matches a per-matrix SVD up to per-row sign.
    """
    g = ref_fd_gram_batched(b)
    lam, u = jnp.linalg.eigh(g)
    lam = jnp.maximum(jnp.flip(lam, axis=-1), 0.0)
    u = jnp.flip(u, axis=-1)
    s = jnp.sqrt(lam)
    tol = s[:, :1] * 1e-7
    w = jnp.where(s > tol, 1.0 / jnp.maximum(s, 1e-30), 0.0)
    vt = ref_fd_project_batched(w, u, b)
    return s, vt


def ref_levscore(m: jax.Array, x: jax.Array) -> jax.Array:
    """Batched quadratic form ``tau_j = x_j^T M x_j``.  m: (d, d), x: (N, d) -> (N,)."""
    xf = x.astype(jnp.float32)
    xm = jnp.matmul(xf, m.astype(jnp.float32), preferred_element_type=jnp.float32)
    return jnp.sum(xm * xf, axis=1)


def ref_quadform(b: jax.Array, x: jax.Array) -> jax.Array:
    """Batched quadratic form ``q_j = ||B x_j||^2``.  b: (L, d), x: (N, d) -> (N,)."""
    bx = jnp.matmul(
        b.astype(jnp.float32), x.astype(jnp.float32).T, preferred_element_type=jnp.float32
    )
    return jnp.sum(bx * bx, axis=0)


def ref_quadform_packed(b: jax.Array, x: jax.Array) -> jax.Array:
    """Packed form: b (T, L, d), x (T, N, d) -> (T, N); row t uses sketch t."""
    return jax.vmap(ref_quadform)(b, x)


def ref_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    scale: float | None = None,
    logit_softcap: float = 0.0,
) -> jax.Array:
    """Reference multi-head attention with GQA + sliding window.

    q: (b, hq, sq, dh); k, v: (b, hkv, skv, dh).  hq % hkv == 0.
    ``window`` > 0 masks keys further than ``window`` positions behind the
    query (sliding-window attention); 0 means unlimited.
    Query position i attends key positions [max(0, i+off-window+1), i+off]
    where off = skv - sq (decode-style alignment: queries are the last sq
    positions of the key stream).
    """
    b, hq, sq, dh = q.shape
    _, hkv, skv, _ = k.shape
    group = hq // hkv
    if scale is None:
        scale = dh**-0.5
    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    # Expand kv heads to q heads.
    kf = jnp.repeat(kf, group, axis=1)
    vf = jnp.repeat(vf, group, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", qf, kf)
    if logit_softcap > 0.0:
        logits = logit_softcap * jnp.tanh(logits / logit_softcap)
    off = skv - sq
    qpos = jnp.arange(sq)[:, None] + off
    kpos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    probs = jnp.where(jnp.isnan(probs), 0.0, probs)  # fully-masked rows
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, vf)
    return out.astype(q.dtype)
