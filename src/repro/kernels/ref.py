"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contracts).

Tests sweep shapes/dtypes and assert the kernels (interpret=True on CPU)
match these to tight tolerances.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ref_fd_gram(b: jax.Array) -> jax.Array:
    """FD Gram product ``G = B @ B.T`` in f32.  b: (L, d) -> (L, L)."""
    b32 = b.astype(jnp.float32)
    return jnp.matmul(b32, b32.T, preferred_element_type=jnp.float32)


def ref_fd_project(w: jax.Array, u: jax.Array, b: jax.Array) -> jax.Array:
    """FD shrink projection ``diag(w) @ (U.T @ B)``.

    w: (L,), u: (L, L), b: (L, d) -> (L, d) in b's dtype.
    """
    out = w[:, None].astype(jnp.float32) * jnp.matmul(
        u.astype(jnp.float32).T, b.astype(jnp.float32), preferred_element_type=jnp.float32
    )
    return out.astype(b.dtype)


def ref_levscore(m: jax.Array, x: jax.Array) -> jax.Array:
    """Batched quadratic form ``tau_j = x_j^T M x_j``.  m: (d, d), x: (N, d) -> (N,)."""
    xf = x.astype(jnp.float32)
    xm = jnp.matmul(xf, m.astype(jnp.float32), preferred_element_type=jnp.float32)
    return jnp.sum(xm * xf, axis=1)


def ref_quadform(b: jax.Array, x: jax.Array) -> jax.Array:
    """Batched quadratic form ``q_j = ||B x_j||^2``.  b: (L, d), x: (N, d) -> (N,)."""
    bx = jnp.matmul(
        b.astype(jnp.float32), x.astype(jnp.float32).T, preferred_element_type=jnp.float32
    )
    return jnp.sum(bx * bx, axis=0)


def ref_quadform_packed(b: jax.Array, x: jax.Array) -> jax.Array:
    """Packed form: b (T, L, d), x (T, N, d) -> (T, N); row t uses sketch t."""
    return jax.vmap(ref_quadform)(b, x)


def ref_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    scale: float | None = None,
    logit_softcap: float = 0.0,
) -> jax.Array:
    """Reference multi-head attention with GQA + sliding window.

    q: (b, hq, sq, dh); k, v: (b, hkv, skv, dh).  hq % hkv == 0.
    ``window`` > 0 masks keys further than ``window`` positions behind the
    query (sliding-window attention); 0 means unlimited.
    Query position i attends key positions [max(0, i+off-window+1), i+off]
    where off = skv - sq (decode-style alignment: queries are the last sq
    positions of the key stream).
    """
    b, hq, sq, dh = q.shape
    _, hkv, skv, _ = k.shape
    group = hq // hkv
    if scale is None:
        scale = dh**-0.5
    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    # Expand kv heads to q heads.
    kf = jnp.repeat(kf, group, axis=1)
    vf = jnp.repeat(vf, group, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", qf, kf)
    if logit_softcap > 0.0:
        logits = logit_softcap * jnp.tanh(logits / logit_softcap)
    off = skv - sq
    qpos = jnp.arange(sq)[:, None] + off
    kpos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    probs = jnp.where(jnp.isnan(probs), 0.0, probs)  # fully-masked rows
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, vf)
    return out.astype(q.dtype)
