"""Pallas TPU kernels: batched FD shrink over a stacked tenant axis.

The multi-tenant ingest path stacks T same-shape FD buffers into one
``(T, L, d)`` array (``runtime/ingest_packed.py``); the shrink that used to
run per tenant — Gram, eigh, projection, three dispatches each — becomes
three *batched* stages over the whole pack:

  * ``fd_gram_batched_pallas``    — ``G_t = B_t @ B_t.T`` for every tenant in
    one launch: ``grid = (T, d / BLOCK_D)`` with d innermost, so each
    tenant's ``(L, L)`` accumulator lives exactly one d-sweep, the same
    lifetime trick ``quadform_packed`` uses.
  * (batched ``eigh`` over the stacked Grams — XLA's ``jnp.linalg.eigh``
    batches over leading axes natively; no kernel needed.)
  * ``fd_project_batched_pallas`` — ``B'_t = diag(w_t) @ (U_t.T @ B_t)`` with
    the rescale fused into the matmul epilogue, one launch for all T.

VMEM working set per step matches the single-tenant kernels (the leading
block axis is 1): L*BLOCK_D streamed block + L*L resident accumulator /
eigenvectors + L*1 weights.  ``ops.fd_shrink`` wraps the three stages with
padding + backend dispatch.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_D = 512


def _gram_batched_kernel(b_ref, o_ref):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    blk = b_ref[0].astype(jnp.float32)
    o_ref[...] += jax.lax.dot_general(
        blk,
        blk,
        dimension_numbers=(((1,), (1,)), ((), ())),  # B_t_blk @ B_t_blk.T
        preferred_element_type=jnp.float32,
    )[None]


def fd_gram_batched_pallas(
    b: jax.Array,
    *,
    block_d: int = DEFAULT_BLOCK_D,
    interpret: bool = False,
) -> jax.Array:
    """Stacked FD Gram products ``G_t = B_t @ B_t.T`` in one launch.

    b: (T, L, d) -> (T, L, L) f32.  L % 8 == 0, d % block_d == 0 (pad
    upstream — ``ops.fd_shrink`` does; zero rows/cols are exact no-ops).
    """
    t, l, d = b.shape
    if d % block_d != 0:
        raise ValueError(f"d={d} must be a multiple of block_d={block_d}")
    grid = (t, d // block_d)  # d innermost: one accumulator lifetime per tenant
    return pl.pallas_call(
        _gram_batched_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1, l, block_d), lambda t, i: (t, 0, i))],
        out_specs=pl.BlockSpec((1, l, l), lambda t, i: (t, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((t, l, l), jnp.float32),
        interpret=interpret,
    )(b)


def _project_batched_kernel(w_ref, u_ref, b_ref, o_ref):
    ut_b = jax.lax.dot_general(
        u_ref[0].astype(jnp.float32),
        b_ref[0].astype(jnp.float32),
        dimension_numbers=(((0,), (0,)), ((), ())),  # U_t.T @ B_t_blk
        preferred_element_type=jnp.float32,
    )
    o_ref[...] = (w_ref[0] * ut_b).astype(o_ref.dtype)[None]


def fd_project_batched_pallas(
    w: jax.Array,
    u: jax.Array,
    b: jax.Array,
    *,
    block_d: int = DEFAULT_BLOCK_D,
    interpret: bool = False,
) -> jax.Array:
    """Stacked shrink projections ``diag(w_t) @ (U_t.T @ B_t)`` in one launch.

    w: (T, L), u: (T, L, L), b: (T, L, d) -> (T, L, d) in b's dtype.  Each
    tenant's U and w stay VMEM-resident across its d-sweep; B streams.
    """
    t, l, d = b.shape
    if u.shape != (t, l, l) or w.shape != (t, l):
        raise ValueError(f"shape mismatch: w{w.shape} u{u.shape} b{b.shape}")
    if d % block_d != 0:
        raise ValueError(f"d={d} must be a multiple of block_d={block_d}")
    grid = (t, d // block_d)
    return pl.pallas_call(
        _project_batched_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, l, 1), lambda t, i: (t, 0, 0)),  # w_t, resident
            pl.BlockSpec((1, l, l), lambda t, i: (t, 0, 0)),  # U_t, resident
            pl.BlockSpec((1, l, block_d), lambda t, i: (t, 0, i)),  # B_t, streamed
        ],
        out_specs=pl.BlockSpec((1, l, block_d), lambda t, i: (t, 0, i)),
        out_shape=jax.ShapeDtypeStruct((t, l, d), b.dtype),
        interpret=interpret,
    )(w[:, :, None], u, b)
