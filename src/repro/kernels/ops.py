"""Jit'd public entry points for all Pallas kernels.

* ``fd_gram`` / ``fd_project`` — FD shrink hot-spots (see fd_ops.py).
* ``fd_shrink`` / ``fd_spectra`` — batched-over-tenants FD shrink and
  spectrum refresh (see fd_shrink_fused.py); one launch per stage serves a
  whole ``(T, 2l, d)`` pack.
* ``flash_attention``         — causal/GQA/windowed attention; pads seq to
  block multiples (padded key rows are masked out by causality + explicit
  length masking, padded q rows are dropped).

Backend dispatch convention (``path="auto"|"pallas"|"xla"``): ``auto``
routes to the fused Pallas kernel on a real accelerator and to the jit'd
XLA reference wherever the kernel would run in interpret mode — on CPU the
interpreted kernel measurably loses to XLA — with both paths pinned equal
to 1e-5 by regression tests.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.fd_ops import fd_gram, fd_project
from repro.kernels.fd_shrink_fused import (
    fd_gram_batched_pallas,
    fd_project_batched_pallas,
)
from repro.kernels.flash_attention import (
    DEFAULT_BLOCK_KV,
    DEFAULT_BLOCK_Q,
    flash_attention_pallas,
)
from repro.kernels.levscore import levscore_pallas
from repro.kernels.quadform import (
    DEFAULT_BLOCK_D,
    DEFAULT_BLOCK_N,
    quadform_pallas,
    quadform_packed_pallas,
)

__all__ = [
    "fd_gram",
    "fd_project",
    "fd_shrink",
    "fd_spectra",
    "flash_attention",
    "levscore",
    "quadform",
    "quadform_packed",
]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: int, mult: int) -> int:
    return -(-x // mult) * mult


@functools.partial(jax.jit, static_argnames=("block_n", "block_d", "interpret"))
def _quadform_padded(b, x, *, block_n, block_d, interpret):
    return quadform_pallas(b, x, block_n=block_n, block_d=block_d, interpret=interpret)


def quadform(
    b: jax.Array,
    x: jax.Array,
    *,
    block_n: int = 0,
    block_d: int = 0,
    interpret: bool | None = None,
) -> jax.Array:
    """Batched ``||B x_j||^2`` via the Pallas kernel, any (L, d) x (N, d) -> (N,).

    Pads L to the f32 sublane multiple and N/d to block multiples; zero
    rows/cols contribute zero to every dot product, so padding is exact.
    """
    if interpret is None:
        interpret = not _on_tpu()
    l, d = b.shape
    n = x.shape[0]
    if block_n <= 0:
        block_n = min(DEFAULT_BLOCK_N, _pad_to(n, 128))
    if block_d <= 0:
        block_d = min(DEFAULT_BLOCK_D, _pad_to(d, 128))
    lp = _pad_to(max(l, 8), 8)
    dp = _pad_to(d, block_d)
    np_ = _pad_to(max(n, block_n), block_n)
    bp = jnp.pad(b, ((0, lp - l), (0, dp - d)))
    xp = jnp.pad(x, ((0, np_ - n), (0, dp - d)))
    out = _quadform_padded(bp, xp, block_n=block_n, block_d=block_d, interpret=interpret)
    return out[0, :n]


@functools.partial(jax.jit, static_argnames=("block_n", "block_d", "interpret"))
def _quadform_packed_padded(b, x, *, block_n, block_d, interpret):
    return quadform_packed_pallas(b, x, block_n=block_n, block_d=block_d, interpret=interpret)


def quadform_packed(
    b: jax.Array,
    x: jax.Array,
    *,
    block_n: int = 0,
    block_d: int = 0,
    interpret: bool | None = None,
) -> jax.Array:
    """Cross-tenant packed ``||B_t x_tj||^2``: (T, L, d) x (T, N, d) -> (T, N).

    One Pallas launch serves every tenant in the pack (vs T separate
    ``quadform`` dispatches).  Padding rules match ``quadform``; zero pad
    rows/cols are exact no-ops, so ragged per-tenant query counts can be
    zero-padded up to a shared N.
    """
    if interpret is None:
        interpret = not _on_tpu()
    t, l, d = b.shape
    n = x.shape[1]
    if block_n <= 0:
        block_n = min(DEFAULT_BLOCK_N, _pad_to(n, 128))
    if block_d <= 0:
        block_d = min(DEFAULT_BLOCK_D, _pad_to(d, 128))
    lp = _pad_to(max(l, 8), 8)
    dp = _pad_to(d, block_d)
    np_ = _pad_to(max(n, block_n), block_n)
    bp = jnp.pad(b, ((0, 0), (0, lp - l), (0, dp - d)))
    xp = jnp.pad(x, ((0, 0), (0, np_ - n), (0, dp - d)))
    out = _quadform_packed_padded(bp, xp, block_n=block_n, block_d=block_d, interpret=interpret)
    return out[:, 0, :n]


@functools.partial(jax.jit, static_argnames=("block_n", "block_d", "interpret"))
def _levscore_padded(m, x, *, block_n, block_d, interpret):
    return levscore_pallas(m, x, block_n=block_n, block_d=block_d, interpret=interpret)


@jax.jit
def _levscore_xla(m, x):
    from repro.kernels.ref import ref_levscore

    return ref_levscore(m, x)


LEVSCORE_PATHS = ("auto", "pallas", "xla")


def levscore(
    m: jax.Array,
    x: jax.Array,
    *,
    block_n: int = 0,
    block_d: int = 0,
    interpret: bool | None = None,
    path: str = "auto",
) -> jax.Array:
    """Batched ``x_j^T M x_j``, backend-dispatched, (d, d) x (N, d) -> (N,).

    ``path="auto"`` picks per backend: the fused Pallas kernel on a real
    accelerator, the jit'd XLA reference contraction wherever the kernel
    would run in interpret mode — on CPU the interpreted kernel
    measurably *loses* to XLA (BENCH_leverage_protocols.json: ~100ms vs
    ~9ms for the same sweep), so falling back is the fast path, and both
    paths agree to 1e-5 (regression-tested).  ``path="pallas"`` /
    ``"xla"`` force one implementation (kernel tests, benchmarks).

    The Pallas path pads N/d to block multiples; zero pad rows/cols of M
    and X contribute zero to every quadratic form, so padding is exact.
    """
    if path not in LEVSCORE_PATHS:
        raise ValueError(f"unknown levscore path {path!r}; choose from {LEVSCORE_PATHS}")
    if interpret is None:
        interpret = not _on_tpu()
    if path == "xla" or (path == "auto" and interpret):
        return _levscore_xla(m, x)
    d = m.shape[0]
    n = x.shape[0]
    if block_n <= 0:
        block_n = min(DEFAULT_BLOCK_N, _pad_to(max(n, 1), 128))
    if block_d <= 0:
        block_d = min(DEFAULT_BLOCK_D, _pad_to(d, 128))
    dp = _pad_to(d, block_d)
    np_ = _pad_to(max(n, block_n), block_n)
    mp = jnp.pad(m, ((0, dp - d), (0, dp - d)))
    xp = jnp.pad(x, ((0, np_ - n), (0, dp - d)))
    out = _levscore_padded(mp, xp, block_n=block_n, block_d=block_d, interpret=interpret)
    return out[0, :n]


FD_SHRINK_PATHS = ("auto", "pallas", "xla")


@jax.jit
def _fd_shrink_xla(b):
    from repro.kernels.ref import ref_fd_shrink

    return ref_fd_shrink(b)


@functools.partial(jax.jit, static_argnames=("half", "block_d", "interpret"))
def _fd_shrink_fused(b, *, half, block_d, interpret):
    g = fd_gram_batched_pallas(b, block_d=block_d, interpret=interpret)
    lam, u = jnp.linalg.eigh(g)  # batched over T; ascending
    lam = jnp.maximum(jnp.flip(lam, axis=-1), 0.0)
    u = jnp.flip(u, axis=-1)
    delta = lam[:, half]
    w = jnp.sqrt(jnp.maximum(lam - delta[:, None], 0.0) / jnp.maximum(lam, 1e-30))
    w = jnp.where(lam <= 1e-30, 0.0, w)
    out = fd_project_batched_pallas(w, u, b, block_d=block_d, interpret=interpret)
    return out, delta


def fd_shrink(
    b: jax.Array,
    *,
    block_d: int = 0,
    interpret: bool | None = None,
    path: str = "auto",
) -> tuple[jax.Array, jax.Array]:
    """Batched FD shrink: (T, 2l, d) -> (B' (T, 2l, d), delta (T,)).

    One fused pipeline shrinks every tenant in a stacked pack: a single
    batched Gram launch, ONE batched ``eigh`` over the (T, 2l, 2l) Grams,
    and a single batched projection launch with the ``diag(w)`` rescale
    fused into the matmul epilogue — versus 3T dispatches for a Python
    loop of per-tenant ``fd_shrink`` calls.  Numerics match
    ``core.fd.fd_shrink`` row for row; also accepts an unstacked (2l, d)
    buffer (returns ((2l, d), ()) like the core routine).

    ``path`` follows the ``levscore`` dispatch convention: ``auto`` uses
    the Pallas kernels on a real accelerator and the jit'd XLA reference
    in interpret mode (where interpreted Pallas loses on CPU); both agree
    to 1e-5.  Pallas padding (2l to the f32 sublane multiple, d to the
    d-block) is exact: padded zero rows add zero eigenvalues, which sort
    past the shrink threshold and get weight zero.
    """
    if path not in FD_SHRINK_PATHS:
        raise ValueError(f"unknown fd_shrink path {path!r}; choose from {FD_SHRINK_PATHS}")
    if interpret is None:
        interpret = not _on_tpu()
    if path == "xla" or (path == "auto" and interpret):
        return _fd_shrink_xla(b)
    squeeze = b.ndim == 2
    bs = b[None] if squeeze else b
    _, two_l, d = bs.shape
    if block_d <= 0:
        block_d = min(DEFAULT_BLOCK_D, _pad_to(d, 128))
    lp = _pad_to(max(two_l, 8), 8)
    dp = _pad_to(d, block_d)
    bp = jnp.pad(bs, ((0, 0), (0, lp - two_l), (0, dp - d)))
    out, delta = _fd_shrink_fused(bp, half=two_l // 2, block_d=block_d, interpret=interpret)
    out = out[:, :two_l, :d]
    if squeeze:
        return out[0], delta[0]
    return out, delta


@jax.jit
def _fd_spectra_xla(b):
    from repro.kernels.ref import ref_fd_spectra

    return ref_fd_spectra(b)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def _fd_spectra_fused(b, *, block_d, interpret):
    g = fd_gram_batched_pallas(b, block_d=block_d, interpret=interpret)
    lam, u = jnp.linalg.eigh(g)
    lam = jnp.maximum(jnp.flip(lam, axis=-1), 0.0)
    u = jnp.flip(u, axis=-1)
    s = jnp.sqrt(lam)
    tol = s[:, :1] * 1e-7
    w = jnp.where(s > tol, 1.0 / jnp.maximum(s, 1e-30), 0.0)
    vt = fd_project_batched_pallas(w, u, b, block_d=block_d, interpret=interpret)
    return s, vt


def fd_spectra(
    b: jax.Array,
    *,
    block_d: int = 0,
    interpret: bool | None = None,
    path: str = "auto",
) -> tuple[jax.Array, jax.Array]:
    """Batched sketch spectra: (T, l, d) -> (s (T, l), vt (T, l, d)).

    The publish-time spectrum refresh: one batched Gram + ONE batched
    ``eigh`` + one batched projection recover every stacked sketch's
    singular values (descending) and right singular directions — the same
    ``(s, vt)`` pair ``QueryEngine``'s per-snapshot SVD produces, up to
    per-row sign (irrelevant to every served quantity, which squares the
    projections).  Rows whose singular value is below ``1e-7 * s_max``
    come back zero instead of noise.  ``path`` dispatches like
    ``fd_shrink``; requires l <= d (thin spectra).
    """
    if path not in FD_SHRINK_PATHS:
        raise ValueError(f"unknown fd_spectra path {path!r}; choose from {FD_SHRINK_PATHS}")
    if interpret is None:
        interpret = not _on_tpu()
    if b.ndim != 3 or b.shape[1] > b.shape[2]:
        raise ValueError(f"fd_spectra wants stacked (T, l, d) with l <= d, got {b.shape}")
    if path == "xla" or (path == "auto" and interpret):
        return _fd_spectra_xla(b)
    _, l, d = b.shape
    if block_d <= 0:
        block_d = min(DEFAULT_BLOCK_D, _pad_to(d, 128))
    lp = _pad_to(max(l, 8), 8)
    dp = _pad_to(d, block_d)
    bp = jnp.pad(b, ((0, 0), (0, lp - l), (0, dp - d)))
    s, vt = _fd_spectra_fused(bp, block_d=block_d, interpret=interpret)
    return s[:, :l], vt[:, :l, :d]


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "logit_softcap", "block_q", "block_kv", "interpret"),
)
def _flash_padded(q, k, v, *, causal, window, scale, logit_softcap, block_q, block_kv, interpret):
    return flash_attention_pallas(
        q,
        k,
        v,
        causal=causal,
        window=window,
        scale=scale,
        logit_softcap=logit_softcap,
        block_q=block_q,
        block_kv=block_kv,
        interpret=interpret,
    )


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    scale: float | None = None,
    logit_softcap: float = 0.0,
    block_q: int = DEFAULT_BLOCK_Q,
    block_kv: int = DEFAULT_BLOCK_KV,
    interpret: bool | None = None,
) -> jax.Array:
    """Self-attention (sq == skv) with seq padding to block multiples.

    Padded *key* positions sit at the end of the stream; causal masking plus
    the zero-query trick keeps them out of every real row's softmax.
    """
    if interpret is None:
        interpret = not _on_tpu()
    b, hq, s, dh = q.shape
    if scale is None:
        scale = dh**-0.5
    block_q = min(block_q, _pad_to(s, 128))
    block_kv = min(block_kv, _pad_to(s, 128))
    sp = _pad_to(s, max(block_q, block_kv))
    if sp != s:
        pad = ((0, 0), (0, 0), (0, sp - s), (0, 0))
        q = jnp.pad(q, pad)
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
    out = _flash_padded(
        q,
        k,
        v,
        causal=causal,
        window=window,
        scale=scale,
        logit_softcap=logit_softcap,
        block_q=block_q,
        block_kv=block_kv,
        interpret=interpret,
    )
    return out[:, :, :s, :]
