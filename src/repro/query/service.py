"""Admission + batching front-ends over the query engine.

Modeled on the ``ServeEngine`` host loop: callers submit single directions
(the traffic pattern of the paper's coordinator under heavy query load) and
the service coalesces them into kernel-sized batches so the hot path always
sees the fixed shapes the jit/Pallas stack compiles for.  Ragged tails are
zero-padded up to a power-of-two bucket (zero directions cost zero and are
discarded), bounding the number of compiled batch shapes to
``log2(max_batch)`` per tenant.

    svc = QueryService(engine, tenant="run-42")
    tickets = [svc.submit(x) for x in directions]
    svc.flush()                       # or wait for max_batch auto-flush
    tickets[0].result()               # (estimate, error_bound, version)

``PackedQueryService`` is the multi-tenant variant: cross-tenant packing,
per-query deadlines, and per-tenant admission control (bounded queue depth
with shed-and-report via ``QueryShedError``, priority-ordered dispatch
under overload).  ``stats()`` on either service reports served queries,
batches/flushes, padding overhead, shed counts, and the measured
queries/sec of the engine-facing hot path.

``ServicePump`` is the real deadline executor: a small background thread
driving ``PackedQueryService.poll()`` so per-entry deadlines hold even
when nothing else touches the service — no cooperative pumping from an
ingest loop required.  ``PackedQueryService`` is thread-safe (one RLock
around queue state), so submits and pump sweeps may interleave freely.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, NamedTuple

import numpy as np

from repro.obs import Observability, rehome_families
from repro.query.engine import PackedRequest, QueryEngine

__all__ = [
    "PackedQueryService",
    "PackedServiceStats",
    "QueryService",
    "QueryShedError",
    "QueryTicket",
    "ServicePump",
    "ServicePumpError",
    "ServiceStats",
]


class QueryShedError(RuntimeError):
    """A submit was rejected because the tenant's admission quota is full.

    Shedding is *reported*, never silent: the submitter gets this error
    synchronously (no ticket is created, nothing is queued) and the service
    counts the event in ``stats().shed`` / ``shed_counts()``.  Carries
    ``tenant``, ``pending`` (the tenant's queue depth at rejection), and
    ``max_pending`` (the quota that was hit).
    """

    def __init__(self, tenant: str, pending: int, max_pending: int):
        super().__init__(
            f"tenant {tenant!r} admission quota full "
            f"({pending}/{max_pending} queries pending); query shed"
        )
        self.tenant = tenant
        self.pending = pending
        self.max_pending = max_pending


class ServiceStats(NamedTuple):
    """Lifetime counters of a single-tenant ``QueryService``."""

    queries: int
    batches: int
    padded: int  # zero-filled slots added to round batches up
    busy_s: float  # wall time inside the engine hot path
    queries_per_sec: float


class QueryTicket:
    """Handle for one submitted direction; resolved at flush time."""

    __slots__ = ("_service", "estimate", "error_bound", "version", "done")

    def __init__(self, service: "QueryService"):
        self._service = service
        self.estimate: float | None = None
        self.error_bound: float | None = None
        self.version: int | None = None
        self.done = False

    def result(self) -> tuple[float, float, int]:
        """(estimate, error_bound, version) — flushes the service if pending."""
        if not self.done:
            self._service.flush()
        assert self.done, "flush() must resolve every pending ticket"
        return self.estimate, self.error_bound, self.version

    def _resolve(self, estimate: float, error_bound: float, version: int) -> None:
        self.estimate = estimate
        self.error_bound = error_bound
        self.version = version
        self.done = True


def _bucket(n: int, min_bucket: int, max_batch: int) -> int:
    """Smallest power-of-two >= n, clamped to [min_bucket, max_batch]."""
    b = min_bucket
    while b < n:
        b *= 2
    return min(b, max_batch)


class QueryService:
    """Single-tenant admission: coalesce directions into kernel batches."""

    def __init__(
        self,
        engine: QueryEngine,
        *,
        tenant: str = "default",
        path: str = "pallas",
        max_batch: int = 1024,
        min_bucket: int = 8,
        auto_flush: bool = True,
    ):
        if max_batch < min_bucket or min_bucket < 1:
            raise ValueError(f"need 1 <= min_bucket <= max_batch, got {min_bucket}, {max_batch}")
        self.engine = engine
        self.tenant = tenant
        self.path = path
        self.max_batch = max_batch
        self.min_bucket = min_bucket
        self.auto_flush = auto_flush
        self._pending: list[tuple[np.ndarray, QueryTicket]] = []
        self._queries = 0
        self._batches = 0
        self._padded = 0
        self._busy_s = 0.0

    def submit(self, x: np.ndarray) -> QueryTicket:
        """Enqueue one direction; auto-flushes when a full batch is waiting."""
        x = np.asarray(x, np.float32)
        if x.ndim != 1:
            raise ValueError(f"submit takes a single (d,) direction, got shape {x.shape}")
        ticket = QueryTicket(self)
        self._pending.append((x, ticket))
        if self.auto_flush and len(self._pending) >= self.max_batch:
            self.flush()
        return ticket

    def pending(self) -> int:
        """Queued-but-unserved query count."""
        return len(self._pending)

    def flush(self) -> int:
        """Serve every pending ticket in coalesced batches; returns #served."""
        served = 0
        while self._pending:
            # Pop only after the engine succeeds: a raising batch stays
            # pending, so the caller can fix the cause and flush again.
            take = self._pending[: self.max_batch]
            rows = np.stack([x for x, _ in take])
            bucket = _bucket(rows.shape[0], self.min_bucket, self.max_batch)
            batch = np.zeros((bucket, rows.shape[1]), np.float32)
            batch[: rows.shape[0]] = rows
            t0 = self.engine.obs.clock()
            res = self.engine.query_batch(
                batch, tenant=self.tenant, path=self.path
            )
            self._busy_s += self.engine.obs.clock() - t0
            del self._pending[: len(take)]
            for (_, ticket), est in zip(take, res.estimates):
                ticket._resolve(float(est), res.error_bound, res.version)
            self._queries += len(take)
            self._batches += 1
            self._padded += bucket - len(take)
            served += len(take)
        return served

    def stats(self) -> ServiceStats:
        """Lifetime service counters (see ``ServiceStats``)."""
        qps = self._queries / self._busy_s if self._busy_s > 0 else 0.0
        return ServiceStats(
            queries=self._queries,
            batches=self._batches,
            padded=self._padded,
            busy_s=self._busy_s,
            queries_per_sec=qps,
        )


# ---------------------------------------------------------------------------
# Cross-tenant packed admission with deadlines
# ---------------------------------------------------------------------------


class PackedServiceStats(NamedTuple):
    """Lifetime counters of a ``PackedQueryService``."""

    queries: int
    flushes: int  # engine round-trips (each = one packed dispatch sweep)
    packed_tenants: int  # tenant batches packed across all flushes
    padded: int  # zero-filled query slots added while packing
    deadline_flushes: int  # sweeps forced by an expired deadline
    busy_s: float
    queries_per_sec: float
    shed: int = 0  # submits rejected by a tenant quota (QueryShedError)


class PackedQueryService:
    """Multi-tenant admission: pack queued queries across tenants.

    The single-tenant ``QueryService`` coalesces directions for one sketch;
    under many-tenant traffic that still costs one kernel dispatch per
    tenant per flush.  This front-end queues (tenant, direction, deadline)
    triples and, at dispatch time, hands the engine ``query_packed`` calls:
    tenants whose pinned sketches share (l, d) ride a single Pallas launch.

    Dispatch triggers:
      * ``max_batch`` total queued directions (admission pressure), or
      * the earliest queued deadline expiring — ``poll()`` is the deadline
        pump; call it from the ingest loop (the pipeline does).

    Each engine round-trip is one *sweep* of at most ``max_batch`` queries,
    packed in descending tenant-priority order (``set_quota``), so under
    overload high-priority tenants are served first and the deadline pump
    does bounded work per call.  ``flush()`` loops sweeps until drained.

    Admission control is per tenant: ``set_quota(tenant, max_pending=...)``
    bounds the tenant's queued depth; a submit beyond it raises
    ``QueryShedError`` (shed-and-report — the caller learns synchronously,
    the service counts it, nothing is silently dropped).

    ``clock`` is injectable so deadline behaviour is testable without
    sleeping; it governs deadlines only — durations and metrics run off
    ``obs.clock``.  All public methods are thread-safe (one RLock around
    queue state), so a ``ServicePump`` thread can drive ``poll()`` while
    the ingest thread keeps submitting; a sweep holds the lock for its
    engine round-trip, briefly blocking concurrent submits.
    """

    _FAMILIES = (
        ("counter", "repro_service_queries_total", "Queries served by packed sweeps."),
        ("counter", "repro_service_flushes_total", "Engine round-trips (packed dispatch sweeps)."),
        ("counter", "repro_service_packed_tenants_total", "Tenant batches packed across all sweeps."),
        ("counter", "repro_service_padded_total", "Zero-filled query slots added while packing."),
        ("counter", "repro_service_deadline_flushes_total", "Sweeps forced by an expired deadline."),
        ("counter", "repro_service_busy_seconds_total", "Wall time inside the engine hot path."),
        ("counter", "repro_service_sheds_total", "Submits rejected by a tenant quota."),
        ("counter", "repro_service_tenant_sheds_total", "Submits rejected by a tenant quota, per tenant."),
        ("histogram", "repro_serve_latency_seconds", "Engine round-trip latency per packed sweep."),
        ("histogram", "repro_service_poll_seconds", "Deadline-pump poll() latency."),
    )

    def __init__(
        self,
        engine: QueryEngine,
        *,
        max_batch: int = 1024,
        default_deadline_s: float = 0.02,
        auto_flush: bool = True,
        clock: Callable[[], float] = time.monotonic,
        obs: Observability | None = None,
    ):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if default_deadline_s < 0:
            raise ValueError(f"default_deadline_s must be >= 0, got {default_deadline_s}")
        self.engine = engine
        self.max_batch = max_batch
        self.default_deadline_s = default_deadline_s
        self.auto_flush = auto_flush
        self.clock = clock
        self.obs = obs if obs is not None else engine.obs
        self._lock = threading.RLock()
        # tenant -> [(x, ticket, abs_deadline), ...] in FIFO order.
        self._pending: dict[str, list[tuple[np.ndarray, QueryTicket, float]]] = {}
        self._n_pending = 0
        self._earliest_deadline = float("inf")
        self._quotas: dict[str, tuple[int, int]] = {}  # tenant -> (max_pending, priority)
        self._bind_metrics()

    def _bind_metrics(self) -> None:
        handles = {}
        for kind, name, help in self._FAMILIES:
            if name == "repro_service_tenant_sheds_total":
                continue
            handles[name] = self.obs.handle(kind, name, help)
        self._m_queries = handles["repro_service_queries_total"]
        self._m_flushes = handles["repro_service_flushes_total"]
        self._m_packed_tenants = handles["repro_service_packed_tenants_total"]
        self._m_padded = handles["repro_service_padded_total"]
        self._m_deadline_flushes = handles["repro_service_deadline_flushes_total"]
        self._m_busy = handles["repro_service_busy_seconds_total"]
        self._m_shed = handles["repro_service_sheds_total"]
        self._m_serve_latency = handles["repro_serve_latency_seconds"]
        self._m_poll = handles["repro_service_poll_seconds"]
        # Per-tenant shed handles are label-dynamic; cache and re-fetch the
        # known tenants so a re-home keeps shed_counts() intact.
        tenants = tuple(getattr(self, "_m_tenant_sheds", ()))
        self._m_tenant_sheds = {t: self._tenant_shed_handle(t) for t in tenants}

    def _tenant_shed_handle(self, tenant: str):
        return self.obs.handle(
            "counter", "repro_service_tenant_sheds_total",
            "Submits rejected by a tenant quota, per tenant.",
            labels={"tenant": tenant},
        )

    def bind_obs(self, obs: Observability) -> None:
        """Re-home this service's telemetry into another bundle."""
        with self._lock:
            old, self.obs = self.obs, obs
            rehome_families(old, obs, self._FAMILIES)
            self._bind_metrics()

    # -- admission control ---------------------------------------------------

    def set_quota(self, tenant: str, *, max_pending: int = 0, priority: int = 0) -> None:
        """Set a tenant's admission quota and dispatch priority.

        max_pending: maximum queued-but-unserved queries for the tenant
                     (0 = unbounded); overflow submits raise
                     ``QueryShedError``.
        priority:    higher values are packed earlier within each capped
                     dispatch sweep (ties broken by tenant name).
        """
        if max_pending < 0:
            raise ValueError(f"max_pending must be >= 0, got {max_pending}")
        with self._lock:
            self._quotas[tenant] = (int(max_pending), int(priority))

    def quota(self, tenant: str) -> tuple[int, int]:
        """The tenant's ``(max_pending, priority)`` (defaults ``(0, 0)``)."""
        with self._lock:
            return self._quotas.get(tenant, (0, 0))

    def clear_quota(self, tenant: str) -> None:
        """Forget a tenant's quota/priority (tenant removal; no-op if unset)."""
        with self._lock:
            self._quotas.pop(tenant, None)

    def shed_counts(self) -> dict[str, int]:
        """Per-tenant count of submits rejected by the quota (fresh dict)."""
        with self._lock:
            return {
                t: int(h.value) for t, h in self._m_tenant_sheds.items() if h.value
            }

    # -- submission ----------------------------------------------------------

    def submit(
        self,
        x: np.ndarray,
        *,
        tenant: str,
        deadline_s: float | None = None,
    ) -> QueryTicket:
        """Enqueue one (d,) direction for ``tenant``; returns its ticket.

        Raises ``QueryShedError`` (before queuing anything) when the
        tenant's ``max_pending`` quota is already full.
        """
        x = np.asarray(x, np.float32)
        if x.ndim != 1:
            raise ValueError(f"submit takes a single (d,) direction, got shape {x.shape}")
        with self._lock:
            max_pending, _ = self._quotas.get(tenant, (0, 0))
            depth = len(self._pending.get(tenant, ()))
            if max_pending and depth >= max_pending:
                self._m_shed.inc()
                if tenant not in self._m_tenant_sheds:
                    self._m_tenant_sheds[tenant] = self._tenant_shed_handle(tenant)
                self._m_tenant_sheds[tenant].inc()
                raise QueryShedError(tenant, depth, max_pending)
            ticket = QueryTicket(self)
            if deadline_s is None:
                deadline_s = self.default_deadline_s
            deadline = self.clock() + deadline_s
            self._pending.setdefault(tenant, []).append((x, ticket, deadline))
            self._n_pending += 1
            self._earliest_deadline = min(self._earliest_deadline, deadline)
            if self.auto_flush and self._n_pending >= self.max_batch:
                self.flush()
            return ticket

    def pending(self, tenant: str | None = None) -> int:
        """Queued-but-unserved query count (for one tenant, or in total)."""
        with self._lock:
            if tenant is not None:
                return len(self._pending.get(tenant, ()))
            return self._n_pending

    # -- dispatch ------------------------------------------------------------

    def poll(self) -> int:
        """Deadline pump: one priority-ordered sweep iff a deadline passed.

        Bounded work per call (at most ``max_batch`` queries served), so an
        ingest loop — or a ``ServicePump`` thread — can pump it freely; if
        expired queries remain after the sweep the next ``poll`` fires
        again.
        """
        with self._lock:
            t0 = self.obs.clock()
            served = 0
            if self._n_pending and self.clock() >= self._earliest_deadline:
                self._m_deadline_flushes.inc()
                served = self._sweep()
            self._m_poll.observe(self.obs.clock() - t0)
            return served

    def flush(self) -> int:
        """Drain everything pending in capped priority-ordered sweeps."""
        with self._lock:
            served = 0
            while self._n_pending:
                served += self._sweep()
            return served

    def _sweep(self) -> int:
        """One engine round-trip: up to ``max_batch`` queries, priority order."""
        if not self._pending:
            return 0
        order = sorted(
            self._pending, key=lambda t: (-self._quotas.get(t, (0, 0))[1], t)
        )
        take: list[tuple[str, list[tuple[np.ndarray, QueryTicket, float]]]] = []
        budget = self.max_batch
        for tenant in order:
            if budget <= 0:
                break
            entries = self._pending[tenant][:budget]
            take.append((tenant, entries))
            budget -= len(entries)
        requests = [
            PackedRequest(tenant=tenant, x=np.stack([x for x, _, _ in entries]))
            for tenant, entries in take
        ]
        t0 = self.obs.clock()
        # Pending state is only consumed after the engine succeeds: a raising
        # pack (e.g. an unpublished tenant) leaves every ticket pending.
        pad0 = self.engine.packed_pad_slots
        results = self.engine.query_packed(requests)
        elapsed = self.obs.clock() - t0
        self._m_busy.inc(elapsed)
        self._m_serve_latency.observe(elapsed)
        # The engine pads per (l, d) shape group; read its exact count.
        self._m_padded.inc(self.engine.packed_pad_slots - pad0)
        served = 0
        for (tenant, entries), res in zip(take, results):
            rest = self._pending[tenant][len(entries):]
            if rest:
                self._pending[tenant] = rest
            else:
                del self._pending[tenant]
            for (_, ticket, _), est in zip(entries, res.estimates):
                ticket._resolve(float(est), res.error_bound, res.version)
            served += len(entries)
        self._n_pending -= served
        self._earliest_deadline = min(
            (dl for entries in self._pending.values() for _, _, dl in entries),
            default=float("inf"),
        )
        self._m_queries.inc(served)
        self._m_flushes.inc()
        self._m_packed_tenants.inc(len(take))
        return served

    def stats(self) -> PackedServiceStats:
        """Lifetime service counters — a fresh view over the obs registry."""
        with self._lock:
            queries = int(self._m_queries.value)
            busy_s = self._m_busy.value
            qps = queries / busy_s if busy_s > 0 else 0.0
            return PackedServiceStats(
                queries=queries,
                flushes=int(self._m_flushes.value),
                packed_tenants=int(self._m_packed_tenants.value),
                padded=int(self._m_padded.value),
                deadline_flushes=int(self._m_deadline_flushes.value),
                busy_s=busy_s,
                queries_per_sec=qps,
                shed=int(self._m_shed.value),
            )


# ---------------------------------------------------------------------------
# Background deadline executor
# ---------------------------------------------------------------------------


class ServicePumpError(RuntimeError):
    """The pump thread died on an exception raised by ``poll()``.

    Raised by ``ServicePump.stop()`` (and ``start()`` on restart) so a
    crashed pump can never fail silently; the original exception rides
    ``__cause__``.
    """


class ServicePump:
    """Background thread driving ``PackedQueryService.poll()``.

    The deadline pump as a real executor: per-entry deadlines hold even
    when the ingest loop is idle or gone — no cooperative ``poll()`` calls
    required.  The thread wakes every ``interval_s`` seconds, fires one
    bounded deadline sweep, and exits cleanly on ``stop()``.

    Exception safety: an exception escaping ``poll()`` stops the loop and
    is *recorded*, never swallowed — ``error`` exposes it immediately and
    the next ``stop()`` (or restart attempt) raises ``ServicePumpError``
    from it.  The thread is a daemon, so a crashed or forgotten pump never
    blocks interpreter shutdown.
    """

    def __init__(self, service: PackedQueryService, *, interval_s: float = 0.001,
                 name: str = "service-pump"):
        if interval_s <= 0:
            raise ValueError(f"interval_s must be > 0, got {interval_s}")
        self.service = service
        self.interval_s = interval_s
        self.name = name
        self._thread: threading.Thread | None = None
        self._stop: threading.Event | None = None
        self._error: BaseException | None = None
        self.polls = 0  # completed poll() calls
        self.served = 0  # queries resolved by deadline sweeps

    def _run(self, stop: threading.Event) -> None:
        # ``stop`` is captured per thread: a later start() gets a fresh
        # event, so it can never accidentally un-stop an older thread.
        while not stop.wait(self.interval_s):
            try:
                self.served += self.service.poll()
                self.polls += 1
            except BaseException as exc:  # noqa: B036 — recorded, re-raised on stop
                self._error = exc
                return

    def start(self) -> "ServicePump":
        """Start the pump thread (idempotent while running)."""
        if self._error is not None:
            self._raise_error()
        if self.running:
            return self
        self._stop = threading.Event()
        self._thread = threading.Thread(
            target=self._run, args=(self._stop,), name=self.name, daemon=True
        )
        self._thread.start()
        return self

    def stop(self, *, timeout: float = 5.0) -> None:
        """Stop the thread and re-raise any exception the pump captured.

        Raises ``ServicePumpError`` if the thread is still alive after
        ``timeout`` (the pump keeps its reference, so a later ``stop``
        can retry — it is never orphaned).
        """
        thread = self._thread
        if thread is not None:
            self._stop.set()
            thread.join(timeout)
            if thread.is_alive():
                raise ServicePumpError(
                    f"pump {self.name!r} did not stop within {timeout}s "
                    "(a poll() sweep is still running); call stop() again"
                )
            self._thread = None
        if self._error is not None:
            self._raise_error()

    def _raise_error(self) -> None:
        error, self._error = self._error, None
        raise ServicePumpError(
            f"pump {self.name!r} died driving poll(): {error!r}"
        ) from error

    @property
    def running(self) -> bool:
        """Whether the pump thread is alive."""
        return self._thread is not None and self._thread.is_alive()

    @property
    def error(self) -> BaseException | None:
        """The exception that killed the pump loop, if any (not yet raised)."""
        return self._error

    def __enter__(self) -> "ServicePump":
        return self.start()

    def __exit__(self, *exc_info) -> None:
        self.stop()
