"""Admission + batching front-end over the query engine.

Modeled on the ``ServeEngine`` host loop: callers submit single directions
(the traffic pattern of the paper's coordinator under heavy query load) and
the service coalesces them into kernel-sized batches so the hot path always
sees the fixed shapes the jit/Pallas stack compiles for.  Ragged tails are
zero-padded up to a power-of-two bucket (zero directions cost zero and are
discarded), bounding the number of compiled batch shapes to
``log2(max_batch)`` per tenant.

    svc = QueryService(engine, tenant="run-42")
    tickets = [svc.submit(x) for x in directions]
    svc.flush()                       # or wait for max_batch auto-flush
    tickets[0].result()               # (estimate, error_bound, version)

``stats()`` reports served queries, batches, padding overhead and the
measured queries/sec of the engine-facing hot path.
"""
from __future__ import annotations

import time
from typing import NamedTuple

import numpy as np

from repro.query.engine import QueryEngine

__all__ = ["QueryService", "QueryTicket", "ServiceStats"]


class ServiceStats(NamedTuple):
    queries: int
    batches: int
    padded: int  # zero-filled slots added to round batches up
    busy_s: float  # wall time inside the engine hot path
    queries_per_sec: float


class QueryTicket:
    """Handle for one submitted direction; resolved at flush time."""

    __slots__ = ("_service", "estimate", "error_bound", "version", "done")

    def __init__(self, service: "QueryService"):
        self._service = service
        self.estimate: float | None = None
        self.error_bound: float | None = None
        self.version: int | None = None
        self.done = False

    def result(self) -> tuple[float, float, int]:
        """(estimate, error_bound, version) — flushes the service if pending."""
        if not self.done:
            self._service.flush()
        assert self.done, "flush() must resolve every pending ticket"
        return self.estimate, self.error_bound, self.version

    def _resolve(self, estimate: float, error_bound: float, version: int) -> None:
        self.estimate = estimate
        self.error_bound = error_bound
        self.version = version
        self.done = True


def _bucket(n: int, min_bucket: int, max_batch: int) -> int:
    """Smallest power-of-two >= n, clamped to [min_bucket, max_batch]."""
    b = min_bucket
    while b < n:
        b *= 2
    return min(b, max_batch)


class QueryService:
    def __init__(
        self,
        engine: QueryEngine,
        *,
        tenant: str = "default",
        path: str = "pallas",
        max_batch: int = 1024,
        min_bucket: int = 8,
        auto_flush: bool = True,
    ):
        if max_batch < min_bucket or min_bucket < 1:
            raise ValueError(f"need 1 <= min_bucket <= max_batch, got {min_bucket}, {max_batch}")
        self.engine = engine
        self.tenant = tenant
        self.path = path
        self.max_batch = max_batch
        self.min_bucket = min_bucket
        self.auto_flush = auto_flush
        self._pending: list[tuple[np.ndarray, QueryTicket]] = []
        self._queries = 0
        self._batches = 0
        self._padded = 0
        self._busy_s = 0.0

    def submit(self, x: np.ndarray) -> QueryTicket:
        """Enqueue one direction; auto-flushes when a full batch is waiting."""
        x = np.asarray(x, np.float32)
        if x.ndim != 1:
            raise ValueError(f"submit takes a single (d,) direction, got shape {x.shape}")
        ticket = QueryTicket(self)
        self._pending.append((x, ticket))
        if self.auto_flush and len(self._pending) >= self.max_batch:
            self.flush()
        return ticket

    def pending(self) -> int:
        return len(self._pending)

    def flush(self) -> int:
        """Serve every pending ticket in coalesced batches; returns #served."""
        served = 0
        while self._pending:
            # Pop only after the engine succeeds: a raising batch stays
            # pending, so the caller can fix the cause and flush again.
            take = self._pending[: self.max_batch]
            rows = np.stack([x for x, _ in take])
            bucket = _bucket(rows.shape[0], self.min_bucket, self.max_batch)
            batch = np.zeros((bucket, rows.shape[1]), np.float32)
            batch[: rows.shape[0]] = rows
            t0 = time.perf_counter()
            res = self.engine.query_batch(
                batch, tenant=self.tenant, path=self.path
            )
            self._busy_s += time.perf_counter() - t0
            del self._pending[: len(take)]
            for (_, ticket), est in zip(take, res.estimates):
                ticket._resolve(float(est), res.error_bound, res.version)
            self._queries += len(take)
            self._batches += 1
            self._padded += bucket - len(take)
            served += len(take)
        return served

    def stats(self) -> ServiceStats:
        qps = self._queries / self._busy_s if self._busy_s > 0 else 0.0
        return ServiceStats(
            queries=self._queries,
            batches=self._batches,
            padded=self._padded,
            busy_s=self._busy_s,
            queries_per_sec=qps,
        )
