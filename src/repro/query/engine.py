"""Batched query engine over the versioned sketch store.

Serves the paper's matrix query — ``||B x||^2`` as an eps-approximation of
``||A x||^2`` — for whole batches of directions against a pinned snapshot,
three ways:

  * ``pallas``  — the fused batched quadratic-form kernel
                  (``repro.kernels.quadform``): one pass over B per batch.
  * ``cached``  — factor once per (tenant, version) into the sketch's
                  singular spectrum (an LRU-cached eigendecomposition of
                  the Gram ``B B^T``), then every batch is a thin
                  ``(N, d) @ (d, l)`` matmul; ``top_directions`` and
                  ``stable_rank`` read the same cache entry for free.
  * ``naive``   — recompute the SVD per query: the strawman a serving
                  layer exists to beat (see benchmarks/query_service.py).

All paths agree to fp tolerance; every result carries the snapshot's
additive error bound (``delta_sum`` when known, else ``eps ||A||_F^2``).

Snapshots whose ``meta["workload"]`` is ``"hh"`` hold weighted heavy-hitter
estimates (an ``(n, 2)`` [element, estimate] matrix, see
``core.hh.encode_hh_snapshot``) instead of a row sketch; queries against
them are frequency point-lookups — each "direction" is a single element id
— answered with the same ``QueryResult`` shape and the paper's
``eps W`` additive bound.  ``meta["workload"] == "quantile"`` snapshots
hold a sorted ``(n, 2)`` [value, rank-estimate] table
(``core.quantiles.encode_quantile_snapshot``); each query is a ``(2,)``
``[mode, arg]`` row — rank-at-value or phi-quantile — answered by one
searchsorted pass.  ``meta["workload"] == "leverage"`` snapshots hold an
``(n, d+2)`` [row | score | weight] importance-weighted row sample
(``core.leverage.encode_leverage_snapshot``); each query is a ``(d+1,)``
``[mode, x]`` row — a subspace query ``sum_i w_i (a_i . x)^2`` served by
the shared ``quadform`` kernel over the weighted sample, or a ridge
leverage score served by the fused ``levscore`` kernel.  All four kinds
share one admission path and one packed dispatch loop.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import NamedTuple

import numpy as np

from repro.obs import Observability, rehome_families
from repro.query.store import SketchSnapshot, SketchStore

__all__ = ["PackedRequest", "QueryEngine", "QueryResult", "Spectrum"]

PATHS = ("pallas", "cached", "naive")


class PackedRequest(NamedTuple):
    """One tenant's slice of a cross-tenant packed batch."""

    tenant: str
    x: np.ndarray  # (n_i, d) directions for this tenant
    version: int | None = None


class Spectrum(NamedTuple):
    """Cached factorization of a snapshot: B = U diag(s) Vt (thin)."""

    s: np.ndarray  # (l,) singular values, descending
    vt: np.ndarray  # (l, d) right singular directions


class QueryResult(NamedTuple):
    """One tenant's served batch: estimates + the snapshot's certificate."""

    estimates: np.ndarray  # (n,) f32 — ||B x_j||^2 (or HH weight) per query
    error_bound: float  # additive bound vs the true answer
    tenant: str
    version: int
    path: str


def _svd_spectrum(matrix: np.ndarray) -> Spectrum:
    _, s, vt = np.linalg.svd(matrix, full_matrices=False)
    return Spectrum(s=s, vt=vt)


def _workload(snap: SketchSnapshot) -> str:
    """A snapshot's workload kind (``"matrix"`` when untagged)."""
    return snap.meta.get("workload", "matrix")


class QueryEngine:
    """Serves batched queries against pinned ``SketchStore`` snapshots.

    Dispatches per snapshot workload: matrix snapshots ride the quadform
    paths (pallas / cached / naive), HH and quantile snapshots ride
    vectorized lookups, leverage snapshots ride weighted quadform /
    levscore sweeps.  ``query_packed`` packs many tenants per engine call.
    """

    _FAMILIES = (
        ("counter", "repro_engine_cache_ops_total",
         "Per-version cache lookups by cache (spectrum/factor) and op "
         "(hits/misses/evictions)."),
        ("counter", "repro_engine_packed_launches_total",
         "Kernel launches spent by query_packed."),
        ("counter", "repro_engine_packed_pad_slots_total",
         "Zero-filled query slots added while packing."),
    )

    def __init__(
        self,
        store: SketchStore,
        *,
        cache_size: int = 16,
        interpret: bool | None = None,
        obs: Observability | None = None,
    ):
        if cache_size < 1:
            raise ValueError(f"cache_size must be >= 1, got {cache_size}")
        self.store = store
        self.cache_size = cache_size
        self.interpret = interpret
        self.obs = obs if obs is not None else Observability()
        self._cache: OrderedDict[tuple[str, int], Spectrum] = OrderedDict()
        # Leverage tenants' ridge factors, same LRU discipline as _cache.
        self._factor_cache: OrderedDict[tuple, np.ndarray] = OrderedDict()
        self._bind_metrics()

    def _bind_metrics(self) -> None:
        # Per-cache keyed counters: evictions were previously silent, so a
        # thrashing cache (cache_size too small for the live tenant set)
        # looked identical to a healthy one.  Routers and replicas read
        # these (via the cache_stats view) to report hit rates per cell.
        kind, name, help = self._FAMILIES[0]
        self._m_cache = {
            (which, op): self.obs.handle(
                kind, name, help, labels={"cache": which, "op": op}
            )
            for which in ("spectrum", "factor")
            for op in ("hits", "misses", "evictions")
        }
        self._m_launches = self.obs.handle(*self._FAMILIES[1])
        self._m_pad = self.obs.handle(*self._FAMILIES[2])

    def bind_obs(self, obs: Observability) -> None:
        """Re-home this engine's telemetry into another bundle."""
        old, self.obs = self.obs, obs
        rehome_families(old, obs, self._FAMILIES)
        self._bind_metrics()

    @property
    def packed_launches(self) -> int:
        """Kernel launches spent by ``query_packed`` (registry view)."""
        return int(self._m_launches.value)

    @property
    def packed_pad_slots(self) -> int:
        """Zero-filled query slots added while packing (registry view)."""
        return int(self._m_pad.value)

    # -- spectrum cache ------------------------------------------------------

    def spectrum(self, tenant: str, version: int | None = None) -> Spectrum:
        """The snapshot's singular spectrum, LRU-cached by (tenant, version).

        Versions are immutable, so a hit can never be stale; publishing a
        new version changes the key, which *is* the invalidation.
        """
        return self._spectrum_for(self.store.get(tenant, version))

    def _lru_get(self, cache: OrderedDict, key, compute, which: str):
        """One LRU discipline for every per-version cache (spectra, ridge
        factors): keyed hit/miss/eviction counters (``which`` names the
        cache), move-to-end on hit, evict the oldest past ``cache_size``.
        Versions are immutable, so a hit can never be stale; publishing
        changes the key, which IS the invalidation."""
        hit = cache.get(key)
        if hit is not None:
            cache.move_to_end(key)
            self._m_cache[(which, "hits")].inc()
            return hit
        self._m_cache[(which, "misses")].inc()
        value = compute()
        cache[key] = value
        while len(cache) > self.cache_size:
            cache.popitem(last=False)
            self._m_cache[(which, "evictions")].inc()
        return value

    def _spectrum_for(self, snap: SketchSnapshot) -> Spectrum:
        return self._lru_get(
            self._cache,
            (snap.tenant, snap.version),
            lambda: _svd_spectrum(snap.matrix),
            "spectrum",
        )

    def refresh_spectra(self, snaps: list) -> int:
        """Warm the spectrum cache for freshly published matrix snapshots.

        Groups the snapshots' sketches by (l, d) shape (wide sketches
        only, ``l <= d``) and factors each group with ONE stacked Gram
        eigendecomposition (``kernels.ops.fd_spectra``) instead of one
        SVD per tenant — the publish-time half of packed multi-tenant
        ingest.  Per-row signs may differ from the SVD path; every
        consumer (cached quadforms, ``top_directions``, ``stable_rank``)
        is sign-invariant or inherits the same inherent ambiguity.
        Entries land in the LRU the query paths read; hits/misses are
        not counted (this is a prefill, not a lookup), evictions are.
        Non-matrix, tall, empty, or already-cached snapshots are
        skipped.  Returns the number of spectra warmed.
        """
        import jax.numpy as jnp

        from repro.kernels.ops import fd_spectra

        by_shape: dict[tuple[int, int], list[SketchSnapshot]] = {}
        for snap in snaps:
            mat = np.asarray(snap.matrix)
            if (
                _workload(snap) != "matrix"
                or (snap.tenant, snap.version) in self._cache
                or mat.ndim != 2
                or not 0 < mat.shape[0] <= mat.shape[1]
            ):
                continue
            by_shape.setdefault(mat.shape, []).append(snap)
        warmed = 0
        evictions = self._m_cache[("spectrum", "evictions")]
        for group in by_shape.values():
            b = jnp.asarray(np.stack([np.asarray(s.matrix) for s in group]))
            s_all, vt_all = fd_spectra(b, interpret=self.interpret)
            s_all, vt_all = np.asarray(s_all), np.asarray(vt_all)
            for t, snap in enumerate(group):
                self._cache[(snap.tenant, snap.version)] = Spectrum(
                    s=s_all[t], vt=vt_all[t]
                )
                warmed += 1
                while len(self._cache) > self.cache_size:
                    self._cache.popitem(last=False)
                    evictions.inc()
        return warmed

    def _cache_op(self, which: str, op: str) -> int:
        return int(self._m_cache[(which, op)].value)

    @property
    def cache_hits(self) -> int:
        """Total cache hits across both per-version caches."""
        return self._cache_op("spectrum", "hits") + self._cache_op("factor", "hits")

    @property
    def cache_misses(self) -> int:
        """Total cache misses across both per-version caches."""
        return self._cache_op("spectrum", "misses") + self._cache_op("factor", "misses")

    def cache_stats(self) -> dict:
        """Keyed counters for the per-version caches (a registry view).

        ``hits``/``misses``/``evictions`` aggregate both caches;
        ``spectrum`` and ``factor`` break the same counters out per cache
        (evictions were previously untracked, so cache thrash was
        invisible); ``entries`` is the spectrum cache's resident count,
        ``factor_entries`` the leverage factor cache's; ``hit_rate`` is
        the aggregate fraction of lookups served from cache — what the
        cluster router and serving replicas report per cell.  On a cold
        cache (zero lookups) ``hit_rate`` is 0.0, never NaN.  The dict —
        nested per-cache dicts included — is built fresh per call, so
        mutating it cannot corrupt the live counters.
        """
        hits, misses = self.cache_hits, self.cache_misses
        return {
            "hits": hits,
            "misses": misses,
            "evictions": (self._cache_op("spectrum", "evictions")
                          + self._cache_op("factor", "evictions")),
            "entries": len(self._cache),
            "factor_entries": len(self._factor_cache),
            "spectrum": {op: self._cache_op("spectrum", op)
                         for op in ("hits", "misses", "evictions")},
            "factor": {op: self._cache_op("factor", op)
                       for op in ("hits", "misses", "evictions")},
            "hit_rate": hits / max(hits + misses, 1),
        }

    # -- batched quadratic forms --------------------------------------------

    def query_batch(
        self,
        x: np.ndarray,
        *,
        tenant: str = "default",
        version: int | None = None,
        path: str = "pallas",
    ) -> QueryResult:
        """Serve every row of ``x`` against the tenant's pinned snapshot.

        Matrix tenants: ``||B x_j||^2`` per (d,)-direction row, on the
        chosen ``path``.  HH tenants: estimated weight per (1,)-element-id
        row (``path`` is ignored; the lookup has one implementation).
        """
        if path not in PATHS:
            raise ValueError(f"unknown query path {path!r}; choose from {PATHS}")
        with self.obs.trace("engine.query_batch", tenant=tenant, path=path):
            return self._query_batch(x, tenant=tenant, version=version, path=path)

    def _query_batch(
        self,
        x: np.ndarray,
        *,
        tenant: str,
        version: int | None,
        path: str,
    ) -> QueryResult:
        snap = self.store.get(tenant, version)
        x = np.asarray(x, np.float32)
        wl = _workload(snap)
        if wl in _LOOKUPS:
            return QueryResult(
                estimates=_LOOKUPS[wl](self, snap, x),
                error_bound=snap.error_bound,
                tenant=snap.tenant,
                version=snap.version,
                path=wl,
            )
        if x.ndim != 2 or x.shape[1] != snap.matrix.shape[1]:
            raise ValueError(
                f"directions must be (n, {snap.matrix.shape[1]}), got {x.shape}"
            )
        if path == "pallas":
            est = self._pallas_batch(snap, x)
        elif path == "cached":
            est = self._cached_batch(snap, x)
        else:
            est = self._naive_batch(snap, x)
        return QueryResult(
            estimates=est,
            error_bound=snap.error_bound,
            tenant=snap.tenant,
            version=snap.version,
            path=path,
        )

    def query(self, x: np.ndarray, **kw) -> float:
        """Single-direction convenience wrapper over ``query_batch``."""
        return float(self.query_batch(np.asarray(x)[None, :], **kw).estimates[0])

    def query_packed(self, requests: list[PackedRequest]) -> list[QueryResult]:
        """Serve many tenants' query batches, packing kernel launches.

        Matrix requests whose pinned sketches share an (l, d) shape are
        stacked — sketches into (T, l, d), directions zero-padded to a
        common N into (T, N, d) — and served by ONE ``quadform_packed``
        Pallas launch.  Shapes that appear only once fall back to the
        per-tenant kernel; HH and quantile requests are served by their
        searchsorted lookup paths (no kernel launch) and leverage
        requests by their per-tenant weighted sweeps in the same call.
        Results come back in request order, one ``QueryResult`` each,
        identical (to fp tolerance) to serial per-tenant ``query_batch``.
        """
        with self.obs.trace("engine.query_packed", requests=len(requests)):
            return self._query_packed(requests)

    def _query_packed(self, requests: list[PackedRequest]) -> list[QueryResult]:
        from repro.kernels.ops import quadform_packed

        snaps: list[SketchSnapshot] = []
        xs: list[np.ndarray] = []
        lookups: dict[int, str] = {}  # request index -> lookup workload
        for i, req in enumerate(requests):
            snap = self.store.get(req.tenant, req.version)
            x = np.asarray(req.x, np.float32)
            if _workload(snap) in _LOOKUPS:
                lookups[i] = _workload(snap)
            elif x.ndim != 2 or x.shape[1] != snap.matrix.shape[1]:
                raise ValueError(
                    f"tenant {req.tenant!r}: directions must be "
                    f"(n, {snap.matrix.shape[1]}), got {x.shape}"
                )
            snaps.append(snap)
            xs.append(x)

        estimates: list[np.ndarray | None] = [None] * len(requests)
        by_shape: dict[tuple[int, int], list[int]] = {}
        for i, snap in enumerate(snaps):
            if i not in lookups:
                by_shape.setdefault(snap.matrix.shape, []).append(i)
        for i, wl in lookups.items():
            estimates[i] = _LOOKUPS[wl](self, snaps[i], xs[i])

        for shape, idxs in by_shape.items():
            self._m_launches.inc()
            if len(idxs) == 1:
                i = idxs[0]
                estimates[i] = self._pallas_batch(snaps[i], xs[i])
                continue
            n_max = max(xs[i].shape[0] for i in idxs)
            b_stack = np.stack([np.asarray(snaps[i].matrix) for i in idxs])
            x_stack = np.zeros((len(idxs), n_max, shape[1]), np.float32)
            for t, i in enumerate(idxs):
                x_stack[t, : xs[i].shape[0]] = xs[i]
                self._m_pad.inc(n_max - xs[i].shape[0])
            out = np.asarray(quadform_packed(b_stack, x_stack, interpret=self.interpret))
            for t, i in enumerate(idxs):
                estimates[i] = out[t, : xs[i].shape[0]]

        return [
            QueryResult(
                estimates=est,
                error_bound=snap.error_bound,
                tenant=snap.tenant,
                version=snap.version,
                path=lookups.get(i, "pallas"),
            )
            for i, (est, snap) in enumerate(zip(estimates, snaps))
        ]

    def _pallas_batch(self, snap: SketchSnapshot, x: np.ndarray) -> np.ndarray:
        from repro.kernels.ops import quadform

        return np.asarray(quadform(snap.matrix, x, interpret=self.interpret))

    def _hh_batch(self, snap: SketchSnapshot, x: np.ndarray) -> np.ndarray:
        """Vectorized HH point-lookup: estimated weight per queried element.

        ``x`` is an ``(n, 1)`` (or ``(n,)``) batch of element ids; unknown
        elements estimate 0 (the MG underestimate convention).  Ids compare
        exactly: both sides ride the f32 encoding of ints < 2**24.
        """
        q = np.asarray(x, np.float32)
        if q.ndim == 2 and q.shape[1] == 1:
            q = q[:, 0]
        if q.ndim != 1:
            raise ValueError(
                f"tenant {snap.tenant!r}: HH queries must be (n,) or (n, 1) "
                f"element ids, got {np.asarray(x).shape}"
            )
        mat = np.asarray(snap.matrix)
        if mat.shape[0] == 0:
            return np.zeros(q.shape[0], np.float32)
        # encode_hh_snapshot stores keys sorted and unique: binary search
        # instead of a dense (queries x keys) equality matrix.
        keys, counts = mat[:, 0], mat[:, 1]
        idx = np.clip(np.searchsorted(keys, q), 0, keys.shape[0] - 1)
        return np.where(keys[idx] == q, counts[idx], 0.0).astype(np.float32)

    def _quantile_batch(self, snap: SketchSnapshot, x: np.ndarray) -> np.ndarray:
        """Quantile lookups: each query row is ``(2,)`` ``[mode, arg]``.

        Mode ``QUERY_RANK`` (0) estimates the weighted rank of value
        ``arg``; mode ``QUERY_QUANTILE`` (1) returns the value whose rank
        is nearest ``arg * W`` — both one searchsorted pass over the
        published table, the same code path the live protocols answer
        from (``core.quantiles.table_rank`` / ``table_quantile``).
        """
        from repro.core.quantiles import (
            QUERY_QUANTILE,
            QUERY_RANK,
            table_quantile,
            table_rank,
        )

        q = np.asarray(x, np.float32)
        if q.ndim != 2 or q.shape[1] != 2:
            raise ValueError(
                f"tenant {snap.tenant!r}: quantile queries must be (n, 2) "
                f"[mode, arg] rows, got {np.asarray(x).shape}"
            )
        modes, args = q[:, 0], q[:, 1]
        is_rank = modes == QUERY_RANK
        is_quant = modes == QUERY_QUANTILE
        if not np.all(is_rank | is_quant):
            raise ValueError(
                f"tenant {snap.tenant!r}: quantile query mode must be "
                f"{QUERY_RANK} (rank) or {QUERY_QUANTILE} (phi-quantile)"
            )
        mat = np.asarray(snap.matrix)
        out = np.empty(q.shape[0], np.float32)
        out[is_rank] = table_rank(mat, args[is_rank])
        out[is_quant] = table_quantile(mat, snap.frob, args[is_quant])
        return out

    def _leverage_batch(self, snap: SketchSnapshot, x: np.ndarray) -> np.ndarray:
        """Leverage lookups: each query row is ``(d+1,)`` ``[mode, x_1..x_d]``.

        Mode ``QUERY_SUBSPACE`` (0) serves the importance-weighted
        ``||A x||^2`` estimate ``sum_i w_i (a_i . x)^2`` — the weighted
        sample rows ride the same ``quadform`` kernel matrix snapshots
        use.  Mode ``QUERY_SCORE`` (1) serves the approximate ridge
        leverage score of ``x`` against the published sample's Gram via
        the fused ``levscore`` kernel (the snapshot's ``meta["lam"]``
        pins the ridge the sample was published at).

        The batch's ``error_bound`` (``eps * F_hat``) certifies the
        SUBSPACE answers only; a ridge score lives on the ~[0, d_eff]
        scale and carries no additive certificate — score answers are
        diagnostics, not bounded estimates (see ``core.leverage.score_query``).
        """
        import jax.numpy as jnp

        from repro.core.leverage import (
            QUERY_SCORE,
            QUERY_SUBSPACE,
            serve_subspace,
        )
        from repro.kernels.ops import levscore

        mat = np.asarray(snap.matrix)
        d = mat.shape[1] - 2
        q = np.asarray(x, np.float32)
        if q.ndim != 2 or q.shape[1] != d + 1:
            raise ValueError(
                f"tenant {snap.tenant!r}: leverage queries must be (n, {d + 1}) "
                f"[mode, x] rows, got {np.asarray(x).shape}"
            )
        modes, dirs = q[:, 0], q[:, 1:]
        is_sub = modes == QUERY_SUBSPACE
        is_score = modes == QUERY_SCORE
        if not np.all(is_sub | is_score):
            raise ValueError(
                f"tenant {snap.tenant!r}: leverage query mode must be "
                f"{QUERY_SUBSPACE} (subspace) or {QUERY_SCORE} (score)"
            )
        out = np.empty(q.shape[0], np.float32)
        if np.any(is_sub):
            out[is_sub] = serve_subspace(mat, dirs[is_sub], interpret=self.interpret)
        if np.any(is_score):
            out[is_score] = np.asarray(levscore(
                jnp.asarray(self._factor_for(snap), jnp.float32),
                jnp.asarray(dirs[is_score]),
                interpret=self.interpret,
            ))
        return out

    def _factor_for(self, snap: SketchSnapshot) -> np.ndarray:
        """The leverage snapshot's ridge scoring factor, LRU-cached.

        Keyed ``(tenant, version)`` like ``_spectrum_for`` (same shared
        LRU discipline via ``_lru_get``; the version pins ``meta["lam"]``),
        which keeps repeated score sweeps against a pinned snapshot from
        redoing the O(d^3) pseudo-inverse per batch.
        """
        from repro.core.leverage import (
            decode_leverage_snapshot,
            default_lambda,
            ridge_factor,
        )

        def compute() -> np.ndarray:
            rows, _, w = decode_leverage_snapshot(np.asarray(snap.matrix))
            lam = float(snap.meta.get("lam", default_lambda(snap.eps, snap.frob)))
            return ridge_factor(rows, w, lam)

        return self._lru_get(
            self._factor_cache, (snap.tenant, snap.version), compute, "factor"
        )

    def _cached_batch(self, snap: SketchSnapshot, x: np.ndarray) -> np.ndarray:
        spec = self._spectrum_for(snap)
        proj = (x @ spec.vt.T) * spec.s[None, :]
        return np.sum(proj * proj, axis=1, dtype=np.float32).astype(np.float32)

    def _naive_batch(self, snap: SketchSnapshot, x: np.ndarray) -> np.ndarray:
        out = np.empty(x.shape[0], np.float32)
        for i, row in enumerate(x):
            spec = _svd_spectrum(snap.matrix)  # deliberately recomputed per query
            proj = spec.s * (spec.vt @ row)
            out[i] = np.float32(proj @ proj)
        return out

    # -- spectral summaries (served from the same cache) ---------------------

    def top_directions(
        self, k: int, *, tenant: str = "default", version: int | None = None
    ) -> tuple[np.ndarray, np.ndarray]:
        """Streaming-PCA answer: top-k right singular directions + values."""
        spec = self.spectrum(tenant, version)
        k = min(k, spec.s.shape[0])
        return spec.vt[:k], spec.s[:k]

    def stable_rank(self, *, tenant: str = "default", version: int | None = None) -> float:
        """``||B||_F^2 / sigma_1^2`` of the pinned sketch."""
        spec = self.spectrum(tenant, version)
        if spec.s.size == 0:
            return 0.0
        return float(np.sum(spec.s**2) / max(float(spec.s[0] ** 2), 1e-30))


# Non-matrix workloads: snapshot kinds served by their own per-tenant path
# (searchsorted for hh/quantile, weighted quadform / levscore sweeps for
# leverage) instead of joining the cross-tenant quadform pack.  One
# dispatch point for query_batch and query_packed, so adding a kind cannot
# desynchronize the two paths.
_LOOKUPS = {
    "hh": QueryEngine._hh_batch,
    "quantile": QueryEngine._quantile_batch,
    "leverage": QueryEngine._leverage_batch,
}
