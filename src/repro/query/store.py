"""Versioned sketch store — the coordinator's published artifact shelf.

In the paper the coordinator maintains one sketch B and answers
``||A x||^2 ~ ||B x||^2`` queries against it.  At serving scale the sketch
and the query path must be decoupled: trackers *publish* coordinator
sketches here as immutable, monotonically-versioned snapshots (one sequence
per tenant namespace), and the query engine pins a version for the lifetime
of a batch — readers never observe a half-updated sketch and repeated
queries against a pinned version are trivially cacheable.

    tracker.publish(store, tenant="run-42")   # writer side, cheap
    store.get("run-42")                       # latest snapshot
    store.get("run-42", version=7)            # pinned historical snapshot

``retain`` bounds memory: only the newest ``retain`` versions per tenant
are kept (0 = unbounded).  All operations are thread-safe.
"""
from __future__ import annotations

import threading
from typing import Any, Mapping, NamedTuple

import numpy as np

__all__ = ["SketchSnapshot", "SketchStore"]


class SketchSnapshot(NamedTuple):
    """One immutable published sketch.

    matrix:    (l, d) f32 sketch B, write-protected.
    frob:      coordinator estimate of the stream mass ``||A||_F^2``.
    eps:       approximation parameter the sketch was built for.
    delta_sum: accumulated FD shrink mass when known (single-stream
               sketches) — the instance-specific error bound; None for
               distributed protocols where only the paper's worst case
               ``eps * ||A||_F^2`` is certified.
    n_seen:    rows of the stream the sketch summarizes.
    """

    tenant: str
    version: int
    matrix: np.ndarray
    frob: float
    eps: float
    delta_sum: float | None
    n_seen: int
    meta: Mapping[str, Any]

    @property
    def error_bound(self) -> float:
        """Additive bound on ``||A x||^2 - ||B x||^2`` for unit directions x."""
        if self.delta_sum is not None:
            return float(self.delta_sum)
        return float(self.eps * self.frob)


class SketchStore:
    """Per-tenant, monotonically versioned snapshot registry."""

    def __init__(self, *, retain: int = 0):
        if retain < 0:
            raise ValueError(f"retain must be >= 0, got {retain}")
        self.retain = retain
        self._lock = threading.Lock()
        self._snaps: dict[str, dict[int, SketchSnapshot]] = {}
        self._next_version: dict[str, int] = {}

    def publish(
        self,
        tenant: str,
        matrix: np.ndarray,
        *,
        frob: float,
        eps: float,
        delta_sum: float | None = None,
        n_seen: int = 0,
        meta: Mapping[str, Any] | None = None,
    ) -> SketchSnapshot:
        """Register a sketch as the tenant's next version; returns the snapshot."""
        b = np.array(matrix, dtype=np.float32, copy=True)
        if b.ndim != 2:
            raise ValueError(f"sketch matrix must be 2-D, got shape {b.shape}")
        b.setflags(write=False)
        with self._lock:
            version = self._next_version.get(tenant, 1)
            self._next_version[tenant] = version + 1
            snap = SketchSnapshot(
                tenant=tenant,
                version=version,
                matrix=b,
                frob=float(frob),
                eps=float(eps),
                delta_sum=None if delta_sum is None else float(delta_sum),
                n_seen=int(n_seen),
                meta=dict(meta or {}),
            )
            shelf = self._snaps.setdefault(tenant, {})
            shelf[version] = snap
            if self.retain:
                for old in sorted(shelf)[: -self.retain]:
                    del shelf[old]
            return snap

    def get(self, tenant: str, version: int | None = None) -> SketchSnapshot:
        """Fetch a snapshot; ``version=None`` means the latest."""
        with self._lock:
            shelf = self._snaps.get(tenant)
            if not shelf:
                raise KeyError(f"no sketches published for tenant {tenant!r}")
            if version is None:
                version = max(shelf)
            snap = shelf.get(version)
            if snap is None:
                raise KeyError(
                    f"tenant {tenant!r} has no version {version} "
                    f"(available: {sorted(shelf)})"
                )
            return snap

    def latest_version(self, tenant: str) -> int:
        return self.get(tenant).version

    def versions(self, tenant: str) -> list[int]:
        with self._lock:
            return sorted(self._snaps.get(tenant, {}))

    def tenants(self) -> list[str]:
        with self._lock:
            return sorted(self._snaps)

    def __len__(self) -> int:
        with self._lock:
            return sum(len(s) for s in self._snaps.values())
