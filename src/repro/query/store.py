"""Versioned sketch store — the coordinator's published artifact shelf.

In the paper the coordinator maintains one sketch B and answers
``||A x||^2 ~ ||B x||^2`` queries against it.  At serving scale the sketch
and the query path must be decoupled: trackers *publish* coordinator
sketches here as immutable, monotonically-versioned snapshots (one sequence
per tenant namespace), and the query engine pins a version for the lifetime
of a batch — readers never observe a half-updated sketch and repeated
queries against a pinned version are trivially cacheable.

    tracker.publish(store, tenant="run-42")   # writer side, cheap
    store.get("run-42")                       # latest snapshot
    store.get("run-42", version=7)            # pinned historical snapshot

``retain`` bounds memory: only the newest ``retain`` versions per tenant
are kept (0 = unbounded).  All operations are thread-safe.

``save``/``load`` persist the whole store through ``repro.ckpt`` (atomic
rename, per-leaf sha256, zstd/zlib), so a coordinator restart recovers
every tenant's versioned snapshots — including historical versions a
reader may still have pinned.
"""
from __future__ import annotations

import threading
from typing import Any, Mapping, NamedTuple

import numpy as np

__all__ = ["SketchSnapshot", "SketchStore"]


class SketchSnapshot(NamedTuple):
    """One immutable published sketch.

    matrix:    (l, d) f32 sketch B, write-protected.
    frob:      coordinator estimate of the stream mass ``||A||_F^2``.
    eps:       approximation parameter the sketch was built for.
    delta_sum: accumulated FD shrink mass when known (single-stream
               sketches) — the instance-specific error bound; None for
               distributed protocols where only the paper's worst case
               ``eps * ||A||_F^2`` is certified.
    n_seen:    rows of the stream the sketch summarizes.
    published_at: publish timestamp on the tenant's own timeline —
               wall-clock (``obs`` clock) for full-stream tenants, the
               event-time watermark for windowed tenants; 0.0 when the
               publisher tracks no time.  The axis ``as_of`` reads along.
    """

    tenant: str
    version: int
    matrix: np.ndarray
    frob: float
    eps: float
    delta_sum: float | None
    n_seen: int
    meta: Mapping[str, Any]
    published_at: float = 0.0

    @property
    def error_bound(self) -> float:
        """Additive bound on ``||A x||^2 - ||B x||^2`` for unit directions x."""
        if self.delta_sum is not None:
            return float(self.delta_sum)
        return float(self.eps * self.frob)


class SketchStore:
    """Per-tenant, monotonically versioned snapshot registry."""

    def __init__(self, *, retain: int = 0):
        if retain < 0:
            raise ValueError(f"retain must be >= 0, got {retain}")
        self.retain = retain
        self._lock = threading.Lock()
        self._snaps: dict[str, dict[int, SketchSnapshot]] = {}
        self._next_version: dict[str, int] = {}

    def publish(
        self,
        tenant: str,
        matrix: np.ndarray,
        *,
        frob: float,
        eps: float,
        delta_sum: float | None = None,
        n_seen: int = 0,
        meta: Mapping[str, Any] | None = None,
        published_at: float = 0.0,
    ) -> SketchSnapshot:
        """Register a sketch as the tenant's next version; returns the snapshot."""
        b = np.array(matrix, dtype=np.float32, copy=True)
        if b.ndim != 2:
            raise ValueError(f"sketch matrix must be 2-D, got shape {b.shape}")
        b.setflags(write=False)
        with self._lock:
            version = self._next_version.get(tenant, 1)
            self._next_version[tenant] = version + 1
            snap = SketchSnapshot(
                tenant=tenant,
                version=version,
                matrix=b,
                frob=float(frob),
                eps=float(eps),
                delta_sum=None if delta_sum is None else float(delta_sum),
                n_seen=int(n_seen),
                meta=dict(meta or {}),
                published_at=float(published_at),
            )
            shelf = self._snaps.setdefault(tenant, {})
            shelf[version] = snap
            if self.retain:
                for old in sorted(shelf)[: -self.retain]:
                    del shelf[old]
            return snap

    def get(self, tenant: str, version: int | None = None) -> SketchSnapshot:
        """Fetch a snapshot; ``version=None`` means the latest."""
        with self._lock:
            shelf = self._snaps.get(tenant)
            if not shelf:
                raise KeyError(f"no sketches published for tenant {tenant!r}")
            if version is None:
                version = max(shelf)
            snap = shelf.get(version)
            if snap is None:
                raise KeyError(
                    f"tenant {tenant!r} has no version {version} "
                    f"(available: {sorted(shelf)})"
                )
            return snap

    def latest_version(self, tenant: str) -> int:
        """The tenant's newest published version number."""
        return self.get(tenant).version

    def versions(self, tenant: str) -> list[int]:
        """All retained version numbers for a tenant (ascending)."""
        with self._lock:
            return sorted(self._snaps.get(tenant, {}))

    def versions_since(self, tenant: str, after: int) -> list[SketchSnapshot]:
        """Retained snapshots newer than version ``after`` (ascending).

        The replica-sync API: a ``ServingReplica`` tracks the last version
        it pulled per tenant and asks the owning cell for everything
        published since.  ``after=0`` returns every retained version; an
        unknown tenant returns ``[]`` (replicas poll ahead of the first
        publish).  Snapshots are immutable, so handing them across the
        cell boundary shares, never copies.
        """
        with self._lock:
            shelf = self._snaps.get(tenant, {})
            return [shelf[v] for v in sorted(shelf) if v > after]

    def as_of(self, tenant: str, t: float) -> SketchSnapshot:
        """Time-travel read: the newest snapshot published at or before ``t``.

        Versions are immutable and ``published_at`` rides the tenant's own
        timeline (watermark time for windowed tenants), so ``as_of`` lets
        a query replay the exact sketch that was live at any retained
        instant.  Ties (equal ``published_at``) resolve to the higher
        version.  Raises ``KeyError`` when the tenant has no snapshot that
        old — same contract as ``get`` on an unknown version.
        """
        t = float(t)
        with self._lock:
            shelf = self._snaps.get(tenant)
            if not shelf:
                raise KeyError(f"no sketches published for tenant {tenant!r}")
            for v in sorted(shelf, reverse=True):
                if shelf[v].published_at <= t:
                    return shelf[v]
            raise KeyError(
                f"tenant {tenant!r} has no snapshot published at or before t={t} "
                f"(oldest retained: {min(s.published_at for s in shelf.values())})"
            )

    def install(self, snap: SketchSnapshot) -> SketchSnapshot:
        """Install an already-versioned snapshot (replica sync / tenant import).

        Unlike ``publish`` the version number is *preserved* — the cell
        that built the snapshot owns the tenant's version sequence and
        this store mirrors it.  Installing an existing ``(tenant,
        version)`` pair is a no-op returning the resident snapshot
        (idempotent sync); the per-tenant ``retain`` bound still applies.
        """
        with self._lock:
            shelf = self._snaps.setdefault(snap.tenant, {})
            if snap.version in shelf:
                return shelf[snap.version]
            shelf[snap.version] = snap
            nxt = self._next_version.get(snap.tenant, 1)
            self._next_version[snap.tenant] = max(nxt, snap.version + 1)
            if self.retain:
                for old in sorted(shelf)[: -self.retain]:
                    del shelf[old]
            return snap

    def drop_tenant(self, tenant: str) -> int:
        """Forget a tenant's snapshots *and* its version counter.

        Returns the number of snapshots dropped.  Used by the cluster
        rebalancer after a tenant export: the destination cell now owns
        the version sequence, so the source must not retain a counter
        that could fork it.
        """
        with self._lock:
            dropped = len(self._snaps.pop(tenant, {}))
            self._next_version.pop(tenant, None)
            return dropped

    def export_tenant(self, tenant: str) -> tuple[dict, dict]:
        """One tenant's snapshots as ``(tree, extra)`` checkpoint halves.

        The tenant-scoped subset of ``state_tree``: same leaf/extra format
        (``kind: "sketch_store"``), restricted to one tenant's versions
        and version counter.  ``import_tenant`` on another store installs
        it bit-identically — the cluster rebalancer's payload for moving
        a live tenant between cells.
        """
        with self._lock:
            shelf = self._snaps.get(tenant, {})
            snaps = [shelf[v] for v in sorted(shelf)]
            next_version = {tenant: self._next_version.get(tenant, 1)}
        tree = {f"snap_{i:05d}": snap.matrix for i, snap in enumerate(snaps)}
        extra = {
            "kind": "sketch_store",
            "retain": self.retain,
            "next_version": next_version,
            "snapshots": [
                {
                    "key": f"snap_{i:05d}",
                    "tenant": snap.tenant,
                    "version": snap.version,
                    "shape": list(snap.matrix.shape),
                    "frob": snap.frob,
                    "eps": snap.eps,
                    "delta_sum": snap.delta_sum,
                    "n_seen": snap.n_seen,
                    "meta": dict(snap.meta),
                    "published_at": snap.published_at,
                }
                for i, snap in enumerate(snaps)
            ],
        }
        return tree, extra

    def import_tenant(self, tree: dict, extra: dict) -> list[int]:
        """Install an ``export_tenant`` payload; returns installed versions.

        Refuses to overwrite: importing a tenant that already has
        snapshots (or a version counter) here raises — a rebalance must
        move a tenant onto a cell that does not serve it yet.
        """
        if extra.get("kind") != "sketch_store":
            raise ValueError(
                f"tenant payload is not a sketch store export: {extra.get('kind')!r}"
            )
        tenants = {e["tenant"] for e in extra["snapshots"]} | set(extra["next_version"])
        if len(tenants) > 1:
            raise ValueError(f"tenant payload spans multiple tenants: {sorted(tenants)}")
        with self._lock:
            for t in tenants:
                if t in self._snaps or t in self._next_version:
                    raise ValueError(
                        f"tenant {t!r} already present in this store; "
                        "drop_tenant it before importing"
                    )
        # Validate the whole payload BEFORE installing anything: a truncated
        # tree or a manifest/leaf mismatch must raise with the store
        # untouched, never leave a half-imported tenant behind.
        for e in extra["snapshots"]:
            if e["key"] not in tree:
                raise ValueError(
                    f"truncated tenant payload: snapshot entry {e['key']!r} "
                    f"(version {e['version']}) has no matrix in the tree"
                )
            got = np.shape(tree[e["key"]])
            want = tuple(e.get("shape", got))
            if tuple(got) != want:
                raise ValueError(
                    f"tenant payload mismatch: snapshot {e['key']!r} has shape "
                    f"{tuple(got)}, manifest says {want}"
                )
        installed = []
        for e in extra["snapshots"]:
            b = np.asarray(tree[e["key"]], np.float32)
            b.setflags(write=False)
            self.install(
                SketchSnapshot(
                    tenant=e["tenant"],
                    version=int(e["version"]),
                    matrix=b,
                    frob=float(e["frob"]),
                    eps=float(e["eps"]),
                    delta_sum=None if e["delta_sum"] is None else float(e["delta_sum"]),
                    n_seen=int(e["n_seen"]),
                    meta=dict(e["meta"]),
                    published_at=float(e.get("published_at", 0.0)),
                )
            )
            installed.append(int(e["version"]))
        with self._lock:
            for t, v in extra["next_version"].items():
                self._next_version[t] = max(self._next_version.get(t, 1), int(v))
        return installed

    def tenants(self) -> list[str]:
        """All tenant namespaces with at least one published snapshot."""
        with self._lock:
            return sorted(self._snaps)

    def __len__(self) -> int:
        with self._lock:
            return sum(len(s) for s in self._snaps.values())

    # -- persistence (repro.ckpt) -------------------------------------------

    def state_tree(self) -> tuple[dict, dict]:
        """The store as ``(tree, extra)`` checkpoint halves.

        ``tree`` maps leaf keys to snapshot matrices (hashed, compressed
        checkpoint leaves); ``extra`` is the JSON-able structure — tenant
        names, version numbers, certificates, metadata — that
        ``from_state_tree`` needs to rebuild the exact store.  ``save``
        writes exactly this pair; the streaming pipeline embeds it inside
        its own combined checkpoint.
        """
        with self._lock:
            snaps = [s for shelf in self._snaps.values() for s in shelf.values()]
            next_version = dict(self._next_version)
        snaps.sort(key=lambda s: (s.tenant, s.version))
        tree = {f"snap_{i:05d}": snap.matrix for i, snap in enumerate(snaps)}
        extra = {
            "kind": "sketch_store",
            "retain": self.retain,
            "next_version": next_version,
            "snapshots": [
                {
                    "key": f"snap_{i:05d}",
                    "tenant": snap.tenant,
                    "version": snap.version,
                    "shape": list(snap.matrix.shape),
                    "frob": snap.frob,
                    "eps": snap.eps,
                    "delta_sum": snap.delta_sum,
                    "n_seen": snap.n_seen,
                    "meta": dict(snap.meta),
                    "published_at": snap.published_at,
                }
                for i, snap in enumerate(snaps)
            ],
        }
        return tree, extra

    @staticmethod
    def state_template(extra: dict) -> dict:
        """Zero-filled restore template matching a ``state_tree`` extra."""
        return {e["key"]: np.zeros(e["shape"], np.float32) for e in extra["snapshots"]}

    @classmethod
    def from_state_tree(cls, tree: dict, extra: dict) -> "SketchStore":
        """Rebuild a store from ``state_tree`` halves (exact round-trip)."""
        if extra.get("kind") != "sketch_store":
            raise ValueError(f"state extra is not a sketch store: {extra.get('kind')!r}")
        store = cls(retain=int(extra.get("retain", 0)))
        with store._lock:
            for e in extra["snapshots"]:
                b = np.asarray(tree[e["key"]], np.float32)
                b.setflags(write=False)
                snap = SketchSnapshot(
                    tenant=e["tenant"],
                    version=int(e["version"]),
                    matrix=b,
                    frob=float(e["frob"]),
                    eps=float(e["eps"]),
                    delta_sum=None if e["delta_sum"] is None else float(e["delta_sum"]),
                    n_seen=int(e["n_seen"]),
                    meta=dict(e["meta"]),
                    published_at=float(e.get("published_at", 0.0)),
                )
                store._snaps.setdefault(snap.tenant, {})[snap.version] = snap
            store._next_version = {t: int(v) for t, v in extra["next_version"].items()}
        return store

    def save(self, directory: str, *, step: int = 0) -> str:
        """Persist every tenant's versions atomically; returns the path.

        Matrices become checkpoint leaves (hashed, compressed); everything
        else — tenant names, version numbers, certificates, metadata — rides
        the manifest's ``extra`` so ``load`` can rebuild the exact store.
        """
        from repro import ckpt

        tree, extra = self.state_tree()
        return ckpt.save(directory, step, tree, extra=extra)

    @classmethod
    def load(cls, directory: str, *, step: int | None = None) -> "SketchStore":
        """Rebuild a store from ``save`` output (latest step by default)."""
        from repro import ckpt

        if step is None:
            step = ckpt.latest_step(directory)
            if step is None:
                raise FileNotFoundError(f"no sketch-store checkpoint under {directory!r}")
        extra = ckpt.read_extra(directory, step)
        if extra.get("kind") != "sketch_store":
            raise ValueError(f"checkpoint at {directory!r} step {step} is not a sketch store")
        # restore() validates leaf shapes against a template; the store's
        # tree structure varies per save, so the template comes from extra.
        tree, _ = ckpt.restore(directory, step, cls.state_template(extra))
        return cls.from_state_tree(tree, extra)
