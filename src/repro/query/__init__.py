"""Coordinator query-serving subsystem.

The paper splits the problem in two: cheap continuous communication builds a
coordinator sketch B, and B then answers ``||A x||^2`` queries for any
direction at any time.  This package is the second half at serving scale:

  * store.py   — versioned, per-tenant registry of immutable published
                 sketches (trackers publish; readers pin a version).
  * engine.py  — batched quadratic-form serving with an LRU-cached
                 eigendecomposition per (tenant, version), a fused Pallas
                 kernel path (``repro.kernels.quadform``), and cross-tenant
                 batch packing (``query_packed`` — tenants whose sketches
                 share (l, d) ride one ``quadform_packed`` launch).
  * service.py — admission front-ends: ``QueryService`` coalesces single
                 directions for one tenant; ``PackedQueryService`` queues
                 (tenant, direction, deadline) triples and flushes packed
                 cross-tenant sweeps when full or when a deadline expires.
"""
from repro.query.engine import PackedRequest, QueryEngine, QueryResult, Spectrum
from repro.query.service import (
    PackedQueryService,
    PackedServiceStats,
    QueryService,
    QueryShedError,
    QueryTicket,
    ServicePump,
    ServicePumpError,
    ServiceStats,
)
from repro.query.store import SketchSnapshot, SketchStore

__all__ = [
    "PackedQueryService",
    "PackedRequest",
    "PackedServiceStats",
    "QueryEngine",
    "QueryResult",
    "QueryService",
    "QueryShedError",
    "QueryTicket",
    "ServicePump",
    "ServicePumpError",
    "ServiceStats",
    "SketchSnapshot",
    "SketchStore",
    "Spectrum",
]
