"""Coordinator query-serving subsystem.

The paper splits the problem in two: cheap continuous communication builds a
coordinator sketch B, and B then answers ``||A x||^2`` queries for any
direction at any time.  This package is the second half at serving scale:

  * store.py   — versioned, per-tenant registry of immutable published
                 sketches (trackers publish; readers pin a version).
  * engine.py  — batched quadratic-form serving with an LRU-cached
                 eigendecomposition per (tenant, version) and a fused
                 Pallas kernel path (``repro.kernels.quadform``).
  * service.py — admission front-end coalescing single queries into
                 kernel-sized batches, with throughput accounting.
"""
from repro.query.engine import QueryEngine, QueryResult, Spectrum
from repro.query.service import QueryService, QueryTicket, ServiceStats
from repro.query.store import SketchSnapshot, SketchStore

__all__ = [
    "QueryEngine",
    "QueryResult",
    "QueryService",
    "QueryTicket",
    "ServiceStats",
    "SketchSnapshot",
    "SketchStore",
    "Spectrum",
]
