"""Unified telemetry: metrics registry + request tracing.

An ``Observability`` bundle ties one :class:`~repro.obs.MetricsRegistry`
and one :class:`~repro.obs.Tracer` to one shared monotonic clock.  Every
coordinator object (``StreamingPipeline``, ``PipelineCell``,
``ClusterRouter``) owns a bundle; when cells join a router, their
telemetry is re-homed into the router's bundle via the components'
``bind_obs`` methods so the whole cluster scrapes as one registry and
one query traces end to end.

Scope labels ride the bundle: ``obs.scoped(cell="cell-0")`` is a view
sharing the same registry/tracer/clock whose base labels stamp every
series a component binds through it.  Standalone components default to
``cell="-"`` so one metric name keeps one label schema no matter where
it is emitted from.

See ``docs/observability.md`` for the metric catalogue, label
conventions, and trace anatomy.
"""
from __future__ import annotations

import time

from repro.obs.metrics import (
    DEFAULT_BUCKETS,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    histogram_quantile,
)
from repro.obs.tracing import Span, SpanEvent, TraceNode, Tracer

__all__ = [
    "DEFAULT_BUCKETS",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "Observability",
    "Span",
    "SpanEvent",
    "TraceNode",
    "Tracer",
    "histogram_quantile",
    "rebind",
    "rehome_families",
]


class Observability:
    """One registry + one tracer + one clock, owned by a coordinator.

    ``labels`` are the bundle's base labels — merged under every series
    handle fetched through :meth:`handle`.  The default scope is
    ``{"cell": "-"}`` (standalone, not yet part of a cluster).
    """

    def __init__(self, *, clock=None, max_finished_spans: int = 8192,
                 registry: MetricsRegistry | None = None,
                 tracer: Tracer | None = None,
                 labels: dict[str, str] | None = None):
        self.clock = clock if clock is not None else time.perf_counter
        self.registry = (
            registry if registry is not None else MetricsRegistry(clock=self.clock)
        )
        self.tracer = (
            tracer
            if tracer is not None
            else Tracer(clock=self.clock, max_finished=max_finished_spans)
        )
        self.labels = dict(labels) if labels is not None else {"cell": "-"}

    def scoped(self, **labels: str) -> "Observability":
        """A view on the same registry/tracer/clock with merged base labels."""
        return Observability(
            clock=self.clock,
            registry=self.registry,
            tracer=self.tracer,
            labels={**self.labels, **{k: str(v) for k, v in labels.items()}},
        )

    def trace(self, name: str, *, trace_id: str | None = None, **attrs):
        """Shorthand for ``self.tracer.trace(...)``."""
        return self.tracer.trace(name, trace_id=trace_id, **attrs)

    def handle(self, kind: str, name: str, help: str = "", *,
               labels: dict[str, str] | None = None,
               buckets: tuple[float, ...] | None = None):
        """One series handle under this bundle's base labels (+ extras)."""
        merged = {**self.labels, **{k: str(v) for k, v in (labels or {}).items()}}
        names = tuple(sorted(merged))
        if kind == "counter":
            fam = self.registry.counter(name, help, labels=names)
        elif kind == "gauge":
            fam = self.registry.gauge(name, help, labels=names)
        elif kind == "histogram":
            fam = self.registry.histogram(name, help, labels=names, buckets=buckets)
        else:
            raise ValueError(f"unknown metric kind {kind!r}")
        return fam.labels(**merged)


def rebind(obs: Observability, kind: str, name: str, help: str = "", *,
           labels: dict[str, str] | None = None,
           buckets: tuple[float, ...] | None = None, old=None):
    """Fetch a series handle on ``obs``, carrying an old handle's state.

    Components re-home their telemetry when they join a larger scope
    (cell joins router): the value accumulated under the old registry is
    merged into the new series so no history is lost, and a no-op rebind
    (same series object) never double-counts.  Counters add their old
    value, gauges carry the last set value, histograms merge bucket
    counts/sum/count.
    """
    handle = obs.handle(kind, name, help, labels=labels, buckets=buckets)
    if old is None or old is handle:
        return handle
    if kind == "counter":
        if old.value:
            handle.inc(old.value)
    elif kind == "gauge":
        handle.set(old.value)
    else:
        if old.count:
            if old.bounds != handle.bounds:
                raise ValueError(
                    f"cannot rebind histogram {name!r}: bucket bounds differ"
                )
            with handle._lock:
                for i, n in enumerate(old._counts):
                    handle._counts[i] += n
                handle._sum += old.sum
                handle._count += old.count
    return handle


def rehome_families(old_obs: Observability | None, new_obs: Observability,
                    families) -> None:
    """Move a component's metric families from one bundle to another.

    ``families`` is an iterable of ``(kind, name, help)``.  Every series
    of each family that sits under ``old_obs``'s base labels is carried
    into ``new_obs`` (same extra labels, new base labels, values merged
    via :func:`rebind`).  When both bundles share one registry — a
    same-registry relabel, e.g. a bare pipeline joining a named cell —
    the old series are dropped afterwards so snapshots never carry a
    stale duplicate.  A no-op rebind (same registry, same labels) leaves
    everything untouched.
    """
    if old_obs is None:
        return
    same = old_obs.registry is new_obs.registry
    if same and old_obs.labels == new_obs.labels:
        return
    base = old_obs.labels
    for kind, name, help in families:
        try:
            fam = old_obs.registry.get(name)
        except KeyError:
            continue
        for labels, series in fam.series():
            if not all(labels.get(k) == v for k, v in base.items()):
                continue
            extra = {k: v for k, v in labels.items() if k not in base}
            rebind(
                new_obs, kind, name, help, labels=extra, old=series,
                buckets=fam._buckets if kind == "histogram" else None,
            )
            if same:
                fam.drop(**labels)
