"""Span-based request tracing with deterministic IDs.

A ``Tracer`` hands out spans through the ``trace(name, **attrs)``
context manager.  Spans nest on a per-thread stack, so one
``query_batch`` call produces a single tree —

    router.query_batch
      transport.message (cell-0)
        transport.send (attempt 1)
          cell.deliver
            engine.query_packed
      transport.message (cell-1)
        ...

— with per-stage durations read off ``Span.duration_s``.

Trace IDs propagate across process-internal message boundaries by
riding the envelope types' optional ``trace_id`` field: the router
stamps the current trace ID onto each ``Ingest``/``Query``/``Export``/
``Heartbeat`` it sends, and the receiving cell re-enters that trace
when it opens its ``cell.deliver`` span.  A replayed or late-delivered
envelope therefore re-attaches to its *original* trace (as a detached
root of that trace), which is exactly what the chaos suite asserts.

Determinism: trace and span IDs are zero-padded per-tracer counters
(``t000001``, ``s000001``), not random, and the clock is injectable —
two runs of the same seeded fault schedule produce identical trees.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from contextlib import contextmanager
from typing import NamedTuple

__all__ = ["Span", "SpanEvent", "TraceNode", "Tracer"]


class SpanEvent(NamedTuple):
    """A timestamped point event attached to a span (e.g. one retry)."""

    ts_s: float
    name: str
    attrs: dict


class Span:
    """One timed operation within a trace."""

    __slots__ = (
        "trace_id", "span_id", "parent_id", "name", "attrs",
        "start_s", "end_s", "events", "_clock",
    )

    def __init__(self, *, trace_id: str, span_id: str, parent_id: str | None,
                 name: str, attrs: dict, start_s: float, clock):
        self.trace_id = trace_id
        self.span_id = span_id
        self.parent_id = parent_id
        self.name = name
        self.attrs = attrs
        self.start_s = start_s
        self.end_s: float | None = None
        self.events: list[SpanEvent] = []
        self._clock = clock

    @property
    def duration_s(self) -> float | None:
        """Wall time between enter and exit (None while still open)."""
        if self.end_s is None:
            return None
        return self.end_s - self.start_s

    def event(self, name: str, **attrs) -> None:
        """Attach a timestamped point event (e.g. a retry/backoff)."""
        self.events.append(SpanEvent(self._clock(), name, attrs))

    def as_dict(self) -> dict:
        """The span as a plain JSON-able dict."""
        return {
            "trace_id": self.trace_id,
            "span_id": self.span_id,
            "parent_id": self.parent_id,
            "name": self.name,
            "attrs": dict(self.attrs),
            "start_s": self.start_s,
            "end_s": self.end_s,
            "duration_s": self.duration_s,
            "events": [
                {"ts_s": e.ts_s, "name": e.name, "attrs": dict(e.attrs)}
                for e in self.events
            ],
        }

    def __repr__(self) -> str:  # noqa: D105
        return (
            f"Span({self.name!r}, trace={self.trace_id}, span={self.span_id}, "
            f"parent={self.parent_id}, dur={self.duration_s})"
        )


class TraceNode(NamedTuple):
    """One node of an assembled trace tree."""

    span: Span
    children: list

    def walk(self):
        """Yield this node and every descendant, depth-first."""
        yield self
        for child in self.children:
            yield from child.walk()


class Tracer:
    """Allocates spans with deterministic IDs and keeps finished ones."""

    def __init__(self, *, clock=None, max_finished: int = 8192):
        self.clock = clock if clock is not None else time.perf_counter
        self._lock = threading.Lock()
        self._local = threading.local()
        self._finished: deque[Span] = deque(maxlen=max_finished)
        self._n_traces = 0
        self._n_spans = 0

    def _stack(self) -> list[Span]:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        return stack

    def _new_trace_id(self) -> str:
        with self._lock:
            self._n_traces += 1
            return f"t{self._n_traces:06d}"

    def _new_span_id(self) -> str:
        with self._lock:
            self._n_spans += 1
            return f"s{self._n_spans:06d}"

    @contextmanager
    def trace(self, name: str, *, trace_id: str | None = None, **attrs):
        """Open a span named ``name`` and yield it.

        Without ``trace_id``, the span nests under the current span on
        this thread (or roots a fresh trace if there is none).  With an
        explicit ``trace_id``, the span joins that trace: it still nests
        under the current span when the IDs agree, and otherwise becomes
        a detached root of the foreign trace — the replay/late-delivery
        case, where an envelope stamped in an old trace is processed
        inside some newer operation.
        """
        stack = self._stack()
        parent = stack[-1] if stack else None
        if trace_id is None:
            if parent is not None:
                tid, parent_id = parent.trace_id, parent.span_id
            else:
                tid, parent_id = self._new_trace_id(), None
        elif parent is not None and parent.trace_id == trace_id:
            tid, parent_id = trace_id, parent.span_id
        else:
            tid, parent_id = trace_id, None
        span = Span(
            trace_id=tid,
            span_id=self._new_span_id(),
            parent_id=parent_id,
            name=name,
            attrs=dict(attrs),
            start_s=self.clock(),
            clock=self.clock,
        )
        stack.append(span)
        try:
            yield span
        finally:
            stack.pop()
            span.end_s = self.clock()
            with self._lock:
                self._finished.append(span)

    def current(self) -> Span | None:
        """The innermost open span on this thread, if any."""
        stack = self._stack()
        return stack[-1] if stack else None

    def current_trace_id(self) -> str | None:
        """Trace ID of the innermost open span on this thread, if any."""
        span = self.current()
        return span.trace_id if span is not None else None

    def event(self, name: str, **attrs) -> bool:
        """Attach an event to the current span; False if none is open."""
        span = self.current()
        if span is None:
            return False
        span.event(name, **attrs)
        return True

    def finished(self, *, trace_id: str | None = None,
                 name: str | None = None) -> list[Span]:
        """Finished spans, optionally filtered by trace ID and/or name."""
        with self._lock:
            spans = list(self._finished)
        if trace_id is not None:
            spans = [s for s in spans if s.trace_id == trace_id]
        if name is not None:
            spans = [s for s in spans if s.name == name]
        return spans

    def trace_ids(self) -> list[str]:
        """Distinct trace IDs among finished spans, in first-seen order."""
        seen: dict[str, None] = {}
        for s in self.finished():
            seen.setdefault(s.trace_id, None)
        return list(seen)

    def tree(self, trace_id: str) -> list[TraceNode]:
        """Assemble the finished spans of one trace into root nodes.

        Children are ordered by ``(start_s, span_id)``.  Multiple roots
        occur when replays re-attach to a trace after the original root
        closed.
        """
        spans = sorted(
            self.finished(trace_id=trace_id),
            key=lambda s: (s.start_s, s.span_id),
        )
        nodes = {s.span_id: TraceNode(s, []) for s in spans}
        roots: list[TraceNode] = []
        for s in spans:
            node = nodes[s.span_id]
            parent = nodes.get(s.parent_id) if s.parent_id else None
            if parent is not None:
                parent.children.append(node)
            else:
                roots.append(node)
        return roots
