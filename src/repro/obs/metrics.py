"""Labeled metrics registry: counters, gauges, fixed-bucket histograms.

One ``MetricsRegistry`` is the scrapeable telemetry surface for a whole
coordinator (pipeline, cell, or cluster): every layer emits into it, and
the registry serializes three ways —

  * ``snapshot()``       — a plain, JSON-able dict (what benchmarks dump
                           next to their ``BENCH_*.json`` numbers),
  * ``to_json()``        — the snapshot as deterministic JSON text,
  * ``to_prometheus()``  — Prometheus text exposition format (what a
                           future HTTP ``/metrics`` endpoint serves
                           verbatim; see docs/observability.md).

The three agree exactly: ``MetricsRegistry.from_json(reg.to_json())``
rebuilds a registry whose ``to_prometheus()`` output is byte-identical
to the original's (tested).

Design constraints, all driven by the chaos suite:

  * **injectable clock** — the registry's clock (and everything timed
    against it) is a constructor argument, so seeded fault schedules
    produce byte-identical snapshots run over run.
  * **per-registry isolation** — there is no process-global default
    registry; tests can never share counter state by accident.
  * **determinism** — snapshots sort families by name and series by
    label values, so equal histories serialize equally.

Metric families are created idempotently: asking for an existing
``(name)`` with the same kind/labels returns the same family; asking
with a different kind or label set raises (one name, one schema).
"""
from __future__ import annotations

import json
import threading
import time
from bisect import bisect_left

__all__ = [
    "DEFAULT_BUCKETS",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "histogram_quantile",
]

# Latency-shaped default bounds (seconds): 100us .. 10s, roughly 2.5x apart.
DEFAULT_BUCKETS = (
    0.0001, 0.00025, 0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025,
    0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0,
)


def _fmt(v: float) -> str:
    """Prometheus-style number rendering: integral values drop the '.0'."""
    f = float(v)
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


class Counter:
    """A monotonically increasing value (one labeled series)."""

    __slots__ = ("_value", "_lock")

    def __init__(self, lock: threading.Lock):
        self._value = 0.0
        self._lock = lock

    def inc(self, n: float = 1.0) -> None:
        """Add ``n`` (must be >= 0) to the counter."""
        if n < 0:
            raise ValueError(f"counters only go up, got inc({n})")
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        """The current cumulative value."""
        return self._value


class Gauge:
    """A value that can go up and down (one labeled series)."""

    __slots__ = ("_value", "_lock")

    def __init__(self, lock: threading.Lock):
        self._value = 0.0
        self._lock = lock

    def set(self, v: float) -> None:
        """Set the gauge to ``v``."""
        with self._lock:
            self._value = float(v)

    def inc(self, n: float = 1.0) -> None:
        """Add ``n`` (may be negative) to the gauge."""
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        """The current value."""
        return self._value


class Histogram:
    """Fixed-bucket histogram (one labeled series).

    ``bounds`` are inclusive upper bounds in ascending order; an implicit
    ``+Inf`` bucket catches everything beyond the last bound.  Bucket
    counts are stored per-bucket (not cumulative); exporters emit the
    Prometheus cumulative form.
    """

    __slots__ = ("bounds", "_counts", "_sum", "_count", "_lock")

    def __init__(self, bounds: tuple[float, ...], lock: threading.Lock):
        self.bounds = bounds
        self._counts = [0] * (len(bounds) + 1)
        self._sum = 0.0
        self._count = 0
        self._lock = lock

    def observe(self, v: float) -> None:
        """Record one observation."""
        v = float(v)
        with self._lock:
            self._counts[bisect_left(self.bounds, v)] += 1
            self._sum += v
            self._count += 1

    @property
    def sum(self) -> float:
        """Sum of all observations."""
        return self._sum

    @property
    def count(self) -> int:
        """Number of observations."""
        return self._count

    def buckets(self) -> list[tuple[float, int]]:
        """Cumulative ``(upper_bound, count)`` pairs, ``+Inf`` last."""
        out, acc = [], 0
        for bound, n in zip(self.bounds, self._counts):
            acc += n
            out.append((bound, acc))
        out.append((float("inf"), acc + self._counts[-1]))
        return out

    def _restore(self, buckets: list, total_sum: float, count: int) -> None:
        """Install exported cumulative buckets (``from_snapshot`` path)."""
        prev = 0
        for i, (_, cum) in enumerate(buckets[: len(self.bounds)]):
            self._counts[i] = int(cum) - prev
            prev = int(cum)
        self._counts[len(self.bounds)] = int(buckets[-1][1]) - prev
        self._sum = float(total_sum)
        self._count = int(count)


def histogram_quantile(buckets: list[tuple[float, int]], q: float) -> float:
    """Estimate the q-quantile from cumulative ``(le, count)`` buckets.

    Linear interpolation inside the containing bucket, Prometheus-style;
    the lowest bucket interpolates from 0.  Returns 0.0 on an empty
    histogram.  ``q`` is a fraction in [0, 1] (0.5 = p50, 0.99 = p99).
    """
    if not 0.0 <= q <= 1.0:
        raise ValueError(f"quantile must be in [0, 1], got {q}")
    total = buckets[-1][1]
    if total == 0:
        return 0.0
    rank = q * total
    prev_le, prev_cum = 0.0, 0
    for le, cum in buckets:
        if cum >= rank:
            if le == float("inf"):
                return prev_le  # open-ended tail: best estimate is the edge
            if cum == prev_cum:
                return le
            return prev_le + (le - prev_le) * (rank - prev_cum) / (cum - prev_cum)
        prev_le, prev_cum = le, cum
    return prev_le


class _Family:
    """One named metric family: a set of labeled series of one kind."""

    __slots__ = ("name", "kind", "help", "label_names", "_series",
                 "_registry", "_buckets")

    def __init__(self, registry: "MetricsRegistry", name: str, kind: str,
                 help: str, label_names: tuple[str, ...],
                 buckets: tuple[float, ...] | None = None):
        self.name = name
        self.kind = kind
        self.help = help
        self.label_names = label_names
        self._series: dict[tuple[str, ...], object] = {}
        self._registry = registry
        self._buckets = buckets

    def labels(self, **labels: str):
        """The series for one label assignment (created on first use)."""
        if set(labels) != set(self.label_names):
            raise ValueError(
                f"metric {self.name!r} takes labels {self.label_names}, "
                f"got {tuple(sorted(labels))}"
            )
        key = tuple(str(labels[n]) for n in self.label_names)
        with self._registry._lock:
            series = self._series.get(key)
            if series is None:
                series = self._registry._new_series(self.kind, self._buckets)
                self._series[key] = series
        return series

    def drop(self, **labels: str) -> bool:
        """Remove one series (rebind hygiene); True if it existed."""
        key = tuple(str(labels[n]) for n in self.label_names)
        with self._registry._lock:
            return self._series.pop(key, None) is not None

    # label-less families act as their single default series -------------

    def _default(self):
        if self.label_names:
            raise ValueError(
                f"metric {self.name!r} is labeled {self.label_names}; "
                "call .labels(...) first"
            )
        return self.labels()

    def inc(self, n: float = 1.0) -> None:
        """Increment the default (label-less) series."""
        self._default().inc(n)

    def set(self, v: float) -> None:
        """Set the default (label-less) gauge series."""
        self._default().set(v)

    def observe(self, v: float) -> None:
        """Observe into the default (label-less) histogram series."""
        self._default().observe(v)

    @property
    def value(self) -> float:
        """Value of the default (label-less) series."""
        return self._default().value

    def series(self) -> list[tuple[dict[str, str], object]]:
        """Every ``(labels, series)`` pair, sorted by label values."""
        with self._registry._lock:
            items = sorted(self._series.items())
        return [(dict(zip(self.label_names, key)), s) for key, s in items]


class MetricsRegistry:
    """An isolated set of metric families with deterministic exporters."""

    def __init__(self, *, clock=None):
        self.clock = clock if clock is not None else time.perf_counter
        self._lock = threading.Lock()
        self._families: dict[str, _Family] = {}

    def _new_series(self, kind: str, buckets: tuple[float, ...] | None = None):
        if kind == "counter":
            return Counter(self._lock)
        if kind == "gauge":
            return Gauge(self._lock)
        return Histogram(buckets if buckets is not None else DEFAULT_BUCKETS,
                         self._lock)

    def _family(self, name: str, kind: str, help: str,
                labels: tuple[str, ...], buckets=None) -> _Family:
        with self._lock:
            fam = self._families.get(name)
            if fam is not None:
                if fam.kind != kind or fam.label_names != tuple(labels):
                    raise ValueError(
                        f"metric {name!r} already registered as {fam.kind} with "
                        f"labels {fam.label_names}; cannot re-register as "
                        f"{kind} with labels {tuple(labels)}"
                    )
                return fam
            bounds = None
            if kind == "histogram":
                bounds = tuple(buckets if buckets is not None else DEFAULT_BUCKETS)
            fam = _Family(self, name, kind, help, tuple(labels), bounds)
            self._families[name] = fam
            return fam

    def counter(self, name: str, help: str = "", *, labels: tuple[str, ...] = ()) -> _Family:
        """Register (or fetch) a counter family."""
        return self._family(name, "counter", help, labels)

    def gauge(self, name: str, help: str = "", *, labels: tuple[str, ...] = ()) -> _Family:
        """Register (or fetch) a gauge family."""
        return self._family(name, "gauge", help, labels)

    def histogram(
        self,
        name: str,
        help: str = "",
        *,
        labels: tuple[str, ...] = (),
        buckets: tuple[float, ...] | None = None,
    ) -> _Family:
        """Register (or fetch) a fixed-bucket histogram family."""
        fam = self._family(name, "histogram", help, labels, buckets=buckets)
        return fam

    def names(self) -> list[str]:
        """Registered family names (sorted)."""
        return sorted(self._families)

    def get(self, name: str) -> _Family:
        """The named family (KeyError if absent)."""
        return self._families[name]

    # -- exporters -----------------------------------------------------------

    def _sorted_families(self) -> list[tuple[str, _Family]]:
        with self._lock:
            return sorted(self._families.items())

    def drop_series(self, **labels: str) -> int:
        """Drop every series whose labels include the given assignment.

        The rebind hygiene hook: when a component re-homes *within* one
        registry under new labels (or a recovered cell replaces a dead
        incarnation), the stale series would otherwise linger in every
        snapshot.  Families lacking one of the label names are untouched.
        Returns the number of series dropped.
        """
        dropped = 0
        for _, fam in self._sorted_families():
            if not set(labels) <= set(fam.label_names):
                continue
            want = {n: str(v) for n, v in labels.items()}
            with self._lock:
                keys = [
                    key for key in fam._series
                    if all(key[fam.label_names.index(n)] == v
                           for n, v in want.items())
                ]
                for key in keys:
                    del fam._series[key]
                    dropped += 1
        return dropped

    # -- exporters -----------------------------------------------------------

    def snapshot(self) -> dict:
        """The whole registry as one JSON-able dict (sorted, deterministic)."""
        metrics = {}
        for name, fam in self._sorted_families():
            series = []
            for labels, s in fam.series():
                if fam.kind == "histogram":
                    series.append({
                        "labels": labels,
                        "buckets": [["+Inf" if le == float("inf") else le, n]
                                    for le, n in s.buckets()],
                        "sum": s.sum,
                        "count": s.count,
                    })
                else:
                    series.append({"labels": labels, "value": s.value})
            entry = {
                "kind": fam.kind,
                "help": fam.help,
                "label_names": list(fam.label_names),
                "series": series,
            }
            if fam.kind == "histogram":
                entry["bounds"] = list(fam._buckets)
            metrics[name] = entry
        return {"metrics": metrics}

    def to_json(self, *, indent: int | None = None) -> str:
        """The snapshot as deterministic JSON text."""
        return json.dumps(self.snapshot(), sort_keys=True, indent=indent)

    def to_prometheus(self) -> str:
        """Prometheus text exposition format (the future ``/metrics`` body)."""
        lines: list[str] = []
        for name, fam in self._sorted_families():
            if fam.help:
                lines.append(f"# HELP {name} {fam.help}")
            lines.append(f"# TYPE {name} {fam.kind}")
            for labels, s in fam.series():
                lbl = ",".join(f'{k}="{v}"' for k, v in labels.items())
                if fam.kind == "histogram":
                    for le, n in s.buckets():
                        le_s = "+Inf" if le == float("inf") else _fmt(le)
                        blbl = (lbl + "," if lbl else "") + f'le="{le_s}"'
                        lines.append(f"{name}_bucket{{{blbl}}} {n}")
                    tail = f"{{{lbl}}}" if lbl else ""
                    lines.append(f"{name}_sum{tail} {_fmt(s.sum)}")
                    lines.append(f"{name}_count{tail} {s.count}")
                else:
                    tail = f"{{{lbl}}}" if lbl else ""
                    lines.append(f"{name}{tail} {_fmt(s.value)}")
        return "\n".join(lines) + ("\n" if lines else "")

    # -- importers -----------------------------------------------------------

    @classmethod
    def from_snapshot(cls, snap: dict, *, clock=None) -> "MetricsRegistry":
        """Rebuild a registry from ``snapshot()`` output (exact values)."""
        reg = cls(clock=clock)
        for name, entry in snap["metrics"].items():
            label_names = tuple(entry["label_names"])
            kind = entry["kind"]
            if kind == "counter":
                fam = reg.counter(name, entry["help"], labels=label_names)
            elif kind == "gauge":
                fam = reg.gauge(name, entry["help"], labels=label_names)
            else:
                fam = reg.histogram(
                    name, entry["help"], labels=label_names,
                    buckets=tuple(entry["bounds"]),
                )
            for s in entry["series"]:
                child = fam.labels(**s["labels"])
                if kind == "counter":
                    child.inc(s["value"])
                elif kind == "gauge":
                    child.set(s["value"])
                else:
                    buckets = [
                        (float("inf") if le == "+Inf" else float(le), int(n))
                        for le, n in s["buckets"]
                    ]
                    child._restore(buckets, s["sum"], s["count"])
        return reg

    @classmethod
    def from_json(cls, text: str, *, clock=None) -> "MetricsRegistry":
        """Rebuild a registry from ``to_json()`` output (exact values)."""
        return cls.from_snapshot(json.loads(text), clock=clock)
