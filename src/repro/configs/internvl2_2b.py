"""internvl2-2b [vlm]: InternLM2 decoder backbone; ViT frontend is a STUB
(input_specs supplies precomputed patch embeddings).
24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553  [arXiv:2404.16821; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=92553,  # padded to 92672 internally for TP sharding
    rope_theta=1000000.0,
    layer_pattern=("global",),
    frontend="patch",
    n_frontend_tokens=256,
    subquadratic=False,  # pure full attention: long_500k skipped (DESIGN.md)
)
