"""Architecture registry: ``--arch <id>`` resolution + input shapes.

The four assigned input-shape cells:
    train_4k     seq_len=4096   global_batch=256   (train_step)
    prefill_32k  seq_len=32768  global_batch=32    (prefill_step)
    decode_32k   seq_len=32768  global_batch=128   (serve_step, 1 new token)
    long_500k    seq_len=524288 global_batch=1     (serve_step; sub-quadratic
                                                    archs only — see DESIGN.md)
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass

from repro.models.config import ModelConfig

_MODULES = {
    "recurrentgemma-2b": "recurrentgemma_2b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "mixtral-8x7b": "mixtral_8x7b",
    "gemma3-1b": "gemma3_1b",
    "h2o-danube-3-4b": "h2o_danube_3_4b",
    "qwen3-0.6b": "qwen3_0_6b",
    "smollm-135m": "smollm_135m",
    "internvl2-2b": "internvl2_2b",
    "mamba2-370m": "mamba2_370m",
    "musicgen-medium": "musicgen_medium",
}

ARCH_NAMES = tuple(_MODULES)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}

SHAPE_NAMES = tuple(SHAPES)


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {', '.join(ARCH_NAMES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def cell_supported(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Is (arch x shape) a live dry-run cell?  (paper-mandated skips only)"""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "pure full-attention arch: long_500k skipped (DESIGN.md §5)"
    return True, ""


def reduced_config(cfg: ModelConfig) -> ModelConfig:
    """Same-family shrink for CPU smoke tests (deliverable f)."""
    changes: dict = {
        "n_layers": min(cfg.n_layers, 2 * len(cfg.layer_pattern)),
        "d_model": 64 if cfg.resolved_head_dim <= 64 else 128,
        "d_ff": 128 if cfg.d_ff else 0,
        "vocab_size": 256,
        "head_dim": min(cfg.resolved_head_dim, 32),
        "n_heads": min(cfg.n_heads, 4) if cfg.n_heads > 1 else 1,
        "window": min(cfg.window, 16) if cfg.window else 0,
        "rnn_width": 64 if cfg.rnn_width else 0,
        "dtype": "float32",
        "remat": "none",
        "n_frontend_tokens": 8 if cfg.n_frontend_tokens else 0,
    }
    if cfg.is_moe:
        changes["n_experts"] = 4
        changes["experts_per_token"] = 2
    if cfg.family == "ssm":
        changes["ssm_state"] = 16
        changes["ssm_head_dim"] = 16
        changes["n_heads"] = 1
    # keep kv divisibility: n_kv_heads <= n_heads and divides it
    nh = changes["n_heads"]
    kv = min(cfg.n_kv_heads, nh)
    while nh % kv:
        kv -= 1
    changes["n_kv_heads"] = kv
    return dataclasses.replace(cfg, **changes)
