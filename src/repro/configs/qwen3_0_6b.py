"""qwen3-0.6b [dense]: qk-norm, GQA.
28L d_model=1024 16H (GQA kv=8) d_ff=3072 vocab=151936  [hf:Qwen/Qwen3-8B; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-0.6b",
    family="dense",
    n_layers=28,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=3072,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1000000.0,
    layer_pattern=("global",),
    subquadratic=False,  # pure full attention: long_500k skipped (DESIGN.md)
)
