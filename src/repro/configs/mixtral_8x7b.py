"""mixtral-8x7b [moe]: 8 experts top-2, sliding-window attention.
32L d_model=4096 32H (GQA kv=8) d_ff=14336/expert vocab=32000  [arXiv:2401.04088; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    n_experts=8,
    experts_per_token=2,
    moe_virtual_split=2,  # 8 experts x 2 halves = EP-16 on the model axis
    window=4096,
    layer_pattern=("local",),
    rope_theta=1000000.0,
    tie_embeddings=False,
    subquadratic=True,  # SWA: KV bounded by the window
)
