"""musicgen-medium [audio]: decoder-only over EnCodec tokens; the EnCodec
frontend is a STUB (vocab is the codebook; delay-pattern flattening assumed).
48L d_model=1536 24H (MHA kv=24) d_ff=6144 vocab=2048  [arXiv:2306.05284; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab_size=2048,
    rope_theta=10000.0,
    layer_pattern=("global",),
    frontend="frames",
    subquadratic=False,  # pure full attention: long_500k skipped (DESIGN.md)
)
