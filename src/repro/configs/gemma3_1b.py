"""gemma3-1b [dense]: 5:1 local:global interleave, 128k context.
26L d_model=1152 4H (GQA kv=1) d_ff=6912 vocab=262144  [hf:google/gemma-3-1b-pt; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b",
    family="dense",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab_size=262144,
    layer_pattern=("local", "local", "local", "local", "local", "global"),
    window=512,
    qk_norm=True,
    rope_theta=1000000.0,
    subquadratic=True,  # 5:1 local; global layers use seq-sharded decode
)
