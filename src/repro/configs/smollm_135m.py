"""smollm-135m [dense]: llama-arch small; also the e2e-training example arch.
30L d_model=576 9H (GQA kv=3) d_ff=1536 vocab=49152  [hf:HuggingFaceTB/SmolLM-135M; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="smollm-135m",
    family="dense",
    n_layers=30,
    d_model=576,
    n_heads=9,
    n_kv_heads=3,
    head_dim=64,
    d_ff=1536,
    vocab_size=49152,
    rope_theta=10000.0,
    layer_pattern=("global",),
    subquadratic=False,  # pure full attention: long_500k skipped (DESIGN.md)
)
