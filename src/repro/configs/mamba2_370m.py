"""mamba2-370m [ssm]: SSD (state-space duality), attention-free.
48L d_model=1024 d_ff=0 vocab=50280 ssm_state=128  [arXiv:2405.21060; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=1,  # unused (attention-free)
    n_kv_heads=1,
    d_ff=0,
    vocab_size=50280,
    layer_pattern=("ssd",),
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    subquadratic=True,  # O(1) decode state
)
