"""Fault-tolerant checkpointing: atomic, hashed, reshardable, async.

Layout (one directory per step):

    <dir>/step_000123/
        manifest.json    — tree structure, shapes, dtypes, sha256 per leaf
        <leaf-path>.npy.zst

Guarantees:
  * atomic: written to ``.tmp-step_000123`` then os.rename'd — a crash never
    leaves a half-readable checkpoint; ``latest_step`` only sees renamed dirs.
  * integrity: per-leaf sha256 verified on restore (corrupt shards are
    reported by path, the unit of repair on a real fleet).
  * elastic: ``restore`` takes target shardings — a checkpoint written on a
    16x16 mesh restores onto 2x16x16 (or 1 CPU) by device_put-ing each leaf
    with the *new* sharding; nothing in the format is mesh-dependent.
  * async: ``AsyncCheckpointer`` snapshots to host memory synchronously
    (cheap) and writes in a background thread, keeping the train loop hot.
"""
from __future__ import annotations

import hashlib
import io
import json
import os
import re
import threading
import zlib

import jax
import numpy as np

try:  # optional: fall back to stdlib zlib on minimal installs
    import zstandard
except ModuleNotFoundError:
    zstandard = None

__all__ = [
    "save",
    "restore",
    "latest_step",
    "read_extra",
    "read_manifest",
    "read_subset",
    "AsyncCheckpointer",
]

_MANIFEST = "manifest.json"


def _compressor() -> tuple[str, "callable"]:
    if zstandard is not None:
        return "zstd", zstandard.ZstdCompressor(level=3).compress
    return "zlib", lambda raw: zlib.compress(raw, 6)


def _decompress(codec: str, data: bytes) -> bytes:
    if codec == "zstd":
        if zstandard is None:
            raise ModuleNotFoundError(
                "checkpoint was written with zstd; install `zstandard` to restore it"
            )
        return zstandard.ZstdDecompressor().decompress(data)
    if codec == "zlib":
        return zlib.decompress(data)
    raise ValueError(f"unknown checkpoint codec {codec!r}")


def _leaf_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = []
    for path, leaf in flat:
        parts = []
        for k in path:
            key = getattr(k, "key", getattr(k, "idx", getattr(k, "name", None)))
            parts.append(str(key))
        paths.append(("__".join(parts) or "root", leaf))
    return paths, treedef


def save(directory: str, step: int, tree, *, extra: dict | None = None) -> str:
    """Write checkpoint atomically; returns the final path."""
    final = os.path.join(directory, f"step_{step:09d}")
    tmp = os.path.join(directory, f".tmp-step_{step:09d}")
    os.makedirs(tmp, exist_ok=True)
    codec, compress = _compressor()
    leaves, treedef = _leaf_paths(tree)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "codec": codec,
        "extra": extra or {},
        "leaves": {},
    }
    for name, leaf in leaves:
        arr = np.asarray(leaf)
        buf = io.BytesIO()
        np.save(buf, arr, allow_pickle=False)
        raw = buf.getvalue()
        comp = compress(raw)
        digest = hashlib.sha256(raw).hexdigest()
        fn = f"{name}.npy.{'zst' if codec == 'zstd' else codec}"
        with open(os.path.join(tmp, fn), "wb") as f:
            f.write(comp)
        manifest["leaves"][name] = {
            "file": fn,
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
            "sha256": digest,
        }
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        import shutil

        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str) -> int | None:
    """Newest fully-written step under ``directory`` (None when empty).

    Only renamed ``step_*`` directories count — an in-flight ``.tmp-*``
    write is invisible, which is what makes ``save`` atomic to readers.
    """
    if not os.path.isdir(directory):
        return None
    steps = [
        int(m.group(1))
        for d in os.listdir(directory)
        if (m := re.fullmatch(r"step_(\d+)", d))
    ]
    return max(steps) if steps else None


def read_manifest(directory: str, step: int) -> dict:
    """Read a checkpoint's full manifest (no leaf I/O).

    The manifest records every leaf's flattened name, shape, dtype, and
    sha256 — the introspection hook for callers that need to validate a
    checkpoint's leaf set against an expected template (the streaming
    pipeline does this before restoring per-tenant protocol state) or to
    inspect a checkpoint without loading it.
    """
    path = os.path.join(directory, f"step_{step:09d}", _MANIFEST)
    with open(path) as f:
        return json.load(f)


def read_extra(directory: str, step: int) -> dict:
    """Read only the ``extra`` metadata of a checkpoint (no leaf I/O).

    Lets callers whose tree *structure* is described by ``extra`` (e.g. the
    sketch store, whose tenants/versions/shapes vary) build the restore
    template before calling ``restore``.
    """
    return read_manifest(directory, step)["extra"]


def read_subset(directory: str, step: int, names) -> dict[str, np.ndarray]:
    """Read only the named leaves of a checkpoint, sha256-verified.

    ``names`` is an iterable of flattened leaf names as they appear in the
    manifest (``read_manifest(...)["leaves"]``).  This is the
    tenant-scoped restore hook: a full pipeline checkpoint holds every
    tenant's protocol state plus every store snapshot, but a cluster
    rebalance (or a forensic inspection) needs exactly one tenant's
    leaves — reading the subset skips the I/O, decompression, and hashing
    for everything else.  Unknown names raise ``KeyError`` before any
    leaf I/O happens.
    """
    path = os.path.join(directory, f"step_{step:09d}")
    with open(os.path.join(path, _MANIFEST)) as f:
        manifest = json.load(f)
    codec = manifest.get("codec", "zstd")
    names = list(names)
    missing = [n for n in names if n not in manifest["leaves"]]
    if missing:
        raise KeyError(f"checkpoint {path} missing leaves {missing}")
    out: dict[str, np.ndarray] = {}
    for name in names:
        meta = manifest["leaves"][name]
        with open(os.path.join(path, meta["file"]), "rb") as f:
            try:
                raw = _decompress(codec, f.read())
            except Exception as exc:
                # A truncated payload usually dies in the decompressor
                # before the sha check can name the culprit; keep the
                # leaf/path attribution either way.
                raise IOError(
                    f"checkpoint corruption in leaf {name} ({path}): {exc}"
                ) from exc
        if hashlib.sha256(raw).hexdigest() != meta["sha256"]:
            raise IOError(f"checkpoint corruption in leaf {name} ({path})")
        out[name] = np.load(io.BytesIO(raw), allow_pickle=False)
    return out


def restore(directory: str, step: int, template, *, shardings=None):
    """Restore into ``template``'s structure.  ``shardings``: optional pytree
    of Shardings (same structure) — this is the elastic-resharding hook."""
    path = os.path.join(directory, f"step_{step:09d}")
    with open(os.path.join(path, _MANIFEST)) as f:
        manifest = json.load(f)
    codec = manifest.get("codec", "zstd")
    leaves, treedef = _leaf_paths(template)
    shard_leaves = None
    if shardings is not None:
        shard_leaves = [s for _, s in _leaf_paths(shardings)[0]]
    out = []
    for i, (name, leaf) in enumerate(leaves):
        meta = manifest["leaves"].get(name)
        if meta is None:
            raise KeyError(f"checkpoint {path} missing leaf {name}")
        with open(os.path.join(path, meta["file"]), "rb") as f:
            raw = _decompress(codec, f.read())
        if hashlib.sha256(raw).hexdigest() != meta["sha256"]:
            raise IOError(f"checkpoint corruption in leaf {name} ({path})")
        arr = np.load(io.BytesIO(raw), allow_pickle=False)
        if list(arr.shape) != list(np.shape(leaf)):
            raise ValueError(
                f"leaf {name}: checkpoint shape {arr.shape} != template {np.shape(leaf)}"
            )
        if shard_leaves is not None:
            out.append(jax.device_put(arr, shard_leaves[i]))
        else:
            out.append(jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, out), manifest["extra"]


class AsyncCheckpointer:
    """Snapshot-to-host synchronously, write in the background."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None

    def save(self, step: int, tree, *, extra: dict | None = None):
        """Snapshot ``tree`` to host now, write it in a background thread.

        Blocks only for the previous outstanding write (one at a time) and
        the device->host transfer; the compression + disk I/O happen off
        the caller's thread.  Write errors surface on the next ``wait``.
        """
        self.wait()  # one outstanding write at a time
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)

        def work():
            try:
                save(self.directory, step, host_tree, extra=extra)
                self._gc()
            except Exception as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        """Block until the outstanding write finishes; re-raise its error."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self):
        steps = sorted(
            int(m.group(1))
            for d in os.listdir(self.directory)
            if (m := re.fullmatch(r"step_(\d+)", d))
        )
        import shutil

        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:09d}"), ignore_errors=True)
