from repro.ckpt.checkpoint import (
    AsyncCheckpointer,
    latest_step,
    read_extra,
    read_manifest,
    restore,
    save,
)
