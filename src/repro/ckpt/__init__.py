"""Fault-tolerant checkpointing: atomic, hashed, reshardable, async.

Public surface re-exported from ``repro.ckpt.checkpoint`` — see its
module docstring for the on-disk layout and guarantees.
"""
from repro.ckpt.checkpoint import (
    AsyncCheckpointer,
    latest_step,
    read_extra,
    read_manifest,
    read_subset,
    restore,
    save,
)
