"""Consistent-hash tenant partitioning for coordinator cells.

The paper's topology is m sites streaming into one coordinator; the
cluster layer applies the same recursion to the coordinator itself —
many ``PipelineCell`` shards, each owning a disjoint tenant subset.  The
ring decides ownership:

  * deterministic — placement is a pure function of ``(cell names,
    vnodes, tenant name)`` via blake2b, so every router replica, every
    restarted process, and every test computes the same owner.  No
    process-seeded ``hash()`` anywhere.
  * virtual nodes — each cell projects ``vnodes`` points onto the ring,
    smoothing the per-cell tenant share to ``~1/cells`` without any
    central assignment table.
  * minimal rebalance — when the cell set changes, consistent hashing
    moves only the tenants whose arc changed owner (classically ``~K/n``
    of them); ``rebalance_plan`` enumerates exactly those moves so the
    router can stream each affected tenant's live state between cells
    and touch nothing else.

``HashRing`` is immutable: resizing builds a new ring (``with_cells``),
and a plan is computed *between* two rings — the router applies it by
exporting/importing tenants (see ``repro.cluster.router``).
"""
from __future__ import annotations

import bisect
import hashlib
from typing import Iterable, NamedTuple

__all__ = ["HashRing", "TenantMove", "RebalancePlan", "rebalance_plan"]


def _point(key: str) -> int:
    """Deterministic 64-bit ring coordinate of ``key`` (blake2b, not hash())."""
    return int.from_bytes(
        hashlib.blake2b(key.encode("utf-8"), digest_size=8).digest(), "big"
    )


class TenantMove(NamedTuple):
    """One tenant's relocation in a rebalance plan."""

    tenant: str
    src: str  # owning cell under the old ring
    dst: str  # owning cell under the new ring


class RebalancePlan(NamedTuple):
    """A minimal tenant-move plan between two rings.

    ``moves`` holds exactly the tenants whose owner changed (sorted by
    tenant name, so plans are reproducible artifacts); ``unmoved`` counts
    the tenants consistent hashing kept in place — the number a naive
    mod-N repartition would have shuffled for nothing.
    """

    moves: tuple[TenantMove, ...]
    unmoved: int

    @property
    def moved_fraction(self) -> float:
        """Fraction of tenants the plan relocates (0.0 when no tenants)."""
        total = len(self.moves) + self.unmoved
        return len(self.moves) / total if total else 0.0


class HashRing:
    """Immutable consistent-hash ring mapping tenant names to cell names."""

    def __init__(self, cells: Iterable[str], *, vnodes: int = 64):
        names = list(cells)
        if not names:
            raise ValueError("a hash ring needs at least one cell")
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate cell names: {sorted(names)}")
        if vnodes < 1:
            raise ValueError(f"vnodes must be >= 1, got {vnodes}")
        self.vnodes = vnodes
        self._cells = tuple(sorted(names))
        points: list[tuple[int, str]] = []
        for cell in self._cells:
            for v in range(vnodes):
                points.append((_point(f"{cell}#{v}"), cell))
        # blake2b collisions across distinct keys are not a practical
        # concern; ties (if ever) break deterministically by cell name.
        points.sort()
        self._points = [p for p, _ in points]
        self._owners = [c for _, c in points]

    def cells(self) -> tuple[str, ...]:
        """The ring's cell names (sorted)."""
        return self._cells

    def place(self, tenant: str) -> str:
        """The cell that owns ``tenant`` — first vnode clockwise of its point."""
        h = _point(tenant)
        i = bisect.bisect_right(self._points, h)
        if i == len(self._points):  # wrap past the top of the ring
            i = 0
        return self._owners[i]

    def with_cells(self, cells: Iterable[str]) -> "HashRing":
        """A new ring over ``cells`` with the same vnode density."""
        return HashRing(cells, vnodes=self.vnodes)

    def spread(self, tenants: Iterable[str]) -> dict[str, int]:
        """Tenant count per cell (every cell listed, empty cells at 0)."""
        counts = {cell: 0 for cell in self._cells}
        for t in tenants:
            counts[self.place(t)] += 1
        return counts

    def __eq__(self, other: object) -> bool:
        return (
            isinstance(other, HashRing)
            and self._cells == other._cells
            and self.vnodes == other.vnodes
        )

    def __hash__(self) -> int:
        return hash((self._cells, self.vnodes))

    def __repr__(self) -> str:
        return f"HashRing(cells={list(self._cells)}, vnodes={self.vnodes})"


def rebalance_plan(
    old: HashRing, new: HashRing, tenants: Iterable[str]
) -> RebalancePlan:
    """The minimal move set taking ``tenants`` from ``old`` to ``new``.

    Only tenants whose placement differs between the rings appear; a
    grow-by-one ring change therefore moves tenants *onto* the new cell
    only (the consistent-hashing guarantee the tests pin down).
    """
    moves = []
    unmoved = 0
    for tenant in sorted(set(tenants)):
        src, dst = old.place(tenant), new.place(tenant)
        if src != dst:
            moves.append(TenantMove(tenant, src, dst))
        else:
            unmoved += 1
    return RebalancePlan(moves=tuple(moves), unmoved=unmoved)
