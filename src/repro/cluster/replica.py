"""ServingReplica: read-only query serving off published snapshot versions.

The store is already versioned and immutable — the cluster leans into
it: cells keep publishing, and any number of replicas pull the published
versions and serve queries without ever touching a cell's ingest path.
This is the classic read-replica split (database-replication /
distributed-cache shape) built on three properties the repo already has:

  * immutability — a ``SketchSnapshot`` never changes after publish, so
    replication is "install the missing versions" (``versions_since`` on
    the owning cell), idempotent and order-safe; the snapshot *object*
    is shared, never copied.
  * cache-aside factors — the replica runs its own ``QueryEngine``, so
    spectrum/ridge-factor LRU entries are computed beside the replica
    (keyed by the same immutable ``(tenant, version)``) and its
    ``cache_stats`` expose the hit rate per replica.
  * versioned staleness — every answer carries ``versions_behind``: how
    many publishes the owning cell is ahead of the version that answered.
    ``max_versions_behind`` turns the surfaced bound into an enforced
    one — the replica read-through-syncs before answering staler than
    allowed.  A miss (unknown tenant / unpulled pinned version) always
    read-through-fetches from the owner.

Replicas answer from whatever they pulled — the answer's ``error_bound``
certificate still holds (it is the *snapshot's* certificate); staleness
only means the stream has moved on since that version was published.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np

from repro.cluster.cell import PipelineCell
from repro.cluster.transport import StalenessExceededError
from repro.obs import Observability, rehome_families
from repro.query.engine import QueryEngine, QueryResult
from repro.query.store import SketchStore

__all__ = ["ReplicaResult", "ServingReplica", "StalenessExceededError"]


class ReplicaResult(NamedTuple):
    """One replica-served batch + its per-tenant staleness bound."""

    result: QueryResult  # estimates + the snapshot's own certificate
    owner_version: int  # newest version the owning cell had published
    versions_behind: int  # owner_version - served version (0 = fresh)


class ServingReplica:
    """Read-only serving node: pulls published versions, answers queries.

    ``source`` is where ownership lives: a ``ClusterRouter`` (tenants
    resolve through its ring) or a single ``PipelineCell``.  The replica
    holds its own ``SketchStore`` + ``QueryEngine``; nothing it does can
    write back to a cell.
    """

    _FAMILIES = (
        ("counter", "repro_replica_syncs_total",
         "sync() calls (explicit + read-through)."),
        ("counter", "repro_replica_pulled_total",
         "Snapshot versions installed."),
        ("counter", "repro_replica_read_throughs_total",
         "Queries that had to fetch before answering."),
        ("counter", "repro_replica_degraded_total",
         "Owner-blind answers served (query_degraded)."),
        ("gauge", "repro_replica_versions_behind",
         "Publishes the owner is ahead of the last served version, per tenant."),
    )

    def __init__(
        self,
        source,
        *,
        cache_size: int = 16,
        interpret: bool | None = None,
        max_versions_behind: int | None = None,
        retain: int = 0,
        obs: Observability | None = None,
    ):
        if max_versions_behind is not None and max_versions_behind < 0:
            raise ValueError(
                f"max_versions_behind must be >= 0, got {max_versions_behind}"
            )
        self.source = source
        self.max_versions_behind = max_versions_behind
        self.store = SketchStore(retain=retain)
        self.obs = obs if obs is not None else Observability(labels={"cell": "replica"})
        self.engine = QueryEngine(
            self.store, cache_size=cache_size, interpret=interpret, obs=self.obs
        )
        self._synced: dict[str, int] = {}  # tenant -> highest pulled version
        self._owner_seen: dict[str, int] = {}  # newest owner version ever observed
        self._bind_metrics()

    # -- telemetry ------------------------------------------------------------

    def _bind_metrics(self) -> None:
        handles = {
            name: self.obs.handle(kind, name, help)
            for kind, name, help in self._FAMILIES
            if kind == "counter"
        }
        self._m_syncs = handles["repro_replica_syncs_total"]
        self._m_pulled = handles["repro_replica_pulled_total"]
        self._m_read_throughs = handles["repro_replica_read_throughs_total"]
        self._m_degraded = handles["repro_replica_degraded_total"]
        tenants = tuple(getattr(self, "_m_behind", ()))
        self._m_behind = {t: self._behind_handle(t) for t in tenants}

    def _behind_handle(self, tenant: str):
        return self.obs.handle(
            "gauge", "repro_replica_versions_behind",
            "Publishes the owner is ahead of the last served version, per tenant.",
            labels={"tenant": tenant},
        )

    def _set_behind(self, tenant: str, behind: int) -> None:
        h = self._m_behind.get(tenant)
        if h is None:
            h = self._m_behind[tenant] = self._behind_handle(tenant)
        h.set(behind)

    def bind_obs(self, obs: Observability) -> None:
        """Re-home the replica's telemetry (incl. its engine) into ``obs``."""
        old, self.obs = self.obs, obs
        rehome_families(old, obs, self._FAMILIES)
        self._bind_metrics()
        self.engine.bind_obs(obs)

    # Legacy counter attributes, now registry views.

    @property
    def syncs(self) -> int:
        """sync() calls (explicit + read-through)."""
        return int(self._m_syncs.value)

    @property
    def pulled(self) -> int:
        """Snapshot versions installed."""
        return int(self._m_pulled.value)

    @property
    def read_throughs(self) -> int:
        """Queries that had to fetch before answering."""
        return int(self._m_read_throughs.value)

    @property
    def degraded(self) -> int:
        """Owner-blind answers served (query_degraded)."""
        return int(self._m_degraded.value)

    def _cell_for(self, tenant: str) -> PipelineCell:
        if isinstance(self.source, PipelineCell):
            return self.source
        return self.source.cell_for(tenant)

    def _source_tenants(self) -> list[str]:
        return self.source.tenants()

    # -- replication -----------------------------------------------------------

    def sync(self, tenant: str | None = None) -> int:
        """Pull every published version newer than the local high-water mark.

        One tenant, or (``tenant=None``) every tenant the source knows.
        Returns the number of versions installed; pulling is idempotent
        (``SketchStore.install`` keyed by immutable version numbers).
        """
        tenants = [tenant] if tenant is not None else self._source_tenants()
        installed = 0
        for t in tenants:
            after = self._synced.get(t, 0)
            for snap in self._cell_for(t).versions_since(t, after):
                self.store.install(snap)
                self._synced[t] = snap.version
                installed += 1
            self._owner_seen[t] = max(self._owner_seen.get(t, 0), self._synced.get(t, 0))
        self._m_syncs.inc()
        self._m_pulled.inc(installed)
        return installed

    def synced_version(self, tenant: str) -> int:
        """The tenant's highest locally-installed version (0 = none yet)."""
        return self._synced.get(tenant, 0)

    # -- read path -------------------------------------------------------------

    def query_batch(
        self,
        x: np.ndarray,
        *,
        tenant: str,
        version: int | None = None,
        path: str = "pallas",
    ) -> ReplicaResult:
        """Serve a batch from the local versions, surfacing staleness.

        Cache-aside with read-through: answers come from the replica's
        own store/engine; a miss — unknown tenant, or a pinned
        ``version`` that was never pulled — fetches from the owning cell
        first (counted in ``read_throughs``).  When
        ``max_versions_behind`` is set, the replica also refreshes before
        answering more than that many publishes behind the owner.  The
        returned ``versions_behind`` is measured against the owner at
        answer time, so callers always see the bound they actually got.
        """
        have = set(self.store.versions(tenant)) if tenant in self.store.tenants() else set()
        miss = not have if version is None else version not in have
        if miss:
            self._m_read_throughs.inc()
            self.sync(tenant)
        owner_latest = self._cell_for(tenant).latest_version(tenant) or 0
        if (
            version is None
            and self.max_versions_behind is not None
            and owner_latest - self._synced.get(tenant, 0) > self.max_versions_behind
        ):
            self.sync(tenant)
        self._owner_seen[tenant] = max(self._owner_seen.get(tenant, 0), owner_latest)
        res = self.engine.query_batch(x, tenant=tenant, version=version, path=path)
        behind = max(0, owner_latest - res.version)
        self._set_behind(tenant, behind)
        return ReplicaResult(
            result=res,
            owner_version=max(owner_latest, res.version),
            versions_behind=behind,
        )

    def query_degraded(
        self, x: np.ndarray, *, tenant: str, path: str = "pallas"
    ) -> ReplicaResult:
        """Serve purely from local versions — the owner is NOT contacted.

        The open-circuit path: when a cell's breaker is open (or it is
        crashed outright), the router answers from whatever this replica
        already pulled.  ``versions_behind`` is measured against the last
        owner version this replica ever *observed* (recorded at sync /
        read-through time — the owner may have published more since, but
        an unreachable owner cannot be asked), and the declared
        ``max_versions_behind`` bound is enforced: a replica that has
        fallen beyond it raises ``StalenessExceededError`` instead of
        silently serving an answer staler than promised.  Raises
        ``KeyError`` when the tenant was never synced here at all.
        """
        if tenant not in self.store.tenants():
            raise KeyError(
                f"tenant {tenant!r} has no local versions on this replica; "
                "degraded serving needs at least one pre-outage sync"
            )
        res = self.engine.query_batch(x, tenant=tenant, path=path)
        owner_latest = max(self._owner_seen.get(tenant, 0), res.version)
        behind = owner_latest - res.version
        self._set_behind(tenant, behind)
        if self.max_versions_behind is not None and behind > self.max_versions_behind:
            raise StalenessExceededError(tenant, behind, self.max_versions_behind)
        self._m_degraded.inc()
        return ReplicaResult(
            result=res, owner_version=owner_latest, versions_behind=behind
        )

    def stats(self) -> dict:
        """Replication + cache counters (cache half from the engine)."""
        return {
            "syncs": self.syncs,
            "pulled": self.pulled,
            "read_throughs": self.read_throughs,
            "degraded": self.degraded,
            "tenants": len(self.store.tenants()),
            "cache": self.engine.cache_stats(),
        }
