"""ClusterRouter: ring-placed ingest + per-shard packed query fan-out.

The thin routing layer ROADMAP's refactor milestone asks for: tenants
live on exactly one ``PipelineCell`` (consistent-hash placement, see
``hashring``), and the router is the only component that knows the
topology.  It does four things and deliberately nothing else:

  * registration/ingest routing — ``add_*_tenant`` and ``ingest`` go to
    the ring-placed owner; the cell's own ``TenantQuota`` admission still
    applies, and a shed (``QueryShedError``) propagates to the submitter
    *and* is counted per cell (``shed_counts``) so overload is visible at
    the cluster edge, not just inside one shard.
  * query fan-out — ``query_batch`` takes a mixed-tenant batch, groups
    it per owning cell, hands each cell ONE ``query_packed`` call (so
    the cross-tenant ``quadform_packed`` sweep the single pipeline
    earned still fires inside every shard), and gathers results back in
    submission order.
  * parallel drive — ``ingest_many(..., parallel=True)`` runs each
    cell's batch sequence on its own worker thread; cells share nothing
    (own store/engine/service), so the only synchronization is the join.
  * rebalance — ``scale_to(new_cells)`` computes the minimal
    ``RebalancePlan`` between the old and new rings and applies it by
    draining + exporting each moved tenant from its source cell and
    importing it (bit-identically, version numbers preserved) into its
    destination.

One-cell degeneracy: a router over a single cell routes everything to
that cell's pipeline, which is exactly the pre-cluster architecture —
the determinism tests pin 1-cell == 4-cell == bare pipeline per tenant.
"""
from __future__ import annotations

from concurrent.futures import ThreadPoolExecutor
from typing import Iterable, Sequence

import numpy as np

from repro.cluster.cell import PipelineCell
from repro.cluster.hashring import HashRing, RebalancePlan, rebalance_plan
from repro.query.engine import PackedRequest, QueryResult
from repro.query.service import QueryShedError, QueryTicket

__all__ = ["ClusterRouter"]


class ClusterRouter:
    """Routes tenants, ingest, and query batches across coordinator cells."""

    def __init__(self, cells: Sequence[PipelineCell], *, vnodes: int = 64):
        names = [c.name for c in cells]
        self.ring = HashRing(names, vnodes=vnodes)
        self._cells: dict[str, PipelineCell] = {c.name: c for c in cells}
        self._tenant_cell: dict[str, str] = {}
        self._shed_by_cell: dict[str, int] = {name: 0 for name in names}
        self.rebalances = 0

    # -- topology --------------------------------------------------------------

    def cells(self) -> list[str]:
        """Cell names on the ring (sorted)."""
        return list(self.ring.cells())

    def cell(self, name: str) -> PipelineCell:
        """The named cell."""
        return self._cells[name]

    def cell_for(self, tenant: str) -> PipelineCell:
        """The cell that owns (or would own) ``tenant``."""
        return self._cells[self._tenant_cell.get(tenant) or self.ring.place(tenant)]

    def tenants(self) -> list[str]:
        """All tenant names registered through this router (sorted)."""
        return sorted(self._tenant_cell)

    def placement(self) -> dict[str, str]:
        """tenant -> owning cell name, for every registered tenant."""
        return dict(self._tenant_cell)

    # -- tenant registration (ring-placed) ------------------------------------

    def _route_add(self, tenant: str):
        if tenant in self._tenant_cell:
            raise ValueError(f"tenant {tenant!r} already registered")
        name = self.ring.place(tenant)
        self._tenant_cell[tenant] = name
        return self._cells[name]

    def add_tenant(self, tenant: str, d: int, **kw):
        """Register a matrix tenant on its ring-placed cell; returns its tracker."""
        return self._route_add(tenant).pipeline.add_tenant(tenant, d, **kw)

    def add_hh_tenant(self, tenant: str, **kw):
        """Register a heavy-hitter tenant on its ring-placed cell."""
        return self._route_add(tenant).pipeline.add_hh_tenant(tenant, **kw)

    def add_quantile_tenant(self, tenant: str, **kw):
        """Register a quantile tenant on its ring-placed cell."""
        return self._route_add(tenant).pipeline.add_quantile_tenant(tenant, **kw)

    def add_leverage_tenant(self, tenant: str, d: int, **kw):
        """Register a leverage-sampling tenant on its ring-placed cell."""
        return self._route_add(tenant).pipeline.add_leverage_tenant(tenant, d, **kw)

    def _owner(self, tenant: str) -> PipelineCell:
        try:
            return self._cells[self._tenant_cell[tenant]]
        except KeyError:
            raise KeyError(
                f"unknown tenant {tenant!r} (registered: {self.tenants()})"
            ) from None

    # -- ingest routing --------------------------------------------------------

    def ingest(self, tenant: str, rows):
        """Route one super-step batch to the tenant's owning cell."""
        return self._owner(tenant).ingest(tenant, rows)

    def ingest_many(
        self,
        batches: Iterable[tuple[str, "np.ndarray"]],
        *,
        parallel: bool = False,
        packed: bool = True,
    ) -> int:
        """Drive interleaved tenants; returns snapshots published.

        Each cell receives its tenants' batch subsequence as ONE
        ``StreamingPipeline.ingest_many`` call, so in-cell packing (same
        pack-key shard tenants stacked into one super-step launch, see
        ``runtime.ingest_packed``) fires behind every shard boundary;
        ``packed=False`` forces the strict serial loop inside every cell.
        ``parallel=True`` additionally runs each cell's call on its own
        worker thread (thread-per-cell x in-cell packing) — per-tenant
        order is preserved (a tenant lives on one cell, and each cell
        replays its subsequence in order), which is all bit-identical
        ingest requires.  Cells share no state, so the fan-out needs no
        locks beyond the join.
        """
        per_cell: dict[str, list[tuple[str, np.ndarray]]] = {}
        for tenant, rows in batches:
            per_cell.setdefault(self._tenant_cell[tenant], []).append((tenant, rows))

        def drive(name: str, sub: list[tuple[str, np.ndarray]]) -> int:
            return self._cells[name].pipeline.ingest_many(sub, packed=packed)

        if not parallel or len(per_cell) <= 1:
            return sum(drive(name, sub) for name, sub in per_cell.items())

        with ThreadPoolExecutor(max_workers=len(per_cell)) as pool:
            futures = [pool.submit(drive, name, sub) for name, sub in per_cell.items()]
            return sum(f.result() for f in futures)

    # -- query fan-out ---------------------------------------------------------

    def submit(self, tenant: str, x, *, deadline_s: float | None = None) -> QueryTicket:
        """Admit one query on the owning cell's packed service.

        A quota shed propagates to the caller unchanged (shed-and-report
        end to end) and is additionally counted per cell — the cluster
        edge sees which shard is saturating.
        """
        cell = self._owner(tenant)
        try:
            return cell.submit(tenant, x, deadline_s=deadline_s)
        except QueryShedError:
            self._shed_by_cell[cell.name] += 1
            raise

    def shed_counts(self) -> dict[str, int]:
        """Per-cell count of sheds that propagated through this router."""
        return dict(self._shed_by_cell)

    def query_batch(
        self, queries: Sequence[tuple[str, "np.ndarray"]]
    ) -> list[QueryResult]:
        """Serve a mixed-tenant batch: one packed engine call per cell.

        ``queries`` is ``[(tenant, x_batch), ...]``; entries are grouped
        by owning cell, each cell serves its group through
        ``QueryEngine.query_packed`` (tenants sharing an (l, d) sketch
        shape inside a cell still ride one ``quadform_packed`` launch),
        and results come back in submission order — exactly what the
        single pipeline would return for the same list, shard boundaries
        invisible.
        """
        per_cell: dict[str, list[int]] = {}
        for i, (tenant, _) in enumerate(queries):
            per_cell.setdefault(self._tenant_cell[tenant], []).append(i)
        out: list[QueryResult | None] = [None] * len(queries)
        for name, idxs in per_cell.items():
            requests = [
                PackedRequest(tenant=queries[i][0], x=np.asarray(queries[i][1], np.float32))
                for i in idxs
            ]
            for i, res in zip(idxs, self._cells[name].engine.query_packed(requests)):
                out[i] = res
        return out  # type: ignore[return-value]

    def flush(self) -> int:
        """Drain every cell's pending queries; returns total served."""
        return sum(cell.flush() for cell in self._cells.values())

    def poll(self) -> int:
        """Deadline pump across every cell; returns total served."""
        return sum(cell.poll() for cell in self._cells.values())

    # -- rebalance -------------------------------------------------------------

    def plan_scale_to(self, cell_names: Sequence[str]) -> RebalancePlan:
        """The minimal move plan for resizing to ``cell_names`` (dry run)."""
        return rebalance_plan(
            self.ring, self.ring.with_cells(cell_names), self._tenant_cell
        )

    def scale_to(self, cells: Sequence[PipelineCell]) -> RebalancePlan:
        """Resize the cluster to ``cells``, moving only the tenants that must.

        ``cells`` is the *complete* new cell set; existing cells are
        matched by name (their objects are kept — passing a fresh object
        under an existing name replaces it only if it is the same object,
        otherwise raises to protect live state).  Each planned move
        drains the source cell, exports the tenant, imports it into the
        destination, then removes it from the source — queries answered
        after the move are bit-identical to before, version numbers
        included.  A cell leaving the ring must end up empty; a non-empty
        removed cell raises before anything is touched.
        """
        new_by_name: dict[str, PipelineCell] = {}
        for cell in cells:
            if cell.name in new_by_name:
                raise ValueError(f"duplicate cell name {cell.name!r}")
            new_by_name[cell.name] = cell
        for name, cell in new_by_name.items():
            if name in self._cells and cell is not self._cells[name]:
                raise ValueError(
                    f"cell {name!r} already exists with live state; reuse its object"
                )
        new_ring = self.ring.with_cells(new_by_name)
        plan = rebalance_plan(self.ring, new_ring, self._tenant_cell)
        removed = set(self._cells) - set(new_by_name)
        stranded = {
            t: c for t, c in self._tenant_cell.items()
            if c in removed and not any(m.tenant == t for m in plan.moves)
        }
        if stranded:  # cannot happen with a consistent plan; belt-and-braces
            raise RuntimeError(f"tenants stranded on removed cells: {stranded}")

        for move in plan.moves:
            src, dst = self._cells[move.src], new_by_name[move.dst]
            src.flush()
            payload = src.export_tenant(move.tenant)
            dst.import_tenant(payload)
            src.remove_tenant(move.tenant)
            self._tenant_cell[move.tenant] = move.dst

        self.ring = new_ring
        self._cells = new_by_name
        for name in new_by_name:
            self._shed_by_cell.setdefault(name, 0)
        for name in removed:
            self._shed_by_cell.pop(name, None)
        self.rebalances += 1
        return plan

    # -- accounting / lifecycle ------------------------------------------------

    def stats(self) -> dict[str, dict]:
        """Per-cell snapshot: tenants, pending queries, sheds, cache hit
        rate, plus the cell pipeline's ingest-side counters
        (``StreamingPipeline.stats()`` with no tenant: rows_per_sec,
        shrink_launches, pack_occupancy, retraces, ...) under
        ``"ingest"``."""
        out = {}
        for name in self.cells():
            cell = self._cells[name]
            cache = cell.engine.cache_stats()
            out[name] = {
                "tenants": len(cell.tenants()),
                "pending": cell.pipeline.service.pending(),
                "shed": self._shed_by_cell.get(name, 0),
                "cache_hit_rate": cache["hit_rate"],
                "cache_evictions": cache["evictions"],
                "ingest": cell.pipeline.stats(),
            }
        return out

    def close(self) -> None:
        """Release every cell's background resources."""
        for cell in self._cells.values():
            cell.close()

    def __enter__(self) -> "ClusterRouter":
        return self

    def __exit__(self, *exc_info) -> None:
        self.close()
