"""ClusterRouter: ring-placed ingest + per-shard packed query fan-out.

The thin routing layer ROADMAP's refactor milestone asks for: tenants
live on exactly one ``PipelineCell`` (consistent-hash placement, see
``hashring``), and the router is the only component that knows the
topology.  Its core duties:

  * registration/ingest routing — ``add_*_tenant`` and ``ingest`` go to
    the ring-placed owner; the cell's own ``TenantQuota`` admission still
    applies, and a shed (``QueryShedError``) propagates to the submitter
    *and* is counted per cell (``shed_counts``) so overload is visible at
    the cluster edge, not just inside one shard.
  * query fan-out — ``query_batch`` takes a mixed-tenant batch, groups
    it per owning cell, hands each cell ONE ``query_packed`` call (so
    the cross-tenant ``quadform_packed`` sweep the single pipeline
    earned still fires inside every shard), and gathers results back in
    submission order.
  * parallel drive — ``ingest_many(..., parallel=True)`` runs each
    cell's batch sequence on its own worker thread; cells share nothing
    (own store/engine/service), so the only synchronization is the join.
  * rebalance — ``scale_to(new_cells)`` computes the minimal
    ``RebalancePlan`` between the old and new rings and applies it by
    draining + exporting each moved tenant from its source cell and
    importing it (bit-identically, version numbers preserved) into its
    destination.  A readers-writer lock serializes rebalances against
    in-flight ingest/query routing, so a move can never race a live
    wave into dropping or double-applying a batch.

With a ``Transport`` attached the router stops calling cells directly
and speaks typed envelopes instead, layering the resilience stack the
paper's exactly-once communication model needs in practice:

  * every ``Ingest`` is stamped ``(tenant, site, seq)`` and retained in
    a per-cell replay queue until the owning cell's checkpoint makes it
    durable — so a crash-restarted cell can be caught up by replay, and
    the cell's dedup window (see ``PipelineCell.ingest_from``) makes
    that replay safe.
  * sends retry under a ``RetryPolicy`` (capped exponential backoff,
    seeded jitter; the spent budget is in ``stats()["_resilience"]``).
  * each cell has a ``CircuitBreaker``; while open, ingest parks in the
    bounded replay queue (overflow -> ``IngestShedError`` through the
    existing shed path) and queries degrade to the router's attached
    ``ServingReplica``, whose ``versions_behind`` staleness bound is
    enforced on every degraded answer.
  * ``heartbeat_all`` drives health: probes every cell, lets half-open
    breakers trial, drains replay backlogs on recovery, and syncs the
    degraded-serving replica from healthy cells.
  * ``checkpoint_cell``/``recover_cell`` are the crash-restart path:
    checkpoint trims the replay queue to the durable horizon; recovery
    rebuilds a dead cell tenant-by-tenant from its checkpoint via the
    ``ckpt.read_subset`` payload path and replays the retained tail.

One-cell degeneracy: a router over a single cell routes everything to
that cell's pipeline, which is exactly the pre-cluster architecture —
the determinism tests pin 1-cell == 4-cell == bare pipeline per tenant.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import ThreadPoolExecutor
from contextlib import contextmanager
from typing import Iterable, Sequence

import numpy as np

from repro.cluster import transport as tp
from repro.cluster.cell import PipelineCell
from repro.cluster.hashring import HashRing, RebalancePlan, rebalance_plan
from repro.cluster.replica import ServingReplica
from repro.obs import Observability
from repro.query.engine import PackedRequest, QueryResult
from repro.query.service import QueryShedError, QueryTicket
from repro.runtime.policies import RetryPolicy

__all__ = ["ClusterRouter"]


class _RWLock:
    """Many readers xor one writer: routing reads, rebalance writes.

    Keeps ``scale_to`` (which rewrites tenant placement mid-loop) from
    interleaving with a live ``ingest_many`` wave or query fan-out —
    the race that could send a batch to a cell that no longer owns the
    tenant (drop) or to both owners (double-apply).  Read acquisition
    is reentrant-safe by construction: a pending writer waits for
    readers to drain but never blocks new read acquisitions by a thread
    that already holds one (readers only wait on an *active* writer).
    """

    def __init__(self):
        self._cond = threading.Condition()
        self._readers = 0
        self._writing = False

    @contextmanager
    def read(self):
        """Shared acquisition (routing paths)."""
        with self._cond:
            while self._writing:
                self._cond.wait()
            self._readers += 1
        try:
            yield
        finally:
            with self._cond:
                self._readers -= 1
                self._cond.notify_all()

    @contextmanager
    def write(self):
        """Exclusive acquisition (rebalance / recovery / checkpoint-trim)."""
        with self._cond:
            while self._writing or self._readers:
                self._cond.wait()
            self._writing = True
        try:
            yield
        finally:
            with self._cond:
                self._writing = False
                self._cond.notify_all()


class _ReplayEntry:
    """One retained ``Ingest`` envelope + whether the owner acked it."""

    __slots__ = ("env", "acked")

    def __init__(self, env: tp.Ingest):
        self.env = env
        self.acked = False


_BREAKER_STATES = {"closed": 0, "half-open": 1, "open": 2}


class ClusterRouter:
    """Routes tenants, ingest, and query batches across coordinator cells.

    The router owns the cluster's one ``Observability`` bundle: every
    cell's pipeline/engine/service telemetry is re-homed into it at
    construction (``bind_obs``), the transport and the degraded-serving
    replica emit into it, and a query fans out as one trace tree —
    ``router.query_batch`` → ``transport.message``/``transport.send`` →
    ``cell.deliver`` → ``engine.query_packed``.  ``obs.registry`` is the
    scrape surface (``to_prometheus()`` is a ready ``/metrics`` body).
    """

    # Resilience counter order is the legacy _resilience dict order.
    _RES_KEYS = (
        ("messages", "Logical sends (first attempts)."),
        ("attempts", "Total transport sends incl. retries."),
        ("retries", "Attempts beyond the first."),
        ("backoff_s", "Total backoff budget slept (seconds)."),
        ("unreachable", "Messages that exhausted their retry budget."),
        ("parked_ingest", "Batches retained while the owner was out."),
        ("ingest_shed", "Replay-queue overflows (IngestShedError)."),
        ("degraded_queries", "Answers served by the replica."),
        ("heartbeats", "Heartbeat probes sent."),
        ("recoveries", "Crash-restart cell recoveries."),
    )

    def __init__(
        self,
        cells: Sequence[PipelineCell],
        *,
        vnodes: int = 64,
        transport: tp.Transport | None = None,
        retry: RetryPolicy | None = None,
        breaker_threshold: int = 3,
        breaker_cooldown_s: float = 30.0,
        replay_bound: int = 256,
        staleness_bound: int | None = None,
        retry_seed: int = 0,
        clock=None,
        sleep=None,
    ):
        names = [c.name for c in cells]
        self.ring = HashRing(names, vnodes=vnodes)
        self._cells: dict[str, PipelineCell] = {c.name: c for c in cells}
        self._tenant_cell: dict[str, str] = {}
        self.rebalances = 0
        self._rw = _RWLock()

        # -- transport / resilience state (all None-guarded on the hot path) --
        self._transport = transport
        self._retry = (retry or RetryPolicy()).validate()
        self._breaker_threshold = breaker_threshold
        self._breaker_cooldown_s = breaker_cooldown_s
        self._replay_bound = replay_bound
        self._clock = clock if clock is not None else time.monotonic
        self._sleep = sleep if sleep is not None else time.sleep
        self._rng = np.random.default_rng(retry_seed)
        self._seq_lock = threading.Lock()
        self._seq: dict[tuple[str, str], int] = {}  # (tenant, site) -> next seq
        self._replay: dict[str, list[_ReplayEntry]] = {}
        self._breakers: dict[str, tp.CircuitBreaker] = {}
        self._hb_seq = 0
        self.degraded_log: list[tuple[str, int]] = []  # (tenant, versions_behind)

        # -- unified telemetry: one bundle for the whole cluster --------------
        # The router's clock (injectable, like the breakers') times every
        # span and latency metric, so seeded chaos schedules with a fake
        # clock serialize byte-identically run over run.
        self.obs = Observability(clock=clock, labels={})
        self._m_res = {
            k: self.obs.handle(
                "counter",
                f"repro_router_{'backoff_seconds' if k == 'backoff_s' else k}_total",
                h)
            for k, h in self._RES_KEYS
        }
        self._m_shed = {name: self._shed_handle(name) for name in names}
        for cell in cells:
            cell.bind_obs(self.obs.scoped(cell=cell.name))
        self.replica: ServingReplica | None = None
        if transport is not None:
            transport.bind_obs(self.obs)
            for cell in cells:
                transport.register(cell.name, cell.deliver)
                self._breakers[cell.name] = self._new_breaker()
                self._set_breaker_gauge(cell.name)
            self.replica = ServingReplica(
                self, max_versions_behind=staleness_bound,
                obs=self.obs.scoped(cell="replica"),
            )

    # -- telemetry helpers -----------------------------------------------------

    def _shed_handle(self, name: str):
        return self.obs.handle(
            "counter", "repro_router_sheds_total",
            "Sheds that propagated through this router, per cell.",
            labels={"cell": name},
        )

    def _set_breaker_gauge(self, name: str) -> None:
        self.obs.handle(
            "gauge", "repro_router_breaker_state",
            "Per-cell breaker state: 0 closed, 1 half-open, 2 open.",
            labels={"cell": name},
        ).set(_BREAKER_STATES[self._breakers[name].state])

    def _new_breaker(self) -> tp.CircuitBreaker:
        return tp.CircuitBreaker(
            failure_threshold=self._breaker_threshold,
            cooldown_s=self._breaker_cooldown_s,
            clock=self._clock,
        )

    # -- topology --------------------------------------------------------------

    def cells(self) -> list[str]:
        """Cell names on the ring (sorted)."""
        return list(self.ring.cells())

    def cell(self, name: str) -> PipelineCell:
        """The named cell."""
        return self._cells[name]

    def cell_for(self, tenant: str) -> PipelineCell:
        """The cell that owns (or would own) ``tenant``."""
        return self._cells[self._tenant_cell.get(tenant) or self.ring.place(tenant)]

    def tenants(self) -> list[str]:
        """All tenant names registered through this router (sorted)."""
        return sorted(self._tenant_cell)

    def placement(self) -> dict[str, str]:
        """tenant -> owning cell name, for every registered tenant."""
        return dict(self._tenant_cell)

    # -- tenant registration (ring-placed) ------------------------------------

    def _route_add(self, tenant: str):
        if tenant in self._tenant_cell:
            raise ValueError(f"tenant {tenant!r} already registered")
        name = self.ring.place(tenant)
        self._tenant_cell[tenant] = name
        return self._cells[name]

    def add_tenant(self, tenant: str, d: int, **kw):
        """Register a matrix tenant on its ring-placed cell; returns its tracker."""
        return self._route_add(tenant).pipeline.add_tenant(tenant, d, **kw)

    def add_hh_tenant(self, tenant: str, **kw):
        """Register a heavy-hitter tenant on its ring-placed cell."""
        return self._route_add(tenant).pipeline.add_hh_tenant(tenant, **kw)

    def add_quantile_tenant(self, tenant: str, **kw):
        """Register a quantile tenant on its ring-placed cell."""
        return self._route_add(tenant).pipeline.add_quantile_tenant(tenant, **kw)

    def add_leverage_tenant(self, tenant: str, d: int, **kw):
        """Register a leverage-sampling tenant on its ring-placed cell."""
        return self._route_add(tenant).pipeline.add_leverage_tenant(tenant, d, **kw)

    def add_windowed_tenant(self, tenant: str, **kw):
        """Register a time-windowed tenant on its ring-placed cell."""
        return self._route_add(tenant).pipeline.add_windowed_tenant(tenant, **kw)

    def _owner(self, tenant: str) -> PipelineCell:
        try:
            return self._cells[self._tenant_cell[tenant]]
        except KeyError:
            raise KeyError(
                f"unknown tenant {tenant!r} (registered: {self.tenants()})"
            ) from None

    # -- transported send (retry + breaker accounting) -------------------------

    def _send_with_retry(self, name: str, envelope):
        """One logical message: send, retry on loss, settle the breaker.

        Returns the reply, or None when the retry budget is exhausted
        (the message is *unreachable*, counted, and — for ``Ingest`` —
        still safely retained in the replay queue).  Every attempt
        consumes a transport message index, which is what lets the chaos
        suite reconcile ``transport.sends`` against
        ``messages + retries`` exactly.

        Tracing: the logical message is one ``transport.message`` span;
        every attempt opens its own ``transport.send`` child (so counting
        a trace's ``transport.send`` spans counts its attempts exactly),
        and each retry lands as a timestamped event on the message span.
        """
        retry = self._retry
        self._m_res["messages"].inc()
        with self.obs.trace(
            "transport.message", cell=name, kind=type(envelope).__name__
        ) as msg:
            for attempt in range(1, retry.max_attempts + 1):
                self._m_res["attempts"].inc()
                try:
                    with self.obs.trace("transport.send", cell=name, attempt=attempt):
                        reply = self._transport.send(name, envelope)
                except (tp.TransportTimeout, tp.CellDownError) as exc:
                    if attempt < retry.max_attempts:
                        self._m_res["retries"].inc()
                        delay = retry.backoff_s(attempt, float(self._rng.random()))
                        self._m_res["backoff_s"].inc(delay)
                        msg.event(
                            "retry", attempt=attempt,
                            error=type(exc).__name__, backoff_s=delay,
                        )
                        self._sleep(delay)
                else:
                    self._breakers[name].record_success()
                    self._set_breaker_gauge(name)
                    return reply
            self._breakers[name].record_failure()
            self._set_breaker_gauge(name)
            self._m_res["unreachable"].inc()
            return None

    # -- ingest routing --------------------------------------------------------

    def ingest(self, tenant: str, rows, *, site: str = "site-0",
               ts: float | None = None):
        """Route one super-step batch to the tenant's owning cell.

        ``ts`` stamps the batch's event time for windowed tenants; the
        timestamp rides the ingest envelope (``TimedRows``), so seq
        stamping, the replay queue, and idempotent cell-side dedup all
        see one opaque batch — replaying a timed batch after a fault
        applies the same event time.

        Direct mode (no transport) returns whatever the pipeline's
        ingest returns.  Transported mode stamps the batch with the next
        ``(tenant, site)`` seq, retains it in the owner's replay queue
        (bounded; overflow sheds with ``IngestShedError``), and returns
        the owner's ``IngestAck`` — or None when the owner is open/
        unreachable and the batch is parked for later replay.
        """
        if ts is not None:
            from repro.core.windows import TimedRows

            rows = TimedRows(
                rows.rows if isinstance(rows, TimedRows) else rows, float(ts)
            )
        with self._rw.read():
            if self._transport is None:
                with self.obs.trace("router.ingest", tenant=tenant, site=site):
                    return self._owner(tenant).ingest(tenant, rows)
            with self.obs.trace("router.ingest", tenant=tenant, site=site):
                cell_name = self._owner(tenant).name
                with self._seq_lock:
                    buf = self._replay.setdefault(cell_name, [])
                    pending = sum(1 for e in buf if not e.acked)
                    if pending >= self._replay_bound:
                        self._m_shed[cell_name].inc()
                        self._m_res["ingest_shed"].inc()
                        raise tp.IngestShedError(tenant, pending, self._replay_bound)
                    seq = self._seq.get((tenant, site), 1)
                    self._seq[(tenant, site)] = seq + 1
                    entry = _ReplayEntry(tp.Ingest(
                        tenant, site, seq, rows,
                        trace_id=self.obs.tracer.current_trace_id(),
                    ))
                    buf.append(entry)
                if not self._breakers[cell_name].allow():
                    self._m_res["parked_ingest"].inc()
                    return None
                ack = self._send_with_retry(cell_name, entry.env)
                if ack is None:
                    self._m_res["parked_ingest"].inc()
                    return None
                entry.acked = True
                return ack

    def ingest_many(
        self,
        batches: Iterable[tuple[str, "np.ndarray"]],
        *,
        parallel: bool = False,
        packed: bool = True,
    ) -> int:
        """Drive interleaved tenants; returns snapshots published.

        Each cell receives its tenants' batch subsequence as ONE
        ``StreamingPipeline.ingest_many`` call, so in-cell packing (same
        pack-key shard tenants stacked into one super-step launch, see
        ``runtime.ingest_packed``) fires behind every shard boundary;
        ``packed=False`` forces the strict serial loop inside every cell.
        ``parallel=True`` additionally runs each cell's call on its own
        worker thread (thread-per-cell x in-cell packing) — per-tenant
        order is preserved (a tenant lives on one cell, and each cell
        replays its subsequence in order), which is all bit-identical
        ingest requires.  Cells share no state, so the fan-out needs no
        locks beyond the join — and the router-level readers-writer lock
        holds the placement fixed for the whole wave, so a concurrent
        ``scale_to`` waits rather than moving a tenant mid-wave.

        With a transport attached the wave crosses the message boundary
        batch-by-batch instead (seq stamping has no packed equivalent);
        returns the number of publishes acked.

        Entries may carry event time for windowed tenants as
        ``(tenant, rows, ts)`` triples or ``(tenant, TimedRows)`` pairs.
        """
        from repro.core.windows import TimedRows

        batches = [
            (b[0], TimedRows(b[1].rows if isinstance(b[1], TimedRows) else b[1],
                             float(b[2])))
            if len(b) == 3 else (b[0], b[1])
            for b in (tuple(b) for b in batches)
        ]
        if self._transport is not None:
            published = 0
            for tenant, rows in batches:
                ack = self.ingest(tenant, rows)
                if ack is not None and ack.version is not None:
                    published += 1
            return published
        with self._rw.read():
            per_cell: dict[str, list[tuple[str, np.ndarray]]] = {}
            for tenant, rows in batches:
                per_cell.setdefault(self._tenant_cell[tenant], []).append((tenant, rows))

            def drive(name: str, sub: list[tuple[str, np.ndarray]]) -> int:
                return self._cells[name].pipeline.ingest_many(sub, packed=packed)

            if not parallel or len(per_cell) <= 1:
                return sum(drive(name, sub) for name, sub in per_cell.items())

            with ThreadPoolExecutor(max_workers=len(per_cell)) as pool:
                futures = [
                    pool.submit(drive, name, sub) for name, sub in per_cell.items()
                ]
                return sum(f.result() for f in futures)

    # -- query fan-out ---------------------------------------------------------

    def submit(self, tenant: str, x, *, deadline_s: float | None = None) -> QueryTicket:
        """Admit one query on the owning cell's packed service.

        A quota shed propagates to the caller unchanged (shed-and-report
        end to end) and is additionally counted per cell — the cluster
        edge sees which shard is saturating.
        """
        with self._rw.read():
            cell = self._owner(tenant)
            try:
                return cell.submit(tenant, x, deadline_s=deadline_s)
            except QueryShedError:
                self._m_shed[cell.name].inc()
                raise

    def shed_counts(self) -> dict[str, int]:
        """Per-cell count of sheds that propagated through this router."""
        return {name: int(h.value) for name, h in self._m_shed.items()}

    def query_batch(
        self, queries: Sequence[tuple[str, "np.ndarray"]]
    ) -> list[QueryResult]:
        """Serve a mixed-tenant batch: one packed engine call per cell.

        ``queries`` is ``[(tenant, x_batch), ...]``; entries are grouped
        by owning cell, each cell serves its group through
        ``QueryEngine.query_packed`` (tenants sharing an (l, d) sketch
        shape inside a cell still ride one ``quadform_packed`` launch),
        and results come back in submission order — exactly what the
        single pipeline would return for the same list, shard boundaries
        invisible.

        With a transport attached, a cell whose breaker is open (or that
        stays unreachable through the retry budget) degrades gracefully:
        its group's answers come from the attached ``ServingReplica``,
        each enforced against the declared ``staleness_bound`` and logged
        in ``degraded_log`` as ``(tenant, versions_behind)``.
        """
        with self._rw.read(), self.obs.trace(
            "router.query_batch", queries=len(queries)
        ):
            per_cell: dict[str, list[int]] = {}
            for i, (tenant, _) in enumerate(queries):
                per_cell.setdefault(self._tenant_cell[tenant], []).append(i)
            out: list[QueryResult | None] = [None] * len(queries)
            for name, idxs in per_cell.items():
                requests = [
                    PackedRequest(
                        tenant=queries[i][0], x=np.asarray(queries[i][1], np.float32)
                    )
                    for i in idxs
                ]
                if self._transport is None:
                    results = self._cells[name].engine.query_packed(requests)
                else:
                    results = None
                    if self._breakers[name].allow():
                        env = tp.Query(
                            tuple(requests),
                            trace_id=self.obs.tracer.current_trace_id(),
                        )
                        results = self._send_with_retry(name, env)
                    if results is None:
                        results = [self._degraded(req) for req in requests]
                for i, res in zip(idxs, results):
                    out[i] = res
            return out  # type: ignore[return-value]

    def _degraded(self, request: PackedRequest) -> QueryResult:
        """Serve one request from the replica (owner open/unreachable)."""
        rr = self.replica.query_degraded(request.x, tenant=request.tenant)
        self._m_res["degraded_queries"].inc()
        self.degraded_log.append((request.tenant, rr.versions_behind))
        return rr.result

    def flush(self) -> int:
        """Drain every cell's pending queries; returns total served."""
        return sum(cell.flush() for cell in self._cells.values())

    def poll(self) -> int:
        """Deadline pump across every cell; returns total served."""
        return sum(cell.poll() for cell in self._cells.values())

    # -- health / replay / crash-restart (transport mode) ----------------------

    def heartbeat_all(self) -> dict[str, str]:
        """Probe every cell; returns ``{name: "ok" | "open" | "failed"}``.

        The operator loop: a healthy reply settles the breaker closed
        and — if the cell has a replay backlog — drains it (dedup makes
        over-delivery safe); an open breaker past its cooldown gets its
        half-open trial here; replicas sync from every healthy cell so
        degraded serving has fresh versions *before* the next outage.
        """
        if self._transport is None:
            raise RuntimeError("heartbeat_all requires a transport-attached router")
        out: dict[str, str] = {}
        with self._rw.read():
            for name in self.cells():
                if not self._breakers[name].allow():
                    out[name] = "open"
                    continue
                self._hb_seq += 1
                self._m_res["heartbeats"].inc()
                env = tp.Heartbeat(
                    self._hb_seq, trace_id=self.obs.tracer.current_trace_id()
                )
                ack = self._send_with_retry(name, env)
                if ack is None:
                    out[name] = "failed"
                    continue
                out[name] = "ok"
                if any(not e.acked for e in self._replay.get(name, ())):
                    self._drain_replay(name)
            for tenant, cname in sorted(self._tenant_cell.items()):
                if out.get(cname) == "ok":
                    self.replica.sync(tenant)
        return out

    def _drain_replay(self, name: str, *, include_acked: bool = False) -> int:
        """Resend retained batches in per-(tenant, site) seq order.

        The receiving cell's dedup window drops anything already applied
        or already durable, so replaying conservatively cannot
        double-count a row.  Ordinary drains (heartbeat recovery from a
        transient outage) resend only unacked entries; a crash-restart
        drain (``include_acked=True``) resends *everything* retained —
        an ack from the dead incarnation proves nothing about the
        rebuilt one, which rolled back to the checkpoint horizon.  Stops
        at the first unreachable send; returns the number acked.
        """
        pending = sorted(
            (
                e
                for e in self._replay.get(name, ())
                if include_acked or not e.acked
            ),
            key=lambda e: (e.env.tenant, e.env.site, e.env.seq),
        )
        acked = 0
        for entry in pending:
            if self._send_with_retry(name, entry.env) is None:
                break
            entry.acked = True
            acked += 1
        return acked

    def checkpoint_cell(self, name: str, directory: str, *, step: int = 0) -> str:
        """Checkpoint one cell and trim its replay queue to the durable horizon.

        The cell's save carries its dedup horizons as a manifest
        attachment; every retained batch that is both acked *and* below
        the checkpointed horizon is now durable at the owner and can be
        forgotten here — the replay queue is a write-ahead tail, not a
        full log.
        """
        with self._rw.write():
            cell = self._cells[name]
            cell.flush()
            path = cell.save(directory, step=step)
            horizons = cell.dedup_state()
            self._replay[name] = [
                e
                for e in self._replay.get(name, [])
                if not (
                    e.acked
                    and e.env.seq
                    < horizons.get(e.env.tenant, {}).get(e.env.site, 1)
                )
            ]
            return path

    def recover_cell(
        self,
        name: str,
        fresh_cell: PipelineCell,
        directory: str,
        *,
        step: int | None = None,
    ) -> int:
        """Crash-restart: rebuild a dead cell from its checkpoint, replay the tail.

        Every tenant the ring assigns to ``name`` is rebuilt into
        ``fresh_cell`` via the tenant-scoped ``ckpt.read_subset`` payload
        path (``StreamingPipeline.read_tenant_export``), the checkpointed
        dedup horizons are restored (so replay cannot double-apply what
        was already durable), the transport endpoint is revived, the
        breaker resets closed, and the retained replay queue is drained.
        Returns the number of batches re-acked during the drain.
        """
        from repro import ckpt

        if self._transport is None:
            raise RuntimeError("recover_cell requires a transport-attached router")
        if fresh_cell.name != name:
            raise ValueError(
                f"replacement cell is named {fresh_cell.name!r}, expected {name!r}"
            )
        with self._rw.write():
            if step is None:
                step = ckpt.latest_step(directory)
                if step is None:
                    raise FileNotFoundError(f"no cell checkpoint under {directory!r}")
            try:
                self._cells[name].close()
            except Exception:
                pass  # the old object is dead weight either way
            owned = sorted(t for t, c in self._tenant_cell.items() if c == name)
            from repro.runtime.pipeline import StreamingPipeline

            for tenant in owned:
                payload = StreamingPipeline.read_tenant_export(
                    directory, tenant, step=step
                )
                fresh_cell.import_tenant(payload)
            attachments = ckpt.read_extra(directory, step).get("attachments", {})
            fresh_cell.restore_dedup(attachments.get("cell", {}).get("dedup", {}))
            self._cells[name] = fresh_cell
            # The dead incarnation's per-cell series go with it — but router
            # sheds are *router* state, so carry that one value across.
            shed = self._m_shed[name].value
            self.obs.registry.drop_series(cell=name)
            self._m_shed[name] = self._shed_handle(name)
            if shed:
                self._m_shed[name].inc(shed)
            fresh_cell.bind_obs(self.obs.scoped(cell=name))
            self._transport.revive(name, fresh_cell.deliver)
            self._breakers[name] = self._new_breaker()
            self._set_breaker_gauge(name)
            self._m_res["recoveries"].inc()
            return self._drain_replay(name, include_acked=True)

    # -- rebalance -------------------------------------------------------------

    def plan_scale_to(self, cell_names: Sequence[str]) -> RebalancePlan:
        """The minimal move plan for resizing to ``cell_names`` (dry run)."""
        return rebalance_plan(
            self.ring, self.ring.with_cells(cell_names), self._tenant_cell
        )

    def scale_to(self, cells: Sequence[PipelineCell]) -> RebalancePlan:
        """Resize the cluster to ``cells``, moving only the tenants that must.

        ``cells`` is the *complete* new cell set; existing cells are
        matched by name (their objects are kept — passing a fresh object
        under an existing name replaces it only if it is the same object,
        otherwise raises to protect live state).  Each planned move
        drains the source cell, exports the tenant, imports it into the
        destination, then removes it from the source — queries answered
        after the move are bit-identical to before, version numbers
        included.  A cell leaving the ring must end up empty; a non-empty
        removed cell raises before anything is touched.

        Runs under the router's writer lock: an in-flight ``ingest_many``
        wave or query fan-out finishes against the old placement before
        any tenant moves, and later waves see only the new placement — a
        batch can be neither dropped nor double-applied mid-move.  With a
        transport attached, each export crosses the message boundary
        (``Export`` envelope, retried; an unreachable source aborts the
        rebalance), and the moved tenant's seq horizons + retained replay
        entries follow it to the destination cell.
        """
        with self._rw.write():
            new_by_name: dict[str, PipelineCell] = {}
            for cell in cells:
                if cell.name in new_by_name:
                    raise ValueError(f"duplicate cell name {cell.name!r}")
                new_by_name[cell.name] = cell
            for name, cell in new_by_name.items():
                if name in self._cells and cell is not self._cells[name]:
                    raise ValueError(
                        f"cell {name!r} already exists with live state; reuse its object"
                    )
            new_ring = self.ring.with_cells(new_by_name)
            plan = rebalance_plan(self.ring, new_ring, self._tenant_cell)
            removed = set(self._cells) - set(new_by_name)
            stranded = {
                t: c for t, c in self._tenant_cell.items()
                if c in removed and not any(m.tenant == t for m in plan.moves)
            }
            if stranded:  # cannot happen with a consistent plan; belt-and-braces
                raise RuntimeError(f"tenants stranded on removed cells: {stranded}")

            for name, cell in new_by_name.items():
                if name not in self._cells:
                    cell.bind_obs(self.obs.scoped(cell=name))
                    self._m_shed.setdefault(name, self._shed_handle(name))
                    if self._transport is not None:
                        self._transport.register(name, cell.deliver)
                        self._breakers[name] = self._new_breaker()
                        self._set_breaker_gauge(name)

            for move in plan.moves:
                src, dst = self._cells[move.src], new_by_name[move.dst]
                src.flush()
                if self._transport is not None:
                    payload = self._send_with_retry(move.src, tp.Export(move.tenant))
                    if payload is None:
                        raise RuntimeError(
                            f"cell {move.src!r} unreachable; cannot rebalance "
                            f"tenant {move.tenant!r}"
                        )
                else:
                    payload = src.export_tenant(move.tenant)
                dst.import_tenant(payload)
                if self._transport is not None:
                    dst.adopt_dedup(move.tenant, src.dedup_for(move.tenant))
                    src_buf = self._replay.get(move.src, [])
                    moved = [e for e in src_buf if e.env.tenant == move.tenant]
                    if moved:
                        self._replay[move.src] = [
                            e for e in src_buf if e.env.tenant != move.tenant
                        ]
                        self._replay.setdefault(move.dst, []).extend(moved)
                src.remove_tenant(move.tenant)
                if self._transport is not None:
                    src.drop_dedup(move.tenant)
                self._tenant_cell[move.tenant] = move.dst
                # Tenant-labelled gauges under the old owner are stale now.
                self.obs.registry.drop_series(cell=move.src, tenant=move.tenant)

            self.ring = new_ring
            self._cells = new_by_name
            for name in removed:
                self._m_shed.pop(name, None)
                self._breakers.pop(name, None)
                self._replay.pop(name, None)
                # A removed cell's label series would otherwise linger on
                # the scrape surface forever at their final values.
                self.obs.registry.drop_series(cell=name)
            self.rebalances += 1
            return plan

    # -- accounting / lifecycle ------------------------------------------------

    def stats(self) -> dict[str, dict]:
        """Per-cell snapshot: tenants, pending queries, sheds, cache hit
        rate, plus the cell pipeline's ingest-side counters
        (``StreamingPipeline.stats()`` with no tenant: rows_per_sec,
        shrink_launches, pack_occupancy, retraces, ...) under
        ``"ingest"``.  A transport-attached router adds per-cell breaker
        state / replay depth / endpoint delivery counters, and one
        reserved ``"_resilience"`` entry carrying the spent retry budget
        (messages, attempts, retries, backoff seconds) and the raw
        transport outcome counters."""
        out = {}
        for name in self.cells():
            cell = self._cells[name]
            cache = cell.engine.cache_stats()
            out[name] = {
                "tenants": len(cell.tenants()),
                "pending": cell.pipeline.service.pending(),
                "shed": int(self._m_shed[name].value) if name in self._m_shed else 0,
                "cache_hit_rate": cache["hit_rate"],
                "cache_evictions": cache["evictions"],
                "ingest": cell.pipeline.stats(),
            }
            if self._transport is not None:
                buf = self._replay.get(name, [])
                out[name]["breaker"] = self._breakers[name].state
                out[name]["replay_pending"] = sum(1 for e in buf if not e.acked)
                out[name]["replay_retained"] = len(buf)
                out[name]["transport"] = dict(cell.transport_counts)
        if self._transport is not None:
            out["_resilience"] = {
                **{
                    k: (h.value if k == "backoff_s" else int(h.value))
                    for k, h in self._m_res.items()
                },
                "transport": {
                    "sends": self._transport.sends,
                    **self._transport.counters,
                },
            }
        return out

    def close(self) -> None:
        """Release every cell's background resources."""
        for cell in self._cells.values():
            cell.close()

    def __enter__(self) -> "ClusterRouter":
        return self

    def __exit__(self, *exc_info) -> None:
        self.close()
