"""PipelineCell: today's ``StreamingPipeline`` as one coordinator shard.

The paper's coordinator, recursed: each cell IS a full single-process
coordinator — its own ``SketchStore``, ``QueryEngine``, packed service,
quotas, ``ServicePump``, and ``repro.ckpt`` save/load — owning the
disjoint tenant subset the cluster's ``HashRing`` assigns it.  The cell
adds exactly the shard-boundary surface the router and replicas need:

  * tenant migration — ``export_tenant``/``import_tenant`` ride the
    pipeline's checkpoint contract (``state_payload``/``restore_payload``
    plus the store's version-preserving tenant subset), so a rebalance
    moves a *live* tenant between cells bit-identically: protocol state,
    publish counters, and every published version number survive.
  * replica sync — ``versions_since`` hands out the tenant's immutable
    published snapshots newer than a high-water mark (what
    ``ServingReplica`` pulls), and ``latest_version`` is the staleness
    reference point.

The transport boundary (``repro.cluster.transport``) lands here as the
``deliver`` endpoint: every ``Ingest`` envelope carries ``(tenant, site,
seq)`` and the cell keeps a per-``(tenant, site)`` dedup window — seq
below the window is acknowledged but NOT re-applied (idempotence: a
retried batch whose ack was lost cannot double-count rows), seq ahead of
the window is parked in a bounded reassembly buffer until the gap fills
(delayed/reordered deliveries apply in stream order).  The window's
horizons ride the pipeline checkpoint as an attachment, so a
crash-restarted cell keeps refusing batches that are already durable.

Everything else is deliberately a thin delegation: a one-cell cluster
behaves exactly like the bare pipeline (tested), which is what makes the
N-cell determinism argument compositional.
"""
from __future__ import annotations

import jax

from repro.cluster import transport as tp
from repro.obs import Observability, rehome_families
from repro.query.store import SketchSnapshot
from repro.runtime.pipeline import StreamingPipeline

__all__ = ["PipelineCell"]


class PipelineCell:
    """One coordinator shard: a named ``StreamingPipeline`` + move/sync APIs."""

    # Event order is the legacy transport_counts dict order.
    _EVENTS = (
        "applied",  # Ingest envelopes absorbed (first delivery)
        "duplicate",  # acknowledged without re-applying
        "parked",  # held for reassembly (gap ahead of them)
        "queries",  # Query envelopes served
        "heartbeats",  # Heartbeat probes answered
    )

    _FAMILIES = (
        ("counter", "repro_cell_transport_total",
         "Transport envelopes handled, partitioned by event."),
    )

    def __init__(
        self,
        name: str,
        mesh: jax.sharding.Mesh,
        *,
        pipeline: StreamingPipeline | None = None,
        park_bound: int = 64,
        **pipeline_kw,
    ):
        if not name:
            raise ValueError("a cell needs a non-empty name")
        if park_bound < 1:
            raise ValueError(f"park_bound must be >= 1, got {park_bound}")
        self.name = name
        if pipeline is None:
            # The cell's bundle carries its name as the base ``cell`` label
            # so every pipeline/engine/service series is scoped to it.
            pipeline_kw.setdefault("obs", Observability(labels={"cell": name}))
            pipeline = StreamingPipeline(mesh, **pipeline_kw)
        elif pipeline.obs.labels.get("cell") != name:
            # An adopted standalone pipeline (cell="-"): relabel its whole
            # telemetry under this cell's name.
            pipeline.bind_obs(pipeline.obs.scoped(cell=name))
        self.pipeline = pipeline
        self.obs = pipeline.obs
        self._bind_metrics()
        self.park_bound = park_bound
        # transport dedup window: (tenant, site) -> next expected seq (from 1)
        self._next_seq: dict[tuple[str, str], int] = {}
        # out-of-order reassembly: (tenant, site) -> {seq: rows}, bounded
        self._parked: dict[tuple[str, str], dict[int, object]] = {}

    # -- telemetry ------------------------------------------------------------

    def _bind_metrics(self) -> None:
        self._m_transport = {
            e: self.obs.handle(
                "counter", "repro_cell_transport_total",
                "Transport envelopes handled, partitioned by event.",
                labels={"event": e})
            for e in self._EVENTS
        }

    def bind_obs(self, obs: Observability) -> None:
        """Re-home the whole cell (incl. its pipeline stack) into ``obs``."""
        old, self.obs = self.obs, obs
        rehome_families(old, obs, self._FAMILIES)
        self._bind_metrics()
        self.pipeline.bind_obs(obs)

    @property
    def transport_counts(self) -> dict[str, int]:
        """Envelopes handled per event (fresh dict, registry view)."""
        return {e: int(self._m_transport[e].value) for e in self._EVENTS}

    # -- thin delegation (the cell IS a coordinator) --------------------------

    @property
    def store(self):
        """The cell's own versioned snapshot store."""
        return self.pipeline.store

    @property
    def engine(self):
        """The cell's own query engine (per-cell spectrum/factor caches)."""
        return self.pipeline.engine

    def tenants(self) -> list[str]:
        """Tenant names this cell owns (sorted)."""
        return self.pipeline.tenants()

    def ingest(self, tenant: str, rows):
        """Absorb one super-step batch for an owned tenant (see pipeline)."""
        return self.pipeline.ingest(tenant, rows)

    def ingest_many(self, batches, *, packed: bool = True) -> int:
        """Drive owned tenants' interleaved batches, packing same-shape
        shard tenants per wave (see ``StreamingPipeline.ingest_many``)."""
        return self.pipeline.ingest_many(batches, packed=packed)

    # -- transport endpoint (idempotent ingest + packed serve) -----------------

    def deliver(self, envelope):
        """Dispatch one typed transport envelope (the cell's wire surface).

        ``Ingest`` goes through the dedup/reassembly window
        (``ingest_from``), ``Query`` through the packed engine sweep,
        ``Export`` through the rebalance export, ``Heartbeat`` answers
        with the tenant count.  This is what the router registers with
        the ``Transport`` — and re-registers on ``revive`` after a
        crash-restart rebuild.
        """
        # Join the sender's trace: a delivery that happens inside the
        # sender's live send span nests under it; a late replay whose
        # originating trace has moved on becomes a detached root of the
        # *original* trace (same trace_id) — one logical message, one tree.
        with self.obs.trace(
            "cell.deliver",
            trace_id=getattr(envelope, "trace_id", None),
            cell=self.name,
            kind=type(envelope).__name__,
        ):
            if isinstance(envelope, tp.Ingest):
                return self.ingest_from(
                    envelope.tenant, envelope.site, envelope.seq, envelope.rows
                )
            if isinstance(envelope, tp.Query):
                self._m_transport["queries"].inc()
                return self.engine.query_packed(list(envelope.requests))
            if isinstance(envelope, tp.Export):
                return self.export_tenant(envelope.tenant)
            if isinstance(envelope, tp.Heartbeat):
                self._m_transport["heartbeats"].inc()
                return tp.HeartbeatAck(envelope.seq, len(self.tenants()))
            raise TypeError(f"unknown envelope type {type(envelope).__name__}")

    def ingest_from(self, tenant: str, site: str, seq: int, rows) -> "tp.IngestAck":
        """Idempotent, order-restoring ingest: apply exactly once, in seq order.

        seq below the window: already absorbed — ack ``"duplicate"``, do
        NOT re-apply (this is what makes sender retries safe).  seq ahead
        of the window: park in the bounded reassembly buffer and ack
        ``"parked"`` (an overflowing gap raises — the sender's replay
        queue still holds the batch).  seq == window: apply, then drain
        any contiguously-parked successors, so a delayed-then-flushed
        batch lands in exactly the order the stream produced it.
        """
        key = (tenant, site)
        expected = self._next_seq.get(key, 1)
        if seq < expected:
            self._m_transport["duplicate"].inc()
            return tp.IngestAck("duplicate", seq, None)
        if seq > expected:
            parked = self._parked.setdefault(key, {})
            if seq not in parked:
                if len(parked) >= self.park_bound:
                    raise tp.IngestShedError(tenant, len(parked), self.park_bound)
                parked[seq] = rows
            self._m_transport["parked"].inc()
            return tp.IngestAck("parked", seq, None)
        version = self._apply(tenant, key, rows)
        # gap just filled: absorb contiguous parked successors in order
        parked = self._parked.get(key)
        while parked:
            nxt = self._next_seq[key]
            if nxt not in parked:
                break
            v = self._apply(tenant, key, parked.pop(nxt))
            version = v if v is not None else version
        self._m_transport["applied"].inc()
        return tp.IngestAck("applied", seq, version)

    def _apply(self, tenant: str, key: tuple[str, str], rows) -> int | None:
        snap = self.pipeline.ingest(tenant, rows)
        self._next_seq[key] = self._next_seq.get(key, 1) + 1
        return None if snap is None else snap.version

    # -- dedup window persistence / migration ----------------------------------

    def dedup_state(self) -> dict:
        """The durable half of the window: ``{tenant: {site: next_seq}}``.

        Parked (not-yet-applied) batches are deliberately volatile —
        the router's replay queue still owns them until they apply.
        """
        out: dict[str, dict[str, int]] = {}
        for (tenant, site), nxt in sorted(self._next_seq.items()):
            out.setdefault(tenant, {})[site] = nxt
        return out

    def restore_dedup(self, state: dict) -> None:
        """Install checkpointed dedup horizons (crash-restart path)."""
        for tenant, sites in state.items():
            for site, nxt in sites.items():
                self._next_seq[(tenant, site)] = int(nxt)

    def dedup_for(self, tenant: str) -> dict[str, int]:
        """One tenant's ``{site: next_seq}`` horizons (rebalance handoff)."""
        return {s: n for (t, s), n in self._next_seq.items() if t == tenant}

    def adopt_dedup(self, tenant: str, horizons: dict[str, int]) -> None:
        """Take over a moved-in tenant's seq horizons from its old owner."""
        for site, nxt in horizons.items():
            self._next_seq[(tenant, site)] = int(nxt)

    def drop_dedup(self, tenant: str) -> None:
        """Forget a moved-away tenant's window (horizons and parked gaps)."""
        for key in [k for k in self._next_seq if k[0] == tenant]:
            del self._next_seq[key]
        for key in [k for k in self._parked if k[0] == tenant]:
            del self._parked[key]

    def parked_count(self, tenant: str | None = None) -> int:
        """Batches held for reassembly (one tenant, or all)."""
        return sum(
            len(v)
            for (t, _), v in self._parked.items()
            if tenant is None or t == tenant
        )

    def submit(self, tenant: str, x, *, deadline_s: float | None = None):
        """Admit one query for an owned tenant (see pipeline.submit)."""
        return self.pipeline.submit(tenant, x, deadline_s=deadline_s)

    def flush(self) -> int:
        """Drain this cell's pending queries in packed sweeps."""
        return self.pipeline.flush()

    def poll(self) -> int:
        """Deadline pump for this cell's packed service."""
        return self.pipeline.poll()

    def close(self) -> None:
        """Release the cell's background resources (pump thread)."""
        self.pipeline.close()

    def __enter__(self) -> "PipelineCell":
        return self

    def __exit__(self, *exc_info) -> None:
        self.close()

    # -- tenant migration (rebalance path) ------------------------------------

    def export_tenant(self, tenant: str) -> dict:
        """Capture a live owned tenant as a portable payload (drained first)."""
        return self.pipeline.export_tenant(tenant)

    def import_tenant(self, payload: dict) -> str:
        """Install an exported tenant here; returns its name."""
        self.pipeline.import_tenant(payload)
        return payload["tenant"]

    def remove_tenant(self, tenant: str) -> None:
        """Deregister a moved-away tenant and drop its local versions."""
        self.pipeline.remove_tenant(tenant)

    # -- replica sync ----------------------------------------------------------

    def versions_since(self, tenant: str, after: int) -> list[SketchSnapshot]:
        """Published snapshots newer than ``after`` (ascending; [] if none)."""
        return self.store.versions_since(tenant, after)

    def latest_version(self, tenant: str) -> int | None:
        """The tenant's newest published version here (None before first)."""
        try:
            return self.store.latest_version(tenant)
        except KeyError:
            return None

    # -- persistence -----------------------------------------------------------

    def save(self, directory: str, *, step: int = 0) -> str:
        """Checkpoint the whole cell (one pipeline ckpt); returns the path.

        The transport dedup horizons ride the same atomic step as a
        manifest attachment, so recovery restores the exactly-once
        window together with the state it protects: a replayed batch
        that is already durable here stays refused after a crash.
        """
        return self.pipeline.save(
            directory,
            step=step,
            attachments={"cell": {"name": self.name, "dedup": self.dedup_state()}},
        )

    @classmethod
    def load(
        cls,
        name: str,
        directory: str,
        mesh: jax.sharding.Mesh,
        *,
        step: int | None = None,
        **pipeline_kw,
    ) -> "PipelineCell":
        """Rebuild a cell from ``save`` output (latest step by default).

        Restores the pipeline *and* the checkpoint's dedup horizons, so
        the reloaded cell refuses replays of batches that were durable
        at save time.
        """
        from repro import ckpt

        if step is None:
            step = ckpt.latest_step(directory)
        pipeline = StreamingPipeline.load(directory, mesh, step=step, **pipeline_kw)
        cell = cls(name, mesh, pipeline=pipeline)
        if step is not None:
            attachments = ckpt.read_extra(directory, step).get("attachments", {})
            cell.restore_dedup(attachments.get("cell", {}).get("dedup", {}))
        return cell

    def __repr__(self) -> str:
        return f"PipelineCell({self.name!r}, tenants={self.tenants()})"
