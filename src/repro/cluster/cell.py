"""PipelineCell: today's ``StreamingPipeline`` as one coordinator shard.

The paper's coordinator, recursed: each cell IS a full single-process
coordinator — its own ``SketchStore``, ``QueryEngine``, packed service,
quotas, ``ServicePump``, and ``repro.ckpt`` save/load — owning the
disjoint tenant subset the cluster's ``HashRing`` assigns it.  The cell
adds exactly the shard-boundary surface the router and replicas need:

  * tenant migration — ``export_tenant``/``import_tenant`` ride the
    pipeline's checkpoint contract (``state_payload``/``restore_payload``
    plus the store's version-preserving tenant subset), so a rebalance
    moves a *live* tenant between cells bit-identically: protocol state,
    publish counters, and every published version number survive.
  * replica sync — ``versions_since`` hands out the tenant's immutable
    published snapshots newer than a high-water mark (what
    ``ServingReplica`` pulls), and ``latest_version`` is the staleness
    reference point.

Everything else is deliberately a thin delegation: a one-cell cluster
behaves exactly like the bare pipeline (tested), which is what makes the
N-cell determinism argument compositional.
"""
from __future__ import annotations

import jax

from repro.query.store import SketchSnapshot
from repro.runtime.pipeline import StreamingPipeline

__all__ = ["PipelineCell"]


class PipelineCell:
    """One coordinator shard: a named ``StreamingPipeline`` + move/sync APIs."""

    def __init__(
        self,
        name: str,
        mesh: jax.sharding.Mesh,
        *,
        pipeline: StreamingPipeline | None = None,
        **pipeline_kw,
    ):
        if not name:
            raise ValueError("a cell needs a non-empty name")
        self.name = name
        self.pipeline = (
            pipeline if pipeline is not None else StreamingPipeline(mesh, **pipeline_kw)
        )

    # -- thin delegation (the cell IS a coordinator) --------------------------

    @property
    def store(self):
        """The cell's own versioned snapshot store."""
        return self.pipeline.store

    @property
    def engine(self):
        """The cell's own query engine (per-cell spectrum/factor caches)."""
        return self.pipeline.engine

    def tenants(self) -> list[str]:
        """Tenant names this cell owns (sorted)."""
        return self.pipeline.tenants()

    def ingest(self, tenant: str, rows):
        """Absorb one super-step batch for an owned tenant (see pipeline)."""
        return self.pipeline.ingest(tenant, rows)

    def ingest_many(self, batches, *, packed: bool = True) -> int:
        """Drive owned tenants' interleaved batches, packing same-shape
        shard tenants per wave (see ``StreamingPipeline.ingest_many``)."""
        return self.pipeline.ingest_many(batches, packed=packed)

    def submit(self, tenant: str, x, *, deadline_s: float | None = None):
        """Admit one query for an owned tenant (see pipeline.submit)."""
        return self.pipeline.submit(tenant, x, deadline_s=deadline_s)

    def flush(self) -> int:
        """Drain this cell's pending queries in packed sweeps."""
        return self.pipeline.flush()

    def poll(self) -> int:
        """Deadline pump for this cell's packed service."""
        return self.pipeline.poll()

    def close(self) -> None:
        """Release the cell's background resources (pump thread)."""
        self.pipeline.close()

    def __enter__(self) -> "PipelineCell":
        return self

    def __exit__(self, *exc_info) -> None:
        self.close()

    # -- tenant migration (rebalance path) ------------------------------------

    def export_tenant(self, tenant: str) -> dict:
        """Capture a live owned tenant as a portable payload (drained first)."""
        return self.pipeline.export_tenant(tenant)

    def import_tenant(self, payload: dict) -> str:
        """Install an exported tenant here; returns its name."""
        self.pipeline.import_tenant(payload)
        return payload["tenant"]

    def remove_tenant(self, tenant: str) -> None:
        """Deregister a moved-away tenant and drop its local versions."""
        self.pipeline.remove_tenant(tenant)

    # -- replica sync ----------------------------------------------------------

    def versions_since(self, tenant: str, after: int) -> list[SketchSnapshot]:
        """Published snapshots newer than ``after`` (ascending; [] if none)."""
        return self.store.versions_since(tenant, after)

    def latest_version(self, tenant: str) -> int | None:
        """The tenant's newest published version here (None before first)."""
        try:
            return self.store.latest_version(tenant)
        except KeyError:
            return None

    # -- persistence -----------------------------------------------------------

    def save(self, directory: str, *, step: int = 0) -> str:
        """Checkpoint the whole cell (one pipeline ckpt); returns the path."""
        return self.pipeline.save(directory, step=step)

    @classmethod
    def load(
        cls,
        name: str,
        directory: str,
        mesh: jax.sharding.Mesh,
        *,
        step: int | None = None,
        **pipeline_kw,
    ) -> "PipelineCell":
        """Rebuild a cell from ``save`` output (latest step by default)."""
        pipeline = StreamingPipeline.load(directory, mesh, step=step, **pipeline_kw)
        return cls(name, mesh, pipeline=pipeline)

    def __repr__(self) -> str:
        return f"PipelineCell({self.name!r}, tenants={self.tenants()})"
