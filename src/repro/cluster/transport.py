"""Fault-injectable in-process transport between the router and its cells.

The paper's eps-guarantee and its O((m/eps) log(beta N)) communication
bound both assume every site->coordinator message is delivered exactly
once: a dropped push silently weakens the served envelope, a duplicated
one double-counts rows and breaks it outright.  This module makes that
assumption *checkable* instead of implicit by putting a real message
boundary between ``ClusterRouter`` and each ``PipelineCell``:

  * typed envelopes — ``Ingest`` (stamped ``(tenant, site, seq)`` so the
    receiving cell can deduplicate and reassemble), ``Query``,
    ``Export``, and ``Heartbeat``; replies are ``IngestAck`` /
    ``HeartbeatAck`` / the cell's native return values.
  * ``Transport`` — a synchronous in-process link with per-send fault
    injection.  Every ``send`` consumes one global message index
    (retries included — that is what lets the chaos tests account for
    every retry), and a ``FaultPlan`` scripts what happens at each
    index: **drop** (the message is lost; the sender sees
    ``TransportTimeout``), **duplicate** (delivered twice; the second
    delivery's reply is discarded, exercising receiver idempotence),
    **delay** (parked at the destination and delivered *after* a later
    message — an observable reorder), **crash** (the destination dies
    mid-receive and stays dead until ``revive``).
  * ``CircuitBreaker`` — the classic closed/open/half-open machine the
    router keeps per cell, with an injectable clock so tests drive the
    cooldown deterministically.
  * ``IngestShedError`` — raised when an unreachable cell's bounded
    replay queue overflows; it subclasses ``QueryShedError`` so the
    overflow rides the existing ``TenantQuota`` shed-and-report path.

Determinism is the design driver: a ``FaultPlan`` is a pure function of
the global send index, so the same driver sequence under the same plan
produces the same faults, the same retries, and — because the cells are
idempotent — byte-identical served answers (``tests/test_chaos.py``).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, NamedTuple

import numpy as np

from repro.obs import Observability, rehome_families
from repro.query.engine import PackedRequest
from repro.query.service import QueryShedError

__all__ = [
    "Ingest",
    "Query",
    "Export",
    "Heartbeat",
    "IngestAck",
    "HeartbeatAck",
    "TransportTimeout",
    "CellDownError",
    "IngestShedError",
    "StalenessExceededError",
    "FaultPlan",
    "Transport",
    "CircuitBreaker",
]


# ---------------------------------------------------------------------------
# Envelopes (the wire format, minus the wire)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Ingest:
    """One super-step batch: ``(tenant, site, seq)`` is the dedup identity.

    ``seq`` is per ``(tenant, site)`` and starts at 1; the receiving cell
    applies batches in seq order exactly once, acking duplicates without
    re-applying and parking out-of-order arrivals until the gap fills.
    ``rows`` is whatever the tenant's workload ingests (a row block, or a
    ``(keys, weights)`` pair for item workloads).

    ``trace_id`` (here and on every envelope kind) stitches distributed
    traces: the sender stamps its live trace, and the receiving cell
    joins its ``cell.deliver`` span to that trace — retries, duplicates,
    and late replays of one logical message all land in one tree.
    """

    tenant: str
    site: str
    seq: int
    rows: object
    trace_id: str | None = None


@dataclass(frozen=True)
class Query:
    """A packed query group for one cell (a tuple of ``PackedRequest``)."""

    requests: tuple[PackedRequest, ...]
    trace_id: str | None = None


@dataclass(frozen=True)
class Export:
    """Request one tenant's portable export payload (rebalance path)."""

    tenant: str
    trace_id: str | None = None


@dataclass(frozen=True)
class Heartbeat:
    """Liveness probe; the reply carries the cell's tenant count."""

    seq: int
    trace_id: str | None = None


class IngestAck(NamedTuple):
    """Receiver's answer to one ``Ingest``.

    status:  ``"applied"`` (first delivery, absorbed now — possibly along
             with previously-parked successors), ``"duplicate"`` (seq is
             below the dedup window; acknowledged, NOT re-applied), or
             ``"parked"`` (ahead of the window; held until the gap fills).
    seq:     echo of the envelope's seq.
    version: the newest version published while absorbing this delivery
             (None if the publish policy did not fire or nothing applied).
    """

    status: str
    seq: int
    version: int | None


class HeartbeatAck(NamedTuple):
    """Reply to a ``Heartbeat``: the probe's seq + the cell's tenant count."""

    seq: int
    tenants: int


# ---------------------------------------------------------------------------
# Errors
# ---------------------------------------------------------------------------


class TransportTimeout(RuntimeError):
    """The message was lost (dropped/delayed/crashed mid-receive): no reply.

    The sender cannot distinguish "never arrived" from "arrived but the
    ack was lost" — which is exactly why receivers must be idempotent.
    """


class CellDownError(RuntimeError):
    """The destination endpoint is crashed and has not been revived."""


class IngestShedError(QueryShedError):
    """An unreachable cell's bounded replay queue overflowed.

    Subclasses ``QueryShedError`` so cluster-edge accounting
    (``ClusterRouter.shed_counts``) and callers' shed handling treat
    ingest overflow exactly like the existing ``TenantQuota`` query
    sheds: typed, counted, never silent.
    """


class StalenessExceededError(RuntimeError):
    """A degraded replica answer would exceed its declared staleness bound."""

    def __init__(self, tenant: str, behind: int, bound: int):
        super().__init__(
            f"tenant {tenant!r}: replica is {behind} versions behind the last "
            f"known owner version, beyond the declared bound {bound}"
        )
        self.tenant = tenant
        self.behind = behind
        self.bound = bound


# ---------------------------------------------------------------------------
# FaultPlan — scripted, seeded, deterministic
# ---------------------------------------------------------------------------


class FaultPlan:
    """Scripted faults keyed by the transport's global send index.

    Each action set holds message indices (0-based, in send order —
    retries consume indices too).  An index may appear in at most one
    set; overlap is an authoring error and raises.  ``seeded`` builds a
    reproducible plan from a PRNG seed, which is how the chaos suite
    sweeps schedules: same seed, same plan, same run.
    """

    def __init__(self, *, drop=(), duplicate=(), delay=(), crash=()):
        self.drop = frozenset(int(i) for i in drop)
        self.duplicate = frozenset(int(i) for i in duplicate)
        self.delay = frozenset(int(i) for i in delay)
        self.crash = frozenset(int(i) for i in crash)
        sets = [self.drop, self.duplicate, self.delay, self.crash]
        total = sum(len(s) for s in sets)
        if len(frozenset().union(*sets)) != total:
            raise ValueError("fault plan assigns multiple actions to one message index")

    @classmethod
    def seeded(
        cls,
        seed: int,
        n_messages: int,
        *,
        p_drop: float = 0.05,
        p_duplicate: float = 0.05,
        p_delay: float = 0.05,
        crash_at: int | None = None,
    ) -> "FaultPlan":
        """A reproducible random plan over the first ``n_messages`` sends.

        Disjoint probability bands of one uniform draw per index assign
        at most one action each; ``crash_at`` (if given) overrides
        whatever band its index fell in.
        """
        if p_drop + p_duplicate + p_delay > 1.0:
            raise ValueError("fault probabilities must sum to <= 1")
        u = np.random.default_rng(seed).random(n_messages)
        drop = {i for i in range(n_messages) if u[i] < p_drop}
        duplicate = {
            i for i in range(n_messages) if p_drop <= u[i] < p_drop + p_duplicate
        }
        delay = {
            i
            for i in range(n_messages)
            if p_drop + p_duplicate <= u[i] < p_drop + p_duplicate + p_delay
        }
        crash = set()
        if crash_at is not None:
            drop.discard(crash_at)
            duplicate.discard(crash_at)
            delay.discard(crash_at)
            crash.add(crash_at)
        return cls(drop=drop, duplicate=duplicate, delay=delay, crash=crash)

    def action(self, index: int) -> str | None:
        """The scripted action for one send index (None = deliver cleanly)."""
        if index in self.crash:
            return "crash"
        if index in self.drop:
            return "drop"
        if index in self.duplicate:
            return "duplicate"
        if index in self.delay:
            return "delay"
        return None

    def __repr__(self) -> str:
        return (
            f"FaultPlan(drop={sorted(self.drop)}, duplicate={sorted(self.duplicate)}, "
            f"delay={sorted(self.delay)}, crash={sorted(self.crash)})"
        )


# ---------------------------------------------------------------------------
# Transport
# ---------------------------------------------------------------------------


class Transport:
    """Synchronous in-process message link with scripted fault injection.

    Endpoints are ``name -> handler(envelope) -> reply`` registrations
    (a cell's ``deliver``).  ``send`` consumes one global message index,
    consults the ``FaultPlan`` (if any), and either delivers, raises
    ``TransportTimeout`` (drop/delay/crash-mid-receive), or raises
    ``CellDownError`` (endpoint crashed earlier, not yet revived).

    Delayed envelopes park at their destination and flush — in park
    order, reply discarded — right after the *next* successful delivery
    to that endpoint: a later message observably overtakes an earlier
    one, which is the reorder the cells' seq windows must absorb.  A
    crash discards the crashed endpoint's parked envelopes (in-flight
    messages die with the link).

    ``counters`` partition every send by outcome, so chaos tests can
    assert ``sends == delivered + dropped + delayed + crashed + down``
    exactly — no message unaccounted for.  Both it and ``sends`` are
    views over the obs registry (``repro_transport_sends_total`` /
    ``repro_transport_outcomes_total{outcome=...}``).
    """

    # Outcome order is the legacy counters-dict order (tests rely on it).
    _OUTCOMES = (
        "delivered",  # primary deliveries that returned a reply
        "dropped",  # lost outright (scripted drop)
        "delayed",  # parked for late delivery (scripted delay)
        "crashed",  # killed the destination mid-receive
        "down",  # sent at a dead endpoint
        "duplicate_deliveries",  # extra handler calls beyond delivered
        "late_deliveries",  # parked envelopes flushed late
    )

    _FAMILIES = (
        ("counter", "repro_transport_sends_total",
         "Global message indices consumed (retries included)."),
        ("counter", "repro_transport_outcomes_total",
         "Sends partitioned by delivery outcome."),
    )

    def __init__(self, *, plan: FaultPlan | None = None,
                 obs: Observability | None = None):
        self.plan = plan
        self.obs = obs if obs is not None else Observability(labels={})
        self._bind_metrics()
        self._endpoints: dict[str, Callable] = {}
        self._down: set[str] = set()
        self._parked: dict[str, list[object]] = {}

    # -- telemetry ----------------------------------------------------------

    def _bind_metrics(self) -> None:
        self._m_sends = self.obs.handle(
            "counter", "repro_transport_sends_total",
            "Global message indices consumed (retries included).")
        self._m_outcomes = {
            k: self.obs.handle(
                "counter", "repro_transport_outcomes_total",
                "Sends partitioned by delivery outcome.",
                labels={"outcome": k})
            for k in self._OUTCOMES
        }

    def bind_obs(self, obs: Observability) -> None:
        """Re-home the transport's telemetry into another bundle."""
        old, self.obs = self.obs, obs
        rehome_families(old, obs, self._FAMILIES)
        self._bind_metrics()

    @property
    def sends(self) -> int:
        """Global message index consumed per ``send()`` (registry view)."""
        return int(self._m_sends.value)

    @property
    def counters(self) -> dict[str, int]:
        """Every send partitioned by outcome (fresh dict, registry view)."""
        return {k: int(self._m_outcomes[k].value) for k in self._OUTCOMES}

    # -- topology ------------------------------------------------------------

    def register(self, name: str, handler: Callable) -> None:
        """Attach an endpoint (replacing any previous handler under ``name``)."""
        self._endpoints[name] = handler
        self._down.discard(name)

    def endpoints(self) -> list[str]:
        """Registered endpoint names (sorted; includes crashed ones)."""
        return sorted(self._endpoints)

    def is_down(self, name: str) -> bool:
        """Whether the endpoint is crashed and awaiting ``revive``."""
        return name in self._down

    def crash(self, name: str) -> None:
        """Kill an endpoint: parked envelopes are lost, sends raise until
        ``revive``.  Also reachable from a plan's scripted ``crash`` index."""
        if name not in self._endpoints:
            raise KeyError(f"unknown endpoint {name!r}")
        self._down.add(name)
        self._parked.pop(name, None)

    def revive(self, name: str, handler: Callable) -> None:
        """Bring a crashed endpoint back with a (possibly rebuilt) handler."""
        if name not in self._endpoints:
            raise KeyError(f"unknown endpoint {name!r}")
        self._endpoints[name] = handler
        self._down.discard(name)

    # -- the link ------------------------------------------------------------

    def send(self, name: str, envelope) -> object:
        """Deliver one envelope; returns the handler's reply.

        Raises ``TransportTimeout`` when the scripted fault loses the
        message (drop, delay, crash-mid-receive) and ``CellDownError``
        when the endpoint is already dead.  Either way the caller has no
        reply and must retry — receivers are idempotent precisely so
        that retrying after an ack-loss cannot double-apply.
        """
        if name not in self._endpoints:
            raise KeyError(f"unknown endpoint {name!r}")
        index = int(self._m_sends.value)
        self._m_sends.inc()
        action = self.plan.action(index) if self.plan is not None else None
        if name in self._down:
            self._m_outcomes["down"].inc()
            raise CellDownError(f"cell {name!r} is down (message {index})")
        if action == "crash":
            self._m_outcomes["crashed"].inc()
            self.crash(name)
            raise TransportTimeout(f"cell {name!r} crashed receiving message {index}")
        if action == "drop":
            self._m_outcomes["dropped"].inc()
            raise TransportTimeout(f"message {index} to {name!r} dropped")
        if action == "delay":
            self._m_outcomes["delayed"].inc()
            self._parked.setdefault(name, []).append(envelope)
            raise TransportTimeout(f"message {index} to {name!r} delayed")
        reply = self._endpoints[name](envelope)
        self._m_outcomes["delivered"].inc()
        if action == "duplicate":
            # The network delivered a second copy; its reply goes nowhere.
            self._endpoints[name](envelope)
            self._m_outcomes["duplicate_deliveries"].inc()
        self._flush_parked(name)
        return reply

    def _flush_parked(self, name: str) -> None:
        # Late arrivals land after the message that followed them — the
        # receiver sees a genuine reorder (and, for already-retried
        # envelopes, a genuine duplicate).  Replies are discarded: the
        # original sender gave up on these long ago.
        for envelope in self._parked.pop(name, []):
            self._endpoints[name](envelope)
            self._m_outcomes["late_deliveries"].inc()


# ---------------------------------------------------------------------------
# CircuitBreaker
# ---------------------------------------------------------------------------


@dataclass
class CircuitBreaker:
    """Per-cell closed/open/half-open breaker with an injectable clock.

    Closed counts consecutive *message* failures (a message fails only
    after its retry budget is exhausted); at ``failure_threshold`` the
    breaker opens and ``allow`` refuses traffic for ``cooldown_s``.
    After the cooldown, one probe is allowed (half-open): success closes
    the breaker, failure re-opens it for a fresh cooldown.  The clock is
    injectable so tests step time deterministically.
    """

    failure_threshold: int = 3
    cooldown_s: float = 30.0
    clock: Callable[[], float] = field(default=None)  # type: ignore[assignment]
    state: str = "closed"
    failures: int = 0
    opens: int = 0
    _opened_at: float = 0.0
    _probing: bool = False

    def __post_init__(self):
        if self.failure_threshold < 1:
            raise ValueError(f"failure_threshold must be >= 1, got {self.failure_threshold}")
        if self.clock is None:
            import time

            self.clock = time.monotonic

    def allow(self) -> bool:
        """Whether a message may be sent now (may transition open->half-open)."""
        if self.state == "closed":
            return True
        if self.state == "open":
            if self.clock() - self._opened_at >= self.cooldown_s:
                self.state = "half-open"
                self._probing = True
                return True
            return False
        # half-open: exactly one in-flight probe
        if not self._probing:
            self._probing = True
            return True
        return False

    def record_success(self) -> None:
        """A message got a reply: reset the failure run and close."""
        self.state = "closed"
        self.failures = 0
        self._probing = False

    def record_failure(self) -> None:
        """A message exhausted its retries: count it; open at the threshold."""
        self.failures += 1
        if self.state == "half-open" or self.failures >= self.failure_threshold:
            if self.state != "open":
                self.opens += 1
            self.state = "open"
            self._opened_at = self.clock()
            self._probing = False
