"""Sharded coordinator cells: the paper's m-site recursion applied to itself.

The paper scales *sites* horizontally but keeps one coordinator; this
package shards the coordinator the same way the paper shards the stream.
Every protocol kind already proves the merge identity that makes this
sound (``fd_merge``, ``mg_merge``, ``quant_merge``, ``lev_merge``), so a
tenant's whole lifecycle can live on any one shard:

  * ``hashring``  — deterministic consistent-hash tenant placement with
                    virtual nodes + minimal rebalance planning.
  * ``cell``      — ``PipelineCell``: one ``StreamingPipeline`` as a
                    shard, plus the tenant export/import move path and
                    the replica-facing ``versions_since`` sync API.
  * ``router``    — ``ClusterRouter``: ring-placed registration/ingest,
                    per-shard packed query fan-out (gathered in
                    submission order), shed propagation, live rebalance.
  * ``replica``   — ``ServingReplica``: read-only serving off published
                    immutable versions with surfaced staleness bounds,
                    including owner-blind degraded serving for cells
                    whose circuit breaker is open.
  * ``transport`` — the fault-injectable message boundary: typed
                    envelopes with ``(tenant, site, seq)``-stamped
                    idempotent ingest, scripted/seeded ``FaultPlan``
                    chaos injection, ``CircuitBreaker``, and the typed
                    loss/crash errors the router's retry loop handles.

See ``docs/cluster.md`` for the ring diagram, cell lifecycle, rebalance
plan format, and staleness semantics, and ``docs/resilience.md`` for the
failure-mode/retry/breaker/staleness contract and how to script a
``FaultPlan``.
"""
from repro.cluster.cell import PipelineCell
from repro.cluster.hashring import HashRing, RebalancePlan, TenantMove, rebalance_plan
from repro.cluster.replica import ReplicaResult, ServingReplica
from repro.cluster.router import ClusterRouter
from repro.cluster.transport import (
    CellDownError,
    CircuitBreaker,
    Export,
    FaultPlan,
    Heartbeat,
    HeartbeatAck,
    Ingest,
    IngestAck,
    IngestShedError,
    Query,
    StalenessExceededError,
    Transport,
    TransportTimeout,
)

__all__ = [
    "CellDownError",
    "CircuitBreaker",
    "ClusterRouter",
    "Export",
    "FaultPlan",
    "HashRing",
    "Heartbeat",
    "HeartbeatAck",
    "Ingest",
    "IngestAck",
    "IngestShedError",
    "PipelineCell",
    "Query",
    "RebalancePlan",
    "ReplicaResult",
    "ServingReplica",
    "StalenessExceededError",
    "TenantMove",
    "Transport",
    "TransportTimeout",
    "rebalance_plan",
]
