"""Continuous distributed matrix tracking as a training-telemetry service.

``DistributedMatrixTracker`` rides along a training run: each data-parallel
shard is a paper "site", the rows it feeds are (sub-sampled) token
hidden-states or gradient rows, and the coordinator sketch gives, at any
step, streaming answers to:

  * ``query(x)``  — ||A x||^2 for any direction x (the paper's guarantee)
  * ``top_directions(k)`` — streaming PCA of everything seen so far
  * ``stable_rank()``     — ||A||_F^2 / sigma_1^2, a live collapse metric

at the paper's O((m/eps) log beta N) communication cost instead of shipping
activations anywhere.  This is the paper's motivating use ("real-time
approximation of the distributed streaming matrix") transplanted to training.

The tracker is a thin facade over the runtime protocol registry
(``repro.runtime.registry``): protocol dispatch, sketch extraction, the
Frobenius estimate, message accounting, and the quadform query path all
come from the registered ``SketchProtocol`` — there are no per-protocol
branches here.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import numpy as np

from repro.core.comm import CommReport

__all__ = ["DistributedMatrixTracker", "TrackerSnapshot"]


class TrackerSnapshot(NamedTuple):
    """Point-in-time tracker view: top-k spectrum, mass, and message costs."""
    basis: np.ndarray  # (k, d) top right-singular directions
    singular_values: np.ndarray  # (k,)
    frob_estimate: float
    stable_rank: float
    messages: CommReport


class DistributedMatrixTracker:
    """Facade over the registry's shard_map engine (default: protocol P2)."""

    def __init__(
        self,
        mesh: jax.sharding.Mesh,
        d: int,
        *,
        eps: float = 0.1,
        axis: str = "data",
        protocol: str = "P2",
        rows_per_step: int = 0,
    ):
        # Lazy: runtime sits above core in the layering; importing it at
        # module scope would cycle through repro.runtime.pipeline.
        from repro.runtime.registry import create_protocol

        self._proto = create_protocol(
            protocol, engine="shard", mesh=mesh, d=d, eps=eps, axis=axis
        )
        self.cfg = self._proto.cfg
        self.protocol = protocol
        self.rows_per_step = rows_per_step

    @property
    def state(self):
        """The underlying protocol's live jit state."""
        return self._proto.state

    @property
    def rows_fed(self) -> int:
        """Stream rows absorbed so far."""
        return self._proto.rows_seen

    def update(self, rows: jax.Array) -> None:
        """Absorb a global (n, d) batch of rows (sharded over the axis)."""
        self._proto.step(rows)

    def sketch_matrix(self) -> np.ndarray:
        """The coordinator's current sketch matrix B (host numpy)."""
        return self._proto.matrix()

    def frob_estimate(self) -> float:
        """Coordinator estimate of ``||A||_F^2`` (uniform across protocols)."""
        return self._proto.frob_estimate()

    def query(self, x: jax.Array) -> float:
        """``||B x||^2`` via the shared ``kernels.ops.quadform`` path — the
        same kernel the serving engine launches, so tracker-side and
        serving-side answers are one code path."""
        return self._proto.query(np.asarray(x))

    def query_batch(self, x: jax.Array) -> np.ndarray:
        """Batched ``||B x_j||^2`` over the same quadform path."""
        return self._proto.query_batch(np.asarray(x))

    def publish(self, store, tenant: str = "default", *, meta: dict | None = None,
                published_at: float = 0.0):
        """Publish the coordinator sketch into a ``repro.query.SketchStore``.

        Snapshots are immutable and versioned, so the serving layer
        (``repro.query``) answers batched queries against a pinned version
        while training keeps streaming rows into this tracker.  Returns the
        ``SketchSnapshot``.
        """
        md = {"protocol": self.protocol, "m": self.cfg.m}
        if meta:
            md.update(meta)
        return store.publish(
            tenant,
            self.sketch_matrix(),
            frob=self.frob_estimate(),
            eps=self.cfg.eps,
            n_seen=self.rows_fed,
            meta=md,
            published_at=published_at,
        )

    def comm_report(self) -> CommReport:
        """Messages spent so far, in the paper's units."""
        return self._proto.comm_report()

    def state_payload(self):
        """Live protocol state as ``(arrays, meta)`` (pipeline checkpoints)."""
        return self._proto.state_payload()

    def restore_payload(self, arrays, meta) -> None:
        """Restore state captured by ``state_payload`` bit-identically."""
        self._proto.restore_payload(arrays, meta)

    def snapshot(self, k: int = 8) -> TrackerSnapshot:
        """Materialize a point-in-time view: top-k spectrum + stable rank + comm."""
        b = self.sketch_matrix()
        u, s, vt = np.linalg.svd(b, full_matrices=False)
        k = min(k, s.shape[0])
        frob = float(np.sum(s**2))
        sr = frob / max(float(s[0] ** 2), 1e-30) if s.size else 0.0
        return TrackerSnapshot(
            basis=vt[:k],
            singular_values=s[:k],
            frob_estimate=frob,
            stable_rank=sr,
            messages=self.comm_report(),
        )
