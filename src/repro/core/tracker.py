"""Continuous distributed matrix tracking as a training-telemetry service.

``DistributedMatrixTracker`` rides along a training run: each data-parallel
shard is a paper "site", the rows it feeds are (sub-sampled) token
hidden-states or gradient rows, and the coordinator sketch gives, at any
step, streaming answers to:

  * ``query(x)``  — ||A x||^2 for any direction x (the paper's guarantee)
  * ``top_directions(k)`` — streaming PCA of everything seen so far
  * ``stable_rank()``     — ||A||_F^2 / sigma_1^2, a live collapse metric

at the paper's O((m/eps) log beta N) communication cost instead of shipping
activations anywhere.  This is the paper's motivating use ("real-time
approximation of the distributed streaming matrix") transplanted to training.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distributed as dist
from repro.core import fd as fdlib

__all__ = ["DistributedMatrixTracker", "TrackerSnapshot"]


class TrackerSnapshot(NamedTuple):
    basis: np.ndarray  # (k, d) top right-singular directions
    singular_values: np.ndarray  # (k,)
    frob_estimate: float
    stable_rank: float
    messages: dict[str, int]


class DistributedMatrixTracker:
    """Facade over the shard_map protocol engine (default: protocol P2)."""

    def __init__(
        self,
        mesh: jax.sharding.Mesh,
        d: int,
        *,
        eps: float = 0.1,
        axis: str = "data",
        protocol: str = "P2",
        rows_per_step: int = 0,
    ):
        m = mesh.shape[axis]
        self.cfg = dist.ProtocolConfig(eps=eps, m=m, d=d, axis=axis).resolved()
        self.protocol = protocol
        self.rows_per_step = rows_per_step
        self.rows_fed = 0
        self.state, self._step = dist.make_protocol_runner(protocol, self.cfg, mesh)

    def update(self, rows: jax.Array) -> None:
        """Absorb a global (n, d) batch of rows (sharded over the axis)."""
        self.state = self._step(self.state, rows)
        self.rows_fed += int(rows.shape[0])

    def sketch_matrix(self) -> np.ndarray:
        if self.protocol == "P3":
            return np.asarray(dist.p3_matrix(self.state))
        return np.asarray(fdlib.fd_matrix(self.state.coord_fd))

    def query(self, x: jax.Array) -> float:
        b = self.sketch_matrix()
        v = b @ np.asarray(x)
        return float(v @ v)

    def publish(self, store, tenant: str = "default", *, meta: dict | None = None):
        """Publish the coordinator sketch into a ``repro.query.SketchStore``.

        Snapshots are immutable and versioned, so the serving layer
        (``repro.query``) answers batched queries against a pinned version
        while training keeps streaming rows into this tracker.  Returns the
        ``SketchSnapshot``.
        """
        b = self.sketch_matrix()
        # P1/P2 carry the coordinator's running mass estimate f_hat
        # (within (1+eps) of ||A||_F^2); P3's estimator matrix preserves the
        # stream mass by construction, so its own Frobenius norm stands in.
        f_hat = getattr(self.state, "f_hat", None)
        frob = float(f_hat) if f_hat is not None else float(np.sum(b * b))
        md = {"protocol": self.protocol, "m": self.cfg.m}
        if meta:
            md.update(meta)
        return store.publish(
            tenant,
            b,
            frob=frob,
            eps=self.cfg.eps,
            n_seen=self.rows_fed,
            meta=md,
        )

    def snapshot(self, k: int = 8) -> TrackerSnapshot:
        b = self.sketch_matrix()
        u, s, vt = np.linalg.svd(b, full_matrices=False)
        k = min(k, s.shape[0])
        frob = float(np.sum(s**2))
        sr = frob / max(float(s[0] ** 2), 1e-30) if s.size else 0.0
        c = self.state.comm
        return TrackerSnapshot(
            basis=vt[:k],
            singular_values=s[:k],
            frob_estimate=frob,
            stable_rank=sr,
            messages={
                "scalar": int(c.scalar_msgs),
                "rows": int(c.row_msgs),
                "broadcast_events": int(c.broadcast_events),
                "total": int(c.scalar_msgs + c.row_msgs + c.broadcast_events * self.cfg.m),
            },
        )
