"""Distributed quantile tracking: mergeable eps-approximate summaries.

The paper's model (m sites, one coordinator, continuous queries under small
communication) extends beyond matrix norms: Yi & Zhang's "Optimal Tracking
of Distributed Heavy Hitters and Quantiles" gives the canonical quantile
counterpart.  This module supplies the third workload kind's math:

  * ``QuantileSummary`` — a GK-style (Greenwald--Khanna) weighted quantile
    summary over python lists: insert, compress, merge, rank/quantile
    query, serialized size.  Every tuple ``(v, g, delta, wv)`` certifies
    the weighted rank interval ``R(v) in [rmin, rmin + delta]`` where
    ``rmin = sum g`` up to the tuple and ``wv`` lower-bounds the mass
    sitting exactly at ``v``.  The maintained invariant ``g + delta <=
    eps * W`` makes every phi-quantile answer an eps-approximate one:
    ``|R(answer) - phi W| <= eps W``.  Merging is interval arithmetic
    (bands add, so eps is preserved when total weights add) — the
    mergeable-summaries property the coordinator folding relies on.
  * ``QuantState`` + ``quant_*`` — the same summary as fixed-shape
    jit-able JAX arrays (production / shard_map engine), padded with
    ``+inf`` values; an all-pad state is the merge identity, which is
    what lets ``quant_p1_step`` ship summaries as masked collectives.
  * ``QuantileP1Stream`` / ``QuantileP3Stream`` — event-driven site ->
    coordinator protocols in the paper's style: deterministic change
    propagation (sites push their summary when local weight grows by a
    ``1 + eps/4`` factor; coordinator merges) and the cheaper priority-
    sampling variant.  Communication is counted via ``CommLog`` in the
    paper's units.
  * snapshot codec — published quantile state is a sorted ``(n, 2)``
    [value, rank-estimate] f32 table (the ``SketchStore`` contract is one
    immutable 2-D array per version); ``table_rank`` / ``table_quantile``
    are the single searchsorted implementation every query surface
    (live protocols, registry interface, packed serving) shares.
"""
from __future__ import annotations

import bisect
import math
from dataclasses import dataclass
from typing import NamedTuple

import numpy as np

__all__ = [
    "QUERY_RANK",
    "QUERY_QUANTILE",
    "QuantileSummary",
    "QuantState",
    "quant_init",
    "quant_insert",
    "quant_merge",
    "quant_table",
    "quant_band",
    "QuantileResult",
    "QuantileP1Stream",
    "QuantileP3Stream",
    "QUANTILE_STREAMS",
    "run_quantile_protocol",
    "encode_quantile_snapshot",
    "decode_quantile_snapshot",
    "table_rank",
    "table_quantile",
    "rank_query",
    "quantile_query",
    "exact_ranks",
]

#: Query-row mode tags for quantile tenants: a packed-service query is a
#: ``(2,)`` row ``[mode, arg]`` — ``QUERY_RANK`` asks for the estimated
#: weighted rank of value ``arg``; ``QUERY_QUANTILE`` for the value whose
#: rank is nearest ``arg * W``.
QUERY_RANK = 0.0
QUERY_QUANTILE = 1.0


def rank_query(value: float) -> np.ndarray:
    """Build the ``(2,)`` query row asking for the rank of ``value``."""
    return np.array([QUERY_RANK, value], np.float32)


def quantile_query(phi: float) -> np.ndarray:
    """Build the ``(2,)`` query row asking for the phi-quantile value."""
    return np.array([QUERY_QUANTILE, phi], np.float32)


# ---------------------------------------------------------------------------
# Python oracle: GK-style weighted summary with explicit rank intervals.
# ---------------------------------------------------------------------------


class QuantileSummary:
    """Mergeable GK-style eps-approximate weighted quantile summary.

    Tuples are ``[v, g, delta, wv]`` sorted by value: ``rmin(i) = sum of g
    up to i`` lower-bounds the weighted rank ``R(v_i)`` (total weight of
    items ``<= v_i``), ``rmin + delta`` upper-bounds it, and ``wv`` is a
    certified lower bound on the mass sitting exactly at ``v_i``.  All
    three operations (insert, compress, merge) preserve interval
    soundness, and compression maintains the invariant ``g_i + delta_i -
    wv_i <= 2 eps W`` — the width of the *uncertain* rank interval
    between consecutive kept values (mass certified to sit exactly at a
    value is not uncertainty, which is what keeps duplicate-heavy
    streams exact).  Consequently every rank answer and every
    phi-quantile answer is within ``eps W`` of the truth, where quantile
    error is measured against the achievable ranks: the answer ``v``
    satisfies ``R(v) >= phi W - eps W`` and ``R(v) - mass(v) <= phi W +
    eps W``.  Merging is interval arithmetic (uncertainties add while
    total weights add), so eps is preserved — the mergeable-summaries
    property the coordinator folding relies on.
    """

    def __init__(self, eps: float):
        if not 0.0 < eps < 1.0:
            raise ValueError(f"eps must be in (0, 1), got {eps}")
        self.eps = eps
        self.tuples: list[list[float]] = []  # [v, g, delta, wv], sorted by v
        self._vals: list[float] = []  # parallel value index for bisect
        self.weight = 0.0
        self._since_compress = 0
        self._compress_every = max(16, math.ceil(1.0 / (2.0 * eps)))

    def insert(self, value: float, w: float = 1.0) -> None:
        """Absorb one weighted item, keeping rank intervals sound."""
        v, w = float(value), float(w)
        if not math.isfinite(v):
            raise ValueError(f"quantile values must be finite, got {v}")
        if w < 0.0:
            raise ValueError(f"weights must be >= 0, got {w}")
        if w == 0.0:
            return
        self.weight += w
        t = self.tuples
        i = bisect.bisect_left(self._vals, v)
        if i < len(t) and self._vals[i] == v:
            # Exact value hit: fold into the tuple (g and wv both certify
            # mass at this exact value; the interval stays sound).
            t[i][1] += w
            t[i][3] += w
        else:
            if i == len(t):
                delta = 0.0  # new maximum: rank exactly W
            else:
                # Classic GK insert band, weighted: the successor's band
                # minus its certified own-value mass (>= 0 by soundness).
                succ = t[i]
                delta = max(0.0, succ[1] + succ[2] - succ[3])
            t.insert(i, [v, w, delta, w])
            self._vals.insert(i, v)
        self._since_compress += 1
        if self._since_compress >= self._compress_every:
            self.compress()

    def extend(self, values, weights=None) -> None:
        """Absorb a batch (uniform weight 1 when ``weights`` is None)."""
        if weights is None:
            for v in np.asarray(values).ravel().tolist():
                self.insert(v, 1.0)
        else:
            for v, w in zip(np.asarray(values).ravel().tolist(),
                            np.asarray(weights).ravel().tolist()):
                self.insert(v, w)

    def compress(self) -> None:
        """Greedy GK compress: fold tuple i into i+1 while the merged
        uncertainty ``g_i + g_{i+1} + delta_{i+1} - wv_{i+1}`` stays
        within ``2 eps W``.  The first and last tuples are kept, so
        min/max stay exact."""
        self._since_compress = 0
        t = self.tuples
        if len(t) < 3:
            return
        thresh = 2.0 * self.eps * self.weight
        i = len(t) - 2
        while i >= 1:
            nxt = t[i + 1]
            if t[i][1] + nxt[1] + nxt[2] - nxt[3] <= thresh:
                nxt[1] += t[i][1]
                del t[i]
                del self._vals[i]
            i -= 1

    def merge(self, other: "QuantileSummary") -> None:
        """Fold ``other`` into this summary (interval arithmetic merge).

        Each output tuple's rank interval is the sum of its own interval
        and the other summary's certified interval at that value, so
        bands add — merging summaries of disjoint substreams at the same
        eps yields an eps-summary of the union (the mergeable-summaries
        property).  ``other`` is not modified.
        """
        a, b = self.tuples, other.tuples
        if not b:
            return
        if not a:
            self.tuples = [list(tp) for tp in b]
            self._vals = list(other._vals)
            self.weight += other.weight
            self.compress()
            return
        wa, wb = self.weight, other.weight

        def cums(ts):
            out, c = [], 0.0
            for tp in ts:
                c += tp[1]
                out.append(c)
            return out

        cum_a, cum_b = cums(a), cums(b)
        merged: list[tuple[float, float, float, float]] = []  # v, rmin, rmax, wv
        i = j = 0

        def upper(ts, cum, k, total, v):
            # Certified upper bound on the other stream's rank at v, read
            # from its next tuple at value >= v (W_other when none).
            if k >= len(ts):
                return total
            tp = ts[k]
            up = cum[k] + tp[2]
            if tp[0] > v:
                up -= tp[3]
            return up

        while i < len(a) or j < len(b):
            va = a[i][0] if i < len(a) else math.inf
            vb = b[j][0] if j < len(b) else math.inf
            if va == vb:  # one combined tuple, both sides inclusive
                rmin = cum_a[i] + cum_b[j]
                rmax = cum_a[i] + a[i][2] + cum_b[j] + b[j][2]
                merged.append((va, rmin, rmax, a[i][3] + b[j][3]))
                i += 1
                j += 1
            elif va < vb:
                rmin = cum_a[i] + (cum_b[j - 1] if j > 0 else 0.0)
                rmax = cum_a[i] + a[i][2] + upper(b, cum_b, j, wb, va)
                merged.append((va, rmin, rmax, a[i][3]))
                i += 1
            else:
                rmin = cum_b[j] + (cum_a[i - 1] if i > 0 else 0.0)
                rmax = cum_b[j] + b[j][2] + upper(a, cum_a, i, wa, vb)
                merged.append((vb, rmin, rmax, b[j][3]))
                j += 1

        tuples, vals = [], []
        prev_rmin = 0.0
        for v, rmin, rmax, wv in merged:
            rmin = max(rmin, prev_rmin)  # enforce monotone lower bounds
            tuples.append([v, rmin - prev_rmin, max(0.0, rmax - rmin), wv])
            vals.append(v)
            prev_rmin = rmin
        self.tuples = tuples
        self._vals = vals
        self.weight = wa + wb
        self.compress()

    # -- queries -------------------------------------------------------------

    def rank(self, x: float) -> float:
        """Estimated weighted rank of ``x`` (error <= ``error_bound()``)."""
        x = float(x)
        i = bisect.bisect_right(self._vals, x) - 1
        if i < 0:
            return 0.0
        t = self.tuples
        lo = sum(tp[1] for tp in t[: i + 1])
        if i + 1 < len(t):
            nxt = t[i + 1]
            hi = lo + nxt[1] + nxt[2] - nxt[3]
        else:
            hi = self.weight
        return 0.5 * (lo + max(lo, hi))

    def quantile(self, phi: float) -> float:
        """An eps-approximate phi-quantile value."""
        return float(table_quantile(self.table(), self.weight,
                                    np.array([phi]))[0])

    def table(self) -> np.ndarray:
        """Publishable sorted ``(n, 2)`` [value, rank-estimate] f32 table.

        Row i holds ``(v_i, c_i)`` where ``c_i`` is the midpoint of the
        certified rank interval for query values in ``[v_i, v_{i+1})`` —
        ``[rmin_i, rmax_{i+1} - wv_{i+1}]`` (upper end ``W`` after the
        last value).  ``table_rank`` answers rank queries by reading
        ``c`` directly and ``table_quantile`` inverts it; both inherit
        the summary's ``eps W`` guarantee.
        """
        t = self.tuples
        if not t:
            return np.zeros((0, 2), np.float32)
        arr = np.asarray(t, np.float64)
        rmin = np.cumsum(arr[:, 1])
        upper_next = np.empty(len(t))
        upper_next[:-1] = rmin[1:] + arr[1:, 2] - arr[1:, 3]
        upper_next[-1] = self.weight
        c = 0.5 * (rmin + np.maximum(rmin, upper_next))
        c = np.maximum.accumulate(c)
        return _dedup_f32_table(arr[:, 0], c)

    def error_bound(self) -> float:
        """Certified rank-error bound (half the widest uncertain interval)."""
        t = self.tuples
        if not t:
            return 0.0
        widest = self.weight - sum(tp[1] for tp in t)  # 0 up to fp noise
        rmin = 0.0
        for i, tp in enumerate(t):
            prev_rmin = rmin
            rmin += tp[1]
            widest = max(widest, rmin + tp[2] - tp[3] - prev_rmin)
        widest = max(widest, self.weight - rmin)
        return 0.5 * widest

    def size(self) -> int:
        """Number of stored tuples."""
        return len(self.tuples)

    def serialized_bytes(self) -> int:
        """Bytes a checkpoint of this summary occupies (4 f64 per tuple)."""
        return 32 * len(self.tuples)

    # -- persistence ---------------------------------------------------------

    def state_dict(self) -> dict:
        """JSON-able snapshot of the summary (exact float round-trip)."""
        return {
            "eps": self.eps,
            "tuples": [list(tp) for tp in self.tuples],
            "weight": self.weight,
            # Compress cadence is part of the state: without it a restored
            # summary compresses on a shifted schedule and the continued
            # stream is no longer bit-identical to the uninterrupted one.
            "since_compress": self._since_compress,
        }

    @classmethod
    def from_state(cls, state: dict) -> "QuantileSummary":
        """Rebuild a summary from ``state_dict`` output (state identity)."""
        qs = cls(float(state["eps"]))
        qs.tuples = [[float(x) for x in tp] for tp in state["tuples"]]
        qs._vals = [tp[0] for tp in qs.tuples]
        qs.weight = float(state["weight"])
        qs._since_compress = int(state.get("since_compress", 0))
        return qs


# ---------------------------------------------------------------------------
# Shared searchsorted query path over the published (n, 2) table.
# ---------------------------------------------------------------------------


def _dedup_f32_table(values, ranks) -> np.ndarray:
    """Build the f32 ``(n, 2)`` table, collapsing values that collide in f32.

    Distinct f64 values can round to the same float32; keeping only the
    last entry of each equal run (whose rank column already covers the
    gap *after* the value) keeps the published values strictly
    increasing — the snapshot-codec contract — without changing any
    searchsorted answer.
    """
    v = np.asarray(values, np.float32)
    c = np.asarray(ranks, np.float32)
    keep = np.concatenate([v[1:] != v[:-1], [True]]) if v.shape[0] else np.ones(0, bool)
    return np.stack([v[keep], np.maximum.accumulate(c)[keep]], axis=1)


def encode_quantile_snapshot(table: np.ndarray) -> np.ndarray:
    """Validate + freeze a quantile table into the store's ``(n, 2)`` form.

    Column 0 holds values (strictly increasing), column 1 the rank
    estimate at each value (non-decreasing).  This is the matrix a
    ``SketchStore`` snapshot carries for a quantile tenant.
    """
    t = np.asarray(table, np.float32)
    if t.ndim != 2 or (t.size and t.shape[1] != 2):
        raise ValueError(f"quantile snapshot table must be (n, 2), got {t.shape}")
    if t.shape[0]:
        if np.any(np.diff(t[:, 0]) <= 0):
            raise ValueError("quantile snapshot values must be strictly increasing")
        if np.any(np.diff(t[:, 1]) < 0):
            raise ValueError("quantile snapshot ranks must be non-decreasing")
    return t


def decode_quantile_snapshot(matrix: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Invert ``encode_quantile_snapshot``: ``(values, ranks)`` columns."""
    m = np.asarray(matrix)
    if m.ndim != 2 or (m.size and m.shape[1] != 2):
        raise ValueError(f"quantile snapshot matrix must be (n, 2), got {m.shape}")
    if not m.size:
        return np.zeros(0, np.float32), np.zeros(0, np.float32)
    return m[:, 0], m[:, 1]


def table_rank(table: np.ndarray, xs) -> np.ndarray:
    """Rank estimates for each query value via one searchsorted pass.

    The single implementation every surface uses — live protocols, the
    registry interface, and published-snapshot serving — so answers
    cannot diverge between them.  ``table[:, 1]`` is the rank estimate
    for query values in the gap at and after each stored value (see
    ``QuantileSummary.table``), so a rank query is one lookup.
    """
    xs = np.atleast_1d(np.asarray(xs, np.float64)).ravel()
    t = np.asarray(table)
    if t.shape[0] == 0:
        return np.zeros(xs.shape[0], np.float32)
    idx = np.searchsorted(t[:, 0], xs, side="right") - 1
    out = np.where(idx >= 0, t[np.clip(idx, 0, None), 1], 0.0)
    return out.astype(np.float32)


def table_quantile(table: np.ndarray, w_total: float, phis) -> np.ndarray:
    """Phi-quantile values: the first stored value whose gap rank estimate
    reaches ``phi * w_total`` (clipped to the maximum)."""
    phis = np.atleast_1d(np.asarray(phis, np.float64)).ravel()
    t = np.asarray(table)
    if t.shape[0] == 0:
        return np.zeros(phis.shape[0], np.float32)
    targets = np.clip(phis, 0.0, 1.0) * float(w_total)
    n = t.shape[0]
    j = np.clip(np.searchsorted(t[:, 1], targets, side="left"), 0, n - 1)
    return t[j, 0].astype(np.float32)


def exact_ranks(values: np.ndarray, weights: np.ndarray, xs) -> np.ndarray:
    """Ground-truth weighted ranks of a finished stream (test oracle)."""
    order = np.argsort(values, kind="stable")
    v = np.asarray(values, np.float64)[order]
    c = np.cumsum(np.asarray(weights, np.float64)[order])
    xs = np.atleast_1d(np.asarray(xs, np.float64)).ravel()
    idx = np.searchsorted(v, xs, side="right") - 1
    return np.where(idx >= 0, c[np.clip(idx, 0, None)], 0.0)


# ---------------------------------------------------------------------------
# Fixed-shape jit-able summary (the shard_map engine's state).
# ---------------------------------------------------------------------------


class QuantState(NamedTuple):
    """GK-style summary as fixed-shape JAX arrays (pad value ``+inf``).

    An all-pad state (every value ``+inf``, weights zero) is the identity
    of ``quant_merge`` — the property the shard engine's masked-collective
    shipping relies on, exactly like the empty ``MGState`` for HH.
    """

    values: "object"  # (cap,) f32, +inf = empty slot
    g: "object"  # (cap,) f32 — rank increments
    delta: "object"  # (cap,) f32 — band widths
    wv: "object"  # (cap,) f32 — certified own-value mass
    weight: "object"  # () f32 — total weight summarized


def quant_init(cap: int) -> QuantState:
    """The empty summary at capacity ``cap`` (merge identity)."""
    import jax.numpy as jnp

    return QuantState(
        values=jnp.full((cap,), jnp.inf, jnp.float32),
        g=jnp.zeros((cap,), jnp.float32),
        delta=jnp.zeros((cap,), jnp.float32),
        wv=jnp.zeros((cap,), jnp.float32),
        weight=jnp.zeros((), jnp.float32),
    )


def _quant_pack(v, rmin, rmax, wv, live, thresh, cap, weight):
    """Greedy GK compress of sorted interval tuples into ``cap`` slots.

    Folds tuple i forward into i+1 while the merged band stays within
    ``thresh``; the first and last live tuples always emit.  Tuples with
    EQUAL values are always folded together (their certified own-value
    masses ``wv`` add), so the output values are strictly increasing —
    the snapshot-codec contract.  If more distinct tuples survive than
    ``cap``, the overflow keeps folding into the last slot — still
    interval-sound, just wider bands near the maximum.
    """
    import jax
    import jax.numpy as jnp

    n = v.shape[0]
    g = jnp.maximum(rmin - jnp.concatenate([jnp.zeros(1, rmin.dtype), rmin[:-1]]), 0.0)
    d = jnp.maximum(rmax - rmin, 0.0)

    def body(i, carry):
        out_v, out_g, out_d, out_wv, count, acc, acc_wv = carry
        live_i = live[i]
        acc = acc + jnp.where(live_i, g[i], 0.0)
        nxt = jnp.minimum(i + 1, n - 1)
        has_next = (i + 1 < n) & live[nxt]
        same_value = has_next & (v[nxt] == v[i])
        fold = same_value | (
            has_next & (acc + g[nxt] + d[nxt] - wv[nxt] <= thresh) & (count > 0)
        )
        emit = live_i & ~fold
        idx = jnp.minimum(count, cap - 1)
        carry_g = jnp.where(count >= cap, out_g[cap - 1], 0.0)
        out_v = jnp.where(emit, out_v.at[idx].set(v[i]), out_v)
        out_g = jnp.where(emit, out_g.at[idx].set(acc + carry_g), out_g)
        out_d = jnp.where(emit, out_d.at[idx].set(d[i]), out_d)
        out_wv = jnp.where(emit, out_wv.at[idx].set(wv[i] + acc_wv), out_wv)
        count = count + emit.astype(jnp.int32)
        acc = jnp.where(emit, 0.0, acc)
        # wv only carries across equal-value folds: a band-fold drops a
        # *different* value, whose own-value mass does not certify v_next.
        acc_wv = jnp.where(same_value & live_i, acc_wv + wv[i], 0.0)
        return out_v, out_g, out_d, out_wv, count, acc, acc_wv

    init = (
        jnp.full((cap,), jnp.inf, jnp.float32),
        jnp.zeros((cap,), jnp.float32),
        jnp.zeros((cap,), jnp.float32),
        jnp.zeros((cap,), jnp.float32),
        jnp.zeros((), jnp.int32),
        jnp.zeros((), jnp.float32),
        jnp.zeros((), jnp.float32),
    )
    out_v, out_g, out_d, out_wv, _, _, _ = jax.lax.fori_loop(0, n, body, init)
    return QuantState(out_v, out_g, out_d, out_wv, weight.astype(jnp.float32))


def quant_merge(a: QuantState, b: QuantState, eps: float, cap: int) -> QuantState:
    """Merge two jit-state summaries and compress to ``cap`` (band <= eps*W).

    The vectorized twin of ``QuantileSummary.merge``: sort the union,
    rebuild every tuple's rank interval as its own interval plus the
    other summary's certified interval at that value, then greedy-pack.
    """
    import jax
    import jax.numpy as jnp
    from jax import lax

    v = jnp.concatenate([a.values, b.values])
    g = jnp.concatenate([a.g, b.g])
    d = jnp.concatenate([a.delta, b.delta])
    wv = jnp.concatenate([a.wv, b.wv])
    la = a.values.shape[0]
    n = v.shape[0]
    is_a = jnp.arange(n) < la
    order = jnp.argsort(v, stable=True)  # ties: A entries first
    v, g, d, wv, is_a = v[order], g[order], d[order], wv[order], is_a[order]
    live = jnp.isfinite(v)

    cum_a = jnp.cumsum(jnp.where(is_a, g, 0.0))
    cum_b = jnp.cumsum(jnp.where(is_a, 0.0, g))
    rmin = cum_a + cum_b  # own inclusive rank + other mass sorted before
    own_cum = jnp.where(is_a, cum_a, cum_b)

    idx = jnp.arange(n)
    pos_a = jnp.where(is_a & live, idx, n)
    pos_b = jnp.where((~is_a) & live, idx, n)

    def suffix_min(x):
        return jnp.flip(lax.cummin(jnp.flip(x)))

    next_a = jnp.concatenate([suffix_min(pos_a)[1:], jnp.array([n])])
    next_b = jnp.concatenate([suffix_min(pos_b)[1:], jnp.array([n])])
    n_other = jnp.where(is_a, next_b, next_a)
    w_other = jnp.where(is_a, b.weight, a.weight)
    safe = jnp.clip(n_other, 0, n - 1)
    up = own_cum[safe] + d[safe] - wv[safe] * (v[safe] > v)
    upper_other = jnp.where(n_other < n, up, w_other)
    rmax = own_cum + d + upper_other

    rmin = lax.cummax(rmin)
    rmax = jnp.maximum(rmax, rmin)
    weight = a.weight + b.weight
    thresh = jnp.float32(2.0 * eps) * weight
    return _quant_pack(v, rmin, rmax, wv, live, thresh, cap, weight)


def quant_insert(state: QuantState, values, weights, eps: float) -> QuantState:
    """Absorb a weighted batch: dedup exact values, merge as an exact summary."""
    import jax.numpy as jnp

    cap = state.values.shape[0]
    values = jnp.asarray(values, jnp.float32).ravel()
    weights = jnp.asarray(weights, jnp.float32).ravel()
    n = values.shape[0]
    if n == 0:  # static shape: nothing to absorb
        return state
    order = jnp.argsort(values)
    vs, ws = values[order], weights[order]
    seg = jnp.cumsum(
        jnp.concatenate([jnp.zeros(1, jnp.int32), (vs[1:] != vs[:-1]).astype(jnp.int32)])
    )
    g = jnp.zeros((n,), jnp.float32).at[seg].add(ws)
    v = jnp.full((n,), jnp.inf, jnp.float32).at[seg].min(vs)
    v = jnp.where(g > 0, v, jnp.inf)  # drop zero-weight slots and pad tails
    batch = QuantState(
        values=v, g=g, delta=jnp.zeros_like(g), wv=g, weight=jnp.sum(ws)
    )
    return quant_merge(state, batch, eps, cap)


def quant_table(state: QuantState) -> np.ndarray:
    """Host-side ``(n, 2)`` [value, rank-estimate] table of a jit summary.

    Same gap-midpoint semantics as ``QuantileSummary.table`` — column 1
    estimates the rank of query values in the gap at and after each
    stored value.
    """
    v = np.asarray(state.values)
    live = np.isfinite(v)
    if not live.any():
        return np.zeros((0, 2), np.float32)
    g = np.asarray(state.g, np.float64)[live]
    d = np.asarray(state.delta, np.float64)[live]
    wv = np.asarray(state.wv, np.float64)[live]
    rmin = np.cumsum(g)
    upper_next = np.empty(rmin.shape[0])
    upper_next[:-1] = rmin[1:] + d[1:] - wv[1:]
    upper_next[-1] = float(state.weight)
    c = 0.5 * (rmin + np.maximum(rmin, upper_next))
    c = np.maximum.accumulate(c)
    return _dedup_f32_table(v[live], c)


def quant_band(state: QuantState) -> float:
    """Certified rank-error bound of a jit summary (see ``error_bound``)."""
    v = np.asarray(state.values)
    live = np.isfinite(v)
    if not live.any():
        return 0.0
    g = np.asarray(state.g, np.float64)[live]
    d = np.asarray(state.delta, np.float64)[live]
    wv = np.asarray(state.wv, np.float64)[live]
    rmin = np.cumsum(g)
    prev = np.concatenate([[0.0], rmin[:-1]])
    widest = float(np.max(rmin + d - wv - prev))
    widest = max(widest, float(state.weight) - float(rmin[-1]), 0.0)
    return 0.5 * widest


# ---------------------------------------------------------------------------
# Event-driven site -> coordinator protocols (paper-style accounting).
# ---------------------------------------------------------------------------


@dataclass
class QuantileResult:
    """The coordinator's current quantile state, queryable at any time."""

    table: np.ndarray  # (k, 2) [value, rank-estimate], sorted
    w_hat: float  # coordinator estimate of the total stream weight
    comm: "object"  # CommLog in the paper's units
    m: int
    eps: float

    def rank(self, xs) -> np.ndarray:
        """Estimated weighted rank per queried value."""
        return table_rank(self.table, xs)

    def quantile(self, phis) -> np.ndarray:
        """Value whose estimated rank is nearest ``phi * w_hat``, per phi."""
        return table_quantile(self.table, self.w_hat, phis)


class QuantileP1Stream:
    """Quantile P1: per-site GK summaries, deterministic change propagation.

    Each site runs a ``QuantileSummary(eps/4)`` over its local substream
    and pushes it to the coordinator when its cumulative weight has grown
    by a ``1 + eps/4`` factor since the last push (with an ``eps/(4m)``
    fraction-of-total floor so early items batch up); the coordinator
    merges pushed summaries at ``eps/2``.  Site summaries reset on push,
    so merged substreams are disjoint and bands add to at most
    ``(eps/2) W``; unpushed site mass accounts for the other ``eps/2``,
    keeping end-to-end quantile rank error within ``eps W``.
    """

    def __init__(self, m, eps, rng=None):
        from repro.core.protocols import CommLog

        self.m, self.eps = m, eps
        self.comm = CommLog()
        self.site_sum = [QuantileSummary(eps / 4.0) for _ in range(m)]
        self.site_w = [0.0] * m
        self.site_pushed = [0.0] * m
        self.coord = QuantileSummary(eps / 2.0)
        self.w_hat = 1.0

    def step(self, values, weights, sites) -> None:
        """Absorb a batch of weighted values, one event at a time."""
        m, eps = self.m, self.eps
        for v, w, j in zip(values.tolist(), weights.tolist(), sites.tolist()):
            self.site_sum[j].insert(v, w)
            self.site_w[j] += w
            unpushed = self.site_w[j] - self.site_pushed[j]
            if (
                self.site_w[j] >= (1.0 + eps / 4.0) * self.site_pushed[j]
                and unpushed >= (eps / (4.0 * m)) * self.w_hat
            ):
                self.comm.sketch_rows += self.site_sum[j].size()
                self.comm.scalar_msgs += 1
                self.coord.merge(self.site_sum[j])
                self.site_sum[j] = QuantileSummary(eps / 4.0)
                self.site_pushed[j] = self.site_w[j]
                if self.coord.weight / self.w_hat > 1.0 + eps / 2.0:
                    self.w_hat = self.coord.weight
                    self.comm.broadcast_events += 1

    def result(self) -> QuantileResult:
        """The coordinator's current table (callable at any time)."""
        return QuantileResult(
            self.coord.table(), self.coord.weight, self.comm, self.m, self.eps
        )

    def state_dict(self) -> dict:
        """JSON-able snapshot of the full coordinator + site state."""
        from repro.core.protocols import _comm_state

        return {
            "site_sum": [s.state_dict() for s in self.site_sum],
            "site_w": list(self.site_w),
            "site_pushed": list(self.site_pushed),
            "coord": self.coord.state_dict(),
            "w_hat": self.w_hat,
            "comm": _comm_state(self.comm),
        }

    def load_state(self, state: dict) -> None:
        """Restore ``state_dict`` output bit-identically."""
        from repro.core.protocols import _comm_from_state

        self.site_sum = [QuantileSummary.from_state(s) for s in state["site_sum"]]
        self.site_w = [float(w) for w in state["site_w"]]
        self.site_pushed = [float(w) for w in state["site_pushed"]]
        self.coord = QuantileSummary.from_state(state["coord"])
        self.w_hat = float(state["w_hat"])
        self.comm = _comm_from_state(state["comm"])


class QuantileP3Stream:
    """Quantile P3: the cheaper sampling variant (distributed priority
    sampling without replacement, as in HH P3, carrying values).

    A size-s priority sample supports subset-sum rank estimates: the rank
    of ``x`` is the estimated weight of items with value ``<= x``.  With
    ``s = O(1/eps^2)`` the error is ``O(eps W)`` with high probability —
    cheaper than P1's summary shipping but randomized (err_factor 2 in
    the registry, like the HH sampling protocols).
    """

    def __init__(self, m, eps, rng, s=None):
        from repro.core.protocols import CommLog

        if s is None:
            s = max(8, math.ceil((1.0 / eps**2) * math.log(max(math.e, 1.0 / eps))))
        self.m, self.eps, self.s = m, eps, s
        self.rng = rng
        self.comm = CommLog()
        self.tau = 1.0
        self.q_cur: list[tuple[float, float, float]] = []  # (value, w, rho)
        self.q_next: list[tuple[float, float, float]] = []

    def step(self, values, weights, sites) -> None:
        """Absorb a batch of weighted values, one event at a time."""
        n = len(values)
        rho_all = weights / np.maximum(self.rng.uniform(size=n), 1e-300)
        for v, w, rho in zip(values.tolist(), weights.tolist(), rho_all.tolist()):
            if rho >= self.tau:
                self.comm.item_msgs += 1
                if rho >= 2.0 * self.tau:
                    self.q_next.append((v, w, rho))
                else:
                    self.q_cur.append((v, w, rho))
                if len(self.q_next) >= self.s:
                    self.tau *= 2.0
                    self.comm.broadcast_events += 1
                    self.q_cur = self.q_next
                    self.q_next = [t for t in self.q_cur if t[2] >= 2.0 * self.tau]
                    self.q_cur = [t for t in self.q_cur if t[2] < 2.0 * self.tau]

    def result(self) -> QuantileResult:
        """Priority-sample estimator table (callable at any time)."""
        sample = self.q_cur + self.q_next
        if not sample:
            return QuantileResult(
                np.zeros((0, 2), np.float32), 0.0, self.comm, self.m, self.eps
            )
        sample = sorted(sample, key=lambda t: t[2])
        rho_hat = sample[0][2]
        kept = sample[1:] if len(sample) > 1 else sample
        vals = np.array([t[0] for t in kept], np.float64)
        wbar = np.maximum(np.array([t[1] for t in kept], np.float64), rho_hat)
        order = np.argsort(vals, kind="stable")
        vals, wbar = vals[order], wbar[order]
        # _dedup_f32_table collapses duplicates *after* the f32 cast, so
        # f64-distinct values that collide in f32 cannot violate the
        # codec's strictly-increasing contract.
        table = _dedup_f32_table(vals, np.cumsum(wbar))
        return QuantileResult(table, float(wbar.sum()), self.comm, self.m, self.eps)

    def state_dict(self) -> dict:
        """JSON-able snapshot of the sampler state (incl. PRNG)."""
        from repro.core.protocols import _comm_state, _rng_state

        return {
            "s": self.s,
            "tau": self.tau,
            "q_cur": [list(t) for t in self.q_cur],
            "q_next": [list(t) for t in self.q_next],
            "rng": _rng_state(self.rng),
            "comm": _comm_state(self.comm),
        }

    def load_state(self, state: dict) -> None:
        """Restore ``state_dict`` output bit-identically."""
        from repro.core.protocols import _comm_from_state, _rng_from_state

        self.s = int(state["s"])
        self.tau = float(state["tau"])
        self.q_cur = [(float(v), float(w), float(r)) for v, w, r in state["q_cur"]]
        self.q_next = [(float(v), float(w), float(r)) for v, w, r in state["q_next"]]
        self.rng = _rng_from_state(state["rng"])
        self.comm = _comm_from_state(state["comm"])


# Resumable stream engines (init/step/result/state_dict) — the registry's
# event-engine quantile entries, mirroring HH_STREAMS / MATRIX_STREAMS.
QUANTILE_STREAMS = {
    "P1": QuantileP1Stream,
    "P3": QuantileP3Stream,
}


def run_quantile_protocol(
    name: str,
    values: np.ndarray,
    weights: np.ndarray,
    sites: np.ndarray,
    m: int,
    eps: float,
    seed: int = 0,
    **kw,
) -> QuantileResult:
    """One-shot wrapper: stream the whole feed through a quantile protocol."""
    rng = np.random.default_rng(seed)
    try:
        stream_cls = QUANTILE_STREAMS[name]
    except KeyError:
        raise KeyError(
            f"unknown quantile protocol {name!r} "
            f"(have: {sorted(QUANTILE_STREAMS)})"
        ) from None
    eng = stream_cls(m, eps, rng, **kw)
    eng.step(values, weights, sites)
    return eng.result()
