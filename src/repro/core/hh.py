"""Weighted frequency sketches: Misra--Gries and SpaceSaving.

The paper's weighted-heavy-hitter protocols (Section 4) are built from a
*weighted* Misra--Gries (MG) summary: the weighted generalisation decrements
all counters by ``delta = min(min_counter, w)`` instead of by 1.  Guarantee
for ``k`` counters over total weight ``W``::

    0 <= f_e - hat{f}_e <= W / (k + 1)        (underestimates)

SpaceSaving is the overestimate twin (``0 <= hat{f}_e - f_e <= W / k``) the
paper cites [31] for bounding per-site space in protocols P2/P4.

Both come in two flavours:
  * ``MGState`` + ``mg_*`` — fixed-shape jit-able JAX arrays (production);
  * ``MGSketch`` / ``SpaceSaving`` — plain-python dict oracles used by the
    event-driven protocol engine and the tests.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "MGState",
    "mg_init",
    "mg_update",
    "mg_update_stream",
    "mg_merge",
    "mg_estimate",
    "mg_items",
    "MGSketch",
    "SpaceSaving",
    "encode_hh_snapshot",
    "decode_hh_snapshot",
    "exact_heavy_hitters",
    "threshold_heavy_hitters",
]

EMPTY = jnp.int32(-1)


class MGState(NamedTuple):
    """Weighted Misra--Gries summary as fixed-shape jit-able arrays."""
    keys: jax.Array  # (k,) int32, -1 = empty
    counts: jax.Array  # (k,) f32
    weight: jax.Array  # () f32 — total weight consumed
    shrink: jax.Array  # () f32 — total decrement mass (error witness)


def mg_init(k: int) -> MGState:
    """The empty k-counter MG summary (the ``mg_merge`` identity)."""
    return MGState(
        keys=jnp.full((k,), EMPTY, jnp.int32),
        counts=jnp.zeros((k,), jnp.float32),
        weight=jnp.zeros((), jnp.float32),
        shrink=jnp.zeros((), jnp.float32),
    )


def mg_update(state: MGState, key: jax.Array, w: jax.Array) -> MGState:
    """Absorb one (element, weight) pair.  Fully branch-free / jit-able."""
    keys, counts = state.keys, state.counts
    key = key.astype(jnp.int32)
    w = w.astype(jnp.float32)

    hit = keys == key
    any_hit = jnp.any(hit)
    empty = keys == EMPTY
    any_empty = jnp.any(empty)
    first_empty = jnp.argmax(empty)

    # Case 1: existing counter.
    counts_hit = counts + jnp.where(hit, w, 0.0)
    # Case 2: take an empty slot.
    keys_ins = keys.at[first_empty].set(key)
    counts_ins = counts.at[first_empty].set(w)
    # Case 3: decrement everyone by delta = min(min_count, w).
    min_c = jnp.min(counts)
    delta = jnp.minimum(min_c, w)
    counts_dec = jnp.maximum(counts - delta, 0.0)
    w_left = w - delta
    freed = jnp.argmin(counts)  # a slot that hit zero when delta == min_c
    take_slot = w_left > 0.0
    keys_dec = jnp.where(take_slot, keys.at[freed].set(key), keys)
    counts_dec = jnp.where(take_slot, counts_dec.at[freed].set(w_left), counts_dec)
    # shrink witness: every element's estimate dropped by at most delta
    # (the replaced slot loses min_c, the incoming item loses delta).

    new_keys = jnp.where(any_hit, keys, jnp.where(any_empty, keys_ins, keys_dec))
    new_counts = jnp.where(any_hit, counts_hit, jnp.where(any_empty, counts_ins, counts_dec))
    new_shrink = state.shrink + jnp.where(any_hit | any_empty, 0.0, delta)
    return MGState(new_keys, new_counts, state.weight + w, new_shrink)


def mg_update_stream(state: MGState, keys: jax.Array, weights: jax.Array) -> MGState:
    """Scan ``mg_update`` over a (keys, weights) batch."""
    def body(st, kw):
        return mg_update(st, kw[0], kw[1]), None

    state, _ = jax.lax.scan(body, state, (keys.astype(jnp.int32), weights.astype(jnp.float32)))
    return state


def mg_estimate(state: MGState, key: jax.Array) -> jax.Array:
    """Estimated weight of ``key`` (0 when untracked; underestimates)."""
    hit = state.keys == key.astype(jnp.int32)
    return jnp.sum(jnp.where(hit, state.counts, 0.0))


def mg_items(state: MGState) -> dict[int, float]:
    """Materialize a jit-side MG summary as a plain ``{element: count}`` dict."""
    keys = np.asarray(state.keys)
    counts = np.asarray(state.counts)
    return {
        int(e): float(c) for e, c in zip(keys.tolist(), counts.tolist()) if e != int(EMPTY)
    }


def mg_merge(a: MGState, b: MGState) -> MGState:
    """Mergeable-summaries MG merge (Agarwal et al.): combine counts for equal
    keys, keep the k largest after subtracting the (k+1)-th largest."""
    k = a.keys.shape[0]
    keys = jnp.concatenate([a.keys, b.keys])
    counts = jnp.concatenate([a.counts, b.counts])
    valid = keys != EMPTY
    counts = jnp.where(valid, counts, 0.0)
    # Deduplicate: O((2k)^2), k is small (O(1/eps)).
    same = (keys[:, None] == keys[None, :]) & valid[:, None] & valid[None, :]
    summed = jnp.sum(jnp.where(same, counts[None, :], 0.0), axis=1)
    first = jnp.arange(2 * k) == jnp.argmax(same, axis=1)
    dedup = jnp.where(first & valid, summed, 0.0)
    # Keep top-k, subtract the (k+1)-th largest.
    order = jnp.argsort(-dedup)
    sorted_counts = dedup[order]
    thresh = sorted_counts[k]
    kept = jnp.maximum(sorted_counts[:k] - thresh, 0.0)
    kept_keys = jnp.where(kept > 0.0, keys[order[:k]], EMPTY)
    return MGState(
        keys=kept_keys,
        counts=kept,
        weight=a.weight + b.weight,
        shrink=a.shrink + b.shrink + thresh,
    )


# ---------------------------------------------------------------------------
# Python oracles (dict-based, exact event-driven semantics).
# ---------------------------------------------------------------------------


class MGSketch:
    """Weighted Misra--Gries over a python dict; error <= W/(k+1)."""

    def __init__(self, k: int):
        self.k = k
        self.counters: dict[int, float] = {}
        self.weight = 0.0
        self.shrink = 0.0

    def update(self, key: int, w: float) -> None:
        """Absorb one (element, weight) pair."""
        self.weight += w
        c = self.counters
        if key in c:
            c[key] += w
            return
        if len(c) < self.k:
            c[key] = w
            return
        delta = min(min(c.values()), w)
        self.shrink += delta
        dead = []
        for e in c:
            c[e] -= delta
            if c[e] <= 1e-12:
                dead.append(e)
        for e in dead:
            del c[e]
        if w - delta > 1e-12:
            c[key] = w - delta

    def extend(self, keys, weights) -> None:
        """Absorb a batch of (element, weight) pairs."""
        for key, w in zip(keys, weights):
            self.update(int(key), float(w))

    def estimate(self, key: int) -> float:
        """Estimated weight of ``key`` (0 when untracked; underestimates)."""
        return self.counters.get(key, 0.0)

    def merge(self, other: "MGSketch") -> None:
        """Fold another MG sketch in (mergeable-summaries merge)."""
        for e, w in other.counters.items():
            self.counters[e] = self.counters.get(e, 0.0) + w
        self.weight += other.weight
        self.shrink += other.shrink
        if len(self.counters) > self.k:
            vals = sorted(self.counters.values(), reverse=True)
            thresh = vals[self.k]
            self.shrink += thresh
            self.counters = {
                e: w - thresh for e, w in self.counters.items() if w - thresh > 1e-12
            }

    def items(self):
        """The live ``{element: count}`` counters (a copy)."""
        return dict(self.counters)

    def state_dict(self) -> dict:
        """JSON-able snapshot of the sketch (counter keys become strings)."""
        return {
            "k": self.k,
            "counters": {str(e): w for e, w in self.counters.items()},
            "weight": self.weight,
            "shrink": self.shrink,
        }

    @classmethod
    def from_state(cls, state: dict) -> "MGSketch":
        """Rebuild a sketch from ``state_dict`` output (exact state identity)."""
        mg = cls(int(state["k"]))
        mg.counters = {int(e): float(w) for e, w in state["counters"].items()}
        mg.weight = float(state["weight"])
        mg.shrink = float(state["shrink"])
        return mg


class SpaceSaving:
    """Weighted SpaceSaving; overestimates, error <= W/k."""

    def __init__(self, k: int):
        self.k = k
        self.counters: dict[int, float] = {}
        self.weight = 0.0

    def update(self, key: int, w: float) -> None:
        """Absorb one (element, weight) pair."""
        self.weight += w
        c = self.counters
        if key in c:
            c[key] += w
        elif len(c) < self.k:
            c[key] = w
        else:
            e_min = min(c, key=c.get)
            v_min = c.pop(e_min)
            c[key] = v_min + w

    def estimate(self, key: int) -> float:
        """Estimated weight of ``key`` (0 when untracked; overestimates)."""
        return self.counters.get(key, 0.0)

    def items(self):
        """The live ``{element: count}`` counters (a copy)."""
        return dict(self.counters)

    def state_dict(self) -> dict:
        """JSON-able snapshot of the sketch (counter keys become strings)."""
        return {
            "k": self.k,
            "counters": {str(e): w for e, w in self.counters.items()},
            "weight": self.weight,
        }

    @classmethod
    def from_state(cls, state: dict) -> "SpaceSaving":
        """Rebuild a sketch from ``state_dict`` output (exact state identity)."""
        ss = cls(int(state["k"]))
        ss.counters = {int(e): float(w) for e, w in state["counters"].items()}
        ss.weight = float(state["weight"])
        return ss


# ---------------------------------------------------------------------------
# Published-snapshot codec: HH estimates as a SketchStore matrix.
# ---------------------------------------------------------------------------


def encode_hh_snapshot(estimates: dict[int, float]) -> np.ndarray:
    """Pack coordinator HH estimates into a publishable ``(n, 2)`` f32 matrix.

    Column 0 holds element ids, column 1 their weight estimates, sorted by
    id so equal estimate sets encode bit-identically.  This is the matrix a
    ``SketchStore`` snapshot carries for an HH tenant (the store's contract
    is "one immutable 2-D array per version"); element ids must stay below
    2**24 so they survive the f32 round-trip exactly.
    """
    if not estimates:
        return np.zeros((0, 2), np.float32)
    if max(estimates) >= 1 << 24 or min(estimates) < 0:
        raise ValueError("HH element ids must be in [0, 2**24) to encode exactly as f32")
    pairs = sorted(estimates.items())
    return np.array(pairs, np.float32).reshape(len(pairs), 2)


def decode_hh_snapshot(matrix: np.ndarray) -> dict[int, float]:
    """Invert ``encode_hh_snapshot``: ``(n, 2)`` matrix back to an estimate dict."""
    m = np.asarray(matrix)
    if m.ndim != 2 or (m.size and m.shape[1] != 2):
        raise ValueError(f"HH snapshot matrix must be (n, 2), got {m.shape}")
    return {int(e): float(w) for e, w in m.tolist()}


def threshold_heavy_hitters(
    estimates: dict[int, float], w_hat: float, eps: float, phi: float
) -> list[int]:
    """The paper's Section 4 answer rule, shared by every query surface.

    Returns (sorted) every element whose estimate crosses
    ``(phi - eps/2) * w_hat`` — the threshold that guarantees no true
    phi-heavy-hitter is missed when estimates carry eps/2 error.  Live
    protocols, the registry interface, and published-snapshot queries must
    all apply this one implementation so their answers cannot diverge.
    """
    thr = (phi - eps / 2.0) * w_hat
    return sorted(e for e, v in estimates.items() if v >= thr)


def exact_heavy_hitters(keys: np.ndarray, weights: np.ndarray, phi: float):
    """Ground-truth phi-weighted heavy hitters of a finished stream."""
    totals: dict[int, float] = {}
    for k, w in zip(keys.tolist(), weights.tolist()):
        totals[k] = totals.get(k, 0.0) + w
    w_total = float(np.sum(weights))
    return {e: v for e, v in totals.items() if v >= phi * w_total}, totals, w_total
