"""TPU-native distributed matrix tracking: shard_map super-step protocols.

The paper's transport is an event-driven network (any site may message the
coordinator at any time).  TPU pods speak synchronous SPMD collectives, so
the production engine processes site streams in *super-steps*: every shard
(= site) absorbs a batch of its local rows, evaluates the paper's send
predicates, and a masked ``all_gather``/``psum`` plays the role of the
site->coordinator channel.  The coordinator state is updated redundantly on
every shard (it is a deterministic function of replicated inputs), matching
the paper's remark that the coordinator "may be one of the sites".

Message accounting is at *protocol* level (exactly the messages the
event-driven protocol would send — masked-out lanes count zero), so the
paper's communication bounds remain the yardstick; the cost of the physical
collectives shows up separately in the roofline's collective term.

Super-step skew: delaying a send to the super-step boundary lets a site
overshoot its threshold by at most the batch mass ``batch * beta``; choosing
``batch * beta << (eps/2m) * F_hat`` keeps the end-to-end guarantee intact
(tested in tests/test_distributed.py).

All three matrix protocols are provided with fixed-shape jit-able states:

    * ``P1`` — per-site FD, ship-the-sketch on threshold, FD-merge at C.
    * ``P2`` — the paper's best: per-direction sigma^2 thresholds.  After an
      FD shrink the buffer rows *are* ``sigma_i v_i`` (orthogonal), so the
      send set is a row mask — no extra SVD on the hot path.
    * ``P3`` — distributed priority sampling without replacement (size-s
      classical priority sample kept as a fixed top-(s+1) buffer).
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import fd as fdlib
from repro.core import hh as hhlib
from repro.core import leverage as levlib
from repro.core import quantiles as qlib
from repro.core.comm import CommReport, build_report

__all__ = [
    "ProtocolConfig",
    "P1State",
    "P2State",
    "P3State",
    "HHP1State",
    "QuantP1State",
    "LevP1State",
    "p1_init",
    "p1_step",
    "p2_init",
    "p2_step",
    "p3_init",
    "p3_step",
    "hh_p1_init",
    "hh_p1_step",
    "hh_estimates",
    "hh_w_hat",
    "quant_p1_init",
    "quant_p1_step",
    "quant_p1_table",
    "quant_p1_w_hat",
    "lev_p1_init",
    "lev_p1_step",
    "lev_p1_table",
    "lev_p1_mass",
    "lev_p1_lambda",
    "p2_query",
    "p3_matrix",
    "protocol_matrix",
    "protocol_frob",
    "make_protocol_runner",
    "make_packed_runner",
    "unstack_packed",
    "PackedRunner",
    "PACKABLE_PROTOCOLS",
]


class ProtocolConfig(NamedTuple):
    """Static shard-protocol configuration (size defaults via ``resolved``)."""
    eps: float
    m: int  # number of sites == mesh axis size
    d: int  # row dimensionality
    axis: str = "sites"
    l_site: int = 0  # site sketch rows (0 -> ceil(4/eps), paper default)
    l_coord: int = 0  # coordinator sketch rows (0 -> ceil(4/eps))
    s: int = 0  # P3 sample size (0 -> ceil(1/eps^2 * log(1/eps)))
    k: int = 0  # HH MG counters (0 -> ceil(2/eps), the MG_{eps/2} default)
    q_cap: int = 0  # quantile summary capacity (0 -> ceil(8/eps) + 8)
    lev_cap: int = 0  # leverage reservoir capacity (0 -> ceil(4/eps), floor 16)
    use_pallas: bool = False

    def resolved(self) -> "ProtocolConfig":
        """Fill size defaults: sketch rows, sample size, MG counters,
        quantile cap, leverage reservoir cap."""
        import math

        l_default = max(2, math.ceil(4.0 / self.eps))
        s_default = max(8, math.ceil((1.0 / self.eps**2) * math.log(max(math.e, 1.0 / self.eps))))
        return self._replace(
            l_site=self.l_site or l_default,
            l_coord=self.l_coord or l_default,
            s=self.s or s_default,
            k=self.k or max(2, math.ceil(2.0 / self.eps)),
            q_cap=self.q_cap or max(32, math.ceil(8.0 / self.eps) + 8),
            lev_cap=self.lev_cap or levlib.default_cap(self.eps),
        )


class CommCounters(NamedTuple):
    """Jit-able protocol-level message counters (the shard engine's CommLog)."""
    scalar_msgs: jax.Array  # i32 — protocol-level scalar messages
    row_msgs: jax.Array  # i32 — protocol-level row messages
    broadcast_events: jax.Array  # i32

    @staticmethod
    def zero() -> "CommCounters":
        """All-zero counters."""
        z = jnp.zeros((), jnp.int32)
        return CommCounters(z, z, z)

    def report(self, m: int) -> CommReport:
        """Collapse the jit-able counters to the engine-agnostic report."""
        return build_report(
            scalar_msgs=self.scalar_msgs,
            row_msgs=self.row_msgs,
            broadcast_events=self.broadcast_events,
            m=m,
        )


def _row_sq(x: jax.Array) -> jax.Array:
    return jnp.sum(x.astype(jnp.float32) ** 2, axis=-1)


# ---------------------------------------------------------------------------
# Protocol 1 — batched FD merge
# ---------------------------------------------------------------------------


class P1State(NamedTuple):
    """Matrix P1 shard state: per-site FD + replicated coordinator FD/totals."""
    site_fd: fdlib.FDState  # per-shard
    f_i: jax.Array  # per-shard () f32 — mass since last ship
    coord_fd: fdlib.FDState  # replicated
    f_c: jax.Array  # replicated — mass received at C
    f_hat: jax.Array  # replicated — broadcast estimate
    comm: CommCounters


def p1_init(cfg: ProtocolConfig) -> P1State:
    """Initial P1 state for one site (tiled per shard by the runner)."""
    cfg = cfg.resolved()
    return P1State(
        site_fd=fdlib.fd_init(cfg.l_site, cfg.d),
        f_i=jnp.zeros((), jnp.float32),
        coord_fd=fdlib.fd_init(cfg.l_coord, cfg.d),
        f_c=jnp.zeros((), jnp.float32),
        f_hat=jnp.ones((), jnp.float32),
        comm=CommCounters.zero(),
    )


def p1_step(cfg: ProtocolConfig, st: P1State, rows: jax.Array) -> P1State:
    """One super-step; call inside shard_map with ``rows`` = local (b, d)."""
    cfg = cfg.resolved()
    site_fd = fdlib.fd_update_stream(st.site_fd, rows, use_pallas=cfg.use_pallas)
    f_i = st.f_i + jnp.sum(_row_sq(rows))

    send = f_i >= (cfg.eps / (2 * cfg.m)) * st.f_hat
    payload = jnp.where(send, fdlib.fd_matrix(site_fd), 0.0)  # (l_site, d)
    gathered = lax.all_gather(payload, cfg.axis)  # (m, l_site, d)
    coord_fd = fdlib.fd_update_stream(
        st.coord_fd, gathered.reshape(-1, cfg.d), use_pallas=cfg.use_pallas
    )
    shipped_rows = lax.psum(
        jnp.where(send, jnp.sum(_row_sq(fdlib.fd_matrix(site_fd)) > 0), 0), cfg.axis
    )
    n_scalar = lax.psum(send.astype(jnp.int32), cfg.axis)

    f_c = st.f_c + lax.psum(jnp.where(send, f_i, 0.0), cfg.axis)
    f_i = jnp.where(send, 0.0, f_i)
    # Reset shipped sketches.
    empty = fdlib.fd_init(cfg.l_site, cfg.d)
    site_fd = jax.tree.map(lambda a, b: jnp.where(send, b, a), site_fd, empty)

    rebroadcast = f_c / st.f_hat > 1.0 + cfg.eps / 2.0
    f_hat = jnp.where(rebroadcast, f_c, st.f_hat)
    comm = CommCounters(
        scalar_msgs=st.comm.scalar_msgs + n_scalar,
        row_msgs=st.comm.row_msgs + shipped_rows.astype(jnp.int32),
        broadcast_events=st.comm.broadcast_events + rebroadcast.astype(jnp.int32),
    )
    return P1State(site_fd, f_i, coord_fd, f_c, f_hat, comm)


# ---------------------------------------------------------------------------
# Protocol 2 — per-direction thresholds (the paper's best)
# ---------------------------------------------------------------------------


class P2State(NamedTuple):
    """Matrix P2 shard state: per-site FD + replicated coordinator FD/thresholds."""
    site_fd: fdlib.FDState  # per-shard; buffer rows are sigma_i v_i
    f_j: jax.Array  # per-shard () f32 — scalar-message accumulator
    coord_fd: fdlib.FDState  # replicated
    f_hat: jax.Array  # replicated
    n_msg: jax.Array  # replicated i32 — scalar msgs since last broadcast
    comm: CommCounters


def p2_init(cfg: ProtocolConfig) -> P2State:
    """Initial P2 state for one site (tiled per shard by the runner)."""
    cfg = cfg.resolved()
    return P2State(
        site_fd=fdlib.fd_init(cfg.l_site, cfg.d),
        f_j=jnp.zeros((), jnp.float32),
        coord_fd=fdlib.fd_init(cfg.l_coord, cfg.d),
        f_hat=jnp.ones((), jnp.float32),
        n_msg=jnp.zeros((), jnp.int32),
        comm=CommCounters.zero(),
    )


def p2_step(cfg: ProtocolConfig, st: P2State, rows: jax.Array) -> P2State:
    """One P2 super-step; call inside shard_map with ``rows`` = local (b, d)."""
    cfg = cfg.resolved()
    # -- scalar totals (Algorithm 5.3 first half) --
    f_j = st.f_j + jnp.sum(_row_sq(rows))
    send_scalar = f_j >= (cfg.eps / cfg.m) * st.f_hat
    f_hat = st.f_hat + lax.psum(jnp.where(send_scalar, f_j, 0.0), cfg.axis)
    n_sent = lax.psum(send_scalar.astype(jnp.int32), cfg.axis)
    f_j = jnp.where(send_scalar, 0.0, f_j)
    n_msg = st.n_msg + n_sent
    rebroadcast = n_msg >= cfg.m
    n_msg = jnp.where(rebroadcast, 0, n_msg)

    # -- direction sends (Algorithm 5.3 second half) --
    # After fd_update the buffer rows are orthogonal sigma_i v_i: the svd in
    # Algorithm 5.3 is already materialised; the send set is a row mask.
    # Only the first l_site buffer rows can be non-zero post-shrink (the
    # shrink weights vanish past l), so the gather ships (l_site, d) per
    # site and the coordinator absorbs m*l_site rows — half the chunked
    # shrinks of gathering the raw 2l buffer, with no phantom all-zero
    # chunks spending shrink mass at the coordinator.
    site_fd = fdlib.fd_update_stream(st.site_fd, rows, use_pallas=cfg.use_pallas)
    buf = site_fd.buf
    live = buf[: cfg.l_site]
    sq = _row_sq(live)
    mask = sq >= (cfg.eps / cfg.m) * f_hat
    payload = jnp.where(mask[:, None], live, 0.0)
    site_fd = site_fd._replace(
        buf=buf.at[: cfg.l_site].set(jnp.where(mask[:, None], 0.0, live))
    )
    gathered = lax.all_gather(payload, cfg.axis)  # (m, l_site, d)
    coord_fd = fdlib.fd_update_stream(
        st.coord_fd, gathered.reshape(-1, cfg.d), use_pallas=cfg.use_pallas
    )
    n_rows = lax.psum(jnp.sum(mask.astype(jnp.int32)), cfg.axis)

    comm = CommCounters(
        scalar_msgs=st.comm.scalar_msgs + n_sent,
        row_msgs=st.comm.row_msgs + n_rows,
        broadcast_events=st.comm.broadcast_events + rebroadcast.astype(jnp.int32),
    )
    return P2State(site_fd, f_j, coord_fd, f_hat, n_msg, comm)


def p2_query(st: P2State, x: jax.Array) -> jax.Array:
    """Coordinator estimate of ||A x||^2 (callable outside shard_map)."""
    return fdlib.fd_query(st.coord_fd, x)


# ---------------------------------------------------------------------------
# Protocol 3 — distributed priority sampling (without replacement)
# ---------------------------------------------------------------------------


class P3State(NamedTuple):
    """Matrix P3 shard state: per-site PRNG + replicated priority-sample buffer."""
    rng: jax.Array  # per-shard PRNG key
    tau: jax.Array  # replicated () f32 — round threshold
    buf_rows: jax.Array  # replicated (s+1, d) — top-priority rows
    buf_w: jax.Array  # replicated (s+1,)
    buf_rho: jax.Array  # replicated (s+1,)
    comm: CommCounters


def p3_init(cfg: ProtocolConfig, seed: int = 0) -> P3State:
    """Initial P3 state (per-site PRNG keys are installed by the runner)."""
    cfg = cfg.resolved()
    return P3State(
        rng=jax.random.key(seed),
        tau=jnp.ones((), jnp.float32),
        buf_rows=jnp.zeros((cfg.s + 1, cfg.d), jnp.float32),
        buf_w=jnp.zeros((cfg.s + 1,), jnp.float32),
        buf_rho=jnp.zeros((cfg.s + 1,), jnp.float32),
        comm=CommCounters.zero(),
    )


def p3_step(cfg: ProtocolConfig, st: P3State, rows: jax.Array) -> P3State:
    """One P3 super-step; call inside shard_map with ``rows`` = local (b, d)."""
    cfg = cfg.resolved()
    site = lax.axis_index(cfg.axis)
    key = jax.random.fold_in(st.rng, site)
    key, sub = jax.random.split(key)
    # Keep per-shard streams decorrelated across steps: carry the split key.
    new_rng = jax.random.split(st.rng)[0]

    w = _row_sq(rows)
    u = jax.random.uniform(sub, w.shape, minval=jnp.finfo(jnp.float32).tiny, maxval=1.0)
    rho = w / u
    mask = rho >= st.tau
    n_sent = lax.psum(jnp.sum(mask.astype(jnp.int32)), cfg.axis)

    cand_rows = jnp.where(mask[:, None], rows.astype(jnp.float32), 0.0)
    cand_w = jnp.where(mask, w, 0.0)
    cand_rho = jnp.where(mask, rho, 0.0)
    g_rows = lax.all_gather(cand_rows, cfg.axis).reshape(-1, cfg.d)
    g_w = lax.all_gather(cand_w, cfg.axis).reshape(-1)
    g_rho = lax.all_gather(cand_rho, cfg.axis).reshape(-1)

    all_rho = jnp.concatenate([st.buf_rho, g_rho])
    all_w = jnp.concatenate([st.buf_w, g_w])
    all_rows = jnp.concatenate([st.buf_rows, g_rows])
    top_rho, top_idx = lax.top_k(all_rho, cfg.s + 1)
    buf_rows = all_rows[top_idx]
    buf_w = all_w[top_idx]
    buf_rho = top_rho

    # Round advance: double tau while >= s buffered items exceed 2*tau.
    def cond(tau):
        return jnp.sum(buf_rho >= 2.0 * tau) >= cfg.s

    def body(tau):
        return tau * 2.0

    new_tau = lax.while_loop(cond, body, st.tau)
    n_broadcast = jnp.round(jnp.log2(new_tau / st.tau)).astype(jnp.int32)

    comm = CommCounters(
        scalar_msgs=st.comm.scalar_msgs,
        row_msgs=st.comm.row_msgs + n_sent,
        broadcast_events=st.comm.broadcast_events + n_broadcast,
    )
    return P3State(new_rng, new_tau, buf_rows, buf_w, buf_rho, comm)


def p3_matrix(st: P3State) -> jax.Array:
    """Coordinator estimate matrix B from the priority sample (s rows).

    Classical priority-sample estimator: tau_hat = smallest buffered
    priority; every kept row is rescaled to squared norm max(w, tau_hat).
    """
    tau_hat = jnp.min(jnp.where(st.buf_rho > 0, st.buf_rho, jnp.inf))
    tau_hat = jnp.where(jnp.isfinite(tau_hat), tau_hat, 0.0)
    smallest = jnp.argmin(jnp.where(st.buf_rho > 0, st.buf_rho, jnp.inf))
    keep = (st.buf_rho > 0) & (jnp.arange(st.buf_rho.shape[0]) != smallest)
    wbar = jnp.maximum(st.buf_w, tau_hat)
    scale = jnp.sqrt(wbar / jnp.maximum(st.buf_w, 1e-30))
    return jnp.where(keep[:, None], st.buf_rows * scale[:, None], 0.0)


# ---------------------------------------------------------------------------
# Weighted heavy hitters, protocol 1 — batched Misra--Gries merge.
#
# The HH twin of matrix P1: every shard (= site) runs a weighted MG_{eps/2}
# summary over its local (element, weight) stream; when a site's weight
# since its last ship crosses ``(eps/2m) * w_hat`` it ships the whole
# summary, and the coordinator folds shipped summaries in with ``mg_merge``
# (the mergeable-summaries merge, so the coordinator error stays one
# ``W/(k+1)`` term per merge depth).  Message units follow the paper: a
# shipped summary of ``r`` live counters costs ``r`` item messages plus one
# scalar, and a ``w_hat`` rebroadcast costs ``m``.
# ---------------------------------------------------------------------------


class HHP1State(NamedTuple):
    """HH P1 shard state: per-site MG summary + replicated coordinator MG/totals."""
    site_mg: hhlib.MGState  # per-shard
    w_i: jax.Array  # per-shard () f32 — weight since last ship
    coord_mg: hhlib.MGState  # replicated
    w_c: jax.Array  # replicated — weight received at C
    w_hat: jax.Array  # replicated — broadcast estimate
    comm: CommCounters


def hh_p1_init(cfg: ProtocolConfig) -> HHP1State:
    """Initial HH P1 state for one site (tiled per shard by the runner)."""
    cfg = cfg.resolved()
    return HHP1State(
        site_mg=hhlib.mg_init(cfg.k),
        w_i=jnp.zeros((), jnp.float32),
        coord_mg=hhlib.mg_init(cfg.k),
        w_c=jnp.zeros((), jnp.float32),
        w_hat=jnp.ones((), jnp.float32),
        comm=CommCounters.zero(),
    )


def hh_p1_step(cfg: ProtocolConfig, st: HHP1State, pairs) -> HHP1State:
    """One super-step; ``pairs`` = local ``(keys i32 (b,), weights f32 (b,))``."""
    cfg = cfg.resolved()
    keys, weights = pairs
    site_mg = hhlib.mg_update_stream(st.site_mg, keys, weights)
    w_i = st.w_i + jnp.sum(weights.astype(jnp.float32))

    send = w_i >= (cfg.eps / (2 * cfg.m)) * st.w_hat
    # Masked ship: a non-sender contributes the empty summary, which is the
    # identity element of mg_merge, so the gather-then-fold below is exactly
    # "the coordinator merges what was shipped".
    pay = hhlib.MGState(
        keys=jnp.where(send, site_mg.keys, hhlib.EMPTY),
        counts=jnp.where(send, site_mg.counts, 0.0),
        weight=jnp.where(send, site_mg.weight, 0.0),
        shrink=jnp.where(send, site_mg.shrink, 0.0),
    )
    gathered = jax.tree.map(lambda a: lax.all_gather(a, cfg.axis), pay)  # (m, ...)
    coord = st.coord_mg
    for j in range(cfg.m):  # static unroll: m is the mesh axis size
        coord = hhlib.mg_merge(coord, jax.tree.map(lambda a: a[j], gathered))

    live = jnp.sum((site_mg.keys != hhlib.EMPTY).astype(jnp.int32))
    shipped = lax.psum(jnp.where(send, live, 0), cfg.axis)
    n_scalar = lax.psum(send.astype(jnp.int32), cfg.axis)

    w_c = st.w_c + lax.psum(jnp.where(send, w_i, 0.0), cfg.axis)
    w_i = jnp.where(send, 0.0, w_i)
    # Reset shipped site summaries.
    empty = hhlib.mg_init(cfg.k)
    site_mg = jax.tree.map(lambda a, b: jnp.where(send, b, a), site_mg, empty)

    rebroadcast = w_c / st.w_hat > 1.0 + cfg.eps / 2.0
    w_hat = jnp.where(rebroadcast, w_c, st.w_hat)
    comm = CommCounters(
        scalar_msgs=st.comm.scalar_msgs + n_scalar,
        row_msgs=st.comm.row_msgs + shipped.astype(jnp.int32),
        broadcast_events=st.comm.broadcast_events + rebroadcast.astype(jnp.int32),
    )
    return HHP1State(site_mg, w_i, coord, w_c, w_hat, comm)


def hh_estimates(st: HHP1State) -> dict[int, float]:
    """The coordinator's current ``{element: weight-estimate}`` map."""
    return hhlib.mg_items(st.coord_mg)


def hh_w_hat(st: HHP1State) -> float:
    """Coordinator estimate of the total stream weight ``W`` (HH frob analog)."""
    return float(st.w_hat)


# ---------------------------------------------------------------------------
# Distributed quantiles, protocol 1 — batched summary merge.
#
# The quantile twin of hh_p1_step: every shard (= site) maintains a fixed-
# shape GK-style ``QuantState`` over its local (value, weight) stream;
# when its cumulative weight has grown by a ``1 + eps/4`` factor since the
# last ship (with an ``eps/(4m) * w_hat`` floor so early items batch up) it
# ships the whole summary, and the coordinator folds shipped summaries in
# with ``quant_merge`` — the all-pad summary is the merge identity, so a
# non-sender's masked payload is exactly "nothing was shipped".  Message
# units follow the paper: a shipped summary of ``r`` live tuples costs
# ``r`` item messages plus one scalar, a ``w_hat`` rebroadcast costs m.
# ---------------------------------------------------------------------------


class QuantP1State(NamedTuple):
    """Quantile P1 shard state: per-site summary + replicated coordinator summary."""
    site_q: qlib.QuantState  # per-shard
    w_i: jax.Array  # per-shard () f32 — cumulative site weight
    w_pushed: jax.Array  # per-shard () f32 — cumulative weight at last ship
    coord_q: qlib.QuantState  # replicated
    w_hat: jax.Array  # replicated — broadcast estimate
    comm: CommCounters


def quant_p1_init(cfg: ProtocolConfig) -> QuantP1State:
    """Initial quantile P1 state for one site (tiled per shard by the runner)."""
    cfg = cfg.resolved()
    return QuantP1State(
        site_q=qlib.quant_init(cfg.q_cap),
        w_i=jnp.zeros((), jnp.float32),
        w_pushed=jnp.zeros((), jnp.float32),
        coord_q=qlib.quant_init(cfg.q_cap),
        w_hat=jnp.ones((), jnp.float32),
        comm=CommCounters.zero(),
    )


def quant_p1_step(cfg: ProtocolConfig, st: QuantP1State, pairs) -> QuantP1State:
    """One super-step; ``pairs`` = local ``(values f32 (b,), weights f32 (b,))``."""
    cfg = cfg.resolved()
    values, weights = pairs
    site_q = qlib.quant_insert(st.site_q, values, weights, cfg.eps / 4.0)
    w_i = st.w_i + jnp.sum(weights.astype(jnp.float32))
    unpushed = w_i - st.w_pushed

    send = (w_i >= (1.0 + cfg.eps / 4.0) * st.w_pushed) & (
        unpushed >= (cfg.eps / (4.0 * cfg.m)) * st.w_hat
    )
    # Masked ship: a non-sender contributes the all-pad summary, which is
    # the identity of quant_merge, so the gather-then-fold below is exactly
    # "the coordinator merges what was shipped".
    pay = qlib.QuantState(
        values=jnp.where(send, site_q.values, jnp.inf),
        g=jnp.where(send, site_q.g, 0.0),
        delta=jnp.where(send, site_q.delta, 0.0),
        wv=jnp.where(send, site_q.wv, 0.0),
        weight=jnp.where(send, site_q.weight, 0.0),
    )
    gathered = jax.tree.map(lambda a: lax.all_gather(a, cfg.axis), pay)  # (m, ...)
    coord = st.coord_q
    for j in range(cfg.m):  # static unroll: m is the mesh axis size
        coord = qlib.quant_merge(
            coord, jax.tree.map(lambda a: a[j], gathered), cfg.eps / 2.0, cfg.q_cap
        )

    live = jnp.sum(jnp.isfinite(site_q.values).astype(jnp.int32))
    shipped = lax.psum(jnp.where(send, live, 0), cfg.axis)
    n_scalar = lax.psum(send.astype(jnp.int32), cfg.axis)

    w_pushed = jnp.where(send, w_i, st.w_pushed)
    # Reset shipped site summaries.
    empty = qlib.quant_init(cfg.q_cap)
    site_q = jax.tree.map(lambda a, b: jnp.where(send, b, a), site_q, empty)

    rebroadcast = coord.weight / st.w_hat > 1.0 + cfg.eps / 2.0
    w_hat = jnp.where(rebroadcast, coord.weight, st.w_hat)
    comm = CommCounters(
        scalar_msgs=st.comm.scalar_msgs + n_scalar,
        row_msgs=st.comm.row_msgs + shipped.astype(jnp.int32),
        broadcast_events=st.comm.broadcast_events + rebroadcast.astype(jnp.int32),
    )
    return QuantP1State(site_q, w_i, w_pushed, coord, w_hat, comm)


def quant_p1_table(st: QuantP1State) -> "jax.Array":
    """The coordinator's published ``(n, 2)`` [value, rank-estimate] table."""
    return qlib.quant_table(st.coord_q)


def quant_p1_w_hat(st: QuantP1State) -> float:
    """Coordinator estimate of the total stream weight (quantile frob analog)."""
    return float(st.coord_q.weight)


# ---------------------------------------------------------------------------
# Leverage-score row sampling, protocol 1 — deterministic threshold
# forwarding over masked collectives.
#
# The leverage twin of quant_p1_step, mirroring the event-driven
# ``LeverageP1Stream``: every shard (= site) scores its local rows against
# the replicated coordinator factor ``(B^T B + lambda I)^+`` (B = residual
# FD rows + the kept reservoir, lambda = eps * F_hat).  Rows whose score
# crosses the broadcast threshold ``theta`` are shipped outright through a
# masked ``all_gather`` and folded into the replicated reservoir with
# ``lev_merge_spill`` (the all-pad candidate batch is the merge identity);
# reservoir spill folds into the residual FD sketch, so overflow never
# loses mass.  Everything below threshold rides the site FD sketch,
# shipped on the matrix-P1 mass threshold ``(eps/2m) F_hat``.  Message
# units follow the paper: a forwarded row or shipped sketch row costs one
# row message, a sketch ship one scalar, and a rebroadcast (F_hat growth
# or theta doubling) costs m.  The scoring factor refreshes ONLY on those
# counted broadcasts, so sites never consume coordinator state that was
# not paid for (the same information boundary f_hat/w_hat observe).
# ---------------------------------------------------------------------------


class LevP1State(NamedTuple):
    """Leverage P1 shard state: per-site FD + replicated reservoir/factor data."""
    site_fd: fdlib.FDState  # per-shard — residual (below-threshold) rows only
    f_i: jax.Array  # per-shard () f32 — residual mass since last ship
    coord_fd: fdlib.FDState  # replicated — residual sketch at C
    res: levlib.LevState  # replicated — kept (row, score, weight) reservoir
    f_res: jax.Array  # replicated — residual mass received at C
    f_hat: jax.Array  # replicated — broadcast estimate of ||A||_F^2
    theta: jax.Array  # replicated — forwarding threshold
    factor: jax.Array  # replicated (d, d) — last BROADCAST scoring factor
    comm: CommCounters


def lev_p1_init(cfg: ProtocolConfig) -> LevP1State:
    """Initial leverage P1 state for one site (tiled per shard by the runner)."""
    cfg = cfg.resolved()
    lam0 = levlib.default_lambda(cfg.eps, 1.0)
    return LevP1State(
        site_fd=fdlib.fd_init(cfg.l_site, cfg.d),
        f_i=jnp.zeros((), jnp.float32),
        coord_fd=fdlib.fd_init(cfg.l_coord, cfg.d),
        res=levlib.lev_init(cfg.lev_cap, cfg.d),
        f_res=jnp.zeros((), jnp.float32),
        f_hat=jnp.ones((), jnp.float32),
        theta=jnp.ones((), jnp.float32),
        factor=jnp.eye(cfg.d, dtype=jnp.float32) / jnp.float32(lam0),
        comm=CommCounters.zero(),
    )


def _lev_factor(coord_fd: fdlib.FDState, res: levlib.LevState,
                f_hat: jax.Array, cfg: ProtocolConfig) -> jax.Array:
    """The scoring factor ``(B^T B + lambda I)^{-1}`` (d, d) at broadcast time.

    B stacks the residual FD rows and the kept reservoir rows; the ridge
    ``lambda = eps * max(f_hat, 1)`` keeps the Gram positive definite, so
    a plain eigh-based inverse is exact and jit-stable.
    """
    ball = jnp.concatenate([fdlib.fd_matrix(coord_fd), res.rows])
    lam = jnp.float32(cfg.eps) * jnp.maximum(f_hat, 1.0)
    g = jnp.matmul(ball.T, ball, preferred_element_type=jnp.float32)
    g = g + lam * jnp.eye(cfg.d, dtype=jnp.float32)
    evals, evecs = jnp.linalg.eigh(g)
    inv = (evecs / jnp.maximum(evals, 1e-30)[None, :]) @ evecs.T
    return inv


def lev_p1_step(cfg: ProtocolConfig, st: LevP1State, rows: jax.Array) -> LevP1State:
    """One super-step; call inside shard_map with ``rows`` = local (b, d)."""
    cfg = cfg.resolved()
    if rows.shape[0] == 0:  # static shape: nothing to absorb
        return st
    rows = rows.astype(jnp.float32)
    # Score against the LAST BROADCAST factor: between counted broadcasts
    # the sites' view of the coordinator summary is frozen, exactly like
    # the event engine's self._factor.
    scores = jnp.sum((rows @ st.factor) * rows, axis=1)
    # A site forwards at most lev_cap rows per super-step (the reservoir
    # can absorb no more): the top local scorers above theta.  Everything
    # else rides the FD residual, so the envelope is indifferent to the
    # cap — it only bounds per-step communication.
    k_local = min(cfg.lev_cap, scores.shape[0])
    kth = lax.top_k(scores, k_local)[0][-1]
    fwd = (scores >= st.theta) & (scores >= kth)
    n_fwd = lax.psum(jnp.sum(fwd.astype(jnp.int32)), cfg.axis)

    # Masked ship of forwarded candidates: a non-forwarded lane contributes
    # a zero-score triple, the identity of lev_merge, so gather-then-merge
    # is exactly "the coordinator keeps what was forwarded".
    cand_rows = lax.all_gather(
        jnp.where(fwd[:, None], rows, 0.0), cfg.axis
    ).reshape(-1, cfg.d)
    cand_scores = lax.all_gather(jnp.where(fwd, scores, 0.0), cfg.axis).reshape(-1)
    res, spilled = levlib.lev_merge_spill(
        st.res, cand_rows, cand_scores, jnp.ones_like(cand_scores)
    )
    # Reservoir spill folds into the residual sketch (coordinator-local):
    # overflow raises theta, it never drops mass.
    coord_fd = fdlib.fd_update_stream(st.coord_fd, spilled, use_pallas=cfg.use_pallas)
    spill_mass = jnp.sum(_row_sq(spilled))
    overflow = spill_mass > 0.0
    # Threshold propagation: once the reservoir overflows, the broadcast
    # entry bar jumps to the smallest kept score (doubling at minimum) —
    # a site learns it must beat the incumbents to forward at all.
    theta = jnp.where(
        overflow, jnp.maximum(st.theta * 2.0, res.scores[-1]), st.theta
    )

    # Below-threshold rows ride the site FD sketch (zero rows are free).
    site_rows = jnp.where(fwd[:, None], 0.0, rows)
    site_fd = fdlib.fd_update_stream(st.site_fd, site_rows, use_pallas=cfg.use_pallas)
    f_i = st.f_i + jnp.sum(_row_sq(site_rows))

    send = f_i >= (cfg.eps / (2 * cfg.m)) * st.f_hat
    payload = jnp.where(send, fdlib.fd_matrix(site_fd), 0.0)  # (l_site, d)
    gathered = lax.all_gather(payload, cfg.axis)  # (m, l_site, d)
    coord_fd = fdlib.fd_update_stream(
        coord_fd, gathered.reshape(-1, cfg.d), use_pallas=cfg.use_pallas
    )
    shipped_rows = lax.psum(
        jnp.where(send, jnp.sum(_row_sq(fdlib.fd_matrix(site_fd)) > 0), 0), cfg.axis
    )
    n_scalar = lax.psum(send.astype(jnp.int32), cfg.axis)

    f_res = st.f_res + spill_mass + lax.psum(jnp.where(send, f_i, 0.0), cfg.axis)
    f_i = jnp.where(send, 0.0, f_i)
    empty = fdlib.fd_init(cfg.l_site, cfg.d)
    site_fd = jax.tree.map(lambda a, b: jnp.where(send, b, a), site_fd, empty)

    mass_kept = jnp.sum(_row_sq(res.rows))
    rebroadcast = (f_res + mass_kept) / st.f_hat > 1.0 + cfg.eps / 2.0
    f_hat = jnp.where(rebroadcast, f_res + mass_kept, st.f_hat)
    # The factor refreshes only when a broadcast is actually counted
    # (mass growth or theta doubling) — sites keep scoring against the
    # stale one until then.
    did_broadcast = rebroadcast | overflow
    factor = jnp.where(did_broadcast, _lev_factor(coord_fd, res, f_hat, cfg),
                       st.factor)
    comm = CommCounters(
        scalar_msgs=st.comm.scalar_msgs + n_scalar,
        row_msgs=st.comm.row_msgs + shipped_rows.astype(jnp.int32) + n_fwd,
        broadcast_events=st.comm.broadcast_events
        + rebroadcast.astype(jnp.int32)
        + overflow.astype(jnp.int32),
    )
    return LevP1State(site_fd, f_i, coord_fd, res, f_res, f_hat, theta, factor,
                      comm)


def lev_p1_table(cfg: ProtocolConfig, st: LevP1State) -> "np.ndarray":
    """The coordinator's published ``(n, d+2)`` [row | score | weight] table.

    Assembled by the shared ``core.leverage.build_p1_table`` encoder (kept
    reservoir rows at weight 1 beside the live residual-sketch rows at
    weight 1) — the same deterministic estimator the event stream
    publishes, so ``table_subspace`` inherits the FD envelope on both
    engines.
    """
    import numpy as np

    cfg = cfg.resolved()
    scores = np.asarray(st.res.scores, np.float64)
    live = scores > 0
    return levlib.build_p1_table(
        np.asarray(st.res.rows, np.float64)[live],
        scores[live],
        np.asarray(fdlib.fd_matrix(st.coord_fd)),
        lev_p1_lambda(cfg, st),
    )


def lev_p1_mass(st: LevP1State) -> float:
    """Coordinator estimate of ``||A||_F^2`` (residual + kept reservoir mass)."""
    return float(st.f_res) + float(jnp.sum(_row_sq(st.res.rows)))


def lev_p1_lambda(cfg: ProtocolConfig, st: LevP1State) -> float:
    """The live ridge ``lambda = eps * max(f_hat, 1)`` of a shard state.

    Based on the *broadcast* mass estimate — the same basis the in-step
    scoring factor uses — so the score column of a published table and a
    served score query for the same vector agree beyond timing lag.
    """
    return levlib.default_lambda(cfg.eps, float(st.f_hat))


# ---------------------------------------------------------------------------
# Runner: wraps a protocol step in shard_map over a mesh axis.
# ---------------------------------------------------------------------------

_INITS = {"P1": p1_init, "P2": p2_init, "P3": p3_init, "HHP1": hh_p1_init,
          "QP1": quant_p1_init, "LP1": lev_p1_init}
_STEPS = {"P1": p1_step, "P2": p2_step, "P3": p3_step, "HHP1": hh_p1_step,
          "QP1": quant_p1_step, "LP1": lev_p1_step}
_MATRICES = {
    "P1": lambda st: fdlib.fd_matrix(st.coord_fd),
    "P2": lambda st: fdlib.fd_matrix(st.coord_fd),
    "P3": p3_matrix,
}


def protocol_matrix(protocol: str, state) -> jax.Array:
    """The coordinator's sketch matrix B for any protocol state (uniform)."""
    return _MATRICES[protocol](state)


def protocol_frob(protocol: str, state, matrix=None) -> float:
    """Coordinator estimate of the stream mass ``||A||_F^2`` (uniform).

    P1/P2 carry the coordinator's running broadcast estimate ``f_hat``
    (within (1+eps) of ``||A||_F^2``); P3's priority-sample estimator matrix
    preserves the stream mass by construction, so its own Frobenius norm
    stands in (pass ``matrix`` to reuse an already-materialized sketch).
    """
    if protocol in ("P1", "P2"):
        return float(state.f_hat)
    b = protocol_matrix(protocol, state) if matrix is None else matrix
    return float(jnp.sum(b * b))


# Per-site state leaves (leading m axis sharded over cfg.axis) per protocol;
# every other leaf is replicated.  Shared by both runner factories.
_PER_SITE_LEAVES = {
    "P1": ("site_fd", "f_i"),
    "P2": ("site_fd", "f_j"),
    "P3": ("rng",),
    "HHP1": ("site_mg", "w_i"),
    "QP1": ("site_q", "w_i", "w_pushed"),
    "LP1": ("site_fd", "f_i"),
}

# Protocols safe to advance as a stacked multi-tenant pack: their step is a
# deterministic function of (state, rows) for which appended zero rows are
# exact no-ops on every served quantity (zero-norm rows add nothing to site
# sketches, masses, thresholds, or candidate sets).  P3 is excluded — its
# per-step PRNG draw shape follows the padded row count, so padding would
# change the sample — as are the pair-input protocols (HHP1/QP1), whose
# weighted items cannot be zero-padded without perturbing the summaries.
PACKABLE_PROTOCOLS = ("P1", "P2", "LP1")

# Jitted (state0, step) runners keyed on (protocol, cfg, mesh): the T-th
# same-shape tenant reuses the first tenant's trace instead of re-tracing.
_RUNNER_CACHE: dict = {}
_PACKED_RUNNER_CACHE: dict = {}


def make_protocol_runner(protocol: str, cfg: ProtocolConfig, mesh: jax.sharding.Mesh):
    """Return ``(init_state, step)``: one jitted shard_map super-step.

    For the matrix protocols and ``LP1`` (leverage sampling)
    ``step(state, rows)`` consumes a global ``(m * b, d)`` array sharded
    over ``cfg.axis``; for ``HHP1`` (element keys) and ``QP1`` (quantile
    values) it consumes a ``(keys, weights)`` pair of global ``(m * b,)``
    arrays sharded the same way.  ``state``
    leaves that are per-site carry a leading ``m`` axis sharded over
    ``cfg.axis``; replicated leaves are replicated.

    Runners are cached on ``(protocol, cfg, mesh)``: protocol state is
    immutable and the step function pure, so same-shape tenants share one
    jitted callable (and its traces) instead of paying a retrace each.
    """
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    cfg = cfg.resolved()
    cached = _RUNNER_CACHE.get((protocol, cfg, mesh))
    if cached is not None:
        return cached
    init_fn = _INITS[protocol]
    step_fn = _STEPS[protocol]

    per_site_leaves = _PER_SITE_LEAVES[protocol]
    # HH and quantile streams arrive as a (keys/values, weights) pair of
    # 1-D arrays; matrix and leverage streams as one (n, d) row block.
    if protocol in ("HHP1", "QP1"):
        data_spec = (P(cfg.axis), P(cfg.axis))
    else:
        data_spec = P(cfg.axis, None)

    def _state_specs(state) -> object:
        specs = {}
        for name in state._fields:
            leaf = getattr(state, name)
            if name in per_site_leaves:
                spec = jax.tree.map(lambda _: P(cfg.axis), leaf)
            else:
                spec = jax.tree.map(lambda _: P(), leaf)
            specs[name] = spec
        return type(state)(**specs)

    def init_state():
        if protocol == "P3":
            one = init_fn(cfg)
            keys = jax.random.split(jax.random.key(0), cfg.m)
            state = one._replace(rng=keys)
        else:
            one = init_fn(cfg)

            def tile(name, leaf):
                if name in per_site_leaves:
                    return jax.tree.map(lambda a: jnp.broadcast_to(a, (cfg.m,) + a.shape), leaf)
                return leaf

            state = type(one)(**{n: tile(n, getattr(one, n)) for n in one._fields})
        return state

    def _inner(state, rows):
        # Inside shard_map: per-site leaves arrive with leading axis 1.
        def unbatch(name, leaf):
            if name in per_site_leaves:
                return jax.tree.map(lambda a: a[0], leaf)
            return leaf

        local = type(state)(**{n: unbatch(n, getattr(state, n)) for n in state._fields})
        new = step_fn(cfg, local, rows)

        def rebatch(name, leaf):
            if name in per_site_leaves:
                return jax.tree.map(lambda a: a[None], leaf)
            return leaf

        return type(new)(**{n: rebatch(n, getattr(new, n)) for n in new._fields})

    state0 = init_state()
    specs = _state_specs(state0)

    step = jax.jit(
        shard_map(
            _inner,
            mesh=mesh,
            in_specs=(specs, data_spec),
            out_specs=specs,
            check_rep=False,
        )
    )
    _RUNNER_CACHE[(protocol, cfg, mesh)] = (state0, step)
    return state0, step


class PackedRunner(NamedTuple):
    """The two jitted entry points of one packed super-step program.

    ``stacked(stacked_state, rows)`` advances a resident ``(T, ...)``
    stacked state — the steady-state path: leaves stay on device in
    their pack layout between waves, nothing restacks.
    ``from_states(states_tuple, rows)`` additionally stacks a tuple of T
    per-tenant states inside the same jit first — the (re)pack path for
    a group's first wave or after a member stepped serially.  Both
    return the advanced *stacked* state; slice a tenant out lazily with
    ``unstack_packed`` only when its state is actually read.
    """

    stacked: Callable
    from_states: Callable


@functools.partial(jax.jit, static_argnums=1)
def unstack_packed(stacked_state, t: int):
    """Materialize tenant ``t``'s per-tenant state from a pack's stacked state.

    Jitted (one trace per (state structure, t)) so slicing a tenant out is
    ONE dispatch, not one per leaf — publish-heavy fleets read a member's
    state every wave, and an eager per-leaf tree.map would hand back most
    of the dispatch savings packing bought.
    """
    return jax.tree.map(lambda a: a[t], stacked_state)


def make_packed_runner(
    protocol: str, cfg: ProtocolConfig, mesh: jax.sharding.Mesh
) -> PackedRunner:
    """Return a ``PackedRunner`` advancing T tenants in one launch.

    The multi-tenant ingest megakernel: T per-tenant protocol states
    (same packable protocol, equal ``cfg``) stack along a leading tenant
    axis — per-site leaves become ``(T, m, ...)`` sharded
    ``P(None, axis)``, replicated leaves ``(T, ...)``, the tenants'
    zero-padded row batches one ``(T, n, d)`` block ``P(None, axis,
    None)`` — and a ``shard_map`` whose body ``vmap``s the per-site
    super-step over the tenant axis advances the whole pack in ONE
    dispatch (collectives batch over ``vmap``; the named site axis is
    orthogonal to the tenant axis).  The advanced state STAYS stacked:
    ``PackedRunner.stacked`` feeds it straight into the next wave with
    zero per-tenant host dispatches, and ``unstack_packed`` slices a
    tenant out only when something actually reads its state (publish,
    query, checkpoint) — restacking 14 leaves x T tenants per wave is
    what made an early packed path *slower* than serial on CPU.

    Ragged packs zero-pad each tenant's rows *per site block* up to the
    common ``n`` (see ``runtime.ingest_packed``); zero rows are exact
    no-ops for every ``PACKABLE_PROTOCOLS`` member, so the packed advance
    matches T serial ``make_protocol_runner`` steps on every served
    answer.  Cached on ``(protocol, cfg, mesh)`` like the serial runner
    (each jit retraces per distinct (T, n) launch shape).
    """
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    cfg = cfg.resolved()
    if protocol not in PACKABLE_PROTOCOLS:
        raise ValueError(
            f"protocol {protocol!r} is not packable; choose from {PACKABLE_PROTOCOLS}"
        )
    cached = _PACKED_RUNNER_CACHE.get((protocol, cfg, mesh))
    if cached is not None:
        return cached
    step_fn = _STEPS[protocol]
    per_site_leaves = _PER_SITE_LEAVES[protocol]
    one = _INITS[protocol](cfg)  # structure only: specs mirror the state tree

    def _specs(state) -> object:
        specs = {}
        for name in state._fields:
            leaf = getattr(state, name)
            if name in per_site_leaves:
                spec = jax.tree.map(lambda _: P(None, cfg.axis), leaf)
            else:
                spec = jax.tree.map(lambda _: P(), leaf)
            specs[name] = spec
        return type(state)(**specs)

    def _inner(state, rows):
        # Inside shard_map: per-site leaves arrive (T, 1, ...); drop the
        # site axis, vmap the per-site step over the tenant axis, rebatch.
        def unbatch(name, leaf):
            if name in per_site_leaves:
                return jax.tree.map(lambda a: a[:, 0], leaf)
            return leaf

        local = type(state)(**{n: unbatch(n, getattr(state, n)) for n in state._fields})
        new = jax.vmap(lambda st, r: step_fn(cfg, st, r))(local, rows)

        def rebatch(name, leaf):
            if name in per_site_leaves:
                return jax.tree.map(lambda a: a[:, None], leaf)
            return leaf

        return type(new)(**{n: rebatch(n, getattr(new, n)) for n in new._fields})

    specs = _specs(one)
    sharded = shard_map(
        _inner,
        mesh=mesh,
        in_specs=(specs, P(None, cfg.axis, None)),
        out_specs=specs,
        check_rep=False,
    )

    @jax.jit
    def step_stacked(stacked, rows):
        return sharded(stacked, rows)

    @jax.jit
    def step_from_states(states, rows):
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *states)
        return sharded(stacked, rows)

    runner = PackedRunner(stacked=step_stacked, from_states=step_from_states)
    _PACKED_RUNNER_CACHE[(protocol, cfg, mesh)] = runner
    return runner
