"""Leverage-score row sampling: the fourth protocol kind's math.

The paper's randomized matrix protocols (P3/P3wr) sample rows by squared
norm, which is known to be weak for low-rank structure: a direction can
carry most of the *spectral* information while holding little Frobenius
mass.  Leverage-score sampling — the workhorse of distributed PCA
(Boutsidis--Woodruff--Zhong) and the natural companion to Frequent
Directions sketches (Ghashami et al.) — samples rows by how much of the
stream's row space they explain.  This module supplies the workload's
math in the same two-implementation shape as the other kinds:

  * ``ridge_factor`` / ``ridge_scores`` — the python oracle: streaming
    approximate *ridge* leverage scores computed against a live sketch,
    ``tau_i = a_i^T (B^T B + lambda I)^+ a_i``.  The ridge ``lambda``
    (adaptively ``eps * F_hat``) keeps the pseudo-inverse stable and
    caps the effective dimension at the directions FD would retain.
  * ``LevState`` + ``lev_*`` — a fixed-shape jit-able reservoir of
    ``(row, score, weight)`` triples, sorted by descending score; the
    all-pad state (every score zero) is the identity of ``lev_merge``,
    which is what lets ``lev_p1_step`` ship candidates as masked
    collectives (exactly like ``MGState`` / ``QuantState``).
  * ``LeverageP1Stream`` / ``LeverageP2Stream`` — event-driven site ->
    coordinator protocols in the paper's style: deterministic threshold
    propagation on score-mass growth (sites forward a row outright when
    its score crosses the broadcast threshold, everything else rides an
    FD residual sketch shipped on mass growth) and the cheaper
    score-weighted reservoir-sampling variant.  Communication is counted
    via ``CommLog`` in the paper's units.
  * snapshot codec — published leverage state is an ``(n, d + 2)``
    ``[row | score | weight]`` f32 table (one immutable 2-D array per
    ``SketchStore`` version); ``table_subspace`` / ``table_scores`` are
    the single implementation every query surface shares.

Query semantics: the published table is an importance-weighted row
sample ``A_sample``; ``||A_sample x||^2 = sum_i w_i (a_i . x)^2``
estimates ``||A x||^2`` (the subspace query), and scoring a vector
against the sample's ridge-regularized Gram answers "how novel is this
row" (the score query).  Both are served inside packed sweeps by
``repro.query.engine``.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import NamedTuple

import numpy as np

from repro.core.fd import FDSketch

__all__ = [
    "QUERY_SUBSPACE",
    "QUERY_SCORE",
    "subspace_query",
    "score_query",
    "ridge_factor",
    "ridge_scores",
    "encode_leverage_snapshot",
    "decode_leverage_snapshot",
    "weighted_rows",
    "table_subspace",
    "serve_subspace",
    "table_scores",
    "build_p1_table",
    "LevState",
    "lev_init",
    "lev_merge",
    "lev_merge_spill",
    "LeverageResult",
    "LeverageP1Stream",
    "LeverageP2Stream",
    "LEVERAGE_STREAMS",
    "run_leverage_protocol",
    "default_cap",
    "default_lambda",
]

#: Query-row mode tags for leverage tenants: a packed-service query is a
#: ``(d + 1,)`` row ``[mode, x_1..x_d]`` — ``QUERY_SUBSPACE`` asks for the
#: importance-weighted estimate of ``||A x||^2``; ``QUERY_SCORE`` for the
#: approximate ridge leverage score of ``x`` against the published sample.
QUERY_SUBSPACE = 0.0
QUERY_SCORE = 1.0


def subspace_query(x: np.ndarray) -> np.ndarray:
    """Build the ``(d + 1,)`` query row asking for ``||A x||^2``."""
    x = np.asarray(x, np.float32).ravel()
    return np.concatenate([np.array([QUERY_SUBSPACE], np.float32), x])


def score_query(x: np.ndarray) -> np.ndarray:
    """Build the ``(d + 1,)`` query row asking for the ridge score of ``x``.

    Score answers are *diagnostics* ("how novel is this direction?") on
    the ~[0, d_eff] scale: unlike subspace answers they are not covered
    by the served ``error_bound`` certificate, which is in ``eps * F_hat``
    (stream-mass) units.
    """
    x = np.asarray(x, np.float32).ravel()
    return np.concatenate([np.array([QUERY_SCORE], np.float32), x])


def default_cap(eps: float) -> int:
    """Default reservoir capacity: ``O(1/eps)`` rows, floor 16."""
    return max(16, math.ceil(4.0 / eps))


def default_lambda(eps: float, f_hat: float) -> float:
    """The adaptive ridge ``lambda = eps * max(F_hat, 1)``.

    Directions with ``sigma^2 < eps * ||A||_F^2`` are exactly the ones the
    eps-level FD sketch is allowed to shrink away, so regularizing at that
    scale caps the score mass at the retained effective dimension.
    """
    return eps * max(float(f_hat), 1.0)


# ---------------------------------------------------------------------------
# Python oracle: ridge leverage scoring against a sketch.
# ---------------------------------------------------------------------------


def ridge_factor(rows: np.ndarray, weights, lam: float) -> np.ndarray:
    """The scoring factor ``M = (sum_i w_i a_i a_i^T + lambda I)^+``.

    ``rows`` is ``(k, d)``; ``weights`` broadcasts over rows (pass 1.0 for
    a plain sketch).  ``lam > 0`` makes the Gram positive definite, so the
    pseudo-inverse is a true inverse and scoring is numerically stable
    even for rank-deficient sketches.  Returned as f64 ``(d, d)``.
    """
    rows = np.asarray(rows, np.float64)
    if rows.ndim != 2:
        raise ValueError(f"scoring rows must be (k, d), got shape {rows.shape}")
    if lam <= 0.0:
        raise ValueError(f"ridge lambda must be > 0, got {lam}")
    d = rows.shape[1]
    w = np.broadcast_to(np.asarray(weights, np.float64), (rows.shape[0],))
    g = (rows * w[:, None]).T @ rows + lam * np.eye(d)
    return np.linalg.pinv(g)


def ridge_scores(factor: np.ndarray, x: np.ndarray) -> np.ndarray:
    """Batched quadratic forms ``tau_j = x_j^T M x_j`` (numpy reference).

    The oracle the Pallas ``levscore`` kernel is validated against; the
    event-driven streams score with this, the serving engine launches the
    fused kernel.
    """
    x = np.asarray(x, np.float64)
    return np.sum((x @ np.asarray(factor, np.float64)) * x, axis=-1)


# ---------------------------------------------------------------------------
# Snapshot codec + shared query paths over the published (n, d+2) table.
# ---------------------------------------------------------------------------


def encode_leverage_snapshot(table: np.ndarray) -> np.ndarray:
    """Validate + freeze a leverage table into the store's ``(n, d+2)`` form.

    Columns ``[0, d)`` hold the sampled (or sketch) rows, column ``d`` the
    score each row was kept at, column ``d+1`` its importance weight.
    Scores and weights must be finite and non-negative.  This is the
    matrix a ``SketchStore`` snapshot carries for a leverage tenant.
    """
    t = np.asarray(table, np.float32)
    if t.ndim != 2 or t.shape[1] < 3:
        raise ValueError(
            f"leverage snapshot table must be (n, d+2) with d >= 1, got {t.shape}"
        )
    if t.shape[0]:
        tail = t[:, -2:]
        if not np.all(np.isfinite(tail)) or tail.min() < 0.0:
            raise ValueError(
                "leverage snapshot scores and weights must be finite and >= 0"
            )
    return t


def decode_leverage_snapshot(
    matrix: np.ndarray,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Invert ``encode_leverage_snapshot``: ``(rows, scores, weights)``."""
    m = np.asarray(matrix)
    if m.ndim != 2 or m.shape[1] < 3:
        raise ValueError(
            f"leverage snapshot matrix must be (n, d+2) with d >= 1, got {m.shape}"
        )
    return m[:, :-2], m[:, -2], m[:, -1]


def weighted_rows(rows: np.ndarray, weights) -> np.ndarray:
    """The scaled sample ``sqrt(w_i) a_i`` whose plain quadratic form IS the
    subspace estimate: ``||weighted_rows(A_s, w) x||^2 = sum_i w_i (a_i.x)^2``.

    The one place the weighting convention lives — the numpy reference
    (``table_subspace``), the registry interface, and the serving engine
    all build their sample through this before squaring (the kernel
    surfaces hand it to ``ops.quadform``), so the convention cannot
    drift between live and published answers.
    """
    rows = np.asarray(rows)
    return rows * np.sqrt(np.maximum(np.asarray(weights), 0.0))[:, None]


def table_subspace(table: np.ndarray, xs) -> np.ndarray:
    """Importance-weighted ``||A x||^2`` estimates per direction row.

    ``sum_i w_i (a_i . x)^2`` over the published sample — the numpy
    reference path; the kernel surfaces serve the same table through
    ``serve_subspace``.
    """
    xs = np.atleast_2d(np.asarray(xs, np.float64))
    rows, _, w = decode_leverage_snapshot(table)
    if rows.shape[0] == 0:
        return np.zeros(xs.shape[0], np.float32)
    proj = xs @ weighted_rows(np.asarray(rows, np.float64), w).T  # (n_query, k)
    return np.sum(proj * proj, axis=1).astype(np.float32)


def serve_subspace(table: np.ndarray, xs, *, interpret=None) -> np.ndarray:
    """Kernel-served twin of ``table_subspace``: one ``quadform`` launch.

    THE implementation every kernel surface uses — the live registry
    interface (``LeverageProtocol.subspace_query_batch``) and the serving
    engine's packed-sweep path (``QueryEngine._leverage_batch``) both
    call this, so live and published answers cannot drift in decode,
    weighting, empty-sample, or kernel conventions.
    """
    import jax.numpy as jnp

    from repro.kernels.ops import quadform

    rows, _, w = decode_leverage_snapshot(table)
    xs = np.atleast_2d(np.asarray(xs, np.float32))
    if rows.shape[0] == 0:  # empty sample: every quadratic form is 0
        return np.zeros(xs.shape[0], np.float32)
    return np.asarray(quadform(
        jnp.asarray(weighted_rows(rows, w), jnp.float32),
        jnp.asarray(xs),
        interpret=interpret,
    ))


def table_scores(table: np.ndarray, xs, lam: float) -> np.ndarray:
    """Ridge leverage scores of ``xs`` against the published sample's Gram."""
    xs = np.atleast_2d(np.asarray(xs, np.float64))
    rows, _, w = decode_leverage_snapshot(table)
    factor = ridge_factor(rows, w, lam)
    return ridge_scores(factor, xs).astype(np.float32)


def build_p1_table(
    kept_rows: np.ndarray, kept_scores, residual_rows: np.ndarray, lam: float
) -> np.ndarray:
    """Assemble the deterministic P1 estimator table, ``(k, d+2)`` f32.

    Kept (forwarded) rows ride at weight 1 with the score they were kept
    at; live (non-zero) residual-sketch rows ride at weight 1 with their
    ridge score against the residual's own factor.  The ONE encoder both
    P1 engines publish through — the event stream
    (``LeverageP1Stream.result``) and the shard super-step
    (``core.distributed.lev_p1_table``) — so the two engines cannot
    drift in what they serve.
    """
    d = residual_rows.shape[1] if residual_rows.ndim == 2 else kept_rows.shape[1]
    parts = []
    if kept_rows.shape[0]:
        kept = np.asarray(kept_rows, np.float64)
        parts.append(np.concatenate(
            [kept, np.asarray(kept_scores, np.float64)[:, None],
             np.ones((kept.shape[0], 1))], axis=1))
    res = np.asarray(residual_rows, np.float64)
    res = res[np.einsum("rd,rd->r", res, res) > 0]
    if res.shape[0]:
        factor = ridge_factor(res, 1.0, lam)
        parts.append(np.concatenate(
            [res, ridge_scores(factor, res)[:, None],
             np.ones((res.shape[0], 1))], axis=1))
    if not parts:
        return np.zeros((0, d + 2), np.float32)
    return np.concatenate(parts, axis=0).astype(np.float32)


# ---------------------------------------------------------------------------
# Fixed-shape jit-able reservoir (the shard_map engine's state).
# ---------------------------------------------------------------------------


class LevState(NamedTuple):
    """Leverage reservoir as fixed-shape JAX arrays (pad score ``0``).

    Invariant: entries are sorted by descending score, pad slots (score 0,
    zero row, zero weight) at the tail.  An all-pad state is the identity
    of ``lev_merge`` — the property the shard engine's masked-collective
    shipping relies on, exactly like the empty ``MGState`` for HH and the
    all-pad ``QuantState`` for quantiles.
    """

    rows: "object"  # (cap, d) f32 — sampled rows, zero on pad
    scores: "object"  # (cap,) f32 — score at keep time, 0 = empty slot
    weights: "object"  # (cap,) f32 — importance weight, 0 on pad


def lev_init(cap: int, d: int) -> LevState:
    """The empty reservoir at capacity ``cap`` (merge identity)."""
    import jax.numpy as jnp

    return LevState(
        rows=jnp.zeros((cap, d), jnp.float32),
        scores=jnp.zeros((cap,), jnp.float32),
        weights=jnp.zeros((cap,), jnp.float32),
    )


def lev_merge_spill(
    state: LevState, rows, scores, weights
) -> tuple[LevState, "object"]:
    """Merge candidate triples into the reservoir; return what spilled out.

    Keeps the top-``cap`` entries of the union by score (ties resolved
    toward the incumbent state, so merging an all-pad candidate batch is
    bit-identical).  The second return value is the ``(n_cand + cap, d)``
    array of *dropped* rows (zero rows elsewhere) — the caller folds them
    into its residual sketch so reservoir overflow never loses mass.
    """
    import jax.numpy as jnp
    from jax import lax

    cap = state.scores.shape[0]
    all_rows = jnp.concatenate([state.rows, rows.astype(jnp.float32)])
    all_scores = jnp.concatenate([state.scores, scores.astype(jnp.float32)])
    all_weights = jnp.concatenate([state.weights, weights.astype(jnp.float32)])
    top_scores, top_idx = lax.top_k(all_scores, cap)
    keep_mask = jnp.zeros(all_scores.shape[0], bool).at[top_idx].set(True)
    new = LevState(
        rows=all_rows[top_idx],
        scores=top_scores,
        weights=all_weights[top_idx],
    )
    # Pad slots that survived top_k carry stale row/weight data only if a
    # zero-score candidate had non-zero payload; mask them out for the
    # all-pad == identity property.
    live = new.scores > 0.0
    new = LevState(
        rows=jnp.where(live[:, None], new.rows, 0.0),
        scores=new.scores,
        weights=jnp.where(live, new.weights, 0.0),
    )
    spilled = jnp.where((~keep_mask[:, None]) & (all_scores[:, None] > 0.0),
                        all_rows, 0.0)
    return new, spilled


def lev_merge(a: LevState, b: LevState) -> LevState:
    """Merge two reservoirs, keeping ``a``'s capacity (all-pad b = identity)."""
    merged, _ = lev_merge_spill(a, b.rows, b.scores, b.weights)
    return merged


# ---------------------------------------------------------------------------
# Event-driven site -> coordinator protocols (paper-style accounting).
# ---------------------------------------------------------------------------


@dataclass
class LeverageResult:
    """The coordinator's current leverage sample, queryable at any time."""

    table: np.ndarray  # (k, d+2) [row | score | weight]
    f_hat: float  # coordinator estimate of ||A||_F^2
    lam: float  # ridge lambda the sample was scored at
    comm: "object"  # CommLog in the paper's units
    m: int
    eps: float

    def subspace(self, xs) -> np.ndarray:
        """Importance-weighted ``||A x||^2`` estimate per direction row."""
        return table_subspace(self.table, xs)

    def scores(self, xs) -> np.ndarray:
        """Ridge leverage score of each queried vector vs the sample."""
        return table_scores(self.table, xs, self.lam)


class LeverageP1Stream:
    """Leverage P1: deterministic threshold propagation on score-mass growth.

    Every site scores each arriving row against the coordinator's last
    broadcast ridge factor ``M = (B^T B + lambda I)^+``.  A row whose
    score crosses the broadcast threshold ``theta`` is forwarded outright
    (it carries subspace information the summary lacks); everything else
    is absorbed into the site's FD residual sketch, shipped to the
    coordinator when the site's unshipped mass crosses the matrix-P1
    threshold ``(eps/2m) F_hat``.  The coordinator keeps the forwarded
    rows (capacity ``s``; on overflow ``theta`` doubles and the pruned
    rows fold into the residual sketch, so no mass is ever dropped) and
    rebroadcasts factor + threshold whenever its received mass grows by a
    ``1 + eps/2`` factor or ``theta`` doubles.

    The published estimator is ``kept rows (weight 1) + residual FD rows
    (weight 1)``, so the served ``||A x||^2`` inherits the deterministic
    FD envelope: kept rows are exact, residual mass is underestimated by
    at most ``eps ||A||_F^2`` (FD shrink + unshipped site tails).
    """

    def __init__(self, m, eps, d, rng=None, l=None, s=None):
        from repro.core.protocols import CommLog

        if l is None:
            l = max(2, math.ceil(4.0 / eps))  # FD err 2/l <= eps/2
        if s is None:
            s = default_cap(eps)
        self.m, self.eps, self.d, self.l, self.s = m, eps, d, l, s
        self.comm = CommLog()
        self.site_fd = [FDSketch(l, d) for _ in range(m)]
        self.site_f = [0.0] * m
        self.coord_fd = FDSketch(l, d)  # residual sketch at C
        self.kept_rows: list[np.ndarray] = []  # forwarded rows (f32)
        self.kept_scores: list[float] = []
        self.f_res = 0.0  # residual mass received at C
        self.mass_kept = 0.0  # exact mass of the kept rows
        self.f_hat = 1.0
        self.theta = 1.0
        self._factor = ridge_factor(
            np.zeros((0, d)), 1.0, default_lambda(eps, self.f_hat)
        )

    def _coord_mass(self) -> float:
        return self.f_res + self.mass_kept

    def _rebroadcast(self) -> None:
        """Recompute + broadcast the ridge factor (and current threshold)."""
        self.comm.broadcast_events += 1
        rows = [self.coord_fd.matrix().astype(np.float64)]
        if self.kept_rows:
            rows.append(np.stack(self.kept_rows).astype(np.float64))
        b = np.concatenate(rows, axis=0)
        lam = default_lambda(self.eps, self._coord_mass())
        self._factor = ridge_factor(b, 1.0, lam)

    def step(self, rows, sites) -> None:
        """Absorb a batch, continuing the event-at-a-time semantics exactly
        where the last batch left off."""
        m, eps = self.m, self.eps
        rows = np.asarray(rows)
        row_sq = np.einsum("nd,nd->n", rows, rows)
        for i, j in enumerate(np.asarray(sites).tolist()):
            a = rows[i].astype(np.float64)
            score = float(a @ (self._factor @ a))
            if score >= self.theta:
                # Forward the row outright: one row message.
                self.comm.item_msgs += 1
                self.kept_rows.append(rows[i].astype(np.float32))
                self.kept_scores.append(score)
                self.mass_kept += float(row_sq[i])
                if len(self.kept_rows) > self.s:
                    # Overflow: double theta, fold pruned rows into the
                    # residual sketch (coordinator-local, no messages).
                    self.theta *= 2.0
                    keep_r, keep_s = [], []
                    for r, sc in zip(self.kept_rows, self.kept_scores):
                        if sc >= self.theta:
                            keep_r.append(r)
                            keep_s.append(sc)
                        else:
                            self.coord_fd.append(r.astype(np.float64))
                            self.mass_kept -= float(r.astype(np.float64) @ r)
                            self.f_res += float(r.astype(np.float64) @ r)
                    self.kept_rows, self.kept_scores = keep_r, keep_s
                    self._rebroadcast()
            else:
                fd = self.site_fd[j]
                fd.append(rows[i])
                self.site_f[j] += float(row_sq[i])
                if self.site_f[j] >= (eps / (2 * m)) * self.f_hat:
                    mat = fd.matrix()
                    nz = mat[np.einsum("rd,rd->r", mat, mat) > 0]
                    self.comm.sketch_rows += int(nz.shape[0])
                    self.comm.scalar_msgs += 1
                    self.coord_fd.merge(fd)
                    self.f_res += self.site_f[j]
                    self.site_fd[j] = FDSketch(self.l, self.d)
                    self.site_f[j] = 0.0
                    if self._coord_mass() / self.f_hat > 1.0 + eps / 2.0:
                        self.f_hat = self._coord_mass()
                        self._rebroadcast()

    def result(self) -> LeverageResult:
        """The coordinator's current sample table (callable at any time)."""
        lam = default_lambda(self.eps, self._coord_mass())
        kept = (np.stack(self.kept_rows) if self.kept_rows
                else np.zeros((0, self.d), np.float32))
        table = build_p1_table(kept, self.kept_scores, self.coord_fd.matrix(), lam)
        return LeverageResult(table, self._coord_mass(), lam, self.comm,
                              self.m, self.eps)

    def state_dict(self) -> dict:
        """JSON-able snapshot of the full coordinator + site state."""
        from repro.core.protocols import _comm_state

        return {
            "site_fd": [fd.state_dict() for fd in self.site_fd],
            "site_f": list(self.site_f),
            "coord_fd": self.coord_fd.state_dict(),
            "kept_rows": [r.tolist() for r in self.kept_rows],
            "kept_scores": list(self.kept_scores),
            "f_res": self.f_res,
            "mass_kept": self.mass_kept,
            "f_hat": self.f_hat,
            "theta": self.theta,
            "factor": np.asarray(self._factor).tolist(),
            "comm": _comm_state(self.comm),
        }

    def load_state(self, state: dict) -> None:
        """Restore ``state_dict`` output bit-identically."""
        from repro.core.protocols import _comm_from_state

        self.site_fd = [FDSketch.from_state(s, self.l, self.d)
                        for s in state["site_fd"]]
        self.site_f = [float(f) for f in state["site_f"]]
        self.coord_fd = FDSketch.from_state(state["coord_fd"], self.l, self.d)
        self.kept_rows = [np.asarray(r, np.float32) for r in state["kept_rows"]]
        self.kept_scores = [float(s) for s in state["kept_scores"]]
        self.f_res = float(state["f_res"])
        self.mass_kept = float(state["mass_kept"])
        self.f_hat = float(state["f_hat"])
        self.theta = float(state["theta"])
        self._factor = np.asarray(state["factor"], np.float64)
        self.comm = _comm_from_state(state["comm"])


class LeverageP2Stream:
    """Leverage P2: score-weighted reservoir sampling (the cheap variant).

    Distributed priority sampling without replacement keyed by the
    *mass-scaled* ridge score ``s_i = lambda * tau_i`` — for a row
    orthogonal to the current sample this is exactly ``||a_i||^2``, for a
    well-covered row it decays toward zero, and at cold start (empty
    factor) it reduces to the matrix-P3 squared-norm key, so priorities
    stay on one scale across factor refreshes.  Site ``j`` draws
    ``rho_i = s_i / u_i`` and forwards the row when ``rho_i`` crosses the
    broadcast threshold; the coordinator keeps everything above it,
    doubling the threshold (one broadcast, which also refreshes the
    scoring factor) whenever the next round fills.  The kept set is a
    *threshold* sample — every item with ``rho_i >= tau`` — so each row's
    inclusion probability is exactly ``min(1, s_i / tau)`` and it carries
    the Horvitz--Thompson importance weight ``w_i = max(s_i, tau) / s_i``
    (deterministic given the keep, never a function of the drawn ``u``),
    making ``sum w_i (a_i . x)^2`` an unbiased estimate of
    ``||A x||^2`` — randomized, so the registry spec carries the
    sampling protocols' looser error factor.
    """

    def __init__(self, m, eps, d, rng, s=None):
        from repro.core.protocols import CommLog

        if s is None:
            s = max(16, math.ceil(2.0 / eps**2))
        self.m, self.eps, self.d, self.s = m, eps, d, s
        self.rng = rng
        self.comm = CommLog()
        self.tau = 1.0
        self.q_cur: list[tuple[np.ndarray, float, float]] = []  # (row, s_i, rho)
        self.q_next: list[tuple[np.ndarray, float, float]] = []
        self._lam = default_lambda(eps, 1.0)
        self._factor = ridge_factor(np.zeros((0, d)), 1.0, self._lam)

    def _refresh_factor(self) -> None:
        # Broadcast already counted by the caller (tau doubling event).
        res = self.result()
        rows, _, w = decode_leverage_snapshot(res.table)
        self._lam = res.lam
        self._factor = ridge_factor(rows, w, res.lam)

    def step(self, rows, sites) -> None:
        """Absorb a batch, continuing the event-at-a-time semantics exactly
        where the last batch left off (each row is scored against the
        factor live at its arrival, not the batch boundary)."""
        rows = np.asarray(rows)
        u = np.maximum(self.rng.uniform(size=rows.shape[0]), 1e-300)
        for i in range(rows.shape[0]):
            a = rows[i].astype(np.float64)
            score = float(a @ (self._factor @ a)) * self._lam
            rho = score / u[i]
            if rho >= self.tau:
                self.comm.item_msgs += 1
                # Copy: sampled rows outlive the caller's batch buffer.
                entry = (rows[i].astype(np.float32).copy(), score, rho)
                if rho >= 2.0 * self.tau:
                    self.q_next.append(entry)
                else:
                    self.q_cur.append(entry)
                if len(self.q_next) >= self.s:
                    self.tau *= 2.0
                    self.comm.broadcast_events += 1
                    self.q_cur = self.q_next
                    self.q_next = [t for t in self.q_cur if t[2] >= 2.0 * self.tau]
                    self.q_cur = [t for t in self.q_cur if t[2] < 2.0 * self.tau]
                    self._refresh_factor()

    def result(self) -> LeverageResult:
        """Threshold-sample estimator table (callable at any time)."""
        sample = self.q_cur + self.q_next
        if not sample:
            return LeverageResult(
                np.zeros((0, self.d + 2), np.float32), 0.0,
                default_lambda(self.eps, 1.0), self.comm, self.m, self.eps,
            )
        rows = np.stack([t[0] for t in sample]).astype(np.float64)
        scores = np.array([t[1] for t in sample], np.float64)
        # HT weight against the live threshold: pi_i = min(1, s_i / tau).
        # Deterministic given the keep — a one-row sample cannot blow up.
        w = np.maximum(scores, self.tau) / np.maximum(scores, 1e-300)
        f_hat = float(np.einsum("kd,kd,k->", rows, rows, w))
        table = np.concatenate(
            [rows, scores[:, None], w[:, None]], axis=1
        ).astype(np.float32)
        return LeverageResult(table, f_hat, default_lambda(self.eps, f_hat),
                              self.comm, self.m, self.eps)

    def state_dict(self) -> dict:
        """JSON-able snapshot of the sampler state (incl. PRNG)."""
        from repro.core.protocols import _comm_state, _rng_state

        return {
            "s": self.s,
            "tau": self.tau,
            "q_cur": [[r.tolist(), sc, rho] for r, sc, rho in self.q_cur],
            "q_next": [[r.tolist(), sc, rho] for r, sc, rho in self.q_next],
            "lam": self._lam,
            "factor": np.asarray(self._factor).tolist(),
            "rng": _rng_state(self.rng),
            "comm": _comm_state(self.comm),
        }

    def load_state(self, state: dict) -> None:
        """Restore ``state_dict`` output bit-identically."""
        from repro.core.protocols import _comm_from_state, _rng_from_state

        self.s = int(state["s"])
        self.tau = float(state["tau"])
        self.q_cur = [(np.asarray(r, np.float32), float(sc), float(rho))
                      for r, sc, rho in state["q_cur"]]
        self.q_next = [(np.asarray(r, np.float32), float(sc), float(rho))
                       for r, sc, rho in state["q_next"]]
        self._lam = float(state["lam"])
        self._factor = np.asarray(state["factor"], np.float64)
        self.rng = _rng_from_state(state["rng"])
        self.comm = _comm_from_state(state["comm"])


# Resumable stream engines (init/step/result/state_dict) — the registry's
# event-engine leverage entries, mirroring QUANTILE_STREAMS.
LEVERAGE_STREAMS = {
    "P1": LeverageP1Stream,
    "P2": LeverageP2Stream,
}


def run_leverage_protocol(
    name: str,
    rows: np.ndarray,
    sites: np.ndarray,
    m: int,
    eps: float,
    seed: int = 0,
    **kw,
) -> LeverageResult:
    """One-shot wrapper: stream the whole feed through a leverage protocol."""
    rng = np.random.default_rng(seed)
    try:
        stream_cls = LEVERAGE_STREAMS[name]
    except KeyError:
        raise KeyError(
            f"unknown leverage protocol {name!r} "
            f"(have: {sorted(LEVERAGE_STREAMS)})"
        ) from None
    eng = stream_cls(m, eps, rows.shape[1], rng, **kw)
    eng.step(rows, sites)
    return eng.result()
