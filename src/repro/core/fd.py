"""Frequent Directions (FD) sketch — the paper's core matrix substrate.

Two implementations, cross-validated in tests:

* ``FDState`` + ``fd_*`` functions — fixed-shape, jit-able JAX implementation
  (the production path; runs inside shard_map / scan on TPU).  Uses the
  Ghashami--Phillips fast variant: a ``2l x d`` buffer, shrinking back to at
  most ``l`` non-zero rows each time the buffer fills.  The shrink is computed
  with the Gram trick (``G = B @ B.T`` is ``2l x 2l``; ``eigh`` on it instead
  of an SVD of ``2l x d``), whose two matmul hot-spots map onto the Pallas
  kernels ``fd_gram`` / ``fd_project``.

* ``FDSketch`` — a plain-numpy, item-at-a-time oracle with the exact
  conditional-shrink semantics of the paper; used by the event-driven
  protocol engine and as the test oracle.

Guarantee (Liberty'13, as quoted in the paper):  for sketch parameter ``l``
and any unit vector ``x``::

    0 <= ||A x||^2 - ||B x||^2 <= delta_sum <= 2 ||A||_F^2 / l

where ``delta_sum`` is the accumulated shrink mass (tracked in the state, so
callers get the *instance-specific* bound, usually far tighter).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "FDState",
    "fd_init",
    "fd_update",
    "fd_update_stream",
    "fd_merge",
    "fd_query",
    "fd_matrix",
    "fd_shrink",
    "jit_cache_stats",
    "FDSketch",
]


# Jitted FD callables, keyed on (op, l, d, dtype, use_pallas[, n_chunks]).
# Per-tenant ingest used to build a fresh trace per tracker instance for
# identical shapes; the cache makes the T-th tenant a dict hit.  ``misses``
# is the retrace count pipeline ingest observability surfaces.
_JIT_CACHE: dict = {}
_JIT_STATS = {"hits": 0, "misses": 0}


def jit_cache_stats() -> dict:
    """Counters for the shared jitted-callable cache.

    ``misses`` counts distinct (shape, dtype, backend) signatures traced —
    the retrace count; ``hits`` counts calls served by an already-built
    callable.  Read by ``StreamingPipeline.stats()``.
    """
    return dict(_JIT_STATS)


def _cached_jit(key: tuple, build):
    fn = _JIT_CACHE.get(key)
    if fn is None:
        _JIT_STATS["misses"] += 1
        fn = _JIT_CACHE[key] = build()
    else:
        _JIT_STATS["hits"] += 1
    return fn


class FDState(NamedTuple):
    """Fixed-shape Frequent Directions sketch state.

    buf:       (2l, d) row buffer; rows [0, l) hold the current sketch, rows
               [l, 2l) are the staging area for incoming rows.
    frob:      () f32 — exact total squared Frobenius norm seen so far.
    delta_sum: () f32 — accumulated shrink mass; instance error bound.
    n_seen:    () i32 — number of rows consumed (excludes zero padding).
    """

    buf: jax.Array
    frob: jax.Array
    delta_sum: jax.Array
    n_seen: jax.Array

    @property
    def l(self) -> int:  # noqa: E743 - matches paper notation
        """Sketch row budget ``l`` (paper notation)."""
        return self.buf.shape[0] // 2

    @property
    def d(self) -> int:
        """Row dimensionality ``d``."""
        return self.buf.shape[1]


def fd_init(l: int, d: int, dtype=jnp.float32) -> FDState:
    """Create an empty sketch with parameter ``l`` (buffer holds ``2l`` rows)."""
    if l < 1:
        raise ValueError(f"FD sketch parameter l must be >= 1, got {l}")
    return FDState(
        buf=jnp.zeros((2 * l, d), dtype),
        frob=jnp.zeros((), jnp.float32),
        delta_sum=jnp.zeros((), jnp.float32),
        n_seen=jnp.zeros((), jnp.int32),
    )


def _gram(b: jax.Array, use_pallas: bool) -> jax.Array:
    if use_pallas:
        from repro.kernels import fd_ops

        return fd_ops.fd_gram(b)
    return jnp.matmul(b, b.T, preferred_element_type=jnp.float32)


def _project(w: jax.Array, u: jax.Array, b: jax.Array, use_pallas: bool) -> jax.Array:
    """Compute ``diag(w) @ (u.T @ b)`` — the FD shrink projection."""
    if use_pallas:
        from repro.kernels import fd_ops

        return fd_ops.fd_project(w, u, b)
    return (w[:, None] * jnp.matmul(u.T, b, preferred_element_type=jnp.float32)).astype(b.dtype)


def fd_shrink(buf: jax.Array, *, use_pallas: bool = False) -> tuple[jax.Array, jax.Array]:
    """One FD shrink of a full ``(2l, d)`` buffer.

    Returns ``(new_buf, delta)`` where ``new_buf`` has at most ``l`` non-zero
    rows (sorted by decreasing singular value) and ``delta`` is the shrink
    threshold ``sigma_l^2`` removed from every retained direction.
    """
    two_l, _ = buf.shape
    l = two_l // 2
    g = _gram(buf.astype(jnp.float32), use_pallas)
    # eigh: ascending eigenvalues.  Flip to descending.
    lam, u = jnp.linalg.eigh(g)
    lam = lam[::-1]
    u = u[:, ::-1]
    lam = jnp.maximum(lam, 0.0)
    delta = lam[l]  # (l+1)-th largest (0-indexed l) — the shrink mass
    new_sq = jnp.maximum(lam - delta, 0.0)
    # w_i = sqrt(new_sq_i / lam_i); safe where lam ~ 0 (row becomes zero).
    w = jnp.sqrt(new_sq / jnp.maximum(lam, 1e-30))
    w = jnp.where(lam > 1e-30, w, 0.0)
    new_buf = _project(w, u, buf.astype(jnp.float32), use_pallas).astype(buf.dtype)
    return new_buf, delta


def _fd_update_impl(state: FDState, chunk: jax.Array, *, use_pallas: bool) -> FDState:
    l = state.l
    row_sq = jnp.sum(chunk.astype(jnp.float32) ** 2, axis=1)
    buf = state.buf.at[l:].set(chunk.astype(state.buf.dtype))
    new_buf, delta = fd_shrink(buf, use_pallas=use_pallas)
    return FDState(
        buf=new_buf,
        frob=state.frob + jnp.sum(row_sq),
        delta_sum=state.delta_sum + delta,
        n_seen=state.n_seen + jnp.sum(row_sq > 0).astype(jnp.int32),
    )


def fd_update(state: FDState, chunk: jax.Array, *, use_pallas: bool = False) -> FDState:
    """Absorb a chunk of exactly ``l`` rows (zero-pad short chunks).

    Zero rows are free: they do not perturb the sketch and are excluded from
    ``frob`` / ``n_seen`` automatically (norm 0, count via non-zero test).
    The jitted callable is cached on ``(l, d, dtype, use_pallas)`` so every
    same-shape tenant shares one trace.
    """
    l = state.l
    if chunk.shape != (l, state.d):
        raise ValueError(f"fd_update wants a ({l}, {state.d}) chunk, got {chunk.shape}")
    fn = _cached_jit(
        ("update", l, state.d, str(state.buf.dtype), bool(use_pallas)),
        lambda: jax.jit(functools.partial(_fd_update_impl, use_pallas=use_pallas)),
    )
    return fn(state, chunk)


def _fd_stream_impl(state: FDState, chunks: jax.Array, *, use_pallas: bool) -> FDState:
    def body(st, ch):
        return _fd_update_impl(st, ch, use_pallas=use_pallas), None

    state, _ = jax.lax.scan(body, state, chunks)
    return state


def fd_update_stream(state: FDState, rows: jax.Array, *, use_pallas: bool = False) -> FDState:
    """Absorb ``(n, d)`` rows via a scan of l-row chunks (n padded up).

    The jitted scan is cached on ``(l, d, dtype, use_pallas, n_chunks)`` —
    per-tenant ingest of a common batch shape stops re-tracing per tenant.
    """
    l, d = state.l, state.d
    n = rows.shape[0]
    n_chunks = -(-n // l)
    pad = n_chunks * l - n
    rows = jnp.pad(rows, ((0, pad), (0, 0)))
    chunks = rows.reshape(n_chunks, l, d)
    fn = _cached_jit(
        ("stream", l, d, str(state.buf.dtype), bool(use_pallas), n_chunks),
        lambda: jax.jit(functools.partial(_fd_stream_impl, use_pallas=use_pallas)),
    )
    return fn(state, chunks)


def fd_merge(a: FDState, b: FDState, *, use_pallas: bool = False) -> FDState:
    """Merge two sketches (mergeable-summaries property, used by protocol P1).

    Stacks the <=l live rows of each into one 2l buffer and shrinks once.
    Error bounds add: delta_sum_merged <= delta_a + delta_b + delta_shrink.
    """
    l = a.l
    if b.l != l or b.d != a.d:
        raise ValueError("fd_merge requires identically-shaped sketches")
    buf = jnp.concatenate([a.buf[:l], b.buf[:l]], axis=0)
    new_buf, delta = fd_shrink(buf, use_pallas=use_pallas)
    return FDState(
        buf=new_buf,
        frob=a.frob + b.frob,
        delta_sum=a.delta_sum + b.delta_sum + delta,
        n_seen=a.n_seen + b.n_seen,
    )


def fd_query(state: FDState, x: jax.Array) -> jax.Array:
    """``||B x||^2`` — the paper's tracked quantity, for unit direction x."""
    return jnp.sum(jnp.matmul(state.buf, x, preferred_element_type=jnp.float32) ** 2, axis=0)


def fd_matrix(state: FDState) -> jax.Array:
    """The sketch matrix B (l x d): the live rows of the buffer."""
    return state.buf[: state.l]


# ---------------------------------------------------------------------------
# Numpy oracle — exact item-at-a-time semantics for the event-driven engine.
# ---------------------------------------------------------------------------


class FDSketch:
    """Plain-numpy Frequent Directions with per-item conditional shrink.

    This is the paper's algorithm verbatim: rows are appended one at a time
    into the first empty slot; when the buffer fills, shrink.  Used as the
    oracle for the JAX implementation and as the site/coordinator sketch in
    the event-driven protocol engine.
    """

    def __init__(self, l: int, d: int):
        self.l = l
        self.d = d
        self.buf = np.zeros((2 * l, d), np.float64)
        self.fill = 0
        self.frob = 0.0
        self.delta_sum = 0.0
        self.n_seen = 0

    def append(self, row: np.ndarray) -> None:
        """Absorb one stream row (shrinks when the buffer fills)."""
        if self.fill == self.buf.shape[0]:
            self._shrink()
        self.buf[self.fill] = row
        self.fill += 1
        self.frob += float(row @ row)
        self.n_seen += 1

    def extend(self, rows: np.ndarray) -> None:
        # Vectorized fast path: fill in slabs, shrink when full.
        """Absorb an (n, d) batch of rows."""
        i = 0
        n = rows.shape[0]
        self.frob += float(np.sum(rows * rows))
        self.n_seen += n
        while i < n:
            if self.fill == self.buf.shape[0]:
                self._shrink()
            take = min(n - i, self.buf.shape[0] - self.fill)
            self.buf[self.fill : self.fill + take] = rows[i : i + take]
            self.fill += take
            i += take

    def _shrink(self) -> None:
        g = self.buf @ self.buf.T
        lam, u = np.linalg.eigh(g)
        lam = np.maximum(lam[::-1], 0.0)
        u = u[:, ::-1]
        delta = lam[self.l]
        w = np.sqrt(np.maximum(lam - delta, 0.0) / np.maximum(lam, 1e-300))
        w[lam <= 1e-300] = 0.0
        self.buf = (w[:, None] * (u.T @ self.buf))
        self.delta_sum += float(delta)
        self.fill = self.l

    def matrix(self) -> np.ndarray:
        """Current sketch rows (fill x d)."""
        return self.buf[: self.fill]

    def query(self, x: np.ndarray) -> float:
        """``||B x||^2`` — the sketch's estimate of ``||A x||^2``."""
        v = self.buf[: self.fill] @ x
        return float(v @ v)

    def merge(self, other: "FDSketch") -> None:
        """Fold another FD sketch in (mergeable-summaries merge)."""
        self.extend(other.matrix())
        # extend() already added other's frob/n via rows; but rows of a sketch
        # under-count the true stream mass — correct with other's bookkeeping.
        self.frob += other.frob - float(np.sum(other.matrix() ** 2))
        self.n_seen += other.n_seen - other.matrix().shape[0]
        self.delta_sum += other.delta_sum

    def covariance_error(self, a: np.ndarray) -> float:
        """``||A^T A - B^T B||_2 / ||A||_F^2`` — the paper's err metric."""
        b = self.matrix()
        m = a.T @ a - b.T @ b
        return float(np.linalg.norm(m, 2) / max(np.sum(a * a), 1e-300))

    # -- persistence ---------------------------------------------------------

    def state_dict(self) -> dict:
        """JSON-able snapshot of the sketch (exact float round-trip).

        The checkpoint convention every sketch in ``core`` follows
        (``MGSketch``, ``QuantileSummary``): streams that embed an
        ``FDSketch`` persist it through this, so a future field change
        cannot silently miss an out-of-module serializer.
        """
        return {
            "buf": self.buf.tolist(),
            "fill": self.fill,
            "frob": self.frob,
            "delta_sum": self.delta_sum,
            "n_seen": self.n_seen,
        }

    @classmethod
    def from_state(cls, state: dict, l: int, d: int) -> "FDSketch":
        """Rebuild a sketch from ``state_dict`` output (state identity)."""
        fd = cls(l, d)
        fd.buf = np.asarray(state["buf"], np.float64)
        fd.fill = int(state["fill"])
        fd.frob = float(state["frob"])
        fd.delta_sum = float(state["delta_sum"])
        fd.n_seen = int(state["n_seen"])
        return fd
