"""Core: the paper's contribution — continuous distributed matrix tracking.

Layers:
  * fd.py          — Frequent Directions sketch (JAX + numpy oracle)
  * hh.py          — weighted Misra--Gries / SpaceSaving
  * quantiles.py   — mergeable GK-style quantile summaries + protocols
  * leverage.py    — streaming ridge leverage scores + row-sampling protocols
  * sampling.py    — priority sampling (Duffield--Lund--Thorup)
  * protocols.py   — event-driven engine: HH P1-P4, matrix P1-P4 (paper-exact)
  * distributed.py — TPU shard_map super-step engine: matrix P1/P2/P3,
                     HH P1, quantile P1, leverage P1
  * tracker.py     — continuous tracking facade for training integration
  * windows.py     — time as a dimension: bucketed sliding windows +
                     exponential decay over the mergeable sketch states
"""
from repro.core.fd import (
    FDSketch,
    FDState,
    fd_init,
    fd_matrix,
    fd_merge,
    fd_query,
    fd_shrink,
    fd_update,
    fd_update_stream,
)
from repro.core.comm import CommReport
from repro.core.hh import MGSketch, MGState, SpaceSaving, mg_init, mg_merge, mg_update
from repro.core.leverage import (
    LeverageP1Stream,
    LeverageP2Stream,
    run_leverage_protocol,
)
from repro.core.quantiles import (
    QuantileP1Stream,
    QuantileP3Stream,
    QuantileSummary,
    run_quantile_protocol,
)
from repro.core.protocols import (
    CommLog,
    HHResult,
    MatrixResult,
    run_hh_protocol,
    run_matrix_protocol,
)
from repro.core.distributed import ProtocolConfig, make_protocol_runner
from repro.core.tracker import DistributedMatrixTracker
from repro.core.windows import (
    ExponentialDecay,
    LateRowError,
    SlidingWindow,
    TimedRows,
    WatermarkTracker,
)
