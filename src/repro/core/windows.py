"""Time as a first-class dimension: sliding windows + exponential decay.

The paper tracks ``||Ax||^2`` over the whole stream; serving traffic asks
"what does A look like over the last hour".  Both time restrictions reduce
to the SAME mergeable-summary algebra the four protocol kinds already
ship:

* **Sliding window** — event time is cut into ``buckets`` equal-width
  buckets per window.  Each bucket holds an independent jit sketch state
  (FD / MG / GK / leverage reservoir), fed only the rows whose timestamp
  lands in it.  Serving folds the live buckets with the existing merge
  identities (``fd_merge`` / ``mg_merge`` / ``quant_merge`` /
  ``lev_merge``); advancing the watermark past a bucket's trailing edge
  drops it wholesale.  The served answer covers at most one bucket width
  more than the exact window — the standard bucketed-window slack — while
  per-bucket error bounds add across disjoint row sets, so the merged
  answer keeps the certified eps envelope over the in-window rows.

* **Exponential decay** — a single state per site, aged with
  *scale-then-insert*: before absorbing a batch at time ``t`` the state is
  scaled so every resident row's contribution is worth
  ``gamma**(t - t_i)``.  Scaling is exact on all four states because each
  is (piecewise) linear in its mass: FD buffers scale by ``sqrt(g)``
  (quadratic forms scale by ``g``), MG counts, GK rank bounds and
  reservoir scores scale by ``g`` directly.

Both wrappers sit behind one watermark/ordering layer (``_TimedSketch``):
rows arrive as ``(batch, ts)``, are parked until the watermark
(``max_ts - lateness``) passes them, and are applied in ``(ts, seq)``
order — so any arrival order within the allowed lateness produces a
bit-identical state sequence.  Rows later than the watermark raise
``LateRowError`` (counted, never silently dropped); the runtime routes
them through its shed/report path.

Everything here is host-side orchestration over the jit states; no new
kernels.  ``runtime/windowed.py`` adapts these wrappers to the registry's
four protocol ABCs.
"""
from __future__ import annotations

import math
from typing import Any, Callable, NamedTuple

import numpy as np

__all__ = [
    "TimedRows",
    "LateRowError",
    "WatermarkTracker",
    "WindowOps",
    "fd_window_ops",
    "mg_window_ops",
    "quant_window_ops",
    "lev_window_ops",
    "LevWindowState",
    "SlidingWindow",
    "ExponentialDecay",
]


class TimedRows(NamedTuple):
    """A rows payload stamped with one event time.

    Rides any existing ``rows`` seam unchanged (``Ingest.rows`` envelopes,
    ``StreamingPipeline.ingest``): consumers unwrap it at the adapter
    boundary, so cluster cells, replication, and checkpoint plumbing never
    need to know about time.
    """

    rows: Any
    ts: float


class LateRowError(ValueError):
    """A batch arrived later than the watermark allows.

    Carries enough to account for the shed: the runtime increments its
    late-row counters from these fields before re-raising/reporting.
    """

    def __init__(self, ts: float, watermark: float, n_rows: int):
        self.ts = float(ts)
        self.watermark = float(watermark)
        self.n_rows = int(n_rows)
        super().__init__(
            f"late batch: ts={self.ts} behind watermark={self.watermark} "
            f"({self.n_rows} rows shed)"
        )


class WatermarkTracker:
    """Bounded out-of-order tolerance: ``watermark = max_ts - lateness``.

    Rows at or ahead of the watermark are parked and applied in event-time
    order once the watermark passes them; rows strictly behind it are late.
    """

    def __init__(self, lateness: float = 0.0):
        lateness = float(lateness)
        if not (math.isfinite(lateness) and lateness >= 0.0):
            raise ValueError(f"lateness must be finite and >= 0, got {lateness}")
        self.lateness = lateness
        self.max_ts = -math.inf

    @property
    def watermark(self) -> float:
        return self.max_ts - self.lateness

    def observe(self, ts: float) -> None:
        ts = float(ts)
        if ts > self.max_ts:
            self.max_ts = ts

    def is_late(self, ts: float) -> bool:
        return float(ts) < self.watermark


class WindowOps(NamedTuple):
    """The per-kind algebra a time wrapper needs, nothing more.

    ``init`` builds the merge identity, ``insert`` folds an (already
    validated) numpy batch, ``merge`` is the kind's mergeable-summary
    fold, ``scale`` multiplies every resident row's mass contribution by
    ``g`` (exact on all four states).  ``state_rows`` is the sketch-rows
    size of one state — the unit the comm accounting charges when a
    state ships to the coordinator.
    """

    init: Callable[[], Any]
    insert: Callable[[Any, np.ndarray], Any]
    merge: Callable[[Any, Any], Any]
    scale: Callable[[Any, float], Any]
    state_rows: int


def fd_window_ops(l: int, d: int) -> WindowOps:
    """FD algebra: quadratic in the buffer, so mass scales via sqrt(g)."""
    import jax.numpy as jnp

    from repro.core import fd

    def insert(st, arr):
        return fd.fd_update_stream(st, jnp.asarray(arr, jnp.float32))

    def scale(st, g):
        g = jnp.float32(g)
        return st._replace(
            buf=st.buf * jnp.sqrt(g), frob=st.frob * g, delta_sum=st.delta_sum * g
        )

    return WindowOps(lambda: fd.fd_init(l, d), insert, fd.fd_merge, scale, l)


def mg_window_ops(k: int) -> WindowOps:
    """Misra-Gries algebra: counts, total weight and the shrink error
    certificate are all linear in mass."""
    import jax.numpy as jnp

    from repro.core import hh

    def insert(st, arr):
        keys = jnp.asarray(arr[:, 0], jnp.int32)
        weights = jnp.asarray(arr[:, 1], jnp.float32)
        return hh.mg_update_stream(st, keys, weights)

    def scale(st, g):
        g = jnp.float32(g)
        return st._replace(
            counts=st.counts * g, weight=st.weight * g, shrink=st.shrink * g
        )

    return WindowOps(lambda: hh.mg_init(k), insert, hh.mg_merge, scale, k)


def quant_window_ops(eps: float, cap: int) -> WindowOps:
    """GK-summary algebra at an internal ``eps`` budget: rank lower
    bounds, gap certificates and item weights are all linear in mass."""
    import jax.numpy as jnp

    from repro.core import quantiles as q

    def insert(st, arr):
        return q.quant_insert(
            st,
            jnp.asarray(arr[:, 0], jnp.float32),
            jnp.asarray(arr[:, 1], jnp.float32),
            eps,
        )

    def merge(a, b):
        return q.quant_merge(a, b, eps, cap)

    def scale(st, g):
        g = jnp.float32(g)
        return st._replace(
            g=st.g * g, delta=st.delta * g, wv=st.wv * g, weight=st.weight * g
        )

    return WindowOps(lambda: q.quant_init(cap), insert, merge, scale, cap)


class LevWindowState(NamedTuple):
    """Leverage reservoir + FD residual + exact mass counter.

    The reservoir alone cannot serve a time-restricted eps envelope: rows
    spilled on overflow would lose their mass.  Exactly like the event P1
    stream, every spilled row folds into an FD residual sketch, and the
    served table is kept rows (exact) + residual FD rows — inheriting the
    FD envelope on whatever the reservoir dropped.
    """

    lev: Any
    resid: Any
    mass: Any


def lev_window_ops(cap: int, d: int, l_resid: int) -> WindowOps:
    """Leverage algebra: norm-scored reservoir with an FD spill residual.

    Window mode keeps every row at weight 1; decay bakes the age factor
    into the row payload itself (``rows *= sqrt(g)``), so spilled rows are
    always correctly scaled for the residual FD fold and the served
    ``sum_i w_i (a_i . x)^2`` ages exactly.
    """
    import jax.numpy as jnp

    from repro.core import fd
    from repro.core import leverage as lev

    def init():
        return LevWindowState(
            lev=lev.lev_init(cap, d),
            resid=fd.fd_init(l_resid, d),
            mass=jnp.float32(0.0),
        )

    def insert(st, arr):
        rows = jnp.asarray(arr, jnp.float32)
        scores = jnp.sum(rows * rows, axis=1)
        weights = jnp.where(scores > 0.0, 1.0, 0.0).astype(jnp.float32)
        new_lev, spilled = lev.lev_merge_spill(st.lev, rows, scores, weights)
        return LevWindowState(
            lev=new_lev,
            resid=fd.fd_update_stream(st.resid, spilled),
            mass=st.mass + jnp.sum(scores),
        )

    def merge(a, b):
        new_lev, spilled = lev.lev_merge_spill(
            a.lev, b.lev.rows, b.lev.scores, b.lev.weights
        )
        resid = fd.fd_merge(a.resid, b.resid)
        return LevWindowState(
            lev=new_lev,
            resid=fd.fd_update_stream(resid, spilled),
            mass=a.mass + b.mass,
        )

    def scale(st, g):
        g = jnp.float32(g)
        root = jnp.sqrt(g)
        return LevWindowState(
            lev=st.lev._replace(rows=st.lev.rows * root, scores=st.lev.scores * g),
            resid=st.resid._replace(
                buf=st.resid.buf * root,
                frob=st.resid.frob * g,
                delta_sum=st.resid.delta_sum * g,
            ),
            mass=st.mass * g,
        )

    return WindowOps(init, insert, merge, scale, cap + l_resid)


def _batch_rows(batch: Any) -> int:
    if isinstance(batch, tuple):
        batch = batch[0]
    return int(np.asarray(batch).shape[0])


def _site_slice(batch: np.ndarray, site: int, sites: int) -> np.ndarray:
    return batch[site::sites]


class _TimedSketch:
    """Watermark/ordering layer shared by both time wrappers.

    Batches are parked until the watermark passes their timestamp, then
    applied in ``(ts, arrival_seq)`` order — the property the
    out-of-order byte-identity tests pin.  ``epoch`` bumps whenever the
    applied state changes; callers key serve caches on it.
    """

    def __init__(self, ops: WindowOps, *, sites: int = 1, lateness: float = 0.0):
        self.ops = ops
        self.sites = max(1, int(sites))
        self.wm = WatermarkTracker(lateness)
        self._pending: list[tuple[float, int, np.ndarray]] = []
        self._seq = 0
        self.late_batches = 0
        self.late_rows = 0
        self.applied_batches = 0
        self.applied_rows = 0
        self.epoch = 0

    # -- kind-agnostic entry points -------------------------------------

    def insert(self, batch: np.ndarray, ts: float) -> None:
        ts = float(ts)
        if not math.isfinite(ts):
            raise ValueError(f"event time must be finite, got {ts}")
        if self.wm.is_late(ts):
            n = _batch_rows(batch)
            self.late_batches += 1
            self.late_rows += n
            raise LateRowError(ts, self.wm.watermark, n)
        self.wm.observe(ts)
        self._pending.append((ts, self._seq, batch))
        self._seq += 1
        self._drain()

    def advance(self, ts: float) -> None:
        """Heartbeat: move the watermark without new rows (closes buckets
        whose boundary it passes)."""
        self.wm.observe(float(ts))
        self._drain()

    @property
    def lag(self) -> float:
        """How far the oldest parked batch trails event time (0 if none)."""
        if not self._pending:
            return 0.0
        return self.wm.max_ts - min(p[0] for p in self._pending)

    # -- machinery -------------------------------------------------------

    def _drain(self) -> None:
        wm = self.wm.watermark
        if wm == -math.inf:
            return
        due = [p for p in self._pending if p[0] <= wm]
        if due:
            due.sort(key=lambda p: (p[0], p[1]))
            self._pending = [p for p in self._pending if p[0] > wm]
            for ts, _, batch in due:
                self._apply(batch, ts)
                self.applied_batches += 1
                self.applied_rows += _batch_rows(batch)
            self.epoch += 1
        self._on_advance(wm)

    def _apply(self, batch: np.ndarray, ts: float) -> None:
        raise NotImplementedError

    def _on_advance(self, wm: float) -> None:
        pass

    def windows_closed(self) -> int:
        return 0

    def serve(self) -> Any:
        raise NotImplementedError


class SlidingWindow(_TimedSketch):
    """Bucketed sliding window over one ``WindowOps`` algebra.

    Event time is cut into buckets of width ``window / buckets``; bucket
    ``b`` covers ``[b*width, (b+1)*width)``.  Serving folds every live
    bucket (times ``sites`` software partitions) with ``ops.merge``;
    advancing the watermark drops buckets that fell entirely behind
    ``watermark - window`` and counts each bucket boundary the watermark
    crosses as a closed window (the ``OnWindowClose`` publish signal).
    """

    def __init__(
        self,
        ops: WindowOps,
        *,
        window: float,
        buckets: int = 8,
        sites: int = 1,
        lateness: float = 0.0,
    ):
        super().__init__(ops, sites=sites, lateness=lateness)
        window = float(window)
        buckets = int(buckets)
        if not (math.isfinite(window) and window > 0.0):
            raise ValueError(f"window must be finite and > 0, got {window}")
        if buckets < 1:
            raise ValueError(f"buckets must be >= 1, got {buckets}")
        self.window = window
        self.buckets = buckets
        self.width = window / buckets
        self._states: dict[int, list] = {}
        self._closed = 0
        self._last_marker: int | None = None

    def _apply(self, batch: np.ndarray, ts: float) -> None:
        b = math.floor(ts / self.width)
        states = self._states.get(b)
        if states is None:
            states = [self.ops.init() for _ in range(self.sites)]
            self._states[b] = states
        if self.sites == 1:
            states[0] = self.ops.insert(states[0], batch)
            return
        for s in range(self.sites):
            part = _site_slice(batch, s, self.sites)
            if part.shape[0]:
                states[s] = self.ops.insert(states[s], part)

    def _on_advance(self, wm: float) -> None:
        cutoff = wm - self.window
        dead = [b for b in self._states if (b + 1) * self.width <= cutoff]
        for b in dead:
            del self._states[b]
        if dead:
            self.epoch += 1
        marker = math.floor(wm / self.width)
        if self._last_marker is None:
            self._last_marker = marker
        elif marker > self._last_marker:
            self._closed += marker - self._last_marker
            self._last_marker = marker

    def windows_closed(self) -> int:
        return self._closed

    def live_states(self) -> int:
        return len(self._states) * self.sites

    def serve(self) -> Any:
        acc = None
        for b in sorted(self._states):
            for st in self._states[b]:
                acc = st if acc is None else self.ops.merge(acc, st)
        return self.ops.init() if acc is None else acc


class ExponentialDecay(_TimedSketch):
    """Scale-then-insert exponential decay over one ``WindowOps`` algebra.

    One state per site; absorbing a batch at time ``t`` first scales the
    states by ``gamma ** (t - ref_ts)`` so every resident row is worth
    ``gamma ** age``.  The watermark layer guarantees applies happen in
    event-time order, so ``ref_ts`` only moves forward.
    """

    def __init__(
        self,
        ops: WindowOps,
        *,
        gamma: float | None = None,
        half_life: float | None = None,
        sites: int = 1,
        lateness: float = 0.0,
    ):
        super().__init__(ops, sites=sites, lateness=lateness)
        if (gamma is None) == (half_life is None):
            raise ValueError("pass exactly one of gamma / half_life")
        if half_life is not None:
            half_life = float(half_life)
            if not (math.isfinite(half_life) and half_life > 0.0):
                raise ValueError(f"half_life must be > 0, got {half_life}")
            gamma = 0.5 ** (1.0 / half_life)
        gamma = float(gamma)
        if not (0.0 < gamma < 1.0):
            raise ValueError(f"gamma must be in (0, 1), got {gamma}")
        self.gamma = gamma
        self._states = [ops.init() for _ in range(self.sites)]
        self.ref_ts: float | None = None

    def _apply(self, batch: np.ndarray, ts: float) -> None:
        if self.ref_ts is None:
            self.ref_ts = ts
        elif ts > self.ref_ts:
            g = self.gamma ** (ts - self.ref_ts)
            self._states = [self.ops.scale(st, g) for st in self._states]
            self.ref_ts = ts
        if self.sites == 1:
            self._states[0] = self.ops.insert(self._states[0], batch)
            return
        for s in range(self.sites):
            part = _site_slice(batch, s, self.sites)
            if part.shape[0]:
                self._states[s] = self.ops.insert(self._states[s], part)

    def live_states(self) -> int:
        return self.sites

    def serve(self) -> Any:
        acc = self._states[0]
        for st in self._states[1:]:
            acc = self.ops.merge(acc, st)
        return acc
