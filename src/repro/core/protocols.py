"""Event-driven distributed-streaming protocols — the paper, verbatim.

This module implements the paper's protocols with their exact item-at-a-time
message semantics on one host (sites are simulated).  It is the *fidelity*
engine: benchmarks reproduce the paper's figures with it, and the TPU
production path (``core/distributed.py``) is validated against it.

Weighted heavy hitters (Section 4):
    * ``HHP1`` — batched Misra--Gries merge            O((m/eps^2) log(beta N))
    * ``HHP2`` — Yi--Zhang thresholds                  O((m/eps)   log(beta N))
    * ``HHP3`` — priority sampling (wor / wr)          O((m+s) log(beta N / s))
    * ``HHP4`` — Huang-et-al probabilistic sends       O((sqrt m/eps) log(beta N))

Matrix tracking (Section 5):
    * ``MP1``  — batched Frequent Directions merge     O((m/eps^2) log(beta N)) rows
    * ``MP2``  — per-direction SVD thresholds          O((m/eps)   log(beta N)) rows
    * ``MP3``  — priority row sampling (wor / wr)      O((m+s) log(beta N / s)) rows
    * ``MP4``  — Appendix C negative result (implemented to reproduce failure)

Message accounting follows the paper: a message is one d-dimensional row (or
one element/scalar pair); a sketch of r rows costs r messages; a coordinator
broadcast costs m messages.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np

from repro.core.comm import CommReport, build_report
from repro.core.fd import FDSketch
from repro.core.hh import MGSketch

__all__ = [
    "CommLog",
    "HHResult",
    "MatrixResult",
    "run_hh_protocol",
    "run_matrix_protocol",
    "HH_PROTOCOLS",
    "HH_STREAMS",
    "MATRIX_PROTOCOLS",
    "MATRIX_STREAMS",
]


def _rng_state(rng: np.random.Generator) -> dict:
    """JSON-able PRNG state (PCG64 state ints serialize losslessly)."""
    return rng.bit_generator.state


def _rng_from_state(state: dict) -> np.random.Generator:
    rng = np.random.default_rng(0)
    rng.bit_generator.state = state
    return rng


@dataclass
class CommLog:
    """Counts messages with the paper's units."""

    scalar_msgs: int = 0  # (total, W_i)-style scalar messages, site -> C
    item_msgs: int = 0  # element/row messages, site -> C
    sketch_rows: int = 0  # rows shipped inside sketch sends, site -> C
    broadcast_events: int = 0  # coordinator -> all sites (each costs m)

    def total(self, m: int) -> int:
        """Total message cost in the paper's units (broadcasts cost m each)."""
        return (
            self.scalar_msgs
            + self.item_msgs
            + self.sketch_rows
            + self.broadcast_events * m
        )

    def report(self, m: int) -> CommReport:
        """Collapse to the engine-agnostic report (item + sketch rows unify)."""
        return build_report(
            scalar_msgs=self.scalar_msgs,
            row_msgs=self.item_msgs + self.sketch_rows,
            broadcast_events=self.broadcast_events,
            m=m,
        )


@dataclass
class HHResult:
    """Coordinator HH answer: estimate map, total weight, message costs."""
    estimates: dict[int, float]
    w_hat: float
    comm: CommLog
    m: int
    eps: float

    def heavy_hitters(self, phi: float) -> list[int]:
        """Return e iff hat{W}_e >= (phi - eps/2) hat{W} (paper Section 4)."""
        from repro.core.hh import threshold_heavy_hitters

        return threshold_heavy_hitters(self.estimates, self.w_hat, self.eps, phi)


@dataclass
class MatrixResult:
    """Coordinator matrix answer: sketch B, mass estimate, message costs."""
    b: np.ndarray  # the coordinator's sketch matrix
    f_hat: float
    comm: CommLog
    m: int
    eps: float

    def covariance_error(self, ata: np.ndarray, frob: float) -> float:
        """``||A^T A - B^T B||_2 / ||A||_F^2`` (paper's err metric)."""
        btb = self.b.T @ self.b
        return float(np.linalg.norm(ata - btb, 2) / max(frob, 1e-300))


# ---------------------------------------------------------------------------
# Weighted heavy hitters — resumable stream engines + one-shot wrappers
#
# Mirrors the matrix section below: each protocol is a class with
# ``step(keys, weights, sites)`` (absorb a batch, continuing the
# event-at-a-time semantics exactly where the last batch left off) and
# ``result()`` (the coordinator's current HHResult, callable at any time).
# Every stream also implements ``state_dict()`` / ``load_state()`` — a
# JSON-able snapshot of its full coordinator+site state — so HH tenants
# survive a ``StreamingPipeline`` checkpoint/restart bit-identically.
# The ``_hh_pX`` one-shot wrappers reproduce the historical draw sequences
# (a single whole-stream ``step`` call is the old code path, verbatim).
# ---------------------------------------------------------------------------


def _comm_state(comm: CommLog) -> dict:
    return {
        "scalar_msgs": comm.scalar_msgs,
        "item_msgs": comm.item_msgs,
        "sketch_rows": comm.sketch_rows,
        "broadcast_events": comm.broadcast_events,
    }


def _comm_from_state(state: dict) -> CommLog:
    return CommLog(**{k: int(v) for k, v in state.items()})


class HHP1Stream:
    """HH P1: per-site MG_{eps/2}, batched sketch shipping + MG merge."""

    def __init__(self, m, eps, rng=None, k=None):
        if k is None:
            k = max(2, math.ceil(2.0 / eps))  # MG_{eps/2}: err <= (eps/2) W
        self.m, self.eps, self.k = m, eps, k
        self.comm = CommLog()
        self.site_mg = [MGSketch(k) for _ in range(m)]
        self.site_w = [0.0] * m
        self.coord = MGSketch(k)
        self.w_c = 0.0
        self.w_hat = 1.0

    def step(self, keys, weights, sites) -> None:
        """Absorb a batch, continuing the event-at-a-time semantics exactly where the last
        batch left off."""
        m, eps = self.m, self.eps
        for e, w, j in zip(keys.tolist(), weights.tolist(), sites.tolist()):
            mg = self.site_mg[j]
            mg.update(e, w)
            self.site_w[j] += w
            if self.site_w[j] >= (eps / (2 * m)) * self.w_hat:
                self.comm.sketch_rows += len(mg.counters)
                self.comm.scalar_msgs += 1
                self.coord.merge(mg)
                self.w_c += self.site_w[j]
                self.site_mg[j] = MGSketch(self.k)
                self.site_w[j] = 0.0
                if self.w_c / self.w_hat > 1.0 + eps / 2.0:
                    self.w_hat = self.w_c
                    self.comm.broadcast_events += 1

    def result(self) -> HHResult:
        """The coordinator's current answer (callable at any time)."""
        return HHResult(self.coord.items(), self.w_hat, self.comm, self.m, self.eps)

    def state_dict(self) -> dict:
        """JSON-able snapshot of the full coordinator + site state."""
        return {
            "k": self.k,
            "site_mg": [mg.state_dict() for mg in self.site_mg],
            "site_w": list(self.site_w),
            "coord": self.coord.state_dict(),
            "w_c": self.w_c,
            "w_hat": self.w_hat,
            "comm": _comm_state(self.comm),
        }

    def load_state(self, state: dict) -> None:
        """Restore ``state_dict`` output bit-identically."""
        self.k = int(state["k"])
        self.site_mg = [MGSketch.from_state(s) for s in state["site_mg"]]
        self.site_w = [float(w) for w in state["site_w"]]
        self.coord = MGSketch.from_state(state["coord"])
        self.w_c = float(state["w_c"])
        self.w_hat = float(state["w_hat"])
        self.comm = _comm_from_state(state["comm"])


def _hh_p1(keys, weights, sites, m, eps, rng) -> HHResult:
    eng = HHP1Stream(m, eps, rng)
    eng.step(keys, weights, sites)
    return eng.result()


class HHP2Stream:
    """HH P2 (Yi--Zhang): scalar total + per-element delta thresholds."""

    def __init__(self, m, eps, rng=None):
        self.m, self.eps = m, eps
        self.comm = CommLog()
        self.site_w = [0.0] * m
        self.site_delta: list[dict[int, float]] = [dict() for _ in range(m)]
        self.w_hat = 1.0
        self.n_msg = 0
        self.est: dict[int, float] = {}
        self.thresh = (eps / m) * self.w_hat

    def step(self, keys, weights, sites) -> None:
        """Absorb a batch, continuing the event-at-a-time semantics exactly where the last
        batch left off."""
        m, eps = self.m, self.eps
        for e, w, j in zip(keys.tolist(), weights.tolist(), sites.tolist()):
            self.site_w[j] += w
            d = self.site_delta[j]
            d[e] = d.get(e, 0.0) + w
            if self.site_w[j] >= self.thresh:
                self.comm.scalar_msgs += 1
                w_hat_c = self.site_w[j]
                self.site_w[j] = 0.0
                self.n_msg += 1
                self.w_hat += w_hat_c
                if self.n_msg >= m:
                    self.n_msg = 0
                    self.comm.broadcast_events += 1
                    self.thresh = (eps / m) * self.w_hat
            if d[e] >= self.thresh:
                self.comm.item_msgs += 1
                self.est[e] = self.est.get(e, 0.0) + d[e]
                d[e] = 0.0

    def result(self) -> HHResult:
        """The coordinator's current answer (callable at any time)."""
        return HHResult(dict(self.est), self.w_hat, self.comm, self.m, self.eps)

    def state_dict(self) -> dict:
        """JSON-able snapshot of the full coordinator + site state."""
        return {
            "site_w": list(self.site_w),
            # Flushed deltas are set to 0.0, not deleted; absent and zero are
            # indistinguishable to step(), so skip them — else a checkpoint
            # embeds every element ever seen per site.
            "site_delta": [
                {str(e): w for e, w in d.items() if w != 0.0} for d in self.site_delta
            ],
            "w_hat": self.w_hat,
            "n_msg": self.n_msg,
            "est": {str(e): w for e, w in self.est.items()},
            "thresh": self.thresh,
            "comm": _comm_state(self.comm),
        }

    def load_state(self, state: dict) -> None:
        """Restore ``state_dict`` output bit-identically."""
        self.site_w = [float(w) for w in state["site_w"]]
        self.site_delta = [
            {int(e): float(w) for e, w in d.items()} for d in state["site_delta"]
        ]
        self.w_hat = float(state["w_hat"])
        self.n_msg = int(state["n_msg"])
        self.est = {int(e): float(w) for e, w in state["est"].items()}
        self.thresh = float(state["thresh"])
        self.comm = _comm_from_state(state["comm"])


def _hh_p2(keys, weights, sites, m, eps, rng) -> HHResult:
    eng = HHP2Stream(m, eps, rng)
    eng.step(keys, weights, sites)
    return eng.result()


class HHP3Stream:
    """HH P3: distributed priority sampling without replacement."""

    def __init__(self, m, eps, rng, s=None):
        if s is None:
            s = max(8, math.ceil((1.0 / eps**2) * math.log(max(math.e, 1.0 / eps))))
        self.m, self.eps, self.s = m, eps, s
        self.rng = rng
        self.comm = CommLog()
        self.tau = 1.0
        self.q_cur: list[tuple[int, float, float]] = []  # (element, w, rho)
        self.q_next: list[tuple[int, float, float]] = []

    def step(self, keys, weights, sites) -> None:
        """Absorb a batch, continuing the event-at-a-time semantics exactly where the last
        batch left off."""
        n = len(keys)
        rho_all = weights / np.maximum(self.rng.uniform(size=n), 1e-300)
        for e, w, rho in zip(keys.tolist(), weights.tolist(), rho_all.tolist()):
            if rho >= self.tau:  # site-side check; one message
                self.comm.item_msgs += 1
                if rho >= 2.0 * self.tau:
                    self.q_next.append((e, w, rho))
                else:
                    self.q_cur.append((e, w, rho))
                if len(self.q_next) >= self.s:
                    self.tau *= 2.0
                    self.comm.broadcast_events += 1
                    self.q_cur = self.q_next
                    self.q_next = [t for t in self.q_cur if t[2] >= 2.0 * self.tau]
                    self.q_cur = [t for t in self.q_cur if t[2] < 2.0 * self.tau]

    def result(self) -> HHResult:
        """The coordinator's current answer (callable at any time)."""
        sample = self.q_cur + self.q_next
        est: dict[int, float] = {}
        if not sample:
            return HHResult(est, 0.0, self.comm, self.m, self.eps)
        sample = sorted(sample, key=lambda t: t[2])
        rho_hat = sample[0][2]
        kept = sample[1:] if len(sample) > 1 else sample
        w_hat = 0.0
        for e, w, _rho in kept:
            wbar = max(w, rho_hat)
            est[e] = est.get(e, 0.0) + wbar
            w_hat += wbar
        return HHResult(est, w_hat, self.comm, self.m, self.eps)

    def state_dict(self) -> dict:
        """JSON-able snapshot of the full coordinator + site state."""
        return {
            "s": self.s,
            "tau": self.tau,
            "q_cur": [list(t) for t in self.q_cur],
            "q_next": [list(t) for t in self.q_next],
            "rng": _rng_state(self.rng),
            "comm": _comm_state(self.comm),
        }

    def load_state(self, state: dict) -> None:
        """Restore ``state_dict`` output bit-identically."""
        self.s = int(state["s"])
        self.tau = float(state["tau"])
        self.q_cur = [(int(e), float(w), float(r)) for e, w, r in state["q_cur"]]
        self.q_next = [(int(e), float(w), float(r)) for e, w, r in state["q_next"]]
        self.rng = _rng_from_state(state["rng"])
        self.comm = _comm_from_state(state["comm"])


def _hh_p3(keys, weights, sites, m, eps, rng, s=None) -> HHResult:
    eng = HHP3Stream(m, eps, rng, s=s)
    eng.step(keys, weights, sites)
    return eng.result()


class HHP3wrStream:
    """HH P3 with replacement: s independent priority samplers.

    Uniform draws are blocked by ``min(n, 1 << 22) // s`` within each
    ``step`` call, so a single whole-stream step reproduces the historical
    one-shot draw sequence exactly.
    """

    def __init__(self, m, eps, rng, s=None):
        if s is None:
            s = max(8, math.ceil((1.0 / eps**2) * math.log(max(math.e, 1.0 / eps))))
        self.m, self.eps, self.s = m, eps, s
        self.rng = rng
        self.comm = CommLog()
        self.tau = 1.0
        self.top1_rho = np.zeros(s)  # highest priority per sampler
        self.top2_rho = np.zeros(s)  # second highest per sampler
        self.top1_elem = np.full(s, -1, np.int64)

    def step(self, keys, weights, sites) -> None:
        """Absorb a batch, continuing the event-at-a-time semantics exactly where the last
        batch left off."""
        s = self.s
        n = len(keys)
        block = max(1, min(n, 1 << 22) // max(s, 1) or 1)
        i = 0
        while i < n:
            hi = min(n, i + block)
            u = self.rng.uniform(size=(hi - i, s))
            rho = weights[i:hi, None] / np.maximum(u, 1e-300)
            send_any = rho >= self.tau
            for r in range(hi - i):
                hit = np.nonzero(send_any[r])[0]
                if hit.size == 0:
                    continue
                self.comm.item_msgs += int(hit.size)
                e = int(keys[i + r])
                rr = rho[r, hit]
                for t, p in zip(hit.tolist(), rr.tolist()):
                    if p > self.top1_rho[t]:
                        self.top2_rho[t] = self.top1_rho[t]
                        self.top1_rho[t] = p
                        self.top1_elem[t] = e
                    elif p > self.top2_rho[t]:
                        self.top2_rho[t] = p
                # Round ends when every sampler's 2nd priority is above 2*tau.
                if np.all(self.top2_rho > 2.0 * self.tau):
                    self.tau *= 2.0
                    self.comm.broadcast_events += 1
            i = hi

    def result(self) -> HHResult:
        """The coordinator's current answer (callable at any time)."""
        w_hat = float(np.mean(self.top2_rho))
        est: dict[int, float] = {}
        for t in range(self.s):
            e = int(self.top1_elem[t])
            if e >= 0:
                est[e] = est.get(e, 0.0) + w_hat / self.s
        return HHResult(est, w_hat, self.comm, self.m, self.eps)

    def state_dict(self) -> dict:
        """JSON-able snapshot of the full coordinator + site state."""
        return {
            "s": self.s,
            "tau": self.tau,
            "top1_rho": self.top1_rho.tolist(),
            "top2_rho": self.top2_rho.tolist(),
            "top1_elem": self.top1_elem.tolist(),
            "rng": _rng_state(self.rng),
            "comm": _comm_state(self.comm),
        }

    def load_state(self, state: dict) -> None:
        """Restore ``state_dict`` output bit-identically."""
        self.s = int(state["s"])
        self.tau = float(state["tau"])
        self.top1_rho = np.array(state["top1_rho"], np.float64)
        self.top2_rho = np.array(state["top2_rho"], np.float64)
        self.top1_elem = np.array(state["top1_elem"], np.int64)
        self.rng = _rng_from_state(state["rng"])
        self.comm = _comm_from_state(state["comm"])


def _hh_p3wr(keys, weights, sites, m, eps, rng, s=None) -> HHResult:
    eng = HHP3wrStream(m, eps, rng, s=s)
    eng.step(keys, weights, sites)
    return eng.result()


class HHP4Stream:
    """HH P4 (Huang et al.): send f_e(A_j) with prob 1 - exp(-p*w)."""

    def __init__(self, m, eps, rng):
        self.m, self.eps = m, eps
        self.rng = rng
        self.comm = CommLog()
        self.w_hat = 1.0  # sites' broadcast estimate; w_hat <= W_C <= 2*w_hat
        self.w_c = 1.0  # coordinator's running total
        self.p = 2.0 * math.sqrt(m) / (eps * self.w_hat)
        self.site_f: list[dict[int, float]] = [dict() for _ in range(m)]
        self.site_w = [0.0] * m
        # Last received (e, j) -> value; coordinator-side.
        self.recv: dict[tuple[int, int], float] = {}

    def step(self, keys, weights, sites) -> None:
        """Absorb a batch, continuing the event-at-a-time semantics exactly where the last
        batch left off."""
        m, eps = self.m, self.eps
        u_all = self.rng.uniform(size=len(keys))
        for idx, (e, w, j) in enumerate(zip(keys.tolist(), weights.tolist(), sites.tolist())):
            f = self.site_f[j]
            f[e] = f.get(e, 0.0) + w
            self.site_w[j] += w
            # Deterministic total-weight tracking (eps=1/2 Yi-Zhang totals);
            # the coordinator re-broadcasts w_hat each time its total doubles.
            if self.site_w[j] >= self.w_hat / (2 * m):
                self.comm.scalar_msgs += 1
                self.w_c += self.site_w[j]
                self.site_w[j] = 0.0
                if self.w_c >= 2.0 * self.w_hat:
                    self.w_hat = self.w_c
                    self.p = 2.0 * math.sqrt(m) / (eps * self.w_hat)
                    self.comm.broadcast_events += 1
            p_bar = 1.0 - math.exp(-self.p * w)
            if u_all[idx] <= p_bar:
                self.comm.item_msgs += 1
                self.recv[(e, j)] = f[e]

    def result(self) -> HHResult:
        """The coordinator's current answer (callable at any time)."""
        est: dict[int, float] = {}
        for (e, _j), v in self.recv.items():
            est[e] = est.get(e, 0.0) + v + 1.0 / self.p
        return HHResult(est, self.w_c, self.comm, self.m, self.eps)

    def state_dict(self) -> dict:
        """JSON-able snapshot of the full coordinator + site state."""
        return {
            "w_hat": self.w_hat,
            "w_c": self.w_c,
            "p": self.p,
            "site_f": [{str(e): w for e, w in f.items()} for f in self.site_f],
            "site_w": list(self.site_w),
            "recv": [[e, j, v] for (e, j), v in self.recv.items()],
            "rng": _rng_state(self.rng),
            "comm": _comm_state(self.comm),
        }

    def load_state(self, state: dict) -> None:
        """Restore ``state_dict`` output bit-identically."""
        self.w_hat = float(state["w_hat"])
        self.w_c = float(state["w_c"])
        self.p = float(state["p"])
        self.site_f = [{int(e): float(w) for e, w in f.items()} for f in state["site_f"]]
        self.site_w = [float(w) for w in state["site_w"]]
        self.recv = {(int(e), int(j)): float(v) for e, j, v in state["recv"]}
        self.rng = _rng_from_state(state["rng"])
        self.comm = _comm_from_state(state["comm"])


def _hh_p4(keys, weights, sites, m, eps, rng) -> HHResult:
    eng = HHP4Stream(m, eps, rng)
    eng.step(keys, weights, sites)
    return eng.result()


HH_PROTOCOLS = {
    "P1": _hh_p1,
    "P2": _hh_p2,
    "P3": _hh_p3,
    "P3wr": _hh_p3wr,
    "P4": _hh_p4,
}

# Resumable stream engines (init/step/result/state_dict) — the registry's
# event-engine HH entries.  Unlike the matrix family, P4 is a *positive*
# result for heavy hitters (Huang et al.), so all five are offered.
HH_STREAMS = {
    "P1": HHP1Stream,
    "P2": HHP2Stream,
    "P3": HHP3Stream,
    "P3wr": HHP3wrStream,
    "P4": HHP4Stream,
}


def run_hh_protocol(
    name: str,
    keys: np.ndarray,
    weights: np.ndarray,
    sites: np.ndarray,
    m: int,
    eps: float,
    seed: int = 0,
    **kw,
) -> HHResult:
    """One-shot wrapper: stream the whole feed through HH protocol ``name``."""
    rng = np.random.default_rng(seed)
    return HH_PROTOCOLS[name](keys, weights, sites, m, eps, rng, **kw)


# ---------------------------------------------------------------------------
# Matrix tracking — resumable stream engines + one-shot wrappers
#
# Each protocol is a small class with ``step(rows, sites)`` (absorb a batch,
# continuing the event-at-a-time semantics exactly where the last batch left
# off) and ``result()`` (the coordinator's current MatrixResult, callable at
# any time — this is the paper's "continuous" query surface).  The module
# level ``_mpX`` functions are one-shot wrappers kept for the benchmarks and
# figure scripts; ``repro.runtime.registry`` builds its event-engine entries
# from the stream classes.
# ---------------------------------------------------------------------------


class MP1Stream:
    """Matrix P1: per-site FD_{eps/2}, batched sketch shipping + FD merge."""

    def __init__(self, m, eps, d, rng, l=None):
        if l is None:
            l = max(2, math.ceil(4.0 / eps))  # FD err 2/l <= eps/2
        self.m, self.eps, self.d = m, eps, d
        self.comm = CommLog()
        self.site_fd = [FDSketch(l, d) for _ in range(m)]
        self.site_f = [0.0] * m
        self.l = l
        self.coord = FDSketch(l, d)
        self.f_c = 0.0
        self.f_hat = 1.0

    def step(self, rows, sites) -> None:
        """Absorb a batch, continuing the event-at-a-time semantics exactly where the last
        batch left off."""
        m, eps = self.m, self.eps
        row_sq = np.einsum("nd,nd->n", rows, rows)
        for i, j in enumerate(sites.tolist()):
            fd = self.site_fd[j]
            fd.append(rows[i])
            self.site_f[j] += float(row_sq[i])
            if self.site_f[j] >= (eps / (2 * m)) * self.f_hat:
                mat = fd.matrix()
                nz = mat[np.einsum("rd,rd->r", mat, mat) > 0]
                self.comm.sketch_rows += int(nz.shape[0])
                self.comm.scalar_msgs += 1
                self.coord.merge(fd)
                self.f_c += self.site_f[j]
                self.site_fd[j] = FDSketch(self.l, self.d)
                self.site_f[j] = 0.0
                if self.f_c / self.f_hat > 1.0 + eps / 2.0:
                    self.f_hat = self.f_c
                    self.comm.broadcast_events += 1

    def result(self) -> MatrixResult:
        """The coordinator's current answer (callable at any time)."""
        return MatrixResult(self.coord.matrix(), self.f_hat, self.comm, self.m, self.eps)


def _mp1(rows, sites, m, eps, rng, l=None) -> MatrixResult:
    eng = MP1Stream(m, eps, rows.shape[1], rng, l=l)
    eng.step(rows, sites)
    return eng.result()


class _MP2Site:
    """Site state for matrix P2: rank-<=d residual matrix + lazy SVD.

    The residual B_j is kept in factored form ``S`` (r x d, r <= d+buffer).
    An SVD is only computed when the cheap upper bound on sigma_1^2
    (last sigma_1^2 + Frobenius mass appended since) can cross the send
    threshold — this is exact, since appending rows raises sigma_1^2 by at
    most the appended squared-Frobenius mass.
    """

    def __init__(self, d: int):
        self.d = d
        self.dirs = np.zeros((0, d))  # sigma_i * v_i rows from last SVD
        self.pending: list[np.ndarray] = []
        self.sig1_sq = 0.0  # sigma_1^2 at last SVD
        self.pending_sq = 0.0

    def append(self, row: np.ndarray) -> None:
        # Copy: pending rows outlive the caller's batch buffer (stream use).
        """Buffer one row (Frobenius mass tracked for the lazy-SVD bound)."""
        self.pending.append(np.array(row, dtype=np.float64))
        self.pending_sq += float(row @ row)

    def maybe_send(self, thresh: float) -> list[np.ndarray]:
        """Ship every direction whose sigma^2 crosses ``thresh`` (lazy SVD)."""
        if self.sig1_sq + self.pending_sq < thresh:
            return []
        if self.pending:
            b = np.concatenate([self.dirs, np.stack(self.pending)], axis=0)
        else:
            b = self.dirs
        if b.shape[0] == 0:
            return []
        # svd: B = U diag(s) Vt
        _, s, vt = np.linalg.svd(b, full_matrices=False)
        send = s**2 >= thresh
        out = [s[i] * vt[i] for i in np.nonzero(send)[0]]
        keep = ~send
        self.dirs = s[keep, None] * vt[keep]
        self.pending = []
        self.pending_sq = 0.0
        self.sig1_sq = float(np.max(s[keep] ** 2)) if np.any(keep) else 0.0
        return out


class MP2Stream:
    """Matrix P2: the paper's best protocol — per-direction thresholds."""

    def __init__(self, m, eps, d, rng):
        self.m, self.eps, self.d = m, eps, d
        self.comm = CommLog()
        self.site = [_MP2Site(d) for _ in range(m)]
        self.site_f = [0.0] * m
        self.f_hat = 1.0
        self.n_msg = 0
        self.thresh = (eps / m) * self.f_hat
        self.coord_rows: list[np.ndarray] = []

    def step(self, rows, sites) -> None:
        """Absorb a batch, continuing the event-at-a-time semantics exactly where the last
        batch left off."""
        m, eps = self.m, self.eps
        row_sq = np.einsum("nd,nd->n", rows, rows)
        for i, j in enumerate(sites.tolist()):
            self.site_f[j] += float(row_sq[i])
            if self.site_f[j] >= self.thresh:
                self.comm.scalar_msgs += 1
                self.f_hat += self.site_f[j]
                self.site_f[j] = 0.0
                self.n_msg += 1
                if self.n_msg >= m:
                    self.n_msg = 0
                    self.comm.broadcast_events += 1
                    self.thresh = (eps / m) * self.f_hat
            st = self.site[j]
            st.append(rows[i])
            sent = st.maybe_send(self.thresh)
            if sent:
                self.comm.item_msgs += len(sent)
                self.coord_rows.extend(sent)

    def result(self) -> MatrixResult:
        """The coordinator's current answer (callable at any time)."""
        b = np.stack(self.coord_rows) if self.coord_rows else np.zeros((0, self.d))
        return MatrixResult(b, self.f_hat, self.comm, self.m, self.eps)


def _mp2(rows, sites, m, eps, rng) -> MatrixResult:
    eng = MP2Stream(m, eps, rows.shape[1], rng)
    eng.step(rows, sites)
    return eng.result()


class MP3Stream:
    """Matrix P3: priority row-sampling without replacement."""

    def __init__(self, m, eps, d, rng, s=None):
        if s is None:
            s = max(8, math.ceil((1.0 / eps**2) * math.log(max(math.e, 1.0 / eps))))
        self.m, self.eps, self.d, self.s = m, eps, d, s
        self.rng = rng
        self.comm = CommLog()
        self.tau = 1.0
        self.q_cur: list[tuple[np.ndarray, float, float]] = []  # (row, w, rho)
        self.q_next: list[tuple[np.ndarray, float, float]] = []

    def step(self, rows, sites) -> None:
        """Absorb a batch, continuing the event-at-a-time semantics exactly where the last
        batch left off."""
        w_all = np.einsum("nd,nd->n", rows, rows)
        rho_all = w_all / np.maximum(self.rng.uniform(size=rows.shape[0]), 1e-300)
        for i, (w, rho) in enumerate(zip(w_all.tolist(), rho_all.tolist())):
            if rho >= self.tau:
                self.comm.item_msgs += 1
                # Copy: sampled rows outlive the caller's batch buffer.
                if rho >= 2.0 * self.tau:
                    self.q_next.append((rows[i].copy(), w, rho))
                else:
                    self.q_cur.append((rows[i].copy(), w, rho))
                if len(self.q_next) >= self.s:
                    self.tau *= 2.0
                    self.comm.broadcast_events += 1
                    self.q_cur = self.q_next
                    self.q_next = [t for t in self.q_cur if t[2] >= 2.0 * self.tau]
                    self.q_cur = [t for t in self.q_cur if t[2] < 2.0 * self.tau]

    def result(self) -> MatrixResult:
        """The coordinator's current answer (callable at any time)."""
        sample = self.q_cur + self.q_next
        if not sample:
            return MatrixResult(np.zeros((0, self.d)), 0.0, self.comm, self.m, self.eps)
        sample = sorted(sample, key=lambda t: t[2])
        rho_hat = sample[0][2]
        kept = sample[1:] if len(sample) > 1 else sample
        out = []
        f_hat = 0.0
        for row, w, _rho in kept:
            wbar = max(w, rho_hat)
            f_hat += wbar
            scale = math.sqrt(wbar / max(w, 1e-300))
            out.append(row * scale)
        return MatrixResult(np.stack(out), f_hat, self.comm, self.m, self.eps)


def _mp3(rows, sites, m, eps, rng, s=None) -> MatrixResult:
    eng = MP3Stream(m, eps, rows.shape[1], rng, s=s)
    eng.step(rows, sites)
    return eng.result()


class MP3wrStream:
    """Matrix P3 with replacement: s independent row samplers.

    Uniform draws are blocked by ``min(n, 1 << 22) // s`` within each
    ``step`` call, so a single whole-stream step reproduces the historical
    one-shot draw sequence exactly.
    """

    def __init__(self, m, eps, d, rng, s=None):
        if s is None:
            s = max(8, math.ceil(1.0 / eps**2))
        self.m, self.eps, self.d, self.s = m, eps, d, s
        self.rng = rng
        self.comm = CommLog()
        self.tau = 1.0
        self.top1_rho = np.zeros(s)
        self.top2_rho = np.zeros(s)
        self.top1_row = [None] * s
        self.top1_w = np.zeros(s)

    def step(self, rows, sites) -> None:
        """Absorb a batch, continuing the event-at-a-time semantics exactly where the last
        batch left off."""
        s = self.s
        w_all = np.einsum("nd,nd->n", rows, rows)
        n = rows.shape[0]
        block = max(1, min(n, 1 << 22) // max(s, 1) or 1)
        i = 0
        while i < n:
            hi = min(n, i + block)
            u = self.rng.uniform(size=(hi - i, s))
            rho = w_all[i:hi, None] / np.maximum(u, 1e-300)
            send_any = rho >= self.tau
            for r in range(hi - i):
                hit = np.nonzero(send_any[r])[0]
                if hit.size == 0:
                    continue
                self.comm.item_msgs += int(hit.size)
                rr = rho[r, hit]
                for t, p in zip(hit.tolist(), rr.tolist()):
                    if p > self.top1_rho[t]:
                        self.top2_rho[t] = self.top1_rho[t]
                        self.top1_rho[t] = p
                        self.top1_row[t] = rows[i + r].copy()  # outlives the batch
                        self.top1_w[t] = w_all[i + r]
                    elif p > self.top2_rho[t]:
                        self.top2_rho[t] = p
                if np.all(self.top2_rho > 2.0 * self.tau):
                    self.tau *= 2.0
                    self.comm.broadcast_events += 1
            i = hi

    def result(self) -> MatrixResult:
        """The coordinator's current answer (callable at any time)."""
        w_hat = float(np.mean(self.top2_rho))
        out = []
        for t in range(self.s):
            row = self.top1_row[t]
            if row is None:
                continue
            w = float(self.top1_w[t])
            scale = math.sqrt((w_hat / self.s) / max(w, 1e-300))
            out.append(row * scale)
        b = np.stack(out) if out else np.zeros((0, self.d))
        return MatrixResult(b, w_hat, self.comm, self.m, self.eps)


def _mp3wr(rows, sites, m, eps, rng, s=None) -> MatrixResult:
    eng = MP3wrStream(m, eps, rows.shape[1], rng, s=s)
    eng.step(rows, sites)
    return eng.result()


def _mp4(rows, sites, m, eps, rng, variant="fixed") -> MatrixResult:
    """Matrix P4 (Appendix C) — the paper's NEGATIVE result, reproduced.

    Sites track hat{A}_j = Z V^T where V never changes (variant='fixed', as
    Algorithm C.1 implies) or is re-seeded from the current covariance at
    each send (variant='resvd', the charitable reading).  Either way the
    error is NOT bounded by eps — see benchmarks/p4_negative.py.
    """
    d = rows.shape[1]
    comm = CommLog()
    f_hat = 1.0
    p = 2.0 * math.sqrt(m) / (eps * f_hat)
    site_cov = [np.zeros((d, d)) for _ in range(m)]  # exact A_j^T A_j
    site_v = [np.eye(d) for _ in range(m)]
    site_z = [np.zeros(d) for _ in range(m)]
    site_w = [0.0] * m

    w_all = np.einsum("nd,nd->n", rows, rows)
    u_all = rng.uniform(size=rows.shape[0])
    for i, j in enumerate(sites.tolist()):
        a = rows[i]
        site_cov[j] += np.outer(a, a)
        site_w[j] += float(w_all[i])
        if site_w[j] >= f_hat / (2 * m):
            comm.scalar_msgs += 1
            f_hat += site_w[j]
            site_w[j] = 0.0
            p = 2.0 * math.sqrt(m) / (eps * f_hat)
        p_bar = 1.0 - math.exp(-p * float(w_all[i]))
        if u_all[i] <= p_bar:
            comm.item_msgs += 1  # one d-dim vector message z
            v = site_v[j]
            if variant == "resvd":
                lam, vec = np.linalg.eigh(site_cov[j])
                v = vec[:, ::-1]
                site_v[j] = v
            quad = np.einsum("di,dk,ki->i", v, site_cov[j], v)
            site_z[j] = np.sqrt(np.maximum(quad + 1.0 / p, 0.0))

    blocks = [site_z[j][:, None] * site_v[j].T for j in range(m)]
    b = np.concatenate(blocks, axis=0)
    return MatrixResult(b, f_hat, comm, m, eps)


MATRIX_PROTOCOLS = {
    "P1": _mp1,
    "P2": _mp2,
    "P3": _mp3,
    "P3wr": _mp3wr,
    "P4": _mp4,
}

# Resumable stream engines (init/step/result) — the registry's event entries.
# P4 is deliberately absent: it is the paper's negative result and must not
# be offered behind an interface whose contract is the eps guarantee.
MATRIX_STREAMS = {
    "P1": MP1Stream,
    "P2": MP2Stream,
    "P3": MP3Stream,
    "P3wr": MP3wrStream,
}


def run_matrix_protocol(
    name: str,
    rows: np.ndarray,
    sites: np.ndarray,
    m: int,
    eps: float,
    seed: int = 0,
    **kw,
) -> MatrixResult:
    """One-shot wrapper: stream the whole feed through matrix protocol ``name``."""
    rng = np.random.default_rng(seed)
    return MATRIX_PROTOCOLS[name](rows, sites, m, eps, rng, **kw)
