"""Uniform protocol-level message accounting (the paper's units).

Both protocol engines keep engine-specific counters while running — the
event-driven engine distinguishes element/row messages from rows shipped
inside sketch sends, the shard_map engine keeps jit-able i32 scalars — but
everything downstream (tracker snapshots, the runtime registry, benchmarks)
consumes one shape: ``CommReport``.  A message is one d-dimensional row or
one scalar pair; a sketch of r rows costs r messages; a coordinator
broadcast costs m messages.
"""
from __future__ import annotations

from dataclasses import dataclass

__all__ = ["CommReport", "build_report"]


@dataclass(frozen=True)
class CommReport:
    """Engine-agnostic communication report with uniform field names.

    scalar_msgs:      (total, W_i)-style scalar messages, site -> C.
    row_msgs:         d-dimensional row messages, site -> C — element/
                      direction sends *and* rows shipped inside sketches.
    broadcast_events: coordinator -> all-sites broadcasts (each costs m).
    m:                number of sites, so ``total`` is self-contained.
    """

    scalar_msgs: int
    row_msgs: int
    broadcast_events: int
    m: int

    @property
    def total(self) -> int:
        """Total message cost in the paper's units (broadcasts cost m each)."""
        return self.scalar_msgs + self.row_msgs + self.broadcast_events * self.m

    def as_dict(self) -> dict[str, int]:
        """The report as a plain dict (includes the derived ``total``)."""
        return {
            "scalar_msgs": self.scalar_msgs,
            "row_msgs": self.row_msgs,
            "broadcast_events": self.broadcast_events,
            "m": self.m,
            "total": self.total,
        }

    def __getitem__(self, key: str) -> int:
        # Dict-style access; "scalar"/"rows" kept as aliases of the old
        # TrackerSnapshot.messages dict keys.
        aliases = {"scalar": "scalar_msgs", "rows": "row_msgs"}
        return self.as_dict()[aliases.get(key, key)]

    def emit(self, registry, **labels) -> None:
        """Set the paper-level comm gauges on an obs registry.

        ``labels`` (typically ``cell=...``, ``tenant=...``) select the
        series; one gauge per report field plus the derived total in the
        paper's units.  Gauges, not counters: a report is a snapshot of
        cumulative protocol state, re-emitted whole at every publish.
        """
        names = tuple(sorted(labels))
        for field, value in self.as_dict().items():
            registry.gauge(
                f"repro_comm_{field}",
                "Protocol communication accounting (paper units); "
                "total = scalar + rows + broadcasts*m.",
                labels=names,
            ).labels(**labels).set(value)


def build_report(*, scalar_msgs, row_msgs, broadcast_events, m) -> CommReport:
    """The one place engine counters collapse into a ``CommReport``.

    Both engines route through here — the event engine with
    ``item_msgs + sketch_rows`` as its row count, the shard_map engine
    with jit-able i32 scalars — so they cannot drift in what they count
    or how values are coerced (everything lands as a Python ``int``).
    """
    return CommReport(
        scalar_msgs=int(scalar_msgs),
        row_msgs=int(row_msgs),
        broadcast_events=int(broadcast_events),
        m=int(m),
    )
