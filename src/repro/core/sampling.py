"""Priority sampling (Duffield--Lund--Thorup) primitives for protocol P3.

Each item with weight ``w`` draws ``u ~ Unif(0, 1]`` and gets priority
``rho = w / u``.  A size-``s`` *without replacement* sample keeps the ``s``
largest priorities; with ``tau`` the (s+1)-th largest priority, the
subset-sum estimator assigns each kept item the adjusted weight
``bar{w} = max(w, tau)``, which is unbiased: ``E[sum bar{w}] = W``.

The streaming/distributed round structure (threshold doubling, queues
Q_j/Q_{j+1}) lives in ``protocols.py``; this module provides the math.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "priorities",
    "priority_sample",
    "PrioritySample",
    "subset_sum_weights",
]


def priorities(weights: jax.Array, key: jax.Array) -> jax.Array:
    """rho_i = w_i / u_i with u ~ Unif(0,1] (jit-able)."""
    u = jax.random.uniform(key, weights.shape, minval=jnp.finfo(jnp.float32).tiny, maxval=1.0)
    return weights / u


class PrioritySample(NamedTuple):
    """A size-s priority sample: kept indices, adjusted weights, threshold tau."""
    indices: jax.Array  # (s,) indices into the source array
    weights: jax.Array  # (s,) adjusted weights bar{w}
    tau: jax.Array  # () the (s+1)-th priority (estimator threshold)


def priority_sample(weights: jax.Array, key: jax.Array, s: int) -> PrioritySample:
    """One-shot size-s priority sample of a weight vector (jit-able)."""
    n = weights.shape[0]
    if n <= s:
        raise ValueError(f"need n > s for a proper sample, got n={n}, s={s}")
    rho = priorities(weights.astype(jnp.float32), key)
    top_rho, top_idx = jax.lax.top_k(rho, s + 1)
    tau = top_rho[s]
    idx = top_idx[:s]
    adj = jnp.maximum(weights[idx].astype(jnp.float32), tau)
    return PrioritySample(indices=idx, weights=adj, tau=tau)


def subset_sum_weights(kept_w: np.ndarray, tau: float) -> np.ndarray:
    """Adjusted weights for a priority sample with threshold tau (numpy)."""
    return np.maximum(kept_w, tau)


class PrioritySampler:
    """Streaming without-replacement priority sampler (numpy oracle).

    Maintains the top-``s`` priorities over everything seen; ``sample()``
    returns (items, adjusted weights).  This is the *centralized* oracle; the
    distributed round protocol in protocols.py reproduces it with low
    communication (paper Lemma 6 / Theorem 5).
    """

    def __init__(self, s: int, rng: np.random.Generator):
        self.s = s
        self.rng = rng
        self._items: list = []
        self._weights: list[float] = []
        self._rhos: list[float] = []

    def update(self, item, w: float) -> None:
        """Offer one (item, weight) pair to the sampler."""
        rho = w / max(self.rng.uniform(), 1e-300)
        self._items.append(item)
        self._weights.append(w)
        self._rhos.append(rho)
        if len(self._items) > 4 * self.s:
            self._compact()

    def _compact(self) -> None:
        order = np.argsort(self._rhos)[::-1][: self.s + 1]
        self._items = [self._items[i] for i in order]
        self._weights = [self._weights[i] for i in order]
        self._rhos = [self._rhos[i] for i in order]

    def sample(self):
        """The current sample as ``(items, adjusted subset-sum weights)``."""
        self._compact()
        if len(self._items) <= self.s:
            return list(self._items), np.asarray(self._weights, np.float64)
        tau = self._rhos[self.s]
        items = self._items[: self.s]
        w = subset_sum_weights(np.asarray(self._weights[: self.s], np.float64), tau)
        return items, w
