from repro.roofline.analysis import (
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS,
    RooflineReport,
    model_flops,
    parse_collective_bytes,
    report_from_compiled,
)
