"""Three-term roofline from compiled dry-run artifacts (deliverable g).

    compute term    = HLO_FLOPs_per_device / peak_FLOP/s
    memory term     = HLO_bytes_per_device / HBM_bw
    collective term = collective_bytes_per_device / link_bw

Per-device quantities come straight from the compiled (SPMD, per-device)
module: ``cost_analysis()`` for FLOPs/bytes, and an HLO-text parse summing
operand sizes of every all-gather / all-reduce / reduce-scatter / all-to-all
/ collective-permute for collective bytes (cost_analysis does not expose
them).  Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
# matches: %name = <result type> opcode(...operands...)
_OP_RE = re.compile(
    r"=\s*[^=]*?\b("
    + "|".join(_COLLECTIVES)
    + r")(-start)?\("
)
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_EXPLICIT_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_EXPLICIT_RE.search(line)
    if m:
        return max(len(m.group(1).split(",")), 1)
    return 2


@dataclass
class CollectiveBytes:
    by_kind: dict = field(default_factory=dict)

    @property
    def total(self) -> int:
        return sum(self.by_kind.values())


def parse_collective_bytes(hlo_text: str) -> CollectiveBytes:
    """Per-device ICI traffic of every collective in an HLO module text,
    under the standard ring-cost model (S = replica-group size, R = result
    bytes of the op):

        all-gather:          R * (S-1)/S      (receives the other shards)
        all-reduce:          2R * (S-1)/S     (reduce-scatter + all-gather)
        reduce-scatter:      R * (S-1)        (ships S-1 result-sized shards)
        all-to-all:          R * (S-1)/S
        collective-permute:  R

    Only ``*-start`` (or plain) forms are counted; ``*-done`` consumes the
    start's result and would double count.
    """
    out = CollectiveBytes()
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        kind = m.group(1)
        # result type sits between '=' and the opcode name
        shapes = _SHAPE_RE.findall(line[m.start() : m.end()])
        if not shapes:
            shapes = _SHAPE_RE.findall(line)
        r = sum(_shape_bytes(d, s) for d, s in shapes)
        s = _group_size(line)
        if kind == "all-gather":
            nbytes = r * (s - 1) / s
        elif kind == "all-reduce":
            nbytes = 2 * r * (s - 1) / s
        elif kind == "reduce-scatter":
            nbytes = r * (s - 1)
        elif kind == "all-to-all":
            nbytes = r * (s - 1) / s
        else:  # collective-permute
            nbytes = r
        out.by_kind[kind] = out.by_kind.get(kind, 0) + int(nbytes)
    return out


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    collective_by_kind: dict
    model_flops_global: float

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def collective_s(self) -> float:
        return max(self.collective_bytes_per_device, 0.0) / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline estimate: max of the three terms (perfect overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / compiled FLOPs — catches remat/redundancy waste."""
        total = self.flops_per_device * self.chips
        return self.model_flops_global / total if total else 0.0

    @property
    def mfu(self) -> float:
        """Model-FLOPs utilisation at the roofline step time."""
        t = self.step_time_s
        return self.model_flops_global / (self.chips * PEAK_FLOPS * t) if t else 0.0

    def row(self) -> dict:
        return {
            "arch": self.arch,
            "shape": self.shape,
            "mesh": self.mesh,
            "chips": self.chips,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "model_flops": self.model_flops_global,
            "useful_flops_ratio": self.useful_flops_ratio,
            "mfu_at_roofline": self.mfu,
            "collective_by_kind": self.collective_by_kind,
        }


def model_flops(cfg, shape_cfg) -> float:
    """6*N_active*D (train) or 2*N_active*D (inference forward)."""
    n = cfg.active_param_count()
    if shape_cfg.kind == "train":
        tokens = shape_cfg.global_batch * (shape_cfg.seq_len - 1)
        return 6.0 * n * tokens
    if shape_cfg.kind == "prefill":
        tokens = shape_cfg.global_batch * shape_cfg.seq_len
        return 2.0 * n * tokens
    tokens = shape_cfg.global_batch  # decode: one token per sequence
    return 2.0 * n * tokens


def report_from_compiled(arch, shape_cfg, mesh_desc, chips, compiled, cfg) -> RooflineReport:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # some backends return [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes accessed", 0.0))
    coll = parse_collective_bytes(compiled.as_text())
    return RooflineReport(
        arch=arch,
        shape=shape_cfg.name,
        mesh=mesh_desc,
        chips=chips,
        flops_per_device=flops,
        bytes_per_device=nbytes,
        collective_bytes_per_device=float(coll.total),
        collective_by_kind=dict(coll.by_kind),
        model_flops_global=model_flops(cfg, shape_cfg),
    )
