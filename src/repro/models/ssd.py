"""Mamba-2 SSD block (state-space duality, chunked dual form).

The block (no separate MLP — SSD blocks are self-contained):

    z, xBC, dt = split(x @ W_in)
    xBC        = silu(causal_conv1d(xBC, width=4))
    xs, B, C   = split(xBC)                      # B, C: (B, T, N), one group
    y          = SSD(xs, dt, A, B, C) + D * xs   # multi-head, P = head_dim
    out        = (rmsnorm(y) * silu(z)) @ W_out  # gated norm, mamba2-style

Training/prefill runs the *chunked* SSD algorithm: quadratic attention-like
math within chunks of Q tokens (MXU-friendly), a linear ``lax.scan`` carrying
the (H, P, N) state across chunks.  Decode is the O(1) recurrent update

    h = exp(dt*A) h + dt * B (x)          y = C h + D x

State: {"h": (B, H, P, N) f32, "conv": (B, cw-1, conv_channels)}.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dtype_of, linear_init

CHUNK = 256


def _dims(cfg):
    di = cfg.ssm_expand * cfg.d_model
    p = cfg.ssm_head_dim
    h = di // p
    n = cfg.ssm_state
    return di, h, p, n


def ssd_init(key, cfg) -> dict:
    d = cfg.d_model
    di, h, p, n = _dims(cfg)
    conv_ch = di + 2 * n
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 4)
    return {
        # fused input projection: [z (di), xBC (di + 2n), dt (h)]
        "w_in": linear_init(ks[0], d, 2 * di + 2 * n + h, dt),
        "w_out": linear_init(ks[1], di, d, dt, scale=di**-0.5),
        "conv": (jax.random.normal(ks[2], (cfg.conv_width, conv_ch), jnp.float32) * 0.1).astype(dt),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h, dtype=jnp.float32)),
        "d_skip": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((h,), 0.01, jnp.float32))),  # softplus^-1
        "norm": jnp.zeros((di,), jnp.float32),
    }


def init_state(cfg, batch: int) -> dict:
    di, h, p, n = _dims(cfg)
    return {
        "h": jnp.zeros((batch, h, p, n), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, di + 2 * n), dtype_of(cfg)),
    }


def _causal_conv(u, weight, tail):
    cw = weight.shape[0]
    if tail is None:
        tail = jnp.zeros((u.shape[0], cw - 1, u.shape[2]), u.dtype)
    ext = jnp.concatenate([tail, u], axis=1)
    out = sum(ext[:, i : i + u.shape[1], :] * weight[i][None, None, :] for i in range(cw))
    new_tail = ext[:, -(cw - 1) :, :] if cw > 1 else tail
    return out, new_tail


def _segsum(x: jax.Array) -> jax.Array:
    """x: (..., q) -> (..., q, q) lower-triangular pairwise cumulative sums."""
    q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, diff, -jnp.inf)


def _ssd_chunked(xs, dt, a, bm, cm, h0):
    """Chunked SSD.  xs: (B,T,H,P); dt: (B,T,H); a: (H,) (negative);
    bm, cm: (B,T,N); h0: (B,H,P,N).  Returns (y, h_final).

    All per-chunk math (decay kernel, intra-chunk quadratic form, state
    update) lives *inside* a rematted ``lax.scan`` body, so peak residency is
    one chunk's (q x q) decay kernel, never (T/q) of them."""
    b, t, h, p = xs.shape
    n = bm.shape[-1]
    q = min(CHUNK, t)
    while t % q:  # largest divisor <= CHUNK (trace-time only)
        q -= 1
    nc = t // q

    xc = xs.reshape(b, nc, q, h, p).transpose(1, 0, 2, 3, 4)  # (nc,b,q,h,p)
    dtc = dt.reshape(b, nc, q, h).transpose(1, 0, 2, 3)
    bc = bm.reshape(b, nc, q, n).transpose(1, 0, 2, 3)
    cc = cm.reshape(b, nc, q, n).transpose(1, 0, 2, 3)

    @jax.checkpoint
    def step(h_prev, inp):
        x_c, dt_c, b_c, c_c = inp  # (b,q,h,p), (b,q,h), (b,q,n), (b,q,n)
        x_c = x_c.astype(jnp.float32)
        b_c = b_c.astype(jnp.float32)
        c_c = c_c.astype(jnp.float32)
        da = (dt_c * a[None, None, :]).transpose(0, 2, 1)  # (b,h,q), <= 0
        da_cum = jnp.cumsum(da, axis=-1)
        da_sum = da_cum[..., -1]  # (b,h)
        # intra-chunk quadratic form
        l_mat = jnp.exp(_segsum(da))  # (b,h,q,q)
        scores = jnp.einsum("bln,bsn->bls", c_c, b_c)  # (b,q,q)
        xdt = x_c * dt_c[..., None]  # (b,q,h,p)
        y_diag = jnp.einsum("bls,bhls,bshp->blhp", scores, l_mat, xdt)
        # carried-state contribution + state update
        y_off = jnp.einsum("bln,bhpn,bhl->blhp", c_c, h_prev, jnp.exp(da_cum))
        decay_states = jnp.exp(da_sum[..., None] - da_cum)  # (b,h,q)
        s_c = jnp.einsum("bsn,bhs,bshp->bhpn", b_c, decay_states, xdt)
        h_new = jnp.exp(da_sum)[..., None, None] * h_prev + s_c
        return h_new, (y_diag + y_off).astype(xs.dtype)

    h_final, y = jax.lax.scan(step, h0.astype(jnp.float32), (xc, dtc, bc, cc))
    y = y.transpose(1, 0, 2, 3, 4).reshape(b, t, h, p)
    return y, h_final


def ssd_apply(cfg, params: dict, x: jax.Array, state: dict | None = None):
    """x: (B, T, D) -> (y, new_state)."""
    b, t, d = x.shape
    di, h, p, n = _dims(cfg)
    proj = jnp.einsum("btd,dk->btk", x, params["w_in"])
    z, xbc, dt_raw = jnp.split(proj, [di, 2 * di + 2 * n], axis=-1)
    tail = state["conv"] if state is not None else None
    xbc, new_tail = _causal_conv(xbc, params["conv"], tail)
    xbc = jax.nn.silu(xbc)
    xs, bm, cm = jnp.split(xbc, [di, di + n], axis=-1)
    xs = xs.reshape(b, t, h, p)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])  # (B,T,H)
    a = -jnp.exp(params["a_log"])  # (H,), negative

    h0 = state["h"] if state is not None else jnp.zeros((b, h, p, n), jnp.float32)
    if t == 1 and state is not None:
        da = jnp.exp(dt[:, 0] * a[None, :])  # (B, H)
        inc = jnp.einsum("bh,bhp,bn->bhpn", dt[:, 0], xs[:, 0].astype(jnp.float32), bm[:, 0].astype(jnp.float32))
        h_new = da[..., None, None] * h0 + inc
        y = jnp.einsum("bn,bhpn->bhp", cm[:, 0].astype(jnp.float32), h_new)[:, None]
        y = y.reshape(b, 1, h, p)
        h_final = h_new
    else:
        y, h_final = _ssd_chunked(xs, dt, a, bm, cm, h0)

    y = y + (params["d_skip"][None, None, :, None] * xs.astype(jnp.float32)).astype(y.dtype)
    y = y.reshape(b, t, di)
    # gated RMSNorm (mamba2): norm(y) * silu(z)
    yf = y.astype(jnp.float32)
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    yn = yf * jax.lax.rsqrt(var + cfg.norm_eps) * (1.0 + params["norm"])
    gated = (yn * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = jnp.einsum("btk,kd->btd", gated, params["w_out"])
    new_state = {"h": h_final, "conv": new_tail} if state is not None else None
    return out, new_state
