"""Shared neural layers: RMSNorm, RoPE, SwiGLU MLP, embeddings."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def dtype_of(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    """RMSNorm in f32, cast back to input dtype."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(x.dtype)


def norm_init(d: int) -> jax.Array:
    return jnp.zeros((d,), jnp.float32)  # (1 + scale) parameterisation


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding.  x: (..., T, n_heads, head_dim); positions: (..., T)."""
    head_dim = x.shape[-1]
    half = head_dim // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freq  # (..., T, half)
    cos = jnp.cos(angles)[..., None, :]  # (..., T, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def linear_init(key, d_in: int, d_out: int, dtype, scale: float | None = None):
    if scale is None:
        scale = d_in**-0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def mlp_init(key, cfg, d_ff: int | None = None) -> dict:
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    dt = dtype_of(cfg)
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": linear_init(k1, d, ff, dt),
        "w_up": linear_init(k2, d, ff, dt),
        "w_down": linear_init(k3, ff, d, dt, scale=ff**-0.5),
    }


def mlp_apply(params: dict, x: jax.Array) -> jax.Array:
    """SwiGLU: w_down(silu(w_gate x) * (w_up x))."""
    gate = jax.nn.silu(jnp.einsum("...d,df->...f", x, params["w_gate"]))
    up = jnp.einsum("...d,df->...f", x, params["w_up"])
    return jnp.einsum("...f,fd->...d", gate * up, params["w_down"])


def embed_init(key, cfg) -> dict:
    dt = dtype_of(cfg)
    v = cfg.padded_vocab  # padded for clean vocab sharding over "model"
    p = {"table": (jax.random.normal(key, (v, cfg.d_model), jnp.float32) * 0.02).astype(dt)}
    if not cfg.tie_embeddings:
        k2 = jax.random.fold_in(key, 1)
        p["unembed"] = (jax.random.normal(k2, (v, cfg.d_model), jnp.float32) * 0.02).astype(dt)
    return p


def embed_apply(params: dict, tokens: jax.Array) -> jax.Array:
    return params["table"][tokens]


def unembed_apply(params: dict, x: jax.Array, softcap: float = 0.0, true_vocab: int = 0) -> jax.Array:
    table = params.get("unembed", params["table"])
    logits = jnp.einsum("...d,vd->...v", x, table).astype(jnp.float32)
    if softcap > 0.0:
        logits = softcap * jnp.tanh(logits / softcap)
    if true_vocab and true_vocab < table.shape[0]:
        pad_mask = jnp.arange(table.shape[0]) >= true_vocab
        logits = jnp.where(pad_mask, -1e30, logits)
    return logits
