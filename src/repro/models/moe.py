"""Token-choice top-k MoE with sort-based dispatch (EP over the model axis).

FLOP-faithful MoE: only routed tokens hit expert weights.  The dispatch is
the sort-based formulation (no (T, E, C) one-hot blow-up):

  1. route: softmax(x @ Wr) -> top-k (gates, expert ids) per token
  2. sort the T*K (token, choice) pairs by expert id
  3. per-pair queue position via searchsorted; drop beyond capacity C
  4. scatter token activations into an (E, C, d) buffer   <- all_to_all
     under EP sharding (E sharded over "model")
  5. batched expert FFN: einsum over the stacked (E, d, ff) weights
  6. gather back and combine with gates                   <- all_to_all back

Capacity C = ceil(T * K / E * capacity_factor); dropped tokens pass through
the residual (standard GShard semantics).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dtype_of, linear_init


def _eff_dims(cfg):
    """Effective (virtual) expert grid: e*v experts of ff/v width each."""
    v = max(cfg.moe_virtual_split, 1)
    return cfg.n_experts * v, cfg.experts_per_token * v, cfg.d_ff // v, v


def moe_init(key, cfg) -> dict:
    d, e = cfg.d_model, cfg.n_experts
    e_v, _, ff_v, _ = _eff_dims(cfg)
    dt = dtype_of(cfg)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "router": linear_init(k1, d, e, jnp.float32),
        "w_gate": (jax.random.normal(k2, (e_v, d, ff_v), jnp.float32) * d**-0.5).astype(dt),
        "w_up": (jax.random.normal(k3, (e_v, d, ff_v), jnp.float32) * d**-0.5).astype(dt),
        "w_down": (jax.random.normal(k4, (e_v, ff_v, d), jnp.float32) * cfg.d_ff**-0.5).astype(dt),
    }


def _virtualize_routing(cfg, gates, expert_idx):
    """(.., k) real-expert choices -> (.., k*v) virtual-expert choices.
    Each half receives the full gate; their down-proj outputs add."""
    _, _, _, v = _eff_dims(cfg)
    if v == 1:
        return gates, expert_idx
    idx = (expert_idx[..., None] * v + jnp.arange(v)).reshape(*expert_idx.shape[:-1], -1)
    g = jnp.repeat(gates, v, axis=-1)
    return g, idx


def moe_apply(cfg, params: dict, x: jax.Array) -> jax.Array:
    """x: (B, T, D) -> (B, T, D).

    The routing/sort/scatter runs *per data-parallel group*: tokens are
    reshaped to (G, n/G, D) with G = the DP degree, and the whole dispatch
    is vmapped over G.  Every dispatch op is then batch-parallel over a
    DP-sharded axis, so XLA executes it without cross-shard communication —
    the only collective left is the intended dispatch/combine all-to-all of
    the expert einsums (EP over the "model" axis).  This is what keeps the
    1M-token qwen3-moe train step from global-sorting 8M routing keys.
    """
    from repro.models import sharding as shd

    b, t, d = x.shape
    n = b * t
    ctx = shd.current_ctx()
    e_v, _, _, _ = _eff_dims(cfg)
    if (
        ctx is not None
        and n > 512
        and ctx["dp_size"] > 1
        and b % ctx["dp_size"] == 0
        and ctx["model_size"] > 1
        and e_v % ctx["model_size"] == 0
    ):
        return _moe_shard_map(cfg, params, x, ctx)
    g = shd.current_dp_size()
    if n > 512 and g > 1 and b % g == 0:
        xg = shd.constrain_moe_tokens(x.reshape(g, n // g, d))
        out = _moe_grouped(cfg, params, xg)
        return out.reshape(b, t, d)
    return _moe_flat(cfg, params, x.reshape(n, d)).reshape(b, t, d)


def _moe_shard_map(cfg, params: dict, x: jax.Array, ctx) -> jax.Array:
    """Manual expert parallelism: tokens DP-local, experts model-sharded.

    Each (data, model) shard routes its *local* tokens (replicated routing
    along the model axis — deterministic), dispatches only the entries bound
    for its own expert slice, runs the local expert FFNs, scatters back and
    psums partial token outputs over the model axis.  The only collectives
    are the entry all-gather (sequence-parallel boundary, inserted by XLA)
    and one (n_local, d) psum — no global sorts, no capacity-bloated
    all-reduces.
    """
    from jax import lax
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    e_v, k_v, ff_v, _ = _eff_dims(cfg)
    msize = ctx["model_size"]
    e_loc = e_v // msize
    dp = ctx["dp"]
    mdl = "model"
    b, t, d = x.shape

    def inner(xb, router, wg, wu, wd):
        # xb: (b_loc, t, d); wg/wu: (e_loc, d, ff_v); wd: (e_loc, ff_v, d)
        j = lax.axis_index(mdl)
        n = xb.shape[0] * xb.shape[1]
        xf = xb.reshape(n, d)
        logits = jnp.einsum("nd,de->ne", xf.astype(jnp.float32), router)
        probs = jax.nn.softmax(logits, axis=-1)
        gates, expert_idx = jax.lax.top_k(probs, cfg.experts_per_token)
        gates = gates / jnp.sum(gates, axis=-1, keepdims=True)
        gates, expert_idx = _virtualize_routing(cfg, gates, expert_idx)

        fe = expert_idx.reshape(-1)
        ftok = jnp.repeat(jnp.arange(n), k_v)
        fgate = gates.reshape(-1)
        order = jnp.argsort(fe)
        se, stok, sgate = fe[order], ftok[order], fgate[order]
        first = jnp.searchsorted(se, se, side="left")
        pos = jnp.arange(n * k_v) - first
        capacity = int(-(-n * k_v // e_v) * cfg.capacity_factor) or 1
        lo = j * e_loc
        mine = (se >= lo) & (se < lo + e_loc) & (pos < capacity)
        dest = jnp.where(mine, (se - lo) * capacity + pos, e_loc * capacity)

        buf = jnp.zeros((e_loc * capacity + 1, d), xb.dtype).at[dest].set(xf[stok])
        expert_in = buf[: e_loc * capacity].reshape(e_loc, capacity, d)
        gate_h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", expert_in, wg))
        up_h = jnp.einsum("ecd,edf->ecf", expert_in, wu)
        expert_out = jnp.einsum("ecf,efd->ecd", gate_h * up_h, wd)

        flat = expert_out.reshape(e_loc * capacity, d)
        flat = jnp.concatenate([flat, jnp.zeros((1, d), xb.dtype)], axis=0)
        picked = flat[dest] * (sgate * mine).astype(xb.dtype)[:, None]
        partial = jnp.zeros((n, d), xb.dtype).at[stok].add(picked)
        out = lax.psum(partial, mdl)
        return out.reshape(xb.shape)

    return shard_map(
        inner,
        mesh=ctx["mesh"],
        in_specs=(
            P(dp, None, None),
            P(),
            P(mdl, None, None),
            P(mdl, None, None),
            P(mdl, None, None),
        ),
        out_specs=P(dp, None, None),
        check_rep=False,
    )(x, params["router"], params["w_gate"], params["w_up"], params["w_down"])


def _moe_grouped(cfg, params: dict, xg: jax.Array) -> jax.Array:
    """Explicit-G dispatch: every op carries the (DP-sharded) group axis."""
    from repro.models import sharding as shd

    g, nl, d = xg.shape
    e, k, _, _ = _eff_dims(cfg)

    logits = jnp.einsum("gnd,de->gne", xg.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gates, expert_idx = jax.lax.top_k(probs, cfg.experts_per_token)  # (g, nl, k_real)
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)
    gates, expert_idx = _virtualize_routing(cfg, gates, expert_idx)

    nk = nl * k
    fe = expert_idx.reshape(g, nk)
    ftok = jnp.broadcast_to(jnp.repeat(jnp.arange(nl), k)[None], (g, nk))
    fgate = gates.reshape(g, nk)
    order = jnp.argsort(fe, axis=-1)
    se = jnp.take_along_axis(fe, order, axis=-1)
    stok = jnp.take_along_axis(ftok, order, axis=-1)
    sgate = jnp.take_along_axis(fgate, order, axis=-1)

    first = jax.vmap(lambda s: jnp.searchsorted(s, s, side="left"))(se)
    pos = jnp.arange(nk)[None] - first
    capacity = int(-(-nk // e) * cfg.capacity_factor) or 1
    keep = pos < capacity
    dest = jnp.where(keep, se * capacity + pos, e * capacity)  # (g, nk)

    # dispatch (flattened group-offset scatter — stays group-local)
    goff = jnp.arange(g)[:, None] * (e * capacity + 1)
    dest_flat = (dest + goff).reshape(-1)
    src = jnp.take_along_axis(xg, stok[..., None], axis=1).reshape(-1, d)
    buf = jnp.zeros((g * (e * capacity + 1), d), xg.dtype).at[dest_flat].set(src)
    expert_in = buf.reshape(g, e * capacity + 1, d)[:, : e * capacity]
    expert_in = shd.constrain_moe_experts(expert_in.reshape(g, e, capacity, d))

    gate_h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", expert_in, params["w_gate"]))
    up_h = jnp.einsum("gecd,edf->gecf", expert_in, params["w_up"])
    expert_out = jnp.einsum("gecf,efd->gecd", gate_h * up_h, params["w_down"])
    expert_out = shd.constrain_moe_experts(expert_out)

    # combine
    flat_out = expert_out.reshape(g, e * capacity, d)
    flat_out = jnp.concatenate([flat_out, jnp.zeros((g, 1, d), xg.dtype)], axis=1)
    picked = jnp.take_along_axis(flat_out, dest[..., None], axis=1)
    picked = picked * (sgate * keep).astype(xg.dtype)[..., None]
    toff = jnp.arange(g)[:, None] * nl
    tok_flat = (stok + toff).reshape(-1)
    out = jnp.zeros((g * nl, d), xg.dtype).at[tok_flat].add(picked.reshape(-1, d))
    return shd.constrain_moe_tokens(out.reshape(g, nl, d))


def _moe_flat(cfg, params: dict, xf: jax.Array) -> jax.Array:
    """Token-choice dispatch on a flat (n, d) token block."""
    n, d = xf.shape
    e, k, _, _ = _eff_dims(cfg)

    # 1. route (router math in f32 for stability)
    logits = jnp.einsum("nd,de->ne", xf.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gates, expert_idx = jax.lax.top_k(probs, cfg.experts_per_token)  # (n, k_real)
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)
    gates, expert_idx = _virtualize_routing(cfg, gates, expert_idx)

    # 2. sort (token, choice) pairs by expert
    flat_expert = expert_idx.reshape(-1)  # (n*k,)
    flat_token = jnp.repeat(jnp.arange(n), k)
    flat_gate = gates.reshape(-1)
    order = jnp.argsort(flat_expert)
    se, stok, sgate = flat_expert[order], flat_token[order], flat_gate[order]

    # 3. queue position within each expert
    first = jnp.searchsorted(se, se, side="left")
    pos = jnp.arange(n * k) - first
    if n <= 512:
        # decode / tiny batches: dropless (worst-case one slot per token),
        # so serve_step matches the full forward exactly.
        capacity = n
    else:
        capacity = int(-(-n * k // e) * cfg.capacity_factor) or 1
    keep = pos < capacity
    dest = jnp.where(keep, se * capacity + pos, e * capacity)  # overflow row

    # 4. dispatch: (E*C + 1, d) buffer, sharded E over "model" upstream
    buf = jnp.zeros((e * capacity + 1, d), xf.dtype).at[dest].set(xf[stok])
    expert_in = buf[: e * capacity].reshape(e, capacity, d)

    # 5. expert FFN (SwiGLU), batched over E
    gate_h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", expert_in, params["w_gate"]))
    up_h = jnp.einsum("ecd,edf->ecf", expert_in, params["w_up"])
    expert_out = jnp.einsum("ecf,efd->ecd", gate_h * up_h, params["w_down"])

    # 6. combine
    flat_out = expert_out.reshape(e * capacity, d)
    flat_out = jnp.concatenate([flat_out, jnp.zeros((1, d), xf.dtype)], axis=0)
    picked = flat_out[dest] * (sgate * keep).astype(xf.dtype)[:, None]
    return jnp.zeros((n, d), xf.dtype).at[stok].add(picked)


def aux_load_balance_loss(cfg, x: jax.Array, params: dict) -> jax.Array:
    """Switch-style load-balance auxiliary (fraction * probability)."""
    b, t, d = x.shape
    logits = jnp.einsum("btd,de->bte", x.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top1 = jnp.argmax(probs, axis=-1)
    frac = jnp.mean(jax.nn.one_hot(top1, cfg.n_experts, dtype=jnp.float32), axis=(0, 1))
    prob = jnp.mean(probs, axis=(0, 1))
    return cfg.n_experts * jnp.sum(frac * prob)
