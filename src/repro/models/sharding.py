"""Parameter/activation sharding rules for the production meshes.

Axis convention: ``model`` = tensor/expert parallel, ``data`` (and ``pod``)
= data parallel.  Rules are *adaptive*: a dimension is only sharded if the
mesh axis size divides it (e.g. smollm's 9 heads fall back to replicated
attention projections instead of failing to lower) — this is what makes one
rule set serve all 10 assigned architectures.

Trailing-dim templates: a rule names the spec of the *last* ``len(rule)``
dims; any extra leading dims (scan-group stacking) get None.
"""
from __future__ import annotations

import contextlib
import contextvars
import re

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

# --- activation sharding (sequence parallelism) -----------------------------
# The residual stream between blocks is constrained to
#   P(dp_axes, "model", None)   (batch over DP, sequence over the TP axis)
# which shrinks the per-layer scan-boundary saves by the TP degree
# (Korthikanti-style sequence parallelism; XLA SPMD inserts the
# all-gather/reduce-scatter pairs around the TP matmuls).

_ACT_CTX: contextvars.ContextVar = contextvars.ContextVar("repro_act_sharding", default=None)


@contextlib.contextmanager
def activation_sharding(mesh: Mesh, *, seq_axis: str | None = "model", dp_over_all: bool = False):
    """Enable residual-stream sharding constraints during tracing.

    ``dp_over_all``: the FSDP/dp layout — every mesh axis acts as data
    parallelism (used for models too small to tensor-parallelize)."""
    dp = tuple(mesh.axis_names) if dp_over_all else data_axes(mesh)
    if dp_over_all:
        seq_axis = None
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    ctx = {
        "mesh": mesh,
        "dp": dp,
        "dp_size": dp_size,
        "seq": seq_axis,
        "seq_size": mesh.shape.get(seq_axis, 1) if seq_axis else 1,
        "model_size": mesh.shape.get("model", 1),
    }
    tok = _ACT_CTX.set(ctx)
    try:
        yield
    finally:
        _ACT_CTX.reset(tok)


def current_dp_size() -> int:
    """DP degree from the active activation-sharding context (1 outside)."""
    ctx = _ACT_CTX.get()
    return ctx["dp_size"] if ctx else 1


def current_ctx():
    return _ACT_CTX.get()


def constrain_moe_tokens(x):
    """(G, n_local, d) grouped-token constraint: groups over DP."""
    ctx = _ACT_CTX.get()
    if ctx is None or x.ndim != 3:
        return x
    if ctx["dp_size"] > 1 and x.shape[0] % ctx["dp_size"] == 0:
        return jax.lax.with_sharding_constraint(x, P(ctx["dp"], None, None))
    return x


def constrain_moe_experts(x):
    """(G, E, C, d) expert-buffer constraint: groups over DP, experts over
    the model axis when divisible (the dispatch all-to-all boundary)."""
    ctx = _ACT_CTX.get()
    if ctx is None or x.ndim != 4:
        return x
    dp = ctx["dp"] if ctx["dp_size"] > 1 and x.shape[0] % ctx["dp_size"] == 0 else None
    mdl = (
        ctx["seq"]
        if ctx["seq"] and ctx["seq_size"] > 1 and x.shape[1] % ctx["seq_size"] == 0
        else None
    )
    if dp is None and mdl is None:
        return x
    return jax.lax.with_sharding_constraint(x, P(dp, mdl, None, None))


def constrain_residual(x):
    """Apply the residual-stream constraint to a (B, T, D) activation (no-op
    outside an activation_sharding context or for non-dividing shapes)."""
    ctx = _ACT_CTX.get()
    if ctx is None or x.ndim != 3:
        return x
    b, t, _ = x.shape
    batch_ok = ctx["dp_size"] > 1 and b % ctx["dp_size"] == 0
    # XLA pads non-dividing shardings; only skip degenerate tiny-T cases.
    seq_ok = ctx["seq"] is not None and ctx["seq_size"] > 1 and t >= 4 * ctx["seq_size"]
    spec = P(
        ctx["dp"] if batch_ok else None,
        ctx["seq"] if seq_ok else None,
        None,
    )
    if spec == P(None, None, None):
        return x
    return jax.lax.with_sharding_constraint(x, spec)

# (path regex, trailing-dim axis template)
_RULES: list[tuple[str, tuple]] = [
    (r"embed/(table|unembed)$", ("model", None)),
    (r"attn/(wq|wk|wv)$", (None, "model")),
    (r"attn/wo$", ("model", None)),
    (r"ffn/router$", (None, None)),
    (r"ffn/(w_gate|w_up)$", "FFN_IN"),  # 2D (d,ff) or 3D MoE (e,d,ff)
    (r"ffn/w_down$", "FFN_OUT"),
    (r"mixer/(w_gate|w_in)$", (None, "model")),
    (r"mixer/w_out$", ("model", None)),
    (r"mixer/conv$", (None, "model")),
    (r"mixer/(w_a|w_i)$", (None, None, None)),
]


def _resolve_template(template, ndim: int) -> list[tuple]:
    """Candidate trailing-dim specs, best first (EP if E divides, else TP
    over the ff dim — e.g. mixtral's 8 experts on a 16-way model axis)."""
    if template == "FFN_IN":
        if ndim >= 3:
            return [("model", None, None), (None, None, "model")]
        return [(None, "model")]
    if template == "FFN_OUT":
        if ndim >= 3:
            return [("model", None, None), (None, "model", None)]
        return [("model", None)]
    return [template]


def _spec_for(path: str, shape: tuple[int, ...], mesh_axes: dict[str, int]) -> P:
    for pat, template in _RULES:
        if not re.search(pat, path):
            continue
        candidates = _resolve_template(template, len(shape))
        best = None
        for tmpl in candidates:
            tmpl = tuple(tmpl)
            n_lead = len(shape) - len(tmpl)
            if n_lead < 0:  # rule wider than leaf (shouldn't happen)
                continue
            full = (None,) * n_lead + tmpl
            # Adaptive: drop axes that don't divide the dim.
            checked = tuple(
                ax if (ax is not None and shape[i] % mesh_axes.get(ax, 1) == 0) else None
                for i, ax in enumerate(full)
            )
            if best is None:
                best = checked
            if any(ax is not None for ax in checked):
                return P(*checked)  # first candidate that actually shards
        return P(*best) if best else P()
    return P()  # norms, scalars, biases: replicated


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def param_shardings(params, mesh: Mesh):
    """NamedSharding pytree matching ``params`` (works on ShapeDtypeStructs)."""
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def one(path, leaf):
        spec = _spec_for(_path_str(path), leaf.shape, axes)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params)


def data_axes(mesh: Mesh) -> tuple[str, ...]:
    """The data-parallel axes of a production mesh."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def dp_param_shardings(params, mesh: Mesh):
    """FSDP/ZeRO-3-style layout for models too small to tensor-parallelize:
    every matrix is sharded over the *data* axis on its largest dividing dim
    (XLA all-gathers weights per layer); no TP.  Used with the batch spread
    over ALL mesh axes (the 'model' axis becomes extra data parallelism)."""
    data = mesh.shape.get("data", 1)

    def one(path, leaf):
        spec = [None] * len(leaf.shape)
        if data > 1 and leaf.ndim >= 2:
            dims = sorted(range(leaf.ndim), key=lambda i: -leaf.shape[i])
            for i in dims:
                if leaf.shape[i] % data == 0 and leaf.shape[i] >= data:
                    spec[i] = "data"
                    break
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, params)


def full_batch_sharding(mesh: Mesh, batch: int, extra_dims: int = 1):
    """Batch sharded over every mesh axis (dp layout)."""
    axes = tuple(mesh.axis_names)
    size = mesh.devices.size
    if batch % size == 0 and size > 1:
        return NamedSharding(mesh, P(axes, *([None] * extra_dims)))
    return batch_sharding(mesh, batch, extra_dims)


def batch_sharding(mesh: Mesh, batch: int, extra_dims: int = 1):
    """Sharding for a (batch, ...) input: batch over the DP axes if they
    divide it, else replicated (long_500k has batch=1)."""
    dp = data_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    if batch % dp_size == 0 and dp_size > 1:
        return NamedSharding(mesh, P(dp, *([None] * extra_dims)))
    return NamedSharding(mesh, P(*([None] * (1 + extra_dims))))


def cache_shardings(cache, mesh: Mesh, batch: int, shard_seq: bool):
    """Shardings for a decode cache pytree.

    Default: batch over DP axes.  ``shard_seq`` (long_500k, batch=1):
    KV seq dim over "data" (context parallelism); recurrent states
    replicated over DP (they are O(1) per sequence).
    """
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = data_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]

    def one(path, leaf):
        name = _path_str(path)
        shape = leaf.shape
        spec = [None] * len(shape)
        # Leading dims may include a scan-stack axis; find the batch dim by
        # structure: caches are (stack?, B, KVH, S, Dh) for kv, (stack?, B, ...)
        # for recurrent states, () for index scalars.
        if not shape:
            return NamedSharding(mesh, P())
        if shape[0] == batch:
            lead = 0
        elif len(shape) > 1 and shape[1] == batch:
            lead = 1  # scan-stacked cache: (repeats, B, ...)
        else:
            lead = -1
        if lead >= 0 and batch % dp_size == 0 and dp_size > 1:
            spec[lead] = dp
        if shard_seq and re.search(r"/(k|v)$", name) and len(shape) >= 3:
            seq_dim = len(shape) - 2
            if shape[seq_dim] % axes.get("data", 1) == 0:
                spec[seq_dim] = "data"
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, cache)
