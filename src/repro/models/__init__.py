"""Model zoo: composable decoder stack covering the 10 assigned archs."""
from repro.models.config import ModelConfig, group_pattern
from repro.models.transformer import LM
