"""GQA attention: XLA and Pallas paths, KV caches with ring-buffer SWA.

Modes (driven by ``cache`` and sequence length):
  * train:   full sequence, no cache.
  * prefill: full sequence, returns a filled cache.
  * decode:  T == 1 against a cache.  Local (windowed) layers keep a
    *ring-buffer* cache of only ``window`` slots — this is what makes
    ``long_500k`` decode cheap for SWA archs: KV memory is O(window), not
    O(context).  Global layers keep the full ``max_len`` cache.

Caches are dicts: {"k": (B, KVH, S, Dh), "v": ..., "index": ()} where S is
window (ring) or max_len (global).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dtype_of, linear_init, norm_init, rms_norm, rope

NEG_INF = -1e30


def attn_init(key, cfg) -> dict:
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    dt = dtype_of(cfg)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "wq": linear_init(k1, d, cfg.n_heads * hd, dt),
        "wk": linear_init(k2, d, cfg.n_kv_heads * hd, dt),
        "wv": linear_init(k3, d, cfg.n_kv_heads * hd, dt),
        "wo": linear_init(k4, cfg.n_heads * hd, d, dt),
    }
    if cfg.qk_norm:
        p["q_norm"] = norm_init(hd)
        p["k_norm"] = norm_init(hd)
    return p


def init_cache(cfg, batch: int, max_len: int, window: int) -> dict:
    """Cache for one attention layer.  Ring-buffer sized for local layers."""
    hd = cfg.resolved_head_dim
    s = min(max_len, window) if window > 0 else max_len
    dt = dtype_of(cfg)
    return {
        "k": jnp.zeros((batch, cfg.n_kv_heads, s, hd), dt),
        "v": jnp.zeros((batch, cfg.n_kv_heads, s, hd), dt),
        "index": jnp.zeros((), jnp.int32),
    }


def _qkv(cfg, params, x, positions):
    b, t, _ = x.shape
    hd = cfg.resolved_head_dim
    q = jnp.einsum("btd,dh->bth", x, params["wq"]).reshape(b, t, cfg.n_heads, hd)
    k = jnp.einsum("btd,dh->bth", x, params["wk"]).reshape(b, t, cfg.n_kv_heads, hd)
    v = jnp.einsum("btd,dh->bth", x, params["wv"]).reshape(b, t, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def _self_attention_xla(cfg, q, k, v, window: int):
    """Causal attention; q: (B, T, H, Dh), k/v: (B, KVH, S, Dh), fp32 softmax."""
    b, t, h, hd = q.shape
    kvh = k.shape[1]
    group = h // kvh
    qh = q.reshape(b, t, kvh, group, hd)
    logits = jnp.einsum("btkgd,bksd->bkgts", qh, k).astype(jnp.float32)
    logits *= hd**-0.5
    if cfg.logit_softcap > 0.0:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    qpos = jnp.arange(t)[:, None]
    kpos = jnp.arange(t)[None, :]
    mask = kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgts,bksd->btkgd", probs, v)
    return out.reshape(b, t, h, hd)


def _self_attention_chunked(cfg, q, k, v, window: int):
    """Flash-style attention in pure XLA: ``lax.scan`` over q blocks keeps
    peak memory at one (B, H, BQ, KV-span) logits block instead of (T, T).

    For windowed (local) layers the KV span per q block is a *static-length*
    dynamic_slice of ``window + BQ`` keys — this is a real FLOP reduction
    (not just masking), which is what makes 32k-prefill SWA layers cheap.
    Global causal layers scan the full KV with masking (the causal half-waste
    is reclaimed by the Pallas kernel on real TPU; see kernels/).
    """
    b, t, h, hd = q.shape
    kvh, s = k.shape[1], k.shape[2]
    group = h // kvh
    bq = min(cfg.attn_block_q, t)
    while t % bq:
        bq -= 1
    nq = t // bq
    span = min(s, window + bq) if window > 0 else s

    qb = q.reshape(b, nq, bq, kvh, group, hd).transpose(1, 0, 3, 4, 2, 5)
    # (nq, b, kvh, group, bq, hd)

    @jax.checkpoint  # backward recomputes per q-block: O(BQ x span) residency
    def body(_, inp):
        qblk, qi = inp
        qs = qi * bq
        if window > 0 and span < s:
            start = jnp.clip(qs + bq - span, 0, s - span)
            kblk = jax.lax.dynamic_slice_in_dim(k, start, span, axis=2)
            vblk = jax.lax.dynamic_slice_in_dim(v, start, span, axis=2)
            kpos = start + jnp.arange(span)
        else:
            kblk, vblk = k, v
            kpos = jnp.arange(s)
        logits = jnp.einsum("bkgqd,bksd->bkgqs", qblk, kblk).astype(jnp.float32)
        logits *= hd**-0.5
        if cfg.logit_softcap > 0.0:
            logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
        qpos = qs + jnp.arange(bq)
        mask = kpos[None, :] <= qpos[:, None]
        if window > 0:
            mask &= kpos[None, :] > qpos[:, None] - window
        logits = jnp.where(mask[None, None, None], logits, NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
        out = jnp.einsum("bkgqs,bksd->bkgqd", probs, vblk)
        return None, out

    _, outs = jax.lax.scan(body, None, (qb, jnp.arange(nq)))
    # outs: (nq, b, kvh, group, bq, hd)
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(b, t, h, hd)
    return out


def _self_attention_pallas(cfg, q, k, v, window: int):
    from repro.kernels.ops import flash_attention

    qt = q.transpose(0, 2, 1, 3)  # (B, H, T, Dh)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = flash_attention(
        qt, kt, vt, causal=True, window=window, logit_softcap=cfg.logit_softcap
    )
    return out.transpose(0, 2, 1, 3)


def _decode_attention(cfg, q, cache, window: int):
    """One-token attention against a (possibly ring-buffer) cache.

    q: (B, 1, H, Dh).  Returns (B, 1, H, Dh).
    """
    b, _, h, hd = q.shape
    k, v, index = cache["k"], cache["v"], cache["index"]
    s = k.shape[2]
    kvh = k.shape[1]
    group = h // kvh
    qh = q.reshape(b, kvh, group, hd)
    logits = jnp.einsum("bkgd,bksd->bkgs", qh, k).astype(jnp.float32)
    logits *= hd**-0.5
    if cfg.logit_softcap > 0.0:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    slots = jnp.arange(s)
    if window > 0 and s == window:
        # Ring buffer: slot r holds absolute position
        #   index - ((write_pos - r) mod window), write_pos = index mod window.
        write_pos = index % window
        abs_pos = index - ((write_pos - slots) % window)
        valid = (abs_pos >= 0) & (abs_pos <= index) & (abs_pos > index - window)
    else:
        valid = slots <= index
        if window > 0:
            valid &= slots > index - window
    logits = jnp.where(valid[None, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgs,bksd->bkgd", probs, v)
    return out.reshape(b, 1, h, hd)


def attn_apply(
    cfg,
    params: dict,
    x: jax.Array,
    *,
    window: int,
    positions: jax.Array,
    cache: dict | None = None,
) -> tuple[jax.Array, dict | None]:
    """x: (B, T, D).  See module docstring for mode selection."""
    b, t, d = x.shape
    hd = cfg.resolved_head_dim

    if cache is not None and t == 1:
        # ---- decode ----
        index = cache["index"]
        q, k, v = _qkv(cfg, params, x, positions)
        s = cache["k"].shape[2]
        slot = index % s  # ring for local, linear for global (index < s)
        knew = cache["k"].at[:, :, slot, :].set(k[:, 0])
        vnew = cache["v"].at[:, :, slot, :].set(v[:, 0])
        new_cache = {"k": knew, "v": vnew, "index": index + 1}
        out = _decode_attention(cfg, q, {**new_cache, "index": index}, window)
    else:
        # ---- train / prefill ----
        q, k, v = _qkv(cfg, params, x, positions)
        kt = k.transpose(0, 2, 1, 3)  # (B, KVH, T, Dh)
        vt = v.transpose(0, 2, 1, 3)
        if cfg.attn_impl == "pallas":
            out = _self_attention_pallas(cfg, q, kt, vt, window)
        elif cfg.attn_impl == "xla_chunked":
            out = _self_attention_chunked(cfg, q, kt, vt, window)
        else:
            out = _self_attention_xla(cfg, q, kt, vt, window)
        new_cache = None
        if cache is not None:
            s = cache["k"].shape[2]
            if s >= t:
                knew = jax.lax.dynamic_update_slice(cache["k"], kt, (0, 0, 0, 0))
                vnew = jax.lax.dynamic_update_slice(cache["v"], vt, (0, 0, 0, 0))
            else:  # ring cache smaller than prompt: keep the tail, ring-aligned
                # Position p must land in slot p % s so decode's ring indexing
                # stays consistent: roll the tail by (t - s) % s.
                knew = jnp.roll(kt[:, :, t - s :, :], (t - s) % s, axis=2)
                vnew = jnp.roll(vt[:, :, t - s :, :], (t - s) % s, axis=2)
            new_cache = {"k": knew, "v": vnew, "index": jnp.asarray(t, jnp.int32)}

    out = out.reshape(b, t, cfg.n_heads * hd)
    y = jnp.einsum("bth,hd->btd", out, params["wo"])
    return y, new_cache
