"""RG-LRU recurrent block (RecurrentGemma / Griffin).

Block structure (Griffin "recurrent block"):

    gate = gelu(x @ W_gate)                                (B, T, W)
    u    = causal_conv1d(x @ W_in, width=4)                (B, T, W)
    h    = RG-LRU(u)                                       (B, T, W)
    y    = (gate * h) @ W_out                              (B, T, D)

RG-LRU recurrence (c = 8, block-diagonal gates with n_heads blocks):

    r_t = sigmoid(u_t @ W_a)          a_t = exp(-c * softplus(Lambda) * r_t)
    i_t = sigmoid(u_t @ W_i)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * u_t)

Training/prefill uses ``jax.lax.associative_scan`` over T (parallel prefix,
the TPU-idiomatic form of the linear recurrence); decode is the O(1) update.
State: {"h": (B, W) f32, "conv": (B, conv_width-1, W)}.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dtype_of, linear_init

RG_LRU_C = 8.0


def rglru_init(key, cfg) -> dict:
    d = cfg.d_model
    w = cfg.resolved_rnn_width
    heads = cfg.n_heads
    bw = w // heads
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 6)
    return {
        "w_gate": linear_init(ks[0], d, w, dt),
        "w_in": linear_init(ks[1], d, w, dt),
        "w_out": linear_init(ks[2], w, d, dt, scale=w**-0.5),
        "conv": (jax.random.normal(ks[3], (cfg.conv_width, w), jnp.float32) * 0.1).astype(dt),
        "w_a": (jax.random.normal(ks[4], (heads, bw, bw), jnp.float32) * bw**-0.5).astype(dt),
        "w_i": (jax.random.normal(ks[5], (heads, bw, bw), jnp.float32) * bw**-0.5).astype(dt),
        # Lambda parameterised so softplus(Lambda) spans slow/fast decay.
        "lam": jnp.linspace(-2.0, 2.0, w, dtype=jnp.float32),
    }


def init_state(cfg, batch: int) -> dict:
    w = cfg.resolved_rnn_width
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, w), dtype_of(cfg)),
    }


def _causal_conv(u: jax.Array, weight: jax.Array, tail: jax.Array | None):
    """Depthwise causal conv along T.  u: (B, T, W); weight: (cw, W).
    ``tail``: (B, cw-1, W) carry-in (decode/prefill continuation)."""
    cw = weight.shape[0]
    if tail is None:
        tail = jnp.zeros((u.shape[0], cw - 1, u.shape[2]), u.dtype)
    ext = jnp.concatenate([tail, u], axis=1)  # (B, T+cw-1, W)
    out = sum(ext[:, i : i + u.shape[1], :] * weight[i][None, None, :] for i in range(cw))
    new_tail = ext[:, -(cw - 1) :, :] if cw > 1 else tail
    return out, new_tail


def _block_diag_gate(u: jax.Array, w: jax.Array) -> jax.Array:
    """u: (..., W) with W = heads*bw; w: (heads, bw, bw)."""
    heads, bw, _ = w.shape
    uh = u.reshape(u.shape[:-1] + (heads, bw))
    out = jnp.einsum("...hb,hbc->...hc", uh, w)
    return out.reshape(u.shape)


CHUNK = 256


def _gates_and_coeffs(params, u_chunk):
    """Per-chunk gate math in f32: returns (a, v) recurrence coefficients."""
    uf = u_chunk.astype(jnp.float32)
    r = jax.nn.sigmoid(_block_diag_gate(uf, params["w_a"].astype(jnp.float32)))
    i = jax.nn.sigmoid(_block_diag_gate(uf, params["w_i"].astype(jnp.float32)))
    log_a = -RG_LRU_C * jax.nn.softplus(params["lam"]) * r  # <= 0
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return a, beta * (i * uf)


def _combine(c1, c2):
    a1, b1 = c1
    a2, b2 = c2
    return a1 * a2, a2 * b1 + b2


def rglru_apply(cfg, params: dict, x: jax.Array, state: dict | None = None):
    """x: (B, T, D) -> (y, new_state).  state=None => training (no carry).

    Training/prefill runs a chunked parallel scan: gates + the associative
    scan are computed per CHUNK-token slab inside a rematted ``lax.scan``
    (carrying h across slabs), so full-sequence f32 gate tensors never
    materialise — the same residency discipline as the SSD block.
    """
    b, t, _ = x.shape
    gate = jax.nn.gelu(jnp.einsum("btd,dw->btw", x, params["w_gate"]))
    u = jnp.einsum("btd,dw->btw", x, params["w_in"])
    tail = state["conv"] if state is not None else None
    u, new_tail = _causal_conv(u, params["conv"], tail)
    w = u.shape[-1]

    h0 = state["h"] if state is not None else jnp.zeros((b, w), jnp.float32)
    if t == 1 and state is not None:
        a, v = _gates_and_coeffs(params, u)
        h = a[:, 0] * h0 + v[:, 0]
        hs = h[:, None].astype(x.dtype)
    else:
        q = min(CHUNK, t)
        while t % q:
            q -= 1
        nc = t // q
        uc = u.reshape(b, nc, q, w).transpose(1, 0, 2, 3)  # (nc, B, q, W)

        @jax.checkpoint
        def body(h, u_c):
            a, v = _gates_and_coeffs(params, u_c)
            v = v.at[:, 0].add(a[:, 0] * h)
            _, hs_c = jax.lax.associative_scan(_combine, (a, v), axis=1)
            return hs_c[:, -1], hs_c.astype(x.dtype)

        h, hs = jax.lax.scan(body, h0, uc)
        hs = hs.transpose(1, 0, 2, 3).reshape(b, t, w)

    y = jnp.einsum("btw,wd->btd", gate * hs, params["w_out"])
    new_state = {"h": h, "conv": new_tail} if state is not None else None
    return y, new_state
