"""Model configuration and layer-pattern utilities.

A model is a stack of *blocks*; each block is described by a layer kind:

    "global" — full causal attention + MLP/MoE
    "local"  — sliding-window attention + MLP/MoE
    "rglru"  — RG-LRU recurrent mixer + MLP (RecurrentGemma/Griffin)
    "ssd"    — Mamba-2 SSD mixer (self-contained block, no MLP)

``layer_pattern`` cycles over ``n_layers``; the stack compiler
(``group_pattern``) folds maximal repeats into ``lax.scan`` groups so a
94-layer model compiles as one loop body.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    # attention
    rope_theta: float = 10000.0
    qk_norm: bool = False
    logit_softcap: float = 0.0
    window: int = 0  # sliding window for "local" layers (0 = full)
    layer_pattern: tuple[str, ...] = ("global",)  # cycled over n_layers
    attn_impl: str = "xla_chunked"  # xla | xla_chunked | pallas (interpret on CPU)
    attn_block_q: int = 512  # q-block for chunked/pallas attention
    # moe
    n_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    # EP when n_experts < the model-axis width: split each expert's ff into
    # `moe_virtual_split` independent virtual experts (SwiGLU is elementwise
    # over ff; the down-proj halves simply add in the combine).  Same params,
    # no giant TP activation all-reduce.
    moe_virtual_split: int = 1
    # rg-lru
    rnn_width: int = 0  # 0 -> d_model
    conv_width: int = 4
    # ssd (mamba2)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    # frontends (stubs: inputs arrive as precomputed embeddings)
    frontend: str = "none"  # none | patch (vlm) | frames (audio)
    n_frontend_tokens: int = 0  # patch/frame positions prepended
    # misc
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    subquadratic: bool = False  # eligible for long_500k
    remat: str = "block"  # none | block — activation checkpointing policy
    vocab_pad_multiple: int = 128  # pad embedding rows for clean TP sharding
    scan_unroll: bool = False  # unroll layer scans (cost-accounting lowers)

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return -(-self.vocab_size // m) * m if m else self.vocab_size

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def resolved_rnn_width(self) -> int:
        return self.rnn_width or self.d_model

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def pattern(self) -> tuple[str, ...]:
        p = self.layer_pattern
        return tuple(p[i % len(p)] for i in range(self.n_layers))

    def param_count(self) -> int:
        """Total parameters (for 6ND model-FLOPs accounting)."""
        d, v = self.d_model, self.vocab_size
        hd = self.resolved_head_dim
        total = v * d  # embedding
        if not self.tie_embeddings:
            total += v * d
        for kind in self.pattern():
            if kind in ("global", "local"):
                total += d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd)
                total += (self.n_heads * hd) * d
                total += 2 * d  # norms
                if self.qk_norm:
                    total += 2 * hd
                if self.is_moe:
                    total += d * self.n_experts  # router
                    total += self.n_experts * 3 * d * self.d_ff
                else:
                    total += 3 * d * self.d_ff
            elif kind == "rglru":
                w = self.resolved_rnn_width
                total += 2 * d * w + w * d  # in (x2 branches) + out proj
                total += self.conv_width * w  # temporal conv
                total += 2 * w + w  # gates a, input scale Lambda
                total += 2 * d
                total += 3 * d * self.d_ff  # MLP half of the block
            elif kind == "ssd":
                di = self.ssm_expand * self.d_model
                nh = di // self.ssm_head_dim
                g = 1
                proj_in = d * (2 * di + 2 * g * self.ssm_state + nh)
                total += proj_in + di * d
                total += self.conv_width * (di + 2 * g * self.ssm_state)
                total += 2 * nh + d  # A, D, norm
            else:
                raise ValueError(f"unknown layer kind {kind}")
        total += d  # final norm
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only routed experts)."""
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        dense = self.param_count() - self.n_layers * self.n_experts * 3 * d * self.d_ff
        return dense + self.n_layers * self.experts_per_token * 3 * d * self.d_ff


def group_pattern(pattern: tuple[str, ...]) -> list[tuple[tuple[str, ...], int]]:
    """Fold a layer pattern into [(block, repeats)] scan groups.

    Finds the smallest period p such that a prefix of the pattern is p
    repeated >= 2 times, emits that as one group, recurses on the rest.
    A 94-layer uniform stack becomes [((kind,), 94)]; gemma3's 26-layer
    (L L L L L G) x 4 + (L L) becomes [((L,L,L,L,L,G), 4), ((L,L), 1)].
    """
    pattern = tuple(pattern)
    if not pattern:
        return []
    n = len(pattern)
    best: tuple[int, int] | None = None  # (period, repeats)
    for p in range(1, n // 2 + 1):
        k = 1
        while (k + 1) * p <= n and pattern[k * p : (k + 1) * p] == pattern[:p]:
            k += 1
        if k >= 2:
            best = (p, k)
            break  # smallest period wins
    if best is None:
        return [(pattern, 1)]
    p, k = best
    return [(pattern[:p], k)] + group_pattern(pattern[k * p :])
