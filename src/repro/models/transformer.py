"""Model assembly: blocks, scan-group stacking, train/prefill/decode.

The layer pattern from the config is folded into scan groups
(``config.group_pattern``): each group is a block of ``p`` layer kinds
repeated ``k`` times; params/caches carry a leading ``k`` axis and the group
executes as one ``lax.scan`` — a 94-layer MoE compiles as a single loop body.

Block shapes:
    global/local:  x += attn(norm(x));  x += ffn(norm(x))   (ffn = MLP | MoE)
    rglru:         x += rglru(norm(x)); x += mlp(norm(x))
    ssd:           x += ssd(norm(x))                         (self-contained)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention, moe, rglru, sharding, ssd
from repro.models.config import ModelConfig, group_pattern
from repro.models.layers import (
    embed_apply,
    embed_init,
    mlp_apply,
    mlp_init,
    norm_init,
    rms_norm,
    unembed_apply,
)

ATTN_KINDS = ("global", "local")


# ---------------------------------------------------------------------------
# Single blocks
# ---------------------------------------------------------------------------


def block_init(key, cfg: ModelConfig, kind: str) -> dict:
    d = cfg.d_model
    k1, k2 = jax.random.split(key)
    if kind in ATTN_KINDS:
        ffn = moe.moe_init(k2, cfg) if cfg.is_moe else mlp_init(k2, cfg)
        return {
            "norm1": norm_init(d),
            "attn": attention.attn_init(k1, cfg),
            "norm2": norm_init(d),
            "ffn": ffn,
        }
    if kind == "rglru":
        return {
            "norm1": norm_init(d),
            "mixer": rglru.rglru_init(k1, cfg),
            "norm2": norm_init(d),
            "ffn": mlp_init(k2, cfg),
        }
    if kind == "ssd":
        return {"norm": norm_init(d), "mixer": ssd.ssd_init(k1, cfg)}
    raise ValueError(f"unknown layer kind {kind}")


def block_apply(cfg: ModelConfig, kind: str, params: dict, x, positions, cache):
    if kind in ATTN_KINDS:
        window = cfg.window if kind == "local" else 0
        h, new_cache = attention.attn_apply(
            cfg,
            params["attn"],
            rms_norm(x, params["norm1"], cfg.norm_eps),
            window=window,
            positions=positions,
            cache=cache,
        )
        x = x + h
        hin = rms_norm(x, params["norm2"], cfg.norm_eps)
        f = moe.moe_apply(cfg, params["ffn"], hin) if cfg.is_moe else mlp_apply(params["ffn"], hin)
        return x + f, new_cache
    if kind == "rglru":
        h, new_cache = rglru.rglru_apply(
            cfg, params["mixer"], rms_norm(x, params["norm1"], cfg.norm_eps), cache
        )
        x = x + h
        f = mlp_apply(params["ffn"], rms_norm(x, params["norm2"], cfg.norm_eps))
        return x + f, new_cache
    if kind == "ssd":
        h, new_cache = ssd.ssd_apply(
            cfg, params["mixer"], rms_norm(x, params["norm"], cfg.norm_eps), cache
        )
        return x + h, new_cache
    raise ValueError(kind)


def block_cache_init(cfg: ModelConfig, kind: str, batch: int, max_len: int) -> dict:
    if kind in ATTN_KINDS:
        window = cfg.window if kind == "local" else 0
        return attention.init_cache(cfg, batch, max_len, window)
    if kind == "rglru":
        return rglru.init_state(cfg, batch)
    if kind == "ssd":
        return ssd.init_state(cfg, batch)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# The model
# ---------------------------------------------------------------------------


class LM:
    """Decoder LM over the configured layer pattern.

    Params pytree:
      {"embed": {...}, "groups": [group_params, ...], "final_norm": arr}
    where group_params = {"pos{i}": block_params} with leaves stacked over
    the group's repeat axis (absent if repeats == 1).
    """

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        if cfg.scan_unroll:  # cost-accounting mode: no while loops in HLO
            self.groups = [((k,), 1) for k in cfg.pattern()]
        else:
            self.groups = group_pattern(cfg.pattern())  # [(kinds, repeats)]

    # -- init ---------------------------------------------------------------

    def init(self, key) -> dict:
        cfg = self.cfg
        keys = jax.random.split(key, len(self.groups) + 1)
        groups = []
        for (kinds, repeats), gk in zip(self.groups, keys[:-1]):
            pos_params = {}
            for i, kind in enumerate(kinds):
                if repeats == 1:
                    pos_params[f"pos{i}"] = block_init(jax.random.fold_in(gk, i), cfg, kind)
                else:
                    ks = jax.random.split(jax.random.fold_in(gk, i), repeats)
                    stacked = jax.vmap(lambda k: block_init(k, cfg, kind))(ks)
                    pos_params[f"pos{i}"] = stacked
            groups.append(pos_params)
        return {
            "embed": embed_init(keys[-1], cfg),
            "groups": groups,
            "final_norm": norm_init(cfg.d_model),
        }

    def init_cache(self, batch: int, max_len: int) -> list:
        cfg = self.cfg
        caches = []
        for kinds, repeats in self.groups:
            pos_cache = {}
            for i, kind in enumerate(kinds):
                c = block_cache_init(cfg, kind, batch, max_len)
                if repeats > 1:
                    c = jax.tree.map(lambda a: jnp.broadcast_to(a, (repeats,) + a.shape), c)
                pos_cache[f"pos{i}"] = c
            caches.append(pos_cache)
        return caches

    # -- forward ------------------------------------------------------------

    def _run_group(self, kinds, repeats, gparams, x, positions, gcache):
        cfg = self.cfg

        def body(carry, xs):
            h = carry
            params_t, cache_t = xs
            new_caches = {}
            for i, kind in enumerate(kinds):
                c = cache_t[f"pos{i}"] if cache_t is not None else None
                h, nc = block_apply(cfg, kind, params_t[f"pos{i}"], h, positions, c)
                if nc is not None:
                    new_caches[f"pos{i}"] = nc
            h = sharding.constrain_residual(h)  # sequence-parallel boundaries
            return h, (new_caches if new_caches else None)

        if cfg.remat == "block":
            body = jax.checkpoint(body)

        if repeats == 1:
            x, new_cache = body(x, (gparams, gcache))
            return x, new_cache
        x, new_cache = jax.lax.scan(body, x, (gparams, gcache))
        return x, new_cache

    def hidden(
        self,
        params: dict,
        tokens: jax.Array,
        *,
        vision_embeds: jax.Array | None = None,
        cache: list | None = None,
        index: jax.Array | None = None,
    ):
        """Final-norm hidden states (frontend positions stripped) + cache.

        * train/prefill: index=None, positions = arange(T) (plus frontend
          offset); cache=None (train) or init_cache output (prefill).
        * decode: T == 1 and ``index`` = current position scalar.
        """
        cfg = self.cfg
        b, t = tokens.shape
        x = embed_apply(params["embed"], tokens)
        n_front = 0
        if vision_embeds is not None:
            n_front = vision_embeds.shape[1]
            x = jnp.concatenate([vision_embeds.astype(x.dtype), x], axis=1)
        if index is None:
            positions = jnp.arange(x.shape[1], dtype=jnp.int32)[None, :].repeat(b, 0)
        else:
            positions = jnp.full((b, x.shape[1]), index, jnp.int32)
        x = sharding.constrain_residual(x)

        new_caches = []
        for (kinds, repeats), gparams, gcache in zip(
            self.groups,
            params["groups"],
            cache if cache is not None else [None] * len(self.groups),
        ):
            x, nc = self._run_group(kinds, repeats, gparams, x, positions, gcache)
            new_caches.append(nc)

        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        if n_front:
            x = x[:, n_front:]
        return x, (new_caches if cache is not None else None)

    def forward(self, params, tokens, *, vision_embeds=None, cache=None, index=None):
        """Full logits (small sequences / decode).  (logits, new_cache)."""
        x, new_cache = self.hidden(
            params, tokens, vision_embeds=vision_embeds, cache=cache, index=index
        )
        logits = unembed_apply(params["embed"], x, true_vocab=self.cfg.vocab_size)
        return logits, new_cache

    # -- convenience entry points (used by launch/, tests, examples) --------

    def loss(self, params, batch: dict, *, loss_chunk: int = 1024) -> jax.Array:
        """Next-token CE with *chunked* unembedding: the (B, chunk, V) f32
        logits block is the only vocab-sized activation ever materialised
        (rematted in backward), instead of a (B, T, V) monster."""
        tokens = batch["tokens"]
        # Full-T hidden pass (keeps T divisible by attention/scan blocks);
        # the final position has no target and is masked out below.
        x, _ = self.hidden(
            params, tokens, vision_embeds=batch.get("vision_embeds")
        )
        targets = jnp.concatenate(
            [tokens[:, 1:], jnp.full((tokens.shape[0], 1), -1, tokens.dtype)], axis=1
        )
        b, t, d = x.shape
        chunk = min(loss_chunk, t)
        pad = (-t) % chunk
        if pad:
            x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
            targets = jnp.pad(targets, ((0, 0), (0, pad)), constant_values=-1)
        nc = (t + pad) // chunk
        xs = x.reshape(b, nc, chunk, d).transpose(1, 0, 2, 3)
        ts = targets.reshape(b, nc, chunk).transpose(1, 0, 2)

        @jax.checkpoint
        def body(carry, inp):
            xc, tc = inp
            logits = unembed_apply(params["embed"], xc, true_vocab=self.cfg.vocab_size)
            lse = jax.nn.logsumexp(logits, axis=-1)
            tgt = jnp.take_along_axis(logits, jnp.maximum(tc, 0)[..., None], axis=-1)[..., 0]
            mask = (tc >= 0).astype(jnp.float32)
            return carry + jnp.sum((lse - tgt) * mask), None

        total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xs, ts))
        return total / (b * (tokens.shape[1] - 1))

    def decode_step(self, params, cache, tokens, index):
        """One serving step: tokens (B, 1), index () -> (logits, new_cache)."""
        return self.forward(params, tokens, cache=cache, index=index)

    def prefill(self, params, tokens, max_len: int, vision_embeds=None):
        """Returns (*last-position* logits (B, V), cache) — the production
        semantics; full-prompt logits are never materialised."""
        cache = self.init_cache(tokens.shape[0], max_len)
        x, cache = self.hidden(
            params, tokens, cache=cache, vision_embeds=vision_embeds
        )
        logits = unembed_apply(
            params["embed"], x[:, -1:], true_vocab=self.cfg.vocab_size
        )
        return logits[:, 0], cache
