"""Publish policies: when does a tenant's live sketch become a snapshot?

The tracker side of the runtime ingests continuously; the serving side
reads immutable versioned snapshots from the ``SketchStore``.  A
``PublishPolicy`` decides, after every ingest super-step, whether the gap
between the live sketch and the last published version justifies a new
version.  Publishing is cheap (one host copy of an (l, d) matrix) but not
free: every version is a spectrum-cache miss for the serving engine, so
policies trade snapshot freshness against cache churn.
"""
from __future__ import annotations

import abc

__all__ = ["PublishPolicy", "EveryKSteps", "FrobDrift", "OnDemand"]


class PublishPolicy(abc.ABC):
    #: Whether the policy reads ``live_frob``.  When False the pipeline
    #: skips computing the tracker's Frobenius estimate each ingest step
    #: (for P3 that materializes the whole estimator matrix).
    needs_live_frob: bool = True

    @abc.abstractmethod
    def should_publish(
        self,
        *,
        steps_since_publish: int,
        live_frob: float,
        published_frob: float | None,
    ) -> bool:
        """Decide right after an ingest step.

        steps_since_publish: ingest steps since the last publish (>= 1).
        live_frob:           the tracker's current ``||A||_F^2`` estimate.
        published_frob:      the last published snapshot's estimate, or
                             None if this tenant has never published.
        """


class EveryKSteps(PublishPolicy):
    """Publish after every k ingest steps (k=1: a snapshot per super-step)."""

    needs_live_frob = False

    def __init__(self, k: int = 1):
        if k < 1:
            raise ValueError(f"k must be >= 1, got {k}")
        self.k = k

    def should_publish(self, *, steps_since_publish, live_frob, published_frob):
        return steps_since_publish >= self.k

    def __repr__(self):
        return f"EveryKSteps(k={self.k})"


class FrobDrift(PublishPolicy):
    """Publish when the stream mass grew by a relative factor.

    The paper's protocols themselves only react when ``||A||_F^2`` drifts by
    (1 + eps); serving snapshots on the same geometric schedule keeps the
    store's version count logarithmic in the stream mass while bounding the
    staleness of any served answer to one ``rel`` factor.  A tenant that has
    never published always publishes.
    """

    def __init__(self, rel: float = 0.1):
        if rel <= 0:
            raise ValueError(f"rel must be > 0, got {rel}")
        self.rel = rel

    def should_publish(self, *, steps_since_publish, live_frob, published_frob):
        if published_frob is None:
            return True
        return live_frob > (1.0 + self.rel) * published_frob

    def __repr__(self):
        return f"FrobDrift(rel={self.rel})"


class OnDemand(PublishPolicy):
    """Never auto-publish; snapshots appear only via ``pipeline.publish()``."""

    needs_live_frob = False

    def should_publish(self, *, steps_since_publish, live_frob, published_frob):
        return False

    def __repr__(self):
        return "OnDemand()"
