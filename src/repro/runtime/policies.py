"""Tenant policies: when to publish, and how much serving a tenant may queue.

The tracker side of the runtime ingests continuously; the serving side
reads immutable versioned snapshots from the ``SketchStore``.  A
``PublishPolicy`` decides, after every ingest super-step, whether the gap
between the live sketch and the last published version justifies a new
version.  Publishing is cheap (one host copy of an (l, d) matrix) but not
free: every version is a spectrum-cache miss for the serving engine, so
policies trade snapshot freshness against cache churn.

``TenantQuota`` is the admission-side policy: a bound on how many queries a
tenant may hold queued in the ``PackedQueryService`` at once (overflow is
shed at submit time with a typed error — never silently dropped) and a
priority that orders tenants inside each capped packed dispatch sweep.

Policies are plain-config objects; ``policy_to_config``/``policy_from_config``
round-trip them through JSON so a ``StreamingPipeline`` checkpoint can
restore each tenant's publish cadence exactly.
"""
from __future__ import annotations

import abc
from typing import NamedTuple

__all__ = [
    "PublishPolicy",
    "EveryKSteps",
    "FrobDrift",
    "OnDemand",
    "OnWindowClose",
    "TenantQuota",
    "RetryPolicy",
    "policy_to_config",
    "policy_from_config",
]


class PublishPolicy(abc.ABC):
    """Decides when a tenant's live sketch becomes a served store version."""

    #: Whether the policy reads ``live_frob``.  When False the pipeline
    #: skips computing the tracker's Frobenius estimate each ingest step
    #: (for P3 that materializes the whole estimator matrix).
    needs_live_frob: bool = True

    #: Whether the policy reads ``windows_closed``.  When True the pipeline
    #: passes the tenant adapter's closed-window count as an extra keyword
    #: after each ingest step (only windowed adapters track one).
    needs_window_close: bool = False

    @abc.abstractmethod
    def should_publish(
        self,
        *,
        steps_since_publish: int,
        live_frob: float,
        published_frob: float | None,
    ) -> bool:
        """Decide right after an ingest step.

        steps_since_publish: ingest steps since the last publish (>= 1).
        live_frob:           the tracker's current ``||A||_F^2`` estimate.
        published_frob:      the last published snapshot's estimate, or
                             None if this tenant has never published.
        """


class EveryKSteps(PublishPolicy):
    """Publish after every k ingest steps (k=1: a snapshot per super-step)."""

    needs_live_frob = False

    def __init__(self, k: int = 1):
        if k < 1:
            raise ValueError(f"k must be >= 1, got {k}")
        self.k = k

    def should_publish(self, *, steps_since_publish, live_frob, published_frob):
        """Publish iff k ingest steps have accumulated since the last one."""
        return steps_since_publish >= self.k

    def __repr__(self):
        return f"EveryKSteps(k={self.k})"


class FrobDrift(PublishPolicy):
    """Publish when the stream mass grew by a relative factor.

    The paper's protocols themselves only react when ``||A||_F^2`` drifts by
    (1 + eps); serving snapshots on the same geometric schedule keeps the
    store's version count logarithmic in the stream mass while bounding the
    staleness of any served answer to one ``rel`` factor.  A tenant that has
    never published always publishes.
    """

    def __init__(self, rel: float = 0.1):
        if rel <= 0:
            raise ValueError(f"rel must be > 0, got {rel}")
        self.rel = rel

    def should_publish(self, *, steps_since_publish, live_frob, published_frob):
        """Publish on first call, then only on > (1+rel) relative mass growth."""
        if published_frob is None:
            return True
        return live_frob > (1.0 + self.rel) * published_frob

    def __repr__(self):
        return f"FrobDrift(rel={self.rel})"


class OnDemand(PublishPolicy):
    """Never auto-publish; snapshots appear only via ``pipeline.publish()``."""

    needs_live_frob = False

    def should_publish(self, *, steps_since_publish, live_frob, published_frob):
        """Never auto-publish."""
        return False

    def __repr__(self):
        return "OnDemand()"


class OnWindowClose(PublishPolicy):
    """Publish whenever the tenant's watermark closes a window bucket.

    The natural cadence for windowed tenants: a version appears exactly
    when a bucket boundary passes the watermark, so every served snapshot
    corresponds to a completed window edge rather than an arbitrary step
    count.  ``seen`` tracks the closed-window count already published
    (checkpointed, so a restored pipeline does not re-publish old edges).
    Non-windowed adapters never report closed windows, so attaching this
    policy to one behaves like ``OnDemand``.
    """

    needs_live_frob = False
    needs_window_close = True

    def __init__(self, seen: int = 0):
        if seen < 0:
            raise ValueError(f"seen must be >= 0, got {seen}")
        self.seen = int(seen)

    def should_publish(
        self, *, steps_since_publish, live_frob, published_frob, windows_closed=0
    ):
        """Publish iff new window buckets closed since the last publish."""
        if windows_closed > self.seen:
            self.seen = int(windows_closed)
            return True
        return False

    def __repr__(self):
        return f"OnWindowClose(seen={self.seen})"


# ---------------------------------------------------------------------------
# Admission quotas / priorities (enforced by query.service.PackedQueryService)
# ---------------------------------------------------------------------------


class TenantQuota(NamedTuple):
    """Per-tenant admission policy for the packed query service.

    max_pending: bound on queued-but-unserved queries for the tenant; a
                 submit beyond it is *shed* — rejected with a typed
                 ``QueryShedError`` and counted in service stats, never
                 silently dropped (0 = unbounded).
    priority:    tenants are packed into each capped dispatch sweep in
                 descending priority order (ties broken by tenant name), so
                 under overload high-priority tenants are served first.
    """

    max_pending: int = 0
    priority: int = 0


# ---------------------------------------------------------------------------
# Transport resilience (consumed by repro.cluster's router/transport layer)
# ---------------------------------------------------------------------------


class RetryPolicy(NamedTuple):
    """Capped exponential backoff with deterministic jitter.

    A message gets ``max_attempts`` total sends; retry ``k`` (1-based)
    waits ``min(cap_s, base_s * 2**(k-1))``, reduced by up to ``jitter``
    fraction via a caller-supplied uniform draw (the router feeds a
    seeded PRNG so the whole backoff schedule is reproducible).  The
    spent budget — retries issued and seconds slept — is surfaced in
    ``ClusterRouter.stats()``.
    """

    max_attempts: int = 4
    base_s: float = 0.01
    cap_s: float = 1.0
    jitter: float = 0.5

    def validate(self) -> "RetryPolicy":
        """Raise on nonsensical parameters; returns self for chaining."""
        if self.max_attempts < 1:
            raise ValueError(f"max_attempts must be >= 1, got {self.max_attempts}")
        if self.base_s < 0 or self.cap_s < 0:
            raise ValueError("backoff delays must be >= 0")
        if not 0.0 <= self.jitter <= 1.0:
            raise ValueError(f"jitter must be in [0, 1], got {self.jitter}")
        return self

    def backoff_s(self, attempt: int, u: float = 0.0) -> float:
        """Sleep before retry ``attempt`` (1-based); ``u`` in [0, 1) jitters."""
        raw = min(self.cap_s, self.base_s * (2.0 ** (attempt - 1)))
        return raw * (1.0 - self.jitter * u)


# ---------------------------------------------------------------------------
# Policy <-> JSON config (for pipeline checkpoints)
# ---------------------------------------------------------------------------

_POLICY_TYPES = {
    "EveryKSteps": EveryKSteps,
    "FrobDrift": FrobDrift,
    "OnDemand": OnDemand,
    "OnWindowClose": OnWindowClose,
}


def policy_to_config(policy: PublishPolicy) -> dict:
    """Serialize a policy to a JSON-able ``{"type": ..., params...}`` dict."""
    if isinstance(policy, EveryKSteps):
        return {"type": "EveryKSteps", "k": policy.k}
    if isinstance(policy, FrobDrift):
        return {"type": "FrobDrift", "rel": policy.rel}
    if isinstance(policy, OnDemand):
        return {"type": "OnDemand"}
    if isinstance(policy, OnWindowClose):
        return {"type": "OnWindowClose", "seen": policy.seen}
    raise TypeError(
        f"cannot serialize publish policy {policy!r}; custom policies must be "
        "re-attached after StreamingPipeline.load"
    )


def policy_from_config(config: dict) -> PublishPolicy:
    """Invert ``policy_to_config``."""
    kw = {k: v for k, v in config.items() if k != "type"}
    try:
        cls = _POLICY_TYPES[config["type"]]
    except KeyError:
        raise ValueError(f"unknown publish policy config {config!r}") from None
    return cls(**kw)
