"""StreamingPipeline: many tenants' ingest→publish→serve as one object.

The paper's model is a single continuous loop — sites stream rows, the
coordinator maintains a sketch, queries are answered at any time.  The repo
previously split that loop across three layers the caller had to glue by
hand (tracker updates, store publishes, service flushes).  The pipeline
owns the whole lifecycle for a fleet of tenants, and a tenant may be any
workload kind in the registry: matrix tracking (paper Section 5), weighted
heavy hitters (Section 4), distributed quantiles (Yi--Zhang), or
leverage-score row sampling (Boutsidis--Woodruff--Zhong)::

    pipeline = StreamingPipeline(mesh, policy=EveryKSteps(4))
    pipeline.add_tenant("run-a", d=64)                       # matrix
    pipeline.add_hh_tenant("clicks", eps=0.05,
                           quota=TenantQuota(max_pending=64, priority=5))
    pipeline.add_quantile_tenant("latency", eps=0.02)
    pipeline.add_leverage_tenant("rowspace", d=64, eps=0.1)

    pipeline.ingest("run-a", rows)         # super-step + policy publish
    pipeline.ingest("clicks", pairs)       # (n, 2) [element, weight] rows
    pipeline.ingest("latency", samples)    # (n, 2) [value, weight] rows
    pipeline.ingest("rowspace", rows)      # (n, d) rows, like matrix
    t = pipeline.submit("run-a", x, deadline_s=0.005)
    e = pipeline.submit("clicks", np.array([element_id], np.float32))
    q = pipeline.submit("latency", quantile_query(0.99))
    s = pipeline.submit("rowspace", subspace_query(x))
    pipeline.poll()                        # deadline pump (packed sweep)
    estimate, bound, version = t.result()

Ingest drives the tenant's protocol one super-step and asks its
``PublishPolicy`` whether the live state drifted enough to become a new
immutable ``SketchStore`` version (matrix tenants publish their sketch B,
HH tenants their encoded estimate table, quantile tenants their sorted
[value, rank] table, leverage tenants their [row | score | weight]
sample).  Queries are admitted through a
``PackedQueryService`` under per-tenant ``TenantQuota``s: overflow is shed
with a typed error, and each dispatch sweep packs tenants in priority
order — matrix batches that share (l, d) ride one packed quadform launch,
HH and quantile lookups ride the same sweep without a kernel, leverage
subspace/score queries ride weighted quadform / levscore sweeps.  Deadlines
are held either cooperatively (every ``ingest`` pumps ``poll()``) or by a
``ServicePump`` background thread the pipeline owns — pass
``pump_interval_s`` (or call ``start_pump``) and expiry fires even while
ingest is idle; ``close()`` (or the context manager) stops it.
``save``/``load`` persist the *whole* pipeline — published store versions
and every tenant's live protocol state — through ``repro.ckpt``, so a
restarted coordinator resumes ingest mid-stream and answers identically
(the pump is stopped around the checkpoint write and restarted after, and
``load`` revives it).
"""
from __future__ import annotations

import math
from typing import Iterable, NamedTuple

import jax
import numpy as np

from repro.core.windows import LateRowError, TimedRows
from repro.obs import Observability, rehome_families
from repro.query import QueryEngine, SketchStore
from repro.query.service import PackedQueryService, QueryTicket, ServicePump
from repro.runtime.policies import (
    EveryKSteps,
    PublishPolicy,
    TenantQuota,
    policy_from_config,
    policy_to_config,
)

__all__ = ["StreamingPipeline", "TenantStats"]


class TenantStats(NamedTuple):
    """One tenant's lifetime pipeline counters."""

    tenant: str
    steps: int  # ingest super-steps absorbed
    rows: int  # stream rows / weighted elements absorbed
    publishes: int  # snapshots auto- or force-published
    latest_version: int | None
    live_frob: float  # live stream-mass estimate (||A||_F^2, or W for HH/quantile)
    comm_total: int  # protocol messages spent (paper units)
    workload: str = "matrix"  # "matrix" | "hh" | "quantile" | "leverage" | "windowed"


class _MatrixAdapter:
    """Uniform ingest/publish face over a ``DistributedMatrixTracker``."""

    workload = "matrix"

    def __init__(self, tracker):
        self.tracker = tracker

    def ingest(self, rows, ts: float | None = None) -> None:
        """Advance the tracker one super-step on an (n, d) row batch."""
        if ts is not None:
            raise ValueError(
                "matrix tenants are full-stream: timestamps only apply to "
                "windowed tenants (add_windowed_tenant)"
            )
        self.tracker.update(rows)

    def live_mass(self) -> float:
        """Live ``||A||_F^2`` estimate (what publish policies read)."""
        return self.tracker.frob_estimate()

    def publish_time(self, clock) -> float:
        """Snapshot timeline stamp: wall-clock for full-stream tenants."""
        return float(clock())

    def publish(self, store, tenant: str, meta: dict, published_at: float = 0.0):
        """Publish the coordinator sketch B as the tenant's next version."""
        return self.tracker.publish(
            store, tenant, meta=meta, published_at=published_at
        )

    def check_query(self, x: np.ndarray) -> None:
        """Reject wrong-shape queries at the submitter (see pipeline.submit)."""
        d = self.tracker.cfg.d
        if x.shape != (d,):
            raise ValueError(
                f"matrix tenants take a ({d},) direction, got shape {x.shape}"
            )

    def rows(self) -> int:
        """Stream rows absorbed so far."""
        return self.tracker.rows_fed

    def comm_report(self):
        """Protocol messages spent, in the paper's units."""
        return self.tracker.comm_report()

    def state_payload(self):
        """Live protocol state as ``(arrays, meta)`` checkpoint halves."""
        return self.tracker.state_payload()

    def restore_payload(self, arrays, meta) -> None:
        """Restore a ``state_payload`` capture bit-identically."""
        self.tracker.restore_payload(arrays, meta)

    def ctor_meta(self) -> dict:
        """Construction parameters ``load`` needs to rebuild the tenant."""
        cfg = self.tracker.cfg
        return {"protocol": self.tracker.protocol, "d": cfg.d, "eps": cfg.eps}

    @property
    def target(self):
        """The wrapped tracker (what ``pipeline.tracker()`` returns)."""
        return self.tracker


class _RegistryAdapter:
    """Uniform ingest/publish face over a registry protocol (HH, quantile).

    Everything a registered ``(kind, engine, name)`` protocol exposes is
    uniform — ``step``/``total_weight``/``snapshot_matrix`` plus the
    checkpoint contract — so this one adapter serves every non-matrix
    kind; subclasses only pin ``workload`` and the per-kind query shape.
    """

    workload = ""  # set by subclasses; also the snapshot meta tag

    def __init__(self, proto, ctor_kw: dict):
        self.proto = proto
        self._ctor_kw = ctor_kw

    def ingest(self, pairs, ts: float | None = None) -> None:
        """Advance the protocol one step on an (n, 2) ingest batch."""
        if ts is not None:
            raise ValueError(
                f"{self.workload} tenants are full-stream: timestamps only "
                "apply to windowed tenants (add_windowed_tenant)"
            )
        self.proto.step(pairs)

    def live_mass(self) -> float:
        """Live total-weight estimate ``hat{W}`` (what publish policies read)."""
        return self.proto.total_weight()

    def check_query(self, x: np.ndarray) -> None:
        """Reject wrong-shape queries at the submitter (see pipeline.submit)."""
        raise NotImplementedError

    def publish_time(self, clock) -> float:
        """Snapshot timeline stamp: wall-clock for full-stream tenants."""
        return float(clock())

    def publish(self, store, tenant: str, meta: dict, published_at: float = 0.0):
        """Publish the encoded snapshot table as the tenant's next version."""
        md = {
            "workload": self.workload,
            "protocol": self.proto.name,
            "engine": self.proto.engine,
            "m": self.proto.m,
        }
        md.update(meta)
        return store.publish(
            tenant,
            self.proto.snapshot_matrix(),
            frob=self.proto.total_weight(),
            eps=self.proto.eps,
            n_seen=self.proto.rows_seen,
            meta=md,
            published_at=published_at,
        )

    def rows(self) -> int:
        """Weighted items absorbed so far."""
        return self.proto.rows_seen

    def comm_report(self):
        """Protocol messages spent, in the paper's units."""
        return self.proto.comm_report()

    def state_payload(self):
        """Live protocol state as ``(arrays, meta)`` checkpoint halves."""
        return self.proto.state_payload()

    def restore_payload(self, arrays, meta) -> None:
        """Restore a ``state_payload`` capture bit-identically."""
        self.proto.restore_payload(arrays, meta)

    def ctor_meta(self) -> dict:
        """Construction parameters ``load`` needs to rebuild the tenant."""
        return {
            "protocol": self.proto.name,
            "engine": self.proto.engine,
            "eps": self.proto.eps,
            "kw": dict(self._ctor_kw),
        }

    @property
    def target(self):
        """The wrapped protocol (what ``pipeline.tracker()`` returns)."""
        return self.proto


class _HHAdapter(_RegistryAdapter):
    """Registry adapter for ``HHProtocol`` tenants."""

    workload = "hh"

    def check_query(self, x: np.ndarray) -> None:
        """Reject wrong-shape queries at the submitter (see pipeline.submit)."""
        if x.shape != (1,):
            raise ValueError(
                f"HH tenants take a (1,) element id, got shape {x.shape}"
            )


class _QuantileAdapter(_RegistryAdapter):
    """Registry adapter for ``QuantileProtocol`` tenants."""

    workload = "quantile"

    def check_query(self, x: np.ndarray) -> None:
        """Reject wrong-shape queries at the submitter (see pipeline.submit)."""
        from repro.core.quantiles import QUERY_QUANTILE, QUERY_RANK

        if x.shape != (2,):
            raise ValueError(
                f"quantile tenants take a (2,) [mode, arg] query, got shape "
                f"{x.shape} (use core.quantiles.rank_query / quantile_query)"
            )
        if x[0] not in (QUERY_RANK, QUERY_QUANTILE):
            raise ValueError(
                f"quantile query mode must be {QUERY_RANK} (rank) or "
                f"{QUERY_QUANTILE} (phi-quantile), got {x[0]}"
            )


class _LeverageAdapter(_RegistryAdapter):
    """Registry adapter for ``LeverageProtocol`` tenants."""

    workload = "leverage"

    def check_query(self, x: np.ndarray) -> None:
        """Reject wrong-shape queries at the submitter (see pipeline.submit)."""
        from repro.core.leverage import QUERY_SCORE, QUERY_SUBSPACE

        d = self.proto.d
        if x.shape != (d + 1,):
            raise ValueError(
                f"leverage tenants take a ({d + 1},) [mode, x] query, got "
                f"shape {x.shape} (use core.leverage.subspace_query / "
                "score_query)"
            )
        if x[0] not in (QUERY_SUBSPACE, QUERY_SCORE):
            raise ValueError(
                f"leverage query mode must be {QUERY_SUBSPACE} (subspace) or "
                f"{QUERY_SCORE} (score), got {x[0]}"
            )

    def publish(self, store, tenant: str, meta: dict, published_at: float = 0.0):
        """Publish the sample table, pinning the live ridge in the metadata."""
        return super().publish(
            store,
            tenant,
            {"lam": self.proto.lam(), "d": self.proto.d, **meta},
            published_at=published_at,
        )

    def ctor_meta(self) -> dict:
        """Construction parameters ``load`` needs to rebuild the tenant."""
        return {**super().ctor_meta(), "d": self.proto.d}


class _WindowedAdapter(_RegistryAdapter):
    """Registry adapter for time-windowed tenants of any protocol kind.

    The checkpoint manifest records ``workload = "windowed"`` (so ``load``
    rebuilds through ``add_windowed_tenant``), but published snapshots are
    tagged with the *underlying* kind — a windowed matrix snapshot rides
    the engine's packed quadform sweeps, a windowed HH snapshot its lookup
    sweep, and so on: windowed tenants serve through the exact same
    ``query_packed`` / router / replica / checkpoint machinery as
    full-stream ones.
    """

    workload = "windowed"

    def ingest(self, rows, ts: float | None = None) -> None:
        """Advance the windowed protocol one step at event time ``ts``."""
        self.proto.step(rows, ts=ts)

    def windows_closed(self) -> int:
        """Buckets sealed by the watermark so far (OnWindowClose's signal)."""
        return self.proto.windows_closed()

    def window_lag(self) -> float:
        """Event-time spread still parked behind the watermark (gauge)."""
        return self.proto.window_lag()

    def publish_time(self, clock) -> float:
        """Snapshot timeline stamp: the event-time watermark, not wall-clock."""
        wm = self.proto.watermark()
        return float(wm) if math.isfinite(wm) else 0.0

    def publish(self, store, tenant: str, meta: dict, published_at: float = 0.0):
        """Publish the in-window fold tagged as the underlying kind."""
        md = {
            "workload": self.proto.kind,
            "protocol": self.proto.name,
            "engine": self.proto.engine,
            "m": self.proto.m,
            "windowed": True,
            "windows_closed": self.proto.windows_closed(),
        }
        if self.proto.kind == "leverage":
            md["lam"] = self.proto.lam()
            md["d"] = self.proto.d
        md.update(meta)
        return store.publish(
            tenant,
            self.proto.snapshot_matrix(),
            frob=self.proto.total_weight(),
            eps=self.proto.eps,
            n_seen=self.proto.rows_seen,
            meta=md,
            published_at=published_at,
        )

    def check_query(self, x: np.ndarray) -> None:
        """Delegate to the underlying kind's query-shape contract."""
        kind = self.proto.kind
        if kind == "matrix":
            d = self.proto.d
            if x.shape != (d,):
                raise ValueError(
                    f"matrix tenants take a ({d},) direction, got shape {x.shape}"
                )
        elif kind == "hh":
            _HHAdapter.check_query(self, x)
        elif kind == "quantile":
            _QuantileAdapter.check_query(self, x)
        else:
            _LeverageAdapter.check_query(self, x)

    def ctor_meta(self) -> dict:
        """Construction parameters ``load`` needs to rebuild the tenant."""
        meta = {**super().ctor_meta(), "kind": self.proto.kind}
        if self.proto.kind in ("matrix", "leverage"):
            meta["d"] = self.proto.d
        return meta


class _Tenant:
    __slots__ = ("adapter", "policy", "quota", "steps", "steps_since_publish",
                 "publishes", "published_frob", "latest_version", "metrics")

    def __init__(self, adapter, policy: PublishPolicy, quota: TenantQuota | None):
        self.adapter = adapter
        self.policy = policy
        self.quota = quota
        self.steps = 0
        self.steps_since_publish = 0
        self.publishes = 0
        self.published_frob: float | None = None
        self.latest_version: int | None = None
        # Per-tenant gauge handles (f_hat / version / lag), cached here so
        # the hot ingest path never re-resolves labeled series.
        self.metrics: dict = {}


class StreamingPipeline:
    """Owns trackers, store, engine, and packed service for many tenants."""

    # Ingest-side counters (see stats() with no tenant): every one is
    # lifetime-cumulative; ingest_s is wall time inside protocol steps
    # (packed launches + serial steps), excluding publishes and query
    # pumping.  Stored as ``repro_ingest_<key>_total`` counters in the
    # obs registry (ingest_s as ``repro_ingest_seconds_total``).
    _INGEST_KEYS = (
        ("rows", "Real stream rows / weighted items absorbed."),
        ("batches", "Ingest batches absorbed (serial + packed slices)."),
        ("waves", "ingest_many waves driven."),
        ("packed_launches", "Stacked super-step launches."),
        ("packed_tenants", "Tenant-batches that rode a packed launch."),
        ("packed_rows", "Real rows absorbed via packed launches."),
        ("pad_rows", "Zero-filled slots added while packing."),
        ("serial_steps", "Per-tenant serial protocol steps."),
        ("retraces", "Packed launch shapes compiled (XLA traces)."),
        # restacks: packed launches that could not reuse a resident stacked
        # state (first wave of a group, or a member stepped / restored
        # out-of-band since the last wave).
        ("restacks", "Packed launches that had to restack member states."),
        ("late_rows", "Rows shed for arriving behind a windowed tenant's watermark."),
        ("ingest_s", "Wall time inside protocol steps."),
    )

    _FAMILIES = tuple(
        ("counter",
         f"repro_ingest_{'seconds' if k == 'ingest_s' else k}_total", h)
        for k, h in _INGEST_KEYS
    ) + (
        ("counter", "repro_publish_total", "Snapshots published to the store."),
        ("counter", "repro_publish_seconds_total", "Wall time spent publishing."),
        ("histogram", "repro_publish_latency_seconds", "Publish latency per snapshot."),
        ("gauge", "repro_tenant_f_hat", "Published Frobenius mass per tenant."),
        ("gauge", "repro_tenant_version", "Latest published store version per tenant."),
        ("gauge", "repro_tenant_publish_lag_steps", "Ingest steps since the tenant last published."),
        ("gauge", "repro_tenant_window_lag", "Event-time lag behind the watermark per windowed tenant."),
        ("gauge", "repro_comm_scalar_msgs", "Protocol communication accounting (paper units)."),
        ("gauge", "repro_comm_row_msgs", "Protocol communication accounting (paper units)."),
        ("gauge", "repro_comm_broadcast_events", "Protocol communication accounting (paper units)."),
        ("gauge", "repro_comm_m", "Protocol communication accounting (paper units)."),
        ("gauge", "repro_comm_total", "Protocol communication accounting (paper units)."),
    )

    def __init__(
        self,
        mesh: jax.sharding.Mesh,
        *,
        eps: float = 0.1,
        axis: str = "data",
        protocol: str = "P2",
        policy: PublishPolicy | None = None,
        store: SketchStore | None = None,
        retain: int = 0,
        interpret: bool | None = None,
        max_batch: int = 1024,
        default_deadline_s: float = 0.02,
        pump_interval_s: float | None = None,
        obs: Observability | None = None,
    ):
        self.mesh = mesh
        self.axis = axis
        self.default_eps = eps
        self.default_protocol = protocol
        self.default_policy = policy if policy is not None else EveryKSteps(1)
        self.store = store if store is not None else SketchStore(retain=retain)
        self.obs = obs if obs is not None else Observability()
        self.engine = QueryEngine(self.store, interpret=interpret, obs=self.obs)
        self.service = PackedQueryService(
            self.engine, max_batch=max_batch, default_deadline_s=default_deadline_s,
            obs=self.obs,
        )
        self._tenants: dict[str, _Tenant] = {}
        self._bind_metrics()
        # Deadline executor: None means cooperative pumping (every ingest
        # calls service.poll()); an interval starts a ServicePump thread
        # the pipeline owns, and ingest stops pumping cooperatively.
        self.pump: ServicePump | None = None
        if pump_interval_s is not None:
            self.start_pump(pump_interval_s)

    # -- telemetry binding ----------------------------------------------------

    def _bind_metrics(self) -> None:
        self._m_ingest = {
            k: self.obs.handle(
                "counter",
                f"repro_ingest_{'seconds' if k == 'ingest_s' else k}_total", h,
            )
            for k, h in self._INGEST_KEYS
        }
        self._m_publish = self.obs.handle(
            "counter", "repro_publish_total", "Snapshots published to the store.")
        self._m_publish_s = self.obs.handle(
            "counter", "repro_publish_seconds_total", "Wall time spent publishing.")
        self._m_publish_latency = self.obs.handle(
            "histogram", "repro_publish_latency_seconds",
            "Publish latency per snapshot.")
        for name, t in self._tenants.items():
            t.metrics = self._tenant_gauges(
                name, windowed=hasattr(t.adapter, "window_lag")
            )

    def _tenant_gauges(self, tenant: str, *, windowed: bool = False) -> dict:
        labels = {"tenant": tenant}
        handles = {
            "f_hat": self.obs.handle(
                "gauge", "repro_tenant_f_hat",
                "Published Frobenius mass per tenant.", labels=labels),
            "version": self.obs.handle(
                "gauge", "repro_tenant_version",
                "Latest published store version per tenant.", labels=labels),
            "lag": self.obs.handle(
                "gauge", "repro_tenant_publish_lag_steps",
                "Ingest steps since the tenant last published.", labels=labels),
        }
        if windowed:
            handles["window_lag"] = self.obs.handle(
                "gauge", "repro_tenant_window_lag",
                "Event-time lag behind the watermark per windowed tenant.",
                labels=labels)
        return handles

    def bind_obs(self, obs: Observability) -> None:
        """Re-home the whole serving stack's telemetry into ``obs``.

        Carries pipeline, engine, and service families (values merged, old
        series dropped on a same-registry relabel) and re-fetches every
        cached handle — including the per-tenant gauges — under the new
        bundle's base labels.
        """
        old, self.obs = self.obs, obs
        rehome_families(old, obs, self._FAMILIES)
        self._bind_metrics()
        self.engine.bind_obs(obs)
        self.service.bind_obs(obs)

    # -- deadline executor lifecycle ------------------------------------------

    def start_pump(self, interval_s: float = 0.001) -> ServicePump:
        """Start (or restart) the background deadline executor.

        While a pump runs, per-entry deadlines hold with no cooperative
        ``poll()`` calls from the ingest loop — ``ingest`` stops pumping.
        """
        if self.pump is not None:
            self.pump.stop()
        self.pump = ServicePump(self.service, interval_s=interval_s)
        return self.pump.start()

    def stop_pump(self) -> None:
        """Stop the background deadline executor (cooperative pumping resumes)."""
        pump, self.pump = self.pump, None
        if pump is not None:
            pump.stop()

    def close(self) -> None:
        """Release background resources (stops the pump thread if running)."""
        self.stop_pump()

    def __enter__(self) -> "StreamingPipeline":
        return self

    def __exit__(self, *exc_info) -> None:
        self.close()

    # -- tenant lifecycle ----------------------------------------------------

    def _register(self, tenant: str, adapter, policy, quota) -> None:
        t = _Tenant(adapter, policy or self.default_policy, quota)
        t.metrics = self._tenant_gauges(
            tenant, windowed=hasattr(adapter, "window_lag")
        )
        self._tenants[tenant] = t
        if quota is not None:
            self.service.set_quota(
                tenant, max_pending=quota.max_pending, priority=quota.priority
            )

    def add_tenant(
        self,
        tenant: str,
        d: int,
        *,
        eps: float | None = None,
        protocol: str | None = None,
        policy: PublishPolicy | None = None,
        quota: TenantQuota | None = None,
    ):
        """Register a matrix-tracking tenant stream; returns its tracker."""
        from repro.core.tracker import DistributedMatrixTracker

        if tenant in self._tenants:
            raise ValueError(f"tenant {tenant!r} already registered")
        tracker = DistributedMatrixTracker(
            self.mesh,
            d,
            eps=self.default_eps if eps is None else eps,
            axis=self.axis,
            protocol=self.default_protocol if protocol is None else protocol,
        )
        self._register(tenant, _MatrixAdapter(tracker), policy, quota)
        return tracker

    def add_hh_tenant(
        self,
        tenant: str,
        *,
        eps: float | None = None,
        protocol: str = "P1",
        engine: str = "event",
        policy: PublishPolicy | None = None,
        quota: TenantQuota | None = None,
        **kw,
    ):
        """Register a weighted heavy-hitter tenant; returns its protocol.

        ``engine="event"`` runs the paper-exact simulator in-process
        (``m`` defaults to the mesh axis size; pass ``m=...`` to override);
        ``engine="shard"`` runs the shard_map MG-merge super-step engine on
        the pipeline's mesh.  Extra ``kw`` (``k``, ``s``, ``seed``) pass
        through to the registered protocol factory and are recorded so
        ``load`` rebuilds the tenant identically.
        """
        from repro.runtime.registry import create_protocol

        if tenant in self._tenants:
            raise ValueError(f"tenant {tenant!r} already registered")
        if engine not in ("event", "shard"):
            raise ValueError(f"unknown HH engine {engine!r}; choose 'event' or 'shard'")
        eps = self.default_eps if eps is None else eps
        kw = dict(kw)
        if engine == "shard":
            proto = create_protocol(
                protocol, engine="shard", kind="hh",
                mesh=self.mesh, eps=eps, axis=self.axis, **kw,
            )
        else:
            kw.setdefault("m", self.mesh.shape[self.axis])
            proto = create_protocol(
                protocol, engine="event", kind="hh", eps=eps, **kw,
            )
        self._register(tenant, _HHAdapter(proto, kw), policy, quota)
        return proto

    def add_quantile_tenant(
        self,
        tenant: str,
        *,
        eps: float | None = None,
        protocol: str = "P1",
        engine: str = "event",
        policy: PublishPolicy | None = None,
        quota: TenantQuota | None = None,
        **kw,
    ):
        """Register a distributed-quantile tenant; returns its protocol.

        ``engine="event"`` runs the paper-style simulator in-process
        (``m`` defaults to the mesh axis size; pass ``m=...`` to override);
        ``engine="shard"`` runs the shard_map summary-merge super-step
        engine on the pipeline's mesh.  Extra ``kw`` (``s``, ``q_cap``,
        ``seed``) pass through to the registered protocol factory and are
        recorded so ``load`` rebuilds the tenant identically.
        """
        from repro.runtime.registry import create_protocol

        if tenant in self._tenants:
            raise ValueError(f"tenant {tenant!r} already registered")
        if engine not in ("event", "shard"):
            raise ValueError(
                f"unknown quantile engine {engine!r}; choose 'event' or 'shard'"
            )
        eps = self.default_eps if eps is None else eps
        kw = dict(kw)
        if engine == "shard":
            proto = create_protocol(
                protocol, engine="shard", kind="quantile",
                mesh=self.mesh, eps=eps, axis=self.axis, **kw,
            )
        else:
            kw.setdefault("m", self.mesh.shape[self.axis])
            proto = create_protocol(
                protocol, engine="event", kind="quantile", eps=eps, **kw,
            )
        self._register(tenant, _QuantileAdapter(proto, kw), policy, quota)
        return proto

    def add_leverage_tenant(
        self,
        tenant: str,
        d: int,
        *,
        eps: float | None = None,
        protocol: str = "P1",
        engine: str = "event",
        policy: PublishPolicy | None = None,
        quota: TenantQuota | None = None,
        **kw,
    ):
        """Register a leverage-score row-sampling tenant; returns its protocol.

        ``engine="event"`` runs the paper-style simulator in-process
        (``m`` defaults to the mesh axis size; pass ``m=...`` to override);
        ``engine="shard"`` runs the shard_map threshold-forwarding
        super-step engine on the pipeline's mesh.  Extra ``kw`` pass
        through to the registered protocol factory — event P1 takes
        ``l``/``s``/``seed``, event P2 ``s``/``seed``, the shard engine
        ``lev_cap``/``l_site``/``l_coord``/``use_pallas`` — and are
        recorded so ``load`` rebuilds the tenant identically.
        """
        from repro.runtime.registry import create_protocol

        if tenant in self._tenants:
            raise ValueError(f"tenant {tenant!r} already registered")
        if engine not in ("event", "shard"):
            raise ValueError(
                f"unknown leverage engine {engine!r}; choose 'event' or 'shard'"
            )
        eps = self.default_eps if eps is None else eps
        kw = dict(kw)
        if engine == "shard":
            proto = create_protocol(
                protocol, engine="shard", kind="leverage",
                mesh=self.mesh, d=d, eps=eps, axis=self.axis, **kw,
            )
        else:
            kw.setdefault("m", self.mesh.shape[self.axis])
            proto = create_protocol(
                protocol, engine="event", kind="leverage", d=d, eps=eps, **kw,
            )
        self._register(tenant, _LeverageAdapter(proto, kw), policy, quota)
        return proto

    def add_windowed_tenant(
        self,
        tenant: str,
        *,
        kind: str = "matrix",
        d: int | None = None,
        eps: float | None = None,
        protocol: str | None = None,
        engine: str = "event",
        policy: PublishPolicy | None = None,
        quota: TenantQuota | None = None,
        **kw,
    ):
        """Register a time-windowed tenant of any kind; returns its protocol.

        ``protocol`` defaults to the sliding-window spec for the kind
        (``"P2win"`` for matrix, ``"P1win"`` otherwise); pass ``"P2decay"``
        / ``"P1decay"`` for exponential decay.  ``ingest`` then accepts
        event timestamps (``ts=`` or ``TimedRows``), rows later than the
        watermark are shed with a counted ``LateRowError``, and published
        snapshots carry the underlying kind's workload tag so windowed
        tenants serve through the same packed sweeps as full-stream ones.
        Extra ``kw`` (``window``, ``buckets``, ``lateness``, ``gamma``,
        ``half_life``, ``sites``, per-kind sizes) pass through to the
        windowed factory and are recorded so ``load`` rebuilds the tenant
        identically.  Pairs naturally with ``policy=OnWindowClose()``.
        """
        from repro.runtime.registry import create_protocol

        if tenant in self._tenants:
            raise ValueError(f"tenant {tenant!r} already registered")
        if engine not in ("event", "shard"):
            raise ValueError(
                f"unknown windowed engine {engine!r}; choose 'event' or 'shard'"
            )
        if kind not in ("matrix", "hh", "quantile", "leverage"):
            raise ValueError(
                f"unknown windowed kind {kind!r}; choose 'matrix', 'hh', "
                "'quantile', or 'leverage'"
            )
        if protocol is None:
            protocol = "P2win" if kind == "matrix" else "P1win"
        eps = self.default_eps if eps is None else eps
        kw = dict(kw)
        dim_kw = {}
        if kind in ("matrix", "leverage"):
            if d is None:
                raise ValueError(f"windowed {kind} tenants need d")
            dim_kw["d"] = int(d)
        elif d is not None:
            raise ValueError(f"windowed {kind} tenants take no d")
        if engine == "shard":
            proto = create_protocol(
                protocol, engine="shard", kind=kind,
                mesh=self.mesh, eps=eps, axis=self.axis, **dim_kw, **kw,
            )
        else:
            kw.setdefault("m", self.mesh.shape[self.axis])
            proto = create_protocol(
                protocol, engine="event", kind=kind, eps=eps, **dim_kw, **kw,
            )
        self._register(tenant, _WindowedAdapter(proto, kw), policy, quota)
        return proto

    def _add_from_ctor(
        self,
        tenant: str,
        workload: str,
        ctor: dict,
        policy: PublishPolicy | None,
        quota: TenantQuota | None,
    ) -> None:
        """Rebuild one tenant from its recorded ``ctor_meta`` (load/import)."""
        if workload == "hh":
            self.add_hh_tenant(
                tenant,
                eps=float(ctor["eps"]),
                protocol=str(ctor["protocol"]),
                engine=str(ctor["engine"]),
                policy=policy,
                quota=quota,
                **ctor["kw"],
            )
        elif workload == "quantile":
            self.add_quantile_tenant(
                tenant,
                eps=float(ctor["eps"]),
                protocol=str(ctor["protocol"]),
                engine=str(ctor["engine"]),
                policy=policy,
                quota=quota,
                **ctor["kw"],
            )
        elif workload == "leverage":
            self.add_leverage_tenant(
                tenant,
                int(ctor["d"]),
                eps=float(ctor["eps"]),
                protocol=str(ctor["protocol"]),
                engine=str(ctor["engine"]),
                policy=policy,
                quota=quota,
                **ctor["kw"],
            )
        elif workload == "windowed":
            self.add_windowed_tenant(
                tenant,
                kind=str(ctor["kind"]),
                d=int(ctor["d"]) if "d" in ctor else None,
                eps=float(ctor["eps"]),
                protocol=str(ctor["protocol"]),
                engine=str(ctor["engine"]),
                policy=policy,
                quota=quota,
                **ctor["kw"],
            )
        elif workload == "matrix":
            self.add_tenant(
                tenant,
                int(ctor["d"]),
                eps=float(ctor["eps"]),
                protocol=str(ctor["protocol"]),
                policy=policy,
                quota=quota,
            )
        else:
            raise ValueError(f"unknown tenant workload {workload!r}")

    # -- cell-facing tenant migration (repro.cluster) -------------------------

    def export_tenant(self, tenant: str) -> dict:
        """Capture one live tenant as a portable payload (cluster rebalance).

        The payload holds everything ``import_tenant`` on *another*
        pipeline needs to continue the tenant bit-identically: the
        construction recipe (``ctor_meta``), live protocol state
        (``state_payload`` — the same halves the checkpoint writes),
        publish policy/quota/counters, and the tenant's published store
        versions (``SketchStore.export_tenant``, version numbers
        preserved).  The tenant must have no queries pending here — a
        live move drains (``flush``) first, because tickets cannot cross
        pipelines.  The tenant stays registered; callers remove it with
        ``remove_tenant`` once the importing cell has it.
        """
        t = self._tenant(tenant)
        if self.service.pending(tenant):
            raise RuntimeError(
                f"tenant {tenant!r} has {self.service.pending(tenant)} queries "
                "pending; flush() before exporting"
            )
        arrays, proto_meta = t.adapter.state_payload()
        store_tree, store_extra = self.store.export_tenant(tenant)
        return {
            "format": "tenant-export-v1",
            "tenant": tenant,
            "workload": t.adapter.workload,
            "ctor": t.adapter.ctor_meta(),
            "policy": policy_to_config(t.policy),
            "quota": None if t.quota is None else list(t.quota),
            "steps": t.steps,
            "steps_since_publish": t.steps_since_publish,
            "publishes": t.publishes,
            "published_frob": t.published_frob,
            "latest_version": t.latest_version,
            "proto_meta": proto_meta,
            "arrays": {k: np.asarray(v) for k, v in dict(arrays).items()},
            "store_tree": store_tree,
            "store_extra": store_extra,
        }

    def import_tenant(self, payload: dict) -> None:
        """Install an ``export_tenant`` payload as a live tenant here.

        Restores the protocol state, counters, and published store
        versions bit-identically — answers after the move match answers
        before it, version numbers included.  Raises if the tenant name
        is already registered (or has snapshots) on this pipeline.
        """
        if payload.get("format") != "tenant-export-v1":
            raise ValueError(
                f"not a tenant export payload: format={payload.get('format')!r}"
            )
        name = payload["tenant"]
        if name in self._tenants:
            raise ValueError(f"tenant {name!r} already registered")
        # Store first: import_tenant refuses resident tenants, so a
        # half-applied payload cannot leave a registered tenant whose
        # snapshots never arrived.
        self.store.import_tenant(payload["store_tree"], payload["store_extra"])
        policy = policy_from_config(payload["policy"])
        quota = None if payload["quota"] is None else TenantQuota(*payload["quota"])
        self._add_from_ctor(name, payload["workload"], payload["ctor"], policy, quota)
        t = self._tenants[name]
        t.adapter.restore_payload(
            {k: np.asarray(v) for k, v in payload["arrays"].items()},
            payload["proto_meta"],
        )
        t.steps = int(payload["steps"])
        t.steps_since_publish = int(payload["steps_since_publish"])
        t.publishes = int(payload["publishes"])
        t.published_frob = (
            None if payload["published_frob"] is None else float(payload["published_frob"])
        )
        t.latest_version = (
            None if payload["latest_version"] is None else int(payload["latest_version"])
        )

    def remove_tenant(self, tenant: str) -> None:
        """Deregister a tenant and drop its published versions.

        The rebalancer's final step after a successful export/import.
        Refuses while queries are pending (flush first); the quota entry
        is cleared so a later re-add starts clean.
        """
        self._tenant(tenant)  # raise KeyError with the registered list
        if self.service.pending(tenant):
            raise RuntimeError(
                f"tenant {tenant!r} has {self.service.pending(tenant)} queries "
                "pending; flush() before removing"
            )
        del self._tenants[tenant]
        self.service.clear_quota(tenant)
        self.store.drop_tenant(tenant)

    @staticmethod
    def read_tenant_export(directory: str, tenant: str, *, step: int | None = None) -> dict:
        """Build an ``import_tenant`` payload straight from a saved checkpoint.

        Reads only the tenant's leaves (``ckpt.read_subset`` over the
        manifest's tenant-scoped subset: its ``tenant_NNNN__*`` protocol
        state plus the store snapshots whose manifest entry names this
        tenant) — a rebalance from a dead cell's checkpoint never pays
        for the other tenants' I/O.
        """
        from repro import ckpt

        if step is None:
            step = ckpt.latest_step(directory)
            if step is None:
                raise FileNotFoundError(f"no pipeline checkpoint under {directory!r}")
        manifest = ckpt.read_manifest(directory, step)
        extra = manifest["extra"]
        if extra.get("kind") != "streaming_pipeline":
            raise ValueError(
                f"checkpoint at {directory!r} step {step} is not a streaming pipeline"
            )
        meta = extra["tenants"].get(tenant)
        if meta is None:
            raise KeyError(
                f"tenant {tenant!r} not in checkpoint "
                f"(has: {sorted(extra['tenants'])})"
            )
        prefix = meta["key"] + "__"
        proto_names = [n for n in manifest["leaves"] if n.startswith(prefix)]
        snap_entries = sorted(
            (e for e in extra["store"]["snapshots"] if e["tenant"] == tenant),
            key=lambda e: e["version"],
        )
        snap_names = [f"store__{e['key']}" for e in snap_entries]
        leaves = ckpt.read_subset(directory, step, proto_names + snap_names)
        # renumber the snapshot keys from 0 so the payload is byte-for-byte
        # the same shape a live ``SketchStore.export_tenant`` produces
        store_tree = {}
        renumbered = []
        for i, e in enumerate(snap_entries):
            key = f"snap_{i:05d}"
            store_tree[key] = leaves[f"store__{e['key']}"]
            renumbered.append({**e, "key": key})
        store_extra = {
            "kind": "sketch_store",
            "retain": extra["store"].get("retain", 0),
            "next_version": {
                tenant: extra["store"]["next_version"].get(tenant, 1)
            },
            "snapshots": renumbered,
        }
        return {
            "format": "tenant-export-v1",
            "tenant": tenant,
            "workload": meta["workload"],
            "ctor": meta["ctor"],
            "policy": meta["policy"],
            "quota": meta["quota"],
            "steps": meta["steps"],
            "steps_since_publish": meta["steps_since_publish"],
            "publishes": meta["publishes"],
            "published_frob": meta["published_frob"],
            "latest_version": meta["latest_version"],
            "proto_meta": meta["proto_meta"],
            "arrays": {n[len(prefix):]: leaves[n] for n in proto_names},
            "store_tree": store_tree,
            "store_extra": store_extra,
        }

    def tenants(self) -> list[str]:
        """Registered tenant names (sorted)."""
        return sorted(self._tenants)

    def workload(self, tenant: str) -> str:
        """The tenant's workload kind (``"matrix"``, ``"hh"``, ``"quantile"``,
        ``"leverage"``, or ``"windowed"``)."""
        return self._tenant(tenant).adapter.workload

    def tracker(self, tenant: str):
        """The tenant's underlying protocol object (tracker or HHProtocol)."""
        return self._tenant(tenant).adapter.target

    def set_quota(self, tenant: str, quota: TenantQuota) -> None:
        """Set or replace a tenant's admission quota / dispatch priority."""
        t = self._tenant(tenant)
        t.quota = quota
        self.service.set_quota(
            tenant, max_pending=quota.max_pending, priority=quota.priority
        )

    def _tenant(self, tenant: str) -> _Tenant:
        try:
            return self._tenants[tenant]
        except KeyError:
            raise KeyError(
                f"unknown tenant {tenant!r} (registered: {self.tenants()})"
            ) from None

    # -- ingest → publish ----------------------------------------------------

    def ingest(self, tenant: str, rows, ts: float | None = None) -> "object | None":
        """Absorb one super-step batch; auto-publish per the tenant's policy.

        Matrix and leverage tenants take an (n, d) row batch, HH tenants
        an (n, 2) [element, weight] batch, quantile tenants an (n, 2)
        [value, weight] batch.  Windowed tenants additionally take the
        batch's event time — pass ``ts=`` or wrap the batch in
        ``core.windows.TimedRows``; a batch later than the tenant's
        watermark is *shed*: counted in the ``late_rows`` ingest counter
        and rejected with ``LateRowError``, never silently dropped.
        Returns the new ``SketchSnapshot`` if the policy
        fired, else None.  When no ``ServicePump`` is running this also
        pumps the packed service's deadlines cooperatively, so a pure
        ingest loop still serves queries on time.  A pump that died on an
        exception is detected here and surfaced as ``ServicePumpError``
        (deadline enforcement must never fail silently).
        """
        t = self._tenant(tenant)
        if isinstance(rows, TimedRows):
            if ts is None:
                ts = rows.ts
            rows = rows.rows
        with self.obs.trace("pipeline.ingest", tenant=tenant):
            t0 = self.obs.clock()
            try:
                t.adapter.ingest(rows, ts=ts)
            except LateRowError as e:
                self._m_ingest["late_rows"].inc(e.n_rows)
                self._m_ingest["ingest_s"].inc(self.obs.clock() - t0)
                raise
            self._m_ingest["ingest_s"].inc(self.obs.clock() - t0)
            self._m_ingest["serial_steps"].inc()
            self._m_ingest["batches"].inc()
            self._m_ingest["rows"].inc(self._batch_len(rows))
            snap = self._post_ingest(tenant, t)
        self._pump_or_poll()
        return snap

    @staticmethod
    def _batch_len(rows) -> int:
        """Items in one ingest batch ((n, ...) array or (keys, weights))."""
        if isinstance(rows, TimedRows):
            rows = rows.rows
        if isinstance(rows, tuple):
            rows = rows[0]
        return int(np.asarray(rows).shape[0])

    def _post_ingest(self, tenant: str, t: _Tenant):
        """Per-batch bookkeeping shared by serial and packed ingest:
        advance the step counters and publish if the tenant's policy
        fires.  Returns the new snapshot or None."""
        t.steps += 1
        t.steps_since_publish += 1
        t.metrics["lag"].set(t.steps_since_publish)
        if "window_lag" in t.metrics:
            t.metrics["window_lag"].set(t.adapter.window_lag())
        # Only pay for the mass estimate when the policy reads it (for
        # matrix P3 it materializes the whole estimator matrix).
        live = t.adapter.live_mass() if t.policy.needs_live_frob else 0.0
        policy_kw = {}
        if getattr(t.policy, "needs_window_close", False):
            wc = getattr(t.adapter, "windows_closed", None)
            policy_kw["windows_closed"] = wc() if wc is not None else 0
        if t.policy.should_publish(
            steps_since_publish=t.steps_since_publish,
            live_frob=live,
            published_frob=t.published_frob,
            **policy_kw,
        ):
            return self._publish(tenant, t)
        return None

    def _pump_or_poll(self) -> None:
        """Pump deadlines cooperatively unless a live executor owns them.

        A pump that died on an exception is detected here and surfaced as
        ``ServicePumpError`` (deadline enforcement must never fail
        silently)."""
        if self.pump is None:
            self.service.poll()
        elif not self.pump.running:
            # The executor died or was stopped behind our back: detach it
            # (raising its captured error, if any) and pump cooperatively
            # so deadlines never silently stop being enforced.
            self.stop_pump()
            self.service.poll()

    def ingest_many(
        self, batches: Iterable[tuple], *, packed: bool = True
    ) -> int:
        """Drive interleaved tenants: ``[(tenant, rows), ...]``; returns
        the number of snapshots published.

        Entries may also carry event time for windowed tenants — either
        ``(tenant, rows, ts)`` triples or ``(tenant, TimedRows(rows, ts))``
        pairs; timed batches always take the serial per-tenant path (the
        packed launch has no time axis).

        With ``packed=True`` (the default) the batches are regrouped into
        waves — wave ``i`` holds each tenant's ``i``-th batch — and every
        wave's shard tenants that share a pack key (same protocol config
        and mesh; see ``runtime.ingest_packed``) advance in ONE stacked
        super-step launch instead of one launch per tenant.  Per-tenant
        batch order is preserved, so each tenant's final state matches
        the serial path to fp tolerance (zero padding is exact for the
        packable protocols); only the cross-tenant interleaving — and
        therefore the order snapshots publish within a wave — changes.
        Deadlines are pumped once per wave rather than once per batch.
        ``packed=False`` restores the strict one-``ingest``-per-batch
        serial loop.
        """
        batches = [
            (b[0], TimedRows(b[1].rows if isinstance(b[1], TimedRows) else b[1],
                             float(b[2])))
            if len(b) == 3 else (b[0], b[1])
            for b in (tuple(b) for b in batches)
        ]
        if not packed:
            published = 0
            for tenant, rows in batches:
                published += self.ingest(tenant, rows) is not None
            return published
        per_tenant: dict[str, list] = {}
        for tenant, rows in batches:
            per_tenant.setdefault(tenant, []).append(rows)
        published = 0
        n_waves = max((len(v) for v in per_tenant.values()), default=0)
        for w in range(n_waves):
            wave = [(name, v[w]) for name, v in per_tenant.items() if w < len(v)]
            published += self._ingest_wave(wave)
        return published

    def _ingest_wave(self, wave: list) -> int:
        """One wave of ``ingest_many``: pack what groups, step the rest.

        Tenants whose adapters expose equal pack signatures (>= 2 of
        them, shardable batches) ride one ``ingest_packed`` launch; all
        others take the serial adapter path.  Publishes fire per tenant
        exactly as serial ingest would; the wave's fresh matrix
        snapshots then warm the query engine's spectrum cache with one
        batched ``refresh_spectra`` pass.
        """
        from repro.runtime.ingest_packed import (
            ingest_packed,
            pack_signature,
            pack_target,
        )

        m = self._m_ingest
        m["waves"].inc()
        groups: dict = {}
        serial: list = []
        for name, rows in wave:
            t = self._tenant(name)
            ts = None
            if isinstance(rows, TimedRows):
                ts = rows.ts
                rows = rows.rows
            sig = pack_signature(t.adapter)
            n = self._batch_len(rows)
            if ts is None and sig is not None and n and n % sig[1].m == 0:
                groups.setdefault(sig, []).append((name, t, rows))
            else:
                serial.append((name, t, rows, ts))
        snaps: list = []
        with self.obs.trace("pipeline.ingest_wave", tenants=len(wave)):
            t0 = self.obs.clock()
            for members in groups.values():
                if len(members) < 2:  # a pack of one gains nothing
                    serial.extend((name, t, rows, None) for name, t, rows in members)
                    continue
                stats = ingest_packed(
                    [(pack_target(t.adapter), rows) for _, t, rows in members]
                )
                m["packed_launches"].inc()
                m["packed_tenants"].inc(stats["tenants"])
                m["packed_rows"].inc(stats["rows"])
                m["rows"].inc(stats["rows"])
                m["batches"].inc(stats["tenants"])
                m["pad_rows"].inc(stats["pad_rows"])
                m["retraces"].inc(bool(stats["new_shape"]))
                m["restacks"].inc(bool(stats["restacked"]))
                for name, t, _ in members:
                    snaps.append(self._post_ingest(name, t))
            for name, t, rows, ts in serial:
                try:
                    t.adapter.ingest(rows, ts=ts)
                except LateRowError as e:
                    # Shed, not dropped: the late batch is counted and the
                    # rest of the wave proceeds (serial ingest re-raises).
                    m["late_rows"].inc(e.n_rows)
                    continue
                m["serial_steps"].inc()
                m["batches"].inc()
                m["rows"].inc(self._batch_len(rows))
                snaps.append(self._post_ingest(name, t))
            m["ingest_s"].inc(self.obs.clock() - t0)
            fresh = [s for s in snaps if s is not None]
            if fresh:
                # One stacked eigh warms every same-shape matrix publish.
                self.engine.refresh_spectra(fresh)
        self._pump_or_poll()
        return len(fresh)

    def publish(self, tenant: str):
        """Force-publish a tenant's live state now (OnDemand's trigger)."""
        return self._publish(tenant, self._tenant(tenant))

    def _publish(self, tenant: str, t: _Tenant):
        with self.obs.trace("pipeline.publish", tenant=tenant):
            t0 = self.obs.clock()
            snap = t.adapter.publish(
                self.store,
                tenant,
                meta={"step": t.steps},
                published_at=t.adapter.publish_time(self.obs.clock),
            )
            elapsed = self.obs.clock() - t0
        self._m_publish.inc()
        self._m_publish_s.inc(elapsed)
        self._m_publish_latency.observe(elapsed)
        t.steps_since_publish = 0
        t.publishes += 1
        t.published_frob = snap.frob
        t.latest_version = snap.version
        t.metrics["f_hat"].set(snap.frob)
        t.metrics["version"].set(snap.version)
        t.metrics["lag"].set(0)
        t.adapter.comm_report().emit(
            self.obs.registry, **{**self.obs.labels, "tenant": tenant}
        )
        return snap

    # -- serve ---------------------------------------------------------------

    def submit(self, tenant: str, x, *, deadline_s: float | None = None) -> QueryTicket:
        """Admit one query for a tenant into the packed service.

        Matrix tenants take a (d,) direction; HH tenants a (1,) element
        id; quantile tenants a (2,) [mode, arg] row (see
        ``core.quantiles.rank_query`` / ``quantile_query``); leverage
        tenants a (d+1,) [mode, x] row (see ``core.leverage.subspace_query``
        / ``score_query``).  The tenant must have at least one published
        snapshot, and ``x``
        must match the tenant's workload shape: admitting a query nothing
        can answer would poison every later packed flush (the service
        keeps failing batches pending by design), wedging other tenants'
        deadline pumps.  Fail at the submitter instead.  Raises
        ``QueryShedError`` when the tenant's quota is full.
        """
        t = self._tenant(tenant)
        if t.latest_version is None and tenant not in self.store.tenants():
            raise KeyError(
                f"tenant {tenant!r} has no published snapshot yet — ingest "
                "until its policy fires, or call publish()"
            )
        x = np.asarray(x, np.float32)
        t.adapter.check_query(x)
        return self.service.submit(x, tenant=tenant, deadline_s=deadline_s)

    def poll(self) -> int:
        """Deadline pump; returns queries served by a deadline-forced sweep."""
        return self.service.poll()

    def flush(self) -> int:
        """Serve everything pending in capped priority-ordered sweeps."""
        return self.service.flush()

    def heavy_hitters(
        self, tenant: str, phi: float, *, version: int | None = None
    ) -> list[int]:
        """The paper's phi-heavy-hitter set from a published HH snapshot.

        Returns every element whose published estimate crosses the
        ``(phi - eps/2) hat{W}`` threshold (Section 4's no-false-negative
        rule), read from the pinned store version — the same data packed
        queries are answered from, so restart recovery covers it too.
        """
        from repro.core.hh import decode_hh_snapshot, threshold_heavy_hitters

        snap = self.store.get(tenant, version)
        if snap.meta.get("workload") != "hh":
            raise ValueError(f"tenant {tenant!r} is not a heavy-hitter tenant")
        return threshold_heavy_hitters(
            decode_hh_snapshot(snap.matrix), snap.frob, snap.eps, phi
        )

    def quantiles(
        self, tenant: str, phis, *, version: int | None = None
    ) -> np.ndarray:
        """Eps-approximate phi-quantile values from a published snapshot.

        Reads the pinned store version — the same sorted [value, rank]
        table packed queries are answered from, so restart recovery
        covers it too.
        """
        from repro.core.quantiles import table_quantile

        snap = self.store.get(tenant, version)
        if snap.meta.get("workload") != "quantile":
            raise ValueError(f"tenant {tenant!r} is not a quantile tenant")
        return table_quantile(snap.matrix, snap.frob, phis)

    def sampled_rows(
        self, tenant: str, *, version: int | None = None
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """The published leverage sample as ``(rows, scores, weights)``.

        Reads the pinned store version — the same [row | score | weight]
        table packed subspace/score queries are answered from, so restart
        recovery covers it too.
        """
        from repro.core.leverage import decode_leverage_snapshot

        snap = self.store.get(tenant, version)
        if snap.meta.get("workload") != "leverage":
            raise ValueError(f"tenant {tenant!r} is not a leverage tenant")
        return decode_leverage_snapshot(snap.matrix)

    # -- persistence / accounting -------------------------------------------

    def save(
        self, directory: str, *, step: int = 0, attachments: dict | None = None
    ) -> str:
        """Checkpoint the whole pipeline atomically; returns the path.

        One ``repro.ckpt`` step holds both halves of the coordinator: the
        published ``SketchStore`` versions *and* every tenant's live
        protocol state (``state_payload``), plus policies, quotas, and
        publish counters in the manifest.  ``load`` rebuilds a pipeline
        that answers queries bit-identically and resumes ingest mid-stream.
        A running ``ServicePump`` is stopped for the duration of the write
        and restarted after (its interval is recorded, so ``load`` revives
        it on the restored pipeline too).

        ``attachments`` is a JSON-able dict stored verbatim under the
        manifest's ``extra["attachments"]`` — the hook wrapping layers
        (e.g. a ``PipelineCell``'s transport dedup horizons) use to make
        their own state crash-durable in the same atomic step.  ``load``
        ignores it; owners read it back via ``ckpt.read_extra``.
        """
        pump = self.pump
        if pump is not None:
            pump.stop()
        try:
            return self._save(directory, step=step, attachments=attachments)
        finally:
            if pump is not None:
                pump.start()

    def _save(
        self, directory: str, *, step: int = 0, attachments: dict | None = None
    ) -> str:
        from repro import ckpt

        store_tree, store_extra = self.store.state_tree()
        tree: dict = {"store": store_tree}
        tenants_meta: dict[str, dict] = {}
        # Tenant names are free-form; leaves get synthetic keys (the store
        # does the same with snap_NNNNN) so a name with '/' or '__' can
        # neither break checkpoint file paths nor alias the leaf namespace.
        for i, (name, t) in enumerate(sorted(self._tenants.items())):
            arrays, proto_meta = t.adapter.state_payload()
            key = f"tenant_{i:04d}"
            tree[key] = dict(arrays)
            tenants_meta[name] = {
                "key": key,
                "workload": t.adapter.workload,
                "ctor": t.adapter.ctor_meta(),
                "policy": policy_to_config(t.policy),
                "quota": None if t.quota is None else list(t.quota),
                "steps": t.steps,
                "steps_since_publish": t.steps_since_publish,
                "publishes": t.publishes,
                "published_frob": t.published_frob,
                "latest_version": t.latest_version,
                "proto_meta": proto_meta,
            }
        extra = {
            "kind": "streaming_pipeline",
            "store": store_extra,
            "tenants": tenants_meta,
            "defaults": {
                "eps": self.default_eps,
                "protocol": self.default_protocol,
                "axis": self.axis,
                "policy": policy_to_config(self.default_policy),
                "pump_interval_s": None if self.pump is None else self.pump.interval_s,
            },
        }
        if attachments is not None:
            extra["attachments"] = attachments
        return ckpt.save(directory, step, tree, extra=extra)

    @classmethod
    def load(
        cls,
        directory: str,
        mesh: jax.sharding.Mesh,
        *,
        step: int | None = None,
        axis: str | None = None,
        **pipeline_kw,
    ) -> "StreamingPipeline":
        """Rebuild a pipeline from ``save`` output (latest step by default).

        ``mesh`` must expose the checkpoint's mesh axis at the same size
        (protocol state is per-site); the axis name itself is recorded in
        the checkpoint, so pass ``axis`` only to override it.  Remaining
        keyword arguments (``interpret``, ``max_batch``, ...) configure the
        fresh serving stack — queue contents are deliberately not
        checkpointed; unresolved tickets don't survive a coordinator crash.
        """
        from repro import ckpt

        if step is None:
            step = ckpt.latest_step(directory)
            if step is None:
                raise FileNotFoundError(f"no pipeline checkpoint under {directory!r}")
        manifest = ckpt.read_manifest(directory, step)
        extra = manifest["extra"]
        if extra.get("kind") != "streaming_pipeline":
            raise ValueError(
                f"checkpoint at {directory!r} step {step} is not a streaming pipeline"
            )
        # The manifest is the single source of truth for leaf shapes/dtypes:
        # build each tenant's restore template from its synthetic key prefix
        # ('tenant_NNNN__...', as written by save).  restore() then verifies
        # per-leaf sha256, and restore_payload rejects config mismatches.
        template: dict = {"store": SketchStore.state_template(extra["store"])}
        for name, meta in extra["tenants"].items():
            prefix = meta["key"] + "__"
            template[meta["key"]] = {
                leaf[len(prefix):]: np.zeros(info["shape"], info["dtype"])
                for leaf, info in manifest["leaves"].items()
                if leaf.startswith(prefix)
            }
        tree, _ = ckpt.restore(directory, step, template)

        defaults = extra.get("defaults", {})
        if "pump_interval_s" not in pipeline_kw and defaults.get("pump_interval_s"):
            pipeline_kw["pump_interval_s"] = float(defaults["pump_interval_s"])
        pipe = cls(
            mesh,
            axis=str(defaults.get("axis", "data")) if axis is None else axis,
            eps=float(defaults.get("eps", 0.1)),
            protocol=str(defaults.get("protocol", "P2")),
            policy=policy_from_config(defaults["policy"]) if "policy" in defaults else None,
            store=SketchStore.from_state_tree(tree["store"], extra["store"]),
            **pipeline_kw,
        )
        for name, meta in sorted(extra["tenants"].items()):
            policy = policy_from_config(meta["policy"])
            quota = None if meta["quota"] is None else TenantQuota(*meta["quota"])
            pipe._add_from_ctor(name, meta["workload"], meta["ctor"], policy, quota)
            t = pipe._tenants[name]
            t.adapter.restore_payload(
                {k: np.asarray(v) for k, v in tree[meta["key"]].items()},
                meta["proto_meta"],
            )
            t.steps = int(meta["steps"])
            t.steps_since_publish = int(meta["steps_since_publish"])
            t.publishes = int(meta["publishes"])
            t.published_frob = (
                None if meta["published_frob"] is None else float(meta["published_frob"])
            )
            t.latest_version = (
                None if meta["latest_version"] is None else int(meta["latest_version"])
            )
        return pipe

    def publish_latency_s(self) -> float:
        """Total wall time spent publishing (store copies + host sync)."""
        return self._m_publish_s.value

    def stats(self, tenant: str | None = None):
        """Lifetime counters: one tenant's ``TenantStats``, or — with no
        tenant — the pipeline's ingest-side observability dict.

        The pipeline-wide dict carries the raw ingest counters (rows,
        batches, waves, packed launches/tenants/rows, pad slots, serial
        steps, retraces, ingest seconds) plus the derived gauges packed
        ingest is judged by: ``rows_per_sec`` (real rows over ingest wall
        time), ``shrink_launches`` (packed launches + serial steps — the
        number of protocol super-steps actually dispatched),
        ``pack_occupancy`` (real-row fraction of packed launch slots;
        1.0 means no padding waste), ``retraces`` (distinct packed
        launch shapes compiled), and ``restacks`` (packed launches that
        had to restack member states instead of reusing the resident
        stacked pack).  ``ClusterRouter.stats`` surfaces the same dict
        per cell.
        """
        if tenant is None:
            # A fresh view over the obs registry, shaped exactly like the
            # pre-registry counter dict (ints stay ints).
            c = {
                k: (h.value if k == "ingest_s" else int(h.value))
                for k, h in self._m_ingest.items()
            }
            c["rows_per_sec"] = (
                c["rows"] / c["ingest_s"] if c["ingest_s"] > 0 else 0.0
            )
            c["shrink_launches"] = c["packed_launches"] + c["serial_steps"]
            c["pack_occupancy"] = c["packed_rows"] / max(
                c["packed_rows"] + c["pad_rows"], 1
            )
            return c
        t = self._tenant(tenant)
        return TenantStats(
            tenant=tenant,
            steps=t.steps,
            rows=t.adapter.rows(),
            publishes=t.publishes,
            latest_version=t.latest_version,
            live_frob=t.adapter.live_mass(),
            comm_total=t.adapter.comm_report().total,
            workload=t.adapter.workload,
        )
