"""StreamingPipeline: many tenants' ingest→publish→serve as one object.

The paper's model is a single continuous loop — sites stream rows, the
coordinator maintains a sketch, queries are answered at any time.  The repo
previously split that loop across three layers the caller had to glue by
hand (tracker updates, store publishes, service flushes).  The pipeline
owns the whole lifecycle for a fleet of tenants:

    pipeline = StreamingPipeline(mesh, policy=EveryKSteps(4))
    pipeline.add_tenant("run-a", d=64)
    pipeline.add_tenant("run-b", d=64, eps=0.2)

    pipeline.ingest("run-a", rows)         # super-step + policy-driven publish
    t = pipeline.submit("run-b", x, deadline_s=0.005)
    pipeline.poll()                        # deadline pump (packed flush)
    estimate, bound, version = t.result()

Ingest drives the tenant's ``DistributedMatrixTracker`` one super-step and
asks its ``PublishPolicy`` whether the live sketch drifted enough to become
a new immutable ``SketchStore`` version.  Queries are admitted through a
``PackedQueryService``: queued directions for *different* tenants whose
sketches share (l, d) ride one packed quadform launch, flushed when full or
when the earliest deadline expires.  ``save``/``load`` persist the store
through ``repro.ckpt`` so a coordinator restart serves identical answers.
"""
from __future__ import annotations

import time
from typing import Iterable, NamedTuple

import jax
import numpy as np

from repro.query import QueryEngine, SketchStore
from repro.query.service import PackedQueryService, QueryTicket
from repro.runtime.policies import EveryKSteps, PublishPolicy

__all__ = ["StreamingPipeline", "TenantStats"]


class TenantStats(NamedTuple):
    tenant: str
    steps: int  # ingest super-steps absorbed
    rows: int  # stream rows absorbed
    publishes: int  # snapshots auto- or force-published
    latest_version: int | None
    live_frob: float
    comm_total: int  # protocol messages spent (paper units)


class _Tenant:
    __slots__ = ("tracker", "policy", "steps", "steps_since_publish",
                 "publishes", "published_frob", "latest_version")

    def __init__(self, tracker, policy: PublishPolicy):
        self.tracker = tracker
        self.policy = policy
        self.steps = 0
        self.steps_since_publish = 0
        self.publishes = 0
        self.published_frob: float | None = None
        self.latest_version: int | None = None


class StreamingPipeline:
    """Owns trackers, store, engine, and packed service for many tenants."""

    def __init__(
        self,
        mesh: jax.sharding.Mesh,
        *,
        eps: float = 0.1,
        axis: str = "data",
        protocol: str = "P2",
        policy: PublishPolicy | None = None,
        store: SketchStore | None = None,
        retain: int = 0,
        interpret: bool | None = None,
        max_batch: int = 1024,
        default_deadline_s: float = 0.02,
    ):
        self.mesh = mesh
        self.axis = axis
        self.default_eps = eps
        self.default_protocol = protocol
        self.default_policy = policy if policy is not None else EveryKSteps(1)
        self.store = store if store is not None else SketchStore(retain=retain)
        self.engine = QueryEngine(self.store, interpret=interpret)
        self.service = PackedQueryService(
            self.engine, max_batch=max_batch, default_deadline_s=default_deadline_s
        )
        self._tenants: dict[str, _Tenant] = {}
        self._publish_s = 0.0

    # -- tenant lifecycle ----------------------------------------------------

    def add_tenant(
        self,
        tenant: str,
        d: int,
        *,
        eps: float | None = None,
        protocol: str | None = None,
        policy: PublishPolicy | None = None,
    ):
        """Register a tenant stream; returns its tracker."""
        from repro.core.tracker import DistributedMatrixTracker

        if tenant in self._tenants:
            raise ValueError(f"tenant {tenant!r} already registered")
        tracker = DistributedMatrixTracker(
            self.mesh,
            d,
            eps=self.default_eps if eps is None else eps,
            axis=self.axis,
            protocol=self.default_protocol if protocol is None else protocol,
        )
        self._tenants[tenant] = _Tenant(tracker, policy or self.default_policy)
        return tracker

    def tenants(self) -> list[str]:
        return sorted(self._tenants)

    def tracker(self, tenant: str):
        return self._tenant(tenant).tracker

    def _tenant(self, tenant: str) -> _Tenant:
        try:
            return self._tenants[tenant]
        except KeyError:
            raise KeyError(
                f"unknown tenant {tenant!r} (registered: {self.tenants()})"
            ) from None

    # -- ingest → publish ----------------------------------------------------

    def ingest(self, tenant: str, rows) -> "object | None":
        """Absorb one super-step batch; auto-publish per the tenant's policy.

        Returns the new ``SketchSnapshot`` if the policy fired, else None.
        Also pumps the packed service's deadlines, so a pure ingest loop
        still serves queries on time.
        """
        t = self._tenant(tenant)
        t.tracker.update(rows)
        t.steps += 1
        t.steps_since_publish += 1
        snap = None
        # Only pay for the Frobenius estimate when the policy reads it (for
        # P3 it materializes the whole estimator matrix).
        live = t.tracker.frob_estimate() if t.policy.needs_live_frob else 0.0
        if t.policy.should_publish(
            steps_since_publish=t.steps_since_publish,
            live_frob=live,
            published_frob=t.published_frob,
        ):
            snap = self._publish(tenant, t)
        self.service.poll()
        return snap

    def ingest_many(self, batches: Iterable[tuple[str, "np.ndarray"]]) -> int:
        """Drive interleaved tenants: ``[(tenant, rows), ...]``; returns
        the number of snapshots published."""
        published = 0
        for tenant, rows in batches:
            published += self.ingest(tenant, rows) is not None
        return published

    def publish(self, tenant: str):
        """Force-publish a tenant's live sketch now (OnDemand's trigger)."""
        return self._publish(tenant, self._tenant(tenant))

    def _publish(self, tenant: str, t: _Tenant):
        t0 = time.perf_counter()
        snap = t.tracker.publish(self.store, tenant, meta={"step": t.steps})
        self._publish_s += time.perf_counter() - t0
        t.steps_since_publish = 0
        t.publishes += 1
        t.published_frob = snap.frob
        t.latest_version = snap.version
        return snap

    # -- serve ---------------------------------------------------------------

    def submit(self, tenant: str, x, *, deadline_s: float | None = None) -> QueryTicket:
        """Admit one (d,) direction for a tenant into the packed service.

        The tenant must have at least one published snapshot: admitting a
        query nothing can answer would poison every later packed flush
        (the service keeps failing batches pending by design), wedging
        other tenants' deadline pumps.  Fail at the submitter instead.
        """
        t = self._tenant(tenant)
        if t.latest_version is None and tenant not in self.store.tenants():
            raise KeyError(
                f"tenant {tenant!r} has no published snapshot yet — ingest "
                "until its policy fires, or call publish()"
            )
        return self.service.submit(np.asarray(x), tenant=tenant, deadline_s=deadline_s)

    def poll(self) -> int:
        """Deadline pump; returns queries served by a deadline-forced flush."""
        return self.service.poll()

    def flush(self) -> int:
        """Serve everything pending in one packed sweep."""
        return self.service.flush()

    # -- persistence / accounting -------------------------------------------

    def save(self, directory: str, *, step: int = 0) -> str:
        """Persist every tenant's published versions (``SketchStore.save``)."""
        return self.store.save(directory, step=step)

    def publish_latency_s(self) -> float:
        """Total wall time spent publishing (store copies + host sync)."""
        return self._publish_s

    def stats(self, tenant: str) -> TenantStats:
        t = self._tenant(tenant)
        return TenantStats(
            tenant=tenant,
            steps=t.steps,
            rows=t.tracker.rows_fed,
            publishes=t.publishes,
            latest_version=t.latest_version,
            live_frob=t.tracker.frob_estimate(),
            comm_total=t.tracker.comm_report().total,
        )
