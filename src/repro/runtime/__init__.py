"""Streaming runtime: the paper's continuous loop as one coherent layer.

The paper's model is m sites streaming rows while a coordinator maintains a
sketch that answers ``||A x||^2`` at any time.  This package is that loop's
runtime substrate:

  * registry.py — typed ``SketchProtocol`` interface + one registration
                  point for both engines (event-driven paper simulator,
                  shard_map TPU super-steps); consumers dispatch through
                  the registry instead of string/getattr probing.
  * policies.py — ``PublishPolicy``: when a tenant's live sketch becomes an
                  immutable served snapshot (every-k-steps, Frobenius
                  drift, on-demand).
  * pipeline.py — ``StreamingPipeline``: many tenants' ingest → publish →
                  serve lifecycle in one object, with cross-tenant packed
                  query admission and ``repro.ckpt`` persistence.
  * ingest_packed.py — stacked multi-tenant ingest: same-shape shard
                  tenants advance in one ``(T, ...)`` super-step launch
                  (``ingest_many``'s fast path).
"""
from repro.runtime.ingest_packed import ingest_packed, pack_signature
from repro.runtime.pipeline import StreamingPipeline, TenantStats
from repro.runtime.policies import (
    EveryKSteps,
    FrobDrift,
    OnDemand,
    OnWindowClose,
    PublishPolicy,
    TenantQuota,
    policy_from_config,
    policy_to_config,
)
from repro.runtime.registry import (
    HHProtocol,
    LeverageProtocol,
    ProtocolSpec,
    QuantileProtocol,
    SketchProtocol,
    create_protocol,
    get_spec,
    protocol_names,
    register_protocol,
    specs,
)

__all__ = [
    "EveryKSteps",
    "FrobDrift",
    "HHProtocol",
    "LeverageProtocol",
    "OnDemand",
    "OnWindowClose",
    "ProtocolSpec",
    "PublishPolicy",
    "QuantileProtocol",
    "SketchProtocol",
    "StreamingPipeline",
    "TenantQuota",
    "TenantStats",
    "create_protocol",
    "get_spec",
    "ingest_packed",
    "pack_signature",
    "policy_from_config",
    "policy_to_config",
    "protocol_names",
    "register_protocol",
    "specs",
]
