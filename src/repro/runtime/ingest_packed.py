"""Packed multi-tenant ingest: one stacked super-step for same-shape tenants.

A ``StreamingPipeline`` fleet often holds many tenants with identical
protocol configs — same ``(l, d, dtype)`` sketch shapes on the same mesh.
Serially each ingest batch pays its own Python dispatch, its own shard_map
launch, and its own FD shrink; packed ingest stacks the group's protocol
states into ``(T, ...)`` pytrees, coalesces their row batches into one
zero-padded ``(T, n_pad, d)`` array, and advances every tenant with ONE
``dist.make_packed_runner`` launch (``jit(shard_map(vmap(step)))``).

Zero padding is exact for the packable protocols (``dist.
PACKABLE_PROTOCOLS``): zero rows contribute nothing to any Gram, mass, or
threshold, so a ragged batch or a cold tenant rides the same launch as a
full one — equivalence on served answers is regression-tested against
serial ingest for every protocol kind.

Padding layout: the packed runner shards the row axis over the mesh with
``P(None, axis, None)``, so site ``j`` reads the contiguous block
``rows[:, j*n_pad/m : (j+1)*n_pad/m]``.  To preserve the serial
``P(axis, None)`` row→site assignment, each tenant's batch is split into
its ``m`` per-site blocks and each block is zero-padded independently
(``_pad_rows``).  Per-site lengths are bucketed to powers of two so the
jitted launch retraces O(log n) times, not once per distinct batch size.

The pack's stacked state stays RESIDENT between waves: the first launch
for a group restacks the members' states inside the jit
(``PackedRunner.from_states``) and every later wave feeds the cached
stacked output straight back in (``PackedRunner.stacked``), so the
steady state moves zero per-tenant leaves per wave.  Each member holds a
lazy ``(stacked, index)`` slot and only slices its own state out when a
publish, query, or checkpoint actually reads it; per-member ``_epoch``
counters detect out-of-band writes (a serial step, a restore) and force
a restack for the next wave.
"""
from __future__ import annotations

import numpy as np

from repro.core import distributed as dist

__all__ = ["ingest_packed", "pack_signature", "pack_target", "shape_cache_stats"]

# Distinct (pack_key, T, n_pad) launch shapes seen — each is one XLA trace
# of the packed step; the pipeline surfaces len() as its retrace counter.
_SHAPES_SEEN: set = set()


def shape_cache_stats() -> dict:
    """Packed-launch trace stats: ``retraces`` = distinct shapes compiled."""
    return {"retraces": len(_SHAPES_SEEN)}


def pack_target(adapter):
    """The shard protocol behind a pipeline adapter, or None.

    Matrix adapters wrap a ``DistributedMatrixTracker`` whose ``_proto``
    is the registry ``ShardProtocol``; leverage/hh/quantile adapters hold
    the registry protocol directly.  Event-engine protocols (no
    ``pack_key``) return None — they always ingest serially.
    """
    target = adapter.target
    proto = getattr(target, "_proto", target)
    return proto if hasattr(proto, "pack_key") else None


def pack_signature(adapter):
    """The tenant's pack grouping key, or None when it must go serial."""
    proto = pack_target(adapter)
    return None if proto is None else proto.pack_key()


def _bucket(n: int) -> int:
    """Next power of two >= n (>= 1): bounds retraces to O(log n) shapes."""
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


def _pad_rows(rows: np.ndarray, m: int, per_pad: int) -> np.ndarray:
    """Zero-pad an (n, d) batch to (m * per_pad, d), per-site block-wise.

    Preserves the serial row→site assignment: site ``j``'s rows land in
    the ``j``-th contiguous ``per_pad`` block, front-aligned, zeros after.
    """
    n, d = rows.shape
    per = n // m
    out = np.zeros((m * per_pad, d), rows.dtype)
    out.reshape(m, per_pad, d)[:, :per] = rows.reshape(m, per, d)
    return out


def ingest_packed(entries: list) -> dict:
    """Advance a group of same-key shard protocols in one stacked launch.

    ``entries`` is a list of ``(proto, rows)`` pairs whose ``pack_key()``
    values are all equal (the caller groups by ``pack_signature``); rows
    are (n_t, d) float32 with ``n_t % m == 0`` (the same shardability the
    serial path requires).  Each protocol is pointed at its slot in the
    stacked result via ``apply_packed`` — afterwards its state, row
    counter, and host caches look exactly as if it had stepped serially,
    but the per-tenant slice is deferred until something reads it.

    Steady state is restack-free: the group's stacked output is cached on
    the first member as ``_pack_group = (members, stacked, epochs)`` and
    reused whenever the same members arrive with unchanged epochs;
    otherwise the launch restacks the members' current states inside the
    jit.

    Returns launch counters: ``tenants``, ``rows`` (real rows absorbed),
    ``pad_rows`` (zero-filled slots), ``new_shape`` (True when this
    launch shape had not been traced before), ``restacked`` (True when
    the launch could not reuse a cached stacked state).
    """
    import jax.numpy as jnp

    if not entries:
        return {
            "tenants": 0, "rows": 0, "pad_rows": 0,
            "new_shape": False, "restacked": False,
        }
    protos = tuple(p for p, _ in entries)
    key = protos[0].pack_key()
    for p in protos[1:]:
        if p.pack_key() != key:
            raise ValueError("ingest_packed entries must share one pack_key")
    name, cfg, mesh = key
    d, m = cfg.d, cfg.m
    batches = []
    for p, rows in entries:
        rows = np.asarray(rows, np.float32)
        if rows.ndim != 2 or rows.shape[1] != d:
            raise ValueError(
                f"packed ingest batches must be (n, {d}) rows, got {rows.shape}"
            )
        if rows.shape[0] % m:
            raise ValueError(
                f"packed ingest batch of {rows.shape[0]} rows does not shard "
                f"over {m} sites"
            )
        batches.append(rows)

    runner = dist.make_packed_runner(name, cfg, mesh)
    per_pad = _bucket(max(b.shape[0] // m for b in batches))
    packed = jnp.asarray(np.stack([_pad_rows(b, m, per_pad) for b in batches]))

    shape = (key, len(entries), per_pad * m)
    new_shape = shape not in _SHAPES_SEEN
    _SHAPES_SEEN.add(shape)

    group = getattr(protos[0], "_pack_group", None)
    hit = (
        group is not None
        and group[0] == protos
        and all(p._epoch == e for p, e in zip(protos, group[2]))
    )
    if hit:
        stacked = runner.stacked(group[1], packed)
    else:
        stacked = runner.from_states(tuple(p.state for p in protos), packed)
    for i, (p, b) in enumerate(zip(protos, batches)):
        p.apply_packed(stacked, i, b.shape[0])
    protos[0]._pack_group = (protos, stacked, tuple(p._epoch for p in protos))

    rows_real = sum(b.shape[0] for b in batches)
    return {
        "tenants": len(entries),
        "rows": rows_real,
        "pad_rows": len(entries) * per_pad * m - rows_real,
        "new_shape": new_shape,
        "restacked": not hit,
    }
