"""Typed protocol registry — one dispatch point for both sketch engines.

The paper describes one object: a continuously-maintained coordinator
sketch that ingests rows and answers ``||A x||^2``.  The repo grows two
engines for it — the paper-exact event-driven simulator
(``core/protocols.py``) and the TPU shard_map super-step engine
(``core/distributed.py``) — and this module gives them one typed surface,
``SketchProtocol``:

    step(rows, sites=None)   absorb a batch of stream rows
    matrix()                 the coordinator sketch B, (l, d) numpy
    frob_estimate()          coordinator estimate of ||A||_F^2
    comm_report()            uniform CommReport (paper message units)
    query(x) / query_batch() ||B x||^2 via the shared quadform kernel path

Every implementation is registered here as a ``ProtocolSpec`` keyed by
``(engine, name)``; consumers (``DistributedMatrixTracker``, the streaming
pipeline, benchmarks, the registry round-trip test harness) enumerate and
construct protocols through the registry instead of hard-coding
per-protocol branches.
"""
from __future__ import annotations

import abc
from dataclasses import dataclass
from typing import Any, Callable

import numpy as np

from repro.core import distributed as dist
from repro.core import protocols as event
from repro.core.comm import CommReport

__all__ = [
    "SketchProtocol",
    "ProtocolSpec",
    "register_protocol",
    "get_spec",
    "protocol_names",
    "specs",
    "create_protocol",
]


class SketchProtocol(abc.ABC):
    """Uniform streaming-sketch interface over every engine/protocol pair."""

    name: str
    engine: str
    m: int
    eps: float
    d: int

    def __init__(self, name: str, engine: str, m: int, eps: float, d: int):
        self.name = name
        self.engine = engine
        self.m = m
        self.eps = eps
        self.d = d
        self.rows_seen = 0

    @abc.abstractmethod
    def step(self, rows: np.ndarray, sites: np.ndarray | None = None) -> None:
        """Absorb an (n, d) batch of stream rows (continuing prior state)."""

    @abc.abstractmethod
    def matrix(self) -> np.ndarray:
        """The coordinator's current sketch matrix B, shape (l, d)."""

    @abc.abstractmethod
    def frob_estimate(self) -> float:
        """Coordinator estimate of the stream mass ``||A||_F^2``."""

    @abc.abstractmethod
    def comm_report(self) -> CommReport:
        """Messages spent so far, in the paper's units."""

    # -- queries: one code path for every engine (and the serving layer) ----

    def query_batch(self, x: np.ndarray) -> np.ndarray:
        """``||B x_j||^2`` for each row of ``x`` via ``kernels.ops.quadform``
        — the same kernel the serving engine's pallas path launches, so
        tracker-side and serving-side answers can never diverge."""
        import jax.numpy as jnp

        from repro.kernels.ops import quadform

        b = self.matrix()
        x = np.asarray(x, np.float32)
        if b.shape[0] == 0:  # empty sketch: every quadratic form is 0
            return np.zeros(x.shape[0], np.float32)
        return np.asarray(quadform(jnp.asarray(b, jnp.float32), jnp.asarray(x)))

    def query(self, x: np.ndarray) -> float:
        return float(self.query_batch(np.asarray(x)[None, :])[0])


@dataclass(frozen=True)
class ProtocolSpec:
    """One registered (engine, protocol) implementation.

    err_factor: multiple of eps the covariance error is certified to stay
    under (1.0 for the deterministic protocols; sampling protocols carry
    the paper's looser constants).  The registry round-trip test drives
    every spec through one harness using this field — no per-protocol
    special cases.
    """

    name: str
    engine: str  # "event" | "shard"
    factory: Callable[..., SketchProtocol]
    err_factor: float = 1.0
    description: str = ""


_REGISTRY: dict[tuple[str, str], ProtocolSpec] = {}


def register_protocol(spec: ProtocolSpec) -> ProtocolSpec:
    key = (spec.engine, spec.name)
    if key in _REGISTRY:
        raise ValueError(f"protocol {spec.name!r} already registered for engine {spec.engine!r}")
    _REGISTRY[key] = spec
    return spec


def get_spec(name: str, engine: str = "event") -> ProtocolSpec:
    try:
        return _REGISTRY[(engine, name)]
    except KeyError:
        raise KeyError(
            f"no protocol {name!r} for engine {engine!r} "
            f"(registered: {sorted(_REGISTRY)})"
        ) from None


def protocol_names(engine: str | None = None) -> list[str]:
    return sorted({n for (e, n) in _REGISTRY if engine is None or e == engine})


def specs(engine: str | None = None) -> list[ProtocolSpec]:
    return [s for (e, _), s in sorted(_REGISTRY.items()) if engine is None or e == engine]


def create_protocol(name: str, *, engine: str = "event", **kw: Any) -> SketchProtocol:
    """Instantiate a registered protocol.

    Event engine:  ``create_protocol("P2", m=8, eps=0.1, d=64, seed=0)``
    Shard engine:  ``create_protocol("P2", engine="shard", mesh=mesh, d=64,
    eps=0.1, axis="data")`` — m is the mesh axis size.
    """
    return get_spec(name, engine).factory(**kw)


# ---------------------------------------------------------------------------
# Event-driven engine adapter (core/protocols.py stream classes)
# ---------------------------------------------------------------------------


class EventProtocol(SketchProtocol):
    """Paper-exact event-at-a-time engine behind the uniform interface."""

    def __init__(self, name: str, stream_cls, *, m: int, eps: float, d: int,
                 seed: int = 0, **kw: Any):
        super().__init__(name, "event", m, eps, d)
        self._rng = np.random.default_rng(seed)
        self._stream = stream_cls(m, eps, d, self._rng, **kw)
        self._rr = 0  # round-robin cursor for site-less feeds
        self._cached_result: event.MatrixResult | None = None

    def step(self, rows: np.ndarray, sites: np.ndarray | None = None) -> None:
        rows = np.asarray(rows)
        if sites is None:
            sites = (np.arange(rows.shape[0]) + self._rr) % self.m
            self._rr = int((self._rr + rows.shape[0]) % self.m)
        self._stream.step(rows, np.asarray(sites))
        self.rows_seen += int(rows.shape[0])
        self._cached_result = None

    def _result(self) -> event.MatrixResult:
        # result() is pure in the stream state; cache until the next step.
        if self._cached_result is None:
            self._cached_result = self._stream.result()
        return self._cached_result

    def matrix(self) -> np.ndarray:
        return np.asarray(self._result().b)

    def frob_estimate(self) -> float:
        return float(self._result().f_hat)

    def comm_report(self) -> CommReport:
        return self._stream.comm.report(self.m)


# ---------------------------------------------------------------------------
# shard_map super-step engine adapter (core/distributed.py)
# ---------------------------------------------------------------------------


class ShardProtocol(SketchProtocol):
    """TPU super-step engine behind the uniform interface.

    ``sites`` is ignored: row placement *is* the sharding of the input batch
    over the mesh axis (each shard is one paper site).
    """

    def __init__(self, name: str, *, mesh, d: int, eps: float = 0.1,
                 axis: str = "data", l_site: int = 0, l_coord: int = 0,
                 s: int = 0, use_pallas: bool = False):
        m = mesh.shape[axis]
        super().__init__(name, "shard", m, eps, d)
        self.cfg = dist.ProtocolConfig(
            eps=eps, m=m, d=d, axis=axis, l_site=l_site, l_coord=l_coord,
            s=s, use_pallas=use_pallas,
        ).resolved()
        self.state, self._step = dist.make_protocol_runner(name, self.cfg, mesh)
        self._cached_matrix: np.ndarray | None = None

    def step(self, rows, sites: np.ndarray | None = None) -> None:
        self.state = self._step(self.state, rows)
        self.rows_seen += int(rows.shape[0])
        self._cached_matrix = None

    def matrix(self) -> np.ndarray:
        # The sketch is a pure function of the state: one device->host
        # materialization per super-step serves matrix/frob/query alike.
        if self._cached_matrix is None:
            self._cached_matrix = np.asarray(dist.protocol_matrix(self.name, self.state))
        return self._cached_matrix

    def frob_estimate(self) -> float:
        # Reuse the host matrix if this super-step already materialized it;
        # otherwise protocol_frob reads f_hat (P1/P2) or reduces on device
        # (P3) without forcing a full host transfer.
        return dist.protocol_frob(self.name, self.state, matrix=self._cached_matrix)

    def comm_report(self) -> CommReport:
        return self.state.comm.report(self.cfg.m)


# ---------------------------------------------------------------------------
# Registrations — the one place protocol names are bound to engines.
# ---------------------------------------------------------------------------


def _event_factory(name: str, stream_cls):
    def make(**kw: Any) -> EventProtocol:
        return EventProtocol(name, stream_cls, **kw)

    return make


def _shard_factory(name: str):
    def make(**kw: Any) -> ShardProtocol:
        return ShardProtocol(name, **kw)

    return make


_EVENT_ERR = {"P1": 1.0, "P2": 1.0, "P3": 1.5, "P3wr": 3.0}

for _name, _cls in event.MATRIX_STREAMS.items():
    register_protocol(ProtocolSpec(
        name=_name,
        engine="event",
        factory=_event_factory(_name, _cls),
        err_factor=_EVENT_ERR[_name],
        description=f"event-driven matrix {_name} (paper Section 5)",
    ))

for _name in ("P1", "P2", "P3"):
    register_protocol(ProtocolSpec(
        name=_name,
        engine="shard",
        factory=_shard_factory(_name),
        err_factor=1.5 if _name == "P3" else 1.0,
        description=f"shard_map super-step matrix {_name}",
    ))
