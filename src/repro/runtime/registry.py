"""Typed protocol registry — one dispatch point for every engine and workload.

The paper describes one coordinator loop over two workload families: matrix
tracking (Section 5, answering ``||A x||^2``) and weighted heavy hitters
(Section 4, answering frequency estimates), each with an event-driven
paper-exact engine (``core/protocols.py``) and a shard_map TPU super-step
engine (``core/distributed.py``).  This module gives them one typed surface
and one registration point:

  * ``SketchProtocol`` — the matrix workload interface::

        step(rows, sites=None)   absorb a batch of stream rows
        matrix()                 the coordinator sketch B, (l, d) numpy
        frob_estimate()          coordinator estimate of ||A||_F^2
        comm_report()            uniform CommReport (paper message units)
        query(x) / query_batch() ||B x||^2 via the shared quadform kernel

  * ``HHProtocol`` — the weighted heavy-hitter workload interface::

        step(pairs, sites=None)  absorb an (n, 2) [element, weight] batch
        estimates()              coordinator {element: weight-estimate} map
        total_weight()           coordinator estimate of the stream mass W
        estimate(keys)           vectorized point lookups
        heavy_hitters(phi)       the paper's (phi - eps/2) W threshold set
        snapshot_matrix()        publishable (n, 2) encoding for the store

  * ``QuantileProtocol`` — the distributed-quantile workload interface::

        step(pairs, sites=None)  absorb an (n, 2) [value, weight] batch
        table()                  coordinator (k, 2) [value, rank] table
        total_weight()           coordinator estimate of the stream mass W
        rank(values)             vectorized weighted-rank estimates
        quantile(phis)           vectorized eps-approximate phi-quantiles
        snapshot_matrix()        publishable (n, 2) encoding for the store

  * ``LeverageProtocol`` — the leverage-score row-sampling interface::

        step(rows, sites=None)   absorb an (n, d) batch of stream rows
        sampled_rows()           coordinator (k, d+2) [row|score|weight] table
        total_weight()           coordinator estimate of ||A||_F^2
        lam()                    the live ridge lambda the sample is scored at
        subspace_query(x)        importance-weighted ||A x||^2 estimate
        score_batch(xs)          ridge leverage scores via ops.levscore
        snapshot_matrix()        publishable (n, d+2) encoding for the store

All interfaces also speak the pipeline checkpoint contract —
``state_payload()`` / ``restore_payload()`` — so a ``StreamingPipeline``
can persist live protocol state (not just published snapshots) and resume
ingest mid-stream after a coordinator restart.

Every implementation is registered as a ``ProtocolSpec`` keyed by
``(kind, engine, name)``; consumers (``DistributedMatrixTracker``, the
streaming pipeline, benchmarks, the registry round-trip tests) enumerate
and construct protocols through the registry instead of hard-coding
per-protocol branches.  A new workload joins the pipeline by registering
one spec.
"""
from __future__ import annotations

import abc
from dataclasses import dataclass
from typing import Any, Callable

import numpy as np

from repro.core import distributed as dist
from repro.core import leverage as lev
from repro.core import protocols as event
from repro.core import quantiles as quant
from repro.core.comm import CommReport
from repro.core.hh import encode_hh_snapshot

__all__ = [
    "SketchProtocol",
    "HHProtocol",
    "QuantileProtocol",
    "LeverageProtocol",
    "ProtocolSpec",
    "register_protocol",
    "get_spec",
    "protocol_names",
    "specs",
    "create_protocol",
]


class _StatefulStream:
    """Shared lifecycle of every registered protocol: identity + checkpointing.

    ``state_payload`` / ``restore_payload`` are the pipeline checkpoint
    contract: ``(arrays, meta)`` where ``arrays`` is a flat dict of numpy
    leaves (stored as hashed checkpoint leaves) and ``meta`` is a JSON-able
    dict (stored in the manifest's ``extra``).  Restoring into a freshly
    constructed protocol of the same spec/config must reproduce the stream
    state bit-identically.
    """

    name: str
    engine: str
    kind: str
    m: int
    eps: float

    def __init__(self, name: str, engine: str, kind: str, m: int, eps: float):
        self.name = name
        self.engine = engine
        self.kind = kind
        self.m = m
        self.eps = eps
        self.rows_seen = 0

    def state_payload(self) -> tuple[dict[str, np.ndarray], dict]:
        """Serialize live protocol state; override to opt into checkpointing."""
        raise NotImplementedError(
            f"{type(self).__name__} ({self.kind}/{self.engine}/{self.name}) does "
            "not implement pipeline checkpointing"
        )

    def restore_payload(self, arrays: dict[str, np.ndarray], meta: dict) -> None:
        """Restore state captured by ``state_payload`` into this instance."""
        raise NotImplementedError(
            f"{type(self).__name__} ({self.kind}/{self.engine}/{self.name}) does "
            "not implement pipeline checkpointing"
        )


class SketchProtocol(_StatefulStream, abc.ABC):
    """Uniform matrix-sketch interface over every engine/protocol pair."""

    d: int

    def __init__(self, name: str, engine: str, m: int, eps: float, d: int):
        super().__init__(name, engine, "matrix", m, eps)
        self.d = d

    @abc.abstractmethod
    def step(self, rows: np.ndarray, sites: np.ndarray | None = None) -> None:
        """Absorb an (n, d) batch of stream rows (continuing prior state)."""

    @abc.abstractmethod
    def matrix(self) -> np.ndarray:
        """The coordinator's current sketch matrix B, shape (l, d)."""

    @abc.abstractmethod
    def frob_estimate(self) -> float:
        """Coordinator estimate of the stream mass ``||A||_F^2``."""

    @abc.abstractmethod
    def comm_report(self) -> CommReport:
        """Messages spent so far, in the paper's units."""

    # -- queries: one code path for every engine (and the serving layer) ----

    def query_batch(self, x: np.ndarray) -> np.ndarray:
        """``||B x_j||^2`` for each row of ``x`` via ``kernels.ops.quadform``.

        The same kernel the serving engine's pallas path launches, so
        tracker-side and serving-side answers can never diverge.
        """
        import jax.numpy as jnp

        from repro.kernels.ops import quadform

        b = self.matrix()
        x = np.asarray(x, np.float32)
        if b.shape[0] == 0:  # empty sketch: every quadratic form is 0
            return np.zeros(x.shape[0], np.float32)
        return np.asarray(quadform(jnp.asarray(b, jnp.float32), jnp.asarray(x)))

    def query(self, x: np.ndarray) -> float:
        """Single-direction ``||B x||^2`` over the shared quadform path."""
        return float(self.query_batch(np.asarray(x)[None, :])[0])


class HHProtocol(_StatefulStream, abc.ABC):
    """Uniform weighted heavy-hitter interface over every engine."""

    def __init__(self, name: str, engine: str, m: int, eps: float):
        super().__init__(name, engine, "hh", m, eps)

    @staticmethod
    def split_pairs(pairs) -> tuple[np.ndarray, np.ndarray]:
        """Normalize an ingest batch to ``(keys int64, weights float64)``.

        Accepts an ``(n, 2)`` array of [element, weight] rows (the pipeline
        wire format — element ids must stay in [0, 2**24) to survive f32) or
        an explicit ``(keys, weights)`` pair of 1-D arrays.  Negative ids
        are rejected: -1 is the MG empty-slot sentinel in the shard engine,
        so letting one through would silently corrupt the sketch.
        """
        if isinstance(pairs, tuple):
            keys, weights = pairs
        else:
            arr = np.asarray(pairs)
            if arr.ndim != 2 or arr.shape[1] != 2:
                raise ValueError(
                    f"HH ingest batch must be (n, 2) [element, weight] rows or a "
                    f"(keys, weights) tuple, got shape {arr.shape}"
                )
            keys, weights = arr[:, 0], arr[:, 1]
        keys = np.asarray(keys).astype(np.int64)
        if keys.size and not (0 <= int(keys.min()) and int(keys.max()) < 1 << 24):
            raise ValueError(
                "HH element ids must be in [0, 2**24): negative ids collide with "
                "the MG empty-slot sentinel, larger ones don't survive the f32 "
                "snapshot encoding"
            )
        return keys, np.asarray(weights, np.float64)

    @abc.abstractmethod
    def step(self, pairs, sites: np.ndarray | None = None) -> None:
        """Absorb a batch of weighted elements (continuing prior state)."""

    @abc.abstractmethod
    def estimates(self) -> dict[int, float]:
        """The coordinator's current ``{element: weight-estimate}`` map."""

    @abc.abstractmethod
    def total_weight(self) -> float:
        """Coordinator estimate of the total stream weight ``W``."""

    @abc.abstractmethod
    def comm_report(self) -> CommReport:
        """Messages spent so far, in the paper's units."""

    # -- queries ------------------------------------------------------------

    def estimate(self, keys) -> np.ndarray:
        """Vectorized point lookups: estimated weight per queried element."""
        est = self.estimates()
        flat = np.asarray(keys).ravel()
        return np.array([est.get(int(e), 0.0) for e in flat], np.float32)

    def heavy_hitters(self, phi: float) -> list[int]:
        """Elements with ``hat{W}_e >= (phi - eps/2) hat{W}`` (paper Sec. 4)."""
        from repro.core.hh import threshold_heavy_hitters

        return threshold_heavy_hitters(
            self.estimates(), self.total_weight(), self.eps, phi
        )

    def snapshot_matrix(self) -> np.ndarray:
        """Publishable ``(n, 2)`` [element, estimate] encoding of the state."""
        return encode_hh_snapshot(self.estimates())


class QuantileProtocol(_StatefulStream, abc.ABC):
    """Uniform distributed-quantile interface over every engine."""

    def __init__(self, name: str, engine: str, m: int, eps: float):
        super().__init__(name, engine, "quantile", m, eps)

    @staticmethod
    def split_pairs(pairs) -> tuple[np.ndarray, np.ndarray]:
        """Normalize an ingest batch to ``(values f64, weights f64)``.

        Accepts an ``(n, 2)`` array of [value, weight] rows (the pipeline
        wire format) or an explicit ``(values, weights)`` pair of 1-D
        arrays.  Values must be finite *as float32* (the summaries and
        the published table are f32; a value that rounds to ``+/-inf``
        would collide with the jit summary's empty-slot sentinel and be
        silently dropped) and weights non-negative.
        """
        if isinstance(pairs, tuple):
            values, weights = pairs
        else:
            arr = np.asarray(pairs)
            if arr.ndim != 2 or arr.shape[1] != 2:
                raise ValueError(
                    f"quantile ingest batch must be (n, 2) [value, weight] rows "
                    f"or a (values, weights) tuple, got shape {arr.shape}"
                )
            values, weights = arr[:, 0], arr[:, 1]
        values = np.asarray(values, np.float64)
        weights = np.asarray(weights, np.float64)
        if values.size and not np.all(
            np.isfinite(values) & (np.abs(values) <= np.finfo(np.float32).max)
        ):
            raise ValueError(
                "quantile values must be finite in float32: +/-inf (incl. "
                "f32 overflow) collides with the summary's empty-slot "
                "sentinel and NaN cannot be ranked"
            )
        if weights.size and (not np.all(np.isfinite(weights)) or weights.min() < 0):
            raise ValueError("quantile weights must be finite and >= 0")
        return values, weights

    @abc.abstractmethod
    def step(self, pairs, sites: np.ndarray | None = None) -> None:
        """Absorb a batch of weighted values (continuing prior state)."""

    @abc.abstractmethod
    def table(self) -> np.ndarray:
        """The coordinator's ``(k, 2)`` [value, rank-estimate] table."""

    @abc.abstractmethod
    def total_weight(self) -> float:
        """Coordinator estimate of the total stream weight ``W``."""

    @abc.abstractmethod
    def comm_report(self) -> CommReport:
        """Messages spent so far, in the paper's units."""

    # -- queries: one searchsorted path for every engine and the serving ----

    def rank(self, values) -> np.ndarray:
        """Vectorized weighted-rank estimates (error <= eps W)."""
        return quant.table_rank(self.table(), values)

    def quantile(self, phis) -> np.ndarray:
        """Vectorized eps-approximate phi-quantile values."""
        return quant.table_quantile(self.table(), self.total_weight(), phis)

    def snapshot_matrix(self) -> np.ndarray:
        """Publishable sorted ``(n, 2)`` [value, rank] encoding of the state."""
        return quant.encode_quantile_snapshot(self.table())


class LeverageProtocol(_StatefulStream, abc.ABC):
    """Uniform leverage-score row-sampling interface over every engine."""

    d: int

    def __init__(self, name: str, engine: str, m: int, eps: float, d: int):
        super().__init__(name, engine, "leverage", m, eps)
        self.d = d
        # Live ridge factor, memoized until the next step() — repeated
        # score_batch sweeps against unchanged state skip the O(d^3) pinv
        # (the serving engine caches the same factor per (tenant, version)).
        self._live_factor: np.ndarray | None = None

    def check_rows(self, rows) -> np.ndarray:
        """Normalize an ingest batch to finite f32 ``(n, d)`` rows."""
        arr = np.asarray(rows, np.float32)
        if arr.ndim != 2 or arr.shape[1] != self.d:
            raise ValueError(
                f"leverage ingest batch must be (n, {self.d}) rows, got shape "
                f"{np.asarray(rows).shape}"
            )
        if arr.size and not np.all(np.isfinite(arr)):
            raise ValueError("leverage stream rows must be finite in float32")
        return arr

    @abc.abstractmethod
    def step(self, rows: np.ndarray, sites: np.ndarray | None = None) -> None:
        """Absorb an (n, d) batch of stream rows (continuing prior state)."""

    @abc.abstractmethod
    def sampled_rows(self) -> np.ndarray:
        """The coordinator's ``(k, d+2)`` [row | score | weight] table."""

    @abc.abstractmethod
    def total_weight(self) -> float:
        """Coordinator estimate of the stream mass ``||A||_F^2``."""

    @abc.abstractmethod
    def lam(self) -> float:
        """The live ridge ``lambda`` the sample is scored at."""

    @abc.abstractmethod
    def comm_report(self) -> CommReport:
        """Messages spent so far, in the paper's units."""

    # -- queries: the serving engine's kernel paths, shared verbatim ---------

    def subspace_query_batch(self, x: np.ndarray) -> np.ndarray:
        """Importance-weighted ``||A x_j||^2`` per (d,) direction row.

        Rides ``core.leverage.serve_subspace`` — the exact code path the
        serving engine's leverage sweeps launch, so live and published
        answers can never diverge.
        """
        return lev.serve_subspace(self.sampled_rows(), np.asarray(x, np.float32))

    def subspace_query(self, x: np.ndarray) -> float:
        """Single-direction ``||A x||^2`` estimate over the shared path."""
        return float(self.subspace_query_batch(np.asarray(x)[None, :])[0])

    def score_batch(self, x: np.ndarray) -> np.ndarray:
        """Ridge leverage score per queried vector via ``ops.levscore``."""
        import jax.numpy as jnp

        from repro.kernels.ops import levscore

        if self._live_factor is None:
            rows, _, w = lev.decode_leverage_snapshot(self.sampled_rows())
            self._live_factor = lev.ridge_factor(rows, w, self.lam())
        x = np.asarray(x, np.float32)
        return np.asarray(
            levscore(jnp.asarray(self._live_factor, jnp.float32), jnp.asarray(x))
        )

    def snapshot_matrix(self) -> np.ndarray:
        """Publishable ``(n, d+2)`` [row|score|weight] encoding of the state."""
        return lev.encode_leverage_snapshot(self.sampled_rows())


@dataclass(frozen=True)
class ProtocolSpec:
    """One registered (kind, engine, protocol) implementation.

    err_factor: multiple of the eps guarantee the protocol is certified to
    stay under — covariance error relative to ``eps ||A||_F^2`` for matrix
    protocols, point-estimate error relative to ``eps W`` for heavy hitters
    (1.0 for the deterministic protocols; sampling protocols carry the
    paper's looser constants).  The registry round-trip tests drive every
    spec through one harness per kind using this field — no per-protocol
    special cases.
    """

    name: str
    engine: str  # "event" | "shard"
    factory: Callable[..., _StatefulStream]
    err_factor: float = 1.0
    description: str = ""
    kind: str = "matrix"  # "matrix" | "hh" | "quantile" | "leverage"


_REGISTRY: dict[tuple[str, str, str], ProtocolSpec] = {}


def register_protocol(spec: ProtocolSpec) -> ProtocolSpec:
    """Add a spec under its ``(kind, engine, name)`` key; rejects duplicates."""
    key = (spec.kind, spec.engine, spec.name)
    if key in _REGISTRY:
        raise ValueError(
            f"protocol {spec.name!r} already registered for "
            f"kind {spec.kind!r} / engine {spec.engine!r}"
        )
    _REGISTRY[key] = spec
    return spec


def get_spec(name: str, engine: str = "event", kind: str = "matrix") -> ProtocolSpec:
    """Look up one spec; raises KeyError naming what *is* registered."""
    try:
        return _REGISTRY[(kind, engine, name)]
    except KeyError:
        raise KeyError(
            f"no {kind} protocol {name!r} for engine {engine!r} "
            f"(registered: {sorted(_REGISTRY)})"
        ) from None


def protocol_names(engine: str | None = None, kind: str | None = None) -> list[str]:
    """Registered protocol names, optionally filtered by engine and/or kind."""
    return sorted(
        {
            n
            for (k, e, n) in _REGISTRY
            if (engine is None or e == engine) and (kind is None or k == kind)
        }
    )


def specs(engine: str | None = None, kind: str | None = None) -> list[ProtocolSpec]:
    """All registered specs, optionally filtered by engine and/or kind."""
    return [
        s
        for (k, e, _), s in sorted(_REGISTRY.items())
        if (engine is None or e == engine) and (kind is None or k == kind)
    ]


def create_protocol(
    name: str, *, engine: str = "event", kind: str = "matrix", **kw: Any
):
    """Instantiate a registered protocol.

    Event engine:  ``create_protocol("P2", m=8, eps=0.1, d=64, seed=0)``
    Shard engine:  ``create_protocol("P2", engine="shard", mesh=mesh, d=64,
    eps=0.1, axis="data")`` — m is the mesh axis size.
    HH workloads:  pass ``kind="hh"`` (and drop ``d``; HH streams are
    (element, weight) pairs).
    Quantiles:     pass ``kind="quantile"`` (streams are (value, weight)
    pairs; see ``QuantileProtocol``).
    Leverage:      pass ``kind="leverage"`` (streams are (n, d) row
    batches like matrix tracking; see ``LeverageProtocol``).
    """
    return get_spec(name, engine, kind).factory(**kw)


# ---------------------------------------------------------------------------
# Event-driven engine adapters (core/protocols.py stream classes)
# ---------------------------------------------------------------------------


class EventProtocol(SketchProtocol):
    """Paper-exact event-at-a-time matrix engine behind the uniform interface."""

    def __init__(self, name: str, stream_cls, *, m: int, eps: float, d: int,
                 seed: int = 0, **kw: Any):
        super().__init__(name, "event", m, eps, d)
        self._rng = np.random.default_rng(seed)
        self._stream = stream_cls(m, eps, d, self._rng, **kw)
        self._rr = 0  # round-robin cursor for site-less feeds
        self._cached_result: event.MatrixResult | None = None

    def step(self, rows: np.ndarray, sites: np.ndarray | None = None) -> None:
        """Absorb an (n, d) batch; site-less feeds get round-robin sites."""
        rows = np.asarray(rows)
        if sites is None:
            sites = (np.arange(rows.shape[0]) + self._rr) % self.m
            self._rr = int((self._rr + rows.shape[0]) % self.m)
        self._stream.step(rows, np.asarray(sites))
        self.rows_seen += int(rows.shape[0])
        self._cached_result = None

    def _result(self) -> event.MatrixResult:
        # result() is pure in the stream state; cache until the next step.
        if self._cached_result is None:
            self._cached_result = self._stream.result()
        return self._cached_result

    def matrix(self) -> np.ndarray:
        """The coordinator's current sketch matrix B."""
        return np.asarray(self._result().b)

    def frob_estimate(self) -> float:
        """Coordinator estimate of ``||A||_F^2``."""
        return float(self._result().f_hat)

    def comm_report(self) -> CommReport:
        """Messages spent so far, in the paper's units."""
        return self._stream.comm.report(self.m)


class EventHHProtocol(HHProtocol):
    """Paper-exact event-at-a-time HH engine behind the uniform interface."""

    def __init__(self, name: str, stream_cls, *, m: int, eps: float,
                 seed: int = 0, **kw: Any):
        super().__init__(name, "event", m, eps)
        self._rng = np.random.default_rng(seed)
        self._stream = stream_cls(m, eps, self._rng, **kw)
        self._rr = 0  # round-robin cursor for site-less feeds
        self._cached_result: event.HHResult | None = None

    def step(self, pairs, sites: np.ndarray | None = None) -> None:
        """Absorb an (n, 2) [element, weight] batch (round-robin if site-less)."""
        keys, weights = self.split_pairs(pairs)
        if sites is None:
            sites = (np.arange(keys.shape[0]) + self._rr) % self.m
            self._rr = int((self._rr + keys.shape[0]) % self.m)
        self._stream.step(keys, weights, np.asarray(sites))
        self.rows_seen += int(keys.shape[0])
        self._cached_result = None

    def _result(self) -> event.HHResult:
        if self._cached_result is None:
            self._cached_result = self._stream.result()
        return self._cached_result

    def estimates(self) -> dict[int, float]:
        """The coordinator's current estimate map."""
        return dict(self._result().estimates)

    def total_weight(self) -> float:
        """Coordinator estimate of the total stream weight."""
        return float(self._result().w_hat)

    def comm_report(self) -> CommReport:
        """Messages spent so far, in the paper's units."""
        return self._stream.comm.report(self.m)

    def state_payload(self) -> tuple[dict[str, np.ndarray], dict]:
        """Full stream state as JSON-able meta (HH state is all small)."""
        return {}, {
            "stream": self._stream.state_dict(),
            "rr": self._rr,
            "rows_seen": self.rows_seen,
        }

    def restore_payload(self, arrays: dict[str, np.ndarray], meta: dict) -> None:
        """Restore a ``state_payload`` capture bit-identically."""
        self._stream.load_state(meta["stream"])
        self._rr = int(meta["rr"])
        self.rows_seen = int(meta["rows_seen"])
        self._cached_result = None


class EventQuantileProtocol(QuantileProtocol):
    """Paper-exact event-at-a-time quantile engine behind the interface."""

    def __init__(self, name: str, stream_cls, *, m: int, eps: float,
                 seed: int = 0, **kw: Any):
        super().__init__(name, "event", m, eps)
        self._rng = np.random.default_rng(seed)
        self._stream = stream_cls(m, eps, self._rng, **kw)
        self._rr = 0  # round-robin cursor for site-less feeds
        self._cached_result: quant.QuantileResult | None = None

    def step(self, pairs, sites: np.ndarray | None = None) -> None:
        """Absorb an (n, 2) [value, weight] batch (round-robin if site-less)."""
        values, weights = self.split_pairs(pairs)
        if sites is None:
            sites = (np.arange(values.shape[0]) + self._rr) % self.m
            self._rr = int((self._rr + values.shape[0]) % self.m)
        self._stream.step(values, weights, np.asarray(sites))
        self.rows_seen += int(values.shape[0])
        self._cached_result = None

    def _result(self) -> quant.QuantileResult:
        if self._cached_result is None:
            self._cached_result = self._stream.result()
        return self._cached_result

    def table(self) -> np.ndarray:
        """The coordinator's current table."""
        return np.asarray(self._result().table)

    def total_weight(self) -> float:
        """Coordinator estimate of the total stream weight."""
        return float(self._result().w_hat)

    def comm_report(self) -> CommReport:
        """Messages spent so far, in the paper's units."""
        return self._stream.comm.report(self.m)

    def state_payload(self) -> tuple[dict[str, np.ndarray], dict]:
        """Full stream state as JSON-able meta (quantile state is small)."""
        return {}, {
            "stream": self._stream.state_dict(),
            "rr": self._rr,
            "rows_seen": self.rows_seen,
        }

    def restore_payload(self, arrays: dict[str, np.ndarray], meta: dict) -> None:
        """Restore a ``state_payload`` capture bit-identically."""
        self._stream.load_state(meta["stream"])
        self._rr = int(meta["rr"])
        self.rows_seen = int(meta["rows_seen"])
        self._cached_result = None


class EventLeverageProtocol(LeverageProtocol):
    """Paper-style event-at-a-time leverage engine behind the interface."""

    def __init__(self, name: str, stream_cls, *, m: int, eps: float, d: int,
                 seed: int = 0, **kw: Any):
        super().__init__(name, "event", m, eps, d)
        self._rng = np.random.default_rng(seed)
        self._stream = stream_cls(m, eps, d, self._rng, **kw)
        self._rr = 0  # round-robin cursor for site-less feeds
        self._cached_result: lev.LeverageResult | None = None

    def step(self, rows: np.ndarray, sites: np.ndarray | None = None) -> None:
        """Absorb an (n, d) row batch (round-robin sites if site-less)."""
        rows = self.check_rows(rows)
        if sites is None:
            sites = (np.arange(rows.shape[0]) + self._rr) % self.m
            self._rr = int((self._rr + rows.shape[0]) % self.m)
        self._stream.step(rows, np.asarray(sites))
        self.rows_seen += int(rows.shape[0])
        self._cached_result = None
        self._live_factor = None

    def _result(self) -> lev.LeverageResult:
        if self._cached_result is None:
            self._cached_result = self._stream.result()
        return self._cached_result

    def sampled_rows(self) -> np.ndarray:
        """The coordinator's current [row | score | weight] table."""
        return np.asarray(self._result().table)

    def total_weight(self) -> float:
        """Coordinator estimate of the stream mass ``||A||_F^2``."""
        return float(self._result().f_hat)

    def lam(self) -> float:
        """The live ridge ``lambda`` the sample is scored at."""
        return float(self._result().lam)

    def comm_report(self) -> CommReport:
        """Messages spent so far, in the paper's units."""
        return self._stream.comm.report(self.m)

    def state_payload(self) -> tuple[dict[str, np.ndarray], dict]:
        """Full stream state as JSON-able meta (leverage state is small)."""
        return {}, {
            "stream": self._stream.state_dict(),
            "rr": self._rr,
            "rows_seen": self.rows_seen,
        }

    def restore_payload(self, arrays: dict[str, np.ndarray], meta: dict) -> None:
        """Restore a ``state_payload`` capture bit-identically."""
        self._stream.load_state(meta["stream"])
        self._rr = int(meta["rr"])
        self.rows_seen = int(meta["rows_seen"])
        self._cached_result = None
        self._live_factor = None


# ---------------------------------------------------------------------------
# shard_map super-step engine adapters (core/distributed.py)
# ---------------------------------------------------------------------------


def _flatten_state(state) -> tuple[dict[str, np.ndarray], list[str]]:
    """Flatten a jax protocol state into ckpt leaves + per-leaf tags.

    PRNG-key leaves (P3's per-site keys) are stored as their raw key data
    and tagged, so restore can rewrap them with ``wrap_key_data``.
    """
    import jax

    leaves, _ = jax.tree_util.tree_flatten(state)
    arrays: dict[str, np.ndarray] = {}
    tags: list[str] = []
    for i, leaf in enumerate(leaves):
        if jax.dtypes.issubdtype(leaf.dtype, jax.dtypes.prng_key):
            arrays[f"leaf_{i:03d}"] = np.asarray(jax.random.key_data(leaf))
            tags.append("prng_key")
        else:
            arrays[f"leaf_{i:03d}"] = np.asarray(leaf)
            tags.append("array")
    return arrays, tags


def _unflatten_state(template, arrays: dict[str, np.ndarray], tags: list[str]):
    import jax
    import jax.numpy as jnp

    leaves, treedef = jax.tree_util.tree_flatten(template)
    if len(leaves) != len(tags):
        raise ValueError(
            f"checkpointed state has {len(tags)} leaves, expected {len(leaves)} "
            "(protocol/config mismatch?)"
        )
    new = []
    for i, old in enumerate(leaves):
        arr = arrays[f"leaf_{i:03d}"]
        if tags[i] == "prng_key":
            restored = jax.random.wrap_key_data(jnp.asarray(arr))
        else:
            restored = jnp.asarray(arr).astype(old.dtype)
        # Shape mismatch means the protocol was rebuilt with a different
        # config (e.g. a mesh whose axis size != the checkpoint's m): fail
        # here with the cause, not later inside a jitted shard_map step.
        if restored.shape != old.shape:
            raise ValueError(
                f"checkpointed state leaf {i} has shape {restored.shape}, "
                f"expected {old.shape} (protocol/config mismatch — was the "
                "pipeline reloaded onto a mesh of a different size?)"
            )
        new.append(restored)
    return jax.tree_util.tree_unflatten(treedef, new)


class _ShardCheckpointMixin:
    """Checkpoint contract shared by every jit-state (shard) protocol.

    Flattens the protocol's jax state into checkpoint leaves (PRNG keys
    tagged for rewrapping) and restores bit-identically; subclasses supply
    ``_invalidate()`` to drop their host-side caches after a restore.

    Also the packed-ingest contract: shard protocols whose distributed
    step is in ``dist.PACKABLE_PROTOCOLS`` expose a ``pack_key`` so the
    pipeline can stack same-shape tenants into one
    ``dist.make_packed_runner`` launch, and ``apply_packed`` installs one
    tenant's stake in the stacked result with the same bookkeeping a
    serial ``step`` performs.  The install is LAZY: ``state`` is a
    property backed by either a materialized per-tenant tree or a
    ``(stacked, index)`` slot into the pack's resident stacked state, and
    the slice only happens when something actually reads the state
    (publish, query, checkpoint).  ``_epoch`` counts every state write so
    ``runtime.ingest_packed`` can tell whether a cached stacked state is
    still current for the whole group or a member stepped out-of-band.
    """

    # Set by each shard protocol __init__: the core.distributed step name
    # ("P1", "LP1", ...) and the mesh the runner was built for.
    _dist_key: str = ""
    _mesh = None
    _state = None
    _pack_slot: tuple | None = None
    _epoch: int = 0

    @property
    def state(self):
        """The protocol's jit state, slicing it out of a pack on first read."""
        if self._pack_slot is not None:
            stacked, index = self._pack_slot
            self._state = dist.unstack_packed(stacked, index)
            self._pack_slot = None
        return self._state

    @state.setter
    def state(self, value) -> None:
        self._state = value
        self._pack_slot = None
        self._epoch += 1

    def state_payload(self) -> tuple[dict[str, np.ndarray], dict]:
        """Flatten the jit-able protocol state into checkpoint leaves."""
        arrays, tags = _flatten_state(self.state)
        return arrays, {"leaves": tags, "rows_seen": self.rows_seen}

    def restore_payload(self, arrays: dict[str, np.ndarray], meta: dict) -> None:
        """Restore a ``state_payload`` capture bit-identically."""
        self.state = _unflatten_state(self.state, arrays, list(meta["leaves"]))
        self.rows_seen = int(meta["rows_seen"])
        self._invalidate()

    def pack_key(self):
        """Grouping key for packed multi-tenant ingest, or None.

        Tenants with equal keys — same distributed step, same resolved
        ``ProtocolConfig`` (hence same (l, d, dtype) state shapes), same
        mesh — may be stacked into one ``(T, ...)`` super-step launch.
        Protocols outside ``dist.PACKABLE_PROTOCOLS`` return None and
        always ingest serially.
        """
        if self._dist_key not in dist.PACKABLE_PROTOCOLS:
            return None
        return (self._dist_key, self.cfg, self._mesh)

    def apply_packed(self, stacked_state, index: int, n_rows: int) -> None:
        """Point this tenant at its slot in a packed super-step result.

        No per-tenant slice happens here — the pack's stacked state stays
        resident on device and the ``state`` property materializes slot
        ``index`` only if something reads it before the next wave.
        """
        self._state = None
        self._pack_slot = (stacked_state, index)
        self._epoch += 1
        self.rows_seen += int(n_rows)
        self._invalidate()


class ShardProtocol(_ShardCheckpointMixin, SketchProtocol):
    """TPU super-step matrix engine behind the uniform interface.

    ``sites`` is ignored: row placement *is* the sharding of the input batch
    over the mesh axis (each shard is one paper site).
    """

    def __init__(self, name: str, *, mesh, d: int, eps: float = 0.1,
                 axis: str = "data", l_site: int = 0, l_coord: int = 0,
                 s: int = 0, use_pallas: bool = False):
        m = mesh.shape[axis]
        super().__init__(name, "shard", m, eps, d)
        self.cfg = dist.ProtocolConfig(
            eps=eps, m=m, d=d, axis=axis, l_site=l_site, l_coord=l_coord,
            s=s, use_pallas=use_pallas,
        ).resolved()
        self._dist_key, self._mesh = name, mesh
        self.state, self._step = dist.make_protocol_runner(name, self.cfg, mesh)
        self._cached_matrix: np.ndarray | None = None

    def step(self, rows, sites: np.ndarray | None = None) -> None:
        """Advance one super-step on a mesh-sharded (n, d) batch."""
        self.state = self._step(self.state, rows)
        self.rows_seen += int(rows.shape[0])
        self._cached_matrix = None

    def matrix(self) -> np.ndarray:
        """The coordinator's current sketch matrix B."""
        # The sketch is a pure function of the state: one device->host
        # materialization per super-step serves matrix/frob/query alike.
        if self._cached_matrix is None:
            self._cached_matrix = np.asarray(dist.protocol_matrix(self.name, self.state))
        return self._cached_matrix

    def frob_estimate(self) -> float:
        """Coordinator estimate of ``||A||_F^2``."""
        # Reuse the host matrix if this super-step already materialized it;
        # otherwise protocol_frob reads f_hat (P1/P2) or reduces on device
        # (P3) without forcing a full host transfer.
        return dist.protocol_frob(self.name, self.state, matrix=self._cached_matrix)

    def comm_report(self) -> CommReport:
        """Messages spent so far, in the paper's units."""
        return self.state.comm.report(self.cfg.m)

    def _invalidate(self) -> None:
        self._cached_matrix = None


class ShardHHProtocol(_ShardCheckpointMixin, HHProtocol):
    """TPU super-step HH engine (batched MG merge) behind the uniform interface.

    ``sites`` is ignored: element placement *is* the sharding of the input
    batch over the mesh axis.  Backed by ``core.distributed.hh_p1_step``
    (per-shard ``MGState`` + ``mg_merge`` coordinator folding).
    """

    def __init__(self, name: str, *, mesh, eps: float = 0.1,
                 axis: str = "data", k: int = 0):
        m = mesh.shape[axis]
        super().__init__(name, "shard", m, eps)
        self.cfg = dist.ProtocolConfig(eps=eps, m=m, d=2, axis=axis, k=k).resolved()
        self._dist_key, self._mesh = "HH" + name, mesh
        self.state, self._step = dist.make_protocol_runner("HH" + name, self.cfg, mesh)
        self._cached_estimates: dict[int, float] | None = None

    def step(self, pairs, sites: np.ndarray | None = None) -> None:
        """Advance one super-step on a mesh-sharded weighted-element batch."""
        import jax.numpy as jnp

        keys, weights = self.split_pairs(pairs)
        self.state = self._step(
            self.state,
            (jnp.asarray(keys, jnp.int32), jnp.asarray(weights, jnp.float32)),
        )
        self.rows_seen += int(keys.shape[0])
        self._cached_estimates = None

    def estimates(self) -> dict[int, float]:
        """The coordinator's current estimate map (one host read per step)."""
        if self._cached_estimates is None:
            self._cached_estimates = dist.hh_estimates(self.state)
        return dict(self._cached_estimates)

    def total_weight(self) -> float:
        """Coordinator estimate of the total stream weight."""
        return dist.hh_w_hat(self.state)

    def comm_report(self) -> CommReport:
        """Messages spent so far, in the paper's units."""
        return self.state.comm.report(self.cfg.m)

    def _invalidate(self) -> None:
        self._cached_estimates = None


class ShardQuantileProtocol(_ShardCheckpointMixin, QuantileProtocol):
    """TPU super-step quantile engine behind the uniform interface.

    ``sites`` is ignored: value placement *is* the sharding of the input
    batch over the mesh axis.  Backed by ``core.distributed.quant_p1_step``
    (per-shard ``QuantState`` + ``quant_merge`` coordinator folding).
    """

    def __init__(self, name: str, *, mesh, eps: float = 0.1,
                 axis: str = "data", q_cap: int = 0):
        m = mesh.shape[axis]
        super().__init__(name, "shard", m, eps)
        self.cfg = dist.ProtocolConfig(
            eps=eps, m=m, d=2, axis=axis, q_cap=q_cap
        ).resolved()
        self._dist_key, self._mesh = "Q" + name, mesh
        self.state, self._step = dist.make_protocol_runner("Q" + name, self.cfg, mesh)
        self._cached_table: np.ndarray | None = None

    def step(self, pairs, sites: np.ndarray | None = None) -> None:
        """Advance one super-step on a mesh-sharded weighted-value batch."""
        import jax.numpy as jnp

        values, weights = self.split_pairs(pairs)
        self.state = self._step(
            self.state,
            (jnp.asarray(values, jnp.float32), jnp.asarray(weights, jnp.float32)),
        )
        self.rows_seen += int(values.shape[0])
        self._cached_table = None

    def table(self) -> np.ndarray:
        """The coordinator's current table (one host read per step)."""
        if self._cached_table is None:
            self._cached_table = np.asarray(dist.quant_p1_table(self.state))
        return self._cached_table

    def total_weight(self) -> float:
        """Coordinator estimate of the total stream weight."""
        return dist.quant_p1_w_hat(self.state)

    def comm_report(self) -> CommReport:
        """Messages spent so far, in the paper's units."""
        return self.state.comm.report(self.cfg.m)

    def _invalidate(self) -> None:
        self._cached_table = None


class ShardLeverageProtocol(_ShardCheckpointMixin, LeverageProtocol):
    """TPU super-step leverage engine behind the uniform interface.

    ``sites`` is ignored: row placement *is* the sharding of the input
    batch over the mesh axis.  Backed by ``core.distributed.lev_p1_step``
    (per-shard FD residual + masked candidate gather, ``lev_merge_spill``
    coordinator folding).
    """

    def __init__(self, name: str, *, mesh, d: int, eps: float = 0.1,
                 axis: str = "data", lev_cap: int = 0, l_site: int = 0,
                 l_coord: int = 0, use_pallas: bool = False):
        m = mesh.shape[axis]
        super().__init__(name, "shard", m, eps, d)
        self.cfg = dist.ProtocolConfig(
            eps=eps, m=m, d=d, axis=axis, lev_cap=lev_cap,
            l_site=l_site, l_coord=l_coord, use_pallas=use_pallas,
        ).resolved()
        self._dist_key, self._mesh = "L" + name, mesh
        self.state, self._step = dist.make_protocol_runner("L" + name, self.cfg, mesh)
        self._cached_table: np.ndarray | None = None

    def step(self, rows, sites: np.ndarray | None = None) -> None:
        """Advance one super-step on a mesh-sharded (n, d) row batch."""
        import jax.numpy as jnp

        rows = self.check_rows(rows)
        self.state = self._step(self.state, jnp.asarray(rows))
        self.rows_seen += int(rows.shape[0])
        self._invalidate()

    def sampled_rows(self) -> np.ndarray:
        """The coordinator's current table (one host read per step)."""
        if self._cached_table is None:
            self._cached_table = dist.lev_p1_table(self.cfg, self.state)
        return self._cached_table

    def total_weight(self) -> float:
        """Coordinator estimate of the stream mass ``||A||_F^2``."""
        return dist.lev_p1_mass(self.state)

    def lam(self) -> float:
        """The live ridge ``lambda`` the sample is scored at."""
        return dist.lev_p1_lambda(self.cfg, self.state)

    def comm_report(self) -> CommReport:
        """Messages spent so far, in the paper's units."""
        return self.state.comm.report(self.cfg.m)

    def _invalidate(self) -> None:
        self._cached_table = None
        self._live_factor = None


# ---------------------------------------------------------------------------
# Registrations — the one place protocol names are bound to engines.
# ---------------------------------------------------------------------------


def _event_factory(name: str, stream_cls):
    def make(**kw: Any) -> EventProtocol:
        return EventProtocol(name, stream_cls, **kw)

    return make


def _event_hh_factory(name: str, stream_cls):
    def make(**kw: Any) -> EventHHProtocol:
        return EventHHProtocol(name, stream_cls, **kw)

    return make


def _shard_factory(name: str):
    def make(**kw: Any) -> ShardProtocol:
        return ShardProtocol(name, **kw)

    return make


def _shard_hh_factory(name: str):
    def make(**kw: Any) -> ShardHHProtocol:
        return ShardHHProtocol(name, **kw)

    return make


_EVENT_ERR = {"P1": 1.0, "P2": 1.0, "P3": 1.5, "P3wr": 3.0}

for _name, _cls in event.MATRIX_STREAMS.items():
    register_protocol(ProtocolSpec(
        name=_name,
        engine="event",
        factory=_event_factory(_name, _cls),
        err_factor=_EVENT_ERR[_name],
        description=f"event-driven matrix {_name} (paper Section 5)",
    ))

for _name in ("P1", "P2", "P3"):
    register_protocol(ProtocolSpec(
        name=_name,
        engine="shard",
        factory=_shard_factory(_name),
        err_factor=1.5 if _name == "P3" else 1.0,
        description=f"shard_map super-step matrix {_name}",
    ))

# Heavy hitters: deterministic P1/P2 meet eps exactly; the sampling
# protocols (P3/P3wr) and probabilistic P4 carry the paper's 2x slack.
_HH_ERR = {"P1": 1.0, "P2": 1.0, "P3": 2.0, "P3wr": 2.0, "P4": 2.0}

for _name, _cls in event.HH_STREAMS.items():
    register_protocol(ProtocolSpec(
        name=_name,
        kind="hh",
        engine="event",
        factory=_event_hh_factory(_name, _cls),
        err_factor=_HH_ERR[_name],
        description=f"event-driven weighted heavy hitters {_name} (paper Section 4)",
    ))

register_protocol(ProtocolSpec(
    name="P1",
    kind="hh",
    engine="shard",
    factory=_shard_hh_factory("P1"),
    err_factor=1.0,
    description="shard_map super-step weighted heavy hitters P1 (MG merge)",
))


def _event_quantile_factory(name: str, stream_cls):
    def make(**kw: Any) -> EventQuantileProtocol:
        return EventQuantileProtocol(name, stream_cls, **kw)

    return make


def _shard_quantile_factory(name: str):
    def make(**kw: Any) -> ShardQuantileProtocol:
        return ShardQuantileProtocol(name, **kw)

    return make


# Quantiles: deterministic P1 meets eps via the GK interval invariant; the
# sampling P3 and the fixed-capacity shard summary carry 2x slack (same
# convention as the HH sampling protocols).
_QUANT_ERR = {"P1": 1.0, "P3": 2.0}

for _name, _cls in quant.QUANTILE_STREAMS.items():
    register_protocol(ProtocolSpec(
        name=_name,
        kind="quantile",
        engine="event",
        factory=_event_quantile_factory(_name, _cls),
        err_factor=_QUANT_ERR[_name],
        description=f"event-driven distributed quantiles {_name} (GK summaries)",
    ))

register_protocol(ProtocolSpec(
    name="P1",
    kind="quantile",
    engine="shard",
    factory=_shard_quantile_factory("P1"),
    err_factor=2.0,
    description="shard_map super-step distributed quantiles P1 (summary merge)",
))


def _event_leverage_factory(name: str, stream_cls):
    def make(**kw: Any) -> EventLeverageProtocol:
        return EventLeverageProtocol(name, stream_cls, **kw)

    return make


def _shard_leverage_factory(name: str):
    def make(**kw: Any) -> ShardLeverageProtocol:
        return ShardLeverageProtocol(name, **kw)

    return make


# Leverage sampling: deterministic P1 (kept rows exact + FD residual) meets
# eps via the FD envelope; the score-weighted sampling P2 and the
# super-step shard engine carry the sampling protocols' looser slack.
_LEV_ERR = {"P1": 1.0, "P2": 3.0}

for _name, _cls in lev.LEVERAGE_STREAMS.items():
    register_protocol(ProtocolSpec(
        name=_name,
        kind="leverage",
        engine="event",
        factory=_event_leverage_factory(_name, _cls),
        err_factor=_LEV_ERR[_name],
        description=f"event-driven leverage-score row sampling {_name}",
    ))

register_protocol(ProtocolSpec(
    name="P1",
    kind="leverage",
    engine="shard",
    factory=_shard_leverage_factory("P1"),
    err_factor=1.5,
    description="shard_map super-step leverage-score row sampling P1 "
                "(threshold forwarding + FD residual)",
))

# Time-restricted tracking: sliding-window + exponential-decay wrappers
# register their (kind, engine, name) specs on import (all four kinds,
# both engines).  Imported last so every ABC above is fully defined.
from repro.runtime import windowed as _windowed  # noqa: E402,F401
