"""Windowed + decayed protocol specs for all four kinds.

Adapts ``core.windows`` (bucketed sliding window, exponential decay) to
the registry's four protocol ABCs and registers them as first-class
``(kind, engine, name)`` specs:

=========  =============  ==============================================
kind       names          serve state
=========  =============  ==============================================
matrix     P2win/P2decay  FD sketch folded across live buckets
hh         P1win/P1decay  Misra-Gries summary folded across live buckets
quantile   P1win/P1decay  GK summary folded across live buckets
leverage   P1win/P1decay  norm-scored reservoir + FD spill residual
=========  =============  ==============================================

``step`` grows a keyword-only ``ts`` (event time).  Without it the
wrapper synthesizes monotone step-count time (one unit per batch), which
makes windowed specs drop-in under every existing driver — the registry
harnesses, pipeline packed-ingest fallbacks, benchmarks — while real
deployments pass event time through ``StreamingPipeline.ingest``.

Communication model (paper units): sites push one scalar digest per
applied batch; the coordinator pulls the live per-bucket/per-site sketch
states (``ops.state_rows`` rows each) whenever it has to serve a fresher
answer than its cache.  Both counters ride the checkpoint payload so a
restored protocol reports bit-identical accounting.

The checkpoint contract matches every other spec: ``state_payload``
flattens the per-bucket jit states plus parked (pending) batches into
named numpy leaves, and ``restore_payload`` rejects geometry mismatches
before touching any state.
"""
from __future__ import annotations

import math
from types import SimpleNamespace
from typing import Any

import numpy as np

from repro.core import fd
from repro.core import hh as hhc
from repro.core import leverage as lev
from repro.core import quantiles as q
from repro.core import windows
from repro.core.comm import CommReport, build_report
from repro.runtime.registry import (
    HHProtocol,
    LeverageProtocol,
    ProtocolSpec,
    QuantileProtocol,
    SketchProtocol,
    register_protocol,
)

__all__ = [
    "DEFAULT_WINDOW",
    "DEFAULT_GAMMA",
    "WindowedMatrixProtocol",
    "WindowedHHProtocol",
    "WindowedQuantileProtocol",
    "WindowedLeverageProtocol",
]

# Synthetic time advances one unit per batch, so the defaults mean
# "effectively unwindowed" until a caller opts into real event time:
# the full harness/benchmark streams stay inside one live window and the
# default decay forgets ~0.1% per batch.
DEFAULT_WINDOW = float(2**20)
DEFAULT_GAMMA = 0.999


class _TimeWrapped:
    """Shared machinery: synthetic time, serve cache, comm, checkpoints."""

    def _init_time(
        self,
        ops: windows.WindowOps,
        mode: str,
        *,
        sites: int = 1,
        window: float | None = None,
        buckets: int = 8,
        lateness: float = 0.0,
        gamma: float | None = None,
        half_life: float | None = None,
    ) -> None:
        if mode == "win":
            self._tracked: windows._TimedSketch = windows.SlidingWindow(
                ops,
                window=DEFAULT_WINDOW if window is None else float(window),
                buckets=buckets,
                sites=sites,
                lateness=lateness,
            )
        elif mode == "decay":
            if gamma is None and half_life is None:
                gamma = DEFAULT_GAMMA
            self._tracked = windows.ExponentialDecay(
                ops, gamma=gamma, half_life=half_life, sites=sites, lateness=lateness
            )
        else:
            raise ValueError(f"mode must be 'win' or 'decay', got {mode!r}")
        self._mode = mode
        self._ops = ops
        self._last_ts = 0.0
        self._ship_rows = 0
        self._serve_cache: Any = None
        self._serve_epoch = -1

    # -- ingest ----------------------------------------------------------

    def _step_timed(self, arr: np.ndarray, ts: float | None) -> None:
        if arr.shape[0] == 0:
            return
        if ts is None:
            ts = self._last_ts + 1.0
        ts = float(ts)
        self._tracked.insert(arr, ts)  # raises LateRowError on shed
        if ts > self._last_ts:
            self._last_ts = ts
        self.rows_seen += int(arr.shape[0])
        self._invalidate()

    def _invalidate(self) -> None:
        pass

    def advance(self, ts: float) -> None:
        """Watermark heartbeat: close buckets without ingesting rows."""
        self._tracked.advance(ts)
        if float(ts) > self._last_ts:
            self._last_ts = float(ts)

    # -- time introspection (pipeline gauges / OnWindowClose) ------------

    def windows_closed(self) -> int:
        return self._tracked.windows_closed()

    def window_lag(self) -> float:
        return self._tracked.lag

    def watermark(self) -> float:
        return self._tracked.wm.watermark

    @property
    def late_rows(self) -> int:
        return self._tracked.late_rows

    @property
    def late_batches(self) -> int:
        return self._tracked.late_batches

    # -- serving ---------------------------------------------------------

    def _serve(self) -> Any:
        tr = self._tracked
        if self._serve_cache is None or self._serve_epoch != tr.epoch:
            self._serve_cache = tr.serve()
            self._serve_epoch = tr.epoch
            # coordinator pulls every live state to refresh its answer
            self._ship_rows += tr.live_states() * self._ops.state_rows
        return self._serve_cache

    def comm_report(self) -> CommReport:
        return build_report(
            scalar_msgs=self._tracked.applied_batches,
            row_msgs=self._ship_rows,
            broadcast_events=0,
            m=self.m,
        )

    # -- checkpoint contract ---------------------------------------------

    def state_payload(self) -> tuple[dict[str, np.ndarray], dict]:
        import jax

        tr = self._tracked
        pending = sorted(tr._pending, key=lambda p: p[1])
        arrays: dict[str, np.ndarray] = {
            f"pend{j:04d}": np.asarray(batch) for j, (_, _, batch) in enumerate(pending)
        }
        meta: dict = {
            "protocol": self.name,
            "engine": self.engine,
            "kind": self.kind,
            "mode": self._mode,
            "m": int(self.m),
            "eps": float(self.eps),
            "sites": int(tr.sites),
            "lateness": float(tr.wm.lateness),
            "max_ts": None if tr.wm.max_ts == -math.inf else float(tr.wm.max_ts),
            "last_ts": float(self._last_ts),
            "late_batches": int(tr.late_batches),
            "late_rows": int(tr.late_rows),
            "applied_batches": int(tr.applied_batches),
            "applied_rows": int(tr.applied_rows),
            "epoch": int(tr.epoch),
            "rows_seen": int(self.rows_seen),
            "ship_rows": int(self._ship_rows),
            "pending_ts": [float(ts) for ts, _, _ in pending],
        }
        if self._mode == "win":
            meta["window"] = float(tr.window)
            meta["buckets"] = int(tr.buckets)
            meta["closed"] = int(tr._closed)
            meta["last_marker"] = tr._last_marker
            meta["bucket_ids"] = sorted(int(b) for b in tr._states)
            groups = [(f"st{bi:04d}", tr._states[b]) for bi, b in enumerate(meta["bucket_ids"])]
        else:
            meta["gamma"] = float(tr.gamma)
            meta["ref_ts"] = tr.ref_ts
            groups = [("st0000", tr._states)]
        for prefix, states in groups:
            for si, st in enumerate(states):
                for li, leaf in enumerate(jax.tree_util.tree_leaves(st)):
                    arrays[f"{prefix}_s{si:02d}_l{li:02d}"] = np.asarray(leaf)
        return arrays, meta

    def restore_payload(self, arrays: dict[str, np.ndarray], meta: dict) -> None:
        import jax
        import jax.numpy as jnp

        tr = self._tracked
        want = {
            "protocol": self.name,
            "engine": self.engine,
            "kind": self.kind,
            "mode": self._mode,
            "m": int(self.m),
            "eps": float(self.eps),
            "sites": int(tr.sites),
        }
        got = {k: meta.get(k) for k in want}
        if got != want:
            raise ValueError(
                f"protocol/config mismatch: expected {want}, payload carries {got}"
            )
        if self._mode == "win" and (
            float(meta["window"]) != tr.window or int(meta["buckets"]) != tr.buckets
        ):
            raise ValueError(
                "protocol/config mismatch: window geometry differs "
                f"(have window={tr.window} buckets={tr.buckets}, payload has "
                f"window={meta['window']} buckets={meta['buckets']})"
            )
        template_leaves, treedef = jax.tree_util.tree_flatten(self._ops.init())

        def unflatten(prefix: str):
            leaves = []
            for li, tmpl in enumerate(template_leaves):
                arr = arrays[f"{prefix}_l{li:02d}"]
                if tuple(arr.shape) != tuple(np.shape(tmpl)):
                    raise ValueError(
                        f"protocol/config mismatch: state leaf {prefix}_l{li:02d} "
                        f"has shape {arr.shape}, expected {np.shape(tmpl)}"
                    )
                leaves.append(jnp.asarray(arr))
            return jax.tree_util.tree_unflatten(treedef, leaves)

        if self._mode == "win":
            tr._states = {
                int(b): [
                    unflatten(f"st{bi:04d}_s{si:02d}") for si in range(tr.sites)
                ]
                for bi, b in enumerate(meta["bucket_ids"])
            }
            tr._closed = int(meta["closed"])
            marker = meta["last_marker"]
            tr._last_marker = None if marker is None else int(marker)
        else:
            tr._states = [unflatten(f"st0000_s{si:02d}") for si in range(tr.sites)]
            ref = meta["ref_ts"]
            tr.ref_ts = None if ref is None else float(ref)
        tr.wm.max_ts = -math.inf if meta["max_ts"] is None else float(meta["max_ts"])
        tr._pending = [
            (float(ts), j, np.asarray(arrays[f"pend{j:04d}"]))
            for j, ts in enumerate(meta["pending_ts"])
        ]
        tr._seq = len(tr._pending)
        tr.late_batches = int(meta["late_batches"])
        tr.late_rows = int(meta["late_rows"])
        tr.applied_batches = int(meta["applied_batches"])
        tr.applied_rows = int(meta["applied_rows"])
        tr.epoch = int(meta["epoch"])
        self._last_ts = float(meta["last_ts"])
        self.rows_seen = int(meta["rows_seen"])
        self._ship_rows = int(meta["ship_rows"])
        self._serve_cache = None
        self._serve_epoch = -1
        self._invalidate()


class WindowedMatrixProtocol(_TimeWrapped, SketchProtocol):
    """Sliding-window / decayed FD matrix tracking (``P2win`` / ``P2decay``)."""

    def __init__(
        self,
        name: str,
        engine: str,
        mode: str,
        *,
        m: int,
        eps: float,
        d: int,
        l: int | None = None,
        sites: int = 1,
        window: float | None = None,
        buckets: int = 8,
        lateness: float = 0.0,
        gamma: float | None = None,
        half_life: float | None = None,
    ):
        super().__init__(name, engine, m, eps, d)
        self._l = int(l) if l else max(8, math.ceil(2.0 / eps))
        self._init_time(
            windows.fd_window_ops(self._l, d),
            mode,
            sites=sites,
            window=window,
            buckets=buckets,
            lateness=lateness,
            gamma=gamma,
            half_life=half_life,
        )

    def step(self, rows, sites=None, *, ts: float | None = None) -> None:
        arr = np.asarray(rows, np.float32)
        if arr.ndim != 2 or arr.shape[1] != self.d:
            raise ValueError(
                f"matrix ingest batch must be (n, {self.d}) rows, got shape "
                f"{np.asarray(rows).shape}"
            )
        self._step_timed(arr, ts)

    def matrix(self) -> np.ndarray:
        return np.asarray(fd.fd_matrix(self._serve()))

    def frob_estimate(self) -> float:
        return float(self._serve().frob)

    def total_weight(self) -> float:
        """Matrix alias for the uniform adapter face: stream mass."""
        return self.frob_estimate()

    def snapshot_matrix(self) -> np.ndarray:
        """Publishable (l, d) sketch — matrix snapshots encode as themselves."""
        return self.matrix()


class WindowedHHProtocol(_TimeWrapped, HHProtocol):
    """Sliding-window / decayed Misra-Gries HH (``P1win`` / ``P1decay``)."""

    def __init__(
        self,
        name: str,
        engine: str,
        mode: str,
        *,
        m: int,
        eps: float,
        k: int | None = None,
        sites: int = 1,
        window: float | None = None,
        buckets: int = 8,
        lateness: float = 0.0,
        gamma: float | None = None,
        half_life: float | None = None,
    ):
        super().__init__(name, engine, m, eps)
        self._k = int(k) if k else max(8, math.ceil(2.0 / eps))
        self._init_time(
            windows.mg_window_ops(self._k),
            mode,
            sites=sites,
            window=window,
            buckets=buckets,
            lateness=lateness,
            gamma=gamma,
            half_life=half_life,
        )

    def step(self, pairs, sites=None, *, ts: float | None = None) -> None:
        keys, weights = self.split_pairs(pairs)
        # ids < 2**24 are exact in f64, so one array keeps pending batches
        # checkpointable as a single leaf
        self._step_timed(np.stack([keys.astype(np.float64), weights], axis=1), ts)

    def estimates(self) -> dict[int, float]:
        return hhc.mg_items(self._serve())

    def total_weight(self) -> float:
        return float(self._serve().weight)


class WindowedQuantileProtocol(_TimeWrapped, QuantileProtocol):
    """Sliding-window / decayed GK quantiles (``P1win`` / ``P1decay``).

    Internal summaries run at ``eps/4`` so the certified band stays under
    ``eps/2 * W`` even after the per-bucket serve folds — the same budget
    split the shard coordinator honors.
    """

    def __init__(
        self,
        name: str,
        engine: str,
        mode: str,
        *,
        m: int,
        eps: float,
        cap: int | None = None,
        sites: int = 1,
        window: float | None = None,
        buckets: int = 8,
        lateness: float = 0.0,
        gamma: float | None = None,
        half_life: float | None = None,
    ):
        super().__init__(name, engine, m, eps)
        self._op_eps = eps / 4.0
        self._cap = int(cap) if cap else math.ceil(2.0 / self._op_eps) + 8
        self._init_time(
            windows.quant_window_ops(self._op_eps, self._cap),
            mode,
            sites=sites,
            window=window,
            buckets=buckets,
            lateness=lateness,
            gamma=gamma,
            half_life=half_life,
        )

    def step(self, pairs, sites=None, *, ts: float | None = None) -> None:
        values, weights = self.split_pairs(pairs)
        self._step_timed(np.stack([values.astype(np.float64), weights], axis=1), ts)

    def table(self) -> np.ndarray:
        return q.quant_table(self._serve())

    def total_weight(self) -> float:
        return float(self._serve().weight)

    @property
    def state(self):
        """Shard-style state view: ``coord_q`` is the serve-folded summary
        (its band certificate honors the coordinator eps/2 budget)."""
        return SimpleNamespace(coord_q=self._serve())


class WindowedLeverageProtocol(_TimeWrapped, LeverageProtocol):
    """Sliding-window / decayed ridge-leverage sample (``P1win``/``P1decay``).

    Served table = kept reservoir rows (exact, at their live weights) +
    the FD spill residual's rows at weight 1 — so reservoir overflow
    never loses mass and the subspace envelope inherits the FD bound.
    """

    def __init__(
        self,
        name: str,
        engine: str,
        mode: str,
        *,
        m: int,
        eps: float,
        d: int,
        cap: int | None = None,
        l_resid: int | None = None,
        sites: int = 1,
        window: float | None = None,
        buckets: int = 8,
        lateness: float = 0.0,
        gamma: float | None = None,
        half_life: float | None = None,
    ):
        super().__init__(name, engine, m, eps, d)
        self._cap = int(cap) if cap else lev.default_cap(eps)
        self._l_resid = int(l_resid) if l_resid else max(8, math.ceil(2.0 / eps))
        self._init_time(
            windows.lev_window_ops(self._cap, d, self._l_resid),
            mode,
            sites=sites,
            window=window,
            buckets=buckets,
            lateness=lateness,
            gamma=gamma,
            half_life=half_life,
        )

    def _invalidate(self) -> None:
        self._live_factor = None

    def step(self, rows, sites=None, *, ts: float | None = None) -> None:
        self._step_timed(self.check_rows(rows), ts)

    def sampled_rows(self) -> np.ndarray:
        st = self._serve()
        rows = np.asarray(st.lev.rows, np.float64)
        scores = np.asarray(st.lev.scores, np.float64)
        weights = np.asarray(st.lev.weights, np.float64)
        live = weights > 0.0
        parts = []
        if live.any():
            parts.append(
                np.concatenate(
                    [rows[live], scores[live][:, None], weights[live][:, None]],
                    axis=1,
                )
            )
        res = np.asarray(fd.fd_matrix(st.resid), np.float64)
        res = res[np.einsum("rd,rd->r", res, res) > 0]
        if res.shape[0]:
            factor = lev.ridge_factor(res, 1.0, self.lam())
            parts.append(
                np.concatenate(
                    [res, lev.ridge_scores(factor, res)[:, None],
                     np.ones((res.shape[0], 1))],
                    axis=1,
                )
            )
        if not parts:
            return np.zeros((0, self.d + 2), np.float32)
        return np.concatenate(parts, axis=0).astype(np.float32)

    def total_weight(self) -> float:
        return float(self._serve().mass)

    def lam(self) -> float:
        return lev.default_lambda(self.eps, max(self.total_weight(), 1e-12))


# ---------------------------------------------------------------------------
# Registration: (kind, engine, name) x {win, decay} for both engines.
# ---------------------------------------------------------------------------

_KIND_CLS = {
    "matrix": WindowedMatrixProtocol,
    "hh": WindowedHHProtocol,
    "quantile": WindowedQuantileProtocol,
    "leverage": WindowedLeverageProtocol,
}


def _windowed_factory(kind: str, name: str, engine: str, mode: str):
    cls = _KIND_CLS[kind]

    def make(**kw: Any):
        kw.pop("seed", None)  # host-side wrappers are deterministic
        if engine == "shard":
            mesh = kw.pop("mesh")
            axis = kw.pop("axis", "data")
            m = int(mesh.shape[axis])
            # shard flavor: rows partition round-robin over m software
            # sites, each with its own per-bucket state (merge at serve)
            kw.setdefault("sites", m)
        else:
            m = int(kw.pop("m"))
        return cls(name, engine, mode, m=m, **kw)

    return make


_WINDOWED_ERR = {
    # (kind, mode) -> err_factor: window folds keep the deterministic
    # bounds; decay adds the (1 - gamma^age) drift vs an unweighted oracle
    ("matrix", "win"): 1.0,
    ("matrix", "decay"): 1.5,
    ("hh", "win"): 1.0,
    ("hh", "decay"): 2.0,
    ("quantile", "win"): 2.0,
    ("quantile", "decay"): 2.0,
    ("leverage", "win"): 1.5,
    ("leverage", "decay"): 2.0,
}

_MODE_DESC = {
    "win": "bucketed sliding-window",
    "decay": "exponential-decay",
}

for _kind, _base in (("matrix", "P2"), ("hh", "P1"), ("quantile", "P1"), ("leverage", "P1")):
    for _mode, _suffix in (("win", "win"), ("decay", "decay")):
        for _engine in ("event", "shard"):
            register_protocol(ProtocolSpec(
                name=f"{_base}{_suffix}",
                kind=_kind,
                engine=_engine,
                factory=_windowed_factory(_kind, f"{_base}{_suffix}", _engine, _mode),
                err_factor=_WINDOWED_ERR[(_kind, _mode)],
                description=(
                    f"{_MODE_DESC[_mode]} {_kind} tracking over {_base} "
                    f"merge identities (core/windows.py)"
                ),
            ))
