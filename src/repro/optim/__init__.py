from repro.optim.adamw import AdamWState, adamw_init, adamw_state_shardings, adamw_update
from repro.optim.grad_compress import FDCompressConfig, compress_and_aggregate, init_residuals
from repro.optim.schedule import warmup_cosine
