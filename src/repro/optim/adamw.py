"""AdamW with ZeRO-1-ready state sharding (functional, pytree-based)."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


class AdamWState(NamedTuple):
    mu: dict
    nu: dict
    count: jax.Array


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
        count=jnp.zeros((), jnp.int32),
    )


def adamw_update(
    grads,
    state: AdamWState,
    params,
    *,
    lr: jax.Array | float,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    grad_clip: float = 1.0,
):
    """Returns (new_params, new_state).  Global-norm clip, decoupled WD."""
    gf = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    if grad_clip > 0:
        gnorm = jnp.sqrt(
            sum(jnp.sum(g * g) for g in jax.tree.leaves(gf)) + 1e-12
        )
        scale = jnp.minimum(1.0, grad_clip / gnorm)
        gf = jax.tree.map(lambda g: g * scale, gf)
    count = state.count + 1
    c1 = 1.0 - b1**count.astype(jnp.float32)
    c2 = 1.0 - b2**count.astype(jnp.float32)

    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, gf)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state.nu, gf)

    def step(p, m, v):
        update = (m / c1) / (jnp.sqrt(v / c2) + eps)
        if weight_decay > 0 and p.ndim >= 2:  # no decay on norms/biases
            update = update + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * update).astype(p.dtype)

    new_params = jax.tree.map(step, params, mu, nu)
    return new_params, AdamWState(mu=mu, nu=nu, count=count)


def adamw_state_shardings(params_template, param_shardings, mesh: Mesh) -> AdamWState:
    """ZeRO-1: shard m/v additionally over the DP 'data' axis on the first
    dimension that is unsharded and divisible — cuts optimizer memory by the
    DP degree without changing any math (the update is elementwise)."""
    data = mesh.shape.get("data", 1)

    def zero1(sh: NamedSharding, leaf):
        spec = list(sh.spec)
        spec += [None] * (leaf.ndim - len(spec))
        if data > 1:
            for i, (ax, dim) in enumerate(zip(spec, leaf.shape)):
                if ax is None and dim % data == 0 and dim >= data:
                    spec[i] = "data"
                    break
        return NamedSharding(mesh, P(*spec))

    mv = jax.tree.map(zero1, param_shardings, params_template)
    return AdamWState(mu=mv, nu=mv, count=NamedSharding(mesh, P()))
