"""FD-sketched data-parallel gradient aggregation with error feedback.

The paper's distributed matrix protocol, applied as a *gradient compression*
distributed-optimization trick.  In data-parallel training each shard j holds
a local gradient G_j for every 2D parameter (d_in rows of dimension d_out):
exactly the paper's "distributed matrix whose rows arrive at m sites".
Instead of all-reducing d_in x d_out floats:

  1. each shard FD-sketches  (G_j + residual_j)  ->  B_j (l, d_out)
  2. all_gather + FD-merge the sketches          ->  B   (l, d_out)
     (this is the paper's P1 merge; comm = m * l * d_out, replicated result)
  3. the top-k rows of B, normalized, form a shared basis V (k, d_out) —
     the FD guarantee says V captures every direction with squared mass
     >= ||G||_F^2 / l of the *global* gradient;
  4. project-and-reduce: P = pmean_j(G_j' @ V.T)  (comm = d_in * k)
  5. decompress ghat = P @ V; error feedback residual_j = G_j' - (G_j'V^T)V.

Compression ratio per layer: (d_in*d_out) / (m*l*d_out/m + d_in*k)
~ d_out / k for the usual d_in >> m*l regime.  Error feedback makes the
scheme convergent despite the lossy step (PowerSGD-style); the FD guarantee
bounds the per-step bias by ||G||_F^2 / l along every direction.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import fd as fdlib


class FDCompressConfig(NamedTuple):
    rank: int = 8  # k: basis size communicated densely
    sketch_rows: int = 16  # l: FD sketch parameter (l >= k)
    axis: str = "data"  # DP axis name inside shard_map
    min_size: int = 4096  # small tensors use plain psum


def _is_matrix(leaf) -> bool:
    return leaf.ndim >= 2


def init_residuals(params) -> dict:
    """Error-feedback buffers: zeros for matrices, () placeholder otherwise."""
    return jax.tree.map(
        lambda p: jnp.zeros(p.shape if _is_matrix(p) else (), jnp.float32), params
    )


class CompressionStats(NamedTuple):
    full_bytes: jax.Array  # what a dense all-reduce would have moved
    compressed_bytes: jax.Array  # what this scheme moved


def compress_and_aggregate(
    grads,
    residuals,
    cfg: FDCompressConfig,
):
    """Inside shard_map over cfg.axis: per-shard grads -> (global grads,
    new residuals, stats).  Non-matrix (or small) leaves take plain pmean."""
    l, k = cfg.sketch_rows, cfg.rank
    full_bytes = jnp.zeros((), jnp.float32)
    comp_bytes = jnp.zeros((), jnp.float32)
    m = lax.psum(1, cfg.axis)

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = treedef.flatten_up_to(residuals)
    out_g, out_r = [], []
    for g, r in zip(flat_g, flat_r):
        size = g.size
        if (not _is_matrix(g)) or size < cfg.min_size or g.shape[-1] < 2 * k:
            out_g.append(lax.pmean(g, cfg.axis))
            out_r.append(r)
            full_bytes += 4.0 * size
            comp_bytes += 4.0 * size
            continue
        d_out = g.shape[-1]
        rows = size // d_out
        acc = g.reshape(rows, d_out).astype(jnp.float32) + r.reshape(rows, d_out)

        # 1. local sketch
        st = fdlib.fd_init(l, d_out)
        st = fdlib.fd_update_stream(st, acc)
        b_local = fdlib.fd_matrix(st)  # (l, d_out)
        # 2. gather + merge (paper P1 merge: stack and re-sketch)
        gathered = lax.all_gather(b_local, cfg.axis).reshape(m * l, d_out)
        st_m = fdlib.fd_init(l, d_out)
        st_m = fdlib.fd_update_stream(st_m, gathered)
        b = fdlib.fd_matrix(st_m)
        # 3. orthonormal basis from top-k sketch rows (rows are sigma_i v_i)
        norms = jnp.sqrt(jnp.sum(b * b, axis=1, keepdims=True))
        v = (b / jnp.maximum(norms, 1e-12))[:k]  # (k, d_out)
        # 4. project and reduce
        p_local = acc @ v.T  # (rows, k)
        p = lax.pmean(p_local, cfg.axis)
        # 5. decompress + error feedback
        ghat = p @ v
        new_r = acc - p_local @ v
        out_g.append(ghat.reshape(g.shape).astype(g.dtype))
        out_r.append(new_r.reshape(r.shape))
        full_bytes += 4.0 * size
        comp_bytes += 4.0 * (l * d_out + rows * k)

    stats = CompressionStats(full_bytes=full_bytes, compressed_bytes=comp_bytes)
    return treedef.unflatten(out_g), treedef.unflatten(out_r), stats
