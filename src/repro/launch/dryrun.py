import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks the device count on first
#   init).  512 placeholder host devices back the production meshes.

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from typing import Callable  # noqa: E402

from repro.configs.registry import (  # noqa: E402
    ARCH_NAMES,
    SHAPE_NAMES,
    SHAPES,
    cell_supported,
    get_config,
)
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.specs import lower_cell  # noqa: E402
from repro.roofline.analysis import (  # noqa: E402
    RooflineReport,
    model_flops,
    parse_collective_bytes,
)

"""Multi-pod dry-run (deliverable e).

For every (architecture x input shape x mesh) cell:
  jit(step).lower(**input_specs).compile()
must succeed; we print/persist memory_analysis() (proves it fits) and a
*loop-corrected* cost analysis (XLA's cost_analysis counts a scan body once,
not x trip-count — verified empirically; see EXPERIMENTS.md §Dry-run).

Loop correction: each cell is lowered twice more with scans unrolled at
1 and 2 pattern-cycles; per-cycle cost = cost(2 cycles) - cost(1 cycle),
total = cost(1 cycle + remainder) + (cycles - 1) * per-cycle.  All three
lowers use the same mesh/shardings, so per-device numbers stay faithful.

    PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-135m --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --out experiments/dryrun
"""


def _mem_dict(compiled) -> dict:
    ma = compiled.memory_analysis()
    out = {}
    for attr in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "alias_size_in_bytes",
        "generated_code_size_in_bytes",
    ):
        v = getattr(ma, attr, None)
        if v is not None:
            out[attr] = int(v)
    out["total_bytes_per_device"] = (
        out.get("argument_size_in_bytes", 0)
        + out.get("output_size_in_bytes", 0)
        + out.get("temp_size_in_bytes", 0)
        - out.get("alias_size_in_bytes", 0)
    )
    return out


def _cost_dict(compiled) -> dict:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    coll = parse_collective_bytes(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "collective": float(coll.total),
        "by_kind": dict(coll.by_kind),
    }


def _combine(base: dict, percycle: dict, extra_cycles: int) -> dict:
    """Linear extrapolation; per-cycle costs are clamped at >= 0 (XLA may
    pick different collective strategies at different unroll depths — a
    negative per-cycle delta is noise, not physics)."""
    out = {
        "flops": base["flops"] + extra_cycles * max(percycle["flops"], 0.0),
        "bytes": base["bytes"] + extra_cycles * max(percycle["bytes"], 0.0),
        "by_kind": {},
    }
    kinds = set(base["by_kind"]) | set(percycle["by_kind"])
    for k in kinds:
        out["by_kind"][k] = max(
            0.0,
            base["by_kind"].get(k, 0.0)
            + extra_cycles * max(percycle["by_kind"].get(k, 0.0), 0.0),
        )
    out["collective"] = sum(out["by_kind"].values())
    return out


def structural_cost(arch: str, cfg, shape_cfg, mesh, seq_parallel: bool, layout: str = "tp") -> dict:
    """Loop-corrected per-device cost via 1-cycle/2-cycle unrolled lowers."""
    p = len(cfg.layer_pattern)
    cycles, rem = divmod(cfg.n_layers, p)
    if cycles <= 2:  # small enough: unroll everything exactly
        full = dataclasses.replace(cfg, scan_unroll=True)
        c = lower_cell(arch, shape_cfg, mesh, cfg_override=full, seq_parallel=seq_parallel, layout=layout).compile()
        return _cost_dict(c)
    one = dataclasses.replace(cfg, n_layers=p + rem, scan_unroll=True)
    two = dataclasses.replace(cfg, n_layers=2 * p + rem, scan_unroll=True)
    c1 = _cost_dict(
        lower_cell(arch, shape_cfg, mesh, cfg_override=one, seq_parallel=seq_parallel, layout=layout).compile()
    )
    c2 = _cost_dict(
        lower_cell(arch, shape_cfg, mesh, cfg_override=two, seq_parallel=seq_parallel, layout=layout).compile()
    )
    percycle = {
        "flops": c2["flops"] - c1["flops"],
        "bytes": c2["bytes"] - c1["bytes"],
        "collective": c2["collective"] - c1["collective"],
        "by_kind": {
            k: c2["by_kind"].get(k, 0.0) - c1["by_kind"].get(k, 0.0)
            for k in set(c1["by_kind"]) | set(c2["by_kind"])
        },
    }
    return _combine(c1, percycle, cycles - 1)


def run_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool,
    outdir: str | None,
    *,
    seq_parallel: bool = True,
    layout: str = "tp",
    tag: str = "",
    clock: Callable[[], float] = time.time,
) -> dict:
    shape_cfg = SHAPES[shape_name]
    cfg = get_config(arch)
    ok, reason = cell_supported(cfg, shape_cfg)
    mesh_desc = "2x16x16" if multi_pod else "16x16"
    cell = f"{arch} x {shape_name} x {mesh_desc}"
    if not ok:
        print(f"[SKIP] {cell}: {reason}")
        return {"arch": arch, "shape": shape_name, "mesh": mesh_desc, "status": "skip", "reason": reason}

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    t0 = clock()
    with mesh:
        # 1. the real (scanned) module: proves lowering+compile+fit
        lowered = lower_cell(arch, shape_cfg, mesh, seq_parallel=seq_parallel, layout=layout)
        t_lower = clock() - t0
        compiled = lowered.compile()
        t_compile = clock() - t0 - t_lower
        mem = _mem_dict(compiled)
        # 2. loop-corrected per-device cost accounting
        cost = structural_cost(arch, cfg, shape_cfg, mesh, seq_parallel, layout)

    report = RooflineReport(
        arch=arch,
        shape=shape_name,
        mesh=mesh_desc,
        chips=chips,
        flops_per_device=cost["flops"],
        bytes_per_device=cost["bytes"],
        collective_bytes_per_device=cost["collective"],
        collective_by_kind=cost["by_kind"],
        model_flops_global=model_flops(cfg, shape_cfg),
    )
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_desc,
        "chips": chips,
        "status": "ok",
        "seq_parallel": seq_parallel,
        "tag": tag,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": mem,
        "flops_per_device": report.flops_per_device,
        "bytes_per_device": report.bytes_per_device,
        "collective_bytes_per_device": report.collective_bytes_per_device,
        "collective_by_kind": report.collective_by_kind,
        "roofline": report.row(),
    }
    print(
        f"[OK] {cell}{('['+tag+']') if tag else ''}: compile {t_compile:.1f}s | "
        f"mem/device {mem['total_bytes_per_device']/2**30:.2f} GiB | "
        f"flops/device {report.flops_per_device:.3e} | "
        f"coll bytes/device {report.collective_bytes_per_device:.3e} | "
        f"dominant={report.dominant} "
        f"(c={report.compute_s*1e3:.2f}ms m={report.memory_s*1e3:.2f}ms "
        f"n={report.collective_s*1e3:.2f}ms) mfu@roofline={report.mfu:.2%}"
    )
    if outdir:
        os.makedirs(outdir, exist_ok=True)
        suffix = f"__{tag}" if tag else ""
        fn = os.path.join(outdir, f"{arch}__{shape_name}__{mesh_desc}{suffix}.json")
        with open(fn, "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCH_NAMES)
    ap.add_argument("--shape", choices=SHAPE_NAMES)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod", action="store_true")
    ap.add_argument("--all", action="store_true", help="every live cell x both meshes")
    ap.add_argument("--no-seq-parallel", action="store_true", help="baseline layout")
    ap.add_argument("--layout", choices=["tp", "dp", "dp_compressed"], default="tp")
    ap.add_argument("--tag", default="", help="variant tag for output filenames")
    ap.add_argument("--out", default=None, help="directory for per-cell JSON records")
    args = ap.parse_args()

    cells: list[tuple[str, str, bool]] = []
    if args.all:
        for arch in ARCH_NAMES:
            for shape in SHAPE_NAMES:
                cells.append((arch, shape, False))
                cells.append((arch, shape, True))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        if args.multi_pod and not args.single_pod:
            meshes = [True]
        elif args.single_pod and not args.multi_pod:
            meshes = [False]
        else:
            meshes = [False, True]
        for mp in meshes:
            cells.append((args.arch, args.shape, mp))

    failures = 0
    for arch, shape, mp in cells:
        try:
            run_cell(
                arch,
                shape,
                mp,
                args.out,
                seq_parallel=not args.no_seq_parallel,
                layout=args.layout,
                tag=args.tag,
            )
        except Exception:
            failures += 1
            print(f"[FAIL] {arch} x {shape} x {'2x16x16' if mp else '16x16'}")
            traceback.print_exc()
    if failures:
        raise SystemExit(f"{failures} cell(s) failed")


if __name__ == "__main__":
    main()
