"""Batched serving driver.

    PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b --reduced \
        --batch 4 --prompt-len 24 --gen 16

Loads (or random-inits) weights, prefills a batch of prompts, decodes with
the KV cache (ring buffers for SWA layers), reports tok/s and greedy
consistency against the teacher-forced forward.
"""
from __future__ import annotations

import argparse
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import latest_step, restore
from repro.configs.registry import ARCH_NAMES, get_config, reduced_config
from repro.models.transformer import LM
from repro.serve import ServeConfig, ServeEngine


def main(clock: Callable[[], float] = time.time) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, default="smollm-135m")
    ap.add_argument("--reduced", action="store_true", help="CPU-sized config")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--ckpt-dir", default=None, help="restore weights from a training run")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    lm = LM(cfg)
    params = lm.init(jax.random.key(args.seed))
    if args.ckpt_dir and (last := latest_step(args.ckpt_dir)) is not None:
        from repro.train.step import TrainConfig, init_train_state

        tpl = init_train_state(lm, jax.random.key(args.seed), TrainConfig())
        state, _ = restore(args.ckpt_dir, last, tpl)
        params = state.params
        print(f"[serve] restored weights from step {last}")

    engine = ServeEngine(
        lm, params, ServeConfig(max_len=args.max_len, temperature=args.temperature, seed=args.seed)
    )
    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, size=(args.batch, args.prompt_len)), jnp.int32
    )
    t0 = clock()
    out = engine.generate(prompts, args.gen)
    dt = clock() - t0
    print(
        f"[serve] {cfg.name}: {args.batch}x{args.gen} tokens in {dt:.2f}s "
        f"({args.batch * args.gen / dt:.1f} tok/s batched)"
    )
    if args.temperature == 0.0:
        logits, _ = lm.forward(params, out[:, :-1])
        greedy = np.asarray(jnp.argmax(logits[:, args.prompt_len - 1 :], -1))
        match = float((greedy == np.asarray(out[:, args.prompt_len :])).mean())
        print(f"[serve] greedy consistency vs teacher-forced forward: {match:.1%}")
    for row in np.asarray(out[:, args.prompt_len :])[:4]:
        print("  ", row.tolist())


if __name__ == "__main__":
    main()
