"""Dry-run plumbing: ShapeDtypeStruct input specs + lowered step builders.

``input_specs(arch, shape)`` returns weak-type-correct, shardable stand-ins
for every model input — no device allocation ever happens; the dry-run
lowers + compiles against these (deliverable e).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.registry import ShapeConfig, get_config
from repro.models.config import ModelConfig
from repro.models.sharding import (
    activation_sharding,
    batch_sharding,
    cache_shardings,
    param_shardings,
)
from repro.models.transformer import LM
from repro.train.step import TrainConfig, TrainState, init_train_state, train_state_shardings


def _sds(shape, dtype, sharding=None):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def input_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh, layout: str = "tp") -> dict:
    """ShapeDtypeStructs for the data inputs of this (arch x shape) cell."""
    from repro.models.sharding import full_batch_sharding

    gb, s = shape.global_batch, shape.seq_len
    tok_sh = full_batch_sharding(mesh, gb) if layout == "dp" else batch_sharding(mesh, gb)
    if shape.kind == "train":
        specs = {"tokens": _sds((gb, s), jnp.int32, tok_sh)}
        if cfg.frontend == "patch":
            n = cfg.n_frontend_tokens
            specs["tokens"] = _sds((gb, s - n), jnp.int32, tok_sh)
            specs["vision_embeds"] = _sds(
                (gb, n, cfg.d_model), jnp.bfloat16, batch_sharding(mesh, gb, extra_dims=2)
            )
        return specs
    if shape.kind == "prefill":
        specs = {"tokens": _sds((gb, s), jnp.int32, tok_sh)}
        if cfg.frontend == "patch":
            n = cfg.n_frontend_tokens
            specs["tokens"] = _sds((gb, s - n), jnp.int32, tok_sh)
            specs["vision_embeds"] = _sds(
                (gb, n, cfg.d_model), jnp.bfloat16, batch_sharding(mesh, gb, extra_dims=2)
            )
        return specs
    if shape.kind == "decode":
        return {
            "tokens": _sds((gb, 1), jnp.int32, tok_sh),
            "index": _sds((), jnp.int32, NamedSharding(mesh, P())),
        }
    raise ValueError(shape.kind)


@dataclass
class LoweredCell:
    arch: str
    shape: str
    mesh_desc: str
    lowered: object
    abstract_state: object  # whatever the step consumes (for reporting)


def _train_templates(lm: LM, mesh: Mesh, layout: str = "tp"):
    tcfg = TrainConfig()
    state_tpl = jax.eval_shape(lambda: init_train_state(lm, jax.random.key(0), tcfg))
    if layout == "dp":
        from repro.models.sharding import dp_param_shardings
        from repro.optim.adamw import AdamWState
        from jax.sharding import NamedSharding

        p_sh = dp_param_shardings(state_tpl.params, mesh)
        st_sh = TrainState(
            params=p_sh,
            opt=AdamWState(mu=p_sh, nu=p_sh, count=NamedSharding(mesh, P())),
            residuals=None,
        )
    else:
        st_sh = train_state_shardings(state_tpl, mesh)
    state_tpl = jax.tree.map(
        lambda t, s: _sds(t.shape, t.dtype, s), state_tpl, st_sh,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )
    return tcfg, state_tpl, st_sh


def _lower_compressed(
    lm: LM, shape_cfg: ShapeConfig, mesh: Mesh, specs: dict, compress_axis: str | None = None
):
    """dp layout + the paper's FD gradient compression replacing the dense
    DP all-reduce (hillclimb variant; params/opt replicated, ZeRO omitted
    for clarity of the comm comparison).  ``compress_axis='pod'`` compresses
    only the inter-pod link (dense intra-pod reduce)."""
    from jax.sharding import NamedSharding

    from repro.optim.grad_compress import FDCompressConfig
    from repro.train.step import make_compressed_train_step

    tcfg = TrainConfig(grad_compression=FDCompressConfig(rank=8, sketch_rows=16))
    state_tpl = jax.eval_shape(lambda: init_train_state(lm, jax.random.key(0), tcfg))
    rep = jax.tree.map(
        lambda t: _sds(t.shape, t.dtype, NamedSharding(mesh, P())),
        state_tpl,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )
    axes = tuple(mesh.axis_names)
    dp_total = mesh.devices.size
    if shape_cfg.global_batch % dp_total:
        # batch can't cover every chip (e.g. 256 seqs on 512 chips): restrict
        # the shard_map DP grid to the axes the batch divides; the remaining
        # axis replicates (the metric of interest here is link traffic).
        axes = tuple(a for a in axes if a in ("pod", "data"))
    step = make_compressed_train_step(lm, tcfg, mesh, axes=axes, compress_axis=compress_axis)
    return step.lower(rep, specs)


def lower_cell(
    arch: str,
    shape_cfg: ShapeConfig,
    mesh: Mesh,
    *,
    donate: bool = True,
    seq_parallel: bool = True,
    layout: str = "tp",
    cfg_override: ModelConfig | None = None,
):
    """Lower (not compile) the step for one (arch x shape x mesh) cell.

    layout="dp": FSDP-style layout for small models — params/optimizer
    sharded over 'data', batch spread over EVERY mesh axis, no TP/SP.
    """
    cfg = cfg_override or get_config(arch)
    lm = LM(cfg)
    specs = input_specs(cfg, shape_cfg, mesh, layout=layout)
    act_ctx = activation_sharding(
        mesh,
        seq_axis="model" if (seq_parallel and layout == "tp") else None,
        dp_over_all=layout == "dp",
    )

    if shape_cfg.kind == "train":
        from repro.train.step import make_train_step

        if layout == "dp_compressed":
            return _lower_compressed(lm, shape_cfg, mesh, specs)
        if layout == "dp_compressed_pod":
            return _lower_compressed(lm, shape_cfg, mesh, specs, compress_axis="pod")
        tcfg, state_tpl, st_sh = _train_templates(lm, mesh, layout)
        step = make_train_step(lm, tcfg)
        jitted = jax.jit(
            step,
            in_shardings=(st_sh, {k: v.sharding for k, v in specs.items()}),
            out_shardings=(st_sh, None),
            donate_argnums=(0,) if donate else (),
        )
        with act_ctx:
            return jitted.lower(state_tpl, specs)

    if shape_cfg.kind == "prefill":
        def prefill_step(params, batch):
            return lm.prefill(
                params,
                batch["tokens"],
                shape_cfg.seq_len,
                vision_embeds=batch.get("vision_embeds"),
            )

        params_tpl = jax.eval_shape(lambda: lm.init(jax.random.key(0)))
        p_sh = param_shardings(params_tpl, mesh)
        params_tpl = jax.tree.map(lambda t, s: _sds(t.shape, t.dtype, s), params_tpl, p_sh)
        jitted = jax.jit(prefill_step, in_shardings=(p_sh, {k: v.sharding for k, v in specs.items()}))
        with act_ctx:
            return jitted.lower(params_tpl, specs)

    if shape_cfg.kind == "decode":
        def serve_step(params, cache, tokens, index):
            return lm.decode_step(params, cache, tokens, index)

        params_tpl = jax.eval_shape(lambda: lm.init(jax.random.key(0)))
        p_sh = param_shardings(params_tpl, mesh)
        params_tpl = jax.tree.map(lambda t, s: _sds(t.shape, t.dtype, s), params_tpl, p_sh)
        cache_tpl = jax.eval_shape(
            lambda: lm.init_cache(shape_cfg.global_batch, shape_cfg.seq_len)
        )
        c_sh = cache_shardings(
            cache_tpl, mesh, shape_cfg.global_batch, shard_seq=shape_cfg.name == "long_500k"
        )
        cache_tpl = jax.tree.map(lambda t, s: _sds(t.shape, t.dtype, s), cache_tpl, c_sh)
        jitted = jax.jit(
            serve_step,
            in_shardings=(p_sh, c_sh, specs["tokens"].sharding, specs["index"].sharding),
            out_shardings=(None, c_sh),
            donate_argnums=(1,) if donate else (),
        )
        with act_ctx:
            return jitted.lower(params_tpl, cache_tpl, specs["tokens"], specs["index"])

    raise ValueError(shape_cfg.kind)
