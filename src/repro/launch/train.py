"""Production training driver: restartable, checkpointed, compressible.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
        --steps 200 --global-batch 8 --seq-len 256 --ckpt-dir /tmp/run1

Fault tolerance in this driver:
  * deterministic data: batch(step) is a pure function — restart-safe;
  * AsyncCheckpointer every --ckpt-every steps + atomic dirs + hash checks;
  * --restore resumes from the latest checkpoint (elastic: the target mesh
    may differ from the writer's);
  * per-step retry-once on transient failure, then checkpoint-and-abort
    (the fleet controller's restart takes over).
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp

from repro.ckpt import AsyncCheckpointer, latest_step, restore
from repro.configs.registry import ARCH_NAMES, get_config, reduced_config
from repro.data import Prefetcher, TokenStream
from repro.models.transformer import LM
from repro.optim import FDCompressConfig
from repro.train.step import (
    TrainConfig,
    init_train_state,
    make_compressed_train_step,
    make_train_step,
)


def main(clock: Callable[[], float] = time.time) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, default="smollm-135m")
    ap.add_argument("--reduced", action="store_true", help="CPU-sized config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--restore", action="store_true")
    ap.add_argument("--compress-grads", action="store_true", help="FD gradient compression (pure-DP)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    cfg = dataclasses.replace(cfg, remat="none") if args.reduced else cfg
    lm = LM(cfg)
    tcfg = TrainConfig(
        peak_lr=args.lr,
        warmup_steps=max(args.steps // 20, 2),
        total_steps=args.steps,
        grad_compression=FDCompressConfig() if args.compress_grads else None,
    )

    state = init_train_state(lm, jax.random.key(args.seed), tcfg)
    start = 0
    ckpt = AsyncCheckpointer(args.ckpt_dir) if args.ckpt_dir else None
    if args.restore and args.ckpt_dir and (last := latest_step(args.ckpt_dir)) is not None:
        state, extra = restore(args.ckpt_dir, last, state)
        start = last
        print(f"[train] restored step {last}")

    if args.compress_grads:
        n = len(jax.devices())
        mesh = jax.make_mesh((n,), ("data",))
        step = make_compressed_train_step(lm, tcfg, mesh)
    else:
        step = jax.jit(make_train_step(lm, tcfg))

    ds = TokenStream(
        global_batch=args.global_batch, seq_len=args.seq_len, vocab=cfg.vocab_size, seed=args.seed
    )
    pf = Prefetcher(ds, start_step=start)
    try:
        t_last = clock()
        for i in range(start, args.steps):
            got_step, batch = pf.next()
            assert got_step == i
            jbatch = {"tokens": jnp.asarray(batch["tokens"])}
            for attempt in (0, 1):  # retry-once on transient failure
                try:
                    state, metrics = step(state, jbatch)
                    break
                except Exception:
                    if attempt == 1:
                        if ckpt:
                            ckpt.save(i, state)
                            ckpt.wait()
                        raise
            if (i + 1) % 10 == 0 or i == start:
                dt = clock() - t_last
                t_last = clock()
                extra = ""
                if "comm_compressed_bytes" in metrics:
                    ratio = float(metrics["comm_full_bytes"]) / max(
                        float(metrics["comm_compressed_bytes"]), 1.0
                    )
                    extra = f" comm_saving={ratio:.1f}x"
                print(
                    f"[train] step {i+1}/{args.steps} loss={float(metrics['loss']):.4f}"
                    f" ({dt:.2f}s/10steps){extra}",
                    flush=True,
                )
            if ckpt and (i + 1) % args.ckpt_every == 0:
                ckpt.save(i + 1, state)
        if ckpt:
            ckpt.save(args.steps, state)
            ckpt.wait()
    finally:
        pf.close()
    print("[train] done")


if __name__ == "__main__":
    main()
