"""Production meshes.

Single pod: (16, 16) = 256 chips, axes (data, model).
Multi-pod:  (2, 16, 16) = 512 chips, axes (pod, data, model) — DP over
(pod, data), TP/EP over model.  PP extension point: stages over the pod axis
(not enabled for the assigned 2-pod mesh; see DESIGN.md §6).

Functions, not module constants, so importing never touches jax device state
(the dry-run must set XLA_FLAGS before any jax initialisation).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Whatever devices exist on this host (tests / examples)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return jax.make_mesh((n // model_parallel, model_parallel), ("data", "model"))
