from repro.data.pipeline import Prefetcher, TokenStream
from repro.data.synthetic import (
    lm_token_batch,
    lowrank_stream,
    msd_like,
    pamap_like,
    site_assignment,
    zipfian_stream,
)
