"""Synthetic data generators for streams, matrices, and token batches.

The paper's UCI datasets (PAMAP, YearPredictionMSD) cannot ship in this
offline container; ``pamap_like`` / ``msd_like`` generate matrices matched to
their published characteristics (size, dimensionality, effective rank) so the
offline SVD/FD baselines land near the paper's reported err values
(PAMAP: SVD_30 err ~ 2e-6 => effectively low-rank; MSD: SVD_50 err ~ 6e-3 =>
heavy-tailed full rank).
"""
from __future__ import annotations

import numpy as np

__all__ = [
    "zipfian_stream",
    "pamap_like",
    "msd_like",
    "lowrank_stream",
    "lm_token_batch",
    "site_assignment",
]


def zipfian_stream(n: int, *, skew: float = 2.0, universe: int = 10_000, beta: float = 1000.0, seed: int = 0):
    """Weighted element stream: zipf(skew) keys, Unif[1, beta] weights."""
    rng = np.random.default_rng(seed)
    keys = (rng.zipf(skew, size=n) % universe).astype(np.int64)
    weights = rng.uniform(1.0, beta, size=n)
    return keys, weights


def _scaled_rows(a: np.ndarray, rng, beta: float) -> np.ndarray:
    """Rescale rows to squared norms in [1, beta] (paper's weight model)."""
    norms = np.sqrt(np.maximum(np.einsum("nd,nd->n", a, a), 1e-12))
    target = np.sqrt(rng.uniform(1.0, beta, size=a.shape[0]))
    return a * (target / norms)[:, None]


def pamap_like(n: int = 100_000, d: int = 44, *, beta: float = 100.0, seed: int = 0) -> np.ndarray:
    """Low-rank-plus-noise matrix (PAMAP is ~rank-25 in 44 dims)."""
    rng = np.random.default_rng(seed)
    rank = 25
    spectrum = np.exp(-0.35 * np.arange(rank))  # fast decay -> low effective rank
    u = rng.normal(size=(n, rank)) * spectrum[None, :]
    v = rng.normal(size=(rank, d)) / np.sqrt(d)
    a = u @ v + 1e-4 * rng.normal(size=(n, d))
    return _scaled_rows(a, rng, beta)


def msd_like(n: int = 100_000, d: int = 90, *, beta: float = 100.0, seed: int = 0) -> np.ndarray:
    """High-rank heavy-tailed matrix (MSD keeps err even at rank 50)."""
    rng = np.random.default_rng(seed)
    spectrum = (1.0 + np.arange(d)) ** -0.35  # slow decay -> high rank
    u = rng.normal(size=(n, d)) * spectrum[None, :]
    v, _ = np.linalg.qr(rng.normal(size=(d, d)))
    a = u @ v.T
    return _scaled_rows(a, rng, beta)


def lowrank_stream(
    n: int, d: int, *, rank: int = 5, noise: float = 0.05, seed: int = 0
) -> np.ndarray:
    """Small low-rank-plus-noise tenant stream with a steep spectrum.

    The runtime demos, benchmarks, and tests all want the same thing: a
    stream whose sketch is meaningful at tiny `l` (so eps-envelope checks
    bite) with per-tenant variation via ``seed``/``rank``.
    """
    rng = np.random.default_rng(seed)
    u = rng.normal(size=(n, rank)) * (np.arange(rank, 0, -1) ** 2)
    a = u @ rng.normal(size=(rank, d)) + noise * rng.normal(size=(n, d))
    return a.astype(np.float32)


def site_assignment(n: int, m: int, *, seed: int = 0) -> np.ndarray:
    """Uniform-random site for each stream element (the paper's model lets
    any site receive any element)."""
    return np.random.default_rng(seed + 7).integers(0, m, size=n)


def lm_token_batch(rng: np.random.Generator, batch: int, seq: int, vocab: int) -> np.ndarray:
    """Markov-ish synthetic token stream: learnable (not uniform) so tiny
    training runs show a falling loss."""
    # Low-entropy transition structure: next token ~ (prev * a + b) mod V
    # with occasional uniform resets.
    a = 31
    b_const = 17
    toks = np.empty((batch, seq), np.int32)
    toks[:, 0] = rng.integers(0, vocab, size=batch)
    resets = rng.uniform(size=(batch, seq)) < 0.1
    rand = rng.integers(0, vocab, size=(batch, seq))
    for t in range(1, seq):
        nxt = (toks[:, t - 1] * a + b_const) % vocab
        toks[:, t] = np.where(resets[:, t], rand[:, t], nxt)
    return toks
