"""Sharded, deterministic, prefetching host data pipeline.

Production loaders on a 1000-node fleet must be: (a) deterministic under
restart (step -> batch is a pure function of (seed, step)), (b) shardable
(each host materialises only its slice), (c) overlapped with compute.  This
pipeline provides all three without external deps:

  * ``TokenStream`` — stateless step->batch generator (seeded counter RNG);
    restart at step k reproduces exactly the batch a non-restarted run would
    have seen (checkpoint/restore correctness is tested on this invariant).
  * ``Prefetcher`` — background-thread double buffering.
  * per-host slicing via (host_index, host_count).
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass

import numpy as np

from repro.data.synthetic import lm_token_batch

__all__ = ["TokenStream", "Prefetcher"]


@dataclass(frozen=True)
class TokenStream:
    """Deterministic synthetic LM token stream."""

    global_batch: int
    seq_len: int
    vocab: int
    seed: int = 0
    host_index: int = 0
    host_count: int = 1

    def __post_init__(self):
        if self.global_batch % self.host_count:
            raise ValueError(
                f"global_batch {self.global_batch} not divisible by host_count {self.host_count}"
            )

    @property
    def host_batch(self) -> int:
        return self.global_batch // self.host_count

    def batch_at(self, step: int) -> dict:
        """Pure function of (seed, step, host): restart-deterministic."""
        rng = np.random.default_rng((self.seed, step, self.host_index))
        tokens = lm_token_batch(rng, self.host_batch, self.seq_len, self.vocab)
        return {"tokens": tokens}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Background-thread prefetch of any step-indexed source."""

    def __init__(self, source, start_step: int = 0, depth: int = 2):
        self._source = source
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._step
        while not self._stop.is_set():
            batch = self._source.batch_at(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def next(self) -> tuple[int, dict]:
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)
