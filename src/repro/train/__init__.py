from repro.train.step import (
    TrainConfig,
    TrainState,
    init_train_state,
    make_compressed_train_step,
    make_jitted_train_step,
    make_train_step,
    train_state_shardings,
)
