"""Train-step factories: the pjit (DP x TP) path and the FD-compressed
pure-DP shard_map path.

The pjit path is what the multi-pod dry-run lowers; the compressed path is
the paper's protocol working as gradient compression (see
optim/grad_compress.py) — selectable via ``TrainConfig.grad_compression``.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models.sharding import batch_sharding, data_axes, param_shardings
from repro.optim.adamw import AdamWState, adamw_init, adamw_state_shardings, adamw_update
from repro.optim.grad_compress import (
    FDCompressConfig,
    compress_and_aggregate,
    init_residuals,
)
from repro.optim.schedule import warmup_cosine


class TrainConfig(NamedTuple):
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    b1: float = 0.9
    b2: float = 0.95
    grad_compression: FDCompressConfig | None = None


class TrainState(NamedTuple):
    params: dict
    opt: AdamWState
    residuals: dict | None = None  # error feedback (compressed path only)


def init_train_state(lm, key, tcfg: TrainConfig) -> TrainState:
    params = lm.init(key)
    opt = adamw_init(params)
    res = init_residuals(params) if tcfg.grad_compression else None
    return TrainState(params=params, opt=opt, residuals=res)


def train_state_shardings(state_template: TrainState, mesh: Mesh) -> TrainState:
    ps = param_shardings(state_template.params, mesh)
    os_ = adamw_state_shardings(state_template.params, ps, mesh)
    res = (
        jax.tree.map(lambda _: NamedSharding(mesh, P()), state_template.residuals)
        if state_template.residuals is not None
        else None
    )
    return TrainState(params=ps, opt=os_, residuals=res)


def _lr(tcfg: TrainConfig, count):
    # count is the pre-increment step; +1 so the very first update is not lr=0
    return warmup_cosine(
        count + 1, peak_lr=tcfg.peak_lr, warmup_steps=tcfg.warmup_steps, total_steps=tcfg.total_steps
    )


def make_train_step(lm, tcfg: TrainConfig):
    """The pjit path: global-batch loss, XLA-inserted DP psums, TP via the
    param shardings.  jit it with in/out shardings from train_state_shardings
    + batch_sharding."""

    def train_step(state: TrainState, batch: dict):
        loss, grads = jax.value_and_grad(lm.loss)(state.params, batch)
        new_params, new_opt = adamw_update(
            grads,
            state.opt,
            state.params,
            lr=_lr(tcfg, state.opt.count),
            b1=tcfg.b1,
            b2=tcfg.b2,
            weight_decay=tcfg.weight_decay,
            grad_clip=tcfg.grad_clip,
        )
        metrics = {"loss": loss, "step": new_opt.count}
        return TrainState(new_params, new_opt, state.residuals), metrics

    return train_step


def make_jitted_train_step(lm, tcfg: TrainConfig, mesh: Mesh, state_template: TrainState, batch_shape):
    """jit + shardings wired up; returns (step_fn, state_shardings)."""
    st_sh = train_state_shardings(state_template, mesh)
    b_sh = {"tokens": batch_sharding(mesh, batch_shape[0])}
    step = jax.jit(
        make_train_step(lm, tcfg),
        in_shardings=(st_sh, b_sh),
        out_shardings=(st_sh, None),
        donate_argnums=(0,),
    )
    return step, st_sh


def make_compressed_train_step(
    lm,
    tcfg: TrainConfig,
    mesh: Mesh,
    axes: tuple | None = None,
    compress_axis: str | None = None,
):
    """Pure-DP shard_map path with FD gradient compression + error feedback.

    Params/opt are replicated across DP (the compression replaces the dense
    gradient all-reduce); batch is sharded over the DP axes (pass
    ``axes=mesh.axis_names`` to use every axis as DP).

    ``compress_axis``: hierarchical mode — gradients are densely pmean'd over
    the *other* (fast-ICI) axes and FD-compressed only across
    ``compress_axis`` (the slow inter-pod/DCN link).  This is the paper's own
    topology: pods = sites, the cross-pod link = the coordinator channel.
    """
    from jax.experimental.shard_map import shard_map

    ccfg = tcfg.grad_compression or FDCompressConfig()
    dp = tuple(axes) if axes is not None else data_axes(mesh)
    axis = dp[-1] if len(dp) == 1 else dp  # compression runs over these axes
    intra: tuple = ()
    if compress_axis is not None:
        intra = tuple(a for a in dp if a != compress_axis)
        axis = compress_axis

    def inner(state: TrainState, batch: dict):
        loss, grads = jax.value_and_grad(lm.loss)(state.params, batch)
        loss = jax.lax.pmean(loss, dp if len(dp) > 1 else dp[-1])
        if intra:  # dense reduce on the fast link first
            grads = jax.lax.pmean(grads, intra if len(intra) > 1 else intra[-1])
        grads, new_res, stats = compress_and_aggregate(
            grads, state.residuals, ccfg._replace(axis=axis)
        )
        new_params, new_opt = adamw_update(
            grads,
            state.opt,
            state.params,
            lr=_lr(tcfg, state.opt.count),
            b1=tcfg.b1,
            b2=tcfg.b2,
            weight_decay=tcfg.weight_decay,
            grad_clip=tcfg.grad_clip,
        )
        metrics = {
            "loss": loss,
            "step": new_opt.count,
            "comm_full_bytes": stats.full_bytes,
            "comm_compressed_bytes": stats.compressed_bytes,
        }
        return TrainState(new_params, new_opt, new_res), metrics

    # Spec prefixes: state/metrics replicated, batch sharded over DP.
    step = jax.jit(
        shard_map(
            inner,
            mesh=mesh,
            in_specs=(P(), {"tokens": P(dp, None)}),
            out_specs=(P(), P()),
            check_rep=False,
        )
    )
    return step
