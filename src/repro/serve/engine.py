"""Batched serving: prefill + decode with greedy/temperature sampling.

``serve_step`` (one token for the whole batch against the KV cache) is the
function the decode_* dry-run cells lower.  The ``ServeEngine`` host loop
drives it for real generation (examples/serve_batched.py).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class ServeConfig(NamedTuple):
    max_len: int
    temperature: float = 0.0  # 0 => greedy
    seed: int = 0


def make_serve_step(lm):
    """(params, cache, tokens (B,1), index ()) -> (next_tokens, logits, cache)."""

    def serve_step(params, cache, tokens, index, rng, temperature):
        logits, cache = lm.decode_step(params, cache, tokens, index)
        greedy = jnp.argmax(logits[:, -1], axis=-1)
        gumbel = -jnp.log(-jnp.log(jax.random.uniform(rng, logits[:, -1].shape) + 1e-9) + 1e-9)
        sampled = jnp.argmax(logits[:, -1] / jnp.maximum(temperature, 1e-6) + gumbel, axis=-1)
        nxt = jnp.where(temperature > 0.0, sampled, greedy).astype(jnp.int32)
        return nxt[:, None], logits, cache

    return serve_step


class ServeEngine:
    def __init__(self, lm, params, cfg: ServeConfig):
        self.lm = lm
        self.params = params
        self.cfg = cfg
        self._step = jax.jit(make_serve_step(lm))
        self._rng = jax.random.key(cfg.seed)

    def generate(self, prompts: jax.Array, n_tokens: int):
        """prompts: (B, T0) -> (B, T0 + n_tokens) greedy/temperature tokens."""
        b, t0 = prompts.shape
        pf_logits, cache = self.lm.prefill(self.params, prompts, self.cfg.max_len)
        cur = jnp.argmax(pf_logits, axis=-1).astype(jnp.int32)[:, None]
        out = [prompts, cur]
        # cur (position t0) is already chosen; each decode step consumes it
        # and emits the next token.
        for i in range(n_tokens - 1):
            self._rng, sub = jax.random.split(self._rng)
            cur, _, cache = self._step(
                self.params,
                cache,
                cur,
                jnp.asarray(t0 + i, jnp.int32),
                sub,
                jnp.asarray(self.cfg.temperature, jnp.float32),
            )
            out.append(cur)
        return jnp.concatenate(out, axis=1)
