"""Query serving end-to-end: sketch -> store -> engine -> batched service.

The paper's coordinator answers ``||A x||^2`` for any direction from its
sketch B; this demo is that query path at serving shape.  An FD sketch of a
PAMAP-like stream is published into the versioned store, then a batch of
single-direction queries is coalesced by the service and served three ways
(naive per-query SVD, cached-eigh, Pallas-batched) with throughput and the
paper's error envelope reported.

    PYTHONPATH=src python examples/query_service.py [--queries 2048]
"""
import argparse

import jax.numpy as jnp
import numpy as np

from repro.core.fd import fd_init, fd_matrix, fd_update_stream
from repro.data import pamap_like
from repro.query import QueryEngine, QueryService, SketchStore

ap = argparse.ArgumentParser()
ap.add_argument("--n", type=int, default=40_000)
ap.add_argument("--queries", type=int, default=2048)
ap.add_argument("--eps", type=float, default=0.1)
args = ap.parse_args()

rng = np.random.default_rng(0)
a = pamap_like(args.n, seed=1).astype(np.float32)
n, d = a.shape
l = int(np.ceil(4.0 / args.eps))
frob = float(np.sum(a.astype(np.float64) ** 2))

state = fd_update_stream(fd_init(l, d), jnp.asarray(a))
store = SketchStore()
snap = store.publish(
    "pamap", np.asarray(fd_matrix(state)), frob=frob, eps=args.eps,
    delta_sum=float(state.delta_sum), n_seen=n,
)
print(f"published sketch v{snap.version}: {snap.matrix.shape} of a {n}x{d} stream "
      f"(compression {n / snap.matrix.shape[0]:.0f}x, bound {snap.error_bound / frob:.2e} ||A||_F^2)")

engine = QueryEngine(store)
x = rng.normal(size=(args.queries, d)).astype(np.float32)
x /= np.linalg.norm(x, axis=1, keepdims=True)
truth = np.sum((a.astype(np.float64) @ x.T.astype(np.float64)) ** 2, axis=0)

print(f"\n{'path':<16}{'qps':>12}{'max gap / ||A||_F^2':>22}")
for path, n_q in [("naive", 64), ("cached", args.queries), ("pallas", args.queries)]:
    svc = QueryService(engine, tenant="pamap", path=path, max_batch=1024)
    tickets = [svc.submit(row) for row in x[:n_q]]
    svc.flush()
    est = np.array([t.result()[0] for t in tickets])
    gap = np.max(np.abs(truth[:n_q] - est)) / frob
    print(f"{path:<16}{svc.stats().queries_per_sec:>12.0f}{gap:>22.2e}")

vt_k, s_k = engine.top_directions(3, tenant="pamap")
print(f"\ntop singular values (streaming PCA): {np.round(s_k, 1)}")
print(f"stable rank: {engine.stable_rank(tenant='pamap'):.2f}")
print(f"spectrum cache: {engine.cache_stats()}")
