"""End-to-end training driver with the paper's protocol as gradient
compression: each data-parallel shard FD-sketches its gradients; sketches are
merged (paper P1 merge) into a shared low-rank basis; only projections are
all-reduced.  Compares loss curves + communication vs dense all-reduce.

    PYTHONPATH=src python examples/train_fd_compressed.py [--steps 60]
(single CPU: the DP mesh is simulated with XLA_FLAGS device_count)
"""
import os

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import argparse

import jax
import jax.numpy as jnp

from repro.data import TokenStream
from repro.models.config import ModelConfig
from repro.models.transformer import LM
from repro.optim import FDCompressConfig
from repro.train import TrainConfig, init_train_state, make_compressed_train_step, make_train_step

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=60)
args = ap.parse_args()

cfg = ModelConfig(name="demo", family="dense", n_layers=4, d_model=128, n_heads=4,
                  n_kv_heads=2, d_ff=256, vocab_size=512, dtype="float32", remat="none")
lm = LM(cfg)
ds = TokenStream(global_batch=16, seq_len=128, vocab=512, seed=0)
mesh = jax.make_mesh((len(jax.devices()),), ("data",))

for mode in ["dense", "fd-compressed"]:
    tcfg = TrainConfig(peak_lr=5e-3, warmup_steps=5, total_steps=args.steps,
                       grad_compression=FDCompressConfig(rank=8, sketch_rows=16) if mode != "dense" else None)
    state = init_train_state(lm, jax.random.key(0), tcfg)
    step = (make_compressed_train_step(lm, tcfg, mesh) if mode != "dense"
            else jax.jit(make_train_step(lm, tcfg)))
    losses = []
    for i in range(args.steps):
        state, m = step(state, {"tokens": jnp.asarray(ds.batch_at(i)["tokens"])})
        losses.append(float(m["loss"]))
    msg = f"{mode:>14}: loss {losses[0]:.3f} -> {losses[-1]:.3f}"
    if mode != "dense":
        ratio = float(m["comm_full_bytes"]) / float(m["comm_compressed_bytes"])
        msg += f"   DP gradient comm saved: {ratio:.1f}x"
    print(msg)
