"""The paper's headline experiment, end to end: m distributed sites stream
rows of a matrix; the coordinator continuously tracks its covariance with
each protocol.  Prints a Table-1-style comparison (err vs messages).

    PYTHONPATH=src python examples/distributed_tracking.py [--n 100000] [--m 50]
"""
import argparse

import numpy as np

from repro.core import run_matrix_protocol
from repro.data import pamap_like, site_assignment

ap = argparse.ArgumentParser()
ap.add_argument("--n", type=int, default=80_000)
ap.add_argument("--m", type=int, default=50)
ap.add_argument("--eps", type=float, default=0.1)
args = ap.parse_args()

a = pamap_like(args.n, seed=1)
sites = site_assignment(args.n, args.m, seed=1)
ata = a.T @ a
frob = float(np.sum(a * a))

print(f"stream: {args.n} rows x {a.shape[1]} dims over {args.m} sites, eps={args.eps}")
print(f"{'protocol':<10}{'err':>12}{'messages':>12}{'vs naive':>10}")
for proto in ["P1", "P2", "P3", "P3wr", "P4"]:
    res = run_matrix_protocol(proto, a, sites, args.m, args.eps, seed=0)
    err = res.covariance_error(ata, frob)
    msgs = res.comm.total(args.m)
    note = "  <-- paper's best" if proto == "P2" else (
        "  <-- NEGATIVE result (App. C)" if proto == "P4" else "")
    print(f"{proto:<10}{err:>12.2e}{msgs:>12}{args.n/msgs:>9.0f}x{note}")
