"""Batched multi-tenant serving demo: the streaming runtime end to end.

Four tenant streams ingest through one ``StreamingPipeline``; publish
policies turn live sketches into immutable store versions; queries are
admitted with deadlines and served in cross-tenant *packed* quadform
launches.  The demo then verifies the three runtime guarantees:

  1. packed cross-tenant answers == per-tenant serial answers (1e-5),
  2. every answer respects the paper's eps ||A||_F^2 envelope,
  3. a pipeline saved via ``repro.ckpt`` and reloaded answers identically
     (coordinator restart recovery; see examples/mixed_tenants.py for the
     mid-stream ingest-resume variant with heavy-hitter tenants).

    PYTHONPATH=src python examples/serve_batched.py [--tenants 4]
"""
import argparse
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import lowrank_stream
from repro.runtime import EveryKSteps, FrobDrift, StreamingPipeline

ap = argparse.ArgumentParser()
ap.add_argument("--tenants", type=int, default=4)
ap.add_argument("--rows", type=int, default=4096)
ap.add_argument("--d", type=int, default=64)
ap.add_argument("--queries", type=int, default=64)
ap.add_argument("--eps", type=float, default=0.2)
args = ap.parse_args()

rng = np.random.default_rng(0)
mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))
pipe = StreamingPipeline(mesh, eps=args.eps, policy=EveryKSteps(2),
                         default_deadline_s=0.002)


streams = {
    f"tenant-{t}": lowrank_stream(args.rows, args.d, rank=3 + t % 4, seed=t)
    for t in range(args.tenants)
}
for i, tenant in enumerate(streams):
    # Mix policies: even tenants publish every 2 steps, odd on Frobenius drift.
    policy = EveryKSteps(2) if i % 2 == 0 else FrobDrift(rel=0.5)
    pipe.add_tenant(tenant, args.d, policy=policy)

print(f"ingesting {args.tenants} tenants x {args.rows} rows (d={args.d}, eps={args.eps})")
batch = args.rows // 8
for step in range(8):
    for tenant, a in streams.items():
        pipe.ingest(tenant, jnp.asarray(a[step * batch : (step + 1) * batch]))
for tenant in streams:
    s = pipe.stats(tenant)
    print(f"  {tenant}: {s.steps} steps, {s.publishes} publishes "
          f"(latest v{s.latest_version}), {s.comm_total} protocol msgs")
print(f"publish latency total: {pipe.publish_latency_s()*1e3:.1f} ms")

# -- deadline-flushed packed queries ----------------------------------------
# Pin a fresh snapshot per tenant: drift policies may lag the live stream
# by up to their `rel` factor, which would widen the eps envelope below.
for tenant in streams:
    pipe.publish(tenant)

xs = {t: rng.normal(size=(args.queries, args.d)).astype(np.float32) for t in streams}
for t in xs:
    xs[t] /= np.linalg.norm(xs[t], axis=1, keepdims=True)

tickets = {t: [pipe.submit(t, x, deadline_s=0.002) for x in xs[t]] for t in streams}
time.sleep(0.004)
served = pipe.poll()  # the deadline pump fires one packed cross-tenant sweep
stats = pipe.service.stats()
print(f"\nserved {served} queries in {stats.flushes} packed flush(es) "
      f"({stats.packed_tenants} tenant batches packed, "
      f"{stats.deadline_flushes} deadline-forced)")

# 1. packed == per-tenant serial
worst = 0.0
for tenant in streams:
    serial = pipe.engine.query_batch(xs[tenant], tenant=tenant, path="pallas").estimates
    got = np.array([tk.result()[0] for tk in tickets[tenant]], np.float32)
    np.testing.assert_allclose(got, serial, rtol=1e-5)
    worst = max(worst, float(np.max(np.abs(got - serial) / np.maximum(serial, 1e-6))))
print(f"packed vs per-tenant serial: max rel gap {worst:.2e}  (OK <= 1e-5)")

# 2. the paper's guarantee, per tenant
for tenant, a in streams.items():
    truth = np.sum((a.astype(np.float64) @ xs[tenant].T.astype(np.float64)) ** 2, axis=0)
    est = np.array([tk.result()[0] for tk in tickets[tenant]])
    frob = float(np.sum(a.astype(np.float64) ** 2))
    gap = np.max(np.abs(truth - est)) / frob
    assert gap <= args.eps + 1e-3, (tenant, gap)
    print(f"  {tenant}: max |truth - est| = {gap:.3e} ||A||_F^2  (eps={args.eps})")

# 3. restart recovery: the reloaded pipeline answers identically
with tempfile.TemporaryDirectory() as d:
    pipe.save(d)
    restored = StreamingPipeline.load(d, mesh)
    for tenant in streams:
        before = pipe.engine.query_batch(xs[tenant], tenant=tenant, path="pallas")
        after = restored.engine.query_batch(xs[tenant], tenant=tenant, path="pallas")
        np.testing.assert_array_equal(before.estimates, after.estimates)
        assert before.version == after.version
print("restored pipeline answers identically: OK")
