"""Batched serving demo: prefill a batch of prompts, decode with the KV
cache (ring-buffer caches for SWA layers), verify greedy consistency.

    PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config, reduced_config
from repro.models.transformer import LM
from repro.serve import ServeConfig, ServeEngine

cfg = reduced_config(get_config("mixtral-8x7b"))  # reduced MoE with SWA
lm = LM(cfg)
params = lm.init(jax.random.key(0))
engine = ServeEngine(lm, params, ServeConfig(max_len=128))

rng = np.random.default_rng(0)
prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(4, 24)), jnp.int32)
t0 = time.time()
out = engine.generate(prompts, 16)
dt = time.time() - t0
print(f"arch: {cfg.name} (reduced; {cfg.n_experts} experts top-{cfg.experts_per_token}, window={cfg.window})")
print(f"generated {out.shape[0]}x16 tokens in {dt:.2f}s  ({out.shape[0]*16/dt:.1f} tok/s batched)")
print("continuations:")
for row in np.asarray(out[:, 24:]):
    print("  ", row.tolist())

# consistency: teacher-forcing the generated tokens reproduces them greedily
logits, _ = lm.forward(params, out[:, :-1])
greedy = np.asarray(jnp.argmax(logits[:, 23:], -1))
match = (greedy == np.asarray(out[:, 24:])).mean()
print(f"greedy consistency vs full forward: {match:.1%}")
