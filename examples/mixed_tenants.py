"""Mixed-workload pipeline demo: matrix + heavy-hitter tenants, one runtime.

One ``StreamingPipeline`` hosts both workloads the paper covers — matrix
tracking (Section 5) and weighted heavy hitters (Section 4) — behind a
single ingest → publish → packed-serve loop, and demonstrates the
hardening this layer adds:

  1. mixed packed serving — matrix quadform batches and HH point-lookups
     resolve through the same admission path and sweep,
  2. per-tenant admission quotas — overload is shed with a typed error and
     counted, never silently dropped; priorities order capped sweeps,
  3. pipeline-level restart — ``save``/``load`` checkpoint live protocol
     state (not just published snapshots), so the restarted coordinator
     resumes ingest mid-stream and answers bit-identically.

    PYTHONPATH=src python examples/mixed_tenants.py
"""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.synthetic import lowrank_stream, zipfian_stream
from repro.query import QueryShedError
from repro.runtime import EveryKSteps, StreamingPipeline, TenantQuota

D, EPS_MAT, EPS_HH, PHI = 32, 0.2, 0.02, 0.05

mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))
pipe = StreamingPipeline(mesh, eps=EPS_MAT, policy=EveryKSteps(2))
pipe.add_tenant("activations", D, quota=TenantQuota(max_pending=8, priority=1))
pipe.add_hh_tenant("clicks", eps=EPS_HH, protocol="P1", engine="event", m=10,
                   quota=TenantQuota(max_pending=8, priority=5))
pipe.add_hh_tenant("clicks-shard", eps=EPS_HH, protocol="P1", engine="shard")

# -- ingest both workloads through one loop ---------------------------------
rows = lowrank_stream(2048, D, rank=4, seed=0)
keys, w = zipfian_stream(40_000, beta=100.0, universe=5000, seed=1)
pairs = np.stack([keys.astype(np.float32), w.astype(np.float32)], axis=1)
for i in range(8):
    pipe.ingest("activations", jnp.asarray(rows[i * 256 : (i + 1) * 256]))
    pipe.ingest("clicks", pairs[i * 5000 : (i + 1) * 5000])
    pipe.ingest("clicks-shard", pairs[i * 5000 : (i + 1) * 5000])
for t in pipe.tenants():
    s = pipe.stats(t)
    print(f"{t:13s} [{s.workload:6s}] steps={s.steps} publishes={s.publishes} "
          f"msgs={s.comm_total}")

# -- mixed packed serving ----------------------------------------------------
x = np.random.default_rng(2).normal(size=D).astype(np.float32)
x /= np.linalg.norm(x)
hot = max(set(keys[:100].tolist()), key=keys[:100].tolist().count)
t_mat = pipe.submit("activations", x)
t_hh = pipe.submit("clicks", np.array([float(hot)], np.float32))
t_sh = pipe.submit("clicks-shard", np.array([float(hot)], np.float32))
pipe.flush()
est, bound, _ = t_mat.result()
print(f"\n||A x||^2 ~ {est:.1f} (+- {bound:.1f})")
print(f"clicks[{hot}] ~ {t_hh.result()[0]:.1f} (event)  "
      f"{t_sh.result()[0]:.1f} (shard)  true "
      f"{float(np.sum(w[keys == hot])):.1f}")
print(f"phi={PHI} heavy hitters: {pipe.heavy_hitters('clicks', PHI)}")

# -- quota overload: shed-and-report ----------------------------------------
held = [pipe.submit("activations", x) for _ in range(8)]
try:
    pipe.submit("activations", x)
except QueryShedError as e:
    print(f"\noverload: {e}")
print(f"shed counts: {pipe.service.shed_counts()} "
      f"(queued queries intact: {pipe.service.pending('activations')})")
pipe.flush()
assert all(t.done for t in held)

# -- restart: live state checkpoint, resume, identical answers ---------------
with tempfile.TemporaryDirectory() as ckdir:
    pipe.save(ckdir)
    restored = StreamingPipeline.load(ckdir, mesh)
    for p in (pipe, restored):  # resume ingest on BOTH coordinators
        p.ingest("clicks", pairs[:5000])
        p.ingest("activations", jnp.asarray(rows[:256]))
    a1 = pipe.submit("clicks", np.array([float(hot)], np.float32))
    a2 = restored.submit("clicks", np.array([float(hot)], np.float32))
    b1, b2 = pipe.submit("activations", x), restored.submit("activations", x)
    pipe.flush(), restored.flush()
    assert a1.result() == a2.result() and b1.result() == b2.result()
    print("\nrestart: resumed ingest answers bit-identical: OK")
