"""Mixed-workload pipeline demo: all four workload kinds, one runtime.

One ``StreamingPipeline`` hosts all four registered workload kinds —
matrix tracking (paper Section 5), weighted heavy hitters (Section 4),
distributed quantiles (Yi–Zhang's companion problem), and leverage-score
row sampling (the distributed-PCA companion) — behind a single
ingest → publish → packed-serve loop, and demonstrates the hardening this
layer adds:

  1. mixed packed serving — matrix quadform batches, HH point-lookups,
     quantile rank/phi lookups, and leverage subspace/score sweeps
     resolve through the same admission path and sweep,
  2. background deadline execution — a ``ServicePump`` thread owned by
     the pipeline holds per-query deadlines with no cooperative
     ``poll()`` calls from the ingest loop,
  3. per-tenant admission quotas — overload is shed with a typed error and
     counted, never silently dropped; priorities order capped sweeps,
  4. pipeline-level restart — ``save``/``load`` checkpoint live protocol
     state (not just published snapshots), so the restarted coordinator
     resumes ingest mid-stream and answers bit-identically (the pump
     revives too).

    PYTHONPATH=src python examples/mixed_tenants.py
"""
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.leverage import score_query, subspace_query
from repro.core.quantiles import quantile_query, rank_query
from repro.data.synthetic import lowrank_stream, zipfian_stream
from repro.query import QueryShedError
from repro.runtime import EveryKSteps, StreamingPipeline, TenantQuota

D, EPS_MAT, EPS_HH, EPS_Q, EPS_LEV, PHI = 32, 0.2, 0.02, 0.02, 0.2, 0.05

mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))
pipe = StreamingPipeline(mesh, eps=EPS_MAT, policy=EveryKSteps(2),
                         pump_interval_s=0.001)
pipe.add_tenant("activations", D, quota=TenantQuota(max_pending=8, priority=1))
pipe.add_hh_tenant("clicks", eps=EPS_HH, protocol="P1", engine="event", m=10,
                   quota=TenantQuota(max_pending=8, priority=5))
pipe.add_hh_tenant("clicks-shard", eps=EPS_HH, protocol="P1", engine="shard")
pipe.add_quantile_tenant("latency", eps=EPS_Q, protocol="P1", engine="event", m=10)
pipe.add_leverage_tenant("rowspace", D, eps=EPS_LEV, protocol="P1",
                         engine="event", m=10)

# -- ingest all four workloads through one loop ------------------------------
rows = lowrank_stream(2048, D, rank=4, seed=0)
keys, w = zipfian_stream(40_000, beta=100.0, universe=5000, seed=1)
pairs = np.stack([keys.astype(np.float32), w.astype(np.float32)], axis=1)
lat_ms = np.random.default_rng(9).lognormal(2.3, 0.8, 40_000).astype(np.float32)
lat = np.stack([lat_ms, np.ones_like(lat_ms)], axis=1)  # [value, weight]
for i in range(8):
    pipe.ingest("activations", jnp.asarray(rows[i * 256 : (i + 1) * 256]))
    pipe.ingest("clicks", pairs[i * 5000 : (i + 1) * 5000])
    pipe.ingest("clicks-shard", pairs[i * 5000 : (i + 1) * 5000])
    pipe.ingest("latency", lat[i * 5000 : (i + 1) * 5000])
    pipe.ingest("rowspace", rows[i * 256 : (i + 1) * 256])
for t in pipe.tenants():
    s = pipe.stats(t)
    print(f"{t:13s} [{s.workload:8s}] steps={s.steps} publishes={s.publishes} "
          f"msgs={s.comm_total}")

# -- mixed packed serving ----------------------------------------------------
x = np.random.default_rng(2).normal(size=D).astype(np.float32)
x /= np.linalg.norm(x)
hot = max(set(keys[:100].tolist()), key=keys[:100].tolist().count)
t_mat = pipe.submit("activations", x)
t_hh = pipe.submit("clicks", np.array([float(hot)], np.float32))
t_sh = pipe.submit("clicks-shard", np.array([float(hot)], np.float32))
t_p50 = pipe.submit("latency", quantile_query(0.5))
t_p99 = pipe.submit("latency", quantile_query(0.99))
t_rank = pipe.submit("latency", rank_query(20.0))
t_sub = pipe.submit("rowspace", subspace_query(x))
t_score = pipe.submit("rowspace", score_query(x))
pipe.flush()
est, bound, _ = t_mat.result()
print(f"\n||A x||^2 ~ {est:.1f} (+- {bound:.1f})")
sub_est, sub_bound, _ = t_sub.result()
n_sampled = pipe.sampled_rows("rowspace")[0].shape[0]
print(f"leverage sample ({n_sampled} rows): ||A x||^2 ~ {sub_est:.1f} "
      f"(+- {sub_bound:.1f}, true {float(np.sum((rows @ x) ** 2)):.1f}), "
      f"ridge score of x ~ {t_score.result()[0]:.2e}")
print(f"clicks[{hot}] ~ {t_hh.result()[0]:.1f} (event)  "
      f"{t_sh.result()[0]:.1f} (shard)  true "
      f"{float(np.sum(w[keys == hot])):.1f}")
print(f"phi={PHI} heavy hitters: {pipe.heavy_hitters('clicks', PHI)}")
print(f"latency p50 ~ {t_p50.result()[0]:.1f}ms (true "
      f"{float(np.quantile(lat_ms, 0.5)):.1f})  p99 ~ {t_p99.result()[0]:.1f}ms "
      f"(true {float(np.quantile(lat_ms, 0.99)):.1f})")
print(f"requests <= 20ms: ~{t_rank.result()[0]:.0f} of {lat_ms.size} "
      f"(true {int(np.sum(lat_ms <= 20.0))})")

# -- background deadline executor: serve while ingest is idle ----------------
tk = pipe.submit("latency", quantile_query(0.9), deadline_s=0.005)
while not tk.done:  # nobody calls poll()/flush(); only the pump can fire
    time.sleep(0.001)
print(f"\npump served p90 ~ {tk.result()[0]:.1f}ms while ingest was idle "
      f"(pump polls={pipe.pump.polls}, served={pipe.pump.served})")

# -- quota overload: shed-and-report ----------------------------------------
# Long deadlines: the background pump must not drain the held queries
# before the 9th submit trips the quota, or the demo has nothing to shed.
held = [pipe.submit("activations", x, deadline_s=60.0) for _ in range(8)]
try:
    pipe.submit("activations", x)
except QueryShedError as e:
    print(f"\noverload: {e}")
print(f"shed counts: {pipe.service.shed_counts()} "
      f"(queued queries intact: {pipe.service.pending('activations')})")
pipe.flush()
assert all(t.done for t in held)

# -- restart: live state checkpoint, resume, identical answers ---------------
with tempfile.TemporaryDirectory() as ckdir:
    pipe.save(ckdir)
    restored = StreamingPipeline.load(ckdir, mesh)
    assert restored.pump is not None and restored.pump.running  # pump revived
    for p in (pipe, restored):  # resume ingest on BOTH coordinators
        p.ingest("clicks", pairs[:5000])
        p.ingest("activations", jnp.asarray(rows[:256]))
        p.ingest("latency", lat[:5000])
        p.ingest("rowspace", rows[:256])
    a1 = pipe.submit("clicks", np.array([float(hot)], np.float32))
    a2 = restored.submit("clicks", np.array([float(hot)], np.float32))
    b1, b2 = pipe.submit("activations", x), restored.submit("activations", x)
    c1 = pipe.submit("latency", quantile_query(0.99))
    c2 = restored.submit("latency", quantile_query(0.99))
    d1 = pipe.submit("rowspace", subspace_query(x))
    d2 = restored.submit("rowspace", subspace_query(x))
    pipe.flush(), restored.flush()
    assert a1.result() == a2.result() and b1.result() == b2.result()
    assert c1.result() == c2.result() and d1.result() == d2.result()
    restored.close()
    print("\nrestart: resumed ingest answers bit-identical: OK")
pipe.close()
