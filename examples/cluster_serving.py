"""Sharded coordinator demo: 4 cells, live rebalance, stale-bounded replicas.

The paper scales *sites* horizontally but keeps one coordinator; this
demo applies the same recursion to the coordinator itself.  A
``ClusterRouter`` consistent-hashes tenants of all four workload kinds
over four ``PipelineCell`` shards, then shows the three cluster
behaviours worth watching:

  1. invisible sharding — a mixed-tenant query batch answers
     bit-identically to a single-pipeline coordinator serving the same
     streams (checked live, per tenant),
  2. minimal rebalance — growing to a fifth cell moves only the tenants
     whose ring arc changed owner, each as a live export/import that
     preserves protocol state, publish counters, and version numbers;
     answers before and after are byte-for-byte equal,
  3. bounded-staleness reads — a ``ServingReplica`` pulls published
     versions, serves without touching ingest, and every answer carries
     how many publishes it trails the owner by.

    PYTHONPATH=src python examples/cluster_serving.py
"""
import numpy as np

import jax

from repro.cluster import ClusterRouter, PipelineCell, ServingReplica
from repro.core.quantiles import quantile_query
from repro.query import PackedRequest
from repro.runtime import EveryKSteps, StreamingPipeline

D, BATCHES, ROWS = 32, 4, 64
mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))
rng = np.random.default_rng(0)


def build(target):
    """Identical registration + ingest for any coordinator-shaped target."""
    for i in range(6):
        target.add_tenant(f"mat-{i}", D, eps=0.2, policy=EveryKSteps(1))
    target.add_hh_tenant("clicks", eps=0.05, policy=EveryKSteps(1))
    target.add_quantile_tenant("latency", eps=0.05, policy=EveryKSteps(1))
    target.add_leverage_tenant("rows", D, eps=0.2, policy=EveryKSteps(1))
    r = np.random.default_rng(1)
    for _ in range(BATCHES):
        for i in range(6):
            target.ingest(f"mat-{i}", r.normal(size=(ROWS, D)).astype(np.float32))
        ids = r.integers(0, 50, 200).astype(np.float32)
        target.ingest("clicks", np.stack([ids, np.ones(200, np.float32)], axis=1))
        lat = r.gamma(2.0, 10.0, 200).astype(np.float32)
        target.ingest("latency", np.stack([lat, np.ones(200, np.float32)], axis=1))
        target.ingest("rows", r.normal(size=(ROWS, D)).astype(np.float32))


x = rng.normal(size=(8, D)).astype(np.float32)
queries = [(f"mat-{i}", x) for i in range(6)] + [
    ("clicks", np.arange(8, dtype=np.float32)[:, None]),
    ("latency", np.stack([quantile_query(0.5), quantile_query(0.99)])),
]

# -- 1. four cells vs one pipeline: sharding must be invisible ---------------
single = StreamingPipeline(mesh, eps=0.2, policy=EveryKSteps(1))
build(single)
base = single.engine.query_packed([PackedRequest(t, q) for t, q in queries])

cells = [PipelineCell(f"cell-{i}", mesh, eps=0.2, policy=EveryKSteps(1)) for i in range(4)]
router = ClusterRouter(cells)
build(router)
spread = router.ring.spread(router.tenants())
print(f"placement over 4 cells: { {c: spread.get(c, 0) for c in router.cells()} }")

answers = router.query_batch(queries)
for b, g in zip(base, answers):
    np.testing.assert_array_equal(b.estimates, g.estimates)
print(f"4-cell answers == single-pipeline answers for all {len(queries)} tenants (bit-identical)")

# -- 2. grow to 5 cells: minimal live rebalance ------------------------------
plan = router.plan_scale_to(router.cells() + ["cell-4"])
print(f"\ngrow-by-one plan: move {len(plan.moves)}/{len(router.tenants())} tenants "
      f"(fraction {plan.moved_fraction:.2f}), all onto cell-4: "
      f"{all(m.dst == 'cell-4' for m in plan.moves)}")
router.scale_to(cells + [PipelineCell("cell-4", mesh, eps=0.2, policy=EveryKSteps(1))])
after = router.query_batch(queries)
for b, g in zip(answers, after):
    np.testing.assert_array_equal(b.estimates, g.estimates)
print("moved tenants answer bit-identically after the rebalance "
      f"(versions preserved: {[r.version for r in after] == [r.version for r in answers]})")

# -- 3. replica serving with surfaced staleness ------------------------------
replica = ServingReplica(router, max_versions_behind=1)
res = replica.query_batch(x, tenant="mat-0")
print(f"\nreplica cold read: version {res.result.version}, "
      f"{res.versions_behind} behind owner (read-throughs: {replica.read_throughs})")
for _ in range(3):  # the owner keeps streaming; the replica does not ingest
    router.ingest("mat-0", rng.normal(size=(ROWS, D)).astype(np.float32))
res = replica.query_batch(x, tenant="mat-0")
print(f"after 3 more owner publishes (bound=1): served version {res.result.version}, "
      f"{res.versions_behind} behind, pulled {replica.pulled} versions total")
print(f"replica stats: {replica.stats()}")

router.close()
single.close()
