"""Quickstart: continuous matrix approximation in 30 lines.

Builds a Frequent-Directions sketch of a streaming matrix and shows the
paper's guarantee  0 <= ||Ax||^2 - ||Bx||^2 <= eps * ||A||_F^2  holding live.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import fd_init, fd_matrix, fd_query, fd_update_stream

rng = np.random.default_rng(0)
n, d, l = 20_000, 64, 32  # l rows => eps = 2/l ~ 6%

# a low-rank-ish stream: 5 dominant directions + noise
u = rng.normal(size=(n, 5)) * np.array([20, 10, 5, 2, 1.0])
stream = (u @ rng.normal(size=(5, d)) + 0.1 * rng.normal(size=(n, d))).astype(np.float32)

state = fd_init(l, d)
for start in range(0, n, 1000):  # rows arrive in batches
    state = fd_update_stream(state, jnp.asarray(stream[start : start + 1000]))

a = stream
frob = float(np.sum(a * a))
print(f"rows seen: {int(state.n_seen)}   sketch rows: {l}   compression: {n / l:.0f}x")
print(f"instance error bound (delta_sum/frob): {float(state.delta_sum)/frob:.2e}  (<= 2/l = {2/l:.2e})")

for trial in range(3):
    x = rng.normal(size=d)
    x /= np.linalg.norm(x)
    ax = float(np.sum((a @ x) ** 2))
    bx = float(fd_query(state, jnp.asarray(x, jnp.float32)))
    print(f"direction {trial}: ||Ax||^2={ax:10.1f}  ||Bx||^2={bx:10.1f}  gap={(ax-bx)/frob:.2e} of ||A||_F^2")

b = np.asarray(fd_matrix(state))
cov_err = np.linalg.norm(a.T @ a - b.T @ b, 2) / frob
print(f"covariance error ||A'A - B'B||_2 / ||A||_F^2 = {cov_err:.2e}")
