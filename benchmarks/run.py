"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  BENCH_SCALE env var scales
stream sizes toward the paper's full 1e7-element runs (default 1.0 keeps
the whole suite to a few minutes on one CPU core).

``--smoke`` shrinks every stream via ``BENCH_SCALE=0.25`` and runs only
the modules CI gates on (kernels, runtime pipeline, cluster scaling) —
a couple of minutes that still exercises every launch path end to end,
including the packed-ingest shootouts, without blessing their numbers.
An optional positional substring still filters module names.
"""
from __future__ import annotations

import os
import sys

SMOKE_MODULES = (
    "kernels_bench",
    "runtime_pipeline",
    "cluster_scaling",
    "windowed_tracking",
)

# BENCH_*.json files whose "obs" telemetry snapshot the smoke lane
# verifies, and the headline counters that must be nonzero in each.
SMOKE_OBS_FILES = (
    "BENCH_runtime_pipeline.json",
    "BENCH_cluster_scaling.json",
    "BENCH_windowed_tracking.json",
)
SMOKE_OBS_HEADLINE = (
    "repro_ingest_rows_total",
    "repro_engine_packed_launches_total",
)


def check_obs_snapshots() -> None:
    """Assert each smoke BENCH json carries a parseable, nonempty
    telemetry snapshot: it must round-trip through
    ``MetricsRegistry.from_snapshot`` and its headline counters must
    have actually counted something."""
    import json

    from repro.obs import MetricsRegistry

    for name in SMOKE_OBS_FILES:
        path = os.path.join(os.getcwd(), name)
        with open(path) as f:
            doc = json.load(f)
        reg = MetricsRegistry.from_snapshot(doc["obs"])
        for family in SMOKE_OBS_HEADLINE:
            total = sum(s.value for _, s in reg.get(family).series())
            assert total > 0, f"{name}: headline counter {family} is zero"
        print(f"# obs snapshot ok: {name}", flush=True)


def main() -> None:
    args = sys.argv[1:]
    smoke = "--smoke" in args
    if smoke:
        args = [a for a in args if a != "--smoke"]
        os.environ.setdefault("BENCH_SCALE", "0.25")

    from benchmarks import (
        cluster_scaling,
        grad_compression,
        hh_protocols,
        kernels_bench,
        leverage_protocols,
        matrix_protocols,
        p4_negative,
        quantile_protocols,
        query_service,
        roofline_table,
        runtime_pipeline,
        tradeoff,
        windowed_tracking,
    )

    print("name,us_per_call,derived")
    only = args[0] if args else None
    for mod in (
        hh_protocols,
        quantile_protocols,
        leverage_protocols,
        matrix_protocols,
        tradeoff,
        p4_negative,
        grad_compression,
        kernels_bench,
        query_service,
        runtime_pipeline,
        windowed_tracking,
        cluster_scaling,
        roofline_table,
    ):
        name = mod.__name__.split(".")[-1]
        if smoke and name not in SMOKE_MODULES:
            continue
        if only and only not in name:
            continue
        mod.run()

    if smoke and not only:
        check_obs_snapshots()


if __name__ == "__main__":
    main()
