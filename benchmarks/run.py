"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  BENCH_SCALE env var scales
stream sizes toward the paper's full 1e7-element runs (default 1.0 keeps
the whole suite to a few minutes on one CPU core).
"""
from __future__ import annotations

import sys


def main() -> None:
    from benchmarks import (
        cluster_scaling,
        grad_compression,
        hh_protocols,
        kernels_bench,
        leverage_protocols,
        matrix_protocols,
        p4_negative,
        quantile_protocols,
        query_service,
        roofline_table,
        runtime_pipeline,
        tradeoff,
    )

    print("name,us_per_call,derived")
    only = sys.argv[1] if len(sys.argv) > 1 else None
    for mod in (
        hh_protocols,
        quantile_protocols,
        leverage_protocols,
        matrix_protocols,
        tradeoff,
        p4_negative,
        grad_compression,
        kernels_bench,
        query_service,
        runtime_pipeline,
        cluster_scaling,
        roofline_table,
    ):
        name = mod.__name__.split(".")[-1]
        if only and only not in name:
            continue
        mod.run()


if __name__ == "__main__":
    main()
