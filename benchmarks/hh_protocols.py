"""Paper Figure 1 (a)-(f): weighted heavy hitters on a Zipfian stream.

Reports recall / precision / relative err of true HHs / messages for
P1-P4 across eps, m, and beta — the paper's exact measurement grid
(reduced stream by default; BENCH_SCALE=10 reproduces 1e7+ elements).

The second half drives HH tenants through the multi-tenant
``StreamingPipeline`` — mixed engines and eps under per-tenant admission
quotas — and writes ``BENCH_hh_pipeline.json``: protocol communication vs
estimate accuracy vs per-tenant serve latency, plus the shed counts the
quota pressure produced.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import emit, scale, timed
from repro.core.hh import exact_heavy_hitters
from repro.core.protocols import run_hh_protocol
from repro.data.synthetic import site_assignment, zipfian_stream

PROTOS = ["P1", "P2", "P3", "P3wr", "P4"]
PHI = 0.05


def _metrics(res, hh, totals, W):
    errs = [abs(totals[e] - res.estimates.get(e, 0.0)) / W for e in hh] or [0.0]
    returned = set(res.heavy_hitters(PHI))
    tp = len(returned & set(hh))
    recall = tp / max(len(hh), 1)
    precision = tp / max(len(returned), 1)
    return recall, precision, float(np.mean(errs))


def run() -> None:
    n = int(1_000_000 * scale())
    m, beta = 50, 1000.0
    keys, w = zipfian_stream(n, beta=beta, universe=50_000, seed=11)
    sites = site_assignment(n, m, seed=11)
    hh, totals, W = exact_heavy_hitters(keys, w, PHI)

    # Fig 1(a-d): sweep eps at m=50
    for eps in [5e-3, 1e-2, 5e-2]:
        for proto in PROTOS:
            kw = {}
            if proto == "P3wr":
                # s independent samplers x N items is O(N*s); cap the
                # sampler count for wall-time (the paper's point — P3wr is
                # dominated by P3wor — survives the cap).
                kw["s"] = min(2048, max(8, int(1 / eps**2)))
            res, us = timed(run_hh_protocol, proto, keys, w, sites, m, eps, seed=1, **kw)
            rec, prec, err = _metrics(res, hh, totals, W)
            emit(
                f"hh/fig1/{proto}/eps={eps:g}",
                us,
                f"recall={rec:.3f};precision={prec:.3f};err={err:.2e};msg={res.comm.total(m)}",
            )

    # Fig 1(e-f): sweep m and beta at eps=1e-2
    eps = 1e-2
    for m_i in [10, 50, 100]:
        sites_i = site_assignment(n, m_i, seed=12)
        for proto in ["P2", "P3", "P4"]:
            res, us = timed(run_hh_protocol, proto, keys, w, sites_i, m_i, eps, seed=2)
            emit(f"hh/fig1e/{proto}/m={m_i}", us, f"msg={res.comm.total(m_i)}")
    for beta_i in [10.0, 1000.0, 100000.0]:
        keys_b, w_b = zipfian_stream(n, beta=beta_i, universe=50_000, seed=13)
        sites_b = site_assignment(n, m, seed=13)
        for proto in ["P2", "P3"]:
            res, us = timed(run_hh_protocol, proto, keys_b, w_b, sites_b, m, eps, seed=3)
            emit(f"hh/fig1f/{proto}/beta={beta_i:g}", us, f"msg={res.comm.total(m)}")

    run_pipeline()


def run_pipeline() -> None:
    """HH tenants as first-class pipeline workloads, under quota pressure.

    Four HH tenants (event P1/P2 at two eps + the shard MG-merge engine)
    stream through one ``StreamingPipeline``; a query storm larger than the
    tenants' admission quotas measures shed behaviour and per-tenant packed
    serve latency.  Writes ``BENCH_hh_pipeline.json``.
    """
    import jax

    from repro.query import QueryShedError
    from repro.runtime import EveryKSteps, StreamingPipeline, TenantQuota

    n = max(20_000, int(200_000 * scale()))
    rounds, queries_per_round = 8, 32
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))
    # max_batch below the round's total admitted load, so each deadline-pump
    # sweep is capped and tenant priority visibly orders resolution times.
    pipe = StreamingPipeline(
        mesh, policy=EveryKSteps(2), max_batch=2 * queries_per_round,
        default_deadline_s=0.0,
    )
    tenants = {
        "hh-p1-tight": dict(protocol="P1", engine="event", eps=0.01, m=10),
        "hh-p1-loose": dict(protocol="P1", engine="event", eps=0.05, m=10),
        "hh-p2": dict(protocol="P2", engine="event", eps=0.01, m=10),
        "hh-shard": dict(protocol="P1", engine="shard", eps=0.01),
    }
    # Quota pressure: every tenant may hold at most one round's queries;
    # priorities stagger so capped sweeps have an observable order.
    for i, (name, kw) in enumerate(tenants.items()):
        pipe.add_hh_tenant(
            name, quota=TenantQuota(max_pending=queries_per_round, priority=i), **kw
        )

    streams = {
        name: zipfian_stream(n, beta=1000.0, universe=20_000, seed=50 + i)
        for i, name in enumerate(tenants)
    }
    batch = n // 8
    t0 = time.perf_counter()
    for name, (keys, w) in streams.items():
        pairs = np.stack([keys.astype(np.float32), w.astype(np.float32)], axis=1)
        for i in range(0, n, batch):
            pipe.ingest(name, pairs[i : i + batch])
    ingest_s = time.perf_counter() - t0

    # Query storm: 2x oversubmission against each tenant's quota; serve via
    # the deadline pump so each sweep is capped and priority-ordered (no
    # auto-flush, or the submit loop would drain the backlog early and
    # neither the quotas nor the priorities would ever bind).
    pipe.service.auto_flush = False
    rng = np.random.default_rng(99)
    shed = 0
    serve_s = {name: 0.0 for name in tenants}
    served = {name: 0 for name in tenants}
    for _ in range(rounds):
        tickets = {name: [] for name in tenants}
        for name, (keys, _) in streams.items():
            probes = rng.choice(keys[: n // 10], size=2 * queries_per_round)
            for e in probes:
                try:
                    tickets[name].append(
                        pipe.submit(name, np.array([float(e)], np.float32))
                    )
                except QueryShedError:
                    shed += 1
        t0 = time.perf_counter()
        resolved = set()
        while pipe.service.pending():
            pipe.poll()  # one capped priority-ordered sweep per pump
            now = time.perf_counter() - t0
            for name, ts in tickets.items():
                if name not in resolved and all(t.done for t in ts):
                    resolved.add(name)
                    serve_s[name] += now
        for name, ts in tickets.items():
            served[name] += len(ts)

    out: dict = {
        "stream": {"n_per_tenant": n, "rounds": rounds,
                   "queries_per_round": 2 * queries_per_round},
        "ingest_s": ingest_s,
        "service": {
            # stats() carries the authoritative shed count; only add the
            # per-tenant breakdown here.
            "shed_by_tenant": pipe.service.shed_counts(),
            **pipe.service.stats()._asdict(),
        },
        "tenants": {},
    }
    for name, (keys, w) in streams.items():
        hh, totals, W = exact_heavy_hitters(keys, w, PHI)
        proto = pipe.tracker(name)
        est = proto.estimates()
        errs = [abs(totals[e] - est.get(e, 0.0)) / W for e in hh] or [0.0]
        returned = set(pipe.heavy_hitters(name, PHI))
        tp = len(returned & set(hh))
        stats = pipe.stats(name)
        lat_us = serve_s[name] / rounds * 1e6  # mean time-to-resolution
        out["tenants"][name] = {
            **tenants[name],
            "priority": pipe.service.quota(name)[1],
            "comm_total": stats.comm_total,
            "recall": tp / max(len(hh), 1),
            "precision": tp / max(len(returned), 1),
            "mean_hh_err": float(np.mean(errs)),
            "queries_served": served[name],
            "serve_latency_us_per_round": lat_us,
            "publishes": stats.publishes,
        }
        emit(
            f"hh/pipeline/{name}",
            lat_us,
            f"recall={tp / max(len(hh), 1):.3f};msg={stats.comm_total};"
            f"shed={pipe.service.shed_counts().get(name, 0)}",
        )

    path = os.path.join(os.getcwd(), "BENCH_hh_pipeline.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
