"""Paper Figure 1 (a)-(f): weighted heavy hitters on a Zipfian stream.

Reports recall / precision / relative err of true HHs / messages for
P1-P4 across eps, m, and beta — the paper's exact measurement grid
(reduced stream by default; BENCH_SCALE=10 reproduces 1e7+ elements).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, scale, timed
from repro.core.hh import exact_heavy_hitters
from repro.core.protocols import run_hh_protocol
from repro.data.synthetic import site_assignment, zipfian_stream

PROTOS = ["P1", "P2", "P3", "P3wr", "P4"]
PHI = 0.05


def _metrics(res, hh, totals, W):
    errs = [abs(totals[e] - res.estimates.get(e, 0.0)) / W for e in hh] or [0.0]
    returned = set(res.heavy_hitters(PHI))
    tp = len(returned & set(hh))
    recall = tp / max(len(hh), 1)
    precision = tp / max(len(returned), 1)
    return recall, precision, float(np.mean(errs))


def run() -> None:
    n = int(1_000_000 * scale())
    m, beta = 50, 1000.0
    keys, w = zipfian_stream(n, beta=beta, universe=50_000, seed=11)
    sites = site_assignment(n, m, seed=11)
    hh, totals, W = exact_heavy_hitters(keys, w, PHI)

    # Fig 1(a-d): sweep eps at m=50
    for eps in [5e-3, 1e-2, 5e-2]:
        for proto in PROTOS:
            kw = {}
            if proto == "P3wr":
                # s independent samplers x N items is O(N*s); cap the
                # sampler count for wall-time (the paper's point — P3wr is
                # dominated by P3wor — survives the cap).
                kw["s"] = min(2048, max(8, int(1 / eps**2)))
            res, us = timed(run_hh_protocol, proto, keys, w, sites, m, eps, seed=1, **kw)
            rec, prec, err = _metrics(res, hh, totals, W)
            emit(
                f"hh/fig1/{proto}/eps={eps:g}",
                us,
                f"recall={rec:.3f};precision={prec:.3f};err={err:.2e};msg={res.comm.total(m)}",
            )

    # Fig 1(e-f): sweep m and beta at eps=1e-2
    eps = 1e-2
    for m_i in [10, 50, 100]:
        sites_i = site_assignment(n, m_i, seed=12)
        for proto in ["P2", "P3", "P4"]:
            res, us = timed(run_hh_protocol, proto, keys, w, sites_i, m_i, eps, seed=2)
            emit(f"hh/fig1e/{proto}/m={m_i}", us, f"msg={res.comm.total(m_i)}")
    for beta_i in [10.0, 1000.0, 100000.0]:
        keys_b, w_b = zipfian_stream(n, beta=beta_i, universe=50_000, seed=13)
        sites_b = site_assignment(n, m, seed=13)
        for proto in ["P2", "P3"]:
            res, us = timed(run_hh_protocol, proto, keys_b, w_b, sites_b, m, eps, seed=3)
            emit(f"hh/fig1f/{proto}/beta={beta_i:g}", us, f"msg={res.comm.total(m)}")
