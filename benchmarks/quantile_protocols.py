"""Distributed quantile tracking: comm vs eps, merge latency, pipeline serve.

First half sweeps the event protocols (P1 deterministic change propagation,
P3 priority sampling) across eps on a heavy-tailed weighted stream —
messages vs worst served rank error vs one-shot wall time — plus the
``QuantileSummary`` merge-latency microbenchmark (the coordinator's hot
operation: sites push summaries, C folds them).

The second half drives quantile tenants through the multi-tenant
``StreamingPipeline`` with a ``ServicePump`` background deadline executor
— mixed engines and eps — and writes ``BENCH_quantile_protocols.json``:
protocol communication vs rank accuracy vs per-tenant packed-serve
latency, with the pump (not the ingest loop) holding deadlines.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import emit, scale, timed
from repro.core.quantiles import (
    QuantileSummary,
    exact_ranks,
    quantile_query,
    run_quantile_protocol,
)
from repro.data.synthetic import site_assignment, zipfian_stream

PHIS = np.linspace(0.05, 0.95, 19)


def _stream(n: int, seed: int):
    """Heavy-tailed weighted value stream (zipf weights, lognormal values)."""
    rng = np.random.default_rng(seed)
    values = rng.lognormal(3.0, 1.5, n).astype(np.float32)
    _, weights = zipfian_stream(n, beta=100.0, universe=50_000, seed=seed)
    return values, weights


def _worst_rank_err(res, values, weights) -> float:
    w_total = float(np.sum(weights))
    worst = 0.0
    for phi in PHIS:
        v = float(res.quantile([phi])[0])
        r = float(exact_ranks(values, weights, [v])[0])
        worst = max(worst, abs(r - phi * w_total) / w_total)
    return worst


def run() -> None:
    n = int(200_000 * scale())
    m = 50
    values, weights = _stream(n, seed=21)
    sites = site_assignment(n, m, seed=21)

    # comm vs eps vs served accuracy.  The deterministic P1 pays per-item
    # python summary work, so its tightest-eps point is left to the cheap
    # sampling P3 (the comparison the protocols exist for).
    eps_grid = {"P1": [1e-2, 5e-2], "P3": [5e-3, 1e-2, 5e-2]}
    for proto, eps_list in eps_grid.items():
        for eps in eps_list:
            res, us = timed(
                run_quantile_protocol, proto, values, weights, sites, m, eps, seed=1
            )
            err = _worst_rank_err(res, values, weights)
            emit(
                f"quantile/comm/{proto}/eps={eps:g}",
                us,
                f"err={err:.2e};msg={res.comm.total(m)};n={n}",
            )

    # merge latency: the coordinator's hot operation, vs summary size (eps)
    for eps in [5e-3, 5e-2]:
        parts = []
        for i in range(8):
            s = QuantileSummary(eps)
            lo = i * (n // 8)
            s.extend(values[lo : lo + n // 8], weights[lo : lo + n // 8])
            parts.append(s)

        def fold(summaries=parts, e=eps):
            acc = QuantileSummary(e)
            for p in summaries:
                acc.merge(p)
            return acc

        acc, us = timed(fold)
        emit(
            f"quantile/merge/eps={eps:g}",
            us / len(parts),  # per-merge
            f"tuples={acc.size()};bytes={acc.serialized_bytes()}",
        )

    run_pipeline()


def run_pipeline() -> None:
    """Quantile tenants as pipeline workloads served under a ServicePump.

    Three quantile tenants (event P1 at two eps + the shard summary-merge
    engine) stream through one ``StreamingPipeline`` whose deadlines are
    held by the background executor; a query storm measures per-tenant
    time-to-resolution with zero cooperative ``poll()`` calls.  Writes
    ``BENCH_quantile_protocols.json``.
    """
    import jax

    from repro.runtime import EveryKSteps, StreamingPipeline, TenantQuota

    n = max(20_000, int(200_000 * scale()))
    rounds, queries_per_round = 8, 32
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))
    pipe = StreamingPipeline(
        mesh, policy=EveryKSteps(2), max_batch=2 * queries_per_round,
        pump_interval_s=0.0005,
    )
    tenants = {
        "q-p1-tight": dict(protocol="P1", engine="event", eps=0.01, m=10),
        "q-p1-loose": dict(protocol="P1", engine="event", eps=0.05, m=10),
        "q-shard": dict(protocol="P1", engine="shard", eps=0.01),
    }
    for i, (name, kw) in enumerate(tenants.items()):
        pipe.add_quantile_tenant(
            name, quota=TenantQuota(max_pending=4 * queries_per_round, priority=i), **kw
        )

    streams = {name: _stream(n, seed=60 + i) for i, name in enumerate(tenants)}
    batch = n // 8
    t0 = time.perf_counter()
    for name, (values, weights) in streams.items():
        pairs = np.stack([values, weights.astype(np.float32)], axis=1)
        for i in range(0, n, batch):
            pipe.ingest(name, pairs[i : i + batch])
    ingest_s = time.perf_counter() - t0

    # Query storm resolved purely by the pump: short per-query deadlines,
    # no poll()/flush() from this loop — time-to-resolution is the pump's.
    rng = np.random.default_rng(99)
    serve_s = {name: 0.0 for name in tenants}
    served = {name: 0 for name in tenants}
    for _ in range(rounds):
        tickets = {
            name: [
                pipe.submit(name, quantile_query(float(p)), deadline_s=0.001)
                for p in rng.uniform(0.01, 0.99, queries_per_round)
            ]
            for name in tenants
        }
        t0 = time.perf_counter()
        resolved: set = set()
        while len(resolved) < len(tenants):
            time.sleep(0.0002)
            now = time.perf_counter() - t0
            for name, ts in tickets.items():
                if name not in resolved and all(t.done for t in ts):
                    resolved.add(name)
                    serve_s[name] += now
        for name, ts in tickets.items():
            served[name] += len(ts)

    pump_polls, pump_served = pipe.pump.polls, pipe.pump.served
    pipe.close()

    out: dict = {
        "stream": {"n_per_tenant": n, "rounds": rounds,
                   "queries_per_round": queries_per_round},
        "ingest_s": ingest_s,
        "pump": {"interval_s": 0.0005, "polls": pump_polls, "served": pump_served},
        "service": pipe.service.stats()._asdict(),
        "tenants": {},
    }
    for name, (values, weights) in streams.items():
        proto = pipe.tracker(name)  # duck-types _worst_rank_err's .quantile
        worst = _worst_rank_err(proto, values, weights)
        stats = pipe.stats(name)
        lat_us = serve_s[name] / rounds * 1e6  # mean time-to-resolution
        out["tenants"][name] = {
            **tenants[name],
            "priority": pipe.service.quota(name)[1],
            "comm_total": stats.comm_total,
            "worst_rank_err": worst,
            "queries_served": served[name],
            "serve_latency_us_per_round": lat_us,
            "publishes": stats.publishes,
        }
        emit(
            f"quantile/pipeline/{name}",
            lat_us,
            f"err={worst:.2e};msg={stats.comm_total};pump_served={pump_served}",
        )

    path = os.path.join(os.getcwd(), "BENCH_quantile_protocols.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
