"""Paper Table 1 + Figures 2/3: matrix tracking on PAMAP-like (low rank) and
MSD-like (high rank) streams.

Columns per method: err = ||A^T A - B^T B||_2 / ||A||_F^2 and msg, against
the two all-data baselines the paper uses (centralized FD, offline SVD_k).
Checks the paper's qualitative findings: SVD << eps for PAMAP (low rank),
SVD ~ 6e-3 for MSD (high rank); P1 accurate but expensive; P2 cheapest
deterministic; P3wor dominates P3wr.

Protocols are enumerated and driven through the runtime registry
(``repro.runtime.registry``): one typed interface — step / matrix /
comm_report — instead of per-protocol result handling.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, scale, timed
from repro.core.fd import FDSketch
from repro.data.synthetic import msd_like, pamap_like, site_assignment
from repro.runtime.registry import create_protocol, protocol_names


def _svd_err(a, k):
    u, s, vt = np.linalg.svd(a, full_matrices=False)
    bk = s[:k, None] * vt[:k]
    return float(np.linalg.norm(a.T @ a - bk.T @ bk, 2) / np.sum(a * a))


def _cov_err(eng, ata, frob):
    """Paper err metric for a registry engine's sketch (MatrixResult.covariance_error)."""
    b = eng.matrix()
    return float(np.linalg.norm(ata - b.T @ b, 2) / max(frob, 1e-300))


def _dataset(name):
    n = int(150_000 * scale())
    if name == "pamap":
        return pamap_like(n, seed=21), 30
    return msd_like(n, seed=22), 50


def _run_event(proto, a, sites, m, eps, seed):
    """Stream the whole matrix through a registry event engine; returns
    (err_fn_inputs, comm_total) via the uniform interface."""
    eng = create_protocol(proto, engine="event", m=m, eps=eps, d=a.shape[1], seed=seed)
    eng.step(a, sites)
    return eng


def run() -> None:
    m, eps = 50, 0.1
    for ds in ["pamap", "msd"]:
        a, k = _dataset(ds)
        n = a.shape[0]
        sites = site_assignment(n, m, seed=23)
        ata = a.T @ a
        frob = float(np.sum(a * a))

        # baselines: offline SVD_k and centralized FD (all data shipped)
        (svd_err, us) = timed(_svd_err, a, k)
        emit(f"matrix/table1/{ds}/SVD", us, f"err={svd_err:.3e};msg={n}")
        fd = FDSketch(max(8, int(4 / eps)), a.shape[1])
        _, us = timed(fd.extend, a)
        emit(f"matrix/table1/{ds}/FD", us, f"err={fd.covariance_error(a):.3e};msg={n}")

        for proto in protocol_names("event", kind="matrix"):
            eng, us = timed(_run_event, proto, a, sites, m, eps, 1)
            emit(
                f"matrix/table1/{ds}/{proto}",
                us,
                f"err={_cov_err(eng, ata, frob):.3e};msg={eng.comm_report().total}",
            )

        # Fig 2/3 (a-b): sweep eps
        for eps_i in [5e-2, 1e-1, 5e-1]:
            for proto in ["P2", "P3"]:
                eng, us = timed(_run_event, proto, a, sites, m, eps_i, 2)
                emit(
                    f"matrix/fig23/{ds}/{proto}/eps={eps_i:g}",
                    us,
                    f"err={_cov_err(eng, ata, frob):.3e};msg={eng.comm_report().total}",
                )
        # Fig 2/3 (c-d): sweep m
        for m_i in [10, 50, 100]:
            sites_i = site_assignment(n, m_i, seed=24)
            for proto in ["P2", "P3"]:
                eng, us = timed(_run_event, proto, a, sites_i, m_i, eps, 3)
                emit(
                    f"matrix/fig23/{ds}/{proto}/m={m_i}",
                    us,
                    f"err={_cov_err(eng, ata, frob):.3e};msg={eng.comm_report().total}",
                )
