"""Windowed + decayed tracking: the cost of carrying time.

Three questions the window layer must answer with numbers:

  * what does the bucket count cost? — per-step ingest + serve latency of
    a sliding-window matrix tenant as buckets grow (serving folds one
    ``fd_merge`` per live bucket, so cost should scale ~linearly);
  * what does event time cost end to end? — pipeline ingest rows/sec and
    packed serve latency for a fleet of windowed tenants (OnWindowClose
    cadence) vs the same fleet tracking the full stream;
  * what does forgetting buy? — on a drifting stream, query error of
    sliding-window and exponential-decay tenants vs a full-stream tenant,
    each against the exact in-window answer.

Emits CSV rows and ``BENCH_windowed_tracking.json`` (with the pipeline's
telemetry snapshot under ``"obs"``).
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import emit, obs_block, scale

TENANTS = 8
D, EPS = 64, 0.2
BATCH = 32
WINDOW = 64.0


def _bucket_sweep() -> dict:
    """Ingest + serve cost of one windowed matrix tracker vs bucket count."""
    from repro.runtime.registry import create_protocol

    rng = np.random.default_rng(0)
    steps = max(64, int(256 * scale()))
    rows = [rng.normal(size=(BATCH, D)).astype(np.float32) for _ in range(8)]
    x = rng.normal(size=D).astype(np.float32)
    out = {}
    for buckets in (4, 16, 64):
        proto = create_protocol(
            "P2win", engine="event", kind="matrix",
            d=D, eps=EPS, m=1, window=WINDOW, buckets=buckets,
        )
        for t in range(buckets):  # warm every bucket + compile
            proto.step(rows[t % len(rows)], ts=float(t))
        proto.query(x)
        t0 = time.perf_counter()
        for t in range(steps):
            proto.step(rows[t % len(rows)], ts=float(buckets + t))
        step_s = (time.perf_counter() - t0) / steps
        t0 = time.perf_counter()
        for _ in range(16):
            proto.query(x)
        serve_s = (time.perf_counter() - t0) / 16
        emit(f"windowed/step/buckets={buckets}", step_s * 1e6,
             f"serve_us={serve_s * 1e6:.0f}")
        out[str(buckets)] = {"step_s": step_s, "serve_s": serve_s}
    return out


def _fleet(mesh, windowed: bool):
    from repro.runtime import EveryKSteps, OnWindowClose, StreamingPipeline

    pipe = StreamingPipeline(mesh, eps=EPS, policy=EveryKSteps(4))
    for i in range(TENANTS):
        if windowed:
            pipe.add_windowed_tenant(
                f"t{i}", kind="matrix", d=D, window=WINDOW, buckets=8,
                policy=OnWindowClose(),
            )
        else:
            pipe.add_tenant(f"t{i}", D)
    return pipe


def _pipeline_shootout(mesh) -> tuple[dict, object]:
    """Windowed fleet vs full-stream fleet through the real pipeline."""
    from repro.query.engine import PackedRequest

    rng = np.random.default_rng(1)
    waves = max(16, int(64 * scale()))
    data = [rng.normal(size=(BATCH, D)).astype(np.float32) for _ in range(8)]
    xs = rng.normal(size=(16, D)).astype(np.float32)
    xs /= np.linalg.norm(xs, axis=1, keepdims=True)
    requests = [PackedRequest(f"t{i}", xs) for i in range(TENANTS)]
    out: dict = {}
    keep = None
    for windowed in (True, False):
        pipe = _fleet(mesh, windowed)
        for w in range(4):  # warm: compile + first publishes
            for i in range(TENANTS):
                pipe.ingest(f"t{i}", data[w % len(data)],
                            ts=float(w) if windowed else None)
        t0 = time.perf_counter()
        for w in range(waves):
            for i in range(TENANTS):
                pipe.ingest(f"t{i}", data[w % len(data)],
                            ts=float(4 + w) if windowed else None)
        ingest_s = time.perf_counter() - t0
        pipe.engine.query_packed(requests)  # warm the packed sweep
        t0 = time.perf_counter()
        for _ in range(8):
            pipe.engine.query_packed(requests)
        serve_s = (time.perf_counter() - t0) / 8
        key = "windowed" if windowed else "full_stream"
        out[key] = {
            "ingest_rows_per_sec": waves * TENANTS * BATCH / ingest_s,
            "packed_serve_s": serve_s,
            "publishes": sum(pipe.stats(t).publishes for t in pipe.tenants()),
        }
        emit(
            f"windowed/pipeline_{key}/t={TENANTS}",
            ingest_s / (waves * TENANTS) * 1e6,
            f"rows_per_sec={out[key]['ingest_rows_per_sec']:.0f}",
        )
        if windowed:
            keep = pipe  # its obs snapshot goes into the BENCH json
        else:
            pipe.close()
    out["overhead_x"] = (
        out["full_stream"]["ingest_rows_per_sec"]
        / out["windowed"]["ingest_rows_per_sec"]
    )
    emit("windowed/ingest_overhead_vs_full_stream", 0.0,
         f"x{out['overhead_x']:.2f}")
    return out, keep


def _drift_accuracy() -> dict:
    """Query error on a drifting stream: forgetting beats remembering."""
    from repro.runtime.registry import create_protocol

    rng = np.random.default_rng(2)
    steps = max(96, int(192 * scale()))
    mk = dict(engine="event", kind="matrix", d=D, eps=EPS, m=1)
    win = create_protocol("P2win", window=32.0, buckets=8, **mk)
    dec = create_protocol("P2decay", half_life=16.0, **mk)
    full = create_protocol("P2", **mk)
    hist = []
    for t in range(steps):
        # the dominant direction drifts: early rows mislead a full tracker
        u = np.zeros(D, np.float32)
        u[(t // 32) % D] = 1.0
        rows = (rng.normal(size=(BATCH, 1)).astype(np.float32) * 4.0) * u
        rows += rng.normal(size=(BATCH, D)).astype(np.float32) * 0.3
        hist.append((float(t), rows))
        win.step(rows, ts=float(t))
        dec.step(rows, ts=float(t))
        full.step(rows)
    x = np.zeros(D, np.float32)
    x[((steps - 1) // 32) % D] = 1.0  # the *current* hot direction
    recent = np.concatenate(
        [r for ts, r in hist if ts >= steps - 1 - 32.0]
    ).astype(np.float64)
    exact = float(np.sum((recent @ x) ** 2))
    out = {}
    for name, proto in (("window", win), ("decay", dec), ("full", full)):
        err = abs(float(proto.query(x)) - exact) / exact
        out[name] = err
        emit(f"windowed/drift_err/{name}", 0.0, f"rel_err={err:.3f}")
    return out


def run() -> None:
    import jax

    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))
    buckets = _bucket_sweep()
    pipeline, pipe = _pipeline_shootout(mesh)
    drift = _drift_accuracy()
    out = {
        "sketch": {"d": D, "eps": EPS, "window": WINDOW},
        "bucket_sweep": buckets,
        "pipeline": pipeline,
        "drift_rel_err": drift,
        "obs": obs_block(pipe.obs),
    }
    pipe.close()
    path = os.path.join(os.getcwd(), "BENCH_windowed_tracking.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
