"""Leverage-score row sampling: comm vs eps, fused kernel, sample-size sweep.

Three sections, writing ``BENCH_leverage_protocols.json``:

  * comm vs eps — the event protocols (P1 deterministic threshold
    forwarding, P2 score-weighted reservoir sampling) across eps on a
    low-rank + noise stream: messages vs worst served subspace error vs
    one-shot wall time.
  * levscore kernel — scoring S rows against a precomputed
    ``(B^T B + lambda I)^+`` factor: the fused Pallas sweep
    (``ops.levscore``) vs the per-row matvec strawman (S python-loop
    ``x @ M @ x`` evaluations) it replaces.
  * subspace-query error vs sample size — the P2 sample's importance-
    weighted ``||A x||^2`` estimate as the reservoir budget grows.
"""
from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import emit, scale, timed
from repro.core.leverage import ridge_factor, run_leverage_protocol
from repro.data.synthetic import lowrank_stream, site_assignment


def _stream(n: int, d: int, seed: int):
    """Low-rank + noise row stream (the structure norm-sampling misses)."""
    return lowrank_stream(n, d, rank=max(2, d // 8), seed=seed)


def _worst_subspace_err(res, a, xs) -> float:
    frob = float(np.sum(a * a))
    true = np.sum((a @ xs.T) ** 2, axis=0)
    return float(np.max(np.abs(res.subspace(xs) - true))) / frob


def run() -> None:
    """Benchmark entry point (registered in benchmarks/run.py)."""
    n = int(100_000 * scale())
    d, m = 32, 50
    a = _stream(n, d, seed=31)
    sites = site_assignment(n, m, seed=31)
    rng = np.random.default_rng(32)
    xs = rng.normal(size=(32, d)).astype(np.float32)
    xs /= np.linalg.norm(xs, axis=1, keepdims=True)

    out: dict = {"stream": {"n": n, "d": d, "m": m}, "comm": [], "kernel": {},
                 "sample_size": []}

    # -- comm vs eps vs served accuracy ------------------------------------
    eps_grid = {"P1": [0.1, 0.3], "P2": [0.05, 0.1, 0.3]}
    for proto, eps_list in eps_grid.items():
        for eps in eps_list:
            res, us = timed(
                run_leverage_protocol, proto, a, sites, m, eps, seed=1
            )
            err = _worst_subspace_err(res, a, xs)
            msg = res.comm.total(m)
            out["comm"].append({"protocol": proto, "eps": eps, "err": err,
                                "messages": msg, "us": us})
            emit(
                f"leverage/comm/{proto}/eps={eps:g}",
                us,
                f"err={err:.2e};msg={msg};n={n}",
            )

    # -- levscore kernel vs per-row matvec scoring -------------------------
    out["kernel"] = _kernel_section()

    # -- subspace-query error vs sample size -------------------------------
    for s in (16, 64, 256):
        errs = []
        for seed in range(3):
            res = run_leverage_protocol("P2", a, sites, m, 0.1, seed=seed, s=s)
            errs.append(_worst_subspace_err(res, a, xs))
        med = float(np.median(errs))
        out["sample_size"].append({"s": s, "median_err": med, "errs": errs})
        emit(f"leverage/sample_size/s={s}", 0.0, f"err={med:.2e}")

    path = os.path.join(os.getcwd(), "BENCH_leverage_protocols.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)


def _kernel_section() -> dict:
    """Fused scoring sweep vs the per-row matvec strawman it replaces.

    On TPU the fused path IS the Pallas kernel (``ops.levscore``); on this
    CPU container the kernel runs in interpret mode (per-element python
    semantics, not wall-time-representative — the kernels_bench caveat),
    so the fused wall-time stand-in is the XLA compilation of the same
    sweep (``ref_levscore`` jitted), with the interpret-mode number
    reported alongside for transparency.
    """
    import jax
    import jax.numpy as jnp

    from repro.kernels.ops import levscore
    from repro.kernels.ref import ref_levscore

    on_tpu = jax.default_backend() == "tpu"
    rng = np.random.default_rng(33)
    d, n_rows = 256, int(4096 * scale())
    b = rng.normal(size=(64, d))
    factor = ridge_factor(b, 1.0, 1.0).astype(np.float32)
    rows = rng.normal(size=(n_rows, d)).astype(np.float32)

    fj = jnp.asarray(factor)
    xj = jnp.asarray(rows)
    ref_jit = jax.jit(ref_levscore)
    jax.block_until_ready(levscore(fj, xj))  # compile outside the timing
    jax.block_until_ready(ref_jit(fj, xj))

    got, pallas_us = timed(lambda: jax.block_until_ready(levscore(fj, xj)))
    _, xla_us = timed(lambda: jax.block_until_ready(ref_jit(fj, xj)))
    fused_us = pallas_us if on_tpu else xla_us

    def per_row():
        out = np.empty(n_rows, np.float32)
        for i, r in enumerate(rows):  # S matvec pairs, one dispatch each
            out[i] = r @ (factor @ r)
        return out

    want, loop_us = timed(per_row)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-3)
    speedup = loop_us / max(fused_us, 1e-9)
    emit(
        f"leverage/levscore/fused/S={n_rows}",
        fused_us,
        f"per_row_us={loop_us:.0f};speedup={speedup:.1f}x;"
        f"pallas_{'tpu' if on_tpu else 'interpret'}_us={pallas_us:.0f}",
    )
    return {"d": d, "rows": n_rows, "backend": jax.default_backend(),
            "fused_us": fused_us, "pallas_us": pallas_us, "xla_us": xla_us,
            "per_row_us": loop_us, "speedup": speedup}


if __name__ == "__main__":
    run()
