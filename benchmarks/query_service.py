"""Query-serving throughput: naive per-query SVD vs cached-eigh vs Pallas.

Builds an FD sketch over a synthetic low-rank stream, publishes it, then
serves a 1024-direction batch through ``repro.query.QueryEngine`` on each
path.  Emits per-query latencies as CSV rows and writes
``BENCH_query_service.json`` with queries/sec for all three paths plus the
batched-vs-naive speedup (the PR gate is >= 5x).
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import emit, scale

BATCH = 1024
NAIVE_SAMPLE = 32  # per-query SVDs are slow; measure a slice, report per-query


def _build_engine(rng, n, d, eps):
    import jax.numpy as jnp

    from repro.core.fd import fd_init, fd_matrix, fd_update_stream
    from repro.query import QueryEngine, SketchStore

    u = rng.normal(size=(n, 8)) * (np.arange(8, 0, -1) ** 2)
    a = (u @ rng.normal(size=(8, d)) + 0.05 * rng.normal(size=(n, d))).astype(np.float32)
    l = int(np.ceil(4.0 / eps))
    st = fd_update_stream(fd_init(l, d), jnp.asarray(a))
    store = SketchStore()
    store.publish(
        "bench",
        np.asarray(fd_matrix(st)),
        frob=float(np.sum(a * a)),
        eps=eps,
        delta_sum=float(st.delta_sum),
        n_seen=n,
    )
    return QueryEngine(store)


def _time_path(engine, x, path, iters):
    engine.query_batch(x, tenant="bench", path=path)  # warm (jit / cache fill)
    t0 = time.perf_counter()
    for _ in range(iters):
        engine.query_batch(x, tenant="bench", path=path)
    return (time.perf_counter() - t0) / iters


def run() -> None:
    rng = np.random.default_rng(0)
    n = int(20000 * scale())
    d, eps = 256, 0.1
    engine = _build_engine(rng, n, d, eps)
    x = rng.normal(size=(BATCH, d)).astype(np.float32)
    x /= np.linalg.norm(x, axis=1, keepdims=True)

    qps: dict[str, float] = {}
    sec = _time_path(engine, x[:NAIVE_SAMPLE], "naive", iters=1)
    qps["naive_svd"] = NAIVE_SAMPLE / sec
    emit(f"query/naive_svd/batch={NAIVE_SAMPLE}", sec / NAIVE_SAMPLE * 1e6, f"qps={qps['naive_svd']:.0f}")

    for path, key in (("cached", "cached_eigh"), ("pallas", "pallas_batched")):
        sec = _time_path(engine, x, path, iters=3)
        qps[key] = BATCH / sec
        emit(f"query/{key}/batch={BATCH}", sec / BATCH * 1e6, f"qps={qps[key]:.0f}")

    speedup = qps["pallas_batched"] / qps["naive_svd"]
    emit("query/speedup_pallas_vs_naive", 0.0, f"x{speedup:.1f}")

    out = {
        "batch": BATCH,
        "sketch": {"d": d, "eps": eps, "rows_streamed": n},
        "queries_per_sec": qps,
        "speedup_pallas_vs_naive": speedup,
    }
    path = os.path.join(os.getcwd(), "BENCH_query_service.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
