"""Shared benchmark plumbing: CSV rows + timing."""
from __future__ import annotations

import os
import time

ROWS: list[tuple[str, float, str]] = []


def scale() -> float:
    """BENCH_SCALE=1.0 gives the default (CI-sized) runs; crank it up to
    approach the paper's full 1e7-element streams."""
    return float(os.environ.get("BENCH_SCALE", "1.0"))


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.3f},{derived}", flush=True)


def timed(fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) * 1e6


def obs_block(obs) -> dict:
    """An ``Observability`` bundle's registry snapshot, for the ``obs``
    key of a ``BENCH_*.json``.  The snapshot is sorted/deterministic and
    parses back through ``MetricsRegistry.from_snapshot`` — bench-smoke
    asserts that round trip plus nonzero headline counters."""
    return obs.registry.snapshot()
