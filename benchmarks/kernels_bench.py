"""Kernel micro-benchmarks.

On this CPU container the Pallas kernels run in interpret mode (per-element
python semantics), so wall-times are NOT TPU-representative; the meaningful
derived numbers are the oracle (XLA-compiled) timings and the kernels'
arithmetic intensities, which we also report for the roofline narrative.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.kernels.ref import ref_attention, ref_fd_gram, ref_fd_project


def _bench(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def run() -> None:
    rng = np.random.default_rng(0)
    for l, d in [(64, 1024), (128, 4096), (256, 4096)]:
        b = jnp.asarray(rng.normal(size=(l, d)), jnp.float32)
        f = jax.jit(ref_fd_gram)
        us = _bench(f, b)
        flops = 2 * l * l * d
        ai = flops / (4 * (l * d + l * l))  # arithmetic intensity (f32)
        emit(f"kernels/fd_gram/L={l},d={d}", us, f"flops={flops:.2e};AI={ai:.1f}")
        w = jnp.asarray(rng.uniform(size=(l,)), jnp.float32)
        u = jnp.asarray(rng.normal(size=(l, l)), jnp.float32)
        fp = jax.jit(ref_fd_project)
        us = _bench(fp, w, u, b)
        emit(f"kernels/fd_project/L={l},d={d}", us, f"flops={2*l*l*d:.2e}")

    for b_, h, s, dh in [(1, 8, 1024, 128), (1, 8, 4096, 128)]:
        q = jnp.asarray(rng.normal(size=(b_, h, s, dh)), jnp.bfloat16)
        k = jnp.asarray(rng.normal(size=(b_, h, s, dh)), jnp.bfloat16)
        v = jnp.asarray(rng.normal(size=(b_, h, s, dh)), jnp.bfloat16)
        f = jax.jit(lambda q, k, v: ref_attention(q, k, v, causal=True))
        us = _bench(f, q, k, v)
        flops = 4 * b_ * h * s * s * dh
        emit(f"kernels/attention_ref/s={s}", us, f"flops={flops:.2e}")
