"""Cluster scale-out: ingest/query throughput at 1/2/4/8 coordinator cells.

Drives the same mixed-tenant workload through a ``ClusterRouter`` at each
cluster size (``ingest_many(parallel=True)`` fans each cell onto its own
worker thread; ``query_batch`` packs per cell), measures router overhead
against a bare single ``StreamingPipeline`` serving the identical load,
and reports a ``ServingReplica``'s factor-cache hit rate on a repeated
read mix.  Emits CSV rows and writes ``BENCH_cluster_scaling.json``.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import emit, obs_block, scale

CELLS = (1, 2, 4, 8)
D = 64
TENANTS = 24
QUERY_ROUNDS = 3


def _mesh():
    import jax

    return jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))


def _batches(n_batches, rows):
    rng = np.random.default_rng(7)
    names = [f"tenant-{i:02d}" for i in range(TENANTS)]
    flat = [
        (names[i % TENANTS], rng.normal(size=(rows, D)).astype(np.float32))
        for i in range(TENANTS * n_batches)
    ]
    return names, flat


def _register(target, names, publish_every=1):
    from repro.runtime import EveryKSteps

    for t in names:
        target.add_tenant(t, D, eps=0.2, policy=EveryKSteps(publish_every))


def _queries(names, rng):
    x = rng.normal(size=(16, D)).astype(np.float32)
    return [(t, x) for t in names]


def _drive_cluster(n_cells, names, flat, queries, packed=True, publish_every=1):
    from repro.cluster import ClusterRouter, PipelineCell
    from repro.runtime import EveryKSteps

    mesh = _mesh()
    cells = [
        PipelineCell(f"cell-{i}", mesh, eps=0.2, policy=EveryKSteps(publish_every))
        for i in range(n_cells)
    ]
    with ClusterRouter(cells) as router:
        _register(router, names, publish_every)
        # Two warm rounds: the packed in-cell path compiles from_states on
        # the first wave and the steady resident-stack program on the second.
        router.ingest_many(flat[:TENANTS], parallel=True, packed=packed)
        router.ingest_many(flat[:TENANTS], parallel=True, packed=packed)
        if queries is not None:
            router.query_batch(queries)  # warm query path

        t0 = time.perf_counter()
        router.ingest_many(flat[TENANTS:], parallel=True, packed=packed)
        ingest_s = time.perf_counter() - t0

        query_s = 0.0
        if queries is not None:
            t0 = time.perf_counter()
            for _ in range(QUERY_ROUNDS):
                out = router.query_batch(queries)
            query_s = (time.perf_counter() - t0) / QUERY_ROUNDS
            assert len(out) == len(queries)
        spread = router.ring.spread(names)
        obs_snap = obs_block(router.obs)
    return ingest_s, query_s, {k: spread[k] for k in sorted(spread)}, obs_snap


def _drive_single(names, flat, queries):
    from repro.query import PackedRequest
    from repro.runtime import EveryKSteps, StreamingPipeline

    pipe = StreamingPipeline(_mesh(), eps=0.2, policy=EveryKSteps(1))
    _register(pipe, names)
    for t, b in flat[:TENANTS]:
        pipe.ingest(t, b)
    requests = [PackedRequest(t, q) for t, q in queries]
    pipe.engine.query_packed(requests)

    t0 = time.perf_counter()
    for t, b in flat[TENANTS:]:
        pipe.ingest(t, b)
    ingest_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    for _ in range(QUERY_ROUNDS):
        pipe.engine.query_packed(requests)
    query_s = (time.perf_counter() - t0) / QUERY_ROUNDS
    pipe.close()
    return ingest_s, query_s


def _replica_hit_rate(names, flat):
    from repro.cluster import PipelineCell, ServingReplica
    from repro.runtime import EveryKSteps

    cell = PipelineCell("serve", _mesh(), eps=0.2, policy=EveryKSteps(1))
    _register(cell.pipeline, names[:4])
    for t, b in flat:
        if t in names[:4]:
            cell.ingest(t, b)
    replica = ServingReplica(cell, cache_size=8)
    replica.sync()
    rng = np.random.default_rng(11)
    for _ in range(8):  # repeated spectrum reads on a fixed version set
        for t in names[:4]:
            replica.engine.spectrum(t)
            replica.query_batch(rng.normal(size=(4, D)).astype(np.float32), tenant=t)
    return replica.stats()["cache"]


def run() -> None:
    n_batches = max(2, int(6 * scale()))
    rows = 256
    names, flat = _batches(n_batches, rows)
    queries = _queries(names, np.random.default_rng(3))
    total_rows = len(flat[TENANTS:]) * rows

    by_cells: dict[str, dict] = {}
    single_ingest, single_query = _drive_single(names, flat, queries)
    emit("cluster/single_pipeline/ingest", single_ingest * 1e6,
         f"rows_per_s={total_rows / single_ingest:.0f}")
    emit("cluster/single_pipeline/query", single_query * 1e6,
         f"qps={len(queries) / single_query:.0f}")

    for n_cells in CELLS:
        ingest_s, query_s, spread, obs_snap = _drive_cluster(
            n_cells, names, flat, queries
        )
        by_cells[str(n_cells)] = {
            "ingest_rows_per_s": total_rows / ingest_s,
            "query_batches_per_s": len(queries) / query_s,
            "tenant_spread": spread,
        }
        emit(f"cluster/cells={n_cells}/ingest", ingest_s * 1e6,
             f"rows_per_s={total_rows / ingest_s:.0f}")
        emit(f"cluster/cells={n_cells}/query", query_s * 1e6,
             f"qps={len(queries) / query_s:.0f}")

    one = by_cells["1"]
    router_overhead_ingest = (total_rows / single_ingest) / one["ingest_rows_per_s"]
    router_overhead_query = (len(queries) / single_query) / one["query_batches_per_s"]
    emit("cluster/router_overhead/ingest", 0.0, f"x{router_overhead_ingest:.2f}")
    emit("cluster/router_overhead/query", 0.0, f"x{router_overhead_query:.2f}")

    # In-cell packed ingest vs the strict serial loop, same 2-cell cluster,
    # at the regime packing is built for: modest per-tenant batches (dispatch
    # overhead dominates; big data-bound batches gain nothing from stacking)
    # and a publish cadence sparser than every wave (a publish reads each
    # member's state, which slices it out of the resident stacked pack).
    small_rows, publish_every = 64, 8
    _, small_flat = _batches(n_batches, small_rows)
    small_total = len(small_flat[TENANTS:]) * small_rows
    packed_ingest_s, _, _, _ = _drive_cluster(
        2, names, small_flat, None, publish_every=publish_every
    )
    serial_ingest_s, _, _, _ = _drive_cluster(
        2, names, small_flat, None, packed=False, publish_every=publish_every
    )
    packed_rows_per_s = small_total / packed_ingest_s
    serial_rows_per_s = small_total / serial_ingest_s
    ingest_packed_speedup = packed_rows_per_s / serial_rows_per_s
    emit(f"cluster/cells=2/ingest_packed/rows={small_rows}",
         packed_ingest_s * 1e6, f"rows_per_s={packed_rows_per_s:.0f}")
    emit(f"cluster/cells=2/ingest_serial/rows={small_rows}",
         serial_ingest_s * 1e6, f"rows_per_s={serial_rows_per_s:.0f}")
    emit("cluster/ingest_speedup_packed_vs_serial", 0.0,
         f"x{ingest_packed_speedup:.2f}")

    cache = _replica_hit_rate(names, flat)
    emit("cluster/replica_cache", 0.0, f"hit_rate={cache['hit_rate']:.2f}")

    out = {
        "workload": {
            "tenants": TENANTS,
            "d": D,
            "rows_per_batch": rows,
            "timed_batches": len(flat) - TENANTS,
            "query_tenants": len(queries),
        },
        "single_pipeline": {
            "ingest_rows_per_s": total_rows / single_ingest,
            "query_batches_per_s": len(queries) / single_query,
        },
        "by_cells": by_cells,
        "router_overhead_vs_single": {
            "ingest": router_overhead_ingest,
            "query": router_overhead_query,
        },
        "ingest_rows_per_sec_2_cells": {
            "rows_per_batch": small_rows,
            "packed": packed_rows_per_s,
            "per_tenant_serial": serial_rows_per_s,
        },
        "ingest_speedup_packed_vs_serial": ingest_packed_speedup,
        "replica_cache": cache,
        # Registry snapshot from the largest timed cluster (the last
        # CELLS entry driven above).
        "obs": obs_snap,
    }
    path = os.path.join(os.getcwd(), "BENCH_cluster_scaling.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"# wrote {path}", flush=True)


if __name__ == "__main__":
    run()
