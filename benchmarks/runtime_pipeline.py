"""Streaming runtime throughput: packed cross-tenant serving vs per-tenant
serial dispatch, plus publish latency.

Drives a ``StreamingPipeline`` with many tenants end to end — policy-driven
ingest→publish, then a query storm served two ways:

  * serial — one ``quadform`` engine call per tenant (T kernel dispatches),
  * packed — one ``query_packed`` call for all tenants whose sketches share
    (l, d) (a single ``quadform_packed`` launch).

This is the heavy multi-user regime the runtime layer exists for: many
tenants, modest per-tenant batches, where per-dispatch overhead dominates.
Emits CSV rows and writes ``BENCH_runtime_pipeline.json`` with packed /
serial queries-per-sec, their speedup, and mean publish latency.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import emit, scale
from repro.data.synthetic import lowrank_stream

TENANTS = 8
QUERIES_PER_TENANT = 64
D, EPS = 128, 0.2
ITERS = 10


def run() -> None:
    import jax
    import jax.numpy as jnp

    from repro.query.engine import PackedRequest
    from repro.runtime import EveryKSteps, StreamingPipeline

    n = max(512, int(4096 * scale()))
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))
    pipe = StreamingPipeline(mesh, eps=EPS, policy=EveryKSteps(2))
    streams = {
        f"tenant-{t}": lowrank_stream(n, D, rank=4 + t % 3, seed=t)
        for t in range(TENANTS)
    }
    for tenant in streams:
        pipe.add_tenant(tenant, D)

    batch = max(128, n // 8)
    for tenant, a in streams.items():
        for i in range(0, n, batch):
            pipe.ingest(tenant, jnp.asarray(a[i : i + batch]))

    publishes = sum(pipe.stats(t).publishes for t in pipe.tenants())
    publish_mean_s = pipe.publish_latency_s() / max(publishes, 1)
    emit(
        f"runtime/publish/tenants={TENANTS}",
        publish_mean_s * 1e6,
        f"publishes={publishes}",
    )

    rng = np.random.default_rng(99)
    xs = {
        tenant: (lambda x: x / np.linalg.norm(x, axis=1, keepdims=True))(
            rng.normal(size=(QUERIES_PER_TENANT, D)).astype(np.float32)
        )
        for tenant in streams
    }
    engine = pipe.engine
    requests = [PackedRequest(tenant, x) for tenant, x in xs.items()]
    total_q = TENANTS * QUERIES_PER_TENANT

    # Warm both paths (jit compile + store reads), then verify equivalence.
    packed = engine.query_packed(requests)
    serial = [engine.query_batch(x, tenant=t, path="pallas") for t, x in xs.items()]
    for p, s in zip(packed, serial):
        np.testing.assert_allclose(p.estimates, s.estimates, rtol=1e-5)

    t0 = time.perf_counter()
    for _ in range(ITERS):
        engine.query_packed(requests)
    packed_s = (time.perf_counter() - t0) / ITERS

    t0 = time.perf_counter()
    for _ in range(ITERS):
        for tenant, x in xs.items():
            engine.query_batch(x, tenant=tenant, path="pallas")
    serial_s = (time.perf_counter() - t0) / ITERS

    packed_qps = total_q / packed_s
    serial_qps = total_q / serial_s
    speedup = packed_qps / serial_qps
    emit(f"runtime/serve_serial/q={total_q}", serial_s / total_q * 1e6, f"qps={serial_qps:.0f}")
    emit(f"runtime/serve_packed/q={total_q}", packed_s / total_q * 1e6, f"qps={packed_qps:.0f}")
    emit("runtime/speedup_packed_vs_serial", 0.0, f"x{speedup:.2f}")

    out = {
        "tenants": TENANTS,
        "queries_per_tenant": QUERIES_PER_TENANT,
        "sketch": {"d": D, "eps": EPS, "rows_streamed_per_tenant": n},
        "publishes": publishes,
        "publish_latency_s_mean": publish_mean_s,
        "queries_per_sec": {"packed": packed_qps, "per_tenant_serial": serial_qps},
        "speedup_packed_vs_serial": speedup,
    }
    path = os.path.join(os.getcwd(), "BENCH_runtime_pipeline.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
