"""Streaming runtime throughput: packed cross-tenant ingest and serving vs
per-tenant serial dispatch, plus publish latency.

Drives a ``StreamingPipeline`` with many tenants end to end — policy-driven
ingest→publish, then a query storm served two ways:

  * serial — one ``quadform`` engine call per tenant (T kernel dispatches),
  * packed — one ``query_packed`` call for all tenants whose sketches share
    (l, d) (a single ``quadform_packed`` launch),

and an ingest shootout over the same fleet shape:

  * serial — one protocol super-step per tenant per wave (T dispatches),
  * packed — ``ingest_many`` stacking the wave into ONE
    ``dist.make_packed_runner`` launch whose stacked state stays resident
    between waves.

This is the heavy multi-user regime the runtime layer exists for: many
tenants, modest per-tenant batches, where per-dispatch overhead dominates.
Emits CSV rows and writes ``BENCH_runtime_pipeline.json`` with packed /
serial queries-per-sec and ingest rows-per-sec, their speedups, and mean
publish latency.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import emit, obs_block, scale
from repro.data.synthetic import lowrank_stream

TENANTS = 8
QUERIES_PER_TENANT = 64
D, EPS = 128, 0.2
ITERS = 10
INGEST_BATCH = 64  # modest per-tenant rows/wave: the dispatch-bound regime
INGEST_WAVES = 30


def _ingest_shootout(mesh) -> dict:
    """Packed vs serial ingest over TENANTS same-shape P2 tenants.

    Fresh fleet per path; two warm waves first (the packed path compiles
    ``from_states`` on wave one and the steady resident-stack program on
    wave two), then ``INGEST_WAVES`` timed waves.  Returns the BENCH dict
    with rows/sec both ways plus the pipeline's own ingest counters.
    """
    import numpy as np

    from repro.runtime import OnDemand, StreamingPipeline

    rng = np.random.default_rng(7)
    data = [
        rng.normal(size=(INGEST_BATCH, D)).astype(np.float32)
        for _ in range(TENANTS)
    ]
    wave = [(f"t{i}", data[i]) for i in range(TENANTS)]
    out: dict = {}
    for packed in (True, False):
        pipe = StreamingPipeline(mesh, eps=EPS, policy=OnDemand())
        for i in range(TENANTS):
            pipe.add_tenant(f"t{i}", D, protocol="P2")
        pipe.ingest_many(wave, packed=packed)
        pipe.ingest_many(wave, packed=packed)
        t0 = time.perf_counter()
        for _ in range(INGEST_WAVES):
            pipe.ingest_many(wave, packed=packed)
        dt = time.perf_counter() - t0
        rows = INGEST_WAVES * TENANTS * INGEST_BATCH
        key = "packed" if packed else "per_tenant_serial"
        out[key] = rows / dt
        if packed:
            s = pipe.stats()
            out["packed_counters"] = {
                k: s[k]
                for k in (
                    "packed_launches",
                    "restacks",
                    "retraces",
                    "pack_occupancy",
                    "shrink_launches",
                )
            }
        pipe.close()
    return out


def run() -> None:
    import jax
    import jax.numpy as jnp

    from repro.query.engine import PackedRequest
    from repro.runtime import EveryKSteps, StreamingPipeline

    n = max(512, int(4096 * scale()))
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))
    pipe = StreamingPipeline(mesh, eps=EPS, policy=EveryKSteps(2))
    streams = {
        f"tenant-{t}": lowrank_stream(n, D, rank=4 + t % 3, seed=t)
        for t in range(TENANTS)
    }
    for tenant in streams:
        pipe.add_tenant(tenant, D)

    batch = max(128, n // 8)
    for tenant, a in streams.items():
        for i in range(0, n, batch):
            pipe.ingest(tenant, jnp.asarray(a[i : i + batch]))

    publishes = sum(pipe.stats(t).publishes for t in pipe.tenants())
    publish_mean_s = pipe.publish_latency_s() / max(publishes, 1)
    emit(
        f"runtime/publish/tenants={TENANTS}",
        publish_mean_s * 1e6,
        f"publishes={publishes}",
    )

    rng = np.random.default_rng(99)
    xs = {
        tenant: (lambda x: x / np.linalg.norm(x, axis=1, keepdims=True))(
            rng.normal(size=(QUERIES_PER_TENANT, D)).astype(np.float32)
        )
        for tenant in streams
    }
    engine = pipe.engine
    requests = [PackedRequest(tenant, x) for tenant, x in xs.items()]
    total_q = TENANTS * QUERIES_PER_TENANT

    # Warm both paths (jit compile + store reads), then verify equivalence.
    packed = engine.query_packed(requests)
    serial = [engine.query_batch(x, tenant=t, path="pallas") for t, x in xs.items()]
    for p, s in zip(packed, serial):
        np.testing.assert_allclose(p.estimates, s.estimates, rtol=1e-5)

    t0 = time.perf_counter()
    for _ in range(ITERS):
        engine.query_packed(requests)
    packed_s = (time.perf_counter() - t0) / ITERS

    t0 = time.perf_counter()
    for _ in range(ITERS):
        for tenant, x in xs.items():
            engine.query_batch(x, tenant=tenant, path="pallas")
    serial_s = (time.perf_counter() - t0) / ITERS

    packed_qps = total_q / packed_s
    serial_qps = total_q / serial_s
    speedup = packed_qps / serial_qps
    emit(f"runtime/serve_serial/q={total_q}", serial_s / total_q * 1e6, f"qps={serial_qps:.0f}")
    emit(f"runtime/serve_packed/q={total_q}", packed_s / total_q * 1e6, f"qps={packed_qps:.0f}")
    emit("runtime/speedup_packed_vs_serial", 0.0, f"x{speedup:.2f}")

    ingest = _ingest_shootout(mesh)
    ingest_speedup = ingest["packed"] / ingest["per_tenant_serial"]
    emit(
        f"runtime/ingest_serial/t={TENANTS}",
        1e6 / ingest["per_tenant_serial"],
        f"rows_per_sec={ingest['per_tenant_serial']:.0f}",
    )
    emit(
        f"runtime/ingest_packed/t={TENANTS}",
        1e6 / ingest["packed"],
        f"rows_per_sec={ingest['packed']:.0f}",
    )
    emit("runtime/ingest_speedup_packed_vs_serial", 0.0, f"x{ingest_speedup:.2f}")

    out = {
        "tenants": TENANTS,
        "queries_per_tenant": QUERIES_PER_TENANT,
        "sketch": {"d": D, "eps": EPS, "rows_streamed_per_tenant": n},
        "publishes": publishes,
        "publish_latency_s_mean": publish_mean_s,
        "queries_per_sec": {"packed": packed_qps, "per_tenant_serial": serial_qps},
        "speedup_packed_vs_serial": speedup,
        "ingest": {
            "rows_per_wave": INGEST_BATCH,
            "waves": INGEST_WAVES,
            "counters": ingest["packed_counters"],
        },
        "ingest_rows_per_sec": {
            "packed": ingest["packed"],
            "per_tenant_serial": ingest["per_tenant_serial"],
        },
        "ingest_speedup_packed_vs_serial": ingest_speedup,
        "obs": obs_block(pipe.obs),
    }
    path = os.path.join(os.getcwd(), "BENCH_runtime_pipeline.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
