"""Beyond-paper: FD gradient compression — communication vs gradient quality.

Single-host (m=1 psum) evaluation of the compressor math: bytes moved vs a
dense all-reduce and cosine similarity of the decompressed gradient, across
ranks.  The multi-device training-convergence check lives in
tests/test_train.py (subprocess, 8 devices).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timed
from repro.core.fd import fd_init, fd_matrix, fd_update_stream


def run() -> None:
    rng = np.random.default_rng(0)
    d_in, d_out = 1024, 1024
    # gradient with decaying spectrum (what error feedback assumes)
    u = rng.normal(size=(d_in, 32)) * (np.arange(32, 0, -1) ** 1.5)
    g = (u @ rng.normal(size=(32, d_out))).astype(np.float32)
    g /= np.linalg.norm(g)

    for rank, l in [(4, 8), (8, 16), (16, 32), (32, 64)]:
        def compress():
            st = fd_update_stream(fd_init(l, d_out), jnp.asarray(g))
            b = np.asarray(fd_matrix(st))
            norms = np.linalg.norm(b, axis=1, keepdims=True)
            v = (b / np.maximum(norms, 1e-12))[:rank]
            p = g @ v.T
            return p @ v

        ghat, us = timed(compress)
        cos = float(np.sum(g * ghat) / (np.linalg.norm(g) * np.linalg.norm(ghat) + 1e-12))
        full = 4 * d_in * d_out
        comp = 4 * (l * d_out + d_in * rank)
        emit(
            f"gradcomp/rank={rank}",
            us,
            f"cos={cos:.4f};bytes_full={full};bytes_comp={comp};ratio={full/comp:.1f}",
        )
