"""Paper Figure 4: msg-vs-err tradeoff — tune eps per protocol, report the
frontier.  P1 should win the low-err/high-msg regime; P2/P3 the low-msg one.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, scale, timed
from repro.core.protocols import run_matrix_protocol
from repro.data.synthetic import pamap_like, site_assignment


def run() -> None:
    n = int(100_000 * scale())
    m = 50
    a = pamap_like(n, seed=31)
    sites = site_assignment(n, m, seed=31)
    ata = a.T @ a
    frob = float(np.sum(a * a))
    grid = {
        "P1": [0.5, 0.2, 0.1],
        "P2": [0.5, 0.1, 0.02],
        "P3": [0.5, 0.1, 0.02],
    }
    for proto, epss in grid.items():
        for eps in epss:
            res, us = timed(run_matrix_protocol, proto, a, sites, m, eps, seed=1)
            emit(
                f"matrix/fig4/{proto}/eps={eps:g}",
                us,
                f"err={res.covariance_error(ata, frob):.3e};msg={res.comm.total(m)}",
            )
