"""Paper Figures 6/7 (Appendix C): the matrix-P4 negative result.

P4's fixed-basis probabilistic updates must show err far above eps and far
above P2/P3 at comparable (or even larger) message budgets — on both the
low-rank (PAMAP-like) and high-rank (MSD-like) streams, for both the
'fixed' (Algorithm C.1 verbatim) and 'resvd' (charitable) variants.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, scale, timed
from repro.core.protocols import run_matrix_protocol
from repro.data.synthetic import msd_like, pamap_like, site_assignment


def run() -> None:
    n = int(60_000 * scale())
    m = 50
    for ds, gen in [("pamap", pamap_like), ("msd", msd_like)]:
        a = gen(n, seed=41)
        sites = site_assignment(n, m, seed=41)
        ata = a.T @ a
        frob = float(np.sum(a * a))
        for eps in [0.05, 0.1, 0.5]:
            for proto, kw in [("P2", {}), ("P3", {}), ("P4", {"variant": "fixed"}), ("P4", {"variant": "resvd"})]:
                res, us = timed(run_matrix_protocol, proto, a, sites, m, eps, seed=1, **kw)
                tag = proto + (f"-{kw['variant']}" if kw else "")
                emit(
                    f"matrix/fig67/{ds}/{tag}/eps={eps:g}",
                    us,
                    f"err={res.covariance_error(ata, frob):.3e};msg={res.comm.total(m)}",
                )
