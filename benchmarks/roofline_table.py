"""Deliverable (g): render the roofline table from dry-run JSON records.

Reads experiments/dryrun/*.json (written by repro.launch.dryrun --all --out)
and emits one CSV row per (arch x shape x mesh) with the three roofline
terms, the dominant bottleneck, and MODEL_FLOPS / HLO_FLOPs.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit

DRYRUN_DIR = os.environ.get("DRYRUN_DIR", "experiments/dryrun")


def run() -> None:
    files = sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json")))
    if not files:
        emit("roofline/none", 0.0, f"no dry-run records in {DRYRUN_DIR}; run repro.launch.dryrun --all first")
        return
    for fn in files:
        with open(fn) as f:
            rec = json.load(f)
        if rec.get("status") != "ok" or rec.get("tag"):
            continue
        r = rec["roofline"]
        emit(
            f"roofline/{rec['arch']}/{rec['shape']}/{rec['mesh']}",
            r["compute_s"] * 1e6,  # us_per_call = roofline compute term
            (
                f"compute_s={r['compute_s']:.4e};memory_s={r['memory_s']:.4e};"
                f"collective_s={r['collective_s']:.4e};dominant={r['dominant']};"
                f"useful_flops_ratio={r['useful_flops_ratio']:.3f};"
                f"mfu_at_roofline={r['mfu_at_roofline']:.4f};"
                f"mem_gib={rec['memory']['total_bytes_per_device']/2**30:.2f}"
            ),
        )
