"""Roofline accounting: HLO collective parser + three-term report."""

from repro.configs.registry import SHAPES, get_config
from repro.roofline.analysis import (
    RooflineReport,
    model_flops,
    parse_collective_bytes,
)

HLO = """
HloModule jit_step
ENTRY main {
  %p = f32[128,256]{1,0} parameter(0)
  %ag = f32[2048,256]{1,0} all-gather(%p), replica_groups=[16,16]<=[256], dimensions={0}
  %ar = bf16[1024]{0} all-reduce(%x), replica_groups=[128,2]<=[256], to_apply=%add
  %rs = f32[64,64]{1,0} reduce-scatter(%y), replica_groups=[16,16]<=[256], dimensions={0}
  %a2a = f32[512]{0} all-to-all(%z), replica_groups={{0,1,2,3}}, dimensions={0}
  %cp = f32[256]{0} collective-permute(%w), source_target_pairs={{0,1}}
  %ags = f32[100]{0} all-gather-start(%q), replica_groups=[2,8]<=[16], dimensions={0}
  %agd = f32[100]{0} all-gather-done(%ags)
  %dot = f32[32,32]{1,0} dot(%a, %b)
}
"""


def test_parser_kinds_and_ring_model():
    cb = parse_collective_bytes(HLO)
    # all-gather: R(2048*256*4) * 15/16 + start-form R(400)*7/8
    ag = 2048 * 256 * 4 * 15 / 16 + 400 * 7 / 8
    assert abs(cb.by_kind["all-gather"] - int(ag)) <= 2
    # all-reduce: 2R * (S-1)/S with S=2
    assert cb.by_kind["all-reduce"] == int(2 * 1024 * 2 * 1 / 2)
    # reduce-scatter: R * (S-1), S=16
    assert cb.by_kind["reduce-scatter"] == 64 * 64 * 4 * 15
    # all-to-all: explicit group of 4
    assert cb.by_kind["all-to-all"] == int(512 * 4 * 3 / 4)
    assert cb.by_kind["collective-permute"] == 256 * 4
    assert cb.total == sum(cb.by_kind.values())


def test_parser_ignores_done_and_noncollectives():
    cb = parse_collective_bytes(HLO)
    assert len(cb.by_kind) == 5  # no dot, no all-gather-done double count


def test_report_terms_and_dominance():
    r = RooflineReport(
        arch="x", shape="train_4k", mesh="16x16", chips=256,
        flops_per_device=197e12 * 0.1,  # 100 ms compute
        bytes_per_device=819e9 * 0.05,  # 50 ms memory
        collective_bytes_per_device=50e9 * 0.2,  # 200 ms collective
        collective_by_kind={}, model_flops_global=197e12 * 0.1 * 256 * 0.5,
    )
    assert abs(r.compute_s - 0.1) < 1e-9
    assert abs(r.memory_s - 0.05) < 1e-9
    assert abs(r.collective_s - 0.2) < 1e-9
    assert r.dominant == "collective"
    assert abs(r.useful_flops_ratio - 0.5) < 1e-9
    assert 0 < r.mfu < 1


def test_model_flops_train_vs_decode():
    cfg = get_config("smollm-135m")
    t = model_flops(cfg, SHAPES["train_4k"])
    d = model_flops(cfg, SHAPES["decode_32k"])
    n = cfg.param_count()
    assert t == 6.0 * n * 256 * 4095
    assert d == 2.0 * n * 128


def test_moe_model_flops_use_active_params():
    cfg = get_config("qwen3-moe-235b-a22b")
    f = model_flops(cfg, SHAPES["train_4k"])
    assert f < 6.0 * cfg.param_count() * 256 * 4095
    assert f == 6.0 * cfg.active_param_count() * 256 * 4095
