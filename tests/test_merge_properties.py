"""Property tests for the four merge identities the cluster leans on.

``fd_merge`` / ``mg_merge`` / ``quant_merge`` / ``lev_merge`` are the
algebra behind every distributed path in this repo: protocol round
collection, tenant export/import, and — since the transport landed —
crash-restart replay, which silently assumes that re-merging a replayed
shard cannot change the served answer.  Three laws per kind:

  * commutativity up to the served answer — merge(a, b) and merge(b, a)
    may differ in representation (row order inside the shrink, tuple
    layout) but must serve the same answers within the certified band;
  * identity-element absorption — merging with the empty/all-pad state
    is a no-op (bit-identical where the representation is canonical);
  * merge-of-splits == merge-of-stream — a stream split across shards
    and merged serves within the same certified envelope as the
    unsplit stream, with mass/weight/count conservation *exact*.

Each law runs under hypothesis when installed and over a seeded numpy
sweep otherwise (see ``conftest.run_property``) — never skipped.
"""
import jax.numpy as jnp
import numpy as np
from conftest import run_property

from repro.core.fd import fd_init, fd_merge, fd_query, fd_update_stream
from repro.core.hh import mg_init, mg_items, mg_merge, mg_update_stream
from repro.core.leverage import lev_init, lev_merge, lev_merge_spill
from repro.core.quantiles import (
    exact_ranks,
    quant_band,
    quant_init,
    quant_insert,
    quant_merge,
    quant_table,
    table_rank,
)

try:  # hypothesis is a test extra; the seeded sweeps below cover its absence
    import hypothesis.extra.numpy as hnp
    import hypothesis.strategies as st
except ModuleNotFoundError:
    st = None


# ---------------------------------------------------------------------------
# fd_merge
# ---------------------------------------------------------------------------


# Shapes draw from small fixed sets so jit-compiled helpers hit their
# shape caches — the value distribution stays wide, the compile count small.
_FD_NS, _FD_DS, _FD_L = (5, 17, 32), (4, 8), 4


def _fd_cases(n_cases):
    rng = np.random.default_rng(7)
    for _ in range(n_cases):
        d = int(rng.choice(_FD_DS))
        na, nb = int(rng.choice(_FD_NS)), int(rng.choice(_FD_NS))
        yield {
            "a": rng.normal(size=(na, d)).astype(np.float32),
            "b": rng.normal(size=(nb, d)).astype(np.float32),
            "l": _FD_L,
        }


def _fd_given():
    def mat():
        return hnp.arrays(
            np.float32,
            st.tuples(st.sampled_from(_FD_NS), st.shared(st.sampled_from(_FD_DS), key="d")),
            elements=st.floats(-3, 3, width=32),
        )

    return {"a": mat(), "b": mat(), "l": st.just(_FD_L)}


def test_fd_merge_commutes_up_to_served_answer():
    """merge(a,b) and merge(b,a) serve ||Bx||^2 within fp tolerance of
    each other and keep identical mass/count/error accounting."""

    def check(a, b, l):
        d = a.shape[1]
        sa = fd_update_stream(fd_init(l, d), jnp.asarray(a))
        sb = fd_update_stream(fd_init(l, d), jnp.asarray(b))
        ab, ba = fd_merge(sa, sb), fd_merge(sb, sa)
        assert float(ab.frob) == float(ba.frob)  # f32 addition commutes
        assert int(ab.n_seen) == int(ba.n_seen)
        frob = float(np.sum(a.astype(np.float64) ** 2) + np.sum(b.astype(np.float64) ** 2))
        x = jnp.eye(d, dtype=jnp.float32)  # all coordinate directions at once
        qa = np.asarray(fd_query(ab, x.T))
        qb = np.asarray(fd_query(ba, x.T))
        np.testing.assert_allclose(qa, qb, atol=1e-3 * max(frob, 1.0) + 1e-5)

    run_property(check, given=_fd_given, cases=_fd_cases(25), max_examples=25)


def test_fd_merge_identity_absorption():
    """Merging with the empty sketch changes nothing the query can see."""

    def check(a, b, l):
        del b
        d = a.shape[1]
        sa = fd_update_stream(fd_init(l, d), jnp.asarray(a))
        for merged in (fd_merge(sa, fd_init(l, d)), fd_merge(fd_init(l, d), sa)):
            assert float(merged.frob) == float(sa.frob)
            assert int(merged.n_seen) == int(sa.n_seen)
            x = jnp.eye(d, dtype=jnp.float32)
            qa = np.asarray(fd_query(sa, x.T))
            qm = np.asarray(fd_query(merged, x.T))
            np.testing.assert_allclose(
                qm, qa, atol=1e-4 * max(float(sa.frob), 1.0) + 1e-6
            )

    run_property(check, given=_fd_given, cases=_fd_cases(15), max_examples=15)


def test_fd_merge_of_splits_matches_stream_envelope():
    """Split-then-merge conserves mass/count exactly and keeps the FD
    guarantee ``0 <= ||Ax||^2 - ||Bx||^2 <= delta_sum`` of the full stream."""

    def check(a, b, l):
        d = a.shape[1]
        full = np.concatenate([a, b], axis=0)
        merged = fd_merge(
            fd_update_stream(fd_init(l, d), jnp.asarray(a)),
            fd_update_stream(fd_init(l, d), jnp.asarray(b)),
        )
        frob = float(np.sum(full.astype(np.float64) ** 2))
        assert int(merged.n_seen) == full.shape[0]
        assert abs(float(merged.frob) - frob) <= 1e-3 * frob + 1e-4
        x = jnp.asarray(np.ones(d, np.float32) / np.sqrt(d))
        ax = float(np.sum((full.astype(np.float64) @ np.asarray(x)) ** 2))
        bx = float(fd_query(merged, x))
        slack = 1e-3 * max(frob, 1.0) + 1e-4
        assert ax - bx >= -slack  # underestimate (shrink only subtracts)
        assert ax - bx <= float(merged.delta_sum) + slack  # certified deficit

    run_property(check, given=_fd_given, cases=_fd_cases(25), max_examples=25)


# ---------------------------------------------------------------------------
# mg_merge
# ---------------------------------------------------------------------------


_MG_NS, _MG_KS = (40, 90), (6, 12)


def _mg_cases(n_cases):
    rng = np.random.default_rng(11)
    for _ in range(n_cases):
        na, nb = int(rng.choice(_MG_NS)), int(rng.choice(_MG_NS))
        yield {
            "ka": rng.integers(0, 25, na).tolist(),
            "wa": rng.uniform(0.5, 10.0, na).tolist(),
            "kb": rng.integers(0, 25, nb).tolist(),
            "wb": rng.uniform(0.5, 10.0, nb).tolist(),
            "k": int(rng.choice(_MG_KS)),
        }


def _mg_given():
    def stream(key):
        keys = st.shared(
            st.sampled_from(_MG_NS).flatmap(
                lambda n: st.lists(st.integers(0, 24), min_size=n, max_size=n)
            ),
            key=key,
        )
        weights = keys.flatmap(
            lambda ks: st.lists(st.floats(0.5, 10.0), min_size=len(ks), max_size=len(ks))
        )
        return keys, weights

    ka, wa = stream("mg-a")
    kb, wb = stream("mg-b")
    return {"ka": ka, "wa": wa, "kb": kb, "wb": wb, "k": st.sampled_from(_MG_KS)}


def _mg_build(keys, weights, k):
    return mg_update_stream(
        mg_init(k), jnp.asarray(keys, jnp.int32), jnp.asarray(weights, jnp.float32)
    )


def test_mg_merge_commutes_up_to_served_answer():
    """Both merge orders estimate every element identically (weight too)."""

    def check(ka, wa, kb, wb, k):
        sa, sb = _mg_build(ka, wa, k), _mg_build(kb, wb, k)
        ab, ba = mg_merge(sa, sb), mg_merge(sb, sa)
        assert float(ab.weight) == float(ba.weight)
        ia, ib = mg_items(ab), mg_items(ba)
        for e in set(ia) | set(ib):
            assert abs(ia.get(e, 0.0) - ib.get(e, 0.0)) <= 1e-3

    run_property(check, given=_mg_given, cases=_mg_cases(30), max_examples=30)


def test_mg_merge_identity_absorption():
    """The empty MG summary is a two-sided identity, bit-identically."""

    def check(ka, wa, kb, wb, k):
        del kb, wb
        sa = _mg_build(ka, wa, k)
        for merged in (mg_merge(sa, mg_init(k)), mg_merge(mg_init(k), sa)):
            assert float(merged.weight) == float(sa.weight)
            assert float(merged.shrink) == float(sa.shrink)
            assert mg_items(merged) == mg_items(sa)

    run_property(check, given=_mg_given, cases=_mg_cases(20), max_examples=20)


def test_mg_merge_of_splits_keeps_the_mg_guarantee():
    """Merged split streams underestimate, with deficit <= 2W/(k+1)
    (each half contributes a W_i/(k+1) term and the merge adds its own)."""

    def check(ka, wa, kb, wb, k):
        merged = mg_merge(_mg_build(ka, wa, k), _mg_build(kb, wb, k))
        totals: dict[int, float] = {}
        for e, w in zip(list(ka) + list(kb), list(wa) + list(wb)):
            totals[e] = totals.get(e, 0.0) + float(w)
        W = sum(totals.values())
        assert abs(float(merged.weight) - W) <= 1e-3 * W + 1e-3
        items = mg_items(merged)
        for e, true in totals.items():
            est = items.get(e, 0.0)
            assert est <= true + 1e-2
            assert true - est <= 2.0 * W / (k + 1) + 1e-2
        assert -1 not in items  # the EMPTY pad key never surfaces

    run_property(check, given=_mg_given, cases=_mg_cases(30), max_examples=30)


# ---------------------------------------------------------------------------
# quant_merge
# ---------------------------------------------------------------------------


_QU_NS, _QU_EPS = (30, 110), (0.1, 0.2)


def _quant_cases(n_cases):
    rng = np.random.default_rng(13)
    for _ in range(n_cases):
        na, nb = int(rng.choice(_QU_NS)), int(rng.choice(_QU_NS))
        yield {
            "va": rng.normal(scale=100.0, size=na).astype(np.float32).tolist(),
            "vb": rng.normal(scale=100.0, size=nb).astype(np.float32).tolist(),
            "eps": float(rng.choice(_QU_EPS)),
        }


def _quant_given():
    def vals():
        return st.sampled_from(_QU_NS).flatmap(
            lambda n: st.lists(st.floats(-1e4, 1e4, width=32), min_size=n, max_size=n)
        )

    return {"va": vals(), "vb": vals(), "eps": st.sampled_from(_QU_EPS)}


def _quant_build(vals, eps, cap):
    return quant_insert(
        quant_init(cap), np.asarray(vals, np.float32), np.ones(len(vals), np.float32), eps
    )


def test_quant_merge_commutes_up_to_served_answer():
    """Both merge orders serve ranks within their combined certified bands."""

    def check(va, vb, eps):
        cap = int(np.ceil(2.0 / eps)) + 4
        sa, sb = _quant_build(va, eps, cap), _quant_build(vb, eps, cap)
        ab, ba = quant_merge(sa, sb, eps, cap), quant_merge(sb, sa, eps, cap)
        assert float(ab.weight) == float(ba.weight)
        W = float(ab.weight)
        probes = np.percentile(np.asarray(list(va) + list(vb)), [5, 25, 50, 75, 95])
        ra = table_rank(quant_table(ab), probes)
        rb = table_rank(quant_table(ba), probes)
        budget = quant_band(ab) + quant_band(ba) + 1e-3 * W + 1e-4
        assert np.max(np.abs(ra.astype(np.float64) - rb.astype(np.float64))) <= budget

    run_property(check, given=_quant_given, cases=_quant_cases(25), max_examples=25)


def test_quant_merge_identity_absorption():
    """Merging with the all-pad summary preserves weight, band, and ranks."""

    def check(va, vb, eps):
        del vb
        cap = int(np.ceil(2.0 / eps)) + 4
        sa = _quant_build(va, eps, cap)
        W = float(sa.weight)
        probes = np.percentile(np.asarray(va), [10, 50, 90])
        for merged in (
            quant_merge(sa, quant_init(cap), eps, cap),
            quant_merge(quant_init(cap), sa, eps, cap),
        ):
            assert float(merged.weight) == W
            assert quant_band(merged) <= eps * W + 1e-3 * W + 1e-4
            gap = np.abs(
                table_rank(quant_table(merged), probes).astype(np.float64)
                - table_rank(quant_table(sa), probes).astype(np.float64)
            )
            assert np.max(gap) <= quant_band(merged) + quant_band(sa) + 1e-3 * W + 1e-4

    run_property(check, given=_quant_given, cases=_quant_cases(20), max_examples=20)


def test_quant_merge_of_splits_keeps_eps_band():
    """Split-then-merge conserves weight exactly and serves every probe
    within its certified band of the exact ranks — the paper's guarantee."""

    def check(va, vb, eps):
        cap = int(np.ceil(2.0 / eps)) + 4
        merged = quant_merge(
            _quant_build(va, eps, cap), _quant_build(vb, eps, cap), eps, cap
        )
        full = np.asarray(list(va) + list(vb), np.float32)
        W = float(full.shape[0])
        assert float(merged.weight) == W
        band = quant_band(merged)
        assert band <= eps * W + 1e-3 * W + 1e-4
        probes = np.unique(np.percentile(full, [5, 25, 50, 75, 95]))
        served = table_rank(quant_table(merged), probes).astype(np.float64)
        exact = exact_ranks(full, np.ones(full.shape[0], np.float32), probes)
        assert np.max(np.abs(served - exact)) <= band + 1e-3 * W + 1e-4

    run_property(check, given=_quant_given, cases=_quant_cases(25), max_examples=25)


# ---------------------------------------------------------------------------
# lev_merge
# ---------------------------------------------------------------------------


_LEV_NS, _LEV_D, _LEV_CAPS = (4, 9), 5, (6, 12)


def _lev_cases(n_cases):
    rng = np.random.default_rng(17)
    for _ in range(n_cases):
        na, nb = int(rng.choice(_LEV_NS)), int(rng.choice(_LEV_NS))
        # distinct scores across both sides -> top-cap selection is unique
        scores = rng.permutation(np.arange(1, na + nb + 1)).astype(np.float32)
        yield {
            "ra": rng.normal(size=(na, _LEV_D)).astype(np.float32),
            "sa_": scores[:na],
            "rb": rng.normal(size=(nb, _LEV_D)).astype(np.float32),
            "sb_": scores[na:],
            "cap": int(rng.choice(_LEV_CAPS)),
        }


def _lev_build(rows, scores, cap):
    state, _ = lev_merge_spill(
        lev_init(cap, rows.shape[1]),
        jnp.asarray(rows),
        jnp.asarray(scores),
        jnp.ones(rows.shape[0], jnp.float32),
    )
    return state


def _lev_key(state):
    """Canonical (score, weight, row) triples of the live slots, sorted."""
    scores = np.asarray(state.scores)
    live = scores > 0
    order = np.argsort(-scores[live], kind="stable")
    return (
        scores[live][order],
        np.asarray(state.weights)[live][order],
        np.asarray(state.rows)[live][order],
    )


def test_lev_merge_commutes_and_absorbs_identity():
    """With distinct scores both merge orders keep the same top-cap set;
    the all-pad reservoir is a bit-exact identity."""

    def check(ra, sa_, rb, sb_, cap):
        a, b = _lev_build(ra, sa_, cap), _lev_build(rb, sb_, cap)
        ab, ba = lev_merge(a, b), lev_merge(b, a)
        for ka, kb in zip(_lev_key(ab), _lev_key(ba)):
            np.testing.assert_allclose(ka, kb, rtol=1e-6)
        ident = lev_merge(a, lev_init(cap, ra.shape[1]))
        np.testing.assert_array_equal(np.asarray(ident.rows), np.asarray(a.rows))
        np.testing.assert_array_equal(np.asarray(ident.scores), np.asarray(a.scores))
        np.testing.assert_array_equal(np.asarray(ident.weights), np.asarray(a.weights))

    # a coupled construction (disjoint distinct scores) has no clean
    # strategy encoding — the seeded sweep runs under both install modes
    run_property(check, given=None, cases=_lev_cases(25))


def test_lev_merge_of_splits_matches_stream_and_conserves_mass():
    """Split reservoirs merge to the same top-cap set as the unsplit
    stream, and overflow never loses mass (kept + spilled == total)."""

    def check(ra, sa_, rb, sb_, cap):
        d = ra.shape[1]
        merged = lev_merge(_lev_build(ra, sa_, cap), _lev_build(rb, sb_, cap))
        rows = np.concatenate([ra, rb], axis=0)
        scores = np.concatenate([sa_, sb_])
        direct = _lev_build(rows, scores, cap)
        for km, kd in zip(_lev_key(merged), _lev_key(direct)):
            np.testing.assert_allclose(km, kd, rtol=1e-6)
        # spill accounting: row mass in == row mass kept + row mass spilled
        state, spilled = lev_merge_spill(
            lev_init(cap, d),
            jnp.asarray(rows),
            jnp.asarray(scores),
            jnp.ones(rows.shape[0], jnp.float32),
        )
        total = float(np.sum(rows.astype(np.float64) ** 2))
        kept = float(np.sum(np.asarray(state.rows, np.float64) ** 2))
        lost = float(np.sum(np.asarray(spilled, np.float64) ** 2))
        assert abs(total - (kept + lost)) <= 1e-3 * max(total, 1.0)

    run_property(check, given=None, cases=_lev_cases(25))
