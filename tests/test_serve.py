"""Serving: greedy generation consistency + SWA ring-buffer cache."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.transformer import LM
from repro.serve import ServeConfig, ServeEngine


def test_generate_matches_teacher_forced_forward():
    cfg = ModelConfig(
        name="t", family="dense", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab_size=128, dtype="float32", remat="none",
    )
    lm = LM(cfg)
    params = lm.init(jax.random.key(0))
    eng = ServeEngine(lm, params, ServeConfig(max_len=64))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, 128, size=(2, 16)), jnp.int32)
    gen = eng.generate(prompts, 8)
    assert gen.shape == (2, 24)
    # teacher-forced check: feeding gen[:, :k] must greedily predict gen[:, k]
    for k in range(16, 24):
        logits, _ = lm.forward(params, gen[:, :k])
        np.testing.assert_array_equal(
            np.asarray(jnp.argmax(logits[:, -1], -1)), np.asarray(gen[:, k])
        )


def test_swa_ring_cache_matches_full_forward():
    """Windowed decode with an O(window) ring cache must equal the full
    forward — across the wrap-around boundary."""
    cfg = ModelConfig(
        name="t", family="dense", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab_size=64, window=8, layer_pattern=("local",),
        dtype="float32", remat="none",
    )
    lm = LM(cfg)
    params = lm.init(jax.random.key(1))
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, 64, size=(1, 30)), jnp.int32)

    # cache sized far below the sequence: ring must wrap several times
    cache = lm.init_cache(1, 64)
    assert cache[0]["pos0"]["k"].shape[-2] == 8, "ring cache must be window-sized"
    _, cache = lm.prefill(params, toks[:, :12], 64)
    for i in range(12, 30):
        dec, cache = lm.decode_step(params, cache, toks[:, i : i + 1], jnp.asarray(i, jnp.int32))
        full, _ = lm.forward(params, toks[:, : i + 1])
        err = float(jnp.max(jnp.abs(dec[:, 0] - full[:, -1])))
        assert err < 2e-3, (i, err)
