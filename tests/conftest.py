import os
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def run_multidevice(script: str, n_devices: int = 8, timeout: int = 420) -> str:
    """Run a python snippet in a subprocess with n fake CPU devices.

    Multi-device tests must not pollute this process (smoke tests and
    benches are required to see exactly 1 device), so shard_map/mesh tests
    execute out-of-process.
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True,
        text=True,
        timeout=timeout,
        env=env,
    )
    assert proc.returncode == 0, f"subprocess failed:\nSTDOUT:{proc.stdout}\nSTDERR:{proc.stderr}"
    return proc.stdout
