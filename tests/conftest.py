import os
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def run_property(check, *, given, cases, max_examples=100):
    """Run a property check under hypothesis, or over a seeded sweep.

    The tier-1 suite must exercise its property tests on minimal installs
    too (hypothesis is an extra, not a requirement), so every property
    test supplies both halves and runs the SAME ``check`` either way:

    given:        zero-arg callable returning the ``hypothesis.given``
                  strategy dict.  Lazy on purpose — strategies cannot be
                  built when hypothesis is absent.  Pass ``None`` when the
                  case family has no natural strategy encoding (e.g. a
                  coupled random construction): the seeded sweep then runs
                  even when hypothesis is installed.
    cases:        iterable of kwargs dicts — the deterministic fallback
                  sweep (seeded numpy, so failures reproduce exactly).
    max_examples: hypothesis example budget (ignored by the fallback).
    """
    try:
        import hypothesis
    except ModuleNotFoundError:
        hypothesis = None
    if hypothesis is not None and given is not None:
        wrapped = hypothesis.settings(max_examples=max_examples, deadline=None)(
            hypothesis.given(**given())(check)
        )
        wrapped()
        return
    ran = 0
    for kw in cases:
        check(**kw)
        ran += 1
    assert ran > 0, "seeded fallback produced no cases"


def run_multidevice(script: str, n_devices: int = 8, timeout: int = 420) -> str:
    """Run a python snippet in a subprocess with n fake CPU devices.

    Multi-device tests must not pollute this process (smoke tests and
    benches are required to see exactly 1 device), so shard_map/mesh tests
    execute out-of-process.
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True,
        text=True,
        timeout=timeout,
        env=env,
    )
    assert proc.returncode == 0, f"subprocess failed:\nSTDOUT:{proc.stdout}\nSTDERR:{proc.stderr}"
    return proc.stdout
