"""Streaming runtime layer: protocol registry round-trip (matrix and HH),
publish policies, quotas/priorities, cross-tenant packed serving, and
store + pipeline persistence.

The registry tests are deliberately ONE harness per workload kind, driven
over every registered ``ProtocolSpec`` — engine- and protocol-specific
knowledge lives in the specs (err_factor), not in the tests.
"""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # property-based tests skip gracefully on minimal installs
    import hypothesis
    import hypothesis.strategies as st
except ModuleNotFoundError:
    hypothesis = None

from repro.core.comm import CommReport
from repro.core.hh import exact_heavy_hitters
from repro.data.synthetic import lowrank_stream, site_assignment, zipfian_stream
from repro.kernels.ops import quadform, quadform_packed
from repro.kernels.ref import ref_quadform_packed
from repro.query import (
    PackedQueryService,
    PackedRequest,
    QueryEngine,
    QueryShedError,
    SketchStore,
)
from repro.runtime import (
    EveryKSteps,
    FrobDrift,
    OnDemand,
    StreamingPipeline,
    TenantQuota,
    create_protocol,
    policy_from_config,
    policy_to_config,
    specs,
)

N, D, M, EPS = 6000, 24, 4, 0.2


@pytest.fixture(scope="module")
def stream():
    a = lowrank_stream(N, D, seed=0)
    sites = np.random.default_rng(1).integers(0, M, N)
    return a, sites, a.T @ a, float(np.sum(a * a))


@pytest.fixture(scope="module")
def mesh():
    return jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))


# ---------------------------------------------------------------------------
# registry: one eps-guarantee harness for every registered spec
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("spec", specs(kind="matrix"), ids=lambda s: f"{s.engine}-{s.name}")
def test_registry_round_trip_eps_harness(spec, stream, mesh):
    """Every (engine, protocol) pair: stream in batches through the uniform
    interface, then check the covariance guarantee, message accounting,
    frob estimate, and the shared quadform query path."""
    a, sites, ata, frob = stream
    if spec.engine == "event":
        proto = create_protocol(spec.name, engine="event", m=M, eps=EPS, d=D, seed=1)
    else:
        proto = create_protocol(spec.name, engine="shard", mesh=mesh, d=D, eps=EPS, axis="data")
    for i in range(0, N, 1000):
        batch = a[i : i + 1000]
        if spec.engine == "event":
            proto.step(batch, sites[i : i + 1000])
        else:
            proto.step(jnp.asarray(batch))
    assert proto.rows_seen == N

    b = proto.matrix()
    assert b.ndim == 2 and b.shape[1] == D
    err = np.linalg.norm(ata - b.T @ b, 2) / frob
    assert err <= spec.err_factor * EPS + 1e-3, (spec.name, err)

    rep = proto.comm_report()
    assert isinstance(rep, CommReport)
    assert rep.m in (M, 1)
    assert 0 < rep.total < N  # beats shipping the stream

    # frob estimate is a constant-factor tracker of the true stream mass
    assert 0.5 * frob <= proto.frob_estimate() <= 2.0 * frob

    # query() answers through the same quadform kernel path as serving
    x = np.random.default_rng(2).normal(size=D).astype(np.float32)
    x /= np.linalg.norm(x)
    want = float(np.asarray(quadform(jnp.asarray(b, jnp.float32), jnp.asarray(x)[None]))[0])
    assert proto.query(x) == pytest.approx(want, rel=1e-6)


def test_registry_unknown_protocol_raises():
    with pytest.raises(KeyError):
        create_protocol("P9", engine="event", m=2, eps=0.5, d=4)
    with pytest.raises(KeyError):
        create_protocol("P4", engine="event", m=2, eps=0.5, d=4)  # negative result: unregistered
    with pytest.raises(KeyError):
        create_protocol("P9", engine="event", kind="hh", m=2, eps=0.5)


# ---------------------------------------------------------------------------
# registry: one error-bound harness for every registered HH spec
# ---------------------------------------------------------------------------

HH_N, HH_M, HH_EPS, HH_PHI, HH_BETA = 30_000, 8, 0.05, 0.05, 100.0


@pytest.fixture(scope="module")
def hh_stream():
    keys, w = zipfian_stream(HH_N, beta=HH_BETA, universe=3000, seed=5)
    sites = site_assignment(HH_N, HH_M, seed=5)
    truth = exact_heavy_hitters(keys, w, HH_PHI)
    return keys, w, sites, truth


def _make_hh(spec, mesh, **kw):
    if spec.engine == "event":
        return create_protocol(
            spec.name, engine="event", kind="hh", m=HH_M, eps=HH_EPS, seed=1, **kw
        )
    return create_protocol(spec.name, engine="shard", kind="hh", mesh=mesh, eps=HH_EPS)


@pytest.mark.parametrize("spec", specs(kind="hh"), ids=lambda s: f"{s.engine}-{s.name}")
def test_registry_hh_harness(spec, hh_stream, mesh):
    """Every (engine, protocol) HH pair: stream batches through the uniform
    interface, then check the weighted-frequency guarantee, message
    accounting, the total-weight estimate, vectorized lookups, and the
    checkpoint payload round-trip (restore -> identical continued stream)."""
    keys, w, sites, (hh, totals, W) = hh_stream
    proto = _make_hh(spec, mesh)
    pairs = np.stack([keys.astype(np.float64), w], axis=1)
    for i in range(0, HH_N, 10_000):
        if spec.engine == "event":
            proto.step(pairs[i : i + 10_000], sites[i : i + 10_000])
        else:
            proto.step(pairs[i : i + 10_000])
    assert proto.rows_seen == HH_N

    est = proto.estimates()
    worst = max(abs(totals[e] - est.get(e, 0.0)) / W for e in totals)
    assert worst <= spec.err_factor * HH_EPS + 1e-6, (spec.name, worst)

    # total-weight estimate tracks the true stream weight
    assert 0.4 * W <= proto.total_weight() <= 2.5 * W

    rep = proto.comm_report()
    assert isinstance(rep, CommReport)
    assert rep.total > 0
    if spec.name != "P3wr":  # P3wr's s samplers only beat N on long streams
        assert rep.total < HH_N  # beats shipping the stream

    # vectorized lookups agree with the estimate map
    probe = np.array(sorted(totals)[:50])
    np.testing.assert_allclose(
        proto.estimate(probe),
        np.array([est.get(int(e), 0.0) for e in probe], np.float32),
    )

    # checkpoint round-trip: a fresh protocol restored from the payload
    # continues the stream identically (the pipeline-restart contract)
    arrays, meta = proto.state_payload()
    clone = _make_hh(spec, mesh)
    clone.restore_payload({k: np.asarray(v) for k, v in arrays.items()}, meta)
    tail = pairs[:5_000]
    if spec.engine == "event":
        proto.step(tail, sites[:5_000])
        clone.step(tail, sites[:5_000])
    else:
        proto.step(tail)
        clone.step(tail)
    assert proto.estimates() == clone.estimates()
    assert proto.total_weight() == clone.total_weight()
    assert proto.comm_report() == clone.comm_report()


@pytest.mark.parametrize(
    "spec", specs(engine="shard", kind="matrix"), ids=lambda s: s.name
)
def test_shard_matrix_state_round_trip(spec, stream, mesh):
    """Every shard matrix protocol honors the checkpoint contract: a fresh
    protocol restored from state_payload continues the stream identically
    (incl. P3's per-site PRNG keys, rewrapped from raw key data)."""
    a, _, _, _ = stream
    proto = create_protocol(spec.name, engine="shard", mesh=mesh, d=D, eps=EPS)
    proto.step(jnp.asarray(a[:2000]))
    arrays, meta = proto.state_payload()
    clone = create_protocol(spec.name, engine="shard", mesh=mesh, d=D, eps=EPS)
    clone.restore_payload({k: np.asarray(v) for k, v in arrays.items()}, meta)
    proto.step(jnp.asarray(a[2000:4000]))
    clone.step(jnp.asarray(a[2000:4000]))
    np.testing.assert_array_equal(proto.matrix(), clone.matrix())
    assert proto.comm_report() == clone.comm_report()
    assert proto.frob_estimate() == clone.frob_estimate()


def test_hh_rejects_out_of_range_and_malformed_ingest(mesh):
    """Element ids outside [0, 2**24) are rejected at the ingest seam:
    negative ids collide with the MG empty-slot sentinel in the shard
    engine, larger ones don't survive the f32 snapshot encoding (and a
    policy-driven publish failing later would wedge the tenant)."""
    for engine in ("event", "shard"):
        kw = {"m": 2} if engine == "event" else {"mesh": mesh}
        proto = create_protocol("P1", engine=engine, kind="hh", eps=0.5, **kw)
        with pytest.raises(ValueError, match="element ids"):
            proto.step(np.array([[-1.0, 5.0]], np.float32))
        with pytest.raises(ValueError, match="element ids"):
            proto.step((np.array([1 << 24]), np.array([1.0])))
        with pytest.raises(ValueError, match="\\(n, 2\\)"):
            proto.step(np.zeros((3, 4), np.float32))


def test_restore_payload_rejects_config_mismatch(stream, mesh):
    """Restoring protocol state into a differently-configured protocol
    (other eps -> other sketch width; other mesh size -> other m) fails
    fast with the cause, not later inside a jitted shard_map step."""
    a, _, _, _ = stream
    p = create_protocol("P2", engine="shard", mesh=mesh, d=D, eps=0.3)
    p.step(jnp.asarray(a[:1000]))
    arrays, meta = p.state_payload()
    q = create_protocol("P2", engine="shard", mesh=mesh, d=D, eps=0.1)
    with pytest.raises(ValueError, match="protocol/config mismatch"):
        q.restore_payload({k: np.asarray(v) for k, v in arrays.items()}, meta)


def test_hh_shard_matches_event_semantics(hh_stream, mesh):
    """The shard HHP1 engine meets the same deterministic eps bound as the
    event P1 on the same stream, with comparable message counts."""
    keys, w, sites, (hh, totals, W) = hh_stream
    pairs = np.stack([keys.astype(np.float64), w], axis=1)
    ev = create_protocol("P1", engine="event", kind="hh", m=1, eps=HH_EPS)
    sh = create_protocol("P1", engine="shard", kind="hh", mesh=mesh, eps=HH_EPS)
    ev.step(pairs, np.zeros(HH_N, np.int64))
    sh.step(pairs)
    for proto in (ev, sh):
        est = proto.estimates()
        worst = max(abs(totals[e] - est.get(e, 0.0)) / W for e in totals)
        assert worst <= HH_EPS + 1e-6
        # the paper's no-false-negative rule holds through heavy_hitters()
        assert set(hh).issubset(set(proto.heavy_hitters(HH_PHI)))


def test_event_protocol_round_robin_sites():
    """Site-less feeds get a deterministic round-robin assignment."""
    proto = create_protocol("P2", engine="event", m=3, eps=0.5, d=8, seed=0)
    proto.step(lowrank_stream(300, 8, seed=3))
    assert proto.rows_seen == 300
    assert proto.comm_report().total > 0


def test_event_streams_do_not_alias_caller_buffer():
    """Feeding through a reused ingest buffer must equal fresh-array feeds:
    retained rows (samples, pending directions) are copies, not views."""
    a = lowrank_stream(1200, 8, rank=2, seed=5)
    for name in ("P2", "P3", "P3wr"):
        fresh = create_protocol(name, engine="event", m=2, eps=0.5, d=8, seed=3)
        reused = create_protocol(name, engine="event", m=2, eps=0.5, d=8, seed=3)
        buf = np.empty((300, 8), np.float32)
        for i in range(0, 1200, 300):
            chunk = a[i : i + 300]
            fresh.step(chunk.copy())
            buf[:] = chunk  # same storage every step
            reused.step(buf)
        np.testing.assert_array_equal(fresh.matrix(), reused.matrix())


def test_comm_report_is_uniform_across_engines(stream, mesh):
    """The satellite contract: both engines emit the same report shape, and
    dict-style access (old TrackerSnapshot.messages keys) still works."""
    a, sites, _, _ = stream
    ev = create_protocol("P2", engine="event", m=M, eps=EPS, d=D, seed=0)
    ev.step(a[:2000], sites[:2000])
    sh = create_protocol("P2", engine="shard", mesh=mesh, d=D, eps=EPS, axis="data")
    sh.step(jnp.asarray(a[:2000]))
    for rep in (ev.comm_report(), sh.comm_report()):
        assert isinstance(rep, CommReport)
        assert rep.total == rep["total"]
        assert rep["rows"] == rep.row_msgs and rep["scalar"] == rep.scalar_msgs
        assert rep.total == rep.scalar_msgs + rep.row_msgs + rep.broadcast_events * rep.m


def test_tracker_snapshot_messages_need_no_renaming(mesh):
    from repro.core.tracker import DistributedMatrixTracker

    tracker = DistributedMatrixTracker(mesh, 16, eps=0.25)
    tracker.update(jnp.asarray(lowrank_stream(512, 16, rank=3, seed=4)))
    snap = tracker.snapshot(k=4)
    assert isinstance(snap.messages, CommReport)
    assert snap.messages["total"] == snap.messages.total
    # tracker queries ride the serving kernel path
    x = np.zeros(16, np.float32)
    x[0] = 1.0
    b = tracker.sketch_matrix()
    want = float(np.asarray(quadform(jnp.asarray(b), jnp.asarray(x)[None]))[0])
    assert tracker.query(jnp.asarray(x)) == pytest.approx(want, rel=1e-6)


# ---------------------------------------------------------------------------
# publish policies
# ---------------------------------------------------------------------------


def _simulate(policy, frobs):
    """Feed a frob trajectory; returns the publish step indices."""
    published = []
    since, pub_frob = 0, None
    for i, f in enumerate(frobs):
        since += 1
        if policy.should_publish(
            steps_since_publish=since, live_frob=f, published_frob=pub_frob
        ):
            published.append(i)
            since, pub_frob = 0, f
    return published


def test_every_k_steps_publishes_on_schedule():
    pubs = _simulate(EveryKSteps(3), np.arange(1.0, 13.0))
    assert pubs == [2, 5, 8, 11]
    assert _simulate(EveryKSteps(1), np.ones(4)) == [0, 1, 2, 3]


def test_frob_drift_publishes_geometrically():
    frobs = [1.0, 1.05, 1.2, 2.0, 2.1, 5.0]
    pubs = _simulate(FrobDrift(rel=0.5), frobs)
    assert pubs == [0, 3, 5]  # first ever, then only on >1.5x growth


def test_on_demand_never_auto_publishes():
    assert _simulate(OnDemand(), np.arange(1.0, 100.0)) == []


def test_policy_validation():
    with pytest.raises(ValueError):
        EveryKSteps(0)
    with pytest.raises(ValueError):
        FrobDrift(rel=0.0)


def test_policy_properties():
    """Property harness: publish counts are bounded for any trajectory.

    Hypothesis when installed, else a seeded sweep over the same check.
    """
    from conftest import run_property

    def check(k, n, rel, growth):
        # EveryKSteps: exactly floor(n / k) publishes over n steps.
        assert len(_simulate(EveryKSteps(k), np.ones(n))) == n // k
        # FrobDrift on a non-decreasing mass curve: version count is
        # logarithmic — at most 1 + log_{1+rel}(final/first).
        frobs = 1.0 + np.cumsum(growth)
        pubs = _simulate(FrobDrift(rel=rel), frobs)
        bound = 1 + np.log(frobs[-1] / frobs[0]) / np.log1p(rel)
        assert 1 <= len(pubs) <= bound + 1
        # staleness invariant: between publishes the live mass never exceeds
        # (1+rel) x the published mass except on the step that republishes.
        pub_frob = None
        for i, f in enumerate(frobs):
            if i in pubs:
                pub_frob = f
            else:
                assert pub_frob is not None and f <= (1.0 + rel) * pub_frob

    rng = np.random.default_rng(0)
    run_property(
        check,
        given=lambda: {
            "k": st.integers(min_value=1, max_value=7),
            "n": st.integers(min_value=0, max_value=60),
            "rel": st.floats(min_value=0.05, max_value=2.0),
            "growth": st.lists(
                st.floats(min_value=0.0, max_value=3.0), min_size=1, max_size=60
            ),
        },
        cases=(
            {
                "k": int(rng.integers(1, 8)),
                "n": int(rng.integers(0, 61)),
                "rel": float(rng.uniform(0.05, 2.0)),
                "growth": rng.uniform(0.0, 3.0, int(rng.integers(1, 61))).tolist(),
            }
            for _ in range(100)
        ),
        max_examples=100,
    )


# ---------------------------------------------------------------------------
# cross-tenant packing
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("t,l,d,ns", [(3, 17, 200, (5, 9, 3)), (2, 8, 64, (1, 16))])
def test_quadform_packed_matches_ref_and_serial(t, l, d, ns):
    rng = np.random.default_rng(t + l)
    b = rng.normal(size=(t, l, d)).astype(np.float32)
    n_max = max(ns)
    x = np.zeros((t, n_max, d), np.float32)
    for i, n in enumerate(ns):
        x[i, :n] = rng.normal(size=(n, d))
    got = np.asarray(quadform_packed(jnp.asarray(b), jnp.asarray(x)))
    want = np.asarray(ref_quadform_packed(jnp.asarray(b), jnp.asarray(x)))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4 * d)
    # per-tenant single launches agree bit-for-bit in interpret mode
    for i in range(t):
        single = np.asarray(quadform(jnp.asarray(b[i]), jnp.asarray(x[i])))
        np.testing.assert_array_equal(got[i], single)


@pytest.fixture()
def multi_store():
    rng = np.random.default_rng(7)
    store = SketchStore()
    for i, tenant in enumerate(["a", "b", "c", "d"]):
        store.publish(
            tenant,
            rng.normal(size=(12, 32)).astype(np.float32),
            frob=float(10 + i),
            eps=0.1,
        )
    # one tenant with a different sketch shape: must not pack with the rest
    store.publish("odd", rng.normal(size=(20, 32)).astype(np.float32), frob=1.0, eps=0.1)
    return store


def test_engine_query_packed_equals_serial(multi_store):
    engine = QueryEngine(multi_store)
    rng = np.random.default_rng(8)
    reqs = [
        PackedRequest(tenant, rng.normal(size=(n, 32)).astype(np.float32))
        for tenant, n in [("a", 7), ("b", 3), ("c", 12), ("d", 1), ("odd", 5)]
    ]
    results = engine.query_packed(reqs)
    assert [r.tenant for r in results] == ["a", "b", "c", "d", "odd"]
    # padding is per shape group: a/b/c/d pad to 12 (5+9+0+11), the odd
    # shape is a singleton launch with no padding
    assert engine.packed_launches == 2
    assert engine.packed_pad_slots == 25
    for req, res in zip(reqs, results):
        serial = engine.query_batch(req.x, tenant=req.tenant, path="pallas")
        np.testing.assert_allclose(res.estimates, serial.estimates, rtol=1e-5)
        assert res.version == serial.version
        assert res.error_bound == serial.error_bound
        assert res.estimates.shape == (req.x.shape[0],)


def test_engine_query_packed_validates_shapes(multi_store):
    engine = QueryEngine(multi_store)
    with pytest.raises(ValueError):
        engine.query_packed([PackedRequest("a", np.zeros((3, 5), np.float32))])
    with pytest.raises(KeyError):
        engine.query_packed([PackedRequest("nobody", np.zeros((3, 32), np.float32))])


def test_packed_service_deadline_flush(multi_store):
    """Deadline pump with an injected clock: no flush before the earliest
    deadline, one packed flush after it."""
    now = [0.0]
    svc = PackedQueryService(
        QueryEngine(multi_store), default_deadline_s=1.0, clock=lambda: now[0]
    )
    rng = np.random.default_rng(9)
    tickets = [
        svc.submit(rng.normal(size=32).astype(np.float32), tenant=t, deadline_s=dl)
        for t, dl in [("a", 5.0), ("b", 2.0), ("c", 9.0)]
    ]
    assert svc.poll() == 0 and svc.pending() == 3
    now[0] = 1.9  # earliest deadline (2.0) not yet expired
    assert svc.poll() == 0
    now[0] = 2.1
    assert svc.poll() == 3  # ONE deadline expiry flushes the whole pack
    assert all(t.done for t in tickets)
    stats = svc.stats()
    assert stats.flushes == 1 and stats.deadline_flushes == 1
    assert stats.packed_tenants == 3 and stats.queries == 3


def test_packed_service_max_batch_auto_flush(multi_store):
    svc = PackedQueryService(QueryEngine(multi_store), max_batch=4)
    rng = np.random.default_rng(10)
    tickets = []
    for i in range(6):
        tickets.append(
            svc.submit(rng.normal(size=32).astype(np.float32), tenant="ab"[i % 2])
        )
    assert tickets[3].done and not tickets[4].done  # flushed at 4 pending
    assert svc.pending() == 2
    est, bound, version = tickets[5].result()  # ticket-triggered flush
    assert svc.pending() == 0 and bound > 0 and version == 1


def test_packed_service_failed_flush_keeps_tickets(multi_store):
    svc = PackedQueryService(QueryEngine(multi_store))
    ok = svc.submit(np.ones(32, np.float32), tenant="a")
    bad = svc.submit(np.ones(32, np.float32), tenant="unpublished")
    with pytest.raises(KeyError):
        svc.flush()
    assert svc.pending() == 2 and not ok.done and not bad.done


# ---------------------------------------------------------------------------
# admission quotas and priorities
# ---------------------------------------------------------------------------


def test_quota_sheds_and_reports(multi_store):
    """Submits beyond a tenant's max_pending are rejected with a typed
    error and counted — never queued, never silently dropped."""
    svc = PackedQueryService(QueryEngine(multi_store))
    svc.set_quota("a", max_pending=2)
    rng = np.random.default_rng(20)
    t1 = svc.submit(rng.normal(size=32).astype(np.float32), tenant="a")
    t2 = svc.submit(rng.normal(size=32).astype(np.float32), tenant="a")
    with pytest.raises(QueryShedError) as ei:
        svc.submit(rng.normal(size=32).astype(np.float32), tenant="a")
    assert (ei.value.tenant, ei.value.pending, ei.value.max_pending) == ("a", 2, 2)
    # other tenants are unaffected by a's quota
    tb = svc.submit(rng.normal(size=32).astype(np.float32), tenant="b")
    assert svc.pending() == 3 and svc.pending("a") == 2
    assert svc.stats().shed == 1 and svc.shed_counts() == {"a": 1}
    # shedding frees nothing until a flush drains the queue
    svc.flush()
    assert all(t.done for t in (t1, t2, tb))
    t4 = svc.submit(rng.normal(size=32).astype(np.float32), tenant="a")
    assert not t4.done  # admitted again after the drain


def test_quota_validation(multi_store):
    svc = PackedQueryService(QueryEngine(multi_store))
    with pytest.raises(ValueError):
        svc.set_quota("a", max_pending=-1)


def test_priority_orders_capped_sweeps(multi_store):
    """With max_batch smaller than the backlog, each deadline-pump sweep
    serves the highest-priority tenant first; lower priority waits."""
    now = [0.0]
    svc = PackedQueryService(
        QueryEngine(multi_store), max_batch=2, auto_flush=False,
        default_deadline_s=1.0, clock=lambda: now[0],
    )
    svc.set_quota("a", priority=0)
    svc.set_quota("b", priority=5)
    rng = np.random.default_rng(21)
    lo = [svc.submit(rng.normal(size=32).astype(np.float32), tenant="a") for _ in range(2)]
    hi = [svc.submit(rng.normal(size=32).astype(np.float32), tenant="b") for _ in range(2)]
    now[0] = 2.0
    assert svc.poll() == 2  # one capped sweep: the high-priority tenant
    assert all(t.done for t in hi) and not any(t.done for t in lo)
    assert svc.poll() == 2  # expired low-priority queries ride the next pump
    assert all(t.done for t in lo)
    stats = svc.stats()
    assert stats.deadline_flushes == 2 and stats.flushes == 2


def test_flush_drains_beyond_max_batch(multi_store):
    """flush() loops capped sweeps until empty, splitting tenants across
    sweeps when needed."""
    svc = PackedQueryService(QueryEngine(multi_store), max_batch=4, auto_flush=False)
    rng = np.random.default_rng(22)
    tickets = [
        svc.submit(rng.normal(size=32).astype(np.float32), tenant="abc"[i % 3])
        for i in range(10)
    ]
    assert svc.flush() == 10
    assert all(t.done for t in tickets)
    assert svc.stats().flushes == 3  # ceil(10 / 4) engine round-trips


def test_policy_config_round_trip():
    for policy in (EveryKSteps(3), FrobDrift(rel=0.25), OnDemand()):
        clone = policy_from_config(policy_to_config(policy))
        assert repr(clone) == repr(policy)
    with pytest.raises(ValueError):
        policy_from_config({"type": "Nope"})


# ---------------------------------------------------------------------------
# store persistence
# ---------------------------------------------------------------------------


def test_store_save_load_round_trip(multi_store):
    engine = QueryEngine(multi_store)
    rng = np.random.default_rng(11)
    x = rng.normal(size=(6, 32)).astype(np.float32)
    with tempfile.TemporaryDirectory() as d:
        multi_store.save(d)
        loaded = SketchStore.load(d)
        assert loaded.tenants() == multi_store.tenants()
        restored = QueryEngine(loaded)
        for tenant in multi_store.tenants():
            assert loaded.versions(tenant) == multi_store.versions(tenant)
            before = engine.query_batch(x, tenant=tenant, path="pallas")
            after = restored.query_batch(x, tenant=tenant, path="pallas")
            np.testing.assert_array_equal(before.estimates, after.estimates)
            assert (before.version, before.error_bound) == (after.version, after.error_bound)
            old, new = multi_store.get(tenant), loaded.get(tenant)
            assert (old.frob, old.eps, old.n_seen) == (new.frob, new.eps, new.n_seen)
        # restored matrices are frozen like published ones
        with pytest.raises(ValueError):
            loaded.get("a").matrix[0, 0] = 1.0
        # version numbering continues, never reuses
        v = loaded.publish("a", np.ones((2, 32), np.float32), frob=1.0, eps=0.5)
        assert v.version == multi_store.latest_version("a") + 1


def test_store_save_preserves_history_and_retention():
    rng = np.random.default_rng(12)
    store = SketchStore(retain=2)
    for i in range(4):
        store.publish("t", rng.normal(size=(4, 8)).astype(np.float32), frob=1.0 + i, eps=0.5)
    with tempfile.TemporaryDirectory() as d:
        store.save(d)
        loaded = SketchStore.load(d)
        assert loaded.retain == 2
        assert loaded.versions("t") == [3, 4]  # pruned history stays pruned
        np.testing.assert_array_equal(loaded.get("t", 3).matrix, store.get("t", 3).matrix)


def test_store_load_error_cases():
    with tempfile.TemporaryDirectory() as d:
        with pytest.raises(FileNotFoundError):
            SketchStore.load(d)
        from repro import ckpt

        ckpt.save(d, 0, {"x": np.zeros(3)}, extra={"kind": "something_else"})
        with pytest.raises(ValueError):
            SketchStore.load(d)


# ---------------------------------------------------------------------------
# pipeline: ingest -> policy publish -> packed serve (the tentpole loop)
# ---------------------------------------------------------------------------


def test_pipeline_end_to_end(mesh):
    pipe = StreamingPipeline(mesh, eps=0.25, policy=EveryKSteps(2), default_deadline_s=0.0)
    d = 16
    streams = {f"t{i}": lowrank_stream(1024, d, rank=3, seed=20 + i) for i in range(4)}
    for tenant in streams:
        pipe.add_tenant(tenant, d)
    with pytest.raises(ValueError):
        pipe.add_tenant("t0", d)  # duplicate tenant

    for step in range(4):
        for tenant, a in streams.items():
            snap = pipe.ingest(tenant, jnp.asarray(a[step * 256 : (step + 1) * 256]))
            assert (snap is not None) == (step % 2 == 1)  # EveryKSteps(2)

    rng = np.random.default_rng(13)
    xs = {t: rng.normal(size=(5, d)).astype(np.float32) for t in streams}
    tickets = {t: [pipe.submit(t, x) for x in xs[t]] for t in streams}
    assert pipe.flush() == 20
    for tenant in streams:
        serial = pipe.engine.query_batch(xs[tenant], tenant=tenant, path="pallas").estimates
        got = np.array([tk.result()[0] for tk in tickets[tenant]], np.float32)
        np.testing.assert_allclose(got, serial, rtol=1e-5)

    s = pipe.stats("t0")
    assert s.steps == 4 and s.rows == 1024 and s.publishes == 2 and s.latest_version == 2
    assert s.comm_total > 0

    with pytest.raises(KeyError):
        pipe.submit("ghost", np.zeros(d, np.float32))

    # restart recovery through the pipeline's own save/load
    with tempfile.TemporaryDirectory() as ckdir:
        pipe.save(ckdir)
        restored = StreamingPipeline.load(ckdir, mesh)
        assert restored.tenants() == pipe.tenants()
        for tenant in streams:
            before = pipe.engine.query_batch(xs[tenant], tenant=tenant, path="pallas")
            after = restored.engine.query_batch(xs[tenant], tenant=tenant, path="pallas")
            np.testing.assert_array_equal(before.estimates, after.estimates)
            assert restored.stats(tenant) == pipe.stats(tenant)


def _mixed_pipeline(mesh):
    """One pipeline hosting a matrix tenant and both HH engines."""
    pipe = StreamingPipeline(mesh, eps=0.25, policy=EveryKSteps(1))
    pipe.add_tenant("mat", 16, quota=TenantQuota(max_pending=4, priority=1))
    pipe.add_hh_tenant("hh-ev", eps=0.05, protocol="P1", engine="event", m=4,
                       quota=TenantQuota(max_pending=8, priority=5))
    pipe.add_hh_tenant("hh-sh", eps=0.05, protocol="P1", engine="shard")
    return pipe


def _mixed_feed():
    a = lowrank_stream(1024, 16, rank=3, seed=41)
    keys, w = zipfian_stream(8000, beta=100.0, universe=1000, seed=42)
    pairs = np.stack([keys.astype(np.float32), w.astype(np.float32)], axis=1)
    return a, pairs


def _mixed_answers(pipe, a, pairs):
    """Resume ingest on the second half of the feed, then query every tenant."""
    for i in (2, 3):
        pipe.ingest("mat", jnp.asarray(a[i * 256 : (i + 1) * 256]))
        pipe.ingest("hh-ev", pairs[i * 2000 : (i + 1) * 2000])
        pipe.ingest("hh-sh", pairs[i * 2000 : (i + 1) * 2000])
    x = np.random.default_rng(43).normal(size=16).astype(np.float32)
    tickets = [
        pipe.submit("mat", x),
        pipe.submit("hh-ev", np.array([1.0], np.float32)),
        pipe.submit("hh-sh", np.array([1.0], np.float32)),
    ]
    pipe.flush()
    out = [v for t in tickets for v in t.result()]
    out += [float(pipe.stats(t).live_frob) for t in pipe.tenants()]
    out += [float(pipe.stats(t).comm_total) for t in pipe.tenants()]
    out += [float(e) for e in pipe.heavy_hitters("hh-ev", 0.05)]
    return np.array(out, np.float64)


def test_pipeline_mixed_workloads_quota_and_restart(mesh, tmp_path):
    """The PR acceptance loop: one pipeline hosts matrix + HH tenants
    concurrently, enforces a per-tenant quota under synthetic overload
    (sheds and reports, never silently drops), and after save -> fresh
    process load resumes ingest and answers bit-identically."""
    from conftest import run_multidevice

    pipe = _mixed_pipeline(mesh)
    a, pairs = _mixed_feed()
    for i in (0, 1):  # first half of every stream
        pipe.ingest("mat", jnp.asarray(a[i * 256 : (i + 1) * 256]))
        pipe.ingest("hh-ev", pairs[i * 2000 : (i + 1) * 2000])
        pipe.ingest("hh-sh", pairs[i * 2000 : (i + 1) * 2000])
    assert {pipe.workload(t) for t in pipe.tenants()} == {"matrix", "hh"}

    # -- synthetic overload: the 5th pending "mat" query trips the quota --
    x = np.random.default_rng(44).normal(size=16).astype(np.float32)
    held = [pipe.submit("mat", x) for _ in range(4)]
    with pytest.raises(QueryShedError) as ei:
        pipe.submit("mat", x)
    assert ei.value.tenant == "mat" and ei.value.max_pending == 4
    # shed is *reported*: counted per tenant, queue depths intact
    assert pipe.service.stats().shed == 1
    assert pipe.service.shed_counts() == {"mat": 1}
    assert pipe.service.pending("mat") == 4
    # the high-priority HH tenant is still admitted during mat's overload
    hh_t = pipe.submit("hh-ev", np.array([1.0], np.float32))
    assert pipe.flush() == 5  # 4 held + 1 HH; the shed query was never queued
    assert all(t.done for t in held) and hh_t.done

    # -- checkpoint, then resume in THIS process --
    ckdir = str(tmp_path / "pipeline_ck")
    pipe.save(ckdir)
    want = _mixed_answers(pipe, a, pairs)

    # -- fresh-process restart: load must answer bit-identically --
    import os

    tests_dir = os.path.dirname(os.path.abspath(__file__))
    script = f"""
import sys
sys.path.insert(0, {tests_dir!r})
import jax, numpy as np
from repro.runtime import StreamingPipeline
from test_runtime import _mixed_answers, _mixed_feed

mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))
pipe = StreamingPipeline.load({ckdir!r}, mesh)
a, pairs = _mixed_feed()
print("ANSWERS=" + _mixed_answers(pipe, a, pairs).tobytes().hex())
"""
    out = run_multidevice(script, n_devices=1)
    got_hex = [ln for ln in out.splitlines() if ln.startswith("ANSWERS=")][0]
    got = np.frombuffer(bytes.fromhex(got_hex.removeprefix("ANSWERS=")), np.float64)
    np.testing.assert_array_equal(got, want)


def test_pipeline_submit_rejects_wrong_workload_shape(mesh):
    """A wrong-shape query must fail at the submitter: once queued, it
    would make every packed flush raise (failing batches stay pending by
    design) and wedge all tenants."""
    pipe = _mixed_pipeline(mesh)
    a, pairs = _mixed_feed()
    pipe.ingest("mat", jnp.asarray(a[:256]))
    pipe.ingest("hh-ev", pairs[:2000])
    with pytest.raises(ValueError, match="element id"):
        pipe.submit("hh-ev", np.zeros(16, np.float32))  # matrix direction
    with pytest.raises(ValueError, match="direction"):
        pipe.submit("mat", np.array([1.0], np.float32))  # HH element id
    # nothing was queued: the service still serves cleanly
    pipe.submit("mat", np.zeros(16, np.float32))
    assert pipe.flush() == 1


def test_pipeline_add_hh_tenant_rejects_unknown_engine(mesh):
    pipe = StreamingPipeline(mesh)
    with pytest.raises(ValueError, match="unknown HH engine"):
        pipe.add_hh_tenant("t", engine="Shard")


def test_pipeline_save_load_with_hostile_tenant_names(mesh, tmp_path):
    """Tenant names are free-form: path separators and '__' must neither
    break checkpoint file paths nor alias the leaf namespace."""
    pipe = StreamingPipeline(mesh, eps=0.25, policy=FrobDrift(rel=0.5))
    names = ["eu/run__a", "eu/run", "tenant_0001"]
    a = lowrank_stream(512, 8, rank=2, seed=60)
    for name in names:
        pipe.add_tenant(name, 8)
        pipe.ingest(name, jnp.asarray(a))  # FrobDrift: first ingest publishes
    ckdir = str(tmp_path / "hostile")
    pipe.save(ckdir)
    restored = StreamingPipeline.load(ckdir, mesh)
    assert restored.tenants() == pipe.tenants()
    # the pipeline-wide default policy survives the round trip too
    assert repr(restored.default_policy) == repr(pipe.default_policy)
    x = np.random.default_rng(61).normal(size=8).astype(np.float32)
    for name in names:
        t1, t2 = pipe.submit(name, x), restored.submit(name, x)
        pipe.flush(), restored.flush()
        assert t1.result() == t2.result()


def test_pipeline_on_demand_and_drift_policies(mesh):
    d = 8
    pipe = StreamingPipeline(mesh, eps=0.5, policy=OnDemand())
    pipe.add_tenant("manual", d)
    pipe.add_tenant("drift", d, policy=FrobDrift(rel=0.25))
    a = lowrank_stream(512, d, rank=2, seed=30)
    assert pipe.ingest("manual", jnp.asarray(a[:256])) is None
    # queries for a tenant with no published snapshot are rejected at
    # submit time (they could never resolve and would poison the pack)
    with pytest.raises(KeyError):
        pipe.submit("manual", np.zeros(d, np.float32))
    assert pipe.ingest("drift", jnp.asarray(a[:256])) is not None  # first publish
    # same mass again: > 1.25x growth, so the drift tenant republishes
    assert pipe.ingest("drift", jnp.asarray(a[256:])) is not None
    assert pipe.stats("manual").publishes == 0
    snap = pipe.publish("manual")
    assert snap.version == 1 and pipe.stats("manual").publishes == 1
