"""Unified telemetry layer: metrics registry, tracing, and the views.

Three layers pinned here:

  * ``repro.obs`` primitives — counter/gauge/histogram families with
    label series, deterministic snapshot/Prometheus/JSON exporters that
    round-trip exactly, span tracing with explicit trace-id joins.
  * golden views — the pre-existing ``stats()`` / ``cache_stats()`` /
    ``report()`` surfaces are reimplemented as *views* over the one
    registry; these tests assert the dicts and the scrape surface agree
    value for value, so neither can drift from the other.
  * end-to-end trace anatomy — one ``query_batch`` on a two-cell
    transported router yields a single trace tree
    (router → transport.message → transport.send → cell.deliver →
    engine.query_packed) with a positive duration on every stage.
"""
import jax
import numpy as np
import pytest

from repro.cluster import ClusterRouter, PipelineCell
from repro.cluster import transport as tp
from repro.obs import (
    DEFAULT_BUCKETS,
    MetricsRegistry,
    Observability,
    Tracer,
    histogram_quantile,
    rehome_families,
)
from repro.query.engine import QueryEngine
from repro.query.store import SketchStore
from repro.runtime import EveryKSteps, StreamingPipeline
from repro.runtime.policies import RetryPolicy

D = 8


@pytest.fixture(scope="module")
def mesh():
    return jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))


class FakeClock:
    """Deterministic monotonic clock: each call advances 1ms."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        self.t += 0.001
        return self.t


# ---------------------------------------------------------------------------
# metrics primitives
# ---------------------------------------------------------------------------


def test_counter_gauge_histogram_basics():
    reg = MetricsRegistry()
    c = reg.counter("c_total", "a counter")
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    with pytest.raises(ValueError):
        c.inc(-1)

    g = reg.gauge("g", "a gauge")
    g.set(4)
    g.inc(-1.5)
    assert g.value == 2.5

    h = reg.histogram("h_seconds", "a histogram", buckets=(0.1, 1.0)).labels()
    for v in (0.05, 0.5, 5.0):
        h.observe(v)
    assert h.count == 3 and h.sum == pytest.approx(5.55)
    assert h.buckets() == [(0.1, 1), (1.0, 2), (float("inf"), 3)]


def test_labeled_series_are_independent_and_sorted():
    reg = MetricsRegistry()
    fam = reg.counter("ops_total", "ops", labels=("cell", "op"))
    fam.labels(cell="a", op="hit").inc(2)
    fam.labels(op="miss", cell="a").inc()  # kwarg order is irrelevant
    fam.labels(cell="b", op="hit").inc()
    assert fam.labels(cell="a", op="hit").value == 2
    series = fam.series()
    assert [lbl for lbl, _ in series] == [
        {"cell": "a", "op": "hit"},
        {"cell": "a", "op": "miss"},
        {"cell": "b", "op": "hit"},
    ]
    with pytest.raises(ValueError):
        fam.labels(cell="a")  # missing a declared label


def test_family_reregistration_conflicts():
    reg = MetricsRegistry()
    reg.counter("x_total", "first help")
    # Same kind + label schema: returns the same family; help may differ.
    assert reg.counter("x_total", "other help") is reg.get("x_total")
    with pytest.raises(ValueError):
        reg.gauge("x_total", "kind mismatch")
    with pytest.raises(ValueError):
        reg.counter("x_total", "label mismatch", labels=("cell",))


def test_histogram_quantile_interpolates():
    reg = MetricsRegistry()
    h = reg.histogram("lat", "latency", buckets=(0.1, 0.2, 0.4)).labels()
    for v in [0.05] * 10 + [0.15] * 10:
        h.observe(v)
    assert histogram_quantile(h.buckets(), 0.25) == pytest.approx(0.05)
    q75 = histogram_quantile(h.buckets(), 0.75)
    assert 0.1 < q75 <= 0.2


def test_drop_series_is_scoped_to_the_label_assignment():
    reg = MetricsRegistry()
    fam = reg.counter("y_total", "y", labels=("cell", "tenant"))
    fam.labels(cell="a", tenant="t0").inc()
    fam.labels(cell="a", tenant="t1").inc()
    fam.labels(cell="b", tenant="t0").inc()
    unlabeled = reg.counter("z_total", "no cell label")
    unlabeled.inc()
    assert reg.drop_series(cell="a") == 2
    assert [lbl for lbl, _ in fam.series()] == [{"cell": "b", "tenant": "t0"}]
    assert unlabeled.value == 1  # families lacking the label are untouched


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------


def _populated_registry():
    clock = FakeClock()
    reg = MetricsRegistry(clock=clock)
    c = reg.counter("repro_ops_total", "ops", labels=("cell",))
    c.labels(cell="a").inc(3)
    c.labels(cell="b").inc(1.5)
    reg.gauge("repro_depth", "queue depth").set(7)
    h = reg.histogram("repro_lat_seconds", "latency", buckets=(0.01, 0.1, 1.0))
    for v in (0.005, 0.05, 0.5, 5.0):
        h.observe(v)
    return reg


def test_snapshot_round_trips_byte_identically():
    reg = _populated_registry()
    text = reg.to_json()
    rebuilt = MetricsRegistry.from_json(text)
    assert rebuilt.to_json() == text
    assert rebuilt.snapshot() == reg.snapshot()


def test_prometheus_round_trips_through_the_json_exporter():
    reg = _populated_registry()
    prom = reg.to_prometheus()
    rebuilt = MetricsRegistry.from_snapshot(reg.snapshot())
    assert rebuilt.to_prometheus() == prom
    # Spot-check the exposition shape itself.
    assert "# TYPE repro_ops_total counter" in prom
    assert 'repro_ops_total{cell="a"} 3' in prom
    assert 'repro_lat_seconds_bucket{le="+Inf"} 4' in prom
    assert "repro_lat_seconds_count 4" in prom
    # Custom buckets survive the snapshot (not silently reset to default).
    assert rebuilt.get("repro_lat_seconds")._buckets == (0.01, 0.1, 1.0)


# ---------------------------------------------------------------------------
# tracing
# ---------------------------------------------------------------------------


def test_tracer_nests_and_builds_one_tree():
    tr = Tracer(clock=FakeClock())
    with tr.trace("root", kind="q") as root:
        with tr.trace("child"):
            with tr.trace("leaf"):
                pass
        root.event("note", detail=1)
    (tree,) = tr.tree(root.trace_id)
    names = [n.span.name for n in tree.walk()]
    assert names == ["root", "child", "leaf"]
    assert all(n.span.duration_s > 0 for n in tree.walk())
    assert tree.span.events[0].name == "note"
    assert tr.current() is None  # stack fully unwound


def test_explicit_trace_id_joins_or_detaches():
    tr = Tracer(clock=FakeClock())
    with tr.trace("origin") as origin:
        with tr.trace("joined", trace_id=origin.trace_id) as joined:
            assert joined.parent_id == origin.span_id
    # Same explicit id with no live parent: a detached root of that trace
    # (the late-delivery / replay case).
    with tr.trace("late", trace_id=origin.trace_id) as late:
        assert late.parent_id is None and late.trace_id == origin.trace_id
    roots = tr.tree(origin.trace_id)
    assert [r.span.name for r in roots] == ["origin", "late"]


def test_trace_ids_are_deterministic_counters():
    tr = Tracer(clock=FakeClock())
    with tr.trace("a") as a:
        pass
    with tr.trace("b") as b:
        pass
    assert (a.trace_id, b.trace_id) == ("t000001", "t000002")
    assert a.span_id == "s000001"


# ---------------------------------------------------------------------------
# Observability bundle + rehoming
# ---------------------------------------------------------------------------


def test_scoped_bundles_share_registry_and_stamp_labels():
    obs = Observability(labels={})
    scoped = obs.scoped(cell="c0")
    assert scoped.registry is obs.registry and scoped.tracer is obs.tracer
    scoped.handle("counter", "s_total", "scoped", labels={"op": "x"}).inc()
    fam = obs.registry.get("s_total")
    assert fam.labels(cell="c0", op="x").value == 1


def test_rehome_families_carries_values_and_drops_stale_series():
    old = Observability(labels={"cell": "-"})
    fams = (("counter", "m_total", "m"), ("gauge", "g", "g"))
    old.handle("counter", "m_total", "m").inc(5)
    old.handle("gauge", "g", "g").set(2)

    # Cross-registry move: values land under the new base labels.
    new = Observability(labels={"cell": "c1"})
    rehome_families(old, new, fams)
    assert new.registry.get("m_total").labels(cell="c1").value == 5

    # Same-registry relabel: the old series must not linger.
    relabeled = new.scoped(cell="c2")
    rehome_families(new, relabeled, fams)
    fam = new.registry.get("m_total")
    assert [lbl for lbl, _ in fam.series()] == [{"cell": "c2"}]
    assert fam.labels(cell="c2").value == 5


# ---------------------------------------------------------------------------
# golden views: stats()/cache_stats() are registry views
# ---------------------------------------------------------------------------


def _store_with_versions(n=3):
    rng = np.random.default_rng(0)
    store = SketchStore()
    for _ in range(n):
        m = rng.normal(size=(4, D)).astype(np.float32)
        store.publish(
            "t", m, frob=float(np.sum(m.astype(np.float64) ** 2)), eps=0.5
        )
    return store


def test_cache_stats_cold_cache_hit_rate_is_zero():
    engine = QueryEngine(SketchStore())
    stats = engine.cache_stats()
    assert stats["hit_rate"] == 0.0  # defined, not NaN/ZeroDivisionError
    assert stats["hits"] == stats["misses"] == stats["evictions"] == 0


def test_cache_stats_returns_defensive_copies():
    engine = QueryEngine(_store_with_versions())
    x = np.random.default_rng(1).normal(size=(2, D)).astype(np.float32)
    engine.query_batch(x, tenant="t", path="cached")
    first = engine.cache_stats()
    first["hits"] = 10**6
    first["hit_rate"] = -1.0
    assert engine.cache_stats()["hits"] != 10**6
    assert engine.cache_stats()["hit_rate"] >= 0.0


def test_cache_stats_agrees_with_registry(mesh):
    engine = QueryEngine(_store_with_versions())
    x = np.random.default_rng(1).normal(size=(2, D)).astype(np.float32)
    engine.query_batch(x, tenant="t", path="cached")
    engine.query_batch(x, tenant="t", path="cached")
    stats = engine.cache_stats()
    fam = engine.obs.registry.get("repro_engine_cache_ops_total")

    def total(op):
        return sum(
            s.value for lbl, s in fam.series() if lbl["op"] == op
        )

    assert stats["hits"] == total("hits") > 0
    assert stats["misses"] == total("misses") > 0
    assert stats["hit_rate"] == stats["hits"] / (stats["hits"] + stats["misses"])


def _drive_pipeline(mesh, n_batches=4):
    pipe = StreamingPipeline(mesh, eps=0.2, policy=EveryKSteps(1))
    pipe.add_tenant("t0", D, eps=0.2, policy=EveryKSteps(1))
    pipe.add_tenant("t1", D, eps=0.2, policy=EveryKSteps(1))
    rng = np.random.default_rng(3)
    for _ in range(n_batches):
        for t in ("t0", "t1"):
            pipe.ingest(t, rng.normal(size=(16, D)).astype(np.float32))
    return pipe


def test_pipeline_stats_is_a_registry_view(mesh):
    pipe = _drive_pipeline(mesh)
    stats = pipe.stats()
    reg = pipe.obs.registry

    def val(name):
        return reg.get(name).labels(cell="-").value

    assert stats["rows"] == int(val("repro_ingest_rows_total")) == 8 * 16
    assert stats["batches"] == int(val("repro_ingest_batches_total")) == 8
    assert stats["ingest_s"] == pytest.approx(val("repro_ingest_seconds_total"))
    assert pipe.publish_latency_s() == pytest.approx(
        val("repro_publish_seconds_total")
    )
    assert int(val("repro_publish_total")) == 8  # EveryKSteps(1): one per batch
    # Tenant gauges track the published state.
    ver = reg.get("repro_tenant_version")
    assert int(ver.labels(cell="-", tenant="t0").value) == pipe.stats(
        "t0"
    ).latest_version


def test_comm_report_publishes_gauges(mesh):
    # EveryKSteps(1) publishes on every batch, so the comm gauges written
    # at publish time match the tenant's live comm report exactly.
    pipe = _drive_pipeline(mesh, n_batches=2)
    fam = pipe.obs.registry.get("repro_comm_total")
    assert fam.labels(cell="-", tenant="t0").value == pipe.stats("t0").comm_total


def test_service_stats_is_a_registry_view(mesh):
    pipe = _drive_pipeline(mesh)
    x = np.random.default_rng(5).normal(size=(D,)).astype(np.float32)
    pipe.submit("t0", x)
    pipe.submit("t1", x)
    pipe.flush()
    stats = pipe.service.stats()
    reg = pipe.obs.registry

    def val(name):
        return reg.get(name).labels(cell="-").value

    assert stats.queries == int(val("repro_service_queries_total")) == 2
    assert stats.flushes == int(val("repro_service_flushes_total")) >= 1
    lat = reg.get("repro_serve_latency_seconds").labels(cell="-")
    assert lat.count == stats.flushes


# ---------------------------------------------------------------------------
# end-to-end trace anatomy + cluster registry
# ---------------------------------------------------------------------------


def _two_cell_router(mesh, clock=None):
    cells = [
        PipelineCell(f"cell-{i}", mesh, eps=0.2, policy=EveryKSteps(1))
        for i in range(2)
    ]
    transport = tp.Transport()
    router = ClusterRouter(
        cells,
        transport=transport,
        retry=RetryPolicy(max_attempts=3, base_s=0.0, cap_s=0.0),
        sleep=lambda s: None,
        clock=clock,
    )
    # t0 -> cell-0, t1..t3 -> cell-1 under the default ring (pinned by
    # the placement assert so a hash change fails loudly, not subtly).
    for i in range(4):
        router.add_tenant(f"t{i}", D, eps=0.2, policy=EveryKSteps(1))
    assert len(set(router.placement().values())) == 2
    return router


def test_query_batch_traces_as_one_tree_across_cells(mesh):
    router = _two_cell_router(mesh)
    rng = np.random.default_rng(11)
    for i in range(4):
        router.ingest(f"t{i}", rng.normal(size=(16, D)).astype(np.float32))
    res = router.query_batch(
        [(f"t{i}", rng.normal(size=(3, D)).astype(np.float32)) for i in range(4)]
    )
    assert all(r is not None for r in res)

    (root_span,) = router.obs.tracer.finished(name="router.query_batch")
    (tree,) = router.obs.tracer.tree(root_span.trace_id)  # ONE tree
    names = [n.span.name for n in tree.walk()]
    # Two cells -> two transport.message fan-out arms under one root.
    assert names == [
        "router.query_batch",
        "transport.message", "transport.send", "cell.deliver",
        "engine.query_packed",
        "transport.message", "transport.send", "cell.deliver",
        "engine.query_packed",
    ]
    assert all(n.span.duration_s > 0 for n in tree.walk())
    cells_hit = {
        n.span.attrs["cell"] for n in tree.walk()
        if n.span.name == "transport.message"
    }
    assert cells_hit == {"cell-0", "cell-1"}


def test_cluster_scrapes_as_one_registry(mesh):
    router = _two_cell_router(mesh)
    rng = np.random.default_rng(11)
    for i in range(4):
        router.ingest(f"t{i}", rng.normal(size=(16, D)).astype(np.float32))
    router.query_batch([("t0", rng.normal(size=(3, D)).astype(np.float32))])

    reg = router.obs.registry
    for cell in ("cell-0", "cell-1"):
        assert reg.get("repro_ingest_rows_total").labels(cell=cell).value > 0
    assert reg.get("repro_transport_sends_total").value == router.stats()[
        "_resilience"
    ]["transport"]["sends"]
    prom = reg.to_prometheus()
    assert 'repro_ingest_rows_total{cell="cell-0"}' in prom
    assert "repro_router_messages_total" in prom
    # The exported surface round-trips and reconciles with stats().
    rebuilt = MetricsRegistry.from_json(reg.to_json())
    assert rebuilt.to_prometheus() == prom
    res = router.stats()["_resilience"]
    assert res["attempts"] == int(
        rebuilt.get("repro_router_attempts_total").value
    )


def test_router_stats_golden_view_reconciles(mesh):
    router = _two_cell_router(mesh)
    rng = np.random.default_rng(11)
    for i in range(4):
        router.ingest(f"t{i}", rng.normal(size=(16, D)).astype(np.float32))
    stats = router.stats()
    res = stats["_resilience"]
    # Per-message accounting: no retries -> attempts == messages == sends.
    assert res["attempts"] == res["messages"] + res["retries"]
    assert res["transport"]["sends"] == res["attempts"]
    assert router.shed_counts() == {"cell-0": 0, "cell-1": 0}
    for cell in ("cell-0", "cell-1"):
        assert stats[cell]["shed"] == 0
        assert stats[cell]["ingest"]["rows"] > 0


# ---------------------------------------------------------------------------
# unified comm reports (core/comm.py)
# ---------------------------------------------------------------------------


def test_build_report_coerces_and_totals():
    import numpy as _np

    from repro.core.comm import build_report

    rep = build_report(
        scalar_msgs=_np.int32(3), row_msgs=_np.int64(5),
        broadcast_events=2.0, m=4,
    )
    assert all(
        isinstance(v, int) for v in (rep.scalar_msgs, rep.row_msgs,
                                     rep.broadcast_events, rep.m)
    )
    assert rep.total == 3 + 5 + 2 * 4
    assert rep.as_dict()["total"] == rep.total
    # Legacy TrackerSnapshot.messages key aliases still resolve.
    assert rep["scalar"] == 3 and rep["rows"] == 5 and rep["total"] == rep.total


def test_comm_report_emit_sets_labeled_gauges():
    from repro.core.comm import build_report

    reg = MetricsRegistry()
    rep = build_report(scalar_msgs=1, row_msgs=2, broadcast_events=1, m=3)
    rep.emit(reg, cell="c0", tenant="t")
    assert reg.get("repro_comm_total").labels(cell="c0", tenant="t").value == 6
    # Re-emitting overwrites (gauges snapshot cumulative protocol state).
    build_report(scalar_msgs=9, row_msgs=0, broadcast_events=0, m=3).emit(
        reg, cell="c0", tenant="t"
    )
    assert reg.get("repro_comm_scalar_msgs").labels(cell="c0", tenant="t").value == 9


def test_both_protocol_engines_report_through_build_report():
    """The two engines' counter shapes collapse to one CommReport."""
    from repro.core.distributed import CommCounters
    from repro.core.protocols import CommLog

    shard = CommCounters(scalar_msgs=4, row_msgs=6, broadcast_events=1).report(m=2)
    event = CommLog(scalar_msgs=4, item_msgs=5, sketch_rows=1,
                    broadcast_events=1).report(m=2)
    assert shard == event  # same fields, same coercion, same totals
    assert shard.total == 4 + 6 + 2
